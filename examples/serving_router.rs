//! Routed-topology demo: a replicated model plus a singleton behind a
//! `plnmf route` front, driven over one client socket.
//!
//! ```text
//!                         ┌─ worker :p1 — {news}  ┐ replicas of one model
//!   client ── route :p0 ──┼─ worker :p2 — {news}  ┘ (least-loaded pick)
//!         NDJSON/TCP      └─ worker :p3 — {faces}
//! ```
//!
//! The workers here are in-process `Server` threads addressed by
//! `host:port` — the router does not care whether a worker lives in a
//! thread, a child process, or another machine, which is exactly the
//! point of the seam. Repeating a model name in the worker list
//! declares replicas; the router routes each request to the
//! least-loaded live replica, retries idempotent ops on a sibling
//! within its budget, and answers `busy` (with a `retry_after_ms`
//! hint) when every replica is at the in-flight ceiling. The
//! `plnmf route` CLI builds the same topology with supervised
//! `plnmf serve` *processes* (crash detection, bounded-backoff
//! restart, manifest hot-reload), replicating per the manifest:
//!
//! ```sh
//! # fleet.json: {"models": [{"name": "news", "path": "...", "replicas": 2}, ...]}
//! plnmf route --models_manifest fleet.json --route_port 7900
//! ```
//!
//! Run this demo with:
//!
//! ```sh
//! cargo run --release --example serving_router
//! ```

use std::sync::Arc;

use plnmf::config::{EngineKind, RunConfig};
use plnmf::coordinator::Driver;
use plnmf::data::DataMatrix;
use plnmf::serve::{
    queries_to_json, save_model, Client, ModelMeta, ModelRegistry, ProjectorOpts, Queries,
    RegistryOpts, Router, RouterOpts, Server,
};
use plnmf::util::json::Json;

fn train(dataset: &str, k: usize, path: &std::path::Path) -> anyhow::Result<Driver> {
    let mut cfg = RunConfig::default();
    cfg.dataset = dataset.into();
    cfg.engine = EngineKind::PlNmf;
    cfg.k = k;
    cfg.max_iters = 15;
    cfg.threads = 2;
    let mut driver = Driver::from_config(&cfg)?;
    let report = driver.run()?;
    let meta = ModelMeta {
        engine: report.engine.to_string(),
        dataset: dataset.into(),
        seed: cfg.seed,
        iters: report.iters_run(),
        rel_error: report.final_rel_error,
    };
    save_model(path, driver.engine_mut().factors(), &meta)?;
    println!("trained {dataset} (k={k}): rel error {:.4}", report.final_rel_error);
    Ok(driver)
}

/// One single-model worker (the per-process shape `plnmf route` spawns,
/// here as a thread for a self-contained demo).
fn start_worker(
    name: &str,
    model: &std::path::Path,
) -> anyhow::Result<(std::net::SocketAddr, std::thread::JoinHandle<anyhow::Result<()>>)> {
    let registry = ModelRegistry::new(RegistryOpts {
        threads: 2,
        per_model_threads: 2,
        projector: ProjectorOpts { sweeps: 60, micro_batch: 16, tol: 1e-6, ..Default::default() },
        warm_cache: 256,
        max_total_nnz: 0,
    });
    registry.load(name, model)?;
    let server = Server::bind(Arc::new(registry), "127.0.0.1", 0)?;
    let addr = server.local_addr();
    println!("worker '{name}' on {addr}");
    Ok((addr, std::thread::spawn(move || server.run())))
}

fn main() -> anyhow::Result<()> {
    plnmf::util::logging::init_from_env();
    let dir = std::env::temp_dir().join(format!("plnmf-router-demo-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;

    // ---- two models; 'news' gets two replicas ----------------------------
    let driver = train("tiny-sparse", 8, &dir.join("news.json"))?;
    train("tiny", 6, &dir.join("faces.json"))?;
    let (news_a, news_a_handle) = start_worker("news", &dir.join("news.json"))?;
    let (news_b, news_b_handle) = start_worker("news", &dir.join("news.json"))?;
    let (faces_addr, faces_handle) = start_worker("faces", &dir.join("faces.json"))?;

    // ---- the routing front: repeated names declare replicas --------------
    let router = Router::with_external_workers(
        &[("news", news_a), ("news", news_b), ("faces", faces_addr)],
        RouterOpts::default(),
    )?;
    let addr = router.local_addr();
    println!(
        "router on {addr} — news -> [{news_a}, {news_b}] (2 replicas), faces -> {faces_addr}"
    );
    let router_handle = std::thread::spawn(move || router.run());

    // ---- one socket reaches every shard ----------------------------------
    let mut client = Client::connect(addr)?;
    let queries = match &driver.ds.at {
        DataMatrix::Sparse(c) => Queries::Sparse(c),
        DataMatrix::Dense(m) => Queries::Dense(m),
    };
    let req = Json::obj(vec![
        ("op", Json::str("transform")),
        ("model", Json::str("news")),
        ("queries", queries_to_json(queries)),
    ]);
    for pass in ["first", "second (least-loaded replica again)"] {
        // `request` (not `request_ok`): the busy backpressure error is a
        // well-formed `"ok": false` response the client should classify
        // and honor, not a hard failure.
        let resp = client.request(&req)?;
        if let Some(hint) = Client::busy_retry_after_ms(&resp) {
            // Backpressure path (not expected at this gentle load):
            // every replica at its in-flight ceiling.
            println!("routed transform [{pass}]: busy — retry after {hint} ms");
            continue;
        }
        anyhow::ensure!(
            resp.get("ok").as_bool() == Some(true),
            "routed transform failed: {resp}"
        );
        let warm = resp.get("warm");
        println!(
            "routed transform [{pass}]: {} docs — {} sweeps, {} cache hits",
            resp.get("h").as_arr().map(|a| a.len()).unwrap_or(0),
            warm.get("sweeps").as_usize().unwrap_or(0),
            warm.get("hits").as_usize().unwrap_or(0),
        );
    }
    let resp = client.request_ok(&Json::obj(vec![
        ("op", Json::str("recommend")),
        ("model", Json::str("faces")),
        (
            "queries",
            Json::arr(vec![Json::Arr(
                (0..60).map(|i| Json::num(if i % 7 == 0 { 1.0 } else { 0.0 })).collect(),
            )]),
        ),
        ("top", Json::num(3.0)),
    ]))?;
    println!("routed recommend on 'faces': {}", resp.get("recs"));

    // ---- aggregated stats + per-replica fleet health ---------------------
    let stats = client.request_ok(&Json::obj(vec![("op", Json::str("stats"))]))?;
    let news = stats.get("workers").get("news");
    println!(
        "router stats: {} requests, news replicas up = {}/{} (in flight {}), merged models = {}",
        stats.get("requests").as_usize().unwrap_or(0),
        news.get("up_replicas").as_usize().unwrap_or(0),
        news.get("replicas").as_usize().unwrap_or(0),
        news.get("in_flight").as_usize().unwrap_or(0),
        stats.get("models").as_obj().map(|o| o.len()).unwrap_or(0),
    );

    // ---- one shutdown drains the whole topology --------------------------
    client.request_ok(&Json::obj(vec![("op", Json::str("shutdown"))]))?;
    router_handle.join().expect("router thread")?;
    news_a_handle.join().expect("news replica 0 thread")?;
    news_b_handle.join().expect("news replica 1 thread")?;
    faces_handle.join().expect("faces worker thread")?;
    println!("router and all three workers shut down cleanly");
    std::fs::remove_dir_all(dir).ok();
    Ok(())
}

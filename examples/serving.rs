//! Serving demo: train → save → load → project → recommend.
//!
//! Trains PL-NMF briefly on the synthetic sparse corpus, persists the
//! factors, then serves them: previously "unseen" documents (here, the
//! training columns themselves) are projected onto the learned topics
//! with the cached-Gram batched solver, and the reconstruction scores
//! drive top-N recommendations.
//!
//! ```sh
//! cargo run --release --example serving
//! ```

use std::sync::Arc;

use plnmf::config::{EngineKind, RunConfig};
use plnmf::coordinator::Driver;
use plnmf::data::DataMatrix;
use plnmf::serve::{load_model, save_model, ModelMeta, Projector, ProjectorOpts, Queries};

fn main() -> anyhow::Result<()> {
    plnmf::util::logging::init_from_env();

    // ---- train ----------------------------------------------------------
    let mut cfg = RunConfig::default();
    cfg.dataset = "tiny-sparse".into();
    cfg.engine = EngineKind::PlNmf;
    cfg.k = 8;
    cfg.max_iters = 25;
    cfg.threads = 2;
    let mut driver = Driver::from_config(&cfg)?;
    let report = driver.run()?;
    println!(
        "trained {} on {}: rel error {:.4} after {} iters",
        report.engine, cfg.dataset, report.final_rel_error, report.iters_run()
    );

    // ---- save / load ----------------------------------------------------
    let path = std::env::temp_dir().join("plnmf-serving-demo.json");
    let meta = ModelMeta {
        engine: report.engine.to_string(),
        dataset: cfg.dataset.clone(),
        seed: cfg.seed,
        iters: report.iters_run(),
        rel_error: report.final_rel_error,
    };
    save_model(&path, driver.engine_mut().factors(), &meta)?;
    let (factors, meta) = load_model(&path)?;
    println!("model round-tripped through {} ({} bytes)", path.display(),
        std::fs::metadata(&path)?.len());

    // ---- serve ----------------------------------------------------------
    let pool = Arc::new(plnmf::parallel::ThreadPool::new(2));
    let opts = ProjectorOpts { sweeps: 50, micro_batch: 16, ..Default::default() };
    let projector = Projector::new(factors.w, pool, opts)?;

    let queries = match &driver.ds.at {
        DataMatrix::Sparse(c) => Queries::Sparse(c),
        DataMatrix::Dense(m) => Queries::Dense(m),
    };
    let (h, res) = projector.project_with_residuals(queries)?;
    let mean = res.iter().sum::<f64>() / res.len() as f64;
    println!(
        "projected {} docs onto {} topics (tile {}): mean rel residual {:.4}",
        h.rows(),
        projector.k(),
        projector.tile(),
        mean
    );

    let recs = projector.recommend(queries, 5, true)?;
    println!("top-5 unseen-word recommendations (model from {}):", meta.engine);
    for (i, rec) in recs.iter().take(3).enumerate() {
        let line: Vec<String> =
            rec.iter().map(|(item, score)| format!("w{item}:{score:.3}")).collect();
        println!("  doc {i}: {}", line.join("  "));
    }
    Ok(())
}

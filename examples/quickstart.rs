//! Quickstart: factorize a small synthetic corpus with PL-NMF and print
//! the convergence trace.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use plnmf::config::{EngineKind, RunConfig};
use plnmf::coordinator::Driver;

fn main() -> anyhow::Result<()> {
    plnmf::util::logging::init_from_env();

    let mut cfg = RunConfig::default();
    cfg.dataset = "20news-small".into(); // synthetic 20-Newsgroups stand-in
    cfg.engine = EngineKind::PlNmf; // the paper's tiled FAST-HALS
    cfg.k = 32; // low rank
    cfg.tile = 0; // 0 = select T from the Eq. 11 model
    cfg.max_iters = 50;
    cfg.record_every = 5;

    let mut driver = Driver::from_config(&cfg)?;
    let report = driver.run()?;

    println!("PL-NMF on {} (V={}, D={}, K={})", cfg.dataset, driver.ds.v(), driver.ds.d(), cfg.k);
    println!("{:>6} {:>12} {:>12}", "iter", "elapsed (s)", "rel error");
    for r in &report.trace {
        println!("{:>6} {:>12.4} {:>12.6}", r.iter, r.elapsed_secs, r.rel_error);
    }
    println!(
        "\nfinal relative error {:.6} after {} iterations ({:.4} s/iter)",
        report.final_rel_error,
        report.iters_run(),
        report.secs_per_iter()
    );
    println!("\nper-phase time:\n{}", report.timers.table());
    Ok(())
}

//! End-to-end reproduction driver: runs every experiment in the paper's
//! evaluation (Figs. 6–9, Table 5, the §5 model numbers, Table 4 stats)
//! on one scale and writes all raw data to `results/`.
//!
//! This is the repository's end-to-end validation entry point: it proves
//! the three layers compose — synthetic datasets (L3) → native tiled
//! engines and CSR SpMM (L3) → AOT-compiled JAX/Pallas updates through
//! PJRT (L2/L1) — on a real small workload, and prints the
//! paper-vs-measured comparison recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example full_reproduction            # small scale
//! PLNMF_SCALE=paper cargo run --release --example full_reproduction
//! ```

use std::path::Path;

use plnmf::bench::{self, Scale};
use plnmf::data::stats::{table_header, DatasetStats};

fn main() -> anyhow::Result<()> {
    plnmf::util::logging::init_from_env();
    let scale = if std::env::var("PLNMF_SCALE").map(|s| s == "paper").unwrap_or(false) {
        Scale::Paper
    } else {
        Scale::Small
    };
    let out = Path::new("results");
    let t0 = std::time::Instant::now();

    println!("=== E8 / Table 4 — dataset statistics =============================");
    println!("{}", table_header());
    for name in scale.datasets() {
        let ds = plnmf::data::load_dataset(name, 42)?;
        println!("{}", DatasetStats::of(&ds).row());
    }

    println!("\n=== E6 / §5 — data-movement model =================================");
    println!("paper: naive 300,525,600 vs tiled 44,897,687 words (6.7x) at");
    println!("       V=11314, K=160, T=15, C=35MB; model T* = 8.94/12.64/15.49");
    for k in [80, 160, 240] {
        let r = bench::model_report(11_314, k, 35 << 20);
        println!(
            "  K={:<4} T*={:<6.2} T={:<3} naive={:<12.0} tiled={:<12.0} ratio={:.1}x",
            r.k, r.t_real, r.t_selected, r.naive_volume, r.tiled_volume, r.ratio
        );
    }

    println!("\n=== E1 / Fig. 6 — tile-size sweep =================================");
    bench::fig6::run(scale, out)?;

    println!("\n=== E2+E7 / Fig. 7 — error vs time, per-iter speedup ==============");
    bench::fig7::run(scale, out)?;

    println!("\n=== E3 / Fig. 8 — error vs iterations =============================");
    bench::fig8::run(scale, out)?;

    println!("\n=== E4 / Fig. 9 — accelerated speedup at matched error ============");
    bench::fig9::run(scale, out)?;

    println!("\n=== E5 / Table 5 — W-update breakdown =============================");
    bench::table5::run(scale, out)?;

    println!(
        "\nfull reproduction done in {:.1}s — raw data in {}/",
        t0.elapsed().as_secs_f64(),
        out.display()
    );
    Ok(())
}

//! Serving-daemon demo: train two models → write a manifest → run the
//! `plnmf serve` daemon in-process → drive it over TCP/JSON.
//!
//! Shows the full multi-model flow: a fleet manifest with nnz-aware
//! admission, two models serving from their own pools, warm-start cache
//! hits cutting sweeps-to-tol on a repeated batch, the `stats` op, and a
//! clean shutdown.
//!
//! ```sh
//! cargo run --release --example serving_daemon
//! ```

use std::sync::Arc;

use plnmf::config::{EngineKind, RunConfig};
use plnmf::coordinator::Driver;
use plnmf::data::DataMatrix;
use plnmf::serve::registry::manifest_json;
use plnmf::serve::{
    queries_to_json, save_model, Client, ModelMeta, ModelRegistry, ProjectorOpts, Queries,
    RegistryOpts, Server,
};
use plnmf::util::json::Json;

fn train(dataset: &str, k: usize, path: &std::path::Path) -> anyhow::Result<Driver> {
    let mut cfg = RunConfig::default();
    cfg.dataset = dataset.into();
    cfg.engine = EngineKind::PlNmf;
    cfg.k = k;
    cfg.max_iters = 15;
    cfg.threads = 2;
    let mut driver = Driver::from_config(&cfg)?;
    let report = driver.run()?;
    let meta = ModelMeta {
        engine: report.engine.to_string(),
        dataset: dataset.into(),
        seed: cfg.seed,
        iters: report.iters_run(),
        rel_error: report.final_rel_error,
    };
    save_model(path, driver.engine_mut().factors(), &meta)?;
    println!("trained {dataset} (k={k}): rel error {:.4}, saved {path:?}", report.final_rel_error);
    Ok(driver)
}

fn main() -> anyhow::Result<()> {
    plnmf::util::logging::init_from_env();
    let dir = std::env::temp_dir().join(format!("plnmf-daemon-demo-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;

    // ---- two models + a fleet manifest ----------------------------------
    let driver = train("tiny-sparse", 8, &dir.join("news.json"))?;
    train("tiny", 6, &dir.join("faces.json"))?;
    let manifest = dir.join("manifest.json");
    std::fs::write(
        &manifest,
        manifest_json(1, 0, &[("news", "news.json"), ("faces", "faces.json")]).pretty(),
    )?;

    // ---- daemon (exactly what `plnmf serve --models_manifest` builds) ---
    let registry = ModelRegistry::from_manifest(
        &manifest,
        RegistryOpts {
            threads: 4,
            per_model_threads: 0, // threads/2 each: both models serve concurrently
            projector: ProjectorOpts {
                sweeps: 60,
                micro_batch: 16,
                tol: 1e-6,
                ..Default::default()
            },
            warm_cache: 256,
            max_total_nnz: 0,
        },
    )?;
    let server = Server::bind(Arc::new(registry), "127.0.0.1", 0)?;
    let addr = server.local_addr();
    println!("daemon listening on {addr} (models: news, faces)");
    let handle = std::thread::spawn(move || server.run());

    // ---- client: project the training docs, twice -----------------------
    let mut client = Client::connect(addr)?;
    let queries = match &driver.ds.at {
        DataMatrix::Sparse(c) => Queries::Sparse(c),
        DataMatrix::Dense(m) => Queries::Dense(m),
    };
    let req = Json::obj(vec![
        ("op", Json::str("transform")),
        ("model", Json::str("news")),
        ("queries", queries_to_json(queries)),
    ]);
    for pass in ["cold", "warm (repeat)"] {
        let resp = client.request_ok(&req)?;
        let warm = resp.get("warm");
        println!(
            "transform [{pass}]: {} docs in {:.4}s — {} sweeps / {} micro-batches, {} cache hits",
            resp.get("h").as_arr().map(|a| a.len()).unwrap_or(0),
            resp.get("secs").as_f64().unwrap_or(0.0),
            warm.get("sweeps").as_usize().unwrap_or(0),
            warm.get("micro_batches").as_usize().unwrap_or(0),
            warm.get("hits").as_usize().unwrap_or(0),
        );
    }

    // ---- PLNB v2: a dense batch over binary frames -----------------------
    // One hello upgrades the connection; transform_dense then ships the
    // batch as raw f32 frames instead of JSON text (the win grows with
    // batch size — see the binary_* rows in the serving bench). Sparse
    // queries and control ops stay JSON even after the upgrade.
    let mut bin_client = Client::connect(addr)?;
    let proto = bin_client.negotiate()?;
    let dense = plnmf::linalg::Mat::from_fn(8, 60, |i, j| ((i * 13 + j) % 5) as plnmf::Elem);
    let (h, _residuals, meta) = bin_client.transform_dense("faces", &dense, true)?;
    println!(
        "transform [PLNB v{proto}]: {} docs on 'faces' in {:.4}s over binary frames",
        h.rows(),
        meta.get("secs").as_f64().unwrap_or(0.0),
    );

    // ---- the second model answers on the same socket ---------------------
    let resp = client.request_ok(&Json::obj(vec![
        ("op", Json::str("recommend")),
        ("model", Json::str("faces")),
        (
            "queries",
            Json::arr(vec![Json::Arr(
                (0..60).map(|i| Json::num(if i % 7 == 0 { 1.0 } else { 0.0 })).collect(),
            )]),
        ),
        ("top", Json::num(3.0)),
    ]))?;
    println!("recommend on 'faces': {}", resp.get("recs"));

    // ---- stats + shutdown ------------------------------------------------
    let stats = client.request_ok(&Json::obj(vec![("op", Json::str("stats"))]))?;
    let news = stats.get("models").get("news");
    println!(
        "stats: news cold avg sweeps {:.1} vs warm {:.1} ({} requests total)",
        news.get("cold").get("avg_sweeps").as_f64().unwrap_or(0.0),
        news.get("warm").get("avg_sweeps").as_f64().unwrap_or(0.0),
        stats.get("requests").as_usize().unwrap_or(0),
    );
    client.request_ok(&Json::obj(vec![("op", Json::str("shutdown"))]))?;
    handle.join().expect("server thread")?;
    println!("daemon shut down cleanly");
    std::fs::remove_dir_all(dir).ok();
    Ok(())
}

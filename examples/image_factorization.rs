//! Parts-based image factorization — the paper's dense-matrix workload
//! (AT&T / PIE face datasets): factorize a dense image collection and
//! report reconstruction quality per rank, exercising the dense GEMM
//! path (`cblas_dgemm` in the paper) end to end.
//!
//! Optionally runs the same factorization through the XLA/Pallas
//! accelerated engine (if `make artifacts` has been run) and checks the
//! two trajectories agree.
//!
//! ```sh
//! cargo run --release --example image_factorization [-- --dataset pie-small]
//! ```

use plnmf::cli::Args;
use plnmf::config::{EngineKind, RunConfig};
use plnmf::coordinator::comparison::run_comparison;
use plnmf::coordinator::Driver;

fn main() -> anyhow::Result<()> {
    plnmf::util::logging::init_from_env();
    let args = Args::parse(std::env::args().skip(1))?;

    let dataset = args.opt("dataset").unwrap_or("pie-small").to_string();
    let iters = args.opt_usize("iters")?.unwrap_or(30);

    // Reconstruction error as a function of rank: the planted low-rank
    // structure of the image generator shows the characteristic elbow.
    println!("rank sweep on {dataset} ({iters} iters each):");
    println!("{:>6} {:>12} {:>12}", "K", "rel error", "s/iter");
    for k in [4, 8, 16, 32] {
        let mut cfg = RunConfig::default();
        cfg.dataset = dataset.clone();
        cfg.k = k;
        cfg.max_iters = iters;
        cfg.record_every = iters;
        let mut driver = Driver::from_config(&cfg)?;
        let report = driver.run()?;
        println!("{:>6} {:>12.6} {:>12.4}", k, report.final_rel_error, report.secs_per_iter());
    }

    // Accelerated engine comparison at one operating point.
    let mut cfg = RunConfig::default();
    cfg.dataset = dataset.clone();
    cfg.k = 32;
    cfg.max_iters = iters;
    cfg.record_every = 5;
    let cmp = run_comparison(&cfg, &[EngineKind::PlNmf, EngineKind::PlNmfXla])?;
    match cmp.reports.len() {
        2 => {
            let (cpu, accel) = (&cmp.reports[0], &cmp.reports[1]);
            let max_div = cpu
                .trace
                .iter()
                .zip(&accel.trace)
                .map(|(a, b)| (a.rel_error - b.rel_error).abs())
                .fold(0.0f64, f64::max);
            println!(
                "\naccelerated (XLA/Pallas) vs native at K=32: max |Δ rel err| = {max_div:.2e}"
            );
            println!(
                "native {:.4} s/iter, accelerated {:.4} s/iter",
                cpu.secs_per_iter(),
                accel.secs_per_iter()
            );
        }
        _ => {
            println!("\n(accelerated engine unavailable: {})", cmp.skipped[0].1);
            println!("run `make artifacts` to build the XLA/Pallas path");
        }
    }
    Ok(())
}

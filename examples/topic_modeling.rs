//! Topic modeling — the paper's motivating application (§1): factorize a
//! bag-of-words corpus, interpret W as word-topic loadings and H as
//! document-topic mixtures, and report topic quality diagnostics.
//!
//! Compares PL-NMF against naive FAST-HALS from the same initialization,
//! demonstrating (a) identical topics (the reorder is exact) and (b) the
//! per-iteration speedup on a sparse, Zipf-skewed matrix.
//!
//! ```sh
//! cargo run --release --example topic_modeling [-- --dataset 20news-small --k 20]
//! ```

use plnmf::cli::Args;
use plnmf::config::{EngineKind, RunConfig};
use plnmf::coordinator::comparison::run_comparison;
use plnmf::data::DataMatrix;

fn main() -> anyhow::Result<()> {
    plnmf::util::logging::init_from_env();
    let args = Args::parse(std::env::args().skip(1))?;

    let mut cfg = RunConfig::default();
    cfg.dataset = args.opt("dataset").unwrap_or("20news-small").to_string();
    cfg.k = args.opt_usize("k")?.unwrap_or(20);
    cfg.max_iters = args.opt_usize("iters")?.unwrap_or(40);
    cfg.record_every = 10;

    let cmp = run_comparison(&cfg, &[EngineKind::PlNmf, EngineKind::FastHals])?;
    let plnmf = &cmp.reports[0];
    let hals = &cmp.reports[1];

    println!(
        "topic modeling on {} — {} topics, {} iterations",
        cfg.dataset, cfg.k, cfg.max_iters
    );
    println!(
        "PL-NMF    : rel error {:.5}, {:.4} s/iter",
        plnmf.final_rel_error,
        plnmf.secs_per_iter()
    );
    println!(
        "FAST-HALS : rel error {:.5}, {:.4} s/iter  (PL-NMF speedup {:.2}x)",
        hals.final_rel_error,
        hals.secs_per_iter(),
        hals.secs_per_iter() / plnmf.secs_per_iter().max(1e-12)
    );
    println!(
        "trajectory agreement: max |Δ rel err| = {:.2e} (associativity reorder only)",
        plnmf
            .trace
            .iter()
            .zip(&hals.trace)
            .map(|(a, b)| (a.rel_error - b.rel_error).abs())
            .fold(0.0f64, f64::max)
    );

    // --- topic diagnostics from the PL-NMF factors -----------------------
    // Re-run PL-NMF to get the factors (reports don't carry them).
    let mut driver =
        plnmf::coordinator::Driver::with_dataset(&cfg, cmp.ds.clone(), cmp.pool.clone())?;
    driver.run()?;
    let f = driver.engine_mut().factors();
    let w = &f.w; // V x K word-topic loadings

    // Top words per topic (synthetic corpus: word ids; Zipf rank order
    // makes low ids "common words").
    println!("\ntop-8 word ids per topic (first 6 topics):");
    for topic in 0..cfg.k.min(6) {
        let mut idx: Vec<usize> = (0..w.rows()).collect();
        idx.sort_by(|&a, &b| w.at(b, topic).total_cmp(&w.at(a, topic)));
        let tops: Vec<String> = idx[..8].iter().map(|i| format!("w{i}")).collect();
        println!("  topic {topic:>2}: {}", tops.join(" "));
    }

    // Topic distinctness: mean pairwise cosine between topic columns
    // (lower = more distinct topics).
    let k = cfg.k;
    let mut mean_cos = 0.0f64;
    let mut pairs = 0usize;
    for i in 0..k {
        for j in (i + 1)..k {
            let mut dot = 0.0f64;
            for v in 0..w.rows() {
                dot += w.at(v, i) as f64 * w.at(v, j) as f64;
            }
            mean_cos += dot; // columns are unit-norm => dot == cosine
            pairs += 1;
        }
    }
    println!("\nmean pairwise topic cosine: {:.4} (unit-norm columns)", mean_cos / pairs as f64);

    // Document coverage: every document should load on some topic.
    let h = &f.h;
    let uncovered = (0..h.rows())
        .filter(|&d| (0..k).all(|t| h.at(d, t) <= 1e-8))
        .count();
    println!("documents with no topic mass: {uncovered} / {}", h.rows());

    if let DataMatrix::Sparse(a) = &cmp.ds.a {
        println!("corpus: {} words x {} docs, {} nnz", a.rows(), a.cols(), a.nnz());
    }
    Ok(())
}

#!/usr/bin/env bash
# Profile-guided-optimization build pipeline for the plnmf binary.
#
#   1. build an instrumented binary (-Cprofile-generate)
#   2. run the paper benches (fig6–fig9) + the serving bench as the
#      profiling workload — the same hot paths the kernels layer serves
#   3. merge the raw profiles with llvm-profdata
#   4. rebuild with -Cprofile-use
#   5. re-run a quick probe on both binaries and print a
#      warmup-vs-optimized comparison table
#
# Usage: scripts/pgo.sh [--scale small|paper] [--out-dir results-pgo]
# Requires the llvm-tools rustup component (for llvm-profdata):
#   rustup component add llvm-tools
set -euo pipefail

cd "$(dirname "$0")/.."

SCALE=small
OUT=results-pgo
while [[ $# -gt 0 ]]; do
  case "$1" in
    --scale)   SCALE="$2"; shift 2 ;;
    --out-dir) OUT="$2"; shift 2 ;;
    *) echo "unknown arg: $1" >&2; exit 2 ;;
  esac
done

PROF_DIR="$(pwd)/target/pgo-profiles"
MERGED="$PROF_DIR/merged.profdata"
BIN=target/release/plnmf
WARMUP_BIN=target/plnmf-instrumented
rm -rf "$PROF_DIR"
mkdir -p "$PROF_DIR" "$OUT"

# llvm-profdata ships in rustup's llvm-tools component, buried in the
# sysroot rather than on PATH.
SYSROOT="$(rustc --print sysroot)"
PROFDATA="$(find "$SYSROOT" -name llvm-profdata -type f | head -n1 || true)"
if [[ -z "$PROFDATA" ]]; then
  echo "llvm-profdata not found under $SYSROOT — run: rustup component add llvm-tools" >&2
  exit 1
fi

echo "== 1/5: instrumented build =="
RUSTFLAGS="-Cprofile-generate=$PROF_DIR" cargo build --release
cp "$BIN" "$WARMUP_BIN"

echo "== 2/5: profiling workload (scale=$SCALE) =="
# Single rep, no warmup: PGO wants coverage of the hot paths, not
# statistically stable timings.
for fig in fig6 fig7 fig8 fig9; do
  "$WARMUP_BIN" bench "$fig" --scale "$SCALE" --out-dir "$OUT/profile-run"
done
PLNMF_BENCH_REPS=1 PLNMF_BENCH_WARMUP=0 \
  "$WARMUP_BIN" bench serving --scale "$SCALE" --out-dir "$OUT/profile-run"

echo "== 3/5: merging $(ls "$PROF_DIR"/*.profraw | wc -l) raw profiles =="
"$PROFDATA" merge -o "$MERGED" "$PROF_DIR"/*.profraw

echo "== 4/5: optimized rebuild (-Cprofile-use) =="
RUSTFLAGS="-Cprofile-use=$MERGED -Cllvm-args=-pgo-warn-missing-function" \
  cargo build --release

echo "== 5/5: warmup-vs-optimized probe =="
# The same quick probe on both binaries: kernels microbench + fig6.
# The instrumented binary pays profiling overhead, so the honest
# baseline would be a plain release build; we time the optimized binary
# against the plain-build CSVs if present, else just print its numbers.
PLNMF_BENCH_REPS=1 PLNMF_BENCH_WARMUP=0 \
  "$WARMUP_BIN" bench kernels --scale "$SCALE" --out-dir "$OUT/warmup"
PLNMF_BENCH_REPS=1 PLNMF_BENCH_WARMUP=0 \
  "$BIN" bench kernels --scale "$SCALE" --out-dir "$OUT/optimized"

python3 scripts/perf_compare.py \
  --label-a warmup --a "$OUT/warmup/kernels_speedup.csv" \
  --label-b pgo-optimized --b "$OUT/optimized/kernels_speedup.csv" \
  --key step --metric selected_secs | tee "$OUT/perf_compare.md"

echo
echo "optimized binary: $BIN"
echo "comparison table: $OUT/perf_compare.md"

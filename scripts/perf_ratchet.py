#!/usr/bin/env python3
"""CI perf ratchet: fail when a bench row regresses past the threshold.

Compares the current bench-smoke CSVs against the previous run's
artifacts row by row (joined on each file's identity columns) and exits
non-zero when any timing column grew by more than --threshold
(default 25%). When the baseline directory or a baseline file is
missing — the first run, an expired artifact, a freshly added bench —
the affected file is reported but never fails the job, so the ratchet
bootstraps itself.

    perf_ratchet.py --baseline prev-artifacts/ --current bench-results/
    perf_ratchet.py ... --threshold 0.25 --min-secs 0.005
    perf_ratchet.py ... --report-only        # never exit non-zero

Rows whose baseline AND current time are both under --min-secs are
skipped: sub-5ms CI timings are dominated by scheduler noise and would
make the ratchet flaky. Rows present on only one side (renamed or new
benches) are reported, not failed.
"""

import argparse
import csv
import os
import sys

# file -> (identity columns, timing column; lower is better)
CHECKS = {
    "serving_daemon.csv": (["dataset", "k", "docs", "mode"], "secs"),
    "train_dist.csv": (["dataset", "k", "iters", "mode", "workers"], "secs_median"),
}


def load(path, key_cols):
    with open(path, newline="") as f:
        return {tuple(r[k] for k in key_cols): r for r in csv.DictReader(f)}


def check_file(name, base_path, cur_path, threshold, min_secs):
    """Returns (regressions, notes) for one CSV pair."""
    key_cols, metric = CHECKS[name]
    base, cur = load(base_path, key_cols), load(cur_path, key_cols)
    regressions, notes = [], []
    for k in base.keys() - cur.keys():
        notes.append(f"{name}: row {k} in baseline only (removed/renamed?)")
    for k in cur.keys() - base.keys():
        notes.append(f"{name}: row {k} is new (no baseline)")
    for k in sorted(base.keys() & cur.keys()):
        b, c = float(base[k][metric]), float(cur[k][metric])
        if b < min_secs and c < min_secs:
            continue  # below the CI noise floor
        if b <= 0:
            continue
        growth = c / b - 1.0
        line = f"{name}: {'/'.join(k)}  {metric} {b:.4f}s -> {c:.4f}s ({growth:+.0%})"
        if growth > threshold:
            regressions.append(line)
        elif growth < -threshold:
            notes.append(line + "  [improvement]")
    return regressions, notes


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="dir with the previous run's CSVs")
    ap.add_argument("--current", required=True, help="dir with this run's CSVs")
    ap.add_argument("--threshold", type=float, default=0.25, help="fail above this growth")
    ap.add_argument("--min-secs", type=float, default=0.005, help="noise floor (seconds)")
    ap.add_argument("--report-only", action="store_true", help="report, never fail")
    args = ap.parse_args()

    all_regressions = []
    for name in CHECKS:
        cur_path = os.path.join(args.current, name)
        base_path = os.path.join(args.baseline, name)
        if not os.path.exists(cur_path):
            print(f"FAIL {name}: missing from --current ({cur_path}) — did the bench run?")
            all_regressions.append(name)
            continue
        if not os.path.exists(base_path):
            print(f"INFO {name}: no baseline at {base_path} — report-only for this file")
            continue
        regressions, notes = check_file(name, base_path, cur_path, args.threshold, args.min_secs)
        for n in notes:
            print(f"NOTE {n}")
        for r in regressions:
            print(f"FAIL {r}")
        if not regressions:
            print(f"OK   {name}: no row regressed more than {args.threshold:.0%}")
        all_regressions.extend(regressions)

    if all_regressions and not args.report_only:
        print(f"\nperf ratchet: {len(all_regressions)} regression(s) past {args.threshold:.0%}")
        return 1
    if all_regressions:
        print(f"\nperf ratchet (report-only): {len(all_regressions)} would-be failure(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python3
"""Markdown comparison table for two bench CSVs sharing a schema.

Joins rows of CSV `--a` and CSV `--b` on the `--key` column(s) and
prints a markdown table of the `--metric` column side by side with the
speedup of b over a. Used by scripts/pgo.sh for its warmup-vs-optimized
report; works on any bench CSV with a numeric metric column.

    perf_compare.py --a warmup.csv --b optimized.csv \
        --key step --metric selected_secs \
        --label-a warmup --label-b pgo
"""

import argparse
import csv
import sys


def load(path, key_cols):
    with open(path, newline="") as f:
        rows = list(csv.DictReader(f))
    out = {}
    for r in rows:
        out[tuple(r[k] for k in key_cols)] = r
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--a", required=True, help="baseline CSV")
    ap.add_argument("--b", required=True, help="comparison CSV")
    ap.add_argument("--key", required=True, help="comma-separated join columns")
    ap.add_argument("--metric", required=True, help="numeric column to compare")
    ap.add_argument("--label-a", default="a")
    ap.add_argument("--label-b", default="b")
    args = ap.parse_args()

    keys = args.key.split(",")
    a, b = load(args.a, keys), load(args.b, keys)
    shared = [k for k in a if k in b]
    if not shared:
        print(f"no shared rows between {args.a} and {args.b}", file=sys.stderr)
        return 1

    head = keys + [f"{args.label_a} {args.metric}", f"{args.label_b} {args.metric}", "speedup"]
    print("| " + " | ".join(head) + " |")
    print("|" + "|".join("---" for _ in head) + "|")
    for k in shared:
        va, vb = float(a[k][args.metric]), float(b[k][args.metric])
        ratio = va / vb if vb > 0 else float("inf")
        cells = list(k) + [f"{va:.6f}", f"{vb:.6f}", f"{ratio:.2f}×"]
        print("| " + " | ".join(cells) + " |")
    for k in a.keys() - b.keys():
        print(f"only in {args.label_a}: {k}", file=sys.stderr)
    for k in b.keys() - a.keys():
        print(f"only in {args.label_b}: {k}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())

//! `cargo bench --bench fig6_tile_size` — regenerates the paper's fig6.
//! Scale via PLNMF_SCALE=small|paper (default small).

fn main() -> anyhow::Result<()> {
    plnmf::util::logging::init_from_env();
    let scale = if std::env::var("PLNMF_SCALE").map(|s| s == "paper").unwrap_or(false) {
        plnmf::bench::Scale::Paper
    } else {
        plnmf::bench::Scale::Small
    };
    plnmf::bench::fig6::run(scale, std::path::Path::new("results"))
}

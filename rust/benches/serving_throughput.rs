//! `cargo bench --bench serving_throughput` — docs/sec of batched factor
//! projection at micro-batch sizes 1/32/512 (the serving-layer
//! acceptance measurement). Scale via PLNMF_SCALE=small|paper.

fn main() -> anyhow::Result<()> {
    plnmf::util::logging::init_from_env();
    let scale = if std::env::var("PLNMF_SCALE").map(|s| s == "paper").unwrap_or(false) {
        plnmf::bench::Scale::Paper
    } else {
        plnmf::bench::Scale::Small
    };
    plnmf::bench::serving::run(scale, std::path::Path::new("results"))
}

//! Microbenchmarks of the substrates on the hot path: blocked GEMM vs
//! naive, CSR SpMM, the two HALS update kernels, and the fork-join
//! primitive. These feed the EXPERIMENTS.md §Perf log.

use plnmf::bench::harness::{measure, row, BenchOpts};
use plnmf::data::load_dataset;
use plnmf::linalg::{gemm, gemm::gemm_naive, gram, GemmOp, Mat};
use plnmf::nmf::halsops::{update_naive, update_tiled, UpdateKind};
use plnmf::parallel::ThreadPool;
use plnmf::sparse::spmm;
use plnmf::util::rng::Pcg32;
use plnmf::util::PhaseTimers;

fn main() -> anyhow::Result<()> {
    plnmf::util::logging::init_from_env();
    let opts = BenchOpts::default();
    let threads = plnmf::parallel::pool::default_threads();
    let pool = ThreadPool::new(threads);
    println!("microbench (threads={threads}, reps={}):\n", opts.reps);

    // --- GEMM: blocked-parallel vs naive (512^3) -------------------------
    let n = 512;
    let mut rng = Pcg32::seeded(1);
    let a = Mat::random(n, n, &mut rng, -1.0, 1.0);
    let b = Mat::random(n, n, &mut rng, -1.0, 1.0);
    let mut c = Mat::zeros(n, n);
    let s = measure(opts, || {
        gemm(&pool, 1.0, a.view(), b.view(), GemmOp::Assign, &mut c.view_mut())
    });
    let flops = 2.0 * (n as f64).powi(3);
    println!(
        "{}  [{:.2} GFLOP/s]",
        row(&format!("gemm blocked {n}^3"), &s),
        flops / s.median / 1e9
    );
    let s_naive = measure(BenchOpts { warmup: 0, reps: 2 }, || {
        gemm_naive(1.0, a.view(), b.view(), GemmOp::Assign, &mut c.view_mut())
    });
    println!(
        "{}  [{:.2} GFLOP/s, blocked is {:.1}x]",
        row(&format!("gemm naive   {n}^3"), &s_naive),
        flops / s_naive.median / 1e9,
        s_naive.median / s.median
    );

    // --- Gram (V x K) -----------------------------------------------------
    let x = Mat::random(20_000, 64, &mut rng, 0.0, 1.0);
    let s = measure(opts, || {
        let _ = gram(&pool, &x);
    });
    println!("{}", row("gram 20000x64", &s));

    // --- SpMM on a Zipf corpus --------------------------------------------
    let ds = load_dataset("20news-small", 42)?;
    let h = Mat::random(ds.d(), 32, &mut rng, 0.0, 1.0);
    let mut p = Mat::zeros(ds.v(), 32);
    if let plnmf::data::DataMatrix::Sparse(csr) = &ds.a {
        let s = measure(opts, || {
            spmm(&pool, 1.0, csr, &h, GemmOp::Assign, &mut p.view_mut())
        });
        println!("{}", row("spmm 20news-small x32", &s));
    }

    // --- HALS update kernels (the paper's core comparison) ----------------
    let v = 8192;
    let k = 64;
    let f = Mat::random(v, k, &mut rng, 0.0, 1.0);
    let g = gram(&pool, &f);
    let bmat = Mat::random(v, k, &mut rng, 0.0, 1.0);
    let x0 = Mat::random(v, k, &mut rng, 0.0, 1.0);
    let mut timers = PhaseTimers::new();

    let mut x = x0.clone();
    let s_naive = measure(opts, || {
        update_naive(&pool, &mut x, &g, &bmat, UpdateKind::WithDiagAndNorm, &mut timers, "dmv")
    });
    println!("{}", row(&format!("update_naive W {v}x{k}"), &s_naive));

    let mut x = x0.clone();
    let mut scratch = Mat::zeros(v, k);
    let tile = plnmf::nmf::cost_model::select_tile(k, 35 << 20);
    let s_tiled = measure(opts, || {
        update_tiled(
            &pool,
            &mut x,
            &mut scratch,
            &g,
            &bmat,
            tile,
            UpdateKind::WithDiagAndNorm,
            &mut timers,
            ["p1", "p2", "p3"],
        )
    });
    println!(
        "{}  [tiled is {:.2}x vs naive]",
        row(&format!("update_tiled W {v}x{k} T={tile}"), &s_tiled),
        s_naive.median / s_tiled.median
    );

    // --- fork/join latency -------------------------------------------------
    let s = measure(BenchOpts { warmup: 10, reps: 20 }, || {
        for _ in 0..100 {
            pool.run(&|_| {});
        }
    });
    println!("{}  [{:.1} us/fork-join]", row("pool.run x100", &s), s.median * 1e4);

    Ok(())
}

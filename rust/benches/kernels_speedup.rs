//! `cargo bench --bench kernels_speedup` — every refactored hot path
//! timed on the scalar and the runtime-selected SIMD backend in one
//! process, with the per-step ratio. Scale via PLNMF_SCALE=small|paper;
//! PLNMF_KERNELS=scalar pins the selected side to scalar (ratio ≈ 1).

fn main() -> anyhow::Result<()> {
    plnmf::util::logging::init_from_env();
    let scale = if std::env::var("PLNMF_SCALE").map(|s| s == "paper").unwrap_or(false) {
        plnmf::bench::Scale::Paper
    } else {
        plnmf::bench::Scale::Small
    };
    plnmf::bench::kernels::run(scale, std::path::Path::new("results"))
}

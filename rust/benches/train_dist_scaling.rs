//! `cargo bench --bench train_dist_scaling` — wall-clock of a fixed
//! distributed FAST-HALS run over 1/2/4 training workers (`dist_w{N}`
//! rows of results/train_dist.csv). Scale via PLNMF_SCALE=small|paper.

fn main() -> anyhow::Result<()> {
    plnmf::util::logging::init_from_env();
    let scale = if std::env::var("PLNMF_SCALE").map(|s| s == "paper").unwrap_or(false) {
        plnmf::bench::Scale::Paper
    } else {
        plnmf::bench::Scale::Small
    };
    plnmf::bench::train_dist::run(scale, std::path::Path::new("results"))
}

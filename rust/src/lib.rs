//! # PL-NMF: Parallel Locality-Optimized Non-negative Matrix Factorization
//!
//! A full reproduction of Moon et al., *PL-NMF: Parallel Locality-Optimized
//! Non-negative Matrix Factorization* (2019), built as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the parallel coordinator: dataset handling,
//!   the leader/worker shared-memory runtime, the native-rust NMF engines
//!   (FAST-HALS, PL-NMF tiled, MU, ANLS-BPP), the PJRT runtime that executes
//!   AOT-compiled update graphs, and the benchmark harness that regenerates
//!   every table and figure of the paper's evaluation.
//! * **Layer 2** — `python/compile/model.py`: the PL-NMF / baseline update
//!   steps expressed in JAX, lowered once to HLO text (`make artifacts`).
//! * **Layer 1** — `python/compile/kernels/`: Pallas kernels for the panel
//!   GEMMs (phases 1/3) and the in-tile sequential column update (phase 2),
//!   mirroring Algorithms 2–5 of the paper.
//!
//! Python never runs on the request path: the `plnmf` binary is
//! self-contained once `artifacts/` exist.
//!
//! ## Quick start
//!
//! ```no_run
//! use plnmf::config::RunConfig;
//! use plnmf::coordinator::Driver;
//!
//! let mut cfg = RunConfig::default();
//! cfg.dataset = "20news-small".into();
//! cfg.k = 32;
//! cfg.max_iters = 50;
//! let report = Driver::from_config(&cfg).unwrap().run().unwrap();
//! println!("final relative error: {}", report.final_rel_error);
//! ```

pub mod util;
pub mod kernels;
pub mod parallel;
pub mod config;
pub mod linalg;
pub mod sparse;
pub mod data;
pub mod nmf;
pub mod coordinator;
pub mod runtime;
pub mod serve;
pub mod dist;
pub mod bench;
pub mod testing;
pub mod cli;

/// Library-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// The floating point element type used throughout the library.
///
/// The paper's CPU code is double precision (dgemm); we use `f32` so the
/// native engines are bit-comparable with the XLA/Pallas path (TPUs are
/// f32/bf16 machines). Reductions that are sensitive to accumulation
/// order (column norms, objective values) accumulate in `f64`.
pub type Elem = f32;

/// The ε floor of the non-negativity projection `max(ε, ·)` (Alg. 1).
pub const EPS: Elem = 1e-16;

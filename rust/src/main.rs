//! `plnmf` — leader entrypoint / CLI for the PL-NMF reproduction.
//!
//! Subcommand dispatch lives in `plnmf::bench::cli_main` so the examples
//! and integration tests can drive the exact same code paths.
//! See `plnmf help` for the command list.

use anyhow::Result;

fn main() -> Result<()> {
    plnmf::util::logging::init_from_env();
    let args = plnmf::cli::Args::from_env()?;
    plnmf::bench::cli_main(args)
}

//! `plnmf serve` — a long-lived TCP daemon over the [`ModelRegistry`].
//!
//! PR 1's `transform` / `recommend` CLI pays model load + Gram build on
//! every invocation, which defeats the cached-Gram design: the §5
//! data-movement savings only compound when the factors stay resident
//! across requests. This daemon keeps every registered model's Ŵ, Gram,
//! thread pool, and warm cache alive and answers requests over a
//! deliberately boring protocol: **newline-delimited JSON over TCP**,
//! std-only, parsed with [`crate::util::json`] — one request object per
//! line in, one response object per line out.
//!
//! ## Protocol
//!
//! Every request is `{"op": ..., ...}`; every response carries
//! `"ok": true|false` (plus `"error"` on failure). Ops:
//!
//! | op | request | response |
//! |----|---------|----------|
//! | `transform` | `model`, `queries`, [`warm`=true] | `h` (m×K), `residuals`, `warm` counters |
//! | `recommend` | `model`, `queries`, [`top`=10], [`exclude_seen`=false], [`warm`=true] | `recs`: per query `[item, score]` pairs |
//! | `update` | `model`, `queries`, [`sweeps`] | `epoch`, `rows_seen` — folds the batch into the factors and publishes epoch N+1 |
//! | `stats` | — | uptime, request count, per-model epoch/sweep/warm counters |
//! | `load` | `name` + `path`, or neither (manifest reload) | `loaded` / `reloaded` |
//! | `unload` | `name` | — |
//! | `ping` | — | `pong` |
//! | `hello` | [`proto`] | negotiated `proto` (see below) |
//! | `shutdown` | — | `bye`, then the daemon drains and exits |
//!
//! Frames are capped at [`MAX_LINE_BYTES`]; an oversized frame gets a
//! protocol error and the connection closed (never unbounded buffering
//! or a hung read loop — fuzzed in `tests/prop_protocol_fuzz.rs`), and
//! a frame that is not UTF-8 gets the distinct `invalid utf-8 in frame`
//! error instead of a lossy best-guess parse.
//!
//! ## PLNB v2 (binary dense batches)
//!
//! `{"op": "hello", "proto": 2}` upgrades the connection to the
//! [`crate::serve::wire`] binary framing for dense `transform` /
//! `recommend` / `update` batches and the `transform` response matrix — raw f32
//! little-endian behind a 20-byte header instead of JSON text, because
//! JSON encode/decode dominates round-trip time for large dense batches
//! (the paper's data-movement argument, off-chip). Sparse queries and
//! every control op stay JSON on a v2 connection; without the hello the
//! protocol is bit-for-bit v1.
//!
//! `queries` is either dense rows (`[[...V numbers...], ...]`) or sparse
//! rows (`[{"cols": [...], "vals": [...]}, ...]`); both deserialize into
//! the same [`Queries`] the in-process API takes, so a daemon round-trip
//! is **bit-identical** to calling [`crate::serve::Projector`] directly
//! (JSON numbers are f64, which carries f32 exactly, and PLNB carries
//! the f32 bits themselves; asserted in `tests/integration_daemon.rs`).
//! Batches flow through the projector's nnz-balanced micro-batching
//! unchanged.
//!
//! ## Concurrency
//!
//! One OS thread per connection parses and serializes; actual solves run
//! on each model's own [`crate::parallel::ThreadPool`] behind that
//! model's queue (see [`crate::serve::registry`]), so two models serve
//! concurrently without oversubscribing the machine while requests for
//! one model queue fairly behind each other.
//!
//! The accept loop also polls the attached manifest (every ~2 s) and
//! hot-reloads the fleet when its `version` increases.

use std::io::{BufReader, ErrorKind};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context};

use crate::dist::TrainStore;
use crate::linalg::Mat;
use crate::serve::projector::{ProjectStats, Queries};
use crate::serve::registry::ModelRegistry;
use crate::serve::wire::{
    self, handle_hello, read_wire, serve_wire, BinFrame, BinOp, WirePayload, WireRead,
    MAX_FRAME_BYTES,
};
use crate::sparse::Csr;
use crate::util::json::Json;
use crate::util::Timer;
use crate::{Elem, Result};

pub(crate) use crate::serve::wire::{err_json, ok_obj};

/// How often the accept loop checks the manifest for a version bump.
const MANIFEST_POLL: Duration = Duration::from_secs(2);
/// How long `run` waits for in-flight connections after `shutdown`.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(2);

/// Historical name of the frame cap (one NDJSON line or one binary
/// frame) — see [`crate::serve::wire::MAX_FRAME_BYTES`].
pub const MAX_LINE_BYTES: usize = MAX_FRAME_BYTES;

struct Shared {
    stop: AtomicBool,
    requests: AtomicU64,
    active: AtomicUsize,
    started: Instant,
    addr: SocketAddr,
}

/// A bound (not yet running) daemon.
pub struct Server {
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    shared: Arc<Shared>,
    /// Resident distributed-training state (shards + H panels), empty
    /// until a coordinator sends `shard-load` frames. Every daemon can
    /// host training jobs; `--train_worker` daemons host nothing else.
    train: Arc<TrainStore>,
}

impl Server {
    /// Bind `host:port` (port 0 = OS-assigned; read it back via
    /// [`Self::local_addr`]).
    pub fn bind(registry: Arc<ModelRegistry>, host: &str, port: u16) -> Result<Server> {
        let listener = TcpListener::bind((host, port))
            .with_context(|| format!("binding {host}:{port}"))?;
        let addr = listener.local_addr().context("reading bound address")?;
        Ok(Server {
            listener,
            registry,
            shared: Arc::new(Shared {
                stop: AtomicBool::new(false),
                requests: AtomicU64::new(0),
                active: AtomicUsize::new(0),
                started: Instant::now(),
                addr,
            }),
            train: Arc::new(TrainStore::new()),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Accept loop: blocks until a client sends `shutdown`, then drains
    /// in-flight connections (bounded) and returns. A background thread
    /// polls the manifest every [`MANIFEST_POLL`] — off the accept path,
    /// so an idle daemon still hot-reloads and a slow model rebuild
    /// never stalls incoming connections.
    pub fn run(self) -> Result<()> {
        let poller = {
            let registry = Arc::clone(&self.registry);
            let shared = Arc::clone(&self.shared);
            std::thread::spawn(move || {
                let tick = Duration::from_millis(100);
                let mut since_poll = Duration::ZERO;
                while !shared.stop.load(Ordering::SeqCst) {
                    std::thread::sleep(tick);
                    since_poll += tick;
                    if since_poll >= MANIFEST_POLL {
                        since_poll = Duration::ZERO;
                        if let Err(e) = registry.reload_manifest() {
                            crate::warn_!("serve: manifest reload failed: {e:#}");
                        }
                    }
                }
            })
        };
        let accepted: Result<()> = loop {
            let (stream, peer) = match self.listener.accept() {
                Ok(x) => x,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => break Err(e).context("accepting connection"),
            };
            if self.shared.stop.load(Ordering::SeqCst) {
                break Ok(());
            }
            crate::debug!("serve: connection from {peer}");
            let registry = Arc::clone(&self.registry);
            let shared = Arc::clone(&self.shared);
            let train = Arc::clone(&self.train);
            shared.active.fetch_add(1, Ordering::SeqCst);
            std::thread::spawn(move || {
                handle_connection(stream, &registry, &shared, &train);
                shared.active.fetch_sub(1, Ordering::SeqCst);
            });
        };
        // Every exit path — clean shutdown or accept failure — stops the
        // poller (it would otherwise re-read the manifest forever in
        // embedded users like the bench) and drains handlers, bounded.
        self.shared.stop.store(true, Ordering::SeqCst);
        let _ = poller.join();
        let deadline = Instant::now() + DRAIN_TIMEOUT;
        while self.shared.active.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        accepted?;
        crate::info!(
            "serve: shut down after {} requests",
            self.shared.requests.load(Ordering::SeqCst)
        );
        Ok(())
    }
}

fn handle_connection(stream: TcpStream, registry: &ModelRegistry, shared: &Shared, train: &TrainStore) {
    serve_wire(stream, &shared.requests, shared.addr, |payload, conn| match payload {
        WirePayload::Line(line) => {
            let trimmed = line.trim();
            match parse_request(trimmed) {
                Ok(req) => {
                    let op = req.get("op").as_str().unwrap_or("");
                    if op == "hello" {
                        // Connection-layer negotiation, not a registry
                        // op: after this, PLNB frames are recognized.
                        return (
                            WirePayload::Line(handle_hello(&req, conn).to_string()),
                            false,
                        );
                    }
                    let is_shutdown = op == "shutdown";
                    (
                        WirePayload::Line(dispatch(&req, registry, shared).to_string()),
                        is_shutdown,
                    )
                }
                Err(e) => (
                    WirePayload::Line(err_json(format!("bad request: {e}")).to_string()),
                    false,
                ),
            }
        }
        WirePayload::Binary(bytes) => (dispatch_binary(bytes, registry, train), false),
    });
}

/// Parse one request line: exactly one JSON value, trailing whitespace
/// allowed (the streaming `parse_prefix` leaves the rest to us). Shared
/// with the shard router, which inspects requests before forwarding.
pub(crate) fn parse_request(line: &str) -> Result<Json> {
    let (v, consumed) = Json::parse_prefix(line).map_err(|e| anyhow!("{e}"))?;
    if !line[consumed..].trim().is_empty() {
        bail!("trailing characters after the JSON request");
    }
    Ok(v)
}

fn dispatch(req: &Json, registry: &ModelRegistry, shared: &Shared) -> Json {
    let op = req.get("op").as_str().unwrap_or("");
    let result = match op {
        "ping" => Ok(ok_obj(vec![("pong", Json::Bool(true))])),
        "transform" => op_transform(req, registry),
        "recommend" => op_recommend(req, registry),
        "update" => op_update(req, registry),
        "stats" => Ok(op_stats(registry, shared)),
        "load" => op_load(req, registry),
        "unload" => op_unload(req, registry),
        "shutdown" => {
            shared.stop.store(true, Ordering::SeqCst);
            Ok(ok_obj(vec![("bye", Json::Bool(true))]))
        }
        "" => Err(anyhow!("request needs an \"op\" string")),
        other => Err(anyhow!(
            "unknown op '{other}' (try transform|recommend|update|stats|load|unload|ping|hello|shutdown)"
        )),
    };
    result.unwrap_or_else(|e| err_json(format!("{e:#}")))
}

/// Decode and answer one PLNB v2 frame. Errors come back as JSON lines
/// (no JSON value starts with the magic byte, so a client can never
/// confuse the framings); only the `transform` and `sweep` responses
/// ride binary.
fn dispatch_binary(bytes: &[u8], registry: &ModelRegistry, train: &TrainStore) -> WirePayload {
    let result = wire::decode(bytes).and_then(|frame| match frame.op {
        BinOp::Transform => op_transform_binary(frame, registry),
        BinOp::Recommend => op_recommend_binary(frame, registry),
        BinOp::Update => op_update_binary(frame, registry),
        BinOp::ShardLoad => crate::dist::worker::op_shard_load(frame, train),
        BinOp::Sweep => crate::dist::worker::op_sweep(frame, train),
        BinOp::SweepMu => crate::dist::worker::op_sweep_mu(frame, train),
        BinOp::GridSweepA => crate::dist::worker::op_grid_a(frame, train),
        BinOp::GridSweepB => crate::dist::worker::op_grid_b(frame, train),
        BinOp::TransformResp | BinOp::GramResp => {
            Err(anyhow!("unexpected PLNB response frame in a request"))
        }
    });
    result.unwrap_or_else(|e| WirePayload::Line(err_json(format!("{e:#}")).to_string()))
}

// ---------------------------------------------------------------------------
// Query (de)serialization.
// ---------------------------------------------------------------------------

/// Owned deserialized query batch (requests outlive no borrow).
pub enum OwnedQueries {
    Dense(Mat),
    Sparse(Csr),
}

impl OwnedQueries {
    pub fn as_queries(&self) -> Queries<'_> {
        match self {
            OwnedQueries::Dense(m) => Queries::Dense(m),
            OwnedQueries::Sparse(c) => Queries::Sparse(c),
        }
    }
}

/// An optional non-negative integer field of a request: absent →
/// `default`; present but negative / fractional / overflowing → a loud
/// error (see [`Json::get_usize_or`]). A client sending `"top": -1`
/// must hear about it, never silently get the default.
pub(crate) fn opt_usize(req: &Json, key: &str, default: usize) -> Result<usize> {
    req.get_usize_or(key, default).map_err(|e| anyhow!(e))
}

/// Deserialize a request's `queries` against a model with `v` features.
fn parse_queries(req: &Json, v: usize) -> Result<OwnedQueries> {
    let rows = req
        .get("queries")
        .as_arr()
        .ok_or_else(|| anyhow!("request needs \"queries\": an array of rows"))?;
    if rows.is_empty() {
        bail!("empty query batch");
    }
    match &rows[0] {
        Json::Arr(_) => {
            let mut data: Vec<Elem> = Vec::with_capacity(rows.len() * v);
            for (i, row) in rows.iter().enumerate() {
                let vals = row
                    .as_arr()
                    .ok_or_else(|| anyhow!("queries[{i}]: expected a dense row array"))?;
                if vals.len() != v {
                    bail!("queries[{i}] has {} entries, model expects V={v}", vals.len());
                }
                for (j, x) in vals.iter().enumerate() {
                    let x = x
                        .as_f64()
                        .ok_or_else(|| anyhow!("queries[{i}][{j}] is not a number"))?;
                    if !x.is_finite() {
                        bail!("queries[{i}][{j}] = {x} is not finite");
                    }
                    data.push(x as Elem);
                }
            }
            Ok(OwnedQueries::Dense(Mat::from_vec(rows.len(), v, data)))
        }
        Json::Obj(_) => {
            let mut triplets: Vec<(usize, usize, Elem)> = Vec::new();
            for (i, row) in rows.iter().enumerate() {
                let cols = row
                    .get("cols")
                    .as_arr()
                    .ok_or_else(|| anyhow!("queries[{i}] needs \"cols\""))?;
                let vals = row
                    .get("vals")
                    .as_arr()
                    .ok_or_else(|| anyhow!("queries[{i}] needs \"vals\""))?;
                if cols.len() != vals.len() {
                    bail!(
                        "queries[{i}]: {} cols but {} vals",
                        cols.len(),
                        vals.len()
                    );
                }
                for (c, x) in cols.iter().zip(vals) {
                    let c = c
                        .as_usize()
                        .ok_or_else(|| anyhow!("queries[{i}]: bad column index {c}"))?;
                    if c >= v {
                        bail!("queries[{i}]: column {c} out of range (V={v})");
                    }
                    let x = x
                        .as_f64()
                        .ok_or_else(|| anyhow!("queries[{i}]: non-numeric value"))?;
                    if !x.is_finite() {
                        bail!("queries[{i}]: value {x} is not finite");
                    }
                    triplets.push((i, c, x as Elem));
                }
            }
            Ok(OwnedQueries::Sparse(Csr::from_triplets(rows.len(), v, triplets)))
        }
        _ => bail!(
            "queries rows must be dense arrays ([[...]]) or sparse objects \
             ([{{\"cols\": [...], \"vals\": [...]}}])"
        ),
    }
}

/// Validate a binary request's batch against the model and move its
/// payload into a dense query matrix (no copy — the frame is consumed).
fn binary_queries(frame: BinFrame, v: usize) -> Result<Mat> {
    if frame.rows == 0 {
        bail!("empty query batch");
    }
    if frame.cols != v {
        bail!(
            "binary batch is {}x{}, model expects V={v}",
            frame.rows,
            frame.cols
        );
    }
    if let Some(i) = frame.data.iter().position(|x| !x.is_finite()) {
        bail!("binary batch value {i} is not finite");
    }
    Ok(Mat::from_vec(frame.rows, frame.cols, frame.data))
}

/// Serialize a query batch into the protocol's `queries` value — the
/// client-side counterpart of the daemon's parser (used by the bench,
/// the example, and the integration tests).
pub fn queries_to_json(q: Queries<'_>) -> Json {
    match q {
        Queries::Dense(m) => Json::Arr(
            (0..m.rows())
                .map(|i| Json::Arr(m.row(i).iter().map(|&x| Json::Num(x as f64)).collect()))
                .collect(),
        ),
        Queries::Sparse(a) => Json::Arr(
            (0..a.rows())
                .map(|i| {
                    let (cols, vals) = a.row(i);
                    Json::obj(vec![
                        (
                            "cols",
                            Json::Arr(cols.iter().map(|&c| Json::num(c as f64)).collect()),
                        ),
                        (
                            "vals",
                            Json::Arr(vals.iter().map(|&v| Json::num(v as f64)).collect()),
                        ),
                    ])
                })
                .collect(),
        ),
    }
}

fn mat_rows_json(m: &Mat) -> Json {
    Json::Arr(
        (0..m.rows())
            .map(|i| Json::Arr(m.row(i).iter().map(|&x| Json::Num(x as f64)).collect()))
            .collect(),
    )
}

/// Parse a response's row-of-rows matrix (e.g. `"h"`) back into exact
/// f32s — the inverse of [`mat_rows_json`], shared by the protocol
/// client and the tests.
pub fn mat_from_json_rows(rows: &Json) -> Result<Mat> {
    let rows = rows.as_arr().ok_or_else(|| anyhow!("expected an array of rows"))?;
    let cols = rows.first().and_then(|r| r.as_arr()).map(|r| r.len()).unwrap_or(0);
    let mut data: Vec<Elem> = Vec::with_capacity(rows.len() * cols);
    for (i, row) in rows.iter().enumerate() {
        let row = row.as_arr().ok_or_else(|| anyhow!("row {i} is not an array"))?;
        if row.len() != cols {
            bail!("row {i} has {} entries, row 0 has {cols}", row.len());
        }
        for x in row {
            data.push(x.as_f64().ok_or_else(|| anyhow!("row {i} has a non-number"))? as Elem);
        }
    }
    Ok(Mat::from_vec(rows.len(), cols, data))
}

fn warm_json(ps: &ProjectStats) -> Json {
    Json::obj(vec![
        ("hits", Json::num(ps.warm_hits as f64)),
        ("misses", Json::num(ps.warm_misses as f64)),
        ("sweeps", Json::num(ps.sweeps as f64)),
        ("micro_batches", Json::num(ps.micro_batches as f64)),
    ])
}

// ---------------------------------------------------------------------------
// Ops.
// ---------------------------------------------------------------------------

fn op_transform(req: &Json, registry: &ModelRegistry) -> Result<Json> {
    let name = req
        .get("model")
        .as_str()
        .ok_or_else(|| anyhow!("transform needs \"model\""))?;
    let entry = registry.get(name)?;
    let q = parse_queries(req, entry.projector().v())?;
    let warm = req.get("warm").as_bool().unwrap_or(true);
    let t = Timer::start();
    let (h, res, ps) = entry.transform(q.as_queries(), warm)?;
    Ok(ok_obj(vec![
        ("model", Json::str(name)),
        ("h", mat_rows_json(&h)),
        ("residuals", Json::Arr(res.iter().map(|&x| Json::Num(x)).collect())),
        ("warm", warm_json(&ps)),
        ("secs", Json::num(t.elapsed_secs())),
    ]))
}

/// The binary twin of [`op_transform`]: raw f32 batch in, raw f32 `h`
/// out, with residuals/counters riding the response meta segment.
fn op_transform_binary(frame: BinFrame, registry: &ModelRegistry) -> Result<WirePayload> {
    let entry = registry.get(&frame.model)?;
    let name = frame.model.clone();
    let warm = frame.meta.get("warm").as_bool().unwrap_or(true);
    let q = binary_queries(frame, entry.projector().v())?;
    let t = Timer::start();
    let (h, res, ps) = entry.transform(Queries::Dense(&q), warm)?;
    let meta = ok_obj(vec![
        ("model", Json::str(name)),
        ("residuals", Json::Arr(res.iter().map(|&x| Json::Num(x)).collect())),
        ("warm", warm_json(&ps)),
        ("secs", Json::num(t.elapsed_secs())),
    ]);
    let out = wire::encode(BinOp::TransformResp, "", &meta, h.rows(), h.cols(), h.data())?;
    Ok(WirePayload::Binary(out))
}

fn recs_json(recs: &[Vec<(u32, Elem)>]) -> Json {
    Json::Arr(
        recs.iter()
            .map(|rec| {
                Json::Arr(
                    rec.iter()
                        .map(|&(item, score)| {
                            Json::Arr(vec![Json::num(item as f64), Json::Num(score as f64)])
                        })
                        .collect(),
                )
            })
            .collect(),
    )
}

/// The shared recommend response shape — identical whether the request
/// arrived as JSON or as a PLNB frame (top-N pairs are small, so the
/// response stays JSON on both protocols).
fn recommend_response(name: &str, recs: &[Vec<(u32, Elem)>], ps: &ProjectStats, secs: f64) -> Json {
    ok_obj(vec![
        ("model", Json::str(name)),
        ("recs", recs_json(recs)),
        ("warm", warm_json(ps)),
        ("secs", Json::num(secs)),
    ])
}

fn op_recommend(req: &Json, registry: &ModelRegistry) -> Result<Json> {
    let name = req
        .get("model")
        .as_str()
        .ok_or_else(|| anyhow!("recommend needs \"model\""))?;
    let entry = registry.get(name)?;
    let q = parse_queries(req, entry.projector().v())?;
    let top = opt_usize(req, "top", 10)?;
    let exclude_seen = req.get("exclude_seen").as_bool().unwrap_or(false);
    let warm = req.get("warm").as_bool().unwrap_or(true);
    let t = Timer::start();
    let (recs, ps) = entry.recommend(q.as_queries(), top, exclude_seen, warm)?;
    Ok(recommend_response(name, &recs, &ps, t.elapsed_secs()))
}

fn op_recommend_binary(frame: BinFrame, registry: &ModelRegistry) -> Result<WirePayload> {
    let entry = registry.get(&frame.model)?;
    let name = frame.model.clone();
    let top = opt_usize(&frame.meta, "top", 10)?;
    let exclude_seen = frame.meta.get("exclude_seen").as_bool().unwrap_or(false);
    let warm = frame.meta.get("warm").as_bool().unwrap_or(true);
    let q = binary_queries(frame, entry.projector().v())?;
    let t = Timer::start();
    let (recs, ps) = entry.recommend(Queries::Dense(&q), top, exclude_seen, warm)?;
    Ok(WirePayload::Line(
        recommend_response(&name, &recs, &ps, t.elapsed_secs()).to_string(),
    ))
}

/// The shared update response shape — identical whether the batch
/// arrived as JSON or as a PLNB frame (the response — an epoch number
/// and a few counters — is a small JSON object on both protocols).
fn update_response(name: &str, out: &crate::serve::registry::UpdateOutcome, secs: f64) -> Json {
    ok_obj(vec![
        ("model", Json::str(name)),
        ("epoch", Json::num(out.epoch as f64)),
        ("rows_seen", Json::num(out.rows_seen as f64)),
        ("warm", warm_json(&out.stats)),
        ("secs", Json::num(secs)),
    ])
}

/// An optional `sweeps` override: absent → the registry's configured
/// `update_sweeps`; present → strict non-negative parse (0 is rejected
/// downstream by the fold, loudly).
fn opt_sweeps(meta: &Json) -> Result<Option<usize>> {
    match meta.get("sweeps") {
        Json::Null => Ok(None),
        _ => Ok(Some(opt_usize(meta, "sweeps", 0)?)),
    }
}

fn op_update(req: &Json, registry: &ModelRegistry) -> Result<Json> {
    let name = req
        .get("model")
        .as_str()
        .ok_or_else(|| anyhow!("update needs \"model\""))?;
    let entry = registry.get(name)?;
    let q = parse_queries(req, entry.projector().v())?;
    let sweeps = opt_sweeps(req)?;
    let t = Timer::start();
    let out = registry.update(name, q.as_queries(), sweeps)?;
    Ok(update_response(name, &out, t.elapsed_secs()))
}

/// The binary twin of [`op_update`]: raw f32 batch in, small JSON line
/// out (mixed framing, like binary errors and `recommend` responses).
fn op_update_binary(frame: BinFrame, registry: &ModelRegistry) -> Result<WirePayload> {
    let entry = registry.get(&frame.model)?;
    let name = frame.model.clone();
    let sweeps = opt_sweeps(&frame.meta)?;
    let q = binary_queries(frame, entry.projector().v())?;
    let t = Timer::start();
    let out = registry.update(&name, Queries::Dense(&q), sweeps)?;
    Ok(WirePayload::Line(
        update_response(&name, &out, t.elapsed_secs()).to_string(),
    ))
}

fn op_stats(registry: &ModelRegistry, shared: &Shared) -> Json {
    ok_obj(vec![
        ("uptime_secs", Json::num(shared.started.elapsed().as_secs_f64())),
        ("requests", Json::num(shared.requests.load(Ordering::SeqCst) as f64)),
        ("manifest_version", Json::num(registry.manifest_version() as f64)),
        ("admission_budget", Json::num(registry.admission_budget() as f64)),
        ("total_nnz", Json::num(registry.total_nnz() as f64)),
        // The kernel backend this process selects for new pools (env
        // override + CPU detection); per-model pools report their own
        // backend inside `models`.
        ("kernels", Json::str(crate::kernels::Kernels::select().name())),
        ("models", registry.stats_json()),
    ])
}

fn op_load(req: &Json, registry: &ModelRegistry) -> Result<Json> {
    match (req.get("name").as_str(), req.get("path").as_str()) {
        (Some(name), Some(path)) => {
            let entry = registry.load(name, std::path::Path::new(path))?;
            Ok(ok_obj(vec![
                ("loaded", Json::str(name)),
                ("nnz", Json::num(entry.nnz() as f64)),
            ]))
        }
        (None, None) => {
            let reloaded = registry.reload_manifest()?;
            Ok(ok_obj(vec![
                ("reloaded", Json::Bool(reloaded)),
                ("manifest_version", Json::num(registry.manifest_version() as f64)),
            ]))
        }
        _ => bail!("load needs both \"name\" and \"path\" (or neither, to re-read the manifest)"),
    }
}

fn op_unload(req: &Json, registry: &ModelRegistry) -> Result<Json> {
    let name = req
        .get("name")
        .as_str()
        .ok_or_else(|| anyhow!("unload needs \"name\""))?;
    registry.unload(name)?;
    Ok(ok_obj(vec![("unloaded", Json::str(name))]))
}

// ---------------------------------------------------------------------------
// Client.
// ---------------------------------------------------------------------------

/// Marker carried in the rendered message of every [`Client`] error
/// where the peer vanished after the request was (or may have been)
/// sent but before a complete response frame arrived — the Display
/// prefix of [`ClientError::ClosedMidResponse`]. Kept public for
/// callers classifying errors that crossed an `anyhow` context chain
/// (see [`Client::is_connection_closed`]); first-class callers match
/// the [`ClientError`] enum instead. The distinction matters to callers
/// like the router's pooled client: a closed-mid-response request may
/// have been processed by the peer and must NOT be blindly retried —
/// it is surfaced as a retryable error instead.
pub const CLOSED_MID_RESPONSE: &str = "connection closed mid-response";

/// The typed failure classes of the [`Client`] request methods
/// (`request_raw` / `request` / `request_ok` / [`DenseCall::send`]).
/// Callers match variants instead of probing marker strings; the
/// Display forms reproduce the historical message texts exactly, so
/// errors converted into `anyhow` chains (every `?` at an `anyhow`
/// call site still compiles, via the blanket `From`) render as before.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClientError {
    /// The router's backpressure signal: every live replica of the
    /// model is at its in-flight ceiling. The right reaction is to
    /// delay `retry_after_ms` (or shed the request), not to hammer
    /// the shard.
    Busy { retry_after_ms: u64 },
    /// The peer vanished after the request was (or may have been)
    /// written but before a complete response frame arrived. The
    /// request may have been processed — never blindly retry it on a
    /// non-idempotent op. The payload is the transport detail.
    ClosedMidResponse(String),
    /// The exchange itself is broken — a malformed or oversized
    /// response frame, unexpected framing, a poisoned connection, or
    /// a daemon-level refusal (`"ok": false` without retry semantics).
    Protocol(String),
    /// A failure that is safe to retry (on this or another replica):
    /// the request provably never reached the peer (write failures),
    /// or the peer explicitly answered `"retryable": true`.
    Retryable(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Busy { retry_after_ms } => {
                write!(f, "daemon busy: retry after {retry_after_ms} ms")
            }
            ClientError::ClosedMidResponse(detail) => {
                write!(f, "{CLOSED_MID_RESPONSE} ({detail})")
            }
            ClientError::Protocol(msg) | ClientError::Retryable(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for ClientError {}

impl ClientError {
    /// Classify a parsed `"ok": false` response: busy/backpressure with
    /// its hint, an explicitly retryable refusal, or a plain daemon
    /// error (the two latter render as the historical
    /// `daemon error: ...` text).
    fn from_response(resp: &Json) -> ClientError {
        if let Some(ms) = Client::busy_retry_after_ms(resp) {
            return ClientError::Busy { retry_after_ms: ms };
        }
        let msg = format!(
            "daemon error: {}",
            resp.get("error").as_str().unwrap_or("(no error message)")
        );
        if resp.get("retryable").as_bool() == Some(true) {
            ClientError::Retryable(msg)
        } else {
            ClientError::Protocol(msg)
        }
    }
}

/// Result of the typed [`Client`] request methods ([`crate::Result`]
/// is the one-parameter `anyhow` alias, so the typed-error results
/// spell their own shorthand).
pub type ClientResult<T> = std::result::Result<T, ClientError>;

/// A blocking protocol client: one request frame out, one response
/// frame in. Used by the daemon bench, the router's per-shard pools,
/// the example, the integration tests, and anyone driving the daemon
/// from Rust. Starts on the v1 NDJSON protocol; [`Self::negotiate`]
/// upgrades to PLNB v2 binary framing where the peer supports it, with
/// a transparent v1 fallback where it does not.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    proto: u8,
    /// Set when a failed [`Self::negotiate`] leaves the connection's
    /// framing state unknowable (hello possibly half-written, or its
    /// reply half-read). A poisoned client refuses further requests:
    /// pooled callers must drop and redial instead of reusing a socket
    /// whose next bytes could be misparsed under either framing.
    poisoned: bool,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to plnmf daemon")?;
        let reader = BufReader::new(stream.try_clone().context("cloning stream")?);
        Ok(Client { reader, writer: stream, proto: 1, poisoned: false })
    }

    /// [`Self::connect`] with a bounded dial: a blackholed peer fails
    /// after `timeout` instead of the OS connect timeout (minutes).
    /// Used by latency-sensitive callers like the router's stats probe.
    pub fn connect_timeout(addr: &SocketAddr, timeout: Duration) -> Result<Client> {
        let stream = TcpStream::connect_timeout(addr, timeout)
            .context("connecting to plnmf daemon")?;
        let reader = BufReader::new(stream.try_clone().context("cloning stream")?);
        Ok(Client { reader, writer: stream, proto: 1, poisoned: false })
    }

    /// Whether a failed negotiate has poisoned this connection (see the
    /// field doc; poisoned clients fail every request fast).
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    /// The protocol this connection is on (1 until a successful
    /// [`Self::negotiate`] lands on 2).
    pub fn proto(&self) -> u8 {
        self.proto
    }

    /// Offer the daemon a `hello {"proto": 2}` upgrade and adopt
    /// whatever it answers. A peer that rejects the op entirely (a
    /// pre-v2 daemon answering `unknown op 'hello'`) leaves the client
    /// on v1 — the auto-upgrade is always safe to attempt. Transport
    /// failures are real errors.
    pub fn negotiate(&mut self) -> Result<u8> {
        let resp = match self.request(&Json::obj(vec![
            ("op", Json::str("hello")),
            ("proto", Json::num(wire::PROTO_MAX as f64)),
        ])) {
            Ok(resp) => resp,
            Err(e) => {
                // The hello may be half-written or its reply half-read;
                // nothing about this socket's framing can be trusted
                // now. Refuse reuse rather than risk desynced frames.
                self.poisoned = true;
                return Err(e.into());
            }
        };
        self.proto = if resp.get("ok").as_bool() == Some(true)
            && resp.get("proto").as_u64() == Some(wire::PROTO_MAX)
        {
            2
        } else {
            1
        };
        Ok(self.proto)
    }

    /// Whether `err` is the distinct "connection closed mid-response"
    /// failure (EOF or a read error after the request was written), as
    /// opposed to a connect failure, a write failure, or a response
    /// that parsed but carried `"ok": false`. Generic over the error's
    /// Display so it accepts both a [`ClientError`] and an
    /// `anyhow::Error` that wrapped one under contexts (`{:#}` renders
    /// the full chain in either case). On a [`ClientError`] in hand,
    /// matching [`ClientError::ClosedMidResponse`] is the direct form.
    pub fn is_connection_closed<E: std::fmt::Display>(err: &E) -> bool {
        format!("{err:#}").contains(CLOSED_MID_RESPONSE)
    }

    /// Whether a parsed response is the router's backpressure signal
    /// (`"busy": true` — every live replica of the model is at its
    /// in-flight ceiling). Returns the server's `Retry-After`-style
    /// hint in milliseconds; the right client reaction is to delay
    /// that long (or shed the request), not to hammer the shard.
    pub fn busy_retry_after_ms(resp: &Json) -> Option<u64> {
        if resp.get("busy").as_bool() == Some(true) {
            Some(resp.get("retry_after_ms").as_u64().unwrap_or(0))
        } else {
            None
        }
    }

    /// Bound how long reads may block (None = forever). Applies to the
    /// underlying socket, so it also covers in-flight `request` calls.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(timeout).context("setting read timeout")
    }

    /// Read one response frame (line or, on a v2 connection, binary).
    fn read_response(&mut self) -> ClientResult<WirePayload> {
        match read_wire(&mut self.reader, MAX_FRAME_BYTES, self.proto >= 2) {
            Ok(WireRead::Payload(p)) => Ok(p),
            Ok(WireRead::Eof) => {
                Err(ClientError::ClosedMidResponse("EOF before a response frame".into()))
            }
            Ok(WireRead::Partial(n)) => Err(ClientError::ClosedMidResponse(format!(
                "EOF after {n} bytes of an incomplete response frame"
            ))),
            Ok(WireRead::TooLong(n)) => Err(ClientError::Protocol(format!(
                "response frame exceeds {MAX_FRAME_BYTES} bytes ({n} read or declared)"
            ))),
            Ok(WireRead::Bad { msg, .. }) => {
                Err(ClientError::Protocol(format!("bad response frame: {msg}")))
            }
            Err(e) => Err(ClientError::ClosedMidResponse(format!("{e}"))),
        }
    }

    fn check_not_poisoned(&self) -> ClientResult<()> {
        if self.poisoned {
            return Err(ClientError::Protocol(
                "connection poisoned by a failed negotiate; drop it and reconnect".into(),
            ));
        }
        Ok(())
    }

    /// Send one already-serialized request line and return the raw
    /// response line, bytes untouched — the router's forwarding path
    /// (relaying the worker's exact bytes is what keeps routed
    /// responses bit-for-bit identical to a single daemon's).
    pub fn request_raw(&mut self, line: &str) -> ClientResult<String> {
        self.check_not_poisoned()?;
        wire::write_line(&mut self.writer, line)
            .map_err(|e| ClientError::Retryable(format!("writing request: {e}")))?;
        match self.read_response()? {
            WirePayload::Line(resp) => Ok(resp),
            WirePayload::Binary(_) => Err(ClientError::Protocol(
                "unexpected binary response frame to a JSON request".into(),
            )),
        }
    }

    /// Send one request frame of either framing and return the raw
    /// response frame — the router's relay path for v2 connections.
    pub(crate) fn request_wire(&mut self, req: &WirePayload) -> ClientResult<WirePayload> {
        self.check_not_poisoned()?;
        req.write_to(&mut self.writer)
            .map_err(|e| ClientError::Retryable(format!("writing request: {e}")))?;
        self.read_response()
    }

    /// Send one request, read one response (whatever its `ok`).
    pub fn request(&mut self, req: &Json) -> ClientResult<Json> {
        let resp = self.request_raw(&req.to_string())?;
        Json::parse(resp.trim())
            .map_err(|e| ClientError::Protocol(format!("bad response JSON: {e}")))
    }

    /// [`Self::request`], classifying `"ok": false` responses into the
    /// typed [`ClientError`] variants (busy/backpressure with its
    /// retry hint, explicitly retryable refusals, plain daemon errors).
    pub fn request_ok(&mut self, req: &Json) -> ClientResult<Json> {
        let resp = self.request(req)?;
        if resp.get("ok").as_bool() != Some(true) {
            return Err(ClientError::from_response(&resp));
        }
        Ok(resp)
    }

    /// One dense `transform` round trip on the negotiated framing:
    /// PLNB v2 binary frames after a successful [`Self::negotiate`],
    /// the v1 JSON encoding otherwise — same answer either way (parity
    /// asserted in the integration tests). Thin wrapper over
    /// [`DenseCall`]. Returns `(h, residuals, response meta)`.
    pub fn transform_dense(
        &mut self,
        model: &str,
        queries: &Mat,
        warm: bool,
    ) -> Result<(Mat, Vec<f64>, Json)> {
        let reply = DenseCall::new(BinOp::Transform, model, queries)
            .meta("warm", Json::Bool(warm))
            .send(self)?;
        let h = match reply.matrix {
            Some(m) => m,
            None => mat_from_json_rows(reply.resp.get("h"))?,
        };
        let residuals = reply
            .resp
            .get("residuals")
            .as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
            .unwrap_or_default();
        Ok((h, residuals, reply.resp))
    }

    /// One dense `recommend` round trip on the negotiated framing (the
    /// response — small top-N pairs — is a JSON object on both
    /// protocols). Thin wrapper over [`DenseCall`]. Returns the parsed
    /// response.
    pub fn recommend_dense(
        &mut self,
        model: &str,
        queries: &Mat,
        top: usize,
        exclude_seen: bool,
        warm: bool,
    ) -> Result<Json> {
        let reply = DenseCall::new(BinOp::Recommend, model, queries)
            .meta("top", Json::num(top as f64))
            .meta("exclude_seen", Json::Bool(exclude_seen))
            .meta("warm", Json::Bool(warm))
            .send(self)?;
        Ok(reply.resp)
    }

    /// One dense `update` round trip on the negotiated framing (the
    /// response — an epoch number and counters — is a JSON object on
    /// both protocols). `sweeps: None` uses the daemon's configured
    /// `update_sweeps`. Thin wrapper over [`DenseCall`]. Returns the
    /// parsed response carrying the new factor `epoch`.
    pub fn update_dense(
        &mut self,
        model: &str,
        queries: &Mat,
        sweeps: Option<usize>,
    ) -> Result<Json> {
        let mut call = DenseCall::new(BinOp::Update, model, queries);
        if let Some(s) = sweeps {
            call = call.meta("sweeps", Json::num(s as f64));
        }
        Ok(call.send(self)?.resp)
    }
}

/// One typed dense request against a daemon: an op, a target model, a
/// dense row-major query block, and op-specific meta fields. This is
/// the single client surface behind [`Client::transform_dense`],
/// [`Client::recommend_dense`], and [`Client::update_dense`] — it picks
/// the negotiated framing (PLNB v2 binary after [`Client::negotiate`],
/// the v1 JSON encoding otherwise) and classifies every failure into a
/// [`ClientError`].
///
/// ```ignore
/// let reply = DenseCall::new(BinOp::Transform, "model", &queries)
///     .meta("warm", Json::Bool(true))
///     .send(&mut client)?;
/// ```
pub struct DenseCall<'a> {
    op: BinOp,
    model: &'a str,
    queries: &'a Mat,
    meta: Vec<(&'static str, Json)>,
}

/// What a [`DenseCall`] came back with: the dense response matrix when
/// the daemon answered with a binary frame (`transform` on v2), plus
/// the response JSON (the frame meta on v2, the whole response on v1).
pub struct DenseReply {
    pub matrix: Option<Mat>,
    pub resp: Json,
}

impl<'a> DenseCall<'a> {
    /// A dense request. `op` must be one of the request ops
    /// ([`BinOp::Transform`], [`BinOp::Recommend`], [`BinOp::Update`]);
    /// anything else fails at [`Self::send`] with
    /// [`ClientError::Protocol`].
    pub fn new(op: BinOp, model: &'a str, queries: &'a Mat) -> Self {
        DenseCall { op, model, queries, meta: Vec::new() }
    }

    /// Append one op-specific meta field (`warm`, `top`, `sweeps`, …).
    /// Order is preserved into the encoded request, so wrappers emit
    /// byte-identical frames to the pre-builder encoding.
    pub fn meta(mut self, key: &'static str, value: Json) -> Self {
        self.meta.push((key, value));
        self
    }

    /// Run the round trip on `client`'s negotiated framing.
    pub fn send(self, client: &mut Client) -> ClientResult<DenseReply> {
        let DenseCall { op, model, queries, meta } = self;
        let name = match op {
            BinOp::Transform => "transform",
            BinOp::Recommend => "recommend",
            BinOp::Update => "update",
            other => {
                return Err(ClientError::Protocol(format!(
                    "PLNB op {other:?} is not a dense request op"
                )))
            }
        };
        if client.proto >= 2 {
            let frame = wire::encode(
                op,
                model,
                &Json::obj(meta),
                queries.rows(),
                queries.cols(),
                queries.data(),
            )
            .map_err(|e| ClientError::Protocol(format!("{e:#}")))?;
            match client.request_wire(&WirePayload::Binary(frame))? {
                WirePayload::Binary(bytes) => {
                    if op != BinOp::Transform {
                        return Err(ClientError::Protocol(format!(
                            "unexpected binary response frame to a {name} request"
                        )));
                    }
                    let f = wire::decode(&bytes)
                        .map_err(|e| ClientError::Protocol(format!("{e:#}")))?;
                    if f.op != BinOp::TransformResp {
                        return Err(ClientError::Protocol(format!(
                            "unexpected PLNB op in a {name} response"
                        )));
                    }
                    Ok(DenseReply {
                        matrix: Some(Mat::from_vec(f.rows, f.cols, f.data)),
                        resp: f.meta,
                    })
                }
                WirePayload::Line(s) => {
                    let resp = Json::parse(s.trim())
                        .map_err(|e| ClientError::Protocol(format!("bad response JSON: {e}")))?;
                    if resp.get("ok").as_bool() != Some(true) {
                        return Err(ClientError::from_response(&resp));
                    }
                    Ok(DenseReply { matrix: None, resp })
                }
            }
        } else {
            let mut fields = vec![
                ("op", Json::str(name)),
                ("model", Json::str(model)),
                ("queries", queries_to_json(Queries::Dense(queries))),
            ];
            fields.extend(meta);
            let resp = client.request_ok(&Json::obj(fields))?;
            Ok(DenseReply { matrix: None, resp })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A failed negotiate must poison the connection: the hello frame
    /// is in an unknowable half-sent state, so any later request on the
    /// same socket could be misparsed under either framing. Regression
    /// for pooled clients (the router) lazily negotiating on a live
    /// socket and then reusing it after the upgrade failed.
    #[test]
    fn failed_negotiate_poisons_the_connection() {
        use std::io::Read;

        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            // Accept, read the hello bytes, then hang up without
            // answering — a daemon dying mid-negotiate.
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = [0u8; 64];
            let _ = s.read(&mut buf);
        });

        let mut client = Client::connect(addr).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        assert!(!client.is_poisoned());
        let err = client.negotiate().unwrap_err();
        assert!(Client::is_connection_closed(&err), "unexpected failure class: {err:#}");
        assert!(client.is_poisoned());

        // Every later request fails fast with the distinct marker —
        // no bytes are written to the dead socket.
        let err = format!("{:#}", client.request_raw("{\"op\":\"ping\"}").unwrap_err());
        assert!(err.contains("poisoned"), "{err}");
        let err = format!(
            "{:#}",
            client.request_wire(&WirePayload::Line("{\"op\":\"ping\"}".into())).unwrap_err()
        );
        assert!(err.contains("poisoned"), "{err}");
        server.join().unwrap();
    }

    #[test]
    fn parse_queries_dense_and_sparse() {
        let dense = Json::parse(r#"{"queries": [[1, 0, 2], [0, 0, 0]]}"#).unwrap();
        match parse_queries(&dense, 3).unwrap() {
            OwnedQueries::Dense(m) => {
                assert_eq!((m.rows(), m.cols()), (2, 3));
                assert_eq!(m.at(0, 2), 2.0);
            }
            _ => panic!("expected dense"),
        }
        let sparse =
            Json::parse(r#"{"queries": [{"cols": [0, 2], "vals": [1.5, 2.5]}]}"#).unwrap();
        match parse_queries(&sparse, 3).unwrap() {
            OwnedQueries::Sparse(c) => {
                assert_eq!((c.rows(), c.cols()), (1, 3));
                assert_eq!(c.row(0).0, &[0, 2]);
            }
            _ => panic!("expected sparse"),
        }
    }

    #[test]
    fn parse_queries_rejects_malformed_batches() {
        for (src, v) in [
            (r#"{"queries": []}"#, 3),
            (r#"{"queries": [[1, 2]]}"#, 3),            // wrong width
            (r#"{"queries": [[1, "x", 2]]}"#, 3),       // non-numeric
            (r#"{"queries": [{"cols": [5], "vals": [1]}]}"#, 3), // col out of range
            (r#"{"queries": [{"cols": [0, 1], "vals": [1]}]}"#, 3), // length mismatch
            (r#"{"queries": [3]}"#, 3),                 // bad row type
            (r#"{"nope": 1}"#, 3),                      // missing key
        ] {
            let req = Json::parse(src).unwrap();
            assert!(parse_queries(&req, v).is_err(), "should reject {src}");
        }
    }

    #[test]
    fn queries_roundtrip_through_protocol_encoding() {
        let m = Mat::from_fn(3, 4, |i, j| if (i + j) % 2 == 0 { (i * 4 + j) as Elem } else { 0.0 });
        let req = Json::obj(vec![("queries", queries_to_json(Queries::Dense(&m)))]);
        match parse_queries(&req, 4).unwrap() {
            OwnedQueries::Dense(re) => assert_eq!(re, m),
            _ => panic!("dense in, dense out"),
        }
        let c = Csr::from_dense(&m);
        let req = Json::obj(vec![("queries", queries_to_json(Queries::Sparse(&c)))]);
        match parse_queries(&req, 4).unwrap() {
            OwnedQueries::Sparse(re) => assert_eq!(re, c),
            _ => panic!("sparse in, sparse out"),
        }
    }

    #[test]
    fn mat_from_json_rows_inverts_mat_rows_json() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as Elem * 0.25);
        let re = mat_from_json_rows(&mat_rows_json(&m)).unwrap();
        assert_eq!(re, m);
        assert!(mat_from_json_rows(&Json::parse("[[1], [1, 2]]").unwrap()).is_err());
        assert!(mat_from_json_rows(&Json::parse("[[1], \"x\"]").unwrap()).is_err());
        assert!(mat_from_json_rows(&Json::parse("3").unwrap()).is_err());
    }

    #[test]
    fn request_line_parsing_rejects_trailing_junk() {
        assert!(parse_request(r#"{"op": "ping"}"#).is_ok());
        assert!(parse_request("{\"op\": \"ping\"}  ").is_ok());
        assert!(parse_request(r#"{"op": "ping"} {"op": "ping"}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn optional_integers_are_strict_when_present() {
        // Regression for the silent-coercion class: a present-but-bogus
        // count must error, never quietly become the default.
        let ok = Json::parse(r#"{"top": 5}"#).unwrap();
        assert_eq!(opt_usize(&ok, "top", 10).unwrap(), 5);
        let absent = Json::parse(r#"{"other": 1}"#).unwrap();
        assert_eq!(opt_usize(&absent, "top", 10).unwrap(), 10);
        for bad in [r#"{"top": -1}"#, r#"{"top": 2.7}"#, r#"{"top": 1e300}"#, r#"{"top": "5"}"#] {
            let req = Json::parse(bad).unwrap();
            let err = format!("{:#}", opt_usize(&req, "top", 10).unwrap_err());
            assert!(err.contains("top"), "{bad}: {err}");
        }
    }

    #[test]
    fn closed_mid_response_is_classified_distinctly() {
        let closed = anyhow!("{CLOSED_MID_RESPONSE} (EOF before a response frame)")
            .context("forwarding to shard 'a'");
        assert!(Client::is_connection_closed(&closed));
        let other = anyhow!("bad response JSON: oops").context("forwarding to shard 'a'");
        assert!(!Client::is_connection_closed(&other));
    }

    #[test]
    fn busy_responses_are_classified_with_their_hint() {
        let busy = Json::parse(
            r#"{"ok": false, "busy": true, "retryable": true, "retry_after_ms": 75}"#,
        )
        .unwrap();
        assert_eq!(Client::busy_retry_after_ms(&busy), Some(75));
        let retryable = Json::parse(r#"{"ok": false, "retryable": true}"#).unwrap();
        assert_eq!(Client::busy_retry_after_ms(&retryable), None);
        let ok = Json::parse(r#"{"ok": true}"#).unwrap();
        assert_eq!(Client::busy_retry_after_ms(&ok), None);
    }

    /// Accept one connection, read one request line, answer `reply` (or
    /// hang up unanswered when `None`), then drop the socket.
    fn one_shot(reply: Option<&'static str>) -> SocketAddr {
        use std::io::{BufRead, Write};
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            let _ = r.read_line(&mut line);
            if let Some(text) = reply {
                let mut w = s;
                let _ = writeln!(w, "{text}");
                let _ = w.flush();
            }
        });
        addr
    }

    /// Every [`ClientError`] variant is reachable over a real socket and
    /// carries the legacy message text through `Display` — callers that
    /// matched on strings keep working, callers that match on the enum
    /// get the classification.
    #[test]
    fn client_errors_classify_over_a_live_socket() {
        let ping = Json::obj(vec![("op", Json::str("ping"))]);
        let rt = Some(Duration::from_secs(5));

        // Busy: ok:false + busy:true carries the server's retry hint.
        let addr = one_shot(Some(
            r#"{"ok": false, "busy": true, "retryable": true, "retry_after_ms": 75, "error": "all replicas busy"}"#,
        ));
        let mut c = Client::connect(addr).unwrap();
        c.set_read_timeout(rt).unwrap();
        match c.request_ok(&ping).unwrap_err() {
            ClientError::Busy { retry_after_ms } => assert_eq!(retry_after_ms, 75),
            other => panic!("expected Busy, got {other:?}"),
        }

        // ClosedMidResponse: request written, peer hangs up unanswered.
        let addr = one_shot(None);
        let mut c = Client::connect(addr).unwrap();
        c.set_read_timeout(rt).unwrap();
        let err = c.request_ok(&ping).unwrap_err();
        assert!(matches!(err, ClientError::ClosedMidResponse(_)), "{err:?}");
        assert!(Client::is_connection_closed(&err));
        assert_eq!(
            err.to_string(),
            "connection closed mid-response (EOF before a response frame)"
        );

        // Protocol: a reply that is not JSON at all.
        let addr = one_shot(Some("not json"));
        let mut c = Client::connect(addr).unwrap();
        c.set_read_timeout(rt).unwrap();
        let err = c.request_ok(&ping).unwrap_err();
        assert!(matches!(err, ClientError::Protocol(_)), "{err:?}");
        assert!(err.to_string().contains("bad response JSON"), "{err}");
        assert!(!Client::is_connection_closed(&err));

        // Retryable: an ok:false the daemon flags as worth retrying.
        let addr = one_shot(Some(r#"{"ok": false, "retryable": true, "error": "replica restarting"}"#));
        let mut c = Client::connect(addr).unwrap();
        c.set_read_timeout(rt).unwrap();
        match c.request_ok(&ping).unwrap_err() {
            ClientError::Retryable(msg) => {
                assert_eq!(msg, "daemon error: replica restarting");
            }
            other => panic!("expected Retryable, got {other:?}"),
        }

        // Plain daemon errors stay Protocol with the legacy text.
        let addr = one_shot(Some(r#"{"ok": false, "error": "unknown model 'ghost'"}"#));
        let mut c = Client::connect(addr).unwrap();
        c.set_read_timeout(rt).unwrap();
        match c.request_ok(&ping).unwrap_err() {
            ClientError::Protocol(msg) => {
                assert_eq!(msg, "daemon error: unknown model 'ghost'");
            }
            other => panic!("expected Protocol, got {other:?}"),
        }
    }
}

//! `plnmf serve` — a long-lived TCP daemon over the [`ModelRegistry`].
//!
//! PR 1's `transform` / `recommend` CLI pays model load + Gram build on
//! every invocation, which defeats the cached-Gram design: the §5
//! data-movement savings only compound when the factors stay resident
//! across requests. This daemon keeps every registered model's Ŵ, Gram,
//! thread pool, and warm cache alive and answers requests over a
//! deliberately boring protocol: **newline-delimited JSON over TCP**,
//! std-only, parsed with [`crate::util::json`] — one request object per
//! line in, one response object per line out.
//!
//! ## Protocol
//!
//! Every request is `{"op": ..., ...}`; every response carries
//! `"ok": true|false` (plus `"error"` on failure). Ops:
//!
//! | op | request | response |
//! |----|---------|----------|
//! | `transform` | `model`, `queries`, [`warm`=true] | `h` (m×K), `residuals`, `warm` counters |
//! | `recommend` | `model`, `queries`, [`top`=10], [`exclude_seen`=false], [`warm`=true] | `recs`: per query `[item, score]` pairs |
//! | `stats` | — | uptime, request count, per-model sweep/warm counters |
//! | `load` | `name` + `path`, or neither (manifest reload) | `loaded` / `reloaded` |
//! | `unload` | `name` | — |
//! | `ping` | — | `pong` |
//! | `shutdown` | — | `bye`, then the daemon drains and exits |
//!
//! Lines are capped at [`MAX_LINE_BYTES`]; an oversized frame gets a
//! protocol error and the connection closed (never unbounded buffering
//! or a hung read loop — fuzzed in `tests/prop_protocol_fuzz.rs`).
//!
//! `queries` is either dense rows (`[[...V numbers...], ...]`) or sparse
//! rows (`[{"cols": [...], "vals": [...]}, ...]`); both deserialize into
//! the same [`Queries`] the in-process API takes, so a daemon round-trip
//! is **bit-identical** to calling [`crate::serve::Projector`] directly
//! (JSON numbers are f64, which carries f32 exactly; asserted in
//! `tests/integration_daemon.rs`). Batches flow through the projector's
//! nnz-balanced micro-batching unchanged.
//!
//! ## Concurrency
//!
//! One OS thread per connection parses and serializes; actual solves run
//! on each model's own [`crate::parallel::ThreadPool`] behind that
//! model's queue (see [`crate::serve::registry`]), so two models serve
//! concurrently without oversubscribing the machine while requests for
//! one model queue fairly behind each other.
//!
//! The accept loop also polls the attached manifest (every ~2 s) and
//! hot-reloads the fleet when its `version` increases.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context};

use crate::linalg::Mat;
use crate::serve::projector::Queries;
use crate::serve::registry::ModelRegistry;
use crate::sparse::Csr;
use crate::util::json::Json;
use crate::util::Timer;
use crate::{Elem, Result};

/// How often the accept loop checks the manifest for a version bump.
const MANIFEST_POLL: Duration = Duration::from_secs(2);
/// How long `run` waits for in-flight connections after `shutdown`.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(2);

/// Hard cap on one protocol line (request or response). A peer that
/// streams more than this without a newline gets a protocol error and
/// the connection closed — never unbounded buffering or a hung read
/// loop. 64 MiB clears the largest dense batch the bench ships by two
/// orders of magnitude.
pub const MAX_LINE_BYTES: usize = 64 * 1024 * 1024;

/// Outcome of one bounded frame read.
pub(crate) enum FrameRead {
    /// A complete newline-terminated line (without its newline).
    Frame(String),
    /// The stream ended mid-line: whatever arrived before the close.
    /// NOT a complete frame — the peer died (or sent a final unflushed
    /// fragment), and treating the bytes as an answer would hand a
    /// truncated response to a caller as if it were whole.
    Partial(String),
    /// The peer exceeded the byte cap before sending a newline; the
    /// payload carries how many bytes were consumed.
    TooLong(usize),
    /// Clean end of stream before any byte of a new frame.
    Eof,
}

/// Move the frame bytes into a `String`, copying only in the (never on
/// our own wire) invalid-UTF-8 case — frames run up to [`MAX_LINE_BYTES`].
fn into_frame_string(buf: Vec<u8>) -> String {
    String::from_utf8(buf)
        .unwrap_or_else(|e| String::from_utf8_lossy(e.as_bytes()).into_owned())
}

/// Read one newline-delimited frame with a byte cap: the codec
/// underneath the daemon, the router, and the protocol client.
pub(crate) fn read_frame(r: &mut impl BufRead, max: usize) -> std::io::Result<FrameRead> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = r.fill_buf()?;
        if chunk.is_empty() {
            return Ok(if buf.is_empty() {
                FrameRead::Eof
            } else {
                FrameRead::Partial(into_frame_string(buf))
            });
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => {
                buf.extend_from_slice(&chunk[..i]);
                r.consume(i + 1);
                if buf.len() > max {
                    return Ok(FrameRead::TooLong(buf.len()));
                }
                return Ok(FrameRead::Frame(into_frame_string(buf)));
            }
            None => {
                let n = chunk.len();
                buf.extend_from_slice(chunk);
                r.consume(n);
                if buf.len() > max {
                    return Ok(FrameRead::TooLong(buf.len()));
                }
            }
        }
    }
}

/// The shared per-connection serve loop (daemon and router): bounded
/// frame reads, one response line per request line, oversized-frame
/// protocol error + close, empty lines skipped. `dispatch` maps one
/// trimmed request line to `(response line, is_shutdown)`; on shutdown
/// the loop wakes the accept loop at `wake_addr` so it observes the
/// stop flag, then closes. A `Partial` read means the peer died
/// mid-line — nothing to answer.
pub(crate) fn serve_lines(
    stream: TcpStream,
    requests: &AtomicU64,
    wake_addr: SocketAddr,
    mut dispatch: impl FnMut(&str) -> (String, bool),
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_frame(&mut reader, MAX_LINE_BYTES) {
            Ok(FrameRead::Frame(line)) => line,
            Ok(FrameRead::TooLong(n)) => {
                requests.fetch_add(1, Ordering::SeqCst);
                let mut out = err_json(format!(
                    "request line exceeds {MAX_LINE_BYTES} bytes ({n} read); closing connection"
                ))
                .to_string();
                out.push('\n');
                let _ = writer.write_all(out.as_bytes());
                break;
            }
            Ok(FrameRead::Partial(_)) | Ok(FrameRead::Eof) | Err(_) => break,
        };
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        requests.fetch_add(1, Ordering::SeqCst);
        let (mut out, is_shutdown) = dispatch(trimmed);
        out.push('\n');
        if writer.write_all(out.as_bytes()).is_err() {
            break;
        }
        if is_shutdown {
            let _ = TcpStream::connect(wake_addr);
            break;
        }
    }
}

struct Shared {
    stop: AtomicBool,
    requests: AtomicU64,
    active: AtomicUsize,
    started: Instant,
    addr: SocketAddr,
}

/// A bound (not yet running) daemon.
pub struct Server {
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    shared: Arc<Shared>,
}

impl Server {
    /// Bind `host:port` (port 0 = OS-assigned; read it back via
    /// [`Self::local_addr`]).
    pub fn bind(registry: Arc<ModelRegistry>, host: &str, port: u16) -> Result<Server> {
        let listener = TcpListener::bind((host, port))
            .with_context(|| format!("binding {host}:{port}"))?;
        let addr = listener.local_addr().context("reading bound address")?;
        Ok(Server {
            listener,
            registry,
            shared: Arc::new(Shared {
                stop: AtomicBool::new(false),
                requests: AtomicU64::new(0),
                active: AtomicUsize::new(0),
                started: Instant::now(),
                addr,
            }),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Accept loop: blocks until a client sends `shutdown`, then drains
    /// in-flight connections (bounded) and returns. A background thread
    /// polls the manifest every [`MANIFEST_POLL`] — off the accept path,
    /// so an idle daemon still hot-reloads and a slow model rebuild
    /// never stalls incoming connections.
    pub fn run(self) -> Result<()> {
        let poller = {
            let registry = Arc::clone(&self.registry);
            let shared = Arc::clone(&self.shared);
            std::thread::spawn(move || {
                let tick = Duration::from_millis(100);
                let mut since_poll = Duration::ZERO;
                while !shared.stop.load(Ordering::SeqCst) {
                    std::thread::sleep(tick);
                    since_poll += tick;
                    if since_poll >= MANIFEST_POLL {
                        since_poll = Duration::ZERO;
                        if let Err(e) = registry.reload_manifest() {
                            crate::warn_!("serve: manifest reload failed: {e:#}");
                        }
                    }
                }
            })
        };
        let accepted: Result<()> = loop {
            let (stream, peer) = match self.listener.accept() {
                Ok(x) => x,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => break Err(e).context("accepting connection"),
            };
            if self.shared.stop.load(Ordering::SeqCst) {
                break Ok(());
            }
            crate::debug!("serve: connection from {peer}");
            let registry = Arc::clone(&self.registry);
            let shared = Arc::clone(&self.shared);
            shared.active.fetch_add(1, Ordering::SeqCst);
            std::thread::spawn(move || {
                handle_connection(stream, &registry, &shared);
                shared.active.fetch_sub(1, Ordering::SeqCst);
            });
        };
        // Every exit path — clean shutdown or accept failure — stops the
        // poller (it would otherwise re-read the manifest forever in
        // embedded users like the bench) and drains handlers, bounded.
        self.shared.stop.store(true, Ordering::SeqCst);
        let _ = poller.join();
        let deadline = Instant::now() + DRAIN_TIMEOUT;
        while self.shared.active.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        accepted?;
        crate::info!(
            "serve: shut down after {} requests",
            self.shared.requests.load(Ordering::SeqCst)
        );
        Ok(())
    }
}

fn handle_connection(stream: TcpStream, registry: &ModelRegistry, shared: &Shared) {
    serve_lines(stream, &shared.requests, shared.addr, |trimmed| {
        match parse_request(trimmed) {
            Ok(req) => {
                let is_shutdown = req.get("op").as_str() == Some("shutdown");
                (dispatch(&req, registry, shared).to_string(), is_shutdown)
            }
            Err(e) => (err_json(format!("bad request: {e}")).to_string(), false),
        }
    });
}

/// Parse one request line: exactly one JSON value, trailing whitespace
/// allowed (the streaming `parse_prefix` leaves the rest to us). Shared
/// with the shard router, which inspects requests before forwarding.
pub(crate) fn parse_request(line: &str) -> Result<Json> {
    let (v, consumed) = Json::parse_prefix(line).map_err(|e| anyhow!("{e}"))?;
    if !line[consumed..].trim().is_empty() {
        bail!("trailing characters after the JSON request");
    }
    Ok(v)
}

fn dispatch(req: &Json, registry: &ModelRegistry, shared: &Shared) -> Json {
    let op = req.get("op").as_str().unwrap_or("");
    let result = match op {
        "ping" => Ok(ok_obj(vec![("pong", Json::Bool(true))])),
        "transform" => op_transform(req, registry),
        "recommend" => op_recommend(req, registry),
        "stats" => Ok(op_stats(registry, shared)),
        "load" => op_load(req, registry),
        "unload" => op_unload(req, registry),
        "shutdown" => {
            shared.stop.store(true, Ordering::SeqCst);
            Ok(ok_obj(vec![("bye", Json::Bool(true))]))
        }
        "" => Err(anyhow!("request needs an \"op\" string")),
        other => Err(anyhow!(
            "unknown op '{other}' (try transform|recommend|stats|load|unload|ping|shutdown)"
        )),
    };
    result.unwrap_or_else(|e| err_json(format!("{e:#}")))
}

pub(crate) fn ok_obj(mut pairs: Vec<(&str, Json)>) -> Json {
    pairs.insert(0, ("ok", Json::Bool(true)));
    Json::obj(pairs)
}

pub(crate) fn err_json(msg: String) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg))])
}

// ---------------------------------------------------------------------------
// Query (de)serialization.
// ---------------------------------------------------------------------------

/// Owned deserialized query batch (requests outlive no borrow).
pub enum OwnedQueries {
    Dense(Mat),
    Sparse(Csr),
}

impl OwnedQueries {
    pub fn as_queries(&self) -> Queries<'_> {
        match self {
            OwnedQueries::Dense(m) => Queries::Dense(m),
            OwnedQueries::Sparse(c) => Queries::Sparse(c),
        }
    }
}

/// Deserialize a request's `queries` against a model with `v` features.
fn parse_queries(req: &Json, v: usize) -> Result<OwnedQueries> {
    let rows = req
        .get("queries")
        .as_arr()
        .ok_or_else(|| anyhow!("request needs \"queries\": an array of rows"))?;
    if rows.is_empty() {
        bail!("empty query batch");
    }
    match &rows[0] {
        Json::Arr(_) => {
            let mut data: Vec<Elem> = Vec::with_capacity(rows.len() * v);
            for (i, row) in rows.iter().enumerate() {
                let vals = row
                    .as_arr()
                    .ok_or_else(|| anyhow!("queries[{i}]: expected a dense row array"))?;
                if vals.len() != v {
                    bail!("queries[{i}] has {} entries, model expects V={v}", vals.len());
                }
                for (j, x) in vals.iter().enumerate() {
                    let x = x
                        .as_f64()
                        .ok_or_else(|| anyhow!("queries[{i}][{j}] is not a number"))?;
                    if !x.is_finite() {
                        bail!("queries[{i}][{j}] = {x} is not finite");
                    }
                    data.push(x as Elem);
                }
            }
            Ok(OwnedQueries::Dense(Mat::from_vec(rows.len(), v, data)))
        }
        Json::Obj(_) => {
            let mut triplets: Vec<(usize, usize, Elem)> = Vec::new();
            for (i, row) in rows.iter().enumerate() {
                let cols = row
                    .get("cols")
                    .as_arr()
                    .ok_or_else(|| anyhow!("queries[{i}] needs \"cols\""))?;
                let vals = row
                    .get("vals")
                    .as_arr()
                    .ok_or_else(|| anyhow!("queries[{i}] needs \"vals\""))?;
                if cols.len() != vals.len() {
                    bail!(
                        "queries[{i}]: {} cols but {} vals",
                        cols.len(),
                        vals.len()
                    );
                }
                for (c, x) in cols.iter().zip(vals) {
                    let c = c
                        .as_usize()
                        .ok_or_else(|| anyhow!("queries[{i}]: bad column index {c}"))?;
                    if c >= v {
                        bail!("queries[{i}]: column {c} out of range (V={v})");
                    }
                    let x = x
                        .as_f64()
                        .ok_or_else(|| anyhow!("queries[{i}]: non-numeric value"))?;
                    if !x.is_finite() {
                        bail!("queries[{i}]: value {x} is not finite");
                    }
                    triplets.push((i, c, x as Elem));
                }
            }
            Ok(OwnedQueries::Sparse(Csr::from_triplets(rows.len(), v, triplets)))
        }
        _ => bail!(
            "queries rows must be dense arrays ([[...]]) or sparse objects \
             ([{{\"cols\": [...], \"vals\": [...]}}])"
        ),
    }
}

/// Serialize a query batch into the protocol's `queries` value — the
/// client-side counterpart of the daemon's parser (used by the bench,
/// the example, and the integration tests).
pub fn queries_to_json(q: Queries<'_>) -> Json {
    match q {
        Queries::Dense(m) => Json::Arr(
            (0..m.rows())
                .map(|i| Json::Arr(m.row(i).iter().map(|&x| Json::Num(x as f64)).collect()))
                .collect(),
        ),
        Queries::Sparse(a) => Json::Arr(
            (0..a.rows())
                .map(|i| {
                    let (cols, vals) = a.row(i);
                    Json::obj(vec![
                        (
                            "cols",
                            Json::Arr(cols.iter().map(|&c| Json::num(c as f64)).collect()),
                        ),
                        (
                            "vals",
                            Json::Arr(vals.iter().map(|&v| Json::num(v as f64)).collect()),
                        ),
                    ])
                })
                .collect(),
        ),
    }
}

fn mat_rows_json(m: &Mat) -> Json {
    Json::Arr(
        (0..m.rows())
            .map(|i| Json::Arr(m.row(i).iter().map(|&x| Json::Num(x as f64)).collect()))
            .collect(),
    )
}

fn warm_json(ps: &crate::serve::projector::ProjectStats) -> Json {
    Json::obj(vec![
        ("hits", Json::num(ps.warm_hits as f64)),
        ("misses", Json::num(ps.warm_misses as f64)),
        ("sweeps", Json::num(ps.sweeps as f64)),
        ("micro_batches", Json::num(ps.micro_batches as f64)),
    ])
}

// ---------------------------------------------------------------------------
// Ops.
// ---------------------------------------------------------------------------

fn op_transform(req: &Json, registry: &ModelRegistry) -> Result<Json> {
    let name = req
        .get("model")
        .as_str()
        .ok_or_else(|| anyhow!("transform needs \"model\""))?;
    let entry = registry.get(name)?;
    let q = parse_queries(req, entry.projector().v())?;
    let warm = req.get("warm").as_bool().unwrap_or(true);
    let t = Timer::start();
    let (h, res, ps) = entry.transform(q.as_queries(), warm)?;
    Ok(ok_obj(vec![
        ("model", Json::str(name)),
        ("h", mat_rows_json(&h)),
        ("residuals", Json::Arr(res.iter().map(|&x| Json::Num(x)).collect())),
        ("warm", warm_json(&ps)),
        ("secs", Json::num(t.elapsed_secs())),
    ]))
}

fn op_recommend(req: &Json, registry: &ModelRegistry) -> Result<Json> {
    let name = req
        .get("model")
        .as_str()
        .ok_or_else(|| anyhow!("recommend needs \"model\""))?;
    let entry = registry.get(name)?;
    let q = parse_queries(req, entry.projector().v())?;
    let top = req.get("top").as_usize().unwrap_or(10);
    let exclude_seen = req.get("exclude_seen").as_bool().unwrap_or(false);
    let warm = req.get("warm").as_bool().unwrap_or(true);
    let t = Timer::start();
    let (recs, ps) = entry.recommend(q.as_queries(), top, exclude_seen, warm)?;
    let recs_json = Json::Arr(
        recs.iter()
            .map(|rec| {
                Json::Arr(
                    rec.iter()
                        .map(|&(item, score)| {
                            Json::Arr(vec![Json::num(item as f64), Json::Num(score as f64)])
                        })
                        .collect(),
                )
            })
            .collect(),
    );
    Ok(ok_obj(vec![
        ("model", Json::str(name)),
        ("recs", recs_json),
        ("warm", warm_json(&ps)),
        ("secs", Json::num(t.elapsed_secs())),
    ]))
}

fn op_stats(registry: &ModelRegistry, shared: &Shared) -> Json {
    ok_obj(vec![
        ("uptime_secs", Json::num(shared.started.elapsed().as_secs_f64())),
        ("requests", Json::num(shared.requests.load(Ordering::SeqCst) as f64)),
        ("manifest_version", Json::num(registry.manifest_version() as f64)),
        ("admission_budget", Json::num(registry.admission_budget() as f64)),
        ("total_nnz", Json::num(registry.total_nnz() as f64)),
        ("models", registry.stats_json()),
    ])
}

fn op_load(req: &Json, registry: &ModelRegistry) -> Result<Json> {
    match (req.get("name").as_str(), req.get("path").as_str()) {
        (Some(name), Some(path)) => {
            let entry = registry.load(name, std::path::Path::new(path))?;
            Ok(ok_obj(vec![
                ("loaded", Json::str(name)),
                ("nnz", Json::num(entry.nnz() as f64)),
            ]))
        }
        (None, None) => {
            let reloaded = registry.reload_manifest()?;
            Ok(ok_obj(vec![
                ("reloaded", Json::Bool(reloaded)),
                ("manifest_version", Json::num(registry.manifest_version() as f64)),
            ]))
        }
        _ => bail!("load needs both \"name\" and \"path\" (or neither, to re-read the manifest)"),
    }
}

fn op_unload(req: &Json, registry: &ModelRegistry) -> Result<Json> {
    let name = req
        .get("name")
        .as_str()
        .ok_or_else(|| anyhow!("unload needs \"name\""))?;
    registry.unload(name)?;
    Ok(ok_obj(vec![("unloaded", Json::str(name))]))
}

// ---------------------------------------------------------------------------
// Client.
// ---------------------------------------------------------------------------

/// Marker carried by every [`Client`] error where the peer vanished
/// after the request was (or may have been) sent but before a complete
/// response line arrived. The vendored `anyhow` has no downcasting, so
/// the distinct error class is a message marker; classify with
/// [`Client::is_connection_closed`]. The distinction matters to callers
/// like the router's pooled client: a closed-mid-response request may
/// have been processed by the peer and must NOT be blindly retried —
/// it is surfaced as a retryable error instead.
pub const CLOSED_MID_RESPONSE: &str = "connection closed mid-response";

/// A blocking protocol client: one request line out, one response line
/// in. Used by the daemon bench, the router's per-shard pools, the
/// example, the integration tests, and anyone driving the daemon from
/// Rust.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to plnmf daemon")?;
        let reader = BufReader::new(stream.try_clone().context("cloning stream")?);
        Ok(Client { reader, writer: stream })
    }

    /// [`Self::connect`] with a bounded dial: a blackholed peer fails
    /// after `timeout` instead of the OS connect timeout (minutes).
    /// Used by latency-sensitive callers like the router's stats probe.
    pub fn connect_timeout(addr: &SocketAddr, timeout: Duration) -> Result<Client> {
        let stream = TcpStream::connect_timeout(addr, timeout)
            .context("connecting to plnmf daemon")?;
        let reader = BufReader::new(stream.try_clone().context("cloning stream")?);
        Ok(Client { reader, writer: stream })
    }

    /// Whether `err` is the distinct "connection closed mid-response"
    /// failure (EOF or a read error after the request was written), as
    /// opposed to a connect failure, a write failure, or a response
    /// that parsed but carried `"ok": false`.
    pub fn is_connection_closed(err: &anyhow::Error) -> bool {
        err.chain().any(|m| m.contains(CLOSED_MID_RESPONSE))
    }

    /// Whether a parsed response is the router's backpressure signal
    /// (`"busy": true` — every live replica of the model is at its
    /// in-flight ceiling). Returns the server's `Retry-After`-style
    /// hint in milliseconds; the right client reaction is to delay
    /// that long (or shed the request), not to hammer the shard.
    pub fn busy_retry_after_ms(resp: &Json) -> Option<u64> {
        if resp.get("busy").as_bool() == Some(true) {
            Some(resp.get("retry_after_ms").as_u64().unwrap_or(0))
        } else {
            None
        }
    }

    /// Bound how long reads may block (None = forever). Applies to the
    /// underlying socket, so it also covers in-flight `request` calls.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(timeout).context("setting read timeout")
    }

    /// Send one already-serialized request line and return the raw
    /// response line, bytes untouched — the router's forwarding path
    /// (relaying the worker's exact bytes is what keeps routed
    /// responses bit-for-bit identical to a single daemon's).
    pub fn request_raw(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes()).context("writing request")?;
        self.writer.write_all(b"\n").context("writing request")?;
        match read_frame(&mut self.reader, MAX_LINE_BYTES) {
            Ok(FrameRead::Frame(resp)) => Ok(resp),
            Ok(FrameRead::Eof) => bail!("{CLOSED_MID_RESPONSE} (EOF before a response line)"),
            Ok(FrameRead::Partial(got)) => bail!(
                "{CLOSED_MID_RESPONSE} (EOF after {} bytes of an unterminated response line)",
                got.len()
            ),
            Ok(FrameRead::TooLong(n)) => {
                bail!("response line exceeds {MAX_LINE_BYTES} bytes ({n} read)")
            }
            Err(e) => Err(anyhow!("{CLOSED_MID_RESPONSE} ({e})")),
        }
    }

    /// Send one request, read one response (whatever its `ok`).
    pub fn request(&mut self, req: &Json) -> Result<Json> {
        let resp = self.request_raw(&req.to_string())?;
        Json::parse(resp.trim()).map_err(|e| anyhow!("bad response JSON: {e}"))
    }

    /// [`Self::request`], failing on `"ok": false` responses.
    pub fn request_ok(&mut self, req: &Json) -> Result<Json> {
        let resp = self.request(req)?;
        if resp.get("ok").as_bool() != Some(true) {
            bail!(
                "daemon error: {}",
                resp.get("error").as_str().unwrap_or("(no error message)")
            );
        }
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_queries_dense_and_sparse() {
        let dense = Json::parse(r#"{"queries": [[1, 0, 2], [0, 0, 0]]}"#).unwrap();
        match parse_queries(&dense, 3).unwrap() {
            OwnedQueries::Dense(m) => {
                assert_eq!((m.rows(), m.cols()), (2, 3));
                assert_eq!(m.at(0, 2), 2.0);
            }
            _ => panic!("expected dense"),
        }
        let sparse =
            Json::parse(r#"{"queries": [{"cols": [0, 2], "vals": [1.5, 2.5]}]}"#).unwrap();
        match parse_queries(&sparse, 3).unwrap() {
            OwnedQueries::Sparse(c) => {
                assert_eq!((c.rows(), c.cols()), (1, 3));
                assert_eq!(c.row(0).0, &[0, 2]);
            }
            _ => panic!("expected sparse"),
        }
    }

    #[test]
    fn parse_queries_rejects_malformed_batches() {
        for (src, v) in [
            (r#"{"queries": []}"#, 3),
            (r#"{"queries": [[1, 2]]}"#, 3),            // wrong width
            (r#"{"queries": [[1, "x", 2]]}"#, 3),       // non-numeric
            (r#"{"queries": [{"cols": [5], "vals": [1]}]}"#, 3), // col out of range
            (r#"{"queries": [{"cols": [0, 1], "vals": [1]}]}"#, 3), // length mismatch
            (r#"{"queries": [3]}"#, 3),                 // bad row type
            (r#"{"nope": 1}"#, 3),                      // missing key
        ] {
            let req = Json::parse(src).unwrap();
            assert!(parse_queries(&req, v).is_err(), "should reject {src}");
        }
    }

    #[test]
    fn queries_roundtrip_through_protocol_encoding() {
        let m = Mat::from_fn(3, 4, |i, j| if (i + j) % 2 == 0 { (i * 4 + j) as Elem } else { 0.0 });
        let req = Json::obj(vec![("queries", queries_to_json(Queries::Dense(&m)))]);
        match parse_queries(&req, 4).unwrap() {
            OwnedQueries::Dense(re) => assert_eq!(re, m),
            _ => panic!("dense in, dense out"),
        }
        let c = Csr::from_dense(&m);
        let req = Json::obj(vec![("queries", queries_to_json(Queries::Sparse(&c)))]);
        match parse_queries(&req, 4).unwrap() {
            OwnedQueries::Sparse(re) => assert_eq!(re, c),
            _ => panic!("sparse in, sparse out"),
        }
    }

    #[test]
    fn request_line_parsing_rejects_trailing_junk() {
        assert!(parse_request(r#"{"op": "ping"}"#).is_ok());
        assert!(parse_request("{\"op\": \"ping\"}  ").is_ok());
        assert!(parse_request(r#"{"op": "ping"} {"op": "ping"}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn read_frame_bounds_and_splits_lines() {
        let feed = |src: &str, max: usize| -> Vec<FrameRead> {
            let mut r = BufReader::new(std::io::Cursor::new(src.as_bytes().to_vec()));
            let mut out = Vec::new();
            loop {
                match read_frame(&mut r, max).unwrap() {
                    FrameRead::Eof => break,
                    f => out.push(f),
                }
            }
            out
        };
        // Two lines plus an unterminated tail: the tail is NOT a
        // complete frame — the stream died mid-line.
        let frames = feed("abc\ndef\ntail", 100);
        assert_eq!(frames.len(), 3);
        match (&frames[0], &frames[1], &frames[2]) {
            (FrameRead::Frame(a), FrameRead::Frame(b), FrameRead::Partial(c)) => {
                assert_eq!((a.as_str(), b.as_str(), c.as_str()), ("abc", "def", "tail"));
            }
            _ => panic!("expected two frames and a partial"),
        }
        // Exactly at the cap is fine; one byte over is TooLong.
        match &feed("abcde\n", 5)[0] {
            FrameRead::Frame(f) => assert_eq!(f, "abcde"),
            _ => panic!("cap is inclusive"),
        }
        assert!(matches!(feed("abcdef\n", 5)[0], FrameRead::TooLong(_)));
        assert!(matches!(feed("abcdefgh", 5)[0], FrameRead::TooLong(_)));
    }

    #[test]
    fn closed_mid_response_is_classified_distinctly() {
        let closed = anyhow!("{CLOSED_MID_RESPONSE} (EOF before a response line)")
            .context("forwarding to shard 'a'");
        assert!(Client::is_connection_closed(&closed));
        let other = anyhow!("bad response JSON: oops").context("forwarding to shard 'a'");
        assert!(!Client::is_connection_closed(&other));
    }

    #[test]
    fn busy_responses_are_classified_with_their_hint() {
        let busy = Json::parse(
            r#"{"ok": false, "busy": true, "retryable": true, "retry_after_ms": 75}"#,
        )
        .unwrap();
        assert_eq!(Client::busy_retry_after_ms(&busy), Some(75));
        let retryable = Json::parse(r#"{"ok": false, "retryable": true}"#).unwrap();
        assert_eq!(Client::busy_retry_after_ms(&retryable), None);
        let ok = Json::parse(r#"{"ok": true}"#).unwrap();
        assert_eq!(Client::busy_retry_after_ms(&ok), None);
    }
}

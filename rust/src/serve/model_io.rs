//! Trained-model persistence: `Factors` ⇄ a versioned JSON file.
//!
//! The format is deliberately simple — a flat object with shapes, training
//! provenance, and the two factor matrices as row-major number arrays —
//! so the Python layer (or a human) can read it without extra tooling.
//! `f32` entries survive the round trip exactly: they widen to `f64`,
//! print via Rust's shortest-round-trip formatting, and narrow back.

use std::path::Path;

use anyhow::{anyhow, bail, Context};

use crate::linalg::Mat;
use crate::nmf::{EngineSpec, Factors};
use crate::util::json::Json;
use crate::{Elem, Result};

/// Format marker stored in every model file.
pub const MODEL_FORMAT: &str = "plnmf-model";
const MODEL_VERSION: usize = 1;

/// Training provenance carried alongside the factors.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ModelMeta {
    /// Engine that produced the factors (e.g. `plnmf-cpu`).
    pub engine: String,
    /// Dataset profile the model was trained on.
    pub dataset: String,
    pub seed: u64,
    /// Outer iterations run.
    pub iters: usize,
    /// Final relative objective at save time.
    pub rel_error: f64,
    /// What the factors optimize (loss, solver, regularization, init).
    /// Serving uses this to pick the projection path; the default spec
    /// is not written to disk, so pre-spec files round-trip byte-for-
    /// byte and load as the default.
    pub spec: EngineSpec,
    /// Factor epoch: how many online `update` batches have been folded
    /// into these factors since the last full (re)train. Freshly trained
    /// models are epoch 0, which — like the default spec — stays off
    /// disk so pre-epoch files round-trip byte-for-byte.
    pub epoch: u64,
}

/// Serialize factors + metadata to `path` (parent dirs are created).
pub fn save_model(path: &Path, factors: &Factors, meta: &ModelMeta) -> Result<()> {
    let mut pairs = vec![
        ("format", Json::str(MODEL_FORMAT)),
        ("version", Json::num(MODEL_VERSION as f64)),
        ("v", Json::num(factors.v() as f64)),
        ("d", Json::num(factors.d() as f64)),
        ("k", Json::num(factors.k() as f64)),
        ("engine", Json::str(meta.engine.clone())),
        ("dataset", Json::str(meta.dataset.clone())),
        // As a string: JSON numbers are f64 and would round seeds ≥ 2⁵³.
        ("seed", Json::str(meta.seed.to_string())),
        ("iters", Json::num(meta.iters as f64)),
        ("rel_error", Json::num(meta.rel_error)),
    ];
    // Only a non-default spec hits the disk: default-spec saves stay
    // byte-identical to the pre-spec format.
    if !meta.spec.is_default() {
        pairs.push(("spec", meta.spec.to_json()));
    }
    // Same story for the factor epoch: 0 (a fresh train) stays off disk.
    if meta.epoch != 0 {
        pairs.push(("epoch", Json::num(meta.epoch as f64)));
    }
    pairs.push(("w", mat_to_json(&factors.w)));
    pairs.push(("h", mat_to_json(&factors.h)));
    let j = Json::obj(pairs);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).ok();
        }
    }
    std::fs::write(path, j.to_string()).with_context(|| format!("writing model {path:?}"))
}

/// Load a model saved by [`save_model`], validating shapes and
/// non-negativity.
pub fn load_model(path: &Path) -> Result<(Factors, ModelMeta)> {
    let src =
        std::fs::read_to_string(path).with_context(|| format!("reading model {path:?}"))?;
    let j = Json::parse(&src).with_context(|| format!("parsing model {path:?}"))?;

    let format = j.get("format").as_str().unwrap_or("");
    if format != MODEL_FORMAT {
        bail!("{path:?} is not a plnmf model (format '{format}')");
    }
    // Strict-when-present numbers throughout (the silent-coercion
    // sweep): an absent field takes its default, but a bogus value —
    // negative, fractional, overflowing — errors instead of quietly
    // becoming 0 and changing meaning.
    let version = j
        .get("version")
        .as_usize()
        .ok_or_else(|| anyhow!("model needs a non-negative integer \"version\""))?;
    if version != MODEL_VERSION {
        bail!("unsupported model version {version} (expected {MODEL_VERSION})");
    }
    let dim = |key: &str| j.get(key).as_usize().ok_or_else(|| anyhow!("missing '{key}'"));
    let (v, d, k) = (dim("v")?, dim("d")?, dim("k")?);
    if k == 0 {
        bail!("model has k = 0");
    }
    let w = json_to_mat(&j, "w", v, k)?;
    let h = json_to_mat(&j, "h", d, k)?;
    let meta = ModelMeta {
        engine: j.get("engine").as_str().unwrap_or("").to_string(),
        dataset: j.get("dataset").as_str().unwrap_or("").to_string(),
        seed: match j.get("seed") {
            Json::Null => 0,
            Json::Str(s) => {
                s.parse().map_err(|_| anyhow!("model \"seed\" is not a u64: {s:?}"))?
            }
            other => other
                .as_u64()
                .ok_or_else(|| anyhow!("model \"seed\" must be a non-negative integer"))?,
        },
        iters: j.get_usize_or("iters", 0).map_err(|e| anyhow!("model {e}"))?,
        rel_error: j.get("rel_error").as_f64().unwrap_or(f64::NAN),
        // Absent ⇒ default (pre-spec files); present ⇒ strictly
        // validated, unknown fields rejected.
        spec: EngineSpec::from_json(j.get("spec")).context("model \"spec\"")?,
        epoch: j.get_usize_or("epoch", 0).map_err(|e| anyhow!("model {e}"))? as u64,
    };
    Ok((Factors::from_parts(w, h)?, meta))
}

fn mat_to_json(m: &Mat) -> Json {
    Json::Arr(m.data().iter().map(|&x| Json::Num(x as f64)).collect())
}

fn json_to_mat(j: &Json, key: &str, rows: usize, cols: usize) -> Result<Mat> {
    let arr = j.get(key).as_arr().ok_or_else(|| anyhow!("model missing '{key}' array"))?;
    if arr.len() != rows * cols {
        bail!("'{key}' has {} entries, expected {rows}x{cols}", arr.len());
    }
    let mut data = Vec::with_capacity(arr.len());
    for (i, x) in arr.iter().enumerate() {
        let v = x.as_f64().ok_or_else(|| anyhow!("'{key}'[{i}] is not a number"))?;
        if !v.is_finite() || v < 0.0 {
            bail!("'{key}'[{i}] = {v} is not a finite non-negative factor entry");
        }
        data.push(v as Elem);
    }
    Ok(Mat::from_vec(rows, cols, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("plnmf-model-{}-{name}.json", std::process::id()))
    }

    #[test]
    fn roundtrip_is_exact() {
        let f = Factors::random(17, 9, 5, 3);
        let meta = ModelMeta {
            engine: "plnmf-cpu".into(),
            dataset: "tiny".into(),
            seed: (1u64 << 53) + 3, // not representable as f64 — string path
            iters: 20,
            rel_error: 0.123456,
            spec: EngineSpec::default(),
            epoch: 0,
        };
        let path = tmp("roundtrip");
        save_model(&path, &f, &meta).unwrap();
        let (re, remeta) = load_model(&path).unwrap();
        assert_eq!(re.w, f.w);
        assert_eq!(re.h, f.h);
        assert_eq!(remeta, meta);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn spec_roundtrips_and_default_is_not_written() {
        use crate::nmf::spec::{Init, Loss, Solver};
        let f = Factors::random(6, 4, 2, 1);
        // Default spec: the file must not mention "spec" at all (byte
        // compatibility with pre-spec writers).
        let path = tmp("spec-default");
        save_model(&path, &f, &ModelMeta::default()).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(!body.contains("spec"), "default spec must stay off disk");
        let (_, meta) = load_model(&path).unwrap();
        assert!(meta.spec.is_default());
        std::fs::remove_file(&path).ok();
        // Non-default spec round-trips exactly.
        let spec = EngineSpec {
            loss: Loss::Kl,
            solver: Solver::Mu,
            alpha: 0.1,
            l1_ratio: 0.5,
            init: Init::Nndsvda,
        };
        let path = tmp("spec-kl");
        save_model(&path, &f, &ModelMeta { spec, ..Default::default() }).unwrap();
        let (_, meta) = load_model(&path).unwrap();
        assert_eq!(meta.spec, spec);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn epoch_roundtrips_and_zero_is_not_written() {
        let f = Factors::random(6, 4, 2, 1);
        // Epoch 0 (a fresh train): the file must not mention "epoch" at
        // all, so pre-epoch writers and readers stay byte-compatible.
        let path = tmp("epoch-zero");
        save_model(&path, &f, &ModelMeta::default()).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(!body.contains("epoch"), "epoch 0 must stay off disk");
        let (_, meta) = load_model(&path).unwrap();
        assert_eq!(meta.epoch, 0);
        std::fs::remove_file(&path).ok();
        // A non-zero epoch round-trips.
        let path = tmp("epoch-seven");
        save_model(&path, &f, &ModelMeta { epoch: 7, ..Default::default() }).unwrap();
        let (_, meta) = load_model(&path).unwrap();
        assert_eq!(meta.epoch, 7);
        // A bogus epoch errors instead of coercing (strict-when-present).
        let body = r#"{"format": "plnmf-model", "version": 1, "v": 1, "d": 1, "k": 1,
            "epoch": -2, "w": [1], "h": [1]}"#;
        std::fs::write(&path, body).unwrap();
        let err = format!("{:#}", load_model(&path).unwrap_err());
        assert!(err.contains("epoch"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bogus_spec_is_rejected() {
        let path = tmp("spec-bad");
        for spec in [
            r#"{"loss": "poisson"}"#,
            r#"{"l1ratio": 0.5}"#,
            r#"{"loss": "kl", "solver": "hals"}"#,
            r#""kl""#,
        ] {
            let body = format!(
                r#"{{"format": "plnmf-model", "version": 1, "v": 1, "d": 1, "k": 1,
                    "spec": {spec}, "w": [1], "h": [1]}}"#
            );
            std::fs::write(&path, &body).unwrap();
            let err = format!("{:#}", load_model(&path).unwrap_err());
            assert!(err.contains("spec"), "{spec}: {err}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_wrong_format_and_shape() {
        let path = tmp("bad");
        std::fs::write(&path, r#"{"format": "other", "version": 1}"#).unwrap();
        assert!(load_model(&path).is_err());
        std::fs::write(
            &path,
            r#"{"format": "plnmf-model", "version": 1, "v": 2, "d": 1, "k": 2,
                "w": [1, 2, 3], "h": [1, 2]}"#,
        )
        .unwrap();
        let err = format!("{:#}", load_model(&path).unwrap_err());
        assert!(err.contains("expected 2x2"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_negative_entries() {
        let path = tmp("neg");
        std::fs::write(
            &path,
            r#"{"format": "plnmf-model", "version": 1, "v": 1, "d": 1, "k": 1,
                "w": [-1], "h": [1]}"#,
        )
        .unwrap();
        assert!(load_model(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_file_is_contextual_error() {
        let err = format!("{:#}", load_model(Path::new("/no/such/model.json")).unwrap_err());
        assert!(err.contains("model"), "{err}");
    }

    #[test]
    fn bogus_numbers_in_metadata_error_instead_of_coercing() {
        // Silent-coercion regression: a negative/fractional version,
        // iters, or seed must be a parse error — not quietly 0 (which
        // would flip "unsupported version" semantics and erase
        // provenance).
        let path = tmp("coerce");
        for (field, value) in
            [("version", "-1"), ("version", "1.5"), ("iters", "-3"), ("seed", "-7")]
        {
            let version = if field == "version" { value } else { "1" };
            let extra = if field == "version" {
                String::new()
            } else {
                format!(", \"{field}\": {value}")
            };
            let body = format!(
                r#"{{"format": "plnmf-model", "version": {version}, "v": 1, "d": 1,
                    "k": 1, "w": [1], "h": [1]{extra}}}"#
            );
            std::fs::write(&path, &body).unwrap();
            let err = format!("{:#}", load_model(&path).unwrap_err());
            assert!(err.contains(field), "{field}={value}: {err}");
        }
        // A string seed that is not a u64 is rejected too.
        std::fs::write(
            &path,
            r#"{"format": "plnmf-model", "version": 1, "v": 1, "d": 1, "k": 1,
                "seed": "not-a-number", "w": [1], "h": [1]}"#,
        )
        .unwrap();
        let err = format!("{:#}", load_model(&path).unwrap_err());
        assert!(err.contains("seed"), "{err}");
        std::fs::remove_file(path).ok();
    }
}

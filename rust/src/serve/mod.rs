//! Inference / serving layer: answer projection queries against trained
//! factors — in-process or through a long-lived daemon.
//!
//! Training (Alg. 2) produces `W` (V×K word/item loadings) and `H` (D×K
//! document mixtures). Deployments — topic modeling and recommenders, the
//! paper's motivating applications — then need the *other* direction: given
//! a stream of previously unseen columns `a ∈ R^V`, recover their mixtures
//!
//! ```text
//! h* = argmin_{h ≥ 0} ‖a − W·h‖₂
//! ```
//!
//! and, for recommender queries, rank the reconstruction `W·h*`.
//!
//! The key structural fact (also exploited by MPI-FAUN and the
//! limited-internal-memory NMF of Nguyen & Ho) is that the whole workload
//! reuses one small cached Gram `S = WᵀW` (K×K) against tall-skinny
//! panels: a batch of m queries is an m×K HALS update — *exactly* the
//! shape `halsops::update_tiled` is engineered for. The serving layer is
//! therefore a thin orchestration over the training kernels rather than a
//! second math stack:
//!
//! * [`model_io`] — factor save/load (`Factors` ⇄ versioned JSON).
//! * [`projector`] — [`Projector`]: caches the Gram once per model,
//!   micro-batches request batches with nnz-balanced shards
//!   ([`crate::coordinator::shard`]), solves each micro-batch with a few
//!   tiled HALS sweeps on the thread pool, serves top-N recommendations
//!   from `W·h*`, and warm-starts repeat queries from a fingerprint-keyed
//!   LRU ([`WarmCache`]).
//! * [`registry`] — [`ModelRegistry`]: named models as independent
//!   serving shards (own pool, own queue, own warm cache), loaded from a
//!   versioned manifest with nnz-aware admission and hot reload, and
//!   updatable in place: the `update` op folds new data rows into a
//!   model's factors and atomically publishes the result as factor
//!   epoch N+1 with zero dropped requests (see
//!   [`ModelRegistry::update`]).
//! * [`wire`] — the shared wire codec: the v1 NDJSON frame reader and
//!   the **PLNB v2 binary frame format** for dense batches (raw f32
//!   little-endian behind a 20-byte header, negotiated per connection
//!   with `hello {"proto": 2}`; JSON encode/decode dominates round-trip
//!   time for large dense batches — the paper's data-movement argument,
//!   applied to the wire).
//! * [`server`] — [`Server`]: the `plnmf serve` daemon speaking
//!   newline-delimited JSON over TCP (plus negotiated PLNB v2 binary
//!   dense batches), keeping every model's factors and Gram resident
//!   across requests (the whole point of the cached-Gram design), plus
//!   the protocol [`Client`] with its v2 auto-upgrade, the typed
//!   [`ClientError`] classification (busy / closed-mid-response /
//!   protocol / retryable), and the [`DenseCall`] builder behind the
//!   dense transform/recommend/update round trips.
//! * [`router`] / [`worker`] — [`Router`]: the `plnmf route` front
//!   daemon fanning the same protocol out to `plnmf serve` worker
//!   **processes** — `replicas: N` per manifest model — with
//!   least-loaded replica routing, a per-request retry budget for
//!   idempotent ops, `busy` backpressure when every live replica is at
//!   its in-flight ceiling, crash detection, bounded-backoff restarts,
//!   and manifest hot-reload; workers are addressed by `host:port` so
//!   the topology extends to other machines unchanged.
//!
//! CLI front-ends: `plnmf run --model m.json` saves a model after
//! training; `plnmf transform` / `plnmf recommend` serve it one-shot;
//! `plnmf serve` keeps it resident; `plnmf route` shards a fleet across
//! worker processes (and replicates each model across N of them).
//! Throughput: `cargo bench --bench serving_throughput` (docs/sec at
//! micro-batch sizes 1/32/512, the daemon and routed round-trip and
//! warm-start deltas, plus `routed_replicated` scaling at 1/2/4
//! replicas).

pub mod model_io;
pub mod projector;
pub mod registry;
pub mod router;
pub mod server;
pub mod wire;
pub mod worker;

pub use model_io::{load_model, save_model, ModelMeta};
pub use projector::{FoldState, ProjectStats, Projector, ProjectorOpts, Queries, WarmCache};
pub use registry::{
    file_fingerprint, Manifest, ModelEntry, ModelRegistry, RegistryOpts, SpecOverride,
    UpdateOutcome,
};
pub use router::{Router, RouterOpts};
pub use server::{
    mat_from_json_rows, queries_to_json, Client, ClientError, ClientResult, DenseCall, DenseReply,
    OwnedQueries, Server, CLOSED_MID_RESPONSE, MAX_LINE_BYTES,
};
pub use wire::{BinFrame, BinOp, MAX_FRAME_BYTES, PLNB_MAGIC, PLNB_VERSION};
pub use worker::WorkerOpts;

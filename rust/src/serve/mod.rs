//! Inference / serving layer: answer projection queries against trained
//! factors.
//!
//! Training (Alg. 2) produces `W` (V×K word/item loadings) and `H` (D×K
//! document mixtures). Deployments — topic modeling and recommenders, the
//! paper's motivating applications — then need the *other* direction: given
//! a stream of previously unseen columns `a ∈ R^V`, recover their mixtures
//!
//! ```text
//! h* = argmin_{h ≥ 0} ‖a − W·h‖₂
//! ```
//!
//! and, for recommender queries, rank the reconstruction `W·h*`.
//!
//! The key structural fact (also exploited by MPI-FAUN and the
//! limited-internal-memory NMF of Nguyen & Ho) is that the whole workload
//! reuses one small cached Gram `S = WᵀW` (K×K) against tall-skinny
//! panels: a batch of m queries is an m×K HALS update — *exactly* the
//! shape `halsops::update_tiled` is engineered for. The serving layer is
//! therefore a thin orchestration over the training kernels rather than a
//! second math stack:
//!
//! * [`model_io`] — factor save/load (`Factors` ⇄ versioned JSON).
//! * [`projector`] — [`Projector`]: caches the Gram once per model,
//!   micro-batches request batches with nnz-balanced shards
//!   ([`crate::coordinator::shard`]), solves each micro-batch with a few
//!   tiled HALS sweeps on the thread pool, and serves top-N
//!   recommendations from `W·h*`.
//!
//! CLI front-ends: `plnmf run --model m.json` saves a model after
//! training; `plnmf transform` / `plnmf recommend` serve it. Throughput:
//! `cargo bench --bench serving_throughput` (docs/sec at micro-batch
//! sizes 1/32/512).

pub mod model_io;
pub mod projector;

pub use model_io::{load_model, save_model, ModelMeta};
pub use projector::{Projector, ProjectorOpts, Queries};

//! `plnmf route` — a cross-process shard router over per-model workers.
//!
//! The in-process [`crate::serve::ModelRegistry`] already isolates each
//! model into its own serving shard (pool, queue, warm cache); this
//! module moves that seam across a **process boundary**: a front daemon
//! speaking the exact single-daemon NDJSON protocol fans requests out
//! to `plnmf serve` worker *processes*. Each model's factors, cached
//! Gram, and warm-start LRU then live in a worker process's heap —
//! resident in that process's caches instead of sharing one daemon's,
//! the serving-scale reading of the paper's §5 data-movement argument
//! and the process-grid direction of MPI-FAUN.
//!
//! ## Topology
//!
//! A manifest model may declare `"replicas": N` (default 1): the router
//! runs N identical worker processes for it and spreads requests across
//! them — replicating computation across processors the way distributed
//! NMF replicates factor blocks, so one model's throughput scales past
//! a single process and a worker crash is absorbed instead of being an
//! availability gap.
//!
//! ```text
//!                        ┌─ worker :p1 — plnmf serve {news}   ┐ news,
//!  client ── route :p0 ──┼─ worker :p2 — plnmf serve {news}   ┘ replicas: 2
//!        NDJSON/TCP      └─ worker :p3 — plnmf serve {faces}
//! ```
//!
//! The routing table maps model name → replicas, each addressed
//! `host:port` — never a PID — so a shard served from another host
//! plugs in unchanged ([`Router::with_external_workers`], where
//! repeating a model name declares replicas); process supervision is a
//! property of *local* shards only ([`crate::serve::worker`]).
//!
//! ## Protocol
//!
//! * `transform` / `recommend` — routed by `"model"` to the
//!   **least-loaded live replica** of that shard (fewest in-flight
//!   requests; ties break to the lowest replica index). The request
//!   frame is forwarded and the response frame relayed
//!   **bytes-untouched**, so routed responses are bit-for-bit identical
//!   to a single daemon's (asserted in `tests/integration_router.rs`).
//!   This holds for both framings: after a client negotiates PLNB v2
//!   (`hello {"proto": 2}`, answered by the router itself), its binary
//!   dense-batch frames are routed exactly like JSON lines — the router
//!   peeks op + model out of the fixed header, lazily negotiates v2 on
//!   the pooled worker connection, and relays bytes untouched — so the
//!   least-loaded/retry/backpressure logic is framing-agnostic.
//! * `update` — **fanned out to every replica** of its model's shard,
//!   in index order: each replica holds its own copy of the factors,
//!   so a mutation must reach all of them to keep factor epochs in
//!   lock-step (a least-loaded pick would fork the replicas' state).
//!   The op is non-idempotent — a replica whose response was lost may
//!   already have folded the batch in — so it is **never retried**,
//!   and it bypasses the busy ceiling (rare control-plane traffic;
//!   shedding one under load would silently fork epochs). The fan-out
//!   stops at the first failure and reports `"retryable": false`:
//!   earlier replicas already applied the batch, so re-sync by
//!   republishing the model (or re-send once the fleet is whole and
//!   accept the extra fold on the replicas that already took it).
//! * `stats` — aggregated: the per-model stats of every replica merged
//!   (counters summed, averages recomputed, structural fields like the
//!   factor `epoch` kept from the first replica) plus a `workers`
//!   health map with per-replica liveness and queue depth.
//! * `ping` — local, with per-replica liveness per shard
//!   (`up` = any replica live, `up_replicas`/`replicas` = k of N).
//! * `load` (bare) — manifest re-read, as in the single daemon.
//!   Targeted `load`/`unload` are rejected: in routed mode the fleet is
//!   declared by the manifest, so publish a new version instead.
//! * `shutdown` — graceful drain: stop accepting, finish in-flight
//!   requests (bounded), then shut every worker down.
//!
//! ## Failure semantics
//!
//! A replica crash is detected by the supervisor heartbeat (process
//! exit) or by a failed forward (connection drop). A failed forward of
//! an **idempotent** op (`transform`/`recommend` — pure reads of model
//! state) is retried on a *different* replica of the same shard, at
//! most [`RouterOpts::route_retries`] times per request; with replicas
//! a single crash is therefore invisible to clients. When the budget is
//! exhausted — or for any future non-idempotent op, which is never
//! re-sent because a closed-mid-response request may already have been
//! processed (see [`crate::serve::server::CLOSED_MID_RESPONSE`]) — the
//! request fails with `"retryable": true`, exactly as a single-replica
//! fleet always has. The crashed replica is restarted on a fresh port
//! after a bounded backoff (doubling from `restart_backoff_ms` up to a
//! cap while startup keeps failing), and its routing entry re-pointed.
//!
//! ## Backpressure
//!
//! Each replica carries an in-flight ceiling
//! ([`RouterOpts::max_inflight`]). When **every live replica** of a
//! model is at the ceiling, the router answers with the distinct
//! `"busy": true` protocol error carrying a `"retry_after_ms"` hint
//! (the `Retry-After` idiom) instead of queuing unboundedly — the
//! client sheds or delays load, and the hint scales with the
//! configured queue depth a retry would face (see
//! [`retry_after_hint_ms`]). Admission is reserve-style (checked at
//! the counter increment, not a stale snapshot), so racing requests
//! cannot jointly overshoot the ceiling. Manifest hot-reload applies
//! added/removed/changed models as
//! before — shards whose entry (path, mtime, replica count, spec
//! overrides) is untouched keep serving without interruption.

use std::collections::BTreeMap;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context};

use crate::serve::registry::{file_fingerprint, Manifest, SpecOverride};
use crate::serve::server::{parse_request, Client};
use crate::serve::wire::{
    self, err_json, handle_hello, ok_obj, read_wire, serve_wire, ConnState, WirePayload,
    MAX_FRAME_BYTES,
};
use crate::serve::worker::{
    probe_free_port, spawn_worker, wait_ready, ManagedWorker, WorkerOpts,
};
use crate::util::json::Json;
use crate::Result;

/// How long `run` waits for in-flight connections after `shutdown`.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(2);
/// Grace given to each worker between the protocol `shutdown` and kill.
const WORKER_SHUTDOWN_TIMEOUT: Duration = Duration::from_secs(3);
/// Read timeout of the dedicated per-replica `stats` probe connection
/// (see [`Replica::probe_stats`]) — bounds how long one wedged replica
/// can delay the aggregated stats response.
const STATS_PROBE_TIMEOUT: Duration = Duration::from_secs(5);

/// Router configuration (the CLI maps `route_port` /
/// `worker_port_base` / `restart_backoff_ms` / `route_retries` /
/// `max_inflight` onto this).
#[derive(Debug, Clone)]
pub struct RouterOpts {
    /// Interface the front listener binds.
    pub host: String,
    /// Front port (0 = OS-assigned; read back via [`Router::local_addr`]).
    pub route_port: u16,
    /// First worker port; the initial fleet's replicas take
    /// `base`, `base+1`, … in manifest order (0 = every worker gets an
    /// OS-assigned port). Restarted or hot-added workers always move to
    /// a fresh OS-assigned port — the old one may sit in `TIME_WAIT`.
    pub worker_port_base: u16,
    /// Initial delay before restarting a crashed worker. Doubles (up to
    /// [`RouterOpts::max_backoff`]) while restarts keep failing to
    /// become ready; resets once a restart succeeds.
    pub restart_backoff: Duration,
    /// Upper bound of the restart backoff.
    pub max_backoff: Duration,
    /// Supervisor heartbeat period (crash detection latency).
    pub health_interval: Duration,
    /// How long a (re)started worker gets to answer its first ping.
    pub ready_timeout: Duration,
    /// How often the supervisor re-checks the fleet manifest.
    pub manifest_poll: Duration,
    /// Read timeout on pooled worker connections. Bounds how long one
    /// forwarded request can hold a replica's queue: a worker that is
    /// alive but wedged would otherwise pin the replica mutex forever,
    /// freezing router shutdown.
    pub forward_timeout: Duration,
    /// Retry budget for idempotent data ops: after a failed forward the
    /// request is re-sent to a *different* replica of the same shard,
    /// at most this many times (0 = fail fast like non-idempotent ops).
    pub route_retries: usize,
    /// Per-replica in-flight ceiling. When every live replica of a
    /// model is at the ceiling the router returns the `busy`
    /// backpressure error instead of queuing unboundedly (0 = no
    /// ceiling).
    pub max_inflight: usize,
}

impl Default for RouterOpts {
    fn default() -> Self {
        RouterOpts {
            host: "127.0.0.1".to_string(),
            route_port: 0,
            worker_port_base: 0,
            restart_backoff: Duration::from_millis(500),
            max_backoff: Duration::from_secs(30),
            health_interval: Duration::from_millis(200),
            ready_timeout: Duration::from_secs(10),
            manifest_poll: Duration::from_secs(2),
            forward_timeout: Duration::from_secs(60),
            route_retries: 1,
            max_inflight: 32,
        }
    }
}

// ---------------------------------------------------------------------------
// Routing decisions (pure — unit-tested without sockets).
// ---------------------------------------------------------------------------

/// A snapshot of one replica's routing-relevant state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct ReplicaLoad {
    up: bool,
    in_flight: usize,
}

/// What to do next with a data op on one shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RoutePlan {
    /// Forward to this replica index (least-loaded live replica not yet
    /// tried this request; ties break to the lowest index).
    Try(usize),
    /// Every live replica is at the in-flight ceiling — shed load.
    Busy { retry_after_ms: u64 },
    /// Nothing left to try: every replica is down, or every live one
    /// already failed this request.
    Exhausted,
}

/// Pick the next replica for one attempt of one request.
///
/// The candidate set is the live replicas not yet tried by this
/// request. Precedence: no live replica at all ⇒ `Exhausted`; no
/// candidate left ⇒ `Exhausted`; every candidate at the ceiling ⇒
/// `Busy` (backpressure beats queuing — and beats deterministically
/// losing admission to a saturated last candidate); otherwise the
/// least-loaded candidate, ties to the lowest index. The ceiling is
/// evaluated over candidates, not single replicas — one saturated
/// replica is fine as long as a less loaded sibling exists, and the
/// least-loaded pick already prefers that sibling.
fn plan_route(loads: &[ReplicaLoad], tried: &[usize], max_inflight: usize) -> RoutePlan {
    if loads.iter().all(|l| !l.up) {
        return RoutePlan::Exhausted;
    }
    let candidates: Vec<(usize, &ReplicaLoad)> = loads
        .iter()
        .enumerate()
        .filter(|(i, l)| l.up && !tried.contains(i))
        .collect();
    if candidates.is_empty() {
        return RoutePlan::Exhausted;
    }
    if max_inflight > 0 && candidates.iter().all(|(_, l)| l.in_flight >= max_inflight) {
        return RoutePlan::Busy { retry_after_ms: retry_after_hint_ms(max_inflight) };
    }
    let (i, _) = candidates
        .iter()
        .min_by_key(|(i, l)| (l.in_flight, *i))
        .expect("candidates is non-empty");
    RoutePlan::Try(*i)
}

/// `Retry-After`-style hint for the `busy` error. The reserve-style
/// [`Shard::admit`] means in-flight counts never exceed the ceiling, so
/// "queue excess" is not observable; the honest proxy for how long a
/// shed request would otherwise wait is the configured per-replica
/// queue depth itself — a deeper ceiling means more work ahead of any
/// retry. Bounded to [25, 1000] ms so a client backoff loop neither
/// spins nor stalls.
fn retry_after_hint_ms(ceiling: usize) -> u64 {
    (5u64.saturating_mul(ceiling as u64)).clamp(25, 1000)
}

/// Whether re-sending `op` to another replica after a failed (or
/// ambiguous, closed-mid-response) forward is safe. `transform` and
/// `recommend` are pure reads of model state — the warm-cache fill is
/// an internal optimization, not client-visible state — so a duplicate
/// execution is harmless. Mutating ops stay off this list: `update`
/// folds the batch into the factors, so a duplicate execution double-
/// counts it (update takes the [`Shard::route_all`] fan-out path, which
/// never retries at all).
fn op_is_idempotent(op: &str) -> bool {
    matches!(op, "transform" | "recommend")
}

/// Why a routed request could not be answered.
enum RouteFailure {
    /// Every live replica is at the in-flight ceiling — backpressure,
    /// not an outage; the client should retry after the hint.
    Busy { retry_after_ms: u64 },
    /// The forward(s) failed (replica down, dial error, severed
    /// connection) — surfaced as `"retryable": true`, as always.
    Down(anyhow::Error),
}

// ---------------------------------------------------------------------------
// Replicas and shards.
// ---------------------------------------------------------------------------

struct ReplicaState {
    addr: SocketAddr,
    /// The supervised local process (None while down, and always for
    /// external replicas).
    worker: Option<ManagedWorker>,
    /// Pooled protocol connection; dropped on any forward failure and
    /// re-dialed (against the *current* addr) on the next request.
    conn: Option<Client>,
    up: bool,
    /// Earliest instant the supervisor may attempt the next restart.
    next_restart_at: Option<Instant>,
    backoff: Duration,
    /// Content fingerprint ([`file_fingerprint`]) of the model file
    /// this replica's worker loaded — NOT an mtime: an in-place rewrite
    /// within the filesystem's timestamp granularity (or with a
    /// restored mtime) must still read as changed on reload.
    loaded_fp: Option<u64>,
}

/// One worker process (or external endpoint) serving one copy of a
/// shard's model.
struct Replica {
    /// Position within the shard (0-based): keys worker-manifest files,
    /// logs, and the least-loaded tie-break.
    idx: usize,
    /// Read-timeout stamped onto pooled connections (see
    /// [`RouterOpts::forward_timeout`]).
    forward_timeout: Duration,
    state: Mutex<ReplicaState>,
    /// Requests currently assigned to this replica — waiting in its
    /// queue or being solved. The least-loaded pick and the busy
    /// ceiling both read this.
    in_flight: AtomicUsize,
    restarts: AtomicU64,
}

impl Replica {
    /// `worker` is the supervised child process (None for external
    /// endpoints); `loaded_fp` the content fingerprint of the model
    /// file it loaded. The one constructor keeps supervised and
    /// external replicas field-for-field identical.
    fn new(
        idx: usize,
        addr: SocketAddr,
        worker: Option<ManagedWorker>,
        loaded_fp: Option<u64>,
        opts: &RouterOpts,
    ) -> Replica {
        Replica {
            idx,
            forward_timeout: opts.forward_timeout,
            state: Mutex::new(ReplicaState {
                addr,
                worker,
                conn: None,
                up: true,
                next_restart_at: None,
                backoff: opts.restart_backoff,
                loaded_fp,
            }),
            in_flight: AtomicUsize::new(0),
            restarts: AtomicU64::new(0),
        }
    }

    fn external(idx: usize, addr: SocketAddr, opts: &RouterOpts) -> Replica {
        Replica::new(idx, addr, None, None, opts)
    }

    fn addr(&self) -> SocketAddr {
        self.state.lock().unwrap().addr
    }

    fn is_up(&self) -> bool {
        self.state.lock().unwrap().up
    }

    /// Fetch this replica's `stats` over a FRESH fully-bounded
    /// connection instead of the pooled one: the pooled connection's
    /// mutex queues behind data solves, and stats is the degradation
    /// observability surface — stalling it behind a saturated queue
    /// (each entry bounded only by `forward_timeout`) would blind
    /// operators exactly when they need to look. Both the dial and the
    /// read are capped by `timeout` (an unreachable external replica —
    /// whose `up` flag never flips — must not pin the probe for the OS
    /// connect timeout). The worker serves each connection on its own
    /// thread, so the probe waits behind at most the one solve
    /// executing right now.
    fn probe_stats(&self, timeout: Duration) -> Result<Json> {
        let (up, addr) = {
            let st = self.state.lock().unwrap();
            (st.up, st.addr)
        };
        if !up {
            bail!("replica {} is down (restart pending)", self.idx);
        }
        let client = Client::connect_timeout(&addr, timeout)
            .with_context(|| format!("dialing worker {addr}"))?;
        let _ = client.set_read_timeout(Some(timeout));
        let mut client = client;
        client
            .request(&Json::obj(vec![("op", Json::str("stats"))]))
            .map_err(anyhow::Error::from)
    }

    /// Forward one raw request frame (JSON line or PLNB binary) to this
    /// replica's worker and return the raw response frame. Any failure
    /// here is *retryable from the caller's side*: the request was not
    /// answered, though a closed-mid-response one may have been
    /// processed. Holding the replica lock across the round trip gives
    /// each replica the same per-model request queue the in-process
    /// registry has — concurrent requests for one shard spread across
    /// replicas instead.
    fn forward_wire(&self, payload: &WirePayload) -> Result<WirePayload> {
        let mut st = self.state.lock().unwrap();
        if !st.up {
            bail!("replica {} is down (restart pending)", self.idx);
        }
        let addr = st.addr;
        if st.conn.is_none() {
            match Client::connect(addr) {
                Ok(c) => {
                    // Bounded reads: one wedged worker must not pin
                    // this replica's queue forever.
                    let _ = c.set_read_timeout(Some(self.forward_timeout));
                    st.conn = Some(c);
                }
                Err(e) => {
                    // Connect refusal: either the worker just died (the
                    // supervisor's exit check will flip `up` and
                    // restart it) or the failure is transient (fd
                    // pressure, backlog). Don't latch `up = false`
                    // here — only process-lifecycle events may, or a
                    // transient dial error against a live worker would
                    // down the replica with no recovery path.
                    return Err(e).with_context(|| format!("dialing worker {addr}"));
                }
            }
        }
        // A binary frame needs the pooled connection on PLNB v2; the
        // upgrade is negotiated lazily, once per connection, the first
        // time a binary frame must cross it (JSON traffic never pays
        // for it). A worker that only speaks v1 fails this forward —
        // the retry budget moves the request to a sibling replica.
        if matches!(payload, WirePayload::Binary(_))
            && st.conn.as_ref().expect("pooled connection just ensured").proto() < 2
        {
            match st.conn.as_mut().expect("pooled connection just ensured").negotiate() {
                Ok(2) => {}
                Ok(_) => {
                    // The upgrade was *refused*, not torn: the socket is
                    // healthy but pinned to v1. Drop it anyway — keeping
                    // it would re-send a doomed hello on every binary
                    // frame, and a worker restarted as v2-capable behind
                    // the same address would never be re-probed.
                    st.conn = None;
                    bail!("worker {addr} speaks protocol v1 only — cannot relay a binary frame")
                }
                Err(e) => {
                    st.conn = None;
                    return Err(e)
                        .with_context(|| format!("negotiating PLNB v2 with worker {addr}"));
                }
            }
        }
        match st.conn.as_mut().expect("pooled connection just ensured").request_wire(payload) {
            Ok(resp) => Ok(resp),
            Err(e) => {
                st.conn = None;
                Err(e).with_context(|| format!("forwarding to worker {addr}"))
            }
        }
    }
}

/// One routed model: a name and N replicas (for local shards, each a
/// supervised worker process).
pub struct Shard {
    name: String,
    /// `Some` ⇒ locally supervised (spawn/restart applies); `None` ⇒
    /// external workers the router only forwards to.
    model_path: Option<PathBuf>,
    /// The fleet manifest entry's serving-spec overrides — shipped into
    /// every replica's worker manifest on (re)spawn.
    spec: SpecOverride,
    replicas: Vec<Arc<Replica>>,
    route_retries: usize,
    max_inflight: usize,
    /// Set by [`shutdown_shard`] before the workers are taken: a shard
    /// can be removed (manifest reload on a handler thread) while the
    /// supervisor holds a stale snapshot, and a retired shard's
    /// replicas must never be restarted — that would leak worker
    /// processes.
    retired: AtomicBool,
}

impl Shard {
    fn external(name: &str, addrs: &[SocketAddr], opts: &RouterOpts) -> Shard {
        Shard {
            name: name.to_string(),
            model_path: None,
            spec: SpecOverride::default(),
            replicas: addrs
                .iter()
                .enumerate()
                .map(|(idx, &addr)| Arc::new(Replica::external(idx, addr, opts)))
                .collect(),
            route_retries: opts.route_retries,
            max_inflight: opts.max_inflight,
            retired: AtomicBool::new(false),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// (live replicas, total replicas).
    fn liveness(&self) -> (usize, usize) {
        (self.replicas.iter().filter(|r| r.is_up()).count(), self.replicas.len())
    }

    fn restarts_total(&self) -> u64 {
        self.replicas.iter().map(|r| r.restarts.load(Ordering::SeqCst)).sum()
    }

    fn in_flight_total(&self) -> usize {
        self.replicas.iter().map(|r| r.in_flight.load(Ordering::SeqCst)).sum()
    }

    fn loads(&self) -> Vec<ReplicaLoad> {
        self.replicas
            .iter()
            .map(|r| ReplicaLoad { up: r.is_up(), in_flight: r.in_flight.load(Ordering::SeqCst) })
            .collect()
    }

    /// Reserve one in-flight slot on replica `idx`, enforcing the
    /// ceiling *under concurrent admission*: the plan's load snapshot
    /// may be stale, so the check happens at the increment (CAS loop),
    /// never before it — K racing requests cannot jointly overshoot
    /// the ceiling the way a snapshot-then-add would allow.
    fn admit(&self, idx: usize) -> bool {
        let counter = &self.replicas[idx].in_flight;
        if self.max_inflight == 0 {
            counter.fetch_add(1, Ordering::SeqCst);
            return true;
        }
        let mut cur = counter.load(Ordering::SeqCst);
        loop {
            if cur >= self.max_inflight {
                return false;
            }
            match counter.compare_exchange(cur, cur + 1, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    /// Route one raw request frame (either framing): least-loaded pick,
    /// retry budget, busy ceiling.
    fn route(
        &self,
        payload: &WirePayload,
        idempotent: bool,
    ) -> std::result::Result<WirePayload, RouteFailure> {
        self.route_with(idempotent, |idx| self.replicas[idx].forward_wire(payload))
    }

    /// [`Self::route`] with the forward injected — the retry-budget and
    /// least-loaded accounting, testable without sockets (and generic
    /// over the response type, so the framing never touches it). One
    /// request makes at most `1 + route_retries` attempts (idempotent
    /// ops) or exactly 1 (everything else), never re-visiting a replica
    /// that already failed it. The in-flight slot is reserved via
    /// [`Self::admit`] before each forward and released after it.
    fn route_with<R>(
        &self,
        idempotent: bool,
        mut forward: impl FnMut(usize) -> Result<R>,
    ) -> std::result::Result<R, RouteFailure> {
        let budget = if idempotent { self.route_retries } else { 0 };
        let mut tried: Vec<usize> = Vec::new();
        let mut last_err: Option<anyhow::Error> = None;
        let mut admission_races = 0usize;
        loop {
            match plan_route(&self.loads(), &tried, self.max_inflight) {
                RoutePlan::Busy { retry_after_ms } => {
                    return Err(RouteFailure::Busy { retry_after_ms })
                }
                RoutePlan::Exhausted => {
                    let err = last_err.unwrap_or_else(|| {
                        anyhow!("all {} replica(s) down (restart pending)", self.replicas.len())
                    });
                    return Err(RouteFailure::Down(err));
                }
                RoutePlan::Try(idx) => {
                    if !self.admit(idx) {
                        // Lost an admission race: the snapshot was stale
                        // and the replica filled to its ceiling first.
                        // Nothing was forwarded (budget untouched), so
                        // re-plan off fresh counters — saturation
                        // everywhere converges to Busy above; the bound
                        // below keeps a pathological churn of
                        // completions from spinning here forever.
                        admission_races += 1;
                        if admission_races > 2 * self.replicas.len() {
                            return Err(RouteFailure::Busy {
                                retry_after_ms: retry_after_hint_ms(self.max_inflight),
                            });
                        }
                        continue;
                    }
                    let res = forward(idx);
                    self.replicas[idx].in_flight.fetch_sub(1, Ordering::SeqCst);
                    match res {
                        Ok(resp) => return Ok(resp),
                        Err(e) => {
                            if tried.len() >= budget {
                                return Err(RouteFailure::Down(e));
                            }
                            tried.push(idx);
                            last_err = Some(e);
                        }
                    }
                }
            }
        }
    }

    /// Forward one raw `update` frame to **every** replica, in index
    /// order (see [`Self::route_all_with`]).
    fn route_all(&self, payload: &WirePayload) -> Result<WirePayload> {
        self.route_all_with(|idx| self.replicas[idx].forward_wire(payload))
    }

    /// [`Self::route_all`] with the forward injected — the `update`
    /// fan-out, testable without sockets. Each replica holds its own
    /// copy of the factors, so a mutation must reach all of them to
    /// keep factor epochs in lock-step; the fleet must be whole before
    /// any forward happens (a down replica fails the request *before*
    /// the first fold, so nothing forks). The in-flight counter is
    /// held around each forward (the least-loaded pick for concurrent
    /// reads sees the update as load) but the busy ceiling is NOT
    /// enforced: shedding an update under read load would silently
    /// fork epochs. Non-transactional: a mid-fan-out failure stops the
    /// sequence and the error says how to re-sync. On success every
    /// replica answered identically (same batch folded into the same
    /// factors); the first replica's response is returned.
    fn route_all_with<R>(&self, mut forward: impl FnMut(usize) -> Result<R>) -> Result<R> {
        if let Some(idx) = self.replicas.iter().position(|r| !r.is_up()) {
            bail!(
                "replica {idx} of {} is down (restart pending) — the update fan-out \
                 needs every replica live; retry once the fleet is whole",
                self.replicas.len()
            );
        }
        let mut first: Option<R> = None;
        for (idx, replica) in self.replicas.iter().enumerate() {
            replica.in_flight.fetch_add(1, Ordering::SeqCst);
            let res = forward(idx);
            replica.in_flight.fetch_sub(1, Ordering::SeqCst);
            match res {
                Ok(resp) => first = Some(first.unwrap_or(resp)),
                Err(e) => {
                    return Err(e.context(format!(
                        "update fan-out stopped at replica {idx} of {} — the {idx} \
                         earlier replica(s) already folded the batch in; republish \
                         the model (or re-send the update once the fleet is whole) \
                         to re-sync factor epochs",
                        self.replicas.len()
                    )));
                }
            }
        }
        first.ok_or_else(|| anyhow!("shard '{}' has no replicas", self.name))
    }
}

struct Shared {
    stop: AtomicBool,
    requests: AtomicU64,
    active: AtomicUsize,
    started: Instant,
    addr: SocketAddr,
}

/// Everything the accept handlers and the supervisor thread share.
struct Control {
    shards: RwLock<BTreeMap<String, Arc<Shard>>>,
    shared: Shared,
    manifest_path: Option<PathBuf>,
    /// Applied fleet-manifest version (attempt-at-most-once, like the
    /// in-process registry).
    manifest_version: Mutex<u64>,
    /// `Some` ⇒ this router supervises local worker processes.
    worker_opts: Option<WorkerOpts>,
    opts: RouterOpts,
}

/// A bound (not yet running) shard router.
pub struct Router {
    listener: TcpListener,
    ctl: Arc<Control>,
}

impl Router {
    /// Spawn the supervised workers of the fleet manifest (`replicas`
    /// per model) and bind the front listener. Fails if any worker
    /// cannot become ready (startup is all-or-nothing; crash *recovery*
    /// is not).
    pub fn from_manifest(
        manifest_path: &Path,
        worker_opts: WorkerOpts,
        opts: RouterOpts,
    ) -> Result<Router> {
        let manifest = Manifest::load(manifest_path)?;
        Self::from_loaded(&manifest, manifest_path, worker_opts, opts)
    }

    /// [`Self::from_manifest`] for an already-parsed manifest — callers
    /// that pre-read it (the CLI sizes per-worker thread shares from
    /// the fleet) avoid a second read racing a concurrent manifest
    /// edit. `manifest_path` is kept for hot reloads.
    pub fn from_loaded(
        manifest: &Manifest,
        manifest_path: &Path,
        worker_opts: WorkerOpts,
        opts: RouterOpts,
    ) -> Result<Router> {
        if manifest.models.is_empty() {
            bail!("manifest {manifest_path:?} lists no models");
        }
        let mut shards = BTreeMap::new();
        let mut cleanup: Vec<Arc<Shard>> = Vec::new();
        let mut port_index: u16 = 0;
        for m in &manifest.models {
            let mut ports = Vec::with_capacity(m.replicas);
            for _ in 0..m.replicas {
                let port = if opts.worker_port_base > 0 {
                    let p = opts.worker_port_base.checked_add(port_index).ok_or_else(|| {
                        anyhow!("worker_port_base + {port_index} overflows a TCP port")
                    })?;
                    port_index += 1;
                    p
                } else {
                    probe_free_port(&worker_opts.host)?
                };
                ports.push(port);
            }
            match start_shard(&worker_opts, &opts, &m.name, &m.path, m.spec, &ports) {
                Ok(shard) => {
                    let shard = Arc::new(shard);
                    cleanup.push(Arc::clone(&shard));
                    shards.insert(m.name.clone(), shard);
                }
                Err(e) => {
                    // Don't leak the already-started part of the fleet.
                    for s in &cleanup {
                        shutdown_shard(s);
                    }
                    return Err(e).with_context(|| format!("starting shard '{}'", m.name));
                }
            }
        }
        match Self::bind(shards, Some(manifest_path), Some(worker_opts), opts) {
            Ok(router) => {
                *router.ctl.manifest_version.lock().unwrap() = manifest.version;
                Ok(router)
            }
            Err(e) => {
                for s in &cleanup {
                    shutdown_shard(s);
                }
                Err(e)
            }
        }
    }

    /// Route to already-running workers addressed by `host:port` — the
    /// multi-host shape (and what the bench/example use: the protocol
    /// does not care whether a worker lives in a child process, another
    /// thread, or another machine). Repeating a model name declares
    /// replicas of that model, in list order. No supervision: a dead
    /// external worker yields retryable errors (absorbed by the retry
    /// budget while a live sibling exists) until it comes back.
    pub fn with_external_workers(
        workers: &[(&str, SocketAddr)],
        opts: RouterOpts,
    ) -> Result<Router> {
        if workers.is_empty() {
            bail!("router needs at least one worker");
        }
        let mut grouped: BTreeMap<String, Vec<SocketAddr>> = BTreeMap::new();
        for &(name, addr) in workers {
            let group = grouped.entry(name.to_string()).or_default();
            if group.contains(&addr) {
                // Two "replicas" on one endpoint are one worker: ping
                // would claim redundancy that does not exist, and the
                // retry budget would re-send to the very process that
                // may already hold the request.
                bail!(
                    "worker '{name}' lists address {addr} twice — replicas must be \
                     distinct endpoints"
                );
            }
            group.push(addr);
        }
        let shards = grouped
            .into_iter()
            .map(|(name, addrs)| {
                let shard = Arc::new(Shard::external(&name, &addrs, &opts));
                (name, shard)
            })
            .collect();
        Self::bind(shards, None, None, opts)
    }

    fn bind(
        shards: BTreeMap<String, Arc<Shard>>,
        manifest_path: Option<&Path>,
        worker_opts: Option<WorkerOpts>,
        opts: RouterOpts,
    ) -> Result<Router> {
        let listener = TcpListener::bind((opts.host.as_str(), opts.route_port))
            .with_context(|| format!("binding router {}:{}", opts.host, opts.route_port))?;
        let addr = listener.local_addr().context("reading bound address")?;
        Ok(Router {
            listener,
            ctl: Arc::new(Control {
                shards: RwLock::new(shards),
                shared: Shared {
                    stop: AtomicBool::new(false),
                    requests: AtomicU64::new(0),
                    active: AtomicUsize::new(0),
                    started: Instant::now(),
                    addr,
                },
                manifest_path: manifest_path.map(|p| p.to_path_buf()),
                manifest_version: Mutex::new(0),
                worker_opts,
                opts,
            }),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.ctl.shared.addr
    }

    /// Routed model names (sorted).
    pub fn names(&self) -> Vec<String> {
        self.ctl.shards.read().unwrap().keys().cloned().collect()
    }

    /// Total worker endpoints across the fleet (replicas included).
    pub fn worker_count(&self) -> usize {
        self.ctl.shards.read().unwrap().values().map(|s| s.replicas.len()).sum()
    }

    /// Accept loop + supervisor: blocks until a client sends
    /// `shutdown`, then drains in-flight connections (bounded) and
    /// shuts the worker fleet down.
    pub fn run(self) -> Result<()> {
        let supervisor = {
            let ctl = Arc::clone(&self.ctl);
            std::thread::spawn(move || supervisor_loop(&ctl))
        };
        let accepted: Result<()> = loop {
            let (stream, peer) = match self.listener.accept() {
                Ok(x) => x,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => break Err(e).context("accepting connection"),
            };
            if self.ctl.shared.stop.load(Ordering::SeqCst) {
                break Ok(());
            }
            crate::debug!("route: connection from {peer}");
            let ctl = Arc::clone(&self.ctl);
            ctl.shared.active.fetch_add(1, Ordering::SeqCst);
            std::thread::spawn(move || {
                handle_connection(stream, &ctl);
                ctl.shared.active.fetch_sub(1, Ordering::SeqCst);
            });
        };
        // Drain in-flight requests BEFORE stopping workers, so accepted
        // requests finish against a live fleet.
        self.ctl.shared.stop.store(true, Ordering::SeqCst);
        let _ = supervisor.join();
        let deadline = Instant::now() + DRAIN_TIMEOUT;
        while self.ctl.shared.active.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        shutdown_fleet(&self.ctl);
        accepted?;
        crate::info!(
            "route: shut down after {} requests",
            self.ctl.shared.requests.load(Ordering::SeqCst)
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Shard lifecycle (supervised mode).
// ---------------------------------------------------------------------------

/// Spawn + readiness-gate one worker per port; the returned shard has
/// every replica up. Partial startup failure stops the replicas already
/// started before surfacing the error.
fn start_shard(
    worker_opts: &WorkerOpts,
    opts: &RouterOpts,
    name: &str,
    model_path: &Path,
    spec: SpecOverride,
    ports: &[u16],
) -> Result<Shard> {
    let mut replicas: Vec<Arc<Replica>> = Vec::with_capacity(ports.len());
    for (idx, &port) in ports.iter().enumerate() {
        match start_worker_checked(worker_opts, opts.ready_timeout, name, idx, model_path, spec, port)
        {
            Ok(worker) => {
                let addr = worker.addr();
                let loaded_fp = file_fingerprint(model_path);
                crate::info!("route: shard '{name}' replica {idx} up on {addr}");
                replicas.push(Arc::new(Replica::new(
                    idx,
                    addr,
                    Some(worker),
                    loaded_fp,
                    opts,
                )));
            }
            Err(e) => {
                for r in &replicas {
                    shutdown_replica(r);
                }
                return Err(e)
                    .with_context(|| format!("starting replica {idx} of shard '{name}'"));
            }
        }
    }
    Ok(Shard {
        name: name.to_string(),
        model_path: Some(model_path.to_path_buf()),
        spec,
        replicas,
        route_retries: opts.route_retries,
        max_inflight: opts.max_inflight,
        retired: AtomicBool::new(false),
    })
}

/// Graceful-then-forced stop of one replica's worker (local or
/// external).
fn shutdown_replica(replica: &Replica) {
    let (worker, addr) = {
        let mut st = replica.state.lock().unwrap();
        st.up = false;
        st.conn = None;
        (st.worker.take(), st.addr)
    };
    match worker {
        Some(w) => w.shutdown(WORKER_SHUTDOWN_TIMEOUT),
        None => {
            // External (or already-dead local) worker: best-effort
            // protocol shutdown — the router owns fleet lifecycle.
            if let Ok(stream) = TcpStream::connect_timeout(&addr, Duration::from_millis(500)) {
                let _ = stream.set_read_timeout(Some(Duration::from_secs(1)));
                let mut stream = stream;
                let _ = stream.write_all(b"{\"op\": \"shutdown\"}\n");
                let mut r = BufReader::new(stream);
                let _ = read_wire(&mut r, MAX_FRAME_BYTES, false);
            }
        }
    }
}

/// Retire a shard and stop every replica. Retiring BEFORE taking the
/// workers means the supervisor (which re-checks the flag under each
/// replica's state lock before installing a restart) and this path both
/// end with the workers stopped, whichever order they run in.
fn shutdown_shard(shard: &Shard) {
    shard.retired.store(true, Ordering::SeqCst);
    for replica in &shard.replicas {
        shutdown_replica(replica);
    }
}

fn shutdown_fleet(ctl: &Control) {
    let shards: Vec<Arc<Shard>> = ctl.shards.read().unwrap().values().cloned().collect();
    for shard in shards {
        shutdown_shard(&shard);
    }
}

/// The supervisor: crash detection, bounded-backoff restarts, and
/// manifest polling, off the accept path.
fn supervisor_loop(ctl: &Control) {
    let tick = ctl.opts.health_interval;
    let mut since_poll = Duration::ZERO;
    while !ctl.shared.stop.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        since_poll += tick;
        if ctl.manifest_path.is_some() && since_poll >= ctl.opts.manifest_poll {
            since_poll = Duration::ZERO;
            if let Err(e) = reload_manifest(ctl) {
                crate::warn_!("route: manifest reload failed: {e:#}");
            }
        }
        let shards: Vec<Arc<Shard>> = ctl.shards.read().unwrap().values().cloned().collect();
        for shard in shards {
            for replica in &shard.replicas {
                if ctl.shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                supervise_replica(ctl, &shard, replica);
            }
        }
    }
}

/// Next restart delay after a failed restart attempt: double the
/// current window, capped at `max`. Pure so the schedule is testable
/// without spawning (and killing) real worker processes; the reset to
/// [`RouterOpts::restart_backoff`] on a successful restart lives in
/// [`supervise_replica`].
fn next_backoff(cur: Duration, max: Duration) -> Duration {
    (cur * 2).min(max)
}

/// One heartbeat step for one replica: detect a dead local worker, and
/// restart it once its backoff window has passed.
fn supervise_replica(ctl: &Control, shard: &Shard, replica: &Replica) {
    let Some(model_path) = shard.model_path.as_ref() else {
        return; // external: nothing to supervise
    };
    if shard.retired.load(Ordering::SeqCst) {
        return; // removed from the table: never restart
    }
    // Phase 1 (under the lock): notice an exited process and schedule
    // its restart.
    let restart_due = {
        let mut st = replica.state.lock().unwrap();
        if let Some(w) = st.worker.as_mut() {
            if let Some(status) = w.poll_exit() {
                crate::warn_!(
                    "route: worker '{}' replica {} on {} died ({status}); restart in {:?}",
                    shard.name,
                    replica.idx,
                    st.addr,
                    st.backoff
                );
                st.worker = None;
                st.conn = None;
                st.up = false;
                st.next_restart_at = Some(Instant::now() + st.backoff);
            }
        }
        st.worker.is_none()
            && st.next_restart_at.map(|t| Instant::now() >= t).unwrap_or(true)
    };
    if !restart_due {
        return;
    }
    // Phase 2 (lock released): spawn + readiness-gate the replacement.
    // Requests meanwhile fail fast (and fail over to sibling replicas)
    // instead of queueing behind a held lock. Only this supervisor
    // thread mutates worker lifecycle, so dropping the lock is
    // race-free.
    let port = match probe_free_port(&ctl.opts.host) {
        Ok(p) => p,
        Err(e) => {
            crate::warn_!("route: no port for '{}': {e:#}", shard.name);
            return;
        }
    };
    let worker_opts = ctl.worker_opts.as_ref().expect("supervised shard without worker opts");
    match start_worker_checked(
        worker_opts,
        ctl.opts.ready_timeout,
        &shard.name,
        replica.idx,
        model_path,
        shard.spec,
        port,
    ) {
        Ok(worker) => {
            let mut st = replica.state.lock().unwrap();
            if shard.retired.load(Ordering::SeqCst) {
                // Retired while we were spawning: stop the replacement
                // instead of installing it.
                drop(st);
                worker.shutdown(WORKER_SHUTDOWN_TIMEOUT);
                return;
            }
            st.addr = worker.addr();
            st.worker = Some(worker);
            st.conn = None;
            st.up = true;
            st.next_restart_at = None;
            st.backoff = ctl.opts.restart_backoff; // became ready: reset
            st.loaded_fp = file_fingerprint(model_path);
            let n = replica.restarts.fetch_add(1, Ordering::SeqCst) + 1;
            crate::info!(
                "route: worker '{}' replica {} restarted on {} (restart #{n})",
                shard.name,
                replica.idx,
                st.addr
            );
        }
        Err(e) => {
            let mut st = replica.state.lock().unwrap();
            st.backoff = next_backoff(st.backoff, ctl.opts.max_backoff);
            st.next_restart_at = Some(Instant::now() + st.backoff);
            crate::warn_!(
                "route: restart of '{}' replica {} failed ({e:#}); next attempt in {:?}",
                shard.name,
                replica.idx,
                st.backoff
            );
        }
    }
}

/// Spawn + wait-ready, cleaning up the child on readiness failure.
fn start_worker_checked(
    worker_opts: &WorkerOpts,
    ready_timeout: Duration,
    name: &str,
    replica: usize,
    model_path: &Path,
    spec: SpecOverride,
    port: u16,
) -> Result<ManagedWorker> {
    let mut worker = spawn_worker(worker_opts, name, replica, model_path, spec, port)?;
    match wait_ready(&mut worker, ready_timeout) {
        Ok(()) => Ok(worker),
        Err(e) => {
            worker.shutdown(WORKER_SHUTDOWN_TIMEOUT);
            Err(e)
        }
    }
}

/// Re-read the fleet manifest and apply it if its version increased:
/// start workers for new models, stop workers for de-listed ones, and
/// swap (new workers first, then old ones drained) models whose file,
/// path, or replica count changed. Untouched shards — and their
/// in-flight requests — are never interrupted.
fn reload_manifest(ctl: &Control) -> Result<bool> {
    let (Some(path), Some(worker_opts)) = (&ctl.manifest_path, &ctl.worker_opts) else {
        return Ok(false);
    };
    let manifest = Manifest::load(path)?;
    {
        let mut version = ctl.manifest_version.lock().unwrap();
        if manifest.version <= *version {
            return Ok(false);
        }
        // Recorded before the fleet changes: a manifest with a broken
        // entry must not re-run its apply on every poll.
        *version = manifest.version;
    }
    // Removals first.
    let listed: Vec<&str> = manifest.models.iter().map(|m| m.name.as_str()).collect();
    let stale: Vec<Arc<Shard>> = {
        let mut shards = ctl.shards.write().unwrap();
        let names: Vec<String> =
            shards.keys().filter(|n| !listed.contains(&n.as_str())).cloned().collect();
        names.iter().filter_map(|n| shards.remove(n)).collect()
    };
    for shard in &stale {
        crate::info!("route: shard '{}' de-listed by manifest", shard.name);
        shutdown_shard(shard);
    }
    // Additions and changes. One broken entry must not abort the rest
    // of the apply: the version is already recorded (attempt-at-most-
    // once), so anything skipped here would stay missing until the
    // operator publishes a NEW version — apply every entry, then
    // report the failures together.
    let mut failures: Vec<String> = Vec::new();
    for m in &manifest.models {
        let existing = ctl.shards.read().unwrap().get(&m.name).cloned();
        let needs_start = match &existing {
            None => true,
            Some(s) => {
                // Content fingerprint, not mtime: an in-place rewrite
                // within the filesystem's timestamp granularity must
                // still restart the shard. An unreadable file (fp =
                // None) reads as changed so the restart surfaces the
                // real I/O error loudly instead of silently serving
                // stale factors.
                let fp = file_fingerprint(&m.path);
                s.model_path.as_deref() != Some(m.path.as_path())
                    || s.spec != m.spec
                    || s.replicas.len() != m.replicas
                    || fp.is_none()
                    || s.replicas.iter().any(|r| r.state.lock().unwrap().loaded_fp != fp)
            }
        };
        if !needs_start {
            continue;
        }
        let started = (0..m.replicas)
            .map(|_| probe_free_port(&worker_opts.host))
            .collect::<Result<Vec<u16>>>()
            .and_then(|ports| {
                start_shard(worker_opts, &ctl.opts, &m.name, &m.path, m.spec, &ports)
            });
        match started {
            Ok(shard) => {
                let old = ctl.shards.write().unwrap().insert(m.name.clone(), Arc::new(shard));
                if let Some(old) = old {
                    // Swapped: the replacement serves before the old
                    // workers drain, so the shard never goes dark.
                    shutdown_shard(&old);
                }
            }
            Err(e) => failures.push(format!("'{}': {e:#}", m.name)),
        }
    }
    if !failures.is_empty() {
        bail!(
            "manifest version {} partially applied — failed shards: {}",
            manifest.version,
            failures.join("; ")
        );
    }
    crate::info!("route: applied manifest version {}", manifest.version);
    Ok(true)
}

// ---------------------------------------------------------------------------
// Request handling.
// ---------------------------------------------------------------------------

fn handle_connection(stream: TcpStream, ctl: &Control) {
    serve_wire(stream, &ctl.shared.requests, ctl.shared.addr, |payload, conn| {
        dispatch(payload, conn, ctl)
    });
}

/// A JSON object as a line frame.
fn line(j: Json) -> WirePayload {
    WirePayload::Line(j.to_string())
}

/// Handle one request frame, returning the raw response frame (routed
/// responses pass through bytes-untouched) and the shutdown flag.
fn dispatch(payload: &WirePayload, conn: &mut ConnState, ctl: &Control) -> (WirePayload, bool) {
    match payload {
        WirePayload::Line(l) => dispatch_line(payload, l.trim(), conn, ctl),
        WirePayload::Binary(bytes) => (dispatch_binary(payload, bytes, ctl), false),
    }
}

fn dispatch_line(
    payload: &WirePayload,
    trimmed: &str,
    conn: &mut ConnState,
    ctl: &Control,
) -> (WirePayload, bool) {
    let req = match parse_request(trimmed) {
        Ok(req) => req,
        Err(e) => return (line(err_json(format!("bad request: {e}"))), false),
    };
    let op = req.get("op").as_str().unwrap_or("");
    match op {
        "hello" => (line(handle_hello(&req, conn)), false),
        "transform" | "recommend" => {
            let Some(name) = req.get("model").as_str() else {
                return (line(err_json("request needs \"model\"".to_string())), false);
            };
            let name = name.to_string();
            // The ORIGINAL payload is forwarded, untrimmed and uncopied
            // (worker-side parsing tolerates surrounding whitespace):
            // the relay path stays zero-copy for line frames, exactly
            // like binary frames.
            (route_payload(payload, &name, op_is_idempotent(op), ctl), false)
        }
        "update" => {
            let Some(name) = req.get("model").as_str() else {
                return (line(err_json("request needs \"model\"".to_string())), false);
            };
            let name = name.to_string();
            (route_all_payload(payload, &name, ctl), false)
        }
        "ping" => (line(op_ping(ctl)), false),
        "stats" => (line(op_stats(ctl)), false),
        "load" => (line(op_load(&req, ctl)), false),
        "unload" => (
            line(err_json(
                "routed daemon: the fleet is declared by the manifest — publish a new \
                 version instead of unload"
                    .to_string(),
            )),
            false,
        ),
        "shutdown" => {
            ctl.shared.stop.store(true, Ordering::SeqCst);
            (line(ok_obj(vec![("bye", Json::Bool(true))])), true)
        }
        "" => (line(err_json("request needs an \"op\" string".to_string())), false),
        other => (
            line(err_json(format!(
                "unknown op '{other}' (try transform|recommend|update|stats|load|ping|hello|shutdown)"
            ))),
            false,
        ),
    }
}

/// Route one PLNB binary frame: op + model come straight out of the
/// fixed header (no payload parse), and the frame is relayed
/// bytes-untouched, exactly like a JSON line. The idempotent dense
/// reads get the least-loaded pick + retry budget; a binary `update`
/// batch gets the every-replica fan-out. Errors come back as JSON
/// lines, as everywhere in the protocol.
fn dispatch_binary(payload: &WirePayload, bytes: &[u8], ctl: &Control) -> WirePayload {
    match wire::peek_route(bytes) {
        Ok((op, model)) if op.is_request() => {
            let name = model.to_string();
            route_payload(payload, &name, true, ctl)
        }
        Ok((wire::BinOp::Update, model)) => {
            let name = model.to_string();
            route_all_payload(payload, &name, ctl)
        }
        Ok((op, _)) => line(err_json(format!(
            "unexpected PLNB frame op {op:?} — only transform/recommend/update requests route"
        ))),
        Err(e) => line(err_json(format!("bad binary frame: {e:#}"))),
    }
}

/// Route a data op to the least-loaded live replica of its model's
/// shard, relaying raw bytes. Failures come back as `"retryable": true`
/// errors once the retry budget is spent; backpressure comes back as
/// the distinct `"busy": true` error with a `retry_after_ms` hint. The
/// *caller* decides whether to re-send after that (the router already
/// used its budget, and never re-sends a non-idempotent request a
/// worker may have processed).
fn route_payload(
    payload: &WirePayload,
    name: &str,
    idempotent: bool,
    ctl: &Control,
) -> WirePayload {
    let shard = ctl.shards.read().unwrap().get(name).cloned();
    let Some(shard) = shard else {
        let names = ctl.shards.read().unwrap().keys().cloned().collect::<Vec<_>>().join(", ");
        return line(err_json(format!("no model '{name}' routed (have: {names})")));
    };
    match shard.route(payload, idempotent) {
        Ok(raw) => raw,
        Err(RouteFailure::Busy { retry_after_ms }) => line(Json::obj(vec![
            ("ok", Json::Bool(false)),
            (
                "error",
                Json::str(format!(
                    "shard '{name}': busy — all {} live replica(s) at the in-flight \
                     ceiling ({})",
                    shard.liveness().0,
                    shard.max_inflight
                )),
            ),
            ("busy", Json::Bool(true)),
            ("retryable", Json::Bool(true)),
            ("retry_after_ms", Json::num(retry_after_ms as f64)),
            ("model", Json::str(name)),
        ])),
        Err(RouteFailure::Down(e)) => line(Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::str(format!("shard '{name}': {e:#}"))),
            ("retryable", Json::Bool(true)),
            ("model", Json::str(name)),
        ])),
    }
}

/// [`route_payload`] for the non-idempotent `update` op: fanned out to
/// **every** replica of the shard (see [`Shard::route_all_with`]).
/// Failures report `"retryable": false` — a blind client re-send is
/// NOT safe, because replicas ahead of the failure already folded the
/// batch in; the error message says how to re-sync.
fn route_all_payload(payload: &WirePayload, name: &str, ctl: &Control) -> WirePayload {
    let shard = ctl.shards.read().unwrap().get(name).cloned();
    let Some(shard) = shard else {
        let names = ctl.shards.read().unwrap().keys().cloned().collect::<Vec<_>>().join(", ");
        return line(err_json(format!("no model '{name}' routed (have: {names})")));
    };
    match shard.route_all(payload) {
        Ok(raw) => raw,
        Err(e) => line(Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::str(format!("shard '{name}': {e:#}"))),
            ("retryable", Json::Bool(false)),
            ("model", Json::str(name)),
        ])),
    }
}

fn op_ping(ctl: &Control) -> Json {
    let shards = ctl.shards.read().unwrap();
    let workers = Json::Obj(
        shards
            .iter()
            .map(|(name, s)| {
                let (up, total) = s.liveness();
                (
                    name.clone(),
                    Json::obj(vec![
                        ("up", Json::Bool(up > 0)),
                        ("up_replicas", Json::num(up as f64)),
                        ("replicas", Json::num(total as f64)),
                    ]),
                )
            })
            .collect(),
    );
    ok_obj(vec![
        ("pong", Json::Bool(true)),
        ("router", Json::Bool(true)),
        ("workers", workers),
    ])
}

fn op_load(req: &Json, ctl: &Control) -> Json {
    match (req.get("name").as_str(), req.get("path").as_str()) {
        (None, None) => match reload_manifest(ctl) {
            Ok(reloaded) => ok_obj(vec![
                ("reloaded", Json::Bool(reloaded)),
                (
                    "manifest_version",
                    Json::num(*ctl.manifest_version.lock().unwrap() as f64),
                ),
            ]),
            Err(e) => err_json(format!("manifest reload: {e:#}")),
        },
        _ => err_json(
            "routed daemon: the fleet is declared by the manifest — publish a new version \
             instead of a targeted load"
                .to_string(),
        ),
    }
}

/// Counter keys summed when merging per-replica (and per-shard) model
/// stats; every other field keeps the first replica's value, and
/// `avg_sweeps` is recomputed from the merged sums.
const SUMMED_STATS: &[&str] = &[
    "requests",
    "docs",
    "micro_batches",
    "sweeps",
    "warm_hits",
    "warm_misses",
    "warm_cache_entries",
    "hits",
    "misses",
];

/// Merge one replica's model-stats object into the aggregate: counters
/// in [`SUMMED_STATS`] add, nested objects (the cold/warm/mixed
/// buckets) merge recursively, and structural fields (v/k/tile/threads/
/// nnz/epoch — identical across replicas of one model, since `update`
/// fans out to all of them) keep their first value.
fn merge_model_stats(into: &mut Json, from: &Json) {
    let Json::Obj(b) = from else { return };
    let Json::Obj(a) = into else { return };
    for (k, v) in b {
        if !a.contains_key(k.as_str()) {
            a.insert(k.clone(), v.clone());
            continue;
        }
        match (a.get_mut(k).unwrap(), v) {
            (Json::Num(x), Json::Num(y)) if SUMMED_STATS.contains(&k.as_str()) => {
                *x += *y;
            }
            (cur @ Json::Obj(_), Json::Obj(_)) => merge_model_stats(cur, v),
            _ => {}
        }
    }
    let sweeps = a.get("sweeps").and_then(|j| j.as_f64());
    let batches = a.get("micro_batches").and_then(|j| j.as_f64());
    if let (Some(s), Some(m)) = (sweeps, batches) {
        if a.contains_key("avg_sweeps") {
            let avg = if m == 0.0 { 0.0 } else { s / m };
            a.insert("avg_sweeps".to_string(), Json::Num(avg));
        }
    }
}

/// Aggregate `stats` across the fleet: merged per-model stats (the
/// single-daemon shape, so existing consumers keep working — counters
/// summed across replicas) plus a `workers` health map with per-replica
/// liveness, restarts, and queue depth.
fn op_stats(ctl: &Control) -> Json {
    let shards: Vec<Arc<Shard>> = ctl.shards.read().unwrap().values().cloned().collect();
    // Probe every replica of every shard CONCURRENTLY: probes are
    // independent and each is bounded by [`STATS_PROBE_TIMEOUT`], so
    // the whole fleet answers within one timeout — serially, a fleet
    // with several unreachable replicas (blackholed externals never
    // flip `up`) would stall stats for the SUM of their timeouts.
    let probes: Vec<Vec<Result<Json>>> = std::thread::scope(|s| {
        let handles: Vec<Vec<_>> = shards
            .iter()
            .map(|shard| {
                shard
                    .replicas
                    .iter()
                    .map(|replica| {
                        let replica = Arc::clone(replica);
                        s.spawn(move || replica.probe_stats(STATS_PROBE_TIMEOUT))
                    })
                    .collect()
            })
            .collect();
        handles
            .into_iter()
            .map(|hs| {
                hs.into_iter()
                    .map(|h| h.join().expect("stats probe thread panicked"))
                    .collect()
            })
            .collect()
    });
    let mut models: BTreeMap<String, Json> = BTreeMap::new();
    let mut workers: BTreeMap<String, Json> = BTreeMap::new();
    for (shard, shard_probes) in shards.iter().zip(probes) {
        let mut replica_stats: Vec<Json> = Vec::with_capacity(shard.replicas.len());
        let mut requests_total = 0.0f64;
        let mut uptime_max = 0.0f64;
        let mut any_probe = false;
        for (replica, probe) in shard.replicas.iter().zip(shard_probes) {
            let mut info = vec![
                ("replica", Json::num(replica.idx as f64)),
                ("addr", Json::str(replica.addr().to_string())),
                ("up", Json::Bool(replica.is_up())),
                ("restarts", Json::num(replica.restarts.load(Ordering::SeqCst) as f64)),
                ("in_flight", Json::num(replica.in_flight.load(Ordering::SeqCst) as f64)),
            ];
            match probe {
                Ok(stats) => {
                    any_probe = true;
                    requests_total += stats.get("requests").as_f64().unwrap_or(0.0);
                    uptime_max = uptime_max.max(stats.get("uptime_secs").as_f64().unwrap_or(0.0));
                    info.push(("requests", stats.get("requests").clone()));
                    info.push(("uptime_secs", stats.get("uptime_secs").clone()));
                    if !matches!(stats.get("kernels"), Json::Null) {
                        info.push(("kernels", stats.get("kernels").clone()));
                    }
                    if let Some(obj) = stats.get("models").as_obj() {
                        for (model, mstats) in obj {
                            if models.contains_key(model.as_str()) {
                                merge_model_stats(models.get_mut(model).unwrap(), mstats);
                            } else {
                                models.insert(model.clone(), mstats.clone());
                            }
                        }
                    }
                }
                Err(e) => info.push(("error", Json::str(format!("{e:#}")))),
            }
            replica_stats.push(Json::obj(info));
        }
        let (up, total) = shard.liveness();
        // `addr` stays the first replica's endpoint, and `requests` /
        // `uptime_secs` stay present at the shard level (summed / oldest
        // across replicas — for one replica, exactly the pre-replication
        // values) so single-replica consumers keep working; the full
        // per-replica map is in `replica_stats`.
        let first_addr = shard
            .replicas
            .first()
            .map(|r| r.addr().to_string())
            .unwrap_or_default();
        let mut entry = vec![
            ("addr", Json::str(first_addr)),
            ("up", Json::Bool(up > 0)),
            ("up_replicas", Json::num(up as f64)),
            ("replicas", Json::num(total as f64)),
            ("restarts", Json::num(shard.restarts_total() as f64)),
            ("in_flight", Json::num(shard.in_flight_total() as f64)),
        ];
        if any_probe {
            entry.push(("requests", Json::num(requests_total)));
            entry.push(("uptime_secs", Json::num(uptime_max)));
        }
        entry.push(("replica_stats", Json::Arr(replica_stats)));
        workers.insert(shard.name.clone(), Json::obj(entry));
    }
    ok_obj(vec![
        ("router", Json::Bool(true)),
        (
            "uptime_secs",
            Json::num(ctl.shared.started.elapsed().as_secs_f64()),
        ),
        (
            "requests",
            Json::num(ctl.shared.requests.load(Ordering::SeqCst) as f64),
        ),
        (
            "manifest_version",
            Json::num(*ctl.manifest_version.lock().unwrap() as f64),
        ),
        // The router's own selection; per-replica backends ride along in
        // `workers.*.replica_stats` (heterogeneous fleets can differ).
        ("kernels", Json::str(crate::kernels::Kernels::select().name())),
        ("workers", Json::Obj(workers)),
        ("models", Json::Obj(models)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn load(up: bool, in_flight: usize) -> ReplicaLoad {
        ReplicaLoad { up, in_flight }
    }

    /// Regression for the restart schedule: doubling from the
    /// configured initial delay, hard-capped at `max_backoff` (~30 s by
    /// default — a flapping worker must never back off into minutes),
    /// and restarting the doubling from the initial delay again after a
    /// success (the reset `supervise_replica` applies on ready).
    #[test]
    fn restart_backoff_doubles_caps_and_resets() {
        let opts = RouterOpts::default();
        assert_eq!(opts.max_backoff, Duration::from_secs(30));

        let mut b = opts.restart_backoff;
        let mut seen = vec![b];
        for _ in 0..12 {
            b = next_backoff(b, opts.max_backoff);
            seen.push(b);
        }
        // 500ms, 1s, 2s, ... exact doubling until the cap.
        assert_eq!(seen[0], Duration::from_millis(500));
        assert_eq!(seen[1], Duration::from_secs(1));
        assert_eq!(seen[4], Duration::from_secs(8));
        for w in seen.windows(2) {
            assert!(w[1] >= w[0], "backoff must be monotone: {seen:?}");
            assert!(w[1] <= opts.max_backoff, "cap violated: {seen:?}");
            if w[1] < opts.max_backoff {
                assert_eq!(w[1], w[0] * 2, "pre-cap growth must be exact doubling");
            }
        }
        // Saturates at the cap and stays there.
        assert_eq!(*seen.last().unwrap(), opts.max_backoff);
        assert_eq!(next_backoff(opts.max_backoff, opts.max_backoff), opts.max_backoff);
        // The reset value (applied on a successful restart) restarts
        // the schedule from the initial delay, not from the cap.
        assert_eq!(next_backoff(opts.restart_backoff, opts.max_backoff), Duration::from_secs(1));
    }

    /// An external shard over fake addresses — routing-decision tests
    /// never dial them because the forward closure is injected.
    fn test_shard(replicas: usize, route_retries: usize, max_inflight: usize) -> Shard {
        let addr: SocketAddr = "127.0.0.1:1".parse().unwrap();
        let opts = RouterOpts { route_retries, max_inflight, ..RouterOpts::default() };
        Shard::external("m", &vec![addr; replicas], &opts)
    }

    #[test]
    fn plan_route_picks_least_loaded_with_index_tie_break() {
        let loads = [load(true, 2), load(true, 1), load(true, 1)];
        assert_eq!(plan_route(&loads, &[], 0), RoutePlan::Try(1), "tie breaks to lowest idx");
        assert_eq!(plan_route(&loads, &[1], 0), RoutePlan::Try(2), "tried replicas excluded");
        assert_eq!(plan_route(&loads, &[1, 2], 0), RoutePlan::Try(0));
        assert_eq!(plan_route(&loads, &[0, 1, 2], 0), RoutePlan::Exhausted);
    }

    #[test]
    fn plan_route_skips_down_replicas_and_exhausts_on_all_down() {
        let loads = [load(false, 0), load(true, 9), load(false, 0)];
        assert_eq!(plan_route(&loads, &[], 0), RoutePlan::Try(1), "only live replica wins");
        let all_down = [load(false, 0), load(false, 0)];
        assert_eq!(plan_route(&all_down, &[], 0), RoutePlan::Exhausted);
        assert_eq!(plan_route(&all_down, &[], 4), RoutePlan::Exhausted, "down beats busy");
    }

    #[test]
    fn plan_route_signals_busy_only_when_every_live_replica_is_at_ceiling() {
        let some_room = [load(true, 4), load(true, 3)];
        assert_eq!(plan_route(&some_room, &[], 4), RoutePlan::Try(1), "one below ceiling");
        let full = [load(true, 4), load(true, 5)];
        match plan_route(&full, &[], 4) {
            RoutePlan::Busy { retry_after_ms } => {
                assert_eq!(retry_after_ms, retry_after_hint_ms(4));
            }
            other => panic!("expected busy, got {other:?}"),
        }
        // A down replica below the ceiling does not avert backpressure.
        let down_idle = [load(false, 0), load(true, 4)];
        assert!(matches!(plan_route(&down_idle, &[], 4), RoutePlan::Busy { .. }));
        // The ceiling is judged over the UNTRIED candidates: after a
        // failure on the idle replica, a saturated survivor means Busy
        // immediately — not a doomed admission attempt against it.
        let failed_idle = [load(true, 0), load(true, 4)];
        assert!(matches!(plan_route(&failed_idle, &[0], 4), RoutePlan::Busy { .. }));
        // Ceiling 0 = unlimited.
        assert_eq!(plan_route(&full, &[], 0), RoutePlan::Try(0));
    }

    #[test]
    fn retry_after_hint_is_bounded_and_scales_with_the_ceiling() {
        assert_eq!(retry_after_hint_ms(4), 25, "shallow ceiling: minimum hint");
        assert!(retry_after_hint_ms(32) > retry_after_hint_ms(4), "deeper queue, longer hint");
        assert_eq!(retry_after_hint_ms(32), 160);
        assert_eq!(retry_after_hint_ms(usize::MAX), 1000, "clamped");
    }

    #[test]
    fn route_retries_on_a_different_replica_within_budget() {
        let shard = test_shard(3, 1, 0);
        let attempts = Mutex::new(Vec::new());
        let out = shard.route_with(true, |idx| {
            attempts.lock().unwrap().push(idx);
            if attempts.lock().unwrap().len() == 1 {
                Err(anyhow!("first forward fails"))
            } else {
                Ok(format!("ok from {idx}"))
            }
        });
        assert_eq!(out.unwrap(), "ok from 1");
        let attempts = attempts.into_inner().unwrap();
        assert_eq!(attempts, vec![0, 1], "retry goes to a different replica");
    }

    #[test]
    fn route_budget_exhaustion_is_retryable_with_all_replicas_distinct() {
        let shard = test_shard(3, 2, 0);
        let attempts = AtomicUsize::new(0);
        let out = shard.route_with(true, |_idx| {
            attempts.fetch_add(1, Ordering::SeqCst);
            Err(anyhow!("forward fails"))
        });
        match out {
            Err(RouteFailure::Down(e)) => assert!(format!("{e:#}").contains("forward fails")),
            _ => panic!("expected Down after budget exhaustion"),
        }
        assert_eq!(attempts.load(Ordering::SeqCst), 3, "1 attempt + 2 retries");

        // Budget larger than the replica set: attempts stop once every
        // live replica has been tried, not after the nominal budget.
        let shard = test_shard(2, 10, 0);
        let attempts = AtomicUsize::new(0);
        let out = shard.route_with(true, |_idx| {
            attempts.fetch_add(1, Ordering::SeqCst);
            Err(anyhow!("forward fails"))
        });
        assert!(matches!(out, Err(RouteFailure::Down(_))));
        assert_eq!(attempts.load(Ordering::SeqCst), 2, "never re-visits a failed replica");
    }

    #[test]
    fn route_never_retries_non_idempotent_ops() {
        let shard = test_shard(3, 5, 0);
        let attempts = AtomicUsize::new(0);
        let out = shard.route_with(false, |_idx| {
            attempts.fetch_add(1, Ordering::SeqCst);
            Err(anyhow!("forward fails"))
        });
        assert!(matches!(out, Err(RouteFailure::Down(_))));
        assert_eq!(attempts.load(Ordering::SeqCst), 1, "exactly one attempt");
        assert!(op_is_idempotent("transform") && op_is_idempotent("recommend"));
        assert!(!op_is_idempotent("load") && !op_is_idempotent("shutdown"));
        // `update` mutates factor state: a duplicate execution would
        // fold the same batch in twice. It must never ride the
        // retried/least-loaded path.
        assert!(!op_is_idempotent("update"));
    }

    #[test]
    fn route_all_forwards_to_every_replica_and_returns_the_first_response() {
        let shard = test_shard(3, 5, 0);
        let attempts = Mutex::new(Vec::new());
        let out = shard.route_all_with(|idx| {
            attempts.lock().unwrap().push(idx);
            Ok(format!("ok from {idx}"))
        });
        assert_eq!(out.unwrap(), "ok from 0");
        assert_eq!(attempts.into_inner().unwrap(), vec![0, 1, 2], "every replica, in order");
        assert_eq!(shard.in_flight_total(), 0, "in-flight released after each forward");
    }

    #[test]
    fn route_all_stops_at_first_failure_and_explains_resync() {
        let shard = test_shard(3, 5, 0);
        let attempts = Mutex::new(Vec::new());
        let out: Result<String> = shard.route_all_with(|idx| {
            attempts.lock().unwrap().push(idx);
            if idx == 1 {
                Err(anyhow!("replica died"))
            } else {
                Ok("ok".to_string())
            }
        });
        let err = format!("{:#}", out.unwrap_err());
        assert!(err.contains("stopped at replica 1"), "{err}");
        assert!(err.contains("re-sync"), "failure must explain recovery: {err}");
        assert_eq!(
            attempts.into_inner().unwrap(),
            vec![0, 1],
            "replicas after the failure never see the batch"
        );

        // A down replica fails the fan-out BEFORE any forward — the
        // live siblings' factors are never forked by a doomed update.
        let shard = test_shard(2, 0, 0);
        shard.replicas[1].state.lock().unwrap().up = false;
        let out: Result<String> =
            shard.route_all_with(|_| panic!("must not forward while a replica is down"));
        let err = format!("{:#}", out.unwrap_err());
        assert!(err.contains("down"), "{err}");
    }

    #[test]
    fn route_all_bypasses_the_busy_ceiling() {
        // Updates are control-plane traffic: shedding one while reads
        // saturate the ceiling would silently fork factor epochs.
        let shard = test_shard(2, 0, 4);
        for r in &shard.replicas {
            r.in_flight.store(4, Ordering::SeqCst);
        }
        let out = shard.route_all_with(|idx| Ok(idx));
        assert_eq!(out.unwrap(), 0);
    }

    #[test]
    fn admission_is_reserve_style_up_to_the_ceiling() {
        // The ceiling must hold under concurrent admission, so the
        // check lives at the increment (CAS), not in the planning
        // snapshot: N successful admits fill the ceiling exactly, the
        // next one is refused.
        let shard = test_shard(1, 0, 2);
        assert!(shard.admit(0));
        assert!(shard.admit(0));
        assert!(!shard.admit(0), "third admit must lose: ceiling is 2");
        assert_eq!(shard.replicas[0].in_flight.load(Ordering::SeqCst), 2);
        // Ceiling 0 = unlimited: always admitted.
        let unbounded = test_shard(1, 0, 0);
        for _ in 0..100 {
            assert!(unbounded.admit(0));
        }
    }

    #[test]
    fn route_returns_busy_without_forwarding_when_shard_is_saturated() {
        let shard = test_shard(2, 1, 4);
        for r in &shard.replicas {
            r.in_flight.store(4, Ordering::SeqCst);
        }
        let out = shard.route_with(true, |_idx| panic!("must not forward while saturated"));
        match out {
            Err(RouteFailure::Busy { retry_after_ms }) => assert!(retry_after_ms >= 25),
            _ => panic!("expected busy"),
        }
        // Free one slot: routed again, to the freed replica.
        shard.replicas[1].in_flight.store(3, Ordering::SeqCst);
        let out = shard.route_with(true, |idx| Ok(format!("ok from {idx}")));
        assert_eq!(out.unwrap(), "ok from 1");
    }

    #[test]
    fn route_skips_down_replicas() {
        let shard = test_shard(2, 1, 0);
        shard.replicas[0].state.lock().unwrap().up = false;
        let out = shard.route_with(true, |idx| {
            assert_eq!(idx, 1, "down replica must not be picked");
            Ok("ok".to_string())
        });
        assert_eq!(out.unwrap(), "ok");
    }

    #[test]
    fn merge_model_stats_sums_counters_and_recomputes_averages() {
        let mut a = Json::parse(
            r#"{"v": 30, "k": 4, "kernels": "avx2+fma", "requests": 2, "warm_hits": 1,
                "cold": {"requests": 2, "sweeps": 10, "micro_batches": 2, "avg_sweeps": 5}}"#,
        )
        .unwrap();
        let b = Json::parse(
            r#"{"v": 30, "k": 4, "kernels": "scalar", "requests": 3, "warm_hits": 4,
                "cold": {"requests": 3, "sweeps": 2, "micro_batches": 2, "avg_sweeps": 1}}"#,
        )
        .unwrap();
        merge_model_stats(&mut a, &b);
        assert_eq!(a.get("v").as_usize(), Some(30), "structural fields keep first value");
        assert_eq!(
            a.get("kernels").as_str(),
            Some("avx2+fma"),
            "kernel backend is structural: keep-first, never concatenated or dropped"
        );
        assert_eq!(a.get("requests").as_usize(), Some(5));
        assert_eq!(a.get("warm_hits").as_usize(), Some(5));
        assert_eq!(a.get("cold").get("requests").as_usize(), Some(5));
        assert_eq!(a.get("cold").get("sweeps").as_usize(), Some(12));
        assert_eq!(
            a.get("cold").get("avg_sweeps").as_f64(),
            Some(3.0),
            "avg recomputed from merged sums, not averaged averages"
        );
    }

    #[test]
    fn ping_reports_per_replica_liveness() {
        // Regression for the pre-replication shape: one `up` flag per
        // model hid partial degradation. Now k-of-N is observable.
        let addr: SocketAddr = "127.0.0.1:1".parse().unwrap();
        let opts = RouterOpts::default();
        let shard = Arc::new(Shard::external("m", &[addr, addr], &opts));
        shard.replicas[1].state.lock().unwrap().up = false;
        let mut shards = BTreeMap::new();
        shards.insert("m".to_string(), Arc::clone(&shard));
        let ctl = Control {
            shards: RwLock::new(shards),
            shared: Shared {
                stop: AtomicBool::new(false),
                requests: AtomicU64::new(0),
                active: AtomicUsize::new(0),
                started: Instant::now(),
                addr,
            },
            manifest_path: None,
            manifest_version: Mutex::new(0),
            worker_opts: None,
            opts,
        };
        let ping = op_ping(&ctl);
        let m = ping.get("workers").get("m");
        assert_eq!(m.get("up").as_bool(), Some(true), "one live replica keeps the shard up");
        assert_eq!(m.get("up_replicas").as_usize(), Some(1), "degradation visible: 1 of 2");
        assert_eq!(m.get("replicas").as_usize(), Some(2));
        // Both replicas down: the shard reads as down.
        shard.replicas[0].state.lock().unwrap().up = false;
        let ping = op_ping(&ctl);
        assert_eq!(ping.get("workers").get("m").get("up").as_bool(), Some(false));
        assert_eq!(ping.get("workers").get("m").get("up_replicas").as_usize(), Some(0));
    }

    #[test]
    fn external_shard_down_worker_yields_retryable_path() {
        // An external shard pointing at a dead port: the forward fails
        // with a dial error on every replica, and once the budget is
        // spent the shard surfaces the Down (retryable) class — never
        // Busy, never a silent blind re-send. The replicas stay `up`
        // (externals have no supervised lifecycle to wait out).
        let port = probe_free_port("127.0.0.1").unwrap();
        let addr: SocketAddr = format!("127.0.0.1:{port}").parse().unwrap();
        let shard = Shard::external("m", &[addr], &RouterOpts::default());
        let req = WirePayload::Line("{\"op\": \"ping\"}".to_string());
        match shard.route(&req, true) {
            Err(RouteFailure::Down(e)) => {
                assert!(format!("{e:#}").contains("dialing worker"), "{e:#}");
            }
            _ => panic!("expected Down"),
        }
        assert!(shard.replicas[0].is_up());
        assert_eq!(shard.in_flight_total(), 0, "in-flight rebalanced after the failure");
    }

    #[test]
    fn binary_frames_route_by_their_header_model() {
        // A PLNB frame is routed off the fixed header alone: an unknown
        // model is the same "no model routed" error JSON lines get, and
        // a known model with a dead endpoint surfaces the retryable
        // Down class — the routing logic is framing-agnostic.
        let port = probe_free_port("127.0.0.1").unwrap();
        let addr: SocketAddr = format!("127.0.0.1:{port}").parse().unwrap();
        let opts = RouterOpts::default();
        let mut shards = BTreeMap::new();
        shards.insert("m".to_string(), Arc::new(Shard::external("m", &[addr], &opts)));
        let ctl = Control {
            shards: RwLock::new(shards),
            shared: Shared {
                stop: AtomicBool::new(false),
                requests: AtomicU64::new(0),
                active: AtomicUsize::new(0),
                started: Instant::now(),
                addr,
            },
            manifest_path: None,
            manifest_version: Mutex::new(0),
            worker_opts: None,
            opts,
        };
        let resp_of = |payload: &WirePayload| -> Json {
            let mut conn = ConnState { proto: 2 };
            match dispatch(payload, &mut conn, &ctl) {
                (WirePayload::Line(s), false) => Json::parse(s.trim()).unwrap(),
                _ => panic!("expected a JSON line response"),
            }
        };
        let ghost = wire::encode(wire::BinOp::Transform, "ghost", &Json::Null, 1, 2, &[1.0, 2.0])
            .unwrap();
        let resp = resp_of(&WirePayload::Binary(ghost));
        assert!(resp.get("error").as_str().unwrap().contains("no model 'ghost'"), "{resp}");
        let known = wire::encode(wire::BinOp::Transform, "m", &Json::Null, 1, 2, &[1.0, 2.0])
            .unwrap();
        let resp = resp_of(&WirePayload::Binary(known));
        assert_eq!(resp.get("retryable").as_bool(), Some(true), "{resp}");
        assert_eq!(resp.get("model").as_str(), Some("m"), "{resp}");
        // A binary update frame takes the fan-out path: same unknown-
        // model error, but a failed fan-out is NOT retryable (a blind
        // re-send could double-fold the batch on replicas that already
        // applied it).
        let upd = wire::encode(wire::BinOp::Update, "m", &Json::Null, 1, 2, &[1.0, 2.0]).unwrap();
        let resp = resp_of(&WirePayload::Binary(upd));
        assert_eq!(resp.get("retryable").as_bool(), Some(false), "{resp}");
        assert_eq!(resp.get("model").as_str(), Some("m"), "{resp}");
        // A response-op frame is rejected without routing.
        let bogus = wire::encode(wire::BinOp::TransformResp, "", &Json::Null, 0, 0, &[]).unwrap();
        let resp = resp_of(&WirePayload::Binary(bogus));
        assert!(resp.get("error").as_str().unwrap().contains("only transform/recommend"));
    }

    #[test]
    fn merge_model_stats_all_zero_merge_stays_finite() {
        // Regression: merging replicas that all report zero requests
        // must keep avg_sweeps at 0.0 (a 0/0 here would serialize as
        // the literal `NaN`, which is not JSON — every stats consumer
        // downstream would fail to parse the response).
        let zero = r#"{"requests": 0,
            "cold": {"requests": 0, "sweeps": 0, "micro_batches": 0, "avg_sweeps": 0}}"#;
        let mut a = Json::parse(zero).unwrap();
        let b = Json::parse(zero).unwrap();
        merge_model_stats(&mut a, &b);
        let avg = a.get("cold").get("avg_sweeps").as_f64().unwrap();
        assert_eq!(avg, 0.0, "zero merged denominator must not produce NaN");
        let reparsed = Json::parse(&a.to_string()).expect("merged stats must stay valid JSON");
        assert_eq!(reparsed.get("cold").get("avg_sweeps").as_f64(), Some(0.0));
    }

    #[test]
    fn router_rejects_empty_fleet_and_groups_duplicates_into_replicas() {
        assert!(Router::with_external_workers(&[], RouterOpts::default()).is_err());
        // Repeating a name with DISTINCT endpoints declares replicas of
        // one model, not an error…
        let a1: SocketAddr = "127.0.0.1:1".parse().unwrap();
        let a2: SocketAddr = "127.0.0.1:2".parse().unwrap();
        let router =
            Router::with_external_workers(&[("a", a1), ("a", a2)], RouterOpts::default())
                .unwrap();
        assert_eq!(router.names(), vec!["a"]);
        assert_eq!(router.worker_count(), 2);
        // …but the same endpoint twice is one worker masquerading as
        // redundancy: rejected.
        let err = Router::with_external_workers(&[("a", a1), ("a", a1)], RouterOpts::default())
            .unwrap_err();
        assert!(format!("{err:#}").contains("twice"), "{err:#}");
    }
}

//! `plnmf route` — a cross-process shard router over per-model workers.
//!
//! The in-process [`crate::serve::ModelRegistry`] already isolates each
//! model into its own serving shard (pool, queue, warm cache); this
//! module moves that seam across a **process boundary**: a front daemon
//! speaking the exact single-daemon NDJSON protocol fans requests out
//! to one `plnmf serve` worker *process* per model. Each model's
//! factors, cached Gram, and warm-start LRU then live in exactly one
//! process's heap — resident in that process's caches instead of
//! sharing one daemon's, the serving-scale reading of the paper's §5
//! data-movement argument and the process-grid direction of MPI-FAUN.
//!
//! ## Topology
//!
//! ```text
//!                        ┌─ worker :p1 — plnmf serve {news}
//!  client ── route :p0 ──┼─ worker :p2 — plnmf serve {faces}
//!        NDJSON/TCP      └─ worker :p3 — plnmf serve {wiki}
//! ```
//!
//! The routing table maps model name → `host:port` — never a PID — so
//! a shard served from another host plugs in unchanged
//! ([`Router::with_external_workers`]); process supervision is a
//! property of *local* shards only ([`crate::serve::worker`]).
//!
//! ## Protocol
//!
//! * `transform` / `recommend` — routed by `"model"` to that shard's
//!   worker. The request line is forwarded and the response line
//!   relayed **bytes-untouched**, so routed responses are bit-for-bit
//!   identical to a single daemon's (asserted in
//!   `tests/integration_router.rs`).
//! * `stats` — aggregated: the merged per-model stats of every worker
//!   plus a `workers` health map (addr / up / restarts).
//! * `ping` — local, with per-worker `up` flags.
//! * `load` (bare) — manifest re-read, as in the single daemon.
//!   Targeted `load`/`unload` are rejected: in routed mode the fleet is
//!   declared by the manifest, so publish a new version instead.
//! * `shutdown` — graceful drain: stop accepting, finish in-flight
//!   requests (bounded), then shut every worker down.
//!
//! ## Failure semantics
//!
//! A worker crash is detected by the supervisor heartbeat (process
//! exit) or by a failed forward (connection drop). In-flight requests
//! to that shard fail with `"retryable": true` — the router never
//! blindly re-sends a request that a worker may already have processed
//! (see [`crate::serve::server::CLOSED_MID_RESPONSE`]). The worker is
//! restarted on a fresh port after a bounded backoff (doubling from
//! `restart_backoff_ms` up to a cap while startup keeps failing), and
//! the routing table is re-pointed. Manifest hot-reload applies
//! added/removed/changed models the same way — shards whose entry is
//! untouched keep serving without interruption.

use std::collections::BTreeMap;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant, SystemTime};

use anyhow::{anyhow, bail, Context};

use crate::serve::registry::Manifest;
use crate::serve::server::{
    err_json, ok_obj, parse_request, read_frame, serve_lines, Client, MAX_LINE_BYTES,
};
use crate::serve::worker::{
    probe_free_port, spawn_worker, wait_ready, ManagedWorker, WorkerOpts,
};
use crate::util::json::Json;
use crate::Result;

/// How long `run` waits for in-flight connections after `shutdown`.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(2);
/// Grace given to each worker between the protocol `shutdown` and kill.
const WORKER_SHUTDOWN_TIMEOUT: Duration = Duration::from_secs(3);

/// Router configuration (the CLI maps `route_port` /
/// `worker_port_base` / `restart_backoff_ms` onto this).
#[derive(Debug, Clone)]
pub struct RouterOpts {
    /// Interface the front listener binds.
    pub host: String,
    /// Front port (0 = OS-assigned; read back via [`Router::local_addr`]).
    pub route_port: u16,
    /// First worker port; workers of the initial fleet take
    /// `base`, `base+1`, … (0 = every worker gets an OS-assigned port).
    /// Restarted or hot-added workers always move to a fresh
    /// OS-assigned port — the old one may sit in `TIME_WAIT`.
    pub worker_port_base: u16,
    /// Initial delay before restarting a crashed worker. Doubles (up to
    /// [`RouterOpts::max_backoff`]) while restarts keep failing to
    /// become ready; resets once a restart succeeds.
    pub restart_backoff: Duration,
    /// Upper bound of the restart backoff.
    pub max_backoff: Duration,
    /// Supervisor heartbeat period (crash detection latency).
    pub health_interval: Duration,
    /// How long a (re)started worker gets to answer its first ping.
    pub ready_timeout: Duration,
    /// How often the supervisor re-checks the fleet manifest.
    pub manifest_poll: Duration,
    /// Read timeout on pooled worker connections. Bounds how long one
    /// forwarded request can hold a shard's queue: a worker that is
    /// alive but wedged would otherwise pin the shard mutex forever,
    /// freezing supervision of the whole fleet and router shutdown.
    pub forward_timeout: Duration,
}

impl Default for RouterOpts {
    fn default() -> Self {
        RouterOpts {
            host: "127.0.0.1".to_string(),
            route_port: 0,
            worker_port_base: 0,
            restart_backoff: Duration::from_millis(500),
            max_backoff: Duration::from_secs(10),
            health_interval: Duration::from_millis(200),
            ready_timeout: Duration::from_secs(10),
            manifest_poll: Duration::from_secs(2),
            forward_timeout: Duration::from_secs(60),
        }
    }
}

struct ShardState {
    addr: SocketAddr,
    /// The supervised local process (None while down, and always for
    /// external shards).
    worker: Option<ManagedWorker>,
    /// Pooled protocol connection; dropped on any forward failure and
    /// re-dialed (against the *current* addr) on the next request.
    conn: Option<Client>,
    up: bool,
    /// Earliest instant the supervisor may attempt the next restart.
    next_restart_at: Option<Instant>,
    backoff: Duration,
    loaded_mtime: Option<SystemTime>,
}

/// One routed model: a name, a worker address, and (for local shards)
/// the supervised process behind it.
pub struct Shard {
    name: String,
    /// `Some` ⇒ locally supervised (spawn/restart applies); `None` ⇒
    /// external worker the router only forwards to.
    model_path: Option<PathBuf>,
    /// Read-timeout stamped onto pooled connections (see
    /// [`RouterOpts::forward_timeout`]).
    forward_timeout: Duration,
    state: Mutex<ShardState>,
    restarts: AtomicU64,
    /// Set by [`shutdown_shard`] before the worker is taken: a shard
    /// can be removed (manifest reload on a handler thread) while the
    /// supervisor holds a stale snapshot, and a retired shard must
    /// never be restarted — that would leak a worker process.
    retired: AtomicBool,
}

impl Shard {
    fn external(name: &str, addr: SocketAddr, opts: &RouterOpts) -> Shard {
        let backoff = opts.restart_backoff;
        Shard {
            name: name.to_string(),
            model_path: None,
            forward_timeout: opts.forward_timeout,
            state: Mutex::new(ShardState {
                addr,
                worker: None,
                conn: None,
                up: true,
                next_restart_at: None,
                backoff,
                loaded_mtime: None,
            }),
            restarts: AtomicU64::new(0),
            retired: AtomicBool::new(false),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn addr(&self) -> SocketAddr {
        self.state.lock().unwrap().addr
    }

    pub fn is_up(&self) -> bool {
        self.state.lock().unwrap().up
    }

    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::SeqCst)
    }

    /// Forward one raw request line to this shard's worker and return
    /// the raw response line. Any failure here is *retryable from the
    /// caller's side* (the router reports it as such): the request was
    /// not answered, though a closed-mid-response one may have been
    /// processed. Holding the shard lock across the round trip gives
    /// the same per-model request queue the in-process registry has.
    fn forward_raw(&self, line: &str) -> Result<String> {
        let mut st = self.state.lock().unwrap();
        if !st.up {
            bail!("worker is down (restart pending)");
        }
        if st.conn.is_none() {
            match Client::connect(st.addr) {
                Ok(c) => {
                    // Bounded reads: one wedged worker must not pin
                    // this shard's queue (and with it, fleet-wide
                    // supervision) forever.
                    let _ = c.set_read_timeout(Some(self.forward_timeout));
                    st.conn = Some(c);
                }
                Err(e) => {
                    // Connect refusal: either the worker just died (the
                    // supervisor's exit check will flip `up` and
                    // restart it) or the failure is transient (fd
                    // pressure, backlog). Don't latch `up = false`
                    // here — only process-lifecycle events may, or a
                    // transient dial error against a live worker would
                    // down the shard with no recovery path.
                    return Err(e).with_context(|| format!("dialing worker {}", st.addr));
                }
            }
        }
        match st.conn.as_mut().unwrap().request_raw(line) {
            Ok(resp) => Ok(resp),
            Err(e) => {
                st.conn = None;
                Err(e).with_context(|| format!("forwarding to worker {}", st.addr))
            }
        }
    }
}

struct Shared {
    stop: AtomicBool,
    requests: AtomicU64,
    active: AtomicUsize,
    started: Instant,
    addr: SocketAddr,
}

/// Everything the accept handlers and the supervisor thread share.
struct Control {
    shards: RwLock<BTreeMap<String, Arc<Shard>>>,
    shared: Shared,
    manifest_path: Option<PathBuf>,
    /// Applied fleet-manifest version (attempt-at-most-once, like the
    /// in-process registry).
    manifest_version: Mutex<u64>,
    /// `Some` ⇒ this router supervises local worker processes.
    worker_opts: Option<WorkerOpts>,
    opts: RouterOpts,
}

/// A bound (not yet running) shard router.
pub struct Router {
    listener: TcpListener,
    ctl: Arc<Control>,
}

impl Router {
    /// Spawn one supervised worker per model of the fleet manifest and
    /// bind the front listener. Fails if any worker cannot become
    /// ready (startup is all-or-nothing; crash *recovery* is not).
    pub fn from_manifest(
        manifest_path: &Path,
        worker_opts: WorkerOpts,
        opts: RouterOpts,
    ) -> Result<Router> {
        let manifest = Manifest::load(manifest_path)?;
        Self::from_loaded(&manifest, manifest_path, worker_opts, opts)
    }

    /// [`Self::from_manifest`] for an already-parsed manifest — callers
    /// that pre-read it (the CLI sizes per-worker thread shares from
    /// the fleet) avoid a second read racing a concurrent manifest
    /// edit. `manifest_path` is kept for hot reloads.
    pub fn from_loaded(
        manifest: &Manifest,
        manifest_path: &Path,
        worker_opts: WorkerOpts,
        opts: RouterOpts,
    ) -> Result<Router> {
        if manifest.models.is_empty() {
            bail!("manifest {manifest_path:?} lists no models");
        }
        let mut shards = BTreeMap::new();
        let mut cleanup: Vec<Arc<Shard>> = Vec::new();
        for (i, m) in manifest.models.iter().enumerate() {
            let port = if opts.worker_port_base > 0 {
                opts.worker_port_base
                    .checked_add(i as u16)
                    .ok_or_else(|| anyhow!("worker_port_base + {i} overflows a TCP port"))?
            } else {
                probe_free_port(&worker_opts.host)?
            };
            match start_shard(&worker_opts, &opts, &m.name, &m.path, port) {
                Ok(shard) => {
                    let shard = Arc::new(shard);
                    cleanup.push(Arc::clone(&shard));
                    shards.insert(m.name.clone(), shard);
                }
                Err(e) => {
                    // Don't leak the already-started part of the fleet.
                    for s in &cleanup {
                        shutdown_shard(s);
                    }
                    return Err(e).with_context(|| format!("starting shard '{}'", m.name));
                }
            }
        }
        match Self::bind(shards, Some(manifest_path), Some(worker_opts), opts) {
            Ok(router) => {
                *router.ctl.manifest_version.lock().unwrap() = manifest.version;
                Ok(router)
            }
            Err(e) => {
                for s in &cleanup {
                    shutdown_shard(s);
                }
                Err(e)
            }
        }
    }

    /// Route to already-running workers addressed by `host:port` — the
    /// multi-host shape (and what the bench/example use: the protocol
    /// does not care whether a worker lives in a child process, another
    /// thread, or another machine). No supervision: a dead external
    /// worker yields retryable errors until it comes back.
    pub fn with_external_workers(
        workers: &[(&str, SocketAddr)],
        opts: RouterOpts,
    ) -> Result<Router> {
        if workers.is_empty() {
            bail!("router needs at least one worker");
        }
        let mut shards = BTreeMap::new();
        for &(name, addr) in workers {
            if shards
                .insert(name.to_string(), Arc::new(Shard::external(name, addr, &opts)))
                .is_some()
            {
                bail!("worker '{name}' listed twice");
            }
        }
        Self::bind(shards, None, None, opts)
    }

    fn bind(
        shards: BTreeMap<String, Arc<Shard>>,
        manifest_path: Option<&Path>,
        worker_opts: Option<WorkerOpts>,
        opts: RouterOpts,
    ) -> Result<Router> {
        let listener = TcpListener::bind((opts.host.as_str(), opts.route_port))
            .with_context(|| format!("binding router {}:{}", opts.host, opts.route_port))?;
        let addr = listener.local_addr().context("reading bound address")?;
        Ok(Router {
            listener,
            ctl: Arc::new(Control {
                shards: RwLock::new(shards),
                shared: Shared {
                    stop: AtomicBool::new(false),
                    requests: AtomicU64::new(0),
                    active: AtomicUsize::new(0),
                    started: Instant::now(),
                    addr,
                },
                manifest_path: manifest_path.map(|p| p.to_path_buf()),
                manifest_version: Mutex::new(0),
                worker_opts,
                opts,
            }),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.ctl.shared.addr
    }

    /// Routed model names (sorted).
    pub fn names(&self) -> Vec<String> {
        self.ctl.shards.read().unwrap().keys().cloned().collect()
    }

    /// Accept loop + supervisor: blocks until a client sends
    /// `shutdown`, then drains in-flight connections (bounded) and
    /// shuts the worker fleet down.
    pub fn run(self) -> Result<()> {
        let supervisor = {
            let ctl = Arc::clone(&self.ctl);
            std::thread::spawn(move || supervisor_loop(&ctl))
        };
        let accepted: Result<()> = loop {
            let (stream, peer) = match self.listener.accept() {
                Ok(x) => x,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => break Err(e).context("accepting connection"),
            };
            if self.ctl.shared.stop.load(Ordering::SeqCst) {
                break Ok(());
            }
            crate::debug!("route: connection from {peer}");
            let ctl = Arc::clone(&self.ctl);
            ctl.shared.active.fetch_add(1, Ordering::SeqCst);
            std::thread::spawn(move || {
                handle_connection(stream, &ctl);
                ctl.shared.active.fetch_sub(1, Ordering::SeqCst);
            });
        };
        // Drain in-flight requests BEFORE stopping workers, so accepted
        // requests finish against a live fleet.
        self.ctl.shared.stop.store(true, Ordering::SeqCst);
        let _ = supervisor.join();
        let deadline = Instant::now() + DRAIN_TIMEOUT;
        while self.ctl.shared.active.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        shutdown_fleet(&self.ctl);
        accepted?;
        crate::info!(
            "route: shut down after {} requests",
            self.ctl.shared.requests.load(Ordering::SeqCst)
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Shard lifecycle (supervised mode).
// ---------------------------------------------------------------------------

/// Spawn + readiness-gate one worker; the returned shard is up.
fn start_shard(
    worker_opts: &WorkerOpts,
    opts: &RouterOpts,
    name: &str,
    model_path: &Path,
    port: u16,
) -> Result<Shard> {
    let worker = start_worker_checked(worker_opts, opts.ready_timeout, name, model_path, port)?;
    let addr = worker.addr();
    let loaded_mtime = std::fs::metadata(model_path).and_then(|m| m.modified()).ok();
    crate::info!("route: shard '{name}' up on {addr}");
    Ok(Shard {
        name: name.to_string(),
        model_path: Some(model_path.to_path_buf()),
        forward_timeout: opts.forward_timeout,
        state: Mutex::new(ShardState {
            addr,
            worker: Some(worker),
            conn: None,
            up: true,
            next_restart_at: None,
            backoff: opts.restart_backoff,
            loaded_mtime,
        }),
        restarts: AtomicU64::new(0),
        retired: AtomicBool::new(false),
    })
}

/// Graceful-then-forced stop of one shard's worker (local or external).
fn shutdown_shard(shard: &Shard) {
    // Retire BEFORE taking the worker: the supervisor re-checks this
    // flag under the state lock before installing a restarted worker,
    // so the two orders both end with the worker stopped (see
    // `supervise`).
    shard.retired.store(true, Ordering::SeqCst);
    let (worker, addr) = {
        let mut st = shard.state.lock().unwrap();
        st.up = false;
        st.conn = None;
        (st.worker.take(), st.addr)
    };
    match worker {
        Some(w) => w.shutdown(WORKER_SHUTDOWN_TIMEOUT),
        None => {
            // External (or already-dead local) worker: best-effort
            // protocol shutdown — the router owns fleet lifecycle.
            if let Ok(stream) = TcpStream::connect_timeout(&addr, Duration::from_millis(500)) {
                let _ = stream.set_read_timeout(Some(Duration::from_secs(1)));
                let mut stream = stream;
                let _ = stream.write_all(b"{\"op\": \"shutdown\"}\n");
                let mut r = BufReader::new(stream);
                let _ = read_frame(&mut r, MAX_LINE_BYTES);
            }
        }
    }
}

fn shutdown_fleet(ctl: &Control) {
    let shards: Vec<Arc<Shard>> = ctl.shards.read().unwrap().values().cloned().collect();
    for shard in shards {
        shutdown_shard(&shard);
    }
}

/// The supervisor: crash detection, bounded-backoff restarts, and
/// manifest polling, off the accept path.
fn supervisor_loop(ctl: &Control) {
    let tick = ctl.opts.health_interval;
    let mut since_poll = Duration::ZERO;
    while !ctl.shared.stop.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        since_poll += tick;
        if ctl.manifest_path.is_some() && since_poll >= ctl.opts.manifest_poll {
            since_poll = Duration::ZERO;
            if let Err(e) = reload_manifest(ctl) {
                crate::warn_!("route: manifest reload failed: {e:#}");
            }
        }
        let shards: Vec<Arc<Shard>> = ctl.shards.read().unwrap().values().cloned().collect();
        for shard in shards {
            if ctl.shared.stop.load(Ordering::SeqCst) {
                return;
            }
            supervise(ctl, &shard);
        }
    }
}

/// One heartbeat step for one shard: detect a dead local worker, and
/// restart it once its backoff window has passed.
fn supervise(ctl: &Control, shard: &Shard) {
    let Some(model_path) = shard.model_path.as_ref() else {
        return; // external: nothing to supervise
    };
    if shard.retired.load(Ordering::SeqCst) {
        return; // removed from the table: never restart
    }
    // Phase 1 (under the lock): notice an exited process and schedule
    // its restart.
    let restart_due = {
        let mut st = shard.state.lock().unwrap();
        if let Some(w) = st.worker.as_mut() {
            if let Some(status) = w.poll_exit() {
                crate::warn_!(
                    "route: worker '{}' on {} died ({status}); restart in {:?}",
                    shard.name,
                    st.addr,
                    st.backoff
                );
                st.worker = None;
                st.conn = None;
                st.up = false;
                st.next_restart_at = Some(Instant::now() + st.backoff);
            }
        }
        st.worker.is_none()
            && st.next_restart_at.map(|t| Instant::now() >= t).unwrap_or(true)
    };
    if !restart_due {
        return;
    }
    // Phase 2 (lock released): spawn + readiness-gate the replacement.
    // Requests meanwhile fail fast with a retryable error instead of
    // queueing behind a held lock. Only this supervisor thread mutates
    // worker lifecycle, so dropping the lock is race-free.
    let port = match probe_free_port(&ctl.opts.host) {
        Ok(p) => p,
        Err(e) => {
            crate::warn_!("route: no port for '{}': {e:#}", shard.name);
            return;
        }
    };
    let worker_opts = ctl.worker_opts.as_ref().expect("supervised shard without worker opts");
    match start_worker_checked(worker_opts, ctl.opts.ready_timeout, &shard.name, model_path, port)
    {
        Ok(worker) => {
            let mut st = shard.state.lock().unwrap();
            if shard.retired.load(Ordering::SeqCst) {
                // Retired while we were spawning: stop the replacement
                // instead of installing it.
                drop(st);
                worker.shutdown(WORKER_SHUTDOWN_TIMEOUT);
                return;
            }
            st.addr = worker.addr();
            st.worker = Some(worker);
            st.conn = None;
            st.up = true;
            st.next_restart_at = None;
            st.backoff = ctl.opts.restart_backoff; // became ready: reset
            st.loaded_mtime =
                std::fs::metadata(model_path).and_then(|m| m.modified()).ok();
            let n = shard.restarts.fetch_add(1, Ordering::SeqCst) + 1;
            crate::info!(
                "route: worker '{}' restarted on {} (restart #{n})",
                shard.name,
                st.addr
            );
        }
        Err(e) => {
            let mut st = shard.state.lock().unwrap();
            st.backoff = (st.backoff * 2).min(ctl.opts.max_backoff);
            st.next_restart_at = Some(Instant::now() + st.backoff);
            crate::warn_!(
                "route: restart of '{}' failed ({e:#}); next attempt in {:?}",
                shard.name,
                st.backoff
            );
        }
    }
}

/// Spawn + wait-ready, cleaning up the child on readiness failure.
fn start_worker_checked(
    worker_opts: &WorkerOpts,
    ready_timeout: Duration,
    name: &str,
    model_path: &Path,
    port: u16,
) -> Result<ManagedWorker> {
    let mut worker = spawn_worker(worker_opts, name, model_path, port)?;
    match wait_ready(&mut worker, ready_timeout) {
        Ok(()) => Ok(worker),
        Err(e) => {
            worker.shutdown(WORKER_SHUTDOWN_TIMEOUT);
            Err(e)
        }
    }
}

/// Re-read the fleet manifest and apply it if its version increased:
/// start workers for new models, stop workers for de-listed ones, and
/// swap (new worker first, then old one drained) models whose file
/// changed. Untouched shards — and their in-flight requests — are
/// never interrupted.
fn reload_manifest(ctl: &Control) -> Result<bool> {
    let (Some(path), Some(worker_opts)) = (&ctl.manifest_path, &ctl.worker_opts) else {
        return Ok(false);
    };
    let manifest = Manifest::load(path)?;
    {
        let mut version = ctl.manifest_version.lock().unwrap();
        if manifest.version <= *version {
            return Ok(false);
        }
        // Recorded before the fleet changes: a manifest with a broken
        // entry must not re-run its apply on every poll.
        *version = manifest.version;
    }
    // Removals first.
    let listed: Vec<&str> = manifest.models.iter().map(|m| m.name.as_str()).collect();
    let stale: Vec<Arc<Shard>> = {
        let mut shards = ctl.shards.write().unwrap();
        let names: Vec<String> =
            shards.keys().filter(|n| !listed.contains(&n.as_str())).cloned().collect();
        names.iter().filter_map(|n| shards.remove(n)).collect()
    };
    for shard in &stale {
        crate::info!("route: shard '{}' de-listed by manifest", shard.name);
        shutdown_shard(shard);
    }
    // Additions and changes. One broken entry must not abort the rest
    // of the apply: the version is already recorded (attempt-at-most-
    // once), so anything skipped here would stay missing until the
    // operator publishes a NEW version — apply every entry, then
    // report the failures together.
    let mut failures: Vec<String> = Vec::new();
    for m in &manifest.models {
        let existing = ctl.shards.read().unwrap().get(&m.name).cloned();
        let needs_start = match &existing {
            None => true,
            Some(s) => {
                let st = s.state.lock().unwrap();
                let mtime = std::fs::metadata(&m.path).and_then(|x| x.modified()).ok();
                s.model_path.as_deref() != Some(m.path.as_path())
                    || (mtime.is_some() && mtime != st.loaded_mtime)
            }
        };
        if !needs_start {
            continue;
        }
        let started = probe_free_port(&worker_opts.host)
            .and_then(|port| start_shard(worker_opts, &ctl.opts, &m.name, &m.path, port));
        match started {
            Ok(shard) => {
                let old = ctl.shards.write().unwrap().insert(m.name.clone(), Arc::new(shard));
                if let Some(old) = old {
                    // Swapped: the replacement serves before the old
                    // worker drains, so the shard never goes dark.
                    shutdown_shard(&old);
                }
            }
            Err(e) => failures.push(format!("'{}': {e:#}", m.name)),
        }
    }
    if !failures.is_empty() {
        bail!(
            "manifest version {} partially applied — failed shards: {}",
            manifest.version,
            failures.join("; ")
        );
    }
    crate::info!("route: applied manifest version {}", manifest.version);
    Ok(true)
}

// ---------------------------------------------------------------------------
// Request handling.
// ---------------------------------------------------------------------------

fn handle_connection(stream: TcpStream, ctl: &Control) {
    serve_lines(stream, &ctl.shared.requests, ctl.shared.addr, |trimmed| {
        dispatch(trimmed, ctl)
    });
}

/// Handle one request line, returning the raw response line (routed
/// responses pass through bytes-untouched) and the shutdown flag.
fn dispatch(line: &str, ctl: &Control) -> (String, bool) {
    let req = match parse_request(line) {
        Ok(req) => req,
        Err(e) => return (err_json(format!("bad request: {e}")).to_string(), false),
    };
    let op = req.get("op").as_str().unwrap_or("");
    match op {
        "transform" | "recommend" => (route_to_shard(line, &req, ctl), false),
        "ping" => (op_ping(ctl).to_string(), false),
        "stats" => (op_stats(ctl).to_string(), false),
        "load" => (op_load(&req, ctl).to_string(), false),
        "unload" => (
            err_json(
                "routed daemon: the fleet is declared by the manifest — publish a new \
                 version instead of unload"
                    .to_string(),
            )
            .to_string(),
            false,
        ),
        "shutdown" => {
            ctl.shared.stop.store(true, Ordering::SeqCst);
            (ok_obj(vec![("bye", Json::Bool(true))]).to_string(), true)
        }
        "" => (err_json("request needs an \"op\" string".to_string()).to_string(), false),
        other => (
            err_json(format!(
                "unknown op '{other}' (try transform|recommend|stats|load|ping|shutdown)"
            ))
            .to_string(),
            false,
        ),
    }
}

/// Route a data op to its model's worker, relaying raw bytes. Failures
/// come back as `"retryable": true` errors: the worker may be mid-
/// restart, and the *caller* decides whether to re-send (the router
/// does not, because a closed-mid-response request may have been
/// processed).
fn route_to_shard(line: &str, req: &Json, ctl: &Control) -> String {
    let Some(name) = req.get("model").as_str() else {
        return err_json("request needs \"model\"".to_string()).to_string();
    };
    let shard = ctl.shards.read().unwrap().get(name).cloned();
    let Some(shard) = shard else {
        let names = ctl.shards.read().unwrap().keys().cloned().collect::<Vec<_>>().join(", ");
        return err_json(format!("no model '{name}' routed (have: {names})")).to_string();
    };
    match shard.forward_raw(line) {
        Ok(raw) => raw,
        Err(e) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::str(format!("shard '{name}': {e:#}"))),
            ("retryable", Json::Bool(true)),
            ("model", Json::str(name)),
        ])
        .to_string(),
    }
}

fn op_ping(ctl: &Control) -> Json {
    let shards = ctl.shards.read().unwrap();
    let workers = Json::Obj(
        shards
            .iter()
            .map(|(name, s)| {
                (name.clone(), Json::obj(vec![("up", Json::Bool(s.is_up()))]))
            })
            .collect(),
    );
    ok_obj(vec![
        ("pong", Json::Bool(true)),
        ("router", Json::Bool(true)),
        ("workers", workers),
    ])
}

fn op_load(req: &Json, ctl: &Control) -> Json {
    match (req.get("name").as_str(), req.get("path").as_str()) {
        (None, None) => match reload_manifest(ctl) {
            Ok(reloaded) => ok_obj(vec![
                ("reloaded", Json::Bool(reloaded)),
                (
                    "manifest_version",
                    Json::num(*ctl.manifest_version.lock().unwrap() as f64),
                ),
            ]),
            Err(e) => err_json(format!("manifest reload: {e:#}")),
        },
        _ => err_json(
            "routed daemon: the fleet is declared by the manifest — publish a new version \
             instead of a targeted load"
                .to_string(),
        ),
    }
}

/// Aggregate `stats` across the fleet: merged per-model stats (the
/// single-daemon shape, so existing consumers keep working) plus a
/// `workers` health map.
fn op_stats(ctl: &Control) -> Json {
    let shards: Vec<Arc<Shard>> = ctl.shards.read().unwrap().values().cloned().collect();
    let mut models: BTreeMap<String, Json> = BTreeMap::new();
    let mut workers: BTreeMap<String, Json> = BTreeMap::new();
    for shard in &shards {
        let mut info = vec![
            ("addr", Json::str(shard.addr().to_string())),
            ("up", Json::Bool(shard.is_up())),
            ("restarts", Json::num(shard.restarts() as f64)),
        ];
        match shard
            .forward_raw("{\"op\": \"stats\"}")
            .and_then(|raw| Json::parse(raw.trim()).map_err(|e| anyhow!("bad stats JSON: {e}")))
        {
            Ok(stats) => {
                info.push(("requests", stats.get("requests").clone()));
                info.push(("uptime_secs", stats.get("uptime_secs").clone()));
                if let Some(obj) = stats.get("models").as_obj() {
                    for (model, mstats) in obj {
                        models.insert(model.clone(), mstats.clone());
                    }
                }
            }
            Err(e) => info.push(("error", Json::str(format!("{e:#}")))),
        }
        workers.insert(shard.name.clone(), Json::obj(info));
    }
    ok_obj(vec![
        ("router", Json::Bool(true)),
        (
            "uptime_secs",
            Json::num(ctl.shared.started.elapsed().as_secs_f64()),
        ),
        (
            "requests",
            Json::num(ctl.shared.requests.load(Ordering::SeqCst) as f64),
        ),
        (
            "manifest_version",
            Json::num(*ctl.manifest_version.lock().unwrap() as f64),
        ),
        ("workers", Json::Obj(workers)),
        ("models", Json::Obj(models)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn external_shard_down_worker_yields_retryable_path() {
        // An external shard pointing at a dead port: forward fails with
        // a dial error (the retryable class), and the shard stays `up`
        // (externals have no supervised lifecycle to wait out).
        let port = probe_free_port("127.0.0.1").unwrap();
        let addr: SocketAddr = format!("127.0.0.1:{port}").parse().unwrap();
        let shard = Shard::external("m", addr, &RouterOpts::default());
        let err = shard.forward_raw("{\"op\": \"ping\"}").unwrap_err();
        assert!(format!("{err:#}").contains("dialing worker"), "{err:#}");
        assert!(shard.is_up());
    }

    #[test]
    fn router_rejects_empty_fleet() {
        assert!(Router::with_external_workers(&[], RouterOpts::default()).is_err());
        let addr: SocketAddr = "127.0.0.1:1".parse().unwrap();
        assert!(
            Router::with_external_workers(&[("a", addr), ("a", addr)], RouterOpts::default())
                .is_err()
        );
    }
}

//! Batched factor projection with a cached Gram and a warm-start cache.
//!
//! A [`Projector`] owns a trained `W` and answers `h* = argmin_{h≥0}
//! ‖a − W·h‖` for batches of query columns. Construction does the
//! per-model work once:
//!
//! * columns of `W` are L2-normalized into `Ŵ` (inverse norms kept), so
//!   the cached Gram `Ĝ = ŴᵀŴ` has a **unit diagonal** — which makes the
//!   `UpdateKind::Plain` HALS kernel an *exact* coordinate step
//!   (`h_t ← max(ε, h_t + b_t − Σ_j h_j·Ĝ_jt)` needs no `/Ĝ_tt`);
//! * the tile width is picked from the §5 data-movement model.
//!
//! Serving a batch is then:
//!
//! 1. shard the m queries into micro-batches — nnz-balanced contiguous
//!    ranges ([`crate::coordinator::shard::balanced_row_shards`]) for
//!    sparse batches (bag-of-words queries are Zipf-skewed like the
//!    training data), even row splits for dense;
//! 2. per micro-batch, one panel product `B = Q·Ŵ` (CSR SpMM or blocked
//!    GEMM — the same hot kernels training uses);
//! 3. a few sweeps of `halsops::update_tiled` (Plain kind) on the m̂×K
//!    panel against the cached Ĝ — each sweep is the paper's
//!    three-phase tiled update, thread-parallel over the micro-batch rows;
//! 4. rescale `h = D⁻¹·ĥ` back to original-`W` coordinates.
//!
//! Micro-batches run sequentially because every stage already saturates
//! the pool internally; the batch-size win comes from amortizing kernel
//! dispatch and turning per-query dot products into panel GEMMs (the
//! `serving_throughput` bench measures docs/sec at sizes 1/32/512).
//!
//! ## Warm starts
//!
//! Long-lived deployments (the `plnmf serve` daemon) see repeat and
//! near-repeat queries: the same user profile re-projected after one new
//! click, the same document re-ranked under a different `top_n`. A
//! [`WarmCache`] exploits this: each solved query row is fingerprinted
//! (support + magnitude-quantized values) and its unit-space solution ĥ
//! cached in an LRU. On a hit, the HALS sweeps start from the cached ĥ
//! instead of zero; with a convergence tolerance (`ProjectorOpts::tol`
//! > 0) a repeat query stops after a single verification sweep instead of
//! re-running the whole schedule. Warm starts change only the *starting
//! point* of a convergent fixed-point iteration, so results agree with
//! cold starts to within the sweep tolerance; run with the cache disabled
//! when bit-exact reproducibility matters.

use std::collections::HashMap;
use std::ops::Range;
use std::sync::Arc;

use anyhow::bail;

use crate::coordinator::shard::balanced_row_shards;
use crate::linalg::{gemm, GemmOp, Mat};
use crate::nmf::cost_model;
use crate::nmf::halsops::{update_naive_reg, update_tiled, SharedRows, Shrink, UpdateKind};
use crate::nmf::products;
use crate::nmf::{EngineSpec, Loss};
use crate::parallel::{split_even, ThreadPool};
use crate::sparse::{spmm::spmm_range, Csr};
use crate::util::PhaseTimers;
use crate::{Elem, Result, EPS};

/// A batch of query columns, one query per **row** (m×V — the same
/// orientation as the resident `Aᵀ`, so a dataset's documents can be
/// re-projected directly).
#[derive(Clone, Copy)]
pub enum Queries<'a> {
    Dense(&'a Mat),
    Sparse(&'a Csr),
}

impl<'a> Queries<'a> {
    pub fn rows(&self) -> usize {
        match self {
            Queries::Dense(m) => m.rows(),
            Queries::Sparse(a) => a.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Queries::Dense(m) => m.cols(),
            Queries::Sparse(a) => a.cols(),
        }
    }

    /// Σ_v a_iv of query row `i` (f64 accumulation) — the KL mass.
    fn row_sum(&self, i: usize) -> f64 {
        match self {
            Queries::Dense(m) => m.row(i).iter().map(|&x| x as f64).sum(),
            Queries::Sparse(a) => a.row(i).1.iter().map(|&x| x as f64).sum(),
        }
    }

    /// ‖a_i‖² of query row `i` (f64 accumulation).
    fn row_norm2(&self, i: usize) -> f64 {
        match self {
            Queries::Dense(m) => m.row(i).iter().map(|&x| x as f64 * x as f64).sum(),
            Queries::Sparse(a) => {
                let (_, vals) = a.row(i);
                vals.iter().map(|&x| x as f64 * x as f64).sum()
            }
        }
    }

    /// Whether query row `i` holds no information (all-zero). `Csr`
    /// construction drops explicit zeros, so an empty row is exact.
    fn row_is_zero(&self, i: usize) -> bool {
        match self {
            Queries::Dense(m) => m.row(i).iter().all(|&x| x == 0.0),
            Queries::Sparse(a) => a.row(i).1.is_empty(),
        }
    }

    /// Whether item `v` appears in query row `i` (recommender "seen"
    /// filtering).
    fn seen(&self, i: usize, v: usize) -> bool {
        match self {
            Queries::Dense(m) => m.at(i, v) != 0.0,
            Queries::Sparse(a) => {
                let (cols, _) = a.row(i);
                cols.binary_search(&(v as u32)).is_ok()
            }
        }
    }
}

/// Serving knobs.
#[derive(Debug, Clone, Copy)]
pub struct ProjectorOpts {
    /// HALS sweeps per micro-batch (each sweep is one full tiled pass).
    pub sweeps: usize,
    /// Queries per micro-batch (the throughput/latency trade-off).
    pub micro_batch: usize,
    /// Tile width T; 0 selects via the §5 model.
    pub tile: usize,
    /// Cache size for the tile model (see [`crate::config::RunConfig`]).
    pub cache_bytes: usize,
    /// Early-stop a micro-batch when the max entry change of a sweep
    /// falls below `tol` (0 = always run all `sweeps`, deterministic).
    pub tol: f64,
}

impl Default for ProjectorOpts {
    fn default() -> Self {
        ProjectorOpts {
            sweeps: 30,
            micro_batch: 64,
            tile: 0,
            cache_bytes: 35 * 1024 * 1024,
            tol: 0.0,
        }
    }
}

impl ProjectorOpts {
    /// Reject degenerate configurations up front instead of patching them
    /// with scattered `.max(1)` clamps deep in the solve loop.
    pub fn validate(&self) -> Result<()> {
        if self.sweeps == 0 {
            bail!("ProjectorOpts.sweeps must be >= 1 (0 would run no solve at all)");
        }
        if self.micro_batch == 0 {
            bail!("ProjectorOpts.micro_batch must be >= 1");
        }
        if !(self.tol >= 0.0) {
            bail!("ProjectorOpts.tol must be a non-negative number, got {}", self.tol);
        }
        Ok(())
    }
}

/// Per-call solve statistics: how much work a projection actually did.
///
/// `sweeps` accumulates over micro-batches, so `sweeps / micro_batches`
/// is the average sweeps-to-`tol` — the number warm starts drive down.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProjectStats {
    /// Total HALS sweeps run, summed over micro-batches.
    pub sweeps: usize,
    /// Micro-batches solved.
    pub micro_batches: usize,
    /// Query rows seeded from the warm cache.
    pub warm_hits: usize,
    /// Query rows that missed the warm cache (cold-started).
    pub warm_misses: usize,
}

/// Sufficient statistics for online factor updates — the
/// limited-internal-memory NMF frame (arXiv 1506.08938): the W
/// subproblem `min ‖A − H·Wᵀ‖²` depends on the data only through
/// `S = HᵀH` (K×K) and `P = AᵀH` (V×K), both O(1) in the number of
/// data rows. Folding a batch in is `S += H₁ᵀH₁`, `P += QᵀH₁`; the
/// data itself is dropped. Seeded by [`Projector::fold_seed`], advanced
/// by [`Projector::fold_in`].
#[derive(Debug, Clone)]
pub struct FoldState {
    /// Accumulated mixture Gram `ΣHᵢᵀHᵢ` (K×K).
    s: Mat,
    /// Accumulated data-mixture product `ΣAᵢᵀHᵢ` (V×K).
    p: Mat,
    /// Data rows folded in so far (seed rows included).
    rows: usize,
}

impl FoldState {
    /// Data rows the statistics summarize (seed rows included).
    pub fn rows(&self) -> usize {
        self.rows
    }
}

/// LRU cache of unit-space solutions ĥ keyed by query fingerprint.
///
/// Owned by the caller (the daemon keeps one per model) because the
/// `Projector` itself stays immutable and shareable. Capacity 0 disables
/// caching. Eviction scans for the least-recently-used entry — O(len) per
/// insert, which is fine at the few-thousand-entry capacities the daemon
/// runs; a heap becomes worthwhile only far beyond that.
#[derive(Debug, Default)]
pub struct WarmCache {
    cap: usize,
    tick: u64,
    /// Mixed into every key (see [`WarmCache::set_salt`]): entries
    /// written under one salt can never be found under another.
    salt: u64,
    map: HashMap<u64, WarmEntry>,
}

#[derive(Debug)]
struct WarmEntry {
    ghat: Vec<Elem>,
    last_used: u64,
}

impl WarmCache {
    pub fn new(cap: usize) -> WarmCache {
        WarmCache { cap, tick: 0, salt: 0, map: HashMap::new() }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Set the key salt — the owning model's **factor epoch**. A cached
    /// ĥ is only a valid warm start against the factors it was solved
    /// with; after an in-place factor swap, a stale epoch-N seed leaking
    /// into an epoch-N+1 sweep would start the solve from the wrong
    /// basin. Salting the key (rather than trusting callers to flush)
    /// makes the isolation structural: lookups under the new salt can
    /// never see entries written under the old one.
    pub fn set_salt(&mut self, salt: u64) {
        self.salt = salt;
    }

    /// The query fingerprint mixed with the epoch salt (an FNV-1a-style
    /// odd-prime multiply, a bijection — no extra collisions).
    fn keyed(&self, fp: u64) -> u64 {
        (fp ^ self.salt).wrapping_mul(0x0000_0100_0000_01b3)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn clear(&mut self) {
        self.map.clear();
    }

    fn get(&mut self, key: u64) -> Option<&[Elem]> {
        let key = self.keyed(key);
        self.tick += 1;
        let t = self.tick;
        self.map.get_mut(&key).map(|e| {
            e.last_used = t;
            e.ghat.as_slice()
        })
    }

    fn put(&mut self, key: u64, ghat: Vec<Elem>) {
        if self.cap == 0 {
            return;
        }
        let key = self.keyed(key);
        self.tick += 1;
        let t = self.tick;
        if let Some(e) = self.map.get_mut(&key) {
            e.ghat = ghat;
            e.last_used = t;
            return;
        }
        if self.map.len() >= self.cap {
            // Bind first: an if-let scrutinee's temporaries (here the
            // iterator borrow) live to the end of the statement.
            let victim = self.map.iter().min_by_key(|(_, e)| e.last_used).map(|(&k, _)| k);
            if let Some(victim) = victim {
                self.map.remove(&victim);
            }
        }
        self.map.insert(key, WarmEntry { ghat, last_used: t });
    }
}

/// FNV-1a fingerprint of a query row over (index, quantized value) pairs.
///
/// Values are quantized by dropping the low 12 mantissa bits of the f32
/// pattern (~2⁻¹¹ relative precision), so *near*-repeat queries — the
/// same support with values perturbed below ~0.05% — share a fingerprint
/// and reuse each other's warm start. Zero entries are skipped, so the
/// dense and sparse encodings of the same row fingerprint identically.
fn fingerprint_row(q: Queries<'_>, i: usize) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    #[inline]
    fn mix(h: u64, x: u64) -> u64 {
        (h ^ x).wrapping_mul(PRIME)
    }
    #[inline]
    fn quantize(x: Elem) -> u64 {
        (x.to_bits() >> 12) as u64
    }
    let mut h = OFFSET;
    match q {
        Queries::Sparse(a) => {
            let (cols, vals) = a.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                h = mix(h, c as u64);
                h = mix(h, quantize(v));
            }
        }
        Queries::Dense(m) => {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    h = mix(h, j as u64);
                    h = mix(h, quantize(v));
                }
            }
        }
    }
    h
}

/// Denominator guard for the multiplicative KL projection (matches the
/// training-side `MuKlEngine`).
const KL_DELTA: f64 = 1e-9;

/// A loaded model ready to answer projection queries.
pub struct Projector {
    /// The factor panel (V×K): column-normalized Ŵ in the default
    /// (Frobenius, unregularized) mode; the **raw** `W` when the spec
    /// carries regularization or the KL loss — those solves work in
    /// original coordinates (a uniform penalty on h is non-uniform in
    /// unit space, and the KL update has no normalization trick).
    w_unit: Mat,
    /// Original column norms ‖w_t‖ (0 for dead topics; all 1 in raw
    /// modes, where the panel is already in original coordinates).
    col_norm: Vec<Elem>,
    /// 1/‖w_t‖ (0 for dead topics): maps unit-space solutions back
    /// (identity in raw modes).
    col_scale: Vec<Elem>,
    /// Cached Gram of the stored panel (K×K; unit diagonal only in the
    /// default mode).
    gram: Mat,
    /// Per-topic column sums Σ_v W_vt — the constant denominator of the
    /// multiplicative KL update (empty outside KL mode).
    colsum: Vec<Elem>,
    spec: EngineSpec,
    pool: Arc<ThreadPool>,
    opts: ProjectorOpts,
    tile: usize,
}

impl Projector {
    /// Build from a trained `W` (consumed; `H` is not needed for
    /// serving). Computes the cached Gram once. Fails on a degenerate
    /// `W` (no topics) or invalid [`ProjectorOpts`].
    pub fn new(w: Mat, pool: Arc<ThreadPool>, opts: ProjectorOpts) -> Result<Projector> {
        Projector::with_spec(w, pool, opts, EngineSpec::default())
    }

    /// [`Self::new`] with an [`EngineSpec`] choosing the projection
    /// path: default spec is the historical tiled-HALS pipeline
    /// (bit-for-bit); Frobenius with `alpha > 0` solves the elastic-net
    /// NNLS subproblem against the raw Gram; the KL loss runs
    /// multiplicative updates per micro-batch. The spec's solver/init
    /// fields describe training and are ignored here.
    pub fn with_spec(
        w: Mat,
        pool: Arc<ThreadPool>,
        opts: ProjectorOpts,
        spec: EngineSpec,
    ) -> Result<Projector> {
        opts.validate()?;
        spec.validate()?;
        let (v, k) = (w.rows(), w.cols());
        if k == 0 {
            bail!("Projector needs k >= 1 (got a {v}x0 factor)");
        }
        let mut w_unit = w;

        let unit_mode = spec.loss == Loss::Frobenius && spec.alpha == 0.0;
        let (col_norm, col_scale): (Vec<Elem>, Vec<Elem>);
        if unit_mode {
            // Column norms in f64 (one row-major pass), then scale in
            // place.
            let mut norm2 = vec![0.0f64; k];
            for i in 0..v {
                for (t, &x) in w_unit.row(i).iter().enumerate() {
                    norm2[t] += x as f64 * x as f64;
                }
            }
            col_norm = norm2.iter().map(|&n| n.sqrt() as Elem).collect();
            col_scale =
                col_norm.iter().map(|&n| if n > 1e-12 { 1.0 / n } else { 0.0 }).collect();
            for i in 0..v {
                for (x, &s) in w_unit.row_mut(i).iter_mut().zip(&col_scale) {
                    *x *= s;
                }
            }
        } else {
            // Raw modes keep W as-is; the rescale maps are identities so
            // residuals/recommendations read the panel directly.
            col_norm = vec![1.0; k];
            col_scale = vec![1.0; k];
        }

        let gram = products::factor_gram(&pool, &w_unit);
        let colsum: Vec<Elem> = if spec.loss == Loss::Kl {
            let mut c = vec![0.0f64; k];
            for i in 0..v {
                for (t, &x) in w_unit.row(i).iter().enumerate() {
                    c[t] += x as f64;
                }
            }
            c.iter().map(|&x| x as Elem).collect()
        } else {
            Vec::new()
        };
        let tile = if opts.tile > 0 {
            opts.tile.clamp(1, k)
        } else {
            cost_model::select_tile(k, opts.cache_bytes).clamp(1, k)
        };
        Ok(Projector { w_unit, col_norm, col_scale, gram, colsum, spec, pool, opts, tile })
    }

    /// The engine spec this projector serves under.
    pub fn spec(&self) -> EngineSpec {
        self.spec
    }

    pub fn v(&self) -> usize {
        self.w_unit.rows()
    }

    pub fn k(&self) -> usize {
        self.w_unit.cols()
    }

    pub fn tile(&self) -> usize {
        self.tile
    }

    /// The options this projector was built with.
    pub fn opts(&self) -> ProjectorOpts {
        self.opts
    }

    /// Worker threads of the pool this projector solves on.
    pub fn threads(&self) -> usize {
        self.pool.n_threads()
    }

    /// Name of the SIMD kernel backend this projector's pool dispatches
    /// to (`"scalar"` / `"avx2+fma"`) — surfaced by the `stats` op.
    pub fn kernels_name(&self) -> &'static str {
        self.pool.kernels().name()
    }

    /// The cached Gram (K×K) — exposed for diagnostics/tests.
    pub fn gram(&self) -> &Mat {
        &self.gram
    }

    /// The thread pool this projector solves on — shared with a
    /// successor projector when an online update rebuilds the factors
    /// (one pool per model, across epochs).
    pub fn pool(&self) -> Arc<ThreadPool> {
        Arc::clone(&self.pool)
    }

    /// The factor panel in **original coordinates** (V×K): undoes the
    /// unit-column normalization (`w_t = ŵ_t·‖w_t‖`; dead topics stay
    /// zero). In raw modes the panel is stored unnormalized, so this is
    /// a plain copy.
    pub fn raw_w(&self) -> Mat {
        let (v, k) = (self.v(), self.k());
        let mut w = self.w_unit.clone();
        for i in 0..v {
            let row = w.row_mut(i);
            for t in 0..k {
                row[t] *= self.col_norm[t];
            }
        }
        w
    }

    /// Micro-batch row ranges for an m-row batch: nnz-balanced for
    /// sparse queries, even splits for dense.
    fn shards(&self, q: Queries<'_>) -> Vec<Range<usize>> {
        let m = q.rows();
        let parts = m.div_ceil(self.opts.micro_batch).max(1);
        match q {
            Queries::Sparse(a) => balanced_row_shards(a, parts),
            Queries::Dense(_) => split_even(m, parts),
        }
    }

    /// Project a batch of queries: returns `H*` (m×K, original-`W`
    /// coordinates, entries ≥ 0 with exact zeros where the solve hit the
    /// non-negativity boundary).
    pub fn project(&self, q: Queries<'_>) -> Result<Mat> {
        Ok(self.project_with(q, None, None)?.0)
    }

    /// [`Self::project`] plus per-query relative residuals
    /// `‖a_i − W·h_i‖ / ‖a_i‖`, computed from the micro-batch's live
    /// `B` panel — no second pass over the query matrix (the standalone
    /// [`Self::residuals`] redoes that product).
    pub fn project_with_residuals(&self, q: Queries<'_>) -> Result<(Mat, Vec<f64>)> {
        let mut res = vec![0.0f64; q.rows()];
        let (h, _) = self.project_with(q, Some(&mut res), None)?;
        Ok((h, res))
    }

    /// [`Self::project`] with warm starts: query rows whose fingerprint
    /// hits `cache` start the sweeps from the cached solution. Returns
    /// the solve statistics alongside `H*`.
    pub fn project_warm(
        &self,
        q: Queries<'_>,
        cache: &mut WarmCache,
    ) -> Result<(Mat, ProjectStats)> {
        self.project_with(q, None, Some(cache))
    }

    /// The general projection entry point: optional fused residuals
    /// (slice of length m) and optional warm-start cache.
    pub fn project_with(
        &self,
        q: Queries<'_>,
        mut res: Option<&mut [f64]>,
        mut warm: Option<&mut WarmCache>,
    ) -> Result<(Mat, ProjectStats)> {
        let (m, k) = (q.rows(), self.k());
        if q.cols() != self.v() {
            bail!("queries have {} features, model expects V={}", q.cols(), self.v());
        }
        if let Some(buf) = &res {
            if buf.len() != m {
                bail!("residual buffer has {} slots for {m} queries", buf.len());
            }
        }
        let mut h = Mat::zeros(m, k);
        let mut stats = ProjectStats::default();
        if m == 0 {
            return Ok((h, stats));
        }
        let mut timers = PhaseTimers::new();
        for r in self.shards(q) {
            if !r.is_empty() {
                self.solve_micro_batch(
                    q,
                    r,
                    &mut h,
                    res.as_deref_mut(),
                    warm.as_deref_mut(),
                    &mut stats,
                    &mut timers,
                );
            }
        }
        Ok((h, stats))
    }

    /// One micro-batch: panel product, HALS sweeps, rescale into `h`
    /// (and, when requested, the Gram-expansion residuals while `B` is
    /// still live).
    #[allow(clippy::too_many_arguments)]
    fn solve_micro_batch(
        &self,
        q: Queries<'_>,
        r: Range<usize>,
        h: &mut Mat,
        res: Option<&mut [f64]>,
        mut warm: Option<&mut WarmCache>,
        stats: &mut ProjectStats,
        timers: &mut PhaseTimers,
    ) {
        if self.spec.loss == Loss::Kl {
            return self.solve_micro_batch_kl(q, r, h, res, warm, stats, timers);
        }
        let (mb, k) = (r.len(), self.k());

        // Degenerate rows: an all-zero query has the unique solution
        // h = 0. The ε-floored kernel would instead park every coordinate
        // at ε, so zero rows are masked out of the solve (and of the warm
        // cache) and written back as exact zeros.
        let zero_row: Vec<bool> = r.clone().map(|i| q.row_is_zero(i)).collect();
        if zero_row.iter().all(|&z| z) {
            if let Some(res) = res {
                for i in r {
                    res[i] = 0.0;
                }
            }
            return;
        }

        let mut b = Mat::zeros(mb, k);
        match q {
            Queries::Sparse(a) => timers.time("serve_product", || {
                spmm_range(&self.pool, 1.0, a, r.clone(), &self.w_unit, &mut b.view_mut())
            }),
            Queries::Dense(qm) => timers.time("serve_product", || {
                gemm(
                    &self.pool,
                    1.0,
                    qm.block_view(r.start, r.end, 0, qm.cols()),
                    self.w_unit.view(),
                    GemmOp::Assign,
                    &mut b.view_mut(),
                )
            }),
        }

        let mut g = Mat::zeros(mb, k);

        // Warm-start seeding: fingerprint each live row; hits copy the
        // cached unit-space solution into the panel before the sweeps.
        let mut fps: Vec<u64> = Vec::new();
        if let Some(cache) = warm.as_deref_mut() {
            fps = r.clone().map(|i| fingerprint_row(q, i)).collect();
            for (local, &zero) in zero_row.iter().enumerate() {
                if zero {
                    continue;
                }
                match cache.get(fps[local]) {
                    Some(ghat) if ghat.len() == k => {
                        g.row_mut(local).copy_from_slice(ghat);
                        stats.warm_hits += 1;
                    }
                    _ => stats.warm_misses += 1,
                }
            }
        }

        let shrink = self.spec.shrink();
        let mut scratch = Mat::zeros(mb, k);
        let mut sweeps_run = 0;
        for _ in 0..self.opts.sweeps {
            if shrink.is_none() {
                update_tiled(
                    &self.pool,
                    &mut g,
                    &mut scratch,
                    &self.gram,
                    &b,
                    self.tile,
                    UpdateKind::Plain,
                    timers,
                    ["serve_phase1", "serve_phase2", "serve_phase3"],
                );
            } else {
                // Elastic-net projection: raw coordinates, so the exact
                // coordinate step divides by the true Gram diagonal —
                // the `WithDiag` serving kind (naive kernel only).
                scratch.copy_from(&g);
                timers.time("serve_reg_sweep", || {
                    update_naive_reg(
                        &self.pool,
                        &mut g,
                        &self.gram,
                        &b,
                        UpdateKind::WithDiag,
                        shrink,
                        timers,
                        "serve_reg_dmv",
                    )
                });
            }
            sweeps_run += 1;
            // `scratch` holds the pre-sweep values — a free convergence
            // probe for the optional early stop.
            if self.opts.tol > 0.0 && g.max_abs_diff(&scratch) < self.opts.tol {
                break;
            }
        }
        stats.sweeps += sweeps_run;
        stats.micro_batches += 1;

        if let Some(cache) = warm {
            for (local, &zero) in zero_row.iter().enumerate() {
                if !zero {
                    cache.put(fps[local], g.row(local).to_vec());
                }
            }
        }

        // ĥ → h = D⁻¹ĥ; entries clamped to ε by the kernel are snapped
        // to exact 0 (they are the active non-negativity constraints).
        for (local, i) in r.clone().enumerate() {
            if zero_row[local] {
                continue; // h row stays exactly zero
            }
            let grow = g.row(local);
            let hrow = h.row_mut(i);
            for t in 0..k {
                let gv = grow[t];
                hrow[t] = if gv <= EPS { 0.0 } else { gv * self.col_scale[t] };
            }
        }

        // Residuals from the live panel: ‖a − Ŵĝ‖² = ‖a‖² − 2ĝᵀb + ĝᵀĜĝ.
        if let Some(res) = res {
            for (local, i) in r.enumerate() {
                if zero_row[local] {
                    res[i] = 0.0;
                    continue;
                }
                let ghat = g.row(local);
                let a2 = q.row_norm2(i);
                let mut cross = 0.0f64;
                let mut quad = 0.0f64;
                for t in 0..k {
                    let gt = ghat[t] as f64;
                    cross += gt * b.at(local, t) as f64;
                    let gram_row = self.gram.row(t);
                    let mut s = 0.0f64;
                    for j in 0..k {
                        s += gram_row[j] as f64 * ghat[j] as f64;
                    }
                    quad += gt * s;
                }
                let r2 = (a2 - 2.0 * cross + quad).max(0.0);
                res[i] = if a2 > 0.0 { (r2 / a2).sqrt() } else { 0.0 };
            }
        }
    }

    /// One micro-batch under the KL loss: multiplicative updates
    /// `h_j ← h_j · (Σ_v W_vj·a_v/(W·h)_v) / (Σ_v W_vj + δ + l1 + l2·h_j)`
    /// — the serving analogue of the training-side `MuKlEngine` H step.
    /// The cached Gram never enters the solve (each sweep is O(nnz(a)·K)
    /// over the query support); it is still used for the optional
    /// Euclidean residuals, whose Gram expansion holds unchanged because
    /// the panel is the raw `W` (identity `col_norm`/`col_scale`).
    #[allow(clippy::too_many_arguments)]
    fn solve_micro_batch_kl(
        &self,
        q: Queries<'_>,
        r: Range<usize>,
        h: &mut Mat,
        res: Option<&mut [f64]>,
        mut warm: Option<&mut WarmCache>,
        stats: &mut ProjectStats,
        timers: &mut PhaseTimers,
    ) {
        let (mb, k) = (r.len(), self.k());
        let zero_row: Vec<bool> = r.clone().map(|i| q.row_is_zero(i)).collect();
        if zero_row.iter().all(|&z| z) {
            if let Some(res) = res {
                for i in r {
                    res[i] = 0.0;
                }
            }
            return;
        }

        // Cold rows start mass-matched: h₀ = Σ_v a_v / Σ_t colsum_t makes
        // Σ(W·h₀) = Σa, so the first multiplicative ratio is O(1) instead
        // of blowing up against an arbitrary scale. Warm seeds (and h₀
        // itself) are floored at ε — a multiplicative update can never
        // leave an exact zero.
        let total_colsum: f64 =
            self.colsum.iter().map(|&c| c as f64).sum::<f64>().max(KL_DELTA);
        let mut g = Mat::zeros(mb, k);
        let mut fps: Vec<u64> = Vec::new();
        if warm.is_some() {
            fps = r.clone().map(|i| fingerprint_row(q, i)).collect();
        }
        for (local, i) in r.clone().enumerate() {
            if zero_row[local] {
                continue;
            }
            let mut seeded = false;
            if let Some(cache) = warm.as_deref_mut() {
                match cache.get(fps[local]) {
                    Some(ghat) if ghat.len() == k => {
                        for (dst, &src) in g.row_mut(local).iter_mut().zip(ghat) {
                            *dst = src.max(EPS);
                        }
                        stats.warm_hits += 1;
                        seeded = true;
                    }
                    _ => stats.warm_misses += 1,
                }
            }
            if !seeded {
                let h0 = ((q.row_sum(i) / total_colsum) as Elem).max(EPS);
                for x in g.row_mut(local).iter_mut() {
                    *x = h0;
                }
            }
        }

        let shrink = self.spec.shrink();
        let mut scratch = Mat::zeros(mb, k);
        let mut sweeps_run = 0;
        for _ in 0..self.opts.sweeps {
            scratch.copy_from(&g);
            timers.time("serve_kl_sweep", || {
                self.kl_sweep(q, r.clone(), &zero_row, &mut g, shrink)
            });
            sweeps_run += 1;
            if self.opts.tol > 0.0 && g.max_abs_diff(&scratch) < self.opts.tol {
                break;
            }
        }
        stats.sweeps += sweeps_run;
        stats.micro_batches += 1;

        if let Some(cache) = warm {
            for (local, &zero) in zero_row.iter().enumerate() {
                if !zero {
                    cache.put(fps[local], g.row(local).to_vec());
                }
            }
        }

        // Already in raw coordinates; entries parked at the ε floor are
        // active non-negativity constraints and snap to exact 0.
        for (local, i) in r.clone().enumerate() {
            if zero_row[local] {
                continue;
            }
            let grow = g.row(local);
            let hrow = h.row_mut(i);
            for t in 0..k {
                let gv = grow[t];
                hrow[t] = if gv <= EPS { 0.0 } else { gv };
            }
        }

        // Residuals keep the wire's stable meaning — *Euclidean* relative
        // error — regardless of the training loss. The B panel is lazy:
        // the KL solve itself never needs it.
        if let Some(res) = res {
            let mut b = Mat::zeros(mb, k);
            match q {
                Queries::Sparse(a) => timers.time("serve_product", || {
                    spmm_range(&self.pool, 1.0, a, r.clone(), &self.w_unit, &mut b.view_mut())
                }),
                Queries::Dense(qm) => timers.time("serve_product", || {
                    gemm(
                        &self.pool,
                        1.0,
                        qm.block_view(r.start, r.end, 0, qm.cols()),
                        self.w_unit.view(),
                        GemmOp::Assign,
                        &mut b.view_mut(),
                    )
                }),
            }
            for (local, i) in r.enumerate() {
                if zero_row[local] {
                    res[i] = 0.0;
                    continue;
                }
                let ghat = g.row(local);
                let a2 = q.row_norm2(i);
                let mut cross = 0.0f64;
                let mut quad = 0.0f64;
                for t in 0..k {
                    let gt = ghat[t] as f64;
                    cross += gt * b.at(local, t) as f64;
                    let gram_row = self.gram.row(t);
                    let mut s = 0.0f64;
                    for j in 0..k {
                        s += gram_row[j] as f64 * ghat[j] as f64;
                    }
                    quad += gt * s;
                }
                let r2 = (a2 - 2.0 * cross + quad).max(0.0);
                res[i] = if a2 > 0.0 { (r2 / a2).sqrt() } else { 0.0 };
            }
        }
    }

    /// One multiplicative KL sweep over a micro-batch, thread-parallel
    /// across rows. The numerator `Σ_v W_vj·a_v/(W·h)_v` runs over the
    /// query's support only (terms with `a_v = 0` vanish); the
    /// denominator reuses the precomputed column sums plus the guard and
    /// the elastic-net terms (sklearn's MU regularization placement).
    fn kl_sweep(
        &self,
        q: Queries<'_>,
        r: Range<usize>,
        zero_row: &[bool],
        g: &mut Mat,
        shrink: Shrink,
    ) {
        let k = self.k();
        let (l1, l2) = (shrink.l1 as f64, shrink.l2 as f64);

        /// Fold one support element `a_v` into the numerator accumulator.
        #[inline]
        fn accum(w: &Mat, v: usize, a: f64, hrow: &[Elem], num: &mut [f64]) {
            let wrow = w.row(v);
            let mut wh = 0.0f64;
            for (&wt, &ht) in wrow.iter().zip(hrow) {
                wh += wt as f64 * ht as f64;
            }
            let ratio = a / (wh + KL_DELTA);
            for (nt, &wt) in num.iter_mut().zip(wrow) {
                *nt += wt as f64 * ratio;
            }
        }

        let shared = SharedRows::new(g);
        self.pool.parallel_for(r.len(), None, |rows| {
            let mut num = vec![0.0f64; k];
            for local in rows {
                if zero_row[local] {
                    continue;
                }
                let i = r.start + local;
                // SAFETY: `local` row indices are disjoint across chunks.
                let hrow = unsafe { shared.row_mut(local) };
                num.iter_mut().for_each(|x| *x = 0.0);
                match q {
                    Queries::Sparse(a) => {
                        let (cols, vals) = a.row(i);
                        for (&c, &av) in cols.iter().zip(vals) {
                            accum(&self.w_unit, c as usize, av as f64, hrow, &mut num);
                        }
                    }
                    Queries::Dense(m) => {
                        for (v, &av) in m.row(i).iter().enumerate() {
                            if av != 0.0 {
                                accum(&self.w_unit, v, av as f64, hrow, &mut num);
                            }
                        }
                    }
                }
                for t in 0..k {
                    let ht = hrow[t] as f64;
                    let denom = self.colsum[t] as f64 + KL_DELTA + l1 + l2 * ht;
                    hrow[t] = ((ht * num[t] / denom) as Elem).max(EPS);
                }
            }
        });
    }

    /// Relative residuals `‖a_i − W·h_i‖ / ‖a_i‖` for a projected batch,
    /// computed in O(mK²) via the Gram expansion
    /// `‖a − Ŵĝ‖² = ‖a‖² − 2·ĝᵀb + ĝᵀĜĝ` (never materializes W·h).
    pub fn residuals(&self, q: Queries<'_>, h: &Mat) -> Result<Vec<f64>> {
        let (m, k) = (q.rows(), self.k());
        if h.rows() != m || h.cols() != k {
            bail!("h is {}x{}, expected {m}x{k}", h.rows(), h.cols());
        }
        if q.cols() != self.v() {
            bail!("queries have {} features, model expects V={}", q.cols(), self.v());
        }
        let mut b = Mat::zeros(m, k);
        match q {
            Queries::Sparse(a) => {
                spmm_range(&self.pool, 1.0, a, 0..m, &self.w_unit, &mut b.view_mut())
            }
            Queries::Dense(qm) => gemm(
                &self.pool,
                1.0,
                qm.view(),
                self.w_unit.view(),
                GemmOp::Assign,
                &mut b.view_mut(),
            ),
        }
        let mut out = Vec::with_capacity(m);
        let mut ghat = vec![0.0f64; k];
        for i in 0..m {
            let hrow = h.row(i);
            for t in 0..k {
                ghat[t] = hrow[t] as f64 * self.col_norm[t] as f64;
            }
            let a2 = q.row_norm2(i);
            let mut cross = 0.0f64;
            let mut quad = 0.0f64;
            for t in 0..k {
                cross += ghat[t] * b.at(i, t) as f64;
                let grow = self.gram.row(t);
                let mut s = 0.0f64;
                for j in 0..k {
                    s += grow[j] as f64 * ghat[j];
                }
                quad += ghat[t] * s;
            }
            let r2 = (a2 - 2.0 * cross + quad).max(0.0);
            out.push(if a2 > 0.0 { (r2 / a2).sqrt() } else { 0.0 });
        }
        Ok(out)
    }

    /// Seed the online-update sufficient statistics from a trained
    /// model's own mixtures `H` (D×K): `S = HᵀH` exactly, and
    /// `P = A₀ᵀH ≈ W·S` — exact when the training residual is zero,
    /// since `A₀ ≈ H·Wᵀ ⇒ A₀ᵀH ≈ W·(HᵀH)`. The training data itself is
    /// never needed again (the limited-internal-memory frame).
    pub fn fold_seed(&self, h: &Mat) -> Result<FoldState> {
        let k = self.k();
        if h.cols() != k {
            bail!("fold seed H has {} columns, model expects K={k}", h.cols());
        }
        self.fold_resume(products::factor_gram(&self.pool, h), h.rows())
    }

    /// [`Projector::fold_seed`] from a pre-computed mixture Gram
    /// `S = HᵀH` (K×K) and its row count — what the registry retains per
    /// model (K² floats) so the full V×K `P` panel is only materialized
    /// when a model actually receives its first update.
    pub fn fold_resume(&self, s: Mat, rows: usize) -> Result<FoldState> {
        let (v, k) = (self.v(), self.k());
        if s.rows() != k || s.cols() != k {
            bail!("fold seed S is {}x{}, model expects K={k}", s.rows(), s.cols());
        }
        let w = self.raw_w();
        let mut p = Mat::zeros(v, k);
        gemm(&self.pool, 1.0, w.view(), s.view(), GemmOp::Assign, &mut p.view_mut());
        Ok(FoldState { s, p, rows })
    }

    /// Fold a batch of new data rows into the factors: project the rows
    /// via warm-started NNLS (the serving hot path, unchanged), add
    /// their **exact** sufficient statistics to `fold`, then refine `W`
    /// with `w_sweeps` Gauss–Seidel HALS column updates against the
    /// accumulated `(S, P)` — the FAST-HALS W half-sweep over *all* data
    /// seen so far, without that data being resident. Returns the
    /// updated raw `W` (build the successor [`Projector`] from it) and
    /// the projection statistics.
    ///
    /// Spec-gated like `train-dist`: Frobenius-HALS, unregularized only
    /// — the KL and elastic-net W subproblems need different kernels.
    pub fn fold_in(
        &self,
        q: Queries<'_>,
        fold: &mut FoldState,
        warm: Option<&mut WarmCache>,
        w_sweeps: usize,
    ) -> Result<(Mat, ProjectStats)> {
        if self.spec.loss != Loss::Frobenius || self.spec.alpha != 0.0 {
            bail!(
                "online update is spec-gated (like train-dist): Frobenius-HALS \
                 unregularized only, got loss '{}' with alpha {}",
                self.spec.loss.name(),
                self.spec.alpha
            );
        }
        if w_sweeps == 0 {
            bail!("update needs w_sweeps >= 1 (0 would leave W untouched)");
        }
        let (v, k, m) = (self.v(), self.k(), q.rows());
        if m == 0 {
            bail!("update needs at least one data row");
        }
        if fold.s.rows() != k || fold.s.cols() != k || fold.p.rows() != v || fold.p.cols() != k
        {
            bail!(
                "fold state is S {}x{} / P {}x{}, model expects S {k}x{k} / P {v}x{k}",
                fold.s.rows(),
                fold.s.cols(),
                fold.p.rows(),
                fold.p.cols()
            );
        }
        // 1. Mixtures for the new rows — the existing projection path,
        //    warm starts included (shape errors surface here too).
        let (h1, stats) = self.project_with(q, None, warm)?;

        // 2. Exact statistics of the new batch: S += H₁ᵀH₁, P += QᵀH₁.
        //    The accumulates dispatch through the exact-class `axpy`
        //    (scaling by 1.0 is exact), so the statistics are identical
        //    on every kernel backend.
        let kern = self.pool.kernels();
        let s1 = products::factor_gram(&self.pool, &h1);
        for t in 0..k {
            (kern.axpy)(1.0, s1.row(t), fold.s.row_mut(t));
        }
        match q {
            Queries::Sparse(a) => {
                for i in 0..m {
                    let (cols, vals) = a.row(i);
                    let hrow = h1.row(i);
                    for (&c, &av) in cols.iter().zip(vals) {
                        (kern.axpy)(av, hrow, fold.p.row_mut(c as usize));
                    }
                }
            }
            Queries::Dense(qm) => {
                for i in 0..m {
                    let hrow = h1.row(i);
                    for (vi, &av) in qm.row(i).iter().enumerate() {
                        if av != 0.0 {
                            (kern.axpy)(av, hrow, fold.p.row_mut(vi));
                        }
                    }
                }
            }
        }
        fold.rows += m;

        // 3. W half-sweeps: Gauss–Seidel per column against the cached
        //    product WS (rank-1-refreshed after each column update), the
        //    exact coordinate step `w_t ← max(0, w_t + (P_t − (WS)_t)/S_tt)`.
        let mut w = self.raw_w();
        let mut ws = Mat::zeros(v, k);
        gemm(&self.pool, 1.0, w.view(), fold.s.view(), GemmOp::Assign, &mut ws.view_mut());
        for _ in 0..w_sweeps {
            for t in 0..k {
                let stt = fold.s.at(t, t);
                if stt <= 1e-12 {
                    continue; // dead topic: no data mass to update against
                }
                let srow: Vec<Elem> = fold.s.row(t).to_vec();
                for vi in 0..v {
                    let cur = w.at(vi, t);
                    let new =
                        (cur + (fold.p.at(vi, t) - ws.at(vi, t)) / stt).max(0.0);
                    let d = new - cur;
                    if d != 0.0 {
                        *w.at_mut(vi, t) = new;
                        (kern.axpy)(d, &srow, ws.row_mut(vi));
                    }
                }
            }
        }
        Ok((w, stats))
    }

    /// Project a batch and return, per query, the top-N items by
    /// reconstruction score `(W·h*)_v`, descending. With `exclude_seen`,
    /// items already present in the query (non-zero entries) are skipped
    /// — the standard recommender protocol.
    pub fn recommend(
        &self,
        q: Queries<'_>,
        top_n: usize,
        exclude_seen: bool,
    ) -> Result<Vec<Vec<(u32, Elem)>>> {
        let h = self.project(q)?;
        self.recommend_for(q, &h, top_n, exclude_seen)
    }

    /// Rank items for already-projected mixtures (`h` in original-`W`
    /// coordinates, as returned by [`Self::project`]).
    pub fn recommend_for(
        &self,
        q: Queries<'_>,
        h: &Mat,
        top_n: usize,
        exclude_seen: bool,
    ) -> Result<Vec<Vec<(u32, Elem)>>> {
        let (m, k, v) = (h.rows(), self.k(), self.v());
        if q.rows() != m {
            bail!("queries ({}) and h ({m}) row counts differ", q.rows());
        }
        if q.cols() != v {
            bail!("queries have {} features, model expects V={v}", q.cols());
        }
        if h.cols() != k {
            bail!("h has {} columns, model expects K={k}", h.cols());
        }
        let top_n = top_n.min(v).max(1);
        let mb = self.opts.micro_batch;
        let mut out = Vec::with_capacity(m);
        let mut scores_buf = Vec::with_capacity(v);
        let mut r0 = 0;
        while r0 < m {
            let r1 = (r0 + mb).min(m);
            let width = r1 - r0;
            // ĝᵀ panel (K×m̂): scores = Ŵ·ĝ = W·h, one blocked GEMM.
            let mut gt = Mat::zeros(k, width);
            for j in 0..width {
                let hrow = h.row(r0 + j);
                for t in 0..k {
                    *gt.at_mut(t, j) = hrow[t] * self.col_norm[t];
                }
            }
            let mut scores = Mat::zeros(v, width);
            gemm(&self.pool, 1.0, self.w_unit.view(), gt.view(), GemmOp::Assign, &mut scores.view_mut());
            for j in 0..width {
                let i = r0 + j;
                scores_buf.clear();
                for item in 0..v {
                    if exclude_seen && q.seen(i, item) {
                        continue;
                    }
                    scores_buf.push((item as u32, scores.at(item, j)));
                }
                out.push(top_n_desc(&mut scores_buf, top_n));
            }
            r0 = r1;
        }
        Ok(out)
    }
}

/// Partial selection: the `n` largest-score entries, sorted descending.
fn top_n_desc(scores: &mut Vec<(u32, Elem)>, n: usize) -> Vec<(u32, Elem)> {
    let n = n.min(scores.len());
    if n == 0 {
        return Vec::new();
    }
    let desc = |a: &(u32, Elem), b: &(u32, Elem)| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0));
    if n < scores.len() {
        scores.select_nth_unstable_by(n - 1, desc);
        scores.truncate(n);
    }
    scores.sort_unstable_by(desc);
    scores.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gram::gram_naive;
    use crate::nmf::nnls::nnls_bpp_rows;
    use crate::util::rng::Pcg32;

    fn pool(n: usize) -> Arc<ThreadPool> {
        Arc::new(ThreadPool::new(n))
    }

    /// Dense residual by direct evaluation (reference for the Gram form).
    fn residual_direct(q: &Mat, w: &Mat, h: &Mat, i: usize) -> f64 {
        let mut r2 = 0.0f64;
        for vrow in 0..w.rows() {
            let mut wh = 0.0f64;
            for t in 0..w.cols() {
                wh += w.at(vrow, t) as f64 * h.at(i, t) as f64;
            }
            let d = q.at(i, vrow) as f64 - wh;
            r2 += d * d;
        }
        r2.sqrt()
    }

    fn random_problem(v: usize, k: usize, m: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Pcg32::seeded(seed);
        // Unnormalized W — exercises the unit-column rescaling path.
        let w = Mat::random(v, k, &mut rng, 0.0, 2.0);
        let q = Mat::random(m, v, &mut rng, 0.0, 1.0);
        (w, q)
    }

    #[test]
    fn gram_has_unit_diagonal() {
        let (w, _) = random_problem(40, 7, 1, 1);
        let p = Projector::new(w, pool(2), ProjectorOpts::default()).unwrap();
        for t in 0..7 {
            assert!((p.gram().at(t, t) - 1.0).abs() < 1e-5, "G[{t},{t}]");
        }
    }

    #[test]
    fn degenerate_opts_and_factors_are_rejected() {
        let (w, _) = random_problem(10, 3, 1, 1);
        for opts in [
            ProjectorOpts { sweeps: 0, ..Default::default() },
            ProjectorOpts { micro_batch: 0, ..Default::default() },
            ProjectorOpts { tol: f64::NAN, ..Default::default() },
            ProjectorOpts { tol: -1.0, ..Default::default() },
        ] {
            assert!(opts.validate().is_err(), "{opts:?} must not validate");
            assert!(Projector::new(w.clone(), pool(1), opts).is_err());
        }
        let empty = Mat::zeros(10, 0);
        assert!(Projector::new(empty, pool(1), ProjectorOpts::default()).is_err());
    }

    #[test]
    fn projection_matches_bpp_nnls() {
        // The acceptance bar: a from-scratch NNLS solve of the same
        // columns (BPP finds the exact KKT point) within 1e-3 rel error.
        let (w, q) = random_problem(40, 6, 23, 5);
        let p = Projector::new(
            w.clone(),
            pool(3),
            ProjectorOpts { sweeps: 300, micro_batch: 7, ..Default::default() },
        )
        .unwrap();
        let h = p.project(Queries::Dense(&q)).unwrap();

        // Reference: G = WᵀW, B = Q·W, exact per-row NNLS.
        let g = gram_naive(&w);
        let mut b = Mat::zeros(23, 6);
        gemm(&pool(1), 1.0, q.view(), w.view(), GemmOp::Assign, &mut b.view_mut());
        let mut h_ref = Mat::zeros(23, 6);
        nnls_bpp_rows(&ThreadPool::new(1), &g, &b, &mut h_ref);

        for i in 0..23 {
            let r_hals = residual_direct(&q, &w, &h, i);
            let r_bpp = residual_direct(&q, &w, &h_ref, i);
            assert!(
                r_hals <= r_bpp * 1.001 + 1e-5,
                "query {i}: hals residual {r_hals} vs bpp {r_bpp}"
            );
        }
    }

    #[test]
    fn residuals_match_direct_evaluation() {
        let (w, q) = random_problem(30, 5, 11, 9);
        let p = Projector::new(w.clone(), pool(2), ProjectorOpts::default()).unwrap();
        let h = p.project(Queries::Dense(&q)).unwrap();
        let rel = p.residuals(Queries::Dense(&q), &h).unwrap();
        for i in 0..11 {
            let direct = residual_direct(&q, &w, &h, i) / q.row(i).iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            assert!((rel[i] - direct).abs() < 1e-4, "query {i}: {} vs {}", rel[i], direct);
        }
    }

    #[test]
    fn fused_residuals_match_standalone() {
        let (w, q) = random_problem(28, 5, 13, 17);
        let p = Projector::new(
            w,
            pool(2),
            ProjectorOpts { sweeps: 30, micro_batch: 6, ..Default::default() },
        )
        .unwrap();
        let (h, fused) = p.project_with_residuals(Queries::Dense(&q)).unwrap();
        let standalone = p.residuals(Queries::Dense(&q), &h).unwrap();
        for (i, (a, b)) in fused.iter().zip(&standalone).enumerate() {
            assert!((a - b).abs() < 1e-4, "query {i}: fused {a} vs standalone {b}");
        }
    }

    #[test]
    fn micro_batch_size_does_not_change_results() {
        // The Plain update is row-local, so batching is exact.
        let (w, q) = random_problem(35, 6, 40, 11);
        let mut outs = Vec::new();
        for mb in [1usize, 8, 64] {
            let p = Projector::new(
                w.clone(),
                pool(2),
                ProjectorOpts { sweeps: 20, micro_batch: mb, ..Default::default() },
            )
            .unwrap();
            outs.push(p.project(Queries::Dense(&q)).unwrap());
        }
        assert!(outs[0].max_abs_diff(&outs[1]) < 1e-6);
        assert!(outs[0].max_abs_diff(&outs[2]) < 1e-6);
    }

    #[test]
    fn sparse_and_dense_queries_agree() {
        let (w, qd) = random_problem(30, 5, 19, 13);
        // Sparsify: zero out ~70% of entries, then compare both paths.
        let mut rng = Pcg32::seeded(99);
        let mut qs = qd.clone();
        for i in 0..qs.rows() {
            for x in qs.row_mut(i).iter_mut() {
                if rng.below(10) < 7 {
                    *x = 0.0;
                }
            }
        }
        let csr = Csr::from_dense(&qs);
        let p = Projector::new(
            w,
            pool(3),
            ProjectorOpts { sweeps: 40, micro_batch: 5, ..Default::default() },
        )
        .unwrap();
        let h_dense = p.project(Queries::Dense(&qs)).unwrap();
        let h_sparse = p.project(Queries::Sparse(&csr)).unwrap();
        assert!(h_dense.max_abs_diff(&h_sparse) < 1e-4);
    }

    #[test]
    fn dead_topic_columns_yield_zero_weights() {
        let mut rng = Pcg32::seeded(21);
        let mut w = Mat::random(20, 4, &mut rng, 0.0, 1.0);
        for i in 0..20 {
            *w.at_mut(i, 2) = 0.0; // dead topic
        }
        let q = Mat::random(6, 20, &mut rng, 0.0, 1.0);
        let p = Projector::new(w, pool(1), ProjectorOpts::default()).unwrap();
        let h = p.project(Queries::Dense(&q)).unwrap();
        for i in 0..6 {
            assert_eq!(h.at(i, 2), 0.0, "dead topic must get zero weight");
        }
    }

    #[test]
    fn all_zero_query_rows_return_exact_zero() {
        // Regression: zero rows must not pick up ε-floor garbage scaled
        // by D⁻¹ — the unique solution of min ‖0 − W·h‖, h ≥ 0 is h = 0.
        let (w, mut q) = random_problem(25, 4, 7, 3);
        q.row_mut(2).fill(0.0);
        q.row_mut(5).fill(0.0);
        let p = Projector::new(
            w,
            pool(2),
            ProjectorOpts { sweeps: 10, micro_batch: 3, ..Default::default() },
        )
        .unwrap();
        let (h, res) = p.project_with_residuals(Queries::Dense(&q)).unwrap();
        for i in [2usize, 5] {
            assert!(h.row(i).iter().all(|&x| x == 0.0), "row {i}: {:?}", h.row(i));
            assert_eq!(res[i], 0.0);
        }
        // Non-zero rows still solve normally.
        assert!(h.row(0).iter().any(|&x| x > 0.0));

        // Sparse path: an entirely-zero batch short-circuits.
        let zeros = Mat::zeros(4, 25);
        let csr = Csr::from_dense(&zeros);
        let (hz, stats) = p.project_with(Queries::Sparse(&csr), None, None).unwrap();
        assert!(hz.data().iter().all(|&x| x == 0.0));
        assert_eq!(stats.sweeps, 0, "all-zero batch must not run sweeps");
    }

    #[test]
    fn early_stop_matches_full_sweeps() {
        let (w, q) = random_problem(25, 5, 9, 31);
        let full = Projector::new(
            w.clone(),
            pool(2),
            ProjectorOpts { sweeps: 200, ..Default::default() },
        )
        .unwrap();
        let early = Projector::new(
            w,
            pool(2),
            ProjectorOpts { sweeps: 200, tol: 1e-7, ..Default::default() },
        )
        .unwrap();
        let hf = full.project(Queries::Dense(&q)).unwrap();
        let he = early.project(Queries::Dense(&q)).unwrap();
        assert!(hf.max_abs_diff(&he) < 1e-3);
    }

    #[test]
    fn warm_start_reduces_sweeps_and_stays_within_tol() {
        let (w, q) = random_problem(30, 6, 12, 47);
        let tol = 1e-6;
        let p = Projector::new(
            w,
            pool(2),
            ProjectorOpts { sweeps: 200, micro_batch: 4, tol, ..Default::default() },
        )
        .unwrap();
        let mut cache = WarmCache::new(64);

        let (h_cold, cold) = p.project_warm(Queries::Dense(&q), &mut cache).unwrap();
        assert_eq!(cold.warm_hits, 0);
        assert_eq!(cold.warm_misses, 12);
        assert!(cold.sweeps >= cold.micro_batches, "at least one sweep per batch");

        // Exact repeat: every row hits, the seeded batches stop no later
        // than the cold ones, and the result stays within the sweep tol.
        let (h_warm, warm) = p.project_warm(Queries::Dense(&q), &mut cache).unwrap();
        assert_eq!(warm.warm_hits, 12);
        assert_eq!(warm.warm_misses, 0);
        assert!(
            warm.sweeps <= cold.sweeps,
            "warm start ran more sweeps ({}) than cold ({})",
            warm.sweeps,
            cold.sweeps
        );
        assert!(h_cold.max_abs_diff(&h_warm) < 1e-3);
    }

    #[test]
    fn warm_cache_disabled_matches_cold_exactly() {
        // capacity 0: nothing is cached, results are bit-identical to
        // the plain path.
        let (w, q) = random_problem(20, 4, 6, 53);
        let p = Projector::new(w, pool(2), ProjectorOpts::default()).unwrap();
        let mut cache = WarmCache::new(0);
        let (h_warm, stats) = p.project_warm(Queries::Dense(&q), &mut cache).unwrap();
        let h_plain = p.project(Queries::Dense(&q)).unwrap();
        assert_eq!(h_warm, h_plain);
        assert!(cache.is_empty());
        assert_eq!(stats.warm_hits, 0);
    }

    #[test]
    fn warm_cache_lru_evicts_oldest() {
        let mut cache = WarmCache::new(2);
        cache.put(1, vec![1.0]);
        cache.put(2, vec![2.0]);
        assert!(cache.get(1).is_some()); // touch 1 → 2 is now LRU
        cache.put(3, vec![3.0]);
        assert_eq!(cache.len(), 2);
        assert!(cache.get(2).is_none(), "LRU entry must be evicted");
        assert!(cache.get(1).is_some());
        assert!(cache.get(3).is_some());
    }

    #[test]
    fn fingerprints_agree_across_encodings_and_tolerate_jitter() {
        let (_, mut q) = random_problem(20, 3, 4, 61);
        for i in 0..q.rows() {
            for x in q.row_mut(i).iter_mut() {
                if *x < 0.5 {
                    *x = 0.0;
                }
            }
        }
        let csr = Csr::from_dense(&q);
        for i in 0..q.rows() {
            assert_eq!(
                fingerprint_row(Queries::Dense(&q), i),
                fingerprint_row(Queries::Sparse(&csr), i),
                "row {i}: dense and sparse fingerprints differ"
            );
        }
        // Near-repeat: a sub-quantum perturbation (low mantissa bit, far
        // below the >>12 quantization) keeps the fingerprint; a large
        // one changes it.
        let fp0 = fingerprint_row(Queries::Dense(&q), 0);
        let mut jittered = q.clone();
        for x in jittered.row_mut(0).iter_mut() {
            if *x > 0.0 {
                *x = f32::from_bits(x.to_bits() ^ 1);
            }
        }
        assert_eq!(fp0, fingerprint_row(Queries::Dense(&jittered), 0));
        let mut moved = q.clone();
        for x in moved.row_mut(0).iter_mut() {
            if *x > 0.0 {
                *x *= 2.0;
            }
        }
        assert_ne!(fp0, fingerprint_row(Queries::Dense(&moved), 0));
    }

    #[test]
    fn recommend_ranks_reconstruction_and_excludes_seen() {
        let (w, q) = random_problem(30, 5, 8, 41);
        let p = Projector::new(w.clone(), pool(2), ProjectorOpts::default()).unwrap();
        let recs = p.recommend(Queries::Dense(&q), 5, false).unwrap();
        assert_eq!(recs.len(), 8);
        let h = p.project(Queries::Dense(&q)).unwrap();
        for (i, rec) in recs.iter().enumerate() {
            assert_eq!(rec.len(), 5);
            // Scores descend and match W·h directly.
            for pair in rec.windows(2) {
                assert!(pair[0].1 >= pair[1].1);
            }
            for &(item, score) in rec {
                let mut wh = 0.0f64;
                for t in 0..5 {
                    wh += w.at(item as usize, t) as f64 * h.at(i, t) as f64;
                }
                assert!((score as f64 - wh).abs() < 1e-4);
            }
        }
        // exclude_seen: a sparse query's non-zeros never appear.
        let csr = Csr::from_dense(&q);
        let recs = p.recommend(Queries::Sparse(&csr), 3, true).unwrap();
        for (i, rec) in recs.iter().enumerate() {
            for &(item, _) in rec {
                assert!(!Queries::Sparse(&csr).seen(i, item as usize), "query {i} item {item}");
            }
        }
    }

    #[test]
    fn empty_batch_and_shape_errors() {
        let (w, _) = random_problem(10, 3, 1, 1);
        let p = Projector::new(w, pool(1), ProjectorOpts::default()).unwrap();
        let empty = Mat::zeros(0, 10);
        assert_eq!(p.project(Queries::Dense(&empty)).unwrap().rows(), 0);
        let wrong = Mat::zeros(2, 9);
        assert!(p.project(Queries::Dense(&wrong)).is_err());
        // recommend_for validates shapes too (h can come from anywhere).
        let h = Mat::zeros(2, 3);
        assert!(p.recommend_for(Queries::Dense(&wrong), &h, 2, true).is_err());
        let h_bad = Mat::zeros(2, 4);
        let ok_q = Mat::zeros(2, 10);
        assert!(p.recommend_for(Queries::Dense(&ok_q), &h_bad, 2, false).is_err());
        // Residual buffer length is validated.
        let mut short = vec![0.0f64; 1];
        assert!(p.project_with(Queries::Dense(&ok_q), Some(&mut short), None).is_err());
    }

    fn kl_spec(alpha: f64, l1_ratio: f64) -> EngineSpec {
        EngineSpec {
            loss: Loss::Kl,
            solver: crate::nmf::spec::Solver::Mu,
            alpha,
            l1_ratio,
            ..Default::default()
        }
    }

    /// Generalized KL divergence D(a_i ‖ W·h_i), the KL mode's objective.
    fn kl_div(q: &Mat, w: &Mat, h: &Mat, i: usize) -> f64 {
        let mut d = 0.0f64;
        for v in 0..w.rows() {
            let a = q.at(i, v) as f64;
            let mut wh = 0.0f64;
            for t in 0..w.cols() {
                wh += w.at(v, t) as f64 * h.at(i, t) as f64;
            }
            wh = wh.max(1e-12);
            d += if a > 0.0 { a * (a / wh).ln() - a + wh } else { wh };
        }
        d
    }

    /// Elastic-net objective ½‖a_i − W·h_i‖² + l1·Σh + ½·l2·‖h‖².
    fn reg_objective(q: &Mat, w: &Mat, h: &Mat, i: usize, l1: f64, l2: f64) -> f64 {
        let r = residual_direct(q, w, h, i);
        let mut o = 0.5 * r * r;
        for t in 0..h.cols() {
            let x = h.at(i, t) as f64;
            o += l1 * x + 0.5 * l2 * x * x;
        }
        o
    }

    #[test]
    fn default_spec_is_bit_identical_to_new() {
        let (w, q) = random_problem(30, 5, 9, 23);
        let a = Projector::new(w.clone(), pool(2), ProjectorOpts::default()).unwrap();
        let b =
            Projector::with_spec(w, pool(2), ProjectorOpts::default(), EngineSpec::default())
                .unwrap();
        assert_eq!(
            a.project(Queries::Dense(&q)).unwrap(),
            b.project(Queries::Dense(&q)).unwrap()
        );
    }

    #[test]
    fn regularized_projection_matches_reg_bpp() {
        use crate::nmf::nnls::nnls_bpp_rows_reg;
        // Same acceptance bar as the free path: the exact elastic-net
        // KKT point (reg BPP) within 0.1% on the penalized objective.
        let (w, q) = random_problem(40, 6, 15, 5);
        let spec = EngineSpec { alpha: 0.3, l1_ratio: 0.5, ..Default::default() };
        let p = Projector::with_spec(
            w.clone(),
            pool(3),
            ProjectorOpts { sweeps: 300, micro_batch: 7, ..Default::default() },
            spec,
        )
        .unwrap();
        // Raw mode: the cached Gram is WᵀW itself, not unit-diagonal.
        assert!(p.gram().at(0, 0) > 2.0, "expected a raw (unnormalized) Gram");
        let h = p.project(Queries::Dense(&q)).unwrap();

        let g = gram_naive(&w);
        let mut b = Mat::zeros(15, 6);
        gemm(&pool(1), 1.0, q.view(), w.view(), GemmOp::Assign, &mut b.view_mut());
        let mut h_ref = Mat::zeros(15, 6);
        nnls_bpp_rows_reg(&ThreadPool::new(1), &g, &b, &mut h_ref, spec.shrink());

        let (l1, l2) = (spec.l1() as f64, spec.l2() as f64);
        for i in 0..15 {
            let o_hals = reg_objective(&q, &w, &h, i, l1, l2);
            let o_bpp = reg_objective(&q, &w, &h_ref, i, l1, l2);
            assert!(
                o_hals <= o_bpp * 1.001 + 1e-5,
                "query {i}: serving objective {o_hals} vs bpp {o_bpp}"
            );
        }
    }

    #[test]
    fn serving_l1_regularization_sparsifies_h() {
        let (w, q) = random_problem(30, 6, 10, 19);
        let opts = ProjectorOpts { sweeps: 100, ..Default::default() };
        let free = Projector::new(w.clone(), pool(2), opts).unwrap();
        let spec = EngineSpec { alpha: 5.0, l1_ratio: 1.0, ..Default::default() };
        let reg = Projector::with_spec(w, pool(2), opts, spec).unwrap();
        let hf = free.project(Queries::Dense(&q)).unwrap();
        let hr = reg.project(Queries::Dense(&q)).unwrap();
        let zeros = |h: &Mat| h.data().iter().filter(|&&x| x == 0.0).count();
        assert!(
            zeros(&hr) > zeros(&hf),
            "l1 must produce more exact zeros ({} vs {})",
            zeros(&hr),
            zeros(&hf)
        );
        assert!(hr.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn kl_projection_converges_on_planted_mixtures() {
        let mut rng = Pcg32::seeded(77);
        let w = Mat::random(40, 4, &mut rng, 0.1, 1.0);
        let h_true = Mat::random(9, 4, &mut rng, 0.0, 1.0);
        let mut q = Mat::zeros(9, 40);
        for i in 0..9 {
            for v in 0..40 {
                let mut s = 0.0f64;
                for t in 0..4 {
                    s += h_true.at(i, t) as f64 * w.at(v, t) as f64;
                }
                *q.at_mut(i, v) = s as Elem;
            }
        }
        let p = Projector::with_spec(
            w.clone(),
            pool(2),
            ProjectorOpts { sweeps: 200, micro_batch: 4, ..Default::default() },
            kl_spec(0.0, 0.0),
        )
        .unwrap();
        let (h, res) = p.project_with_residuals(Queries::Dense(&q)).unwrap();
        for i in 0..9 {
            // An exactly factorable row must reach near-zero divergence
            // (relative to its mass) and a small Euclidean residual too.
            let mass: f64 = q.row(i).iter().map(|&x| x as f64).sum();
            let d = kl_div(&q, &w, &h, i);
            assert!(d / mass < 1e-3, "row {i}: KL divergence {d} for mass {mass}");
            assert!(res[i] < 0.05, "row {i}: euclidean residual {}", res[i]);
        }
    }

    #[test]
    fn kl_sparse_and_dense_queries_agree() {
        let (w, qd) = random_problem(25, 4, 11, 83);
        let mut rng = Pcg32::seeded(84);
        let mut qs = qd;
        for i in 0..qs.rows() {
            for x in qs.row_mut(i).iter_mut() {
                if rng.below(10) < 7 {
                    *x = 0.0;
                }
            }
        }
        let csr = Csr::from_dense(&qs);
        let p = Projector::with_spec(
            w,
            pool(3),
            ProjectorOpts { sweeps: 60, micro_batch: 5, ..Default::default() },
            kl_spec(0.0, 0.0),
        )
        .unwrap();
        let h_dense = p.project(Queries::Dense(&qs)).unwrap();
        let h_sparse = p.project(Queries::Sparse(&csr)).unwrap();
        // Both encodings walk the same support in the same order.
        assert!(h_dense.max_abs_diff(&h_sparse) < 1e-6);
    }

    #[test]
    fn kl_regularization_shrinks_mixtures() {
        let (w, q) = random_problem(30, 5, 8, 67);
        let opts = ProjectorOpts { sweeps: 100, ..Default::default() };
        let free = Projector::with_spec(w.clone(), pool(2), opts, kl_spec(0.0, 0.0)).unwrap();
        let reg = Projector::with_spec(w, pool(2), opts, kl_spec(20.0, 1.0)).unwrap();
        let hf = free.project(Queries::Dense(&q)).unwrap();
        let hr = reg.project(Queries::Dense(&q)).unwrap();
        let sum = |h: &Mat| h.data().iter().map(|&x| x as f64).sum::<f64>();
        assert!(
            sum(&hr) < sum(&hf),
            "an l1 penalty must shrink total mixture mass ({} vs {})",
            sum(&hr),
            sum(&hf)
        );
        assert!(hr.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn kl_zero_rows_and_warm_cache() {
        let (w, mut q) = random_problem(25, 4, 7, 91);
        q.row_mut(1).fill(0.0);
        let p = Projector::with_spec(
            w,
            pool(2),
            ProjectorOpts { sweeps: 200, micro_batch: 3, tol: 1e-7, ..Default::default() },
            kl_spec(0.0, 0.0),
        )
        .unwrap();
        let mut cache = WarmCache::new(32);
        let (h_cold, cold) = p.project_warm(Queries::Dense(&q), &mut cache).unwrap();
        assert!(h_cold.row(1).iter().all(|&x| x == 0.0), "zero row stays exactly zero");
        assert_eq!(cold.warm_hits, 0);
        assert_eq!(cold.warm_misses, 6, "zero rows never enter the cache");
        let (h_warm, warm) = p.project_warm(Queries::Dense(&q), &mut cache).unwrap();
        assert_eq!(warm.warm_hits, 6);
        assert_eq!(warm.warm_misses, 0);
        assert!(warm.sweeps <= cold.sweeps);
        assert!(h_cold.max_abs_diff(&h_warm) < 1e-3);
        // Fused residuals in KL mode still report Euclidean error: 0 for
        // the zero row, finite elsewhere.
        let (_, res) = p.project_with_residuals(Queries::Dense(&q)).unwrap();
        assert_eq!(res[1], 0.0);
        assert!(res.iter().all(|r| r.is_finite()));
    }

    #[test]
    fn warm_cache_salt_isolates_epochs() {
        // Regression (stale warm starts across factor swaps): an entry
        // written under epoch N must be invisible under epoch N+1, and
        // reappear if the salt rolls back — proving the isolation is in
        // the key, not in a flush the caller might forget.
        let mut cache = WarmCache::new(8);
        cache.put(42, vec![1.0, 2.0]);
        assert!(cache.get(42).is_some(), "own-epoch lookup must hit");
        cache.set_salt(1);
        assert!(cache.get(42).is_none(), "epoch-0 entry leaked into epoch 1");
        cache.put(42, vec![9.0]);
        assert_eq!(cache.get(42).unwrap(), &[9.0][..]);
        cache.set_salt(0);
        assert_eq!(
            cache.get(42).unwrap(),
            &[1.0, 2.0][..],
            "epoch-0 entry must survive under its own salt"
        );
    }

    /// `XᵀY` in f64, cast down — the exact reference for fold statistics.
    fn xty(x: &Mat, y: &Mat) -> Mat {
        let mut out = Mat::zeros(x.cols(), y.cols());
        for r in 0..x.cols() {
            for c in 0..y.cols() {
                let mut s = 0.0f64;
                for i in 0..x.rows() {
                    s += x.at(i, r) as f64 * y.at(i, c) as f64;
                }
                *out.at_mut(r, c) = s as Elem;
            }
        }
        out
    }

    /// `X·Yᵀ` in f64, cast down — synthesizes exact-rank data batches.
    fn xyt(x: &Mat, y: &Mat) -> Mat {
        let mut out = Mat::zeros(x.rows(), y.rows());
        for r in 0..x.rows() {
            for c in 0..y.rows() {
                let mut s = 0.0f64;
                for t in 0..x.cols() {
                    s += x.at(r, t) as f64 * y.at(c, t) as f64;
                }
                *out.at_mut(r, c) = s as Elem;
            }
        }
        out
    }

    /// The fold-in W half-sweep, re-stated locally: Gauss–Seidel column
    /// updates against (S, P) with a rank-1-refreshed WS product.
    fn hals_w_sweeps(w: &mut Mat, s: &Mat, p: &Mat, sweeps: usize) {
        let (v, k) = (w.rows(), w.cols());
        let mut ws = Mat::zeros(v, k);
        for vi in 0..v {
            for c in 0..k {
                let mut acc = 0.0f64;
                for t in 0..k {
                    acc += w.at(vi, t) as f64 * s.at(t, c) as f64;
                }
                *ws.at_mut(vi, c) = acc as Elem;
            }
        }
        for _ in 0..sweeps {
            for t in 0..k {
                let stt = s.at(t, t);
                if stt <= 1e-12 {
                    continue;
                }
                for vi in 0..v {
                    let cur = w.at(vi, t);
                    let new = (cur + (p.at(vi, t) - ws.at(vi, t)) / stt).max(0.0);
                    let d = new - cur;
                    if d != 0.0 {
                        *w.at_mut(vi, t) = new;
                        for c in 0..k {
                            *ws.at_mut(vi, c) += d * s.at(t, c);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn fold_in_matches_offline_refit_on_concatenated_data() {
        // Exact-rank setup: A₀ = H₀·W₀ᵀ trains the model, Q₁ = H₁·W₀ᵀ
        // arrives online. The incremental path (seeded statistics +
        // fold_in) must land within 2e-3 of an offline refit from the
        // *exact* concatenated statistics S = [H₀;Ĥ₁]ᵀ[H₀;Ĥ₁],
        // P = [A₀;Q₁]ᵀ[H₀;Ĥ₁] — the seed's P₀ = W·S₀ shortcut is exact
        // here because the training residual is zero.
        let mut rng = Pcg32::seeded(131);
        let (v, k, d0, m1) = (30usize, 4usize, 40usize, 12usize);
        let w0 = Mat::random(v, k, &mut rng, 0.1, 1.0);
        let h0 = Mat::random(d0, k, &mut rng, 0.0, 1.0);
        let h1_true = Mat::random(m1, k, &mut rng, 0.0, 1.0);
        let a0 = xyt(&h0, &w0);
        let q1 = xyt(&h1_true, &w0);

        let p = Projector::new(
            w0.clone(),
            pool(2),
            ProjectorOpts { sweeps: 100, micro_batch: 4, ..Default::default() },
        )
        .unwrap();
        // Round-trip sanity: raw_w undoes the unit normalization.
        assert!(p.raw_w().max_abs_diff(&w0) < 1e-4);

        let mut fold = p.fold_seed(&h0).unwrap();
        assert_eq!(fold.rows(), d0);
        let sweeps = 50;
        let (w_inc, _) = p.fold_in(Queries::Dense(&q1), &mut fold, None, sweeps).unwrap();
        assert_eq!(fold.rows(), d0 + m1);

        // Offline reference: identical projection of the batch, exact
        // statistics straight from the concatenated data.
        let (h1, _) = p.project_with(Queries::Dense(&q1), None, None).unwrap();
        let mut s_all = xty(&h0, &h0);
        let s1 = xty(&h1, &h1);
        let mut p_all = xty(&a0, &h0);
        let p1 = xty(&q1, &h1);
        for r in 0..k {
            for c in 0..k {
                *s_all.at_mut(r, c) += s1.at(r, c);
            }
        }
        for r in 0..v {
            for c in 0..k {
                *p_all.at_mut(r, c) += p1.at(r, c);
            }
        }
        let mut w_ref = w0.clone();
        hals_w_sweeps(&mut w_ref, &s_all, &p_all, sweeps);

        assert!(
            w_inc.max_abs_diff(&w_ref) < 2e-3,
            "incremental vs offline refit diverged: {}",
            w_inc.max_abs_diff(&w_ref)
        );
        // And the update genuinely moved the factors toward the new data.
        assert!(w_inc.max_abs_diff(&w0) > 0.0);
    }

    #[test]
    fn fold_in_is_spec_gated_and_validates_inputs() {
        let (w, q) = random_problem(20, 4, 5, 7);
        let opts = ProjectorOpts { sweeps: 30, ..Default::default() };
        let h_seed = Mat::random(6, 4, &mut Pcg32::seeded(8), 0.0, 1.0);

        // KL and regularized specs must refuse the Frobenius-only path.
        for spec in [kl_spec(0.0, 0.0), EngineSpec { alpha: 0.5, l1_ratio: 0.5, ..Default::default() }] {
            let p = Projector::with_spec(w.clone(), pool(1), opts, spec).unwrap();
            let mut fold = p.fold_seed(&h_seed).unwrap();
            let err = p
                .fold_in(Queries::Dense(&q), &mut fold, None, 10)
                .unwrap_err()
                .to_string();
            assert!(err.contains("spec-gated"), "unexpected gate message: {err}");
        }

        // Shape / degenerate-input validation on the default spec.
        let p = Projector::new(w, pool(1), opts).unwrap();
        let bad_seed = Mat::zeros(6, 3);
        assert!(p.fold_seed(&bad_seed).is_err(), "K-mismatched seed must fail");
        let mut fold = p.fold_seed(&h_seed).unwrap();
        assert!(p.fold_in(Queries::Dense(&q), &mut fold, None, 0).is_err(), "0 sweeps");
        let empty = Mat::zeros(0, 20);
        assert!(p.fold_in(Queries::Dense(&empty), &mut fold, None, 5).is_err(), "empty batch");
    }
}

//! Batched factor projection with a cached Gram.
//!
//! A [`Projector`] owns a trained `W` and answers `h* = argmin_{h≥0}
//! ‖a − W·h‖` for batches of query columns. Construction does the
//! per-model work once:
//!
//! * columns of `W` are L2-normalized into `Ŵ` (inverse norms kept), so
//!   the cached Gram `Ĝ = ŴᵀŴ` has a **unit diagonal** — which makes the
//!   `UpdateKind::Plain` HALS kernel an *exact* coordinate step
//!   (`h_t ← max(ε, h_t + b_t − Σ_j h_j·Ĝ_jt)` needs no `/Ĝ_tt`);
//! * the tile width is picked from the §5 data-movement model.
//!
//! Serving a batch is then:
//!
//! 1. shard the m queries into micro-batches — nnz-balanced contiguous
//!    ranges ([`crate::coordinator::shard::balanced_row_shards`]) for
//!    sparse batches (bag-of-words queries are Zipf-skewed like the
//!    training data), even row splits for dense;
//! 2. per micro-batch, one panel product `B = Q·Ŵ` (CSR SpMM or blocked
//!    GEMM — the same hot kernels training uses);
//! 3. a few sweeps of `halsops::update_tiled` (Plain kind) on the m̂×K
//!    panel against the cached Ĝ — each sweep is the paper's
//!    three-phase tiled update, thread-parallel over the micro-batch rows;
//! 4. rescale `h = D⁻¹·ĥ` back to original-`W` coordinates.
//!
//! Micro-batches run sequentially because every stage already saturates
//! the pool internally; the batch-size win comes from amortizing kernel
//! dispatch and turning per-query dot products into panel GEMMs (the
//! `serving_throughput` bench measures docs/sec at sizes 1/32/512).

use std::ops::Range;
use std::sync::Arc;

use anyhow::bail;

use crate::coordinator::shard::balanced_row_shards;
use crate::linalg::{gemm, GemmOp, Mat};
use crate::nmf::cost_model;
use crate::nmf::halsops::{update_tiled, UpdateKind};
use crate::nmf::products;
use crate::parallel::{split_even, ThreadPool};
use crate::sparse::{spmm::spmm_range, Csr};
use crate::util::PhaseTimers;
use crate::{Elem, Result, EPS};

/// A batch of query columns, one query per **row** (m×V — the same
/// orientation as the resident `Aᵀ`, so a dataset's documents can be
/// re-projected directly).
#[derive(Clone, Copy)]
pub enum Queries<'a> {
    Dense(&'a Mat),
    Sparse(&'a Csr),
}

impl<'a> Queries<'a> {
    pub fn rows(&self) -> usize {
        match self {
            Queries::Dense(m) => m.rows(),
            Queries::Sparse(a) => a.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Queries::Dense(m) => m.cols(),
            Queries::Sparse(a) => a.cols(),
        }
    }

    /// ‖a_i‖² of query row `i` (f64 accumulation).
    fn row_norm2(&self, i: usize) -> f64 {
        match self {
            Queries::Dense(m) => m.row(i).iter().map(|&x| x as f64 * x as f64).sum(),
            Queries::Sparse(a) => {
                let (_, vals) = a.row(i);
                vals.iter().map(|&x| x as f64 * x as f64).sum()
            }
        }
    }

    /// Whether item `v` appears in query row `i` (recommender "seen"
    /// filtering).
    fn seen(&self, i: usize, v: usize) -> bool {
        match self {
            Queries::Dense(m) => m.at(i, v) != 0.0,
            Queries::Sparse(a) => {
                let (cols, _) = a.row(i);
                cols.binary_search(&(v as u32)).is_ok()
            }
        }
    }
}

/// Serving knobs.
#[derive(Debug, Clone, Copy)]
pub struct ProjectorOpts {
    /// HALS sweeps per micro-batch (each sweep is one full tiled pass).
    pub sweeps: usize,
    /// Queries per micro-batch (the throughput/latency trade-off).
    pub micro_batch: usize,
    /// Tile width T; 0 selects via the §5 model.
    pub tile: usize,
    /// Cache size for the tile model (see [`crate::config::RunConfig`]).
    pub cache_bytes: usize,
    /// Early-stop a micro-batch when the max entry change of a sweep
    /// falls below `tol` (0 = always run all `sweeps`, deterministic).
    pub tol: f64,
}

impl Default for ProjectorOpts {
    fn default() -> Self {
        ProjectorOpts {
            sweeps: 30,
            micro_batch: 64,
            tile: 0,
            cache_bytes: 35 * 1024 * 1024,
            tol: 0.0,
        }
    }
}

/// A loaded model ready to answer projection queries.
pub struct Projector {
    /// Column-normalized factor Ŵ (V×K).
    w_unit: Mat,
    /// Original column norms ‖w_t‖ (0 for dead topics).
    col_norm: Vec<Elem>,
    /// 1/‖w_t‖ (0 for dead topics): maps unit-space solutions back.
    col_scale: Vec<Elem>,
    /// Cached Gram Ĝ = ŴᵀŴ (K×K, unit diagonal up to fp).
    gram: Mat,
    pool: Arc<ThreadPool>,
    opts: ProjectorOpts,
    tile: usize,
}

impl Projector {
    /// Build from a trained `W` (consumed; `H` is not needed for
    /// serving). Computes the cached Gram once.
    pub fn new(w: Mat, pool: Arc<ThreadPool>, opts: ProjectorOpts) -> Projector {
        let (v, k) = (w.rows(), w.cols());
        assert!(k > 0, "Projector needs k >= 1");
        let mut w_unit = w;

        // Column norms in f64 (one row-major pass), then scale in place.
        let mut norm2 = vec![0.0f64; k];
        for i in 0..v {
            for (t, &x) in w_unit.row(i).iter().enumerate() {
                norm2[t] += x as f64 * x as f64;
            }
        }
        let col_norm: Vec<Elem> = norm2.iter().map(|&n| n.sqrt() as Elem).collect();
        let col_scale: Vec<Elem> =
            col_norm.iter().map(|&n| if n > 1e-12 { 1.0 / n } else { 0.0 }).collect();
        for i in 0..v {
            for (x, &s) in w_unit.row_mut(i).iter_mut().zip(&col_scale) {
                *x *= s;
            }
        }

        let gram = products::factor_gram(&pool, &w_unit);
        let tile = if opts.tile > 0 {
            opts.tile.clamp(1, k)
        } else {
            cost_model::select_tile(k, opts.cache_bytes).clamp(1, k)
        };
        Projector { w_unit, col_norm, col_scale, gram, pool, opts, tile }
    }

    pub fn v(&self) -> usize {
        self.w_unit.rows()
    }

    pub fn k(&self) -> usize {
        self.w_unit.cols()
    }

    pub fn tile(&self) -> usize {
        self.tile
    }

    /// The cached Gram (K×K) — exposed for diagnostics/tests.
    pub fn gram(&self) -> &Mat {
        &self.gram
    }

    /// Micro-batch row ranges for an m-row batch: nnz-balanced for
    /// sparse queries, even splits for dense.
    fn shards(&self, q: Queries<'_>) -> Vec<Range<usize>> {
        let m = q.rows();
        let parts = m.div_ceil(self.opts.micro_batch.max(1)).max(1);
        match q {
            Queries::Sparse(a) => balanced_row_shards(a, parts),
            Queries::Dense(_) => split_even(m, parts),
        }
    }

    /// Project a batch of queries: returns `H*` (m×K, original-`W`
    /// coordinates, entries ≥ 0 with exact zeros where the solve hit the
    /// non-negativity boundary).
    pub fn project(&self, q: Queries<'_>) -> Result<Mat> {
        self.project_impl(q, None)
    }

    /// [`Self::project`] plus per-query relative residuals
    /// `‖a_i − W·h_i‖ / ‖a_i‖`, computed from the micro-batch's live
    /// `B` panel — no second pass over the query matrix (the standalone
    /// [`Self::residuals`] redoes that product).
    pub fn project_with_residuals(&self, q: Queries<'_>) -> Result<(Mat, Vec<f64>)> {
        let mut res = vec![0.0f64; q.rows()];
        let h = self.project_impl(q, Some(&mut res))?;
        Ok((h, res))
    }

    fn project_impl(&self, q: Queries<'_>, mut res: Option<&mut [f64]>) -> Result<Mat> {
        let (m, k) = (q.rows(), self.k());
        if q.cols() != self.v() {
            bail!("queries have {} features, model expects V={}", q.cols(), self.v());
        }
        let mut h = Mat::zeros(m, k);
        if m == 0 {
            return Ok(h);
        }
        let mut timers = PhaseTimers::new();
        for r in self.shards(q) {
            if !r.is_empty() {
                self.solve_micro_batch(q, r, &mut h, res.as_deref_mut(), &mut timers);
            }
        }
        Ok(h)
    }

    /// One micro-batch: panel product, HALS sweeps, rescale into `h`
    /// (and, when requested, the Gram-expansion residuals while `B` is
    /// still live).
    fn solve_micro_batch(
        &self,
        q: Queries<'_>,
        r: Range<usize>,
        h: &mut Mat,
        res: Option<&mut [f64]>,
        timers: &mut PhaseTimers,
    ) {
        let (mb, k) = (r.len(), self.k());
        let mut b = Mat::zeros(mb, k);
        match q {
            Queries::Sparse(a) => timers.time("serve_product", || {
                spmm_range(&self.pool, 1.0, a, r.clone(), &self.w_unit, &mut b.view_mut())
            }),
            Queries::Dense(qm) => timers.time("serve_product", || {
                gemm(
                    &self.pool,
                    1.0,
                    qm.block_view(r.start, r.end, 0, qm.cols()),
                    self.w_unit.view(),
                    GemmOp::Assign,
                    &mut b.view_mut(),
                )
            }),
        }

        let mut g = Mat::zeros(mb, k);
        let mut scratch = Mat::zeros(mb, k);
        for _ in 0..self.opts.sweeps.max(1) {
            update_tiled(
                &self.pool,
                &mut g,
                &mut scratch,
                &self.gram,
                &b,
                self.tile,
                UpdateKind::Plain,
                timers,
                ["serve_phase1", "serve_phase2", "serve_phase3"],
            );
            // `scratch` holds the pre-sweep values — a free convergence
            // probe for the optional early stop.
            if self.opts.tol > 0.0 && g.max_abs_diff(&scratch) < self.opts.tol {
                break;
            }
        }

        // ĥ → h = D⁻¹ĥ; entries clamped to ε by the kernel are snapped
        // to exact 0 (they are the active non-negativity constraints).
        for (local, i) in r.clone().enumerate() {
            let grow = g.row(local);
            let hrow = h.row_mut(i);
            for t in 0..k {
                let gv = grow[t];
                hrow[t] = if gv <= EPS { 0.0 } else { gv * self.col_scale[t] };
            }
        }

        // Residuals from the live panel: ‖a − Ŵĝ‖² = ‖a‖² − 2ĝᵀb + ĝᵀĜĝ.
        if let Some(res) = res {
            for (local, i) in r.enumerate() {
                let ghat = g.row(local);
                let a2 = q.row_norm2(i);
                let mut cross = 0.0f64;
                let mut quad = 0.0f64;
                for t in 0..k {
                    let gt = ghat[t] as f64;
                    cross += gt * b.at(local, t) as f64;
                    let gram_row = self.gram.row(t);
                    let mut s = 0.0f64;
                    for j in 0..k {
                        s += gram_row[j] as f64 * ghat[j] as f64;
                    }
                    quad += gt * s;
                }
                let r2 = (a2 - 2.0 * cross + quad).max(0.0);
                res[i] = if a2 > 0.0 { (r2 / a2).sqrt() } else { 0.0 };
            }
        }
    }

    /// Relative residuals `‖a_i − W·h_i‖ / ‖a_i‖` for a projected batch,
    /// computed in O(mK²) via the Gram expansion
    /// `‖a − Ŵĝ‖² = ‖a‖² − 2·ĝᵀb + ĝᵀĜĝ` (never materializes W·h).
    pub fn residuals(&self, q: Queries<'_>, h: &Mat) -> Result<Vec<f64>> {
        let (m, k) = (q.rows(), self.k());
        if h.rows() != m || h.cols() != k {
            bail!("h is {}x{}, expected {m}x{k}", h.rows(), h.cols());
        }
        if q.cols() != self.v() {
            bail!("queries have {} features, model expects V={}", q.cols(), self.v());
        }
        let mut b = Mat::zeros(m, k);
        match q {
            Queries::Sparse(a) => {
                spmm_range(&self.pool, 1.0, a, 0..m, &self.w_unit, &mut b.view_mut())
            }
            Queries::Dense(qm) => gemm(
                &self.pool,
                1.0,
                qm.view(),
                self.w_unit.view(),
                GemmOp::Assign,
                &mut b.view_mut(),
            ),
        }
        let mut out = Vec::with_capacity(m);
        let mut ghat = vec![0.0f64; k];
        for i in 0..m {
            let hrow = h.row(i);
            for t in 0..k {
                ghat[t] = hrow[t] as f64 * self.col_norm[t] as f64;
            }
            let a2 = q.row_norm2(i);
            let mut cross = 0.0f64;
            let mut quad = 0.0f64;
            for t in 0..k {
                cross += ghat[t] * b.at(i, t) as f64;
                let grow = self.gram.row(t);
                let mut s = 0.0f64;
                for j in 0..k {
                    s += grow[j] as f64 * ghat[j];
                }
                quad += ghat[t] * s;
            }
            let r2 = (a2 - 2.0 * cross + quad).max(0.0);
            out.push(if a2 > 0.0 { (r2 / a2).sqrt() } else { 0.0 });
        }
        Ok(out)
    }

    /// Project a batch and return, per query, the top-N items by
    /// reconstruction score `(W·h*)_v`, descending. With `exclude_seen`,
    /// items already present in the query (non-zero entries) are skipped
    /// — the standard recommender protocol.
    pub fn recommend(
        &self,
        q: Queries<'_>,
        top_n: usize,
        exclude_seen: bool,
    ) -> Result<Vec<Vec<(u32, Elem)>>> {
        let h = self.project(q)?;
        self.recommend_for(q, &h, top_n, exclude_seen)
    }

    /// Rank items for already-projected mixtures (`h` in original-`W`
    /// coordinates, as returned by [`Self::project`]).
    pub fn recommend_for(
        &self,
        q: Queries<'_>,
        h: &Mat,
        top_n: usize,
        exclude_seen: bool,
    ) -> Result<Vec<Vec<(u32, Elem)>>> {
        let (m, k, v) = (h.rows(), self.k(), self.v());
        if q.rows() != m {
            bail!("queries ({}) and h ({m}) row counts differ", q.rows());
        }
        if q.cols() != v {
            bail!("queries have {} features, model expects V={v}", q.cols());
        }
        if h.cols() != k {
            bail!("h has {} columns, model expects K={k}", h.cols());
        }
        let top_n = top_n.min(v).max(1);
        let mb = self.opts.micro_batch.max(1);
        let mut out = Vec::with_capacity(m);
        let mut scores_buf = Vec::with_capacity(v);
        let mut r0 = 0;
        while r0 < m {
            let r1 = (r0 + mb).min(m);
            let width = r1 - r0;
            // ĝᵀ panel (K×m̂): scores = Ŵ·ĝ = W·h, one blocked GEMM.
            let mut gt = Mat::zeros(k, width);
            for j in 0..width {
                let hrow = h.row(r0 + j);
                for t in 0..k {
                    *gt.at_mut(t, j) = hrow[t] * self.col_norm[t];
                }
            }
            let mut scores = Mat::zeros(v, width);
            gemm(&self.pool, 1.0, self.w_unit.view(), gt.view(), GemmOp::Assign, &mut scores.view_mut());
            for j in 0..width {
                let i = r0 + j;
                scores_buf.clear();
                for item in 0..v {
                    if exclude_seen && q.seen(i, item) {
                        continue;
                    }
                    scores_buf.push((item as u32, scores.at(item, j)));
                }
                out.push(top_n_desc(&mut scores_buf, top_n));
            }
            r0 = r1;
        }
        Ok(out)
    }
}

/// Partial selection: the `n` largest-score entries, sorted descending.
fn top_n_desc(scores: &mut Vec<(u32, Elem)>, n: usize) -> Vec<(u32, Elem)> {
    let n = n.min(scores.len());
    if n == 0 {
        return Vec::new();
    }
    let desc = |a: &(u32, Elem), b: &(u32, Elem)| {
        b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
    };
    if n < scores.len() {
        scores.select_nth_unstable_by(n - 1, desc);
        scores.truncate(n);
    }
    scores.sort_unstable_by(desc);
    scores.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gram::gram_naive;
    use crate::nmf::nnls::nnls_bpp_rows;
    use crate::util::rng::Pcg32;

    fn pool(n: usize) -> Arc<ThreadPool> {
        Arc::new(ThreadPool::new(n))
    }

    /// Dense residual by direct evaluation (reference for the Gram form).
    fn residual_direct(q: &Mat, w: &Mat, h: &Mat, i: usize) -> f64 {
        let mut r2 = 0.0f64;
        for vrow in 0..w.rows() {
            let mut wh = 0.0f64;
            for t in 0..w.cols() {
                wh += w.at(vrow, t) as f64 * h.at(i, t) as f64;
            }
            let d = q.at(i, vrow) as f64 - wh;
            r2 += d * d;
        }
        r2.sqrt()
    }

    fn random_problem(v: usize, k: usize, m: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Pcg32::seeded(seed);
        // Unnormalized W — exercises the unit-column rescaling path.
        let w = Mat::random(v, k, &mut rng, 0.0, 2.0);
        let q = Mat::random(m, v, &mut rng, 0.0, 1.0);
        (w, q)
    }

    #[test]
    fn gram_has_unit_diagonal() {
        let (w, _) = random_problem(40, 7, 1, 1);
        let p = Projector::new(w, pool(2), ProjectorOpts::default());
        for t in 0..7 {
            assert!((p.gram().at(t, t) - 1.0).abs() < 1e-5, "G[{t},{t}]");
        }
    }

    #[test]
    fn projection_matches_bpp_nnls() {
        // The acceptance bar: a from-scratch NNLS solve of the same
        // columns (BPP finds the exact KKT point) within 1e-3 rel error.
        let (w, q) = random_problem(40, 6, 23, 5);
        let p = Projector::new(
            w.clone(),
            pool(3),
            ProjectorOpts { sweeps: 300, micro_batch: 7, ..Default::default() },
        );
        let h = p.project(Queries::Dense(&q)).unwrap();

        // Reference: G = WᵀW, B = Q·W, exact per-row NNLS.
        let g = gram_naive(&w);
        let mut b = Mat::zeros(23, 6);
        gemm(&pool(1), 1.0, q.view(), w.view(), GemmOp::Assign, &mut b.view_mut());
        let mut h_ref = Mat::zeros(23, 6);
        nnls_bpp_rows(&ThreadPool::new(1), &g, &b, &mut h_ref);

        for i in 0..23 {
            let r_hals = residual_direct(&q, &w, &h, i);
            let r_bpp = residual_direct(&q, &w, &h_ref, i);
            assert!(
                r_hals <= r_bpp * 1.001 + 1e-5,
                "query {i}: hals residual {r_hals} vs bpp {r_bpp}"
            );
        }
    }

    #[test]
    fn residuals_match_direct_evaluation() {
        let (w, q) = random_problem(30, 5, 11, 9);
        let p = Projector::new(w.clone(), pool(2), ProjectorOpts::default());
        let h = p.project(Queries::Dense(&q)).unwrap();
        let rel = p.residuals(Queries::Dense(&q), &h).unwrap();
        for i in 0..11 {
            let direct = residual_direct(&q, &w, &h, i) / q.row(i).iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            assert!((rel[i] - direct).abs() < 1e-4, "query {i}: {} vs {}", rel[i], direct);
        }
    }

    #[test]
    fn fused_residuals_match_standalone() {
        let (w, q) = random_problem(28, 5, 13, 17);
        let p = Projector::new(
            w,
            pool(2),
            ProjectorOpts { sweeps: 30, micro_batch: 6, ..Default::default() },
        );
        let (h, fused) = p.project_with_residuals(Queries::Dense(&q)).unwrap();
        let standalone = p.residuals(Queries::Dense(&q), &h).unwrap();
        for (i, (a, b)) in fused.iter().zip(&standalone).enumerate() {
            assert!((a - b).abs() < 1e-4, "query {i}: fused {a} vs standalone {b}");
        }
    }

    #[test]
    fn micro_batch_size_does_not_change_results() {
        // The Plain update is row-local, so batching is exact.
        let (w, q) = random_problem(35, 6, 40, 11);
        let mut outs = Vec::new();
        for mb in [1usize, 8, 64] {
            let p = Projector::new(
                w.clone(),
                pool(2),
                ProjectorOpts { sweeps: 20, micro_batch: mb, ..Default::default() },
            );
            outs.push(p.project(Queries::Dense(&q)).unwrap());
        }
        assert!(outs[0].max_abs_diff(&outs[1]) < 1e-6);
        assert!(outs[0].max_abs_diff(&outs[2]) < 1e-6);
    }

    #[test]
    fn sparse_and_dense_queries_agree() {
        let (w, qd) = random_problem(30, 5, 19, 13);
        // Sparsify: zero out ~70% of entries, then compare both paths.
        let mut rng = Pcg32::seeded(99);
        let mut qs = qd.clone();
        for i in 0..qs.rows() {
            for x in qs.row_mut(i).iter_mut() {
                if rng.below(10) < 7 {
                    *x = 0.0;
                }
            }
        }
        let csr = Csr::from_dense(&qs);
        let p = Projector::new(w, pool(3), ProjectorOpts { sweeps: 40, micro_batch: 5, ..Default::default() });
        let h_dense = p.project(Queries::Dense(&qs)).unwrap();
        let h_sparse = p.project(Queries::Sparse(&csr)).unwrap();
        assert!(h_dense.max_abs_diff(&h_sparse) < 1e-4);
    }

    #[test]
    fn dead_topic_columns_yield_zero_weights() {
        let mut rng = Pcg32::seeded(21);
        let mut w = Mat::random(20, 4, &mut rng, 0.0, 1.0);
        for i in 0..20 {
            *w.at_mut(i, 2) = 0.0; // dead topic
        }
        let q = Mat::random(6, 20, &mut rng, 0.0, 1.0);
        let p = Projector::new(w, pool(1), ProjectorOpts::default());
        let h = p.project(Queries::Dense(&q)).unwrap();
        for i in 0..6 {
            assert_eq!(h.at(i, 2), 0.0, "dead topic must get zero weight");
        }
    }

    #[test]
    fn early_stop_matches_full_sweeps() {
        let (w, q) = random_problem(25, 5, 9, 31);
        let full = Projector::new(
            w.clone(),
            pool(2),
            ProjectorOpts { sweeps: 200, ..Default::default() },
        );
        let early = Projector::new(
            w,
            pool(2),
            ProjectorOpts { sweeps: 200, tol: 1e-7, ..Default::default() },
        );
        let hf = full.project(Queries::Dense(&q)).unwrap();
        let he = early.project(Queries::Dense(&q)).unwrap();
        assert!(hf.max_abs_diff(&he) < 1e-3);
    }

    #[test]
    fn recommend_ranks_reconstruction_and_excludes_seen() {
        let (w, q) = random_problem(30, 5, 8, 41);
        let p = Projector::new(w.clone(), pool(2), ProjectorOpts::default());
        let recs = p.recommend(Queries::Dense(&q), 5, false).unwrap();
        assert_eq!(recs.len(), 8);
        let h = p.project(Queries::Dense(&q)).unwrap();
        for (i, rec) in recs.iter().enumerate() {
            assert_eq!(rec.len(), 5);
            // Scores descend and match W·h directly.
            for pair in rec.windows(2) {
                assert!(pair[0].1 >= pair[1].1);
            }
            for &(item, score) in rec {
                let mut wh = 0.0f64;
                for t in 0..5 {
                    wh += w.at(item as usize, t) as f64 * h.at(i, t) as f64;
                }
                assert!((score as f64 - wh).abs() < 1e-4);
            }
        }
        // exclude_seen: a sparse query's non-zeros never appear.
        let csr = Csr::from_dense(&q);
        let recs = p.recommend(Queries::Sparse(&csr), 3, true).unwrap();
        for (i, rec) in recs.iter().enumerate() {
            for &(item, _) in rec {
                assert!(!Queries::Sparse(&csr).seen(i, item as usize), "query {i} item {item}");
            }
        }
    }

    #[test]
    fn empty_batch_and_shape_errors() {
        let (w, _) = random_problem(10, 3, 1, 1);
        let p = Projector::new(w, pool(1), ProjectorOpts::default());
        let empty = Mat::zeros(0, 10);
        assert_eq!(p.project(Queries::Dense(&empty)).unwrap().rows(), 0);
        let wrong = Mat::zeros(2, 9);
        assert!(p.project(Queries::Dense(&wrong)).is_err());
        // recommend_for validates shapes too (h can come from anywhere).
        let h = Mat::zeros(2, 3);
        assert!(p.recommend_for(Queries::Dense(&wrong), &h, 2, true).is_err());
        let h_bad = Mat::zeros(2, 4);
        let ok_q = Mat::zeros(2, 10);
        assert!(p.recommend_for(Queries::Dense(&ok_q), &h_bad, 2, false).is_err());
    }
}

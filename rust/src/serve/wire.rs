//! PLNB v2 — the length-prefixed binary frame codec for dense batches,
//! plus the framed-connection loop shared by the daemon and the router.
//!
//! PL-NMF's thesis is that data movement, not arithmetic, sets the
//! budget — and the serving bench shows the same off-chip: JSON
//! encode/decode dominates daemon round-trip time for large dense
//! batches (`serving_daemon.csv`). A 256×128 f32 batch is 128 KiB of
//! payload but ~0.5 MB of JSON text, every byte of which is formatted,
//! escaped, and re-parsed. PLNB v2 ships the same matrix as raw
//! little-endian f32 behind a fixed header, so the wire cost returns to
//! the data's actual size.
//!
//! ## Frame layout (all integers little-endian)
//!
//! ```text
//! offset  size  field
//! 0       4     magic  "PLNB"
//! 4       1     version (2)
//! 5       1     op      (0x01 transform, 0x02 recommend,
//!                        0x03 shard-load, 0x04 sweep, 0x05 update,
//!                        0x06 sweep-mu, 0x07 grid-sweep-a,
//!                        0x08 grid-sweep-b,
//!                        0x81 transform response, 0x83 gram response)
//! 6       2     name_len  u16 — model-name bytes (0 in responses)
//! 8       4     meta_len  u32 — JSON meta segment bytes (may be 0)
//! 12      4     rows      u32
//! 16      4     cols      u32
//! 20      ...   name bytes, then meta bytes, then rows*cols f32 LE
//! ```
//!
//! The meta segment is a small JSON object carrying what the fixed
//! header cannot: request options (`warm`, `top`, `exclude_seen`) and
//! response extras (`model`, `residuals`, `warm` counters, `secs`).
//! The declared total length is validated against the shared
//! [`MAX_FRAME_BYTES`] cap **before any payload allocation** — a
//! hostile header with `rows = cols = u32::MAX` is a one-line protocol
//! error, never a 64 GiB allocation or a hung read.
//!
//! ## Negotiation
//!
//! Binary framing is strictly opt-in per connection: a client sends the
//! JSON line `{"op": "hello", "proto": 2}` and the peer answers
//! `{"ok": true, "proto": 2}` (or the highest version it speaks).
//! Without that hello the connection is byte-for-byte the v1 NDJSON
//! protocol, so every pre-v2 client keeps working unchanged. After the
//! hello, frames beginning with the magic byte `P` are binary and
//! everything else is still a newline-delimited JSON line — sparse-row
//! queries and control ops (`stats`/`ping`/`load`/`shutdown`) never
//! leave JSON, and error responses to binary requests come back as
//! JSON lines (no JSON value starts with `P`, so the two framings
//! cannot be confused).
//!
//! What rides binary: `transform`/`recommend` dense query batches, the
//! `transform` response matrix (the two payloads that actually scale
//! with batch size), and `update` dense data batches (`0x05` — online
//! factor updates; the response is a small JSON line). `recommend`
//! responses are top-N pairs — small — and stay JSON even on a v2
//! connection.
//!
//! ## Training ops (distributed HALS / MU)
//!
//! `plnmf train-dist` reuses the same framing for its coordinator ↔
//! worker traffic: `0x03 shard-load` ships a CSR shard (as nnz×3
//! triplet rows) or a resident H panel, `0x04 sweep` broadcasts the
//! current W panel and asks for one local HALS half-sweep, `0x06
//! sweep-mu` is the multiplicative-update twin of `0x04` (Frobenius or
//! KL, selected by the meta), `0x07 grid-sweep-a` / `0x08 grid-sweep-b`
//! are the two rounds of a pr×pc-grid epoch (round A ships a W row
//! panel and collects the block's AᵀW partial; round B ships the k×k
//! Gram plus the reduced partial and collects the updated panel's
//! products), and `0x83 gram-response` carries the worker's k×k Gram
//! plus its partial product (and, at sync epochs, its H panel) stacked
//! row-wise. These ops are coordinator-private: they are **not**
//! routable requests ([`BinOp::is_request`] is false), so the serving
//! router refuses to relay them and a training worker is always driven
//! point-to-point.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail};

use crate::util::json::Json;
use crate::{Elem, Result};

/// Hard cap on one protocol frame (request or response), shared by the
/// NDJSON line reader and the binary frame reader. A peer that declares
/// or streams more than this gets a protocol error and the connection
/// closed — never unbounded buffering or a hung read loop. 64 MiB
/// clears the largest dense batch the bench ships by two orders of
/// magnitude.
pub const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

/// First bytes of every binary frame.
pub const PLNB_MAGIC: [u8; 4] = *b"PLNB";

/// Binary frame format version.
pub const PLNB_VERSION: u8 = 2;

/// Fixed header size of a binary frame.
pub const HEADER_LEN: usize = 20;

/// Highest protocol version this build negotiates via `hello`.
pub const PROTO_MAX: u64 = 2;

/// Operation byte of a binary frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    /// Dense transform request (client → daemon).
    Transform = 0x01,
    /// Dense recommend request (client → daemon; the response is JSON).
    Recommend = 0x02,
    /// Training: load a dataset shard / factor panel onto a worker
    /// (coordinator → worker; the ack is a JSON line).
    ShardLoad = 0x03,
    /// Training: broadcast the W panel and run one local HALS
    /// half-sweep (coordinator → worker).
    Sweep = 0x04,
    /// Online factor update: fold a dense batch of new data rows into a
    /// served model's factors and publish the next factor epoch
    /// (client → daemon; the response is a small JSON line).
    Update = 0x05,
    /// Training: broadcast the W panel and run one local multiplicative
    /// half-sweep — Frobenius or KL, selected by the frame meta
    /// (coordinator → worker).
    SweepMu = 0x06,
    /// Training, 2D grid, round A: ship the worker's W row panel and
    /// collect its block's AᵀW partial product (coordinator → worker).
    GridSweepA = 0x07,
    /// Training, 2D grid, round B: ship the k×k W Gram stacked over the
    /// reduced AᵀW partial; the worker updates its H panel and returns
    /// its products (coordinator → worker).
    GridSweepB = 0x08,
    /// Transform response carrying the h matrix (daemon → client).
    TransformResp = 0x81,
    /// Training response carrying Gram + partial-product (+ H panel)
    /// stacked row-wise (worker → coordinator).
    GramResp = 0x83,
}

impl BinOp {
    pub fn from_byte(b: u8) -> Option<BinOp> {
        match b {
            0x01 => Some(BinOp::Transform),
            0x02 => Some(BinOp::Recommend),
            0x03 => Some(BinOp::ShardLoad),
            0x04 => Some(BinOp::Sweep),
            0x05 => Some(BinOp::Update),
            0x06 => Some(BinOp::SweepMu),
            0x07 => Some(BinOp::GridSweepA),
            0x08 => Some(BinOp::GridSweepB),
            0x81 => Some(BinOp::TransformResp),
            0x83 => Some(BinOp::GramResp),
            _ => None,
        }
    }

    /// Whether this op is a request the router may **load-balance** to
    /// one replica (both data requests are idempotent — pure reads of
    /// model state). Training ops mutate worker-resident shard state,
    /// so the router must never relay them: the train-dist coordinator
    /// owns its workers point-to-point. [`BinOp::Update`] is also
    /// deliberately NOT a routable request — it mutates factors, so the
    /// router handles it through a separate every-replica fan-out path
    /// with a zero retry budget, never the least-loaded/retry machinery.
    pub fn is_request(self) -> bool {
        matches!(self, BinOp::Transform | BinOp::Recommend)
    }
}

/// A fully decoded binary frame.
pub struct BinFrame {
    pub op: BinOp,
    /// Model name (empty in responses).
    pub model: String,
    /// The JSON meta segment ([`Json::Null`] when absent).
    pub meta: Json,
    pub rows: usize,
    pub cols: usize,
    /// Row-major rows×cols payload.
    pub data: Vec<Elem>,
}

/// Validate a fixed header and return the frame's total declared length
/// (header included). Computed in u128 so a hostile `rows*cols` can
/// never overflow before the cap check.
fn declared_len(header: &[u8; HEADER_LEN]) -> std::result::Result<u128, String> {
    if header[..4] != PLNB_MAGIC {
        return Err(format!(
            "bad binary frame magic {:?} (expected \"PLNB\")",
            &header[..4]
        ));
    }
    if header[4] != PLNB_VERSION {
        return Err(format!(
            "unsupported PLNB version {} (this daemon speaks {PLNB_VERSION})",
            header[4]
        ));
    }
    if BinOp::from_byte(header[5]).is_none() {
        return Err(format!("unknown PLNB op 0x{:02x}", header[5]));
    }
    let name_len = u16::from_le_bytes([header[6], header[7]]) as u128;
    let meta_len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]) as u128;
    let rows = u32::from_le_bytes([header[12], header[13], header[14], header[15]]) as u128;
    let cols = u32::from_le_bytes([header[16], header[17], header[18], header[19]]) as u128;
    Ok(HEADER_LEN as u128 + name_len + meta_len + rows * cols * 4)
}

/// Encode one binary frame. `data` is the row-major rows×cols payload;
/// the frame is rejected (not truncated) when any segment overflows its
/// header field or the total exceeds [`MAX_FRAME_BYTES`].
pub fn encode(
    op: BinOp,
    model: &str,
    meta: &Json,
    rows: usize,
    cols: usize,
    data: &[Elem],
) -> Result<Vec<u8>> {
    if rows.checked_mul(cols) != Some(data.len()) {
        bail!("PLNB encode: {rows}x{cols} frame with {} data values", data.len());
    }
    if rows > u32::MAX as usize || cols > u32::MAX as usize {
        bail!("PLNB encode: shape {rows}x{cols} does not fit the u32 header fields");
    }
    let name = model.as_bytes();
    if name.len() > u16::MAX as usize {
        bail!("PLNB encode: model name is {} bytes (max {})", name.len(), u16::MAX);
    }
    let meta_s = if meta.is_null() { String::new() } else { meta.to_string() };
    if meta_s.len() > u32::MAX as usize {
        bail!("PLNB encode: meta segment is {} bytes (max {})", meta_s.len(), u32::MAX);
    }
    let total =
        HEADER_LEN as u128 + name.len() as u128 + meta_s.len() as u128 + data.len() as u128 * 4;
    if total > MAX_FRAME_BYTES as u128 {
        bail!("PLNB encode: frame would be {total} bytes, over the {MAX_FRAME_BYTES}-byte cap");
    }
    let mut out = Vec::with_capacity(total as usize);
    out.extend_from_slice(&PLNB_MAGIC);
    out.push(PLNB_VERSION);
    out.push(op as u8);
    out.extend_from_slice(&(name.len() as u16).to_le_bytes());
    out.extend_from_slice(&(meta_s.len() as u32).to_le_bytes());
    out.extend_from_slice(&(rows as u32).to_le_bytes());
    out.extend_from_slice(&(cols as u32).to_le_bytes());
    out.extend_from_slice(name);
    out.extend_from_slice(meta_s.as_bytes());
    for &x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    Ok(out)
}

/// Decode one complete binary frame (as produced by [`encode`] or read
/// off the wire by the framed reader).
pub fn decode(bytes: &[u8]) -> Result<BinFrame> {
    let header = header_of(bytes)?;
    let total = declared_len(header).map_err(|e| anyhow!("{e}"))?;
    if total != bytes.len() as u128 {
        bail!(
            "PLNB frame length mismatch: header declares {total} bytes, frame is {}",
            bytes.len()
        );
    }
    let op = BinOp::from_byte(header[5]).expect("declared_len validated the op");
    let name_len = u16::from_le_bytes([header[6], header[7]]) as usize;
    let meta_len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]) as usize;
    let rows = u32::from_le_bytes([header[12], header[13], header[14], header[15]]) as usize;
    let cols = u32::from_le_bytes([header[16], header[17], header[18], header[19]]) as usize;
    let name_end = HEADER_LEN + name_len;
    let meta_end = name_end + meta_len;
    let model = std::str::from_utf8(&bytes[HEADER_LEN..name_end])
        .map_err(|_| anyhow!("invalid utf-8 in PLNB model name"))?
        .to_string();
    let meta = if meta_len == 0 {
        Json::Null
    } else {
        let s = std::str::from_utf8(&bytes[name_end..meta_end])
            .map_err(|_| anyhow!("invalid utf-8 in PLNB meta segment"))?;
        Json::parse(s.trim()).map_err(|e| anyhow!("bad PLNB meta JSON: {e}"))?
    };
    let mut data = Vec::with_capacity(rows * cols);
    for chunk in bytes[meta_end..].chunks_exact(4) {
        data.push(Elem::from_le_bytes(chunk.try_into().expect("chunks_exact(4)")));
    }
    Ok(BinFrame { op, model, meta, rows, cols, data })
}

/// Routing peek: op byte and model name of a complete frame, without
/// touching the meta or data segments — what the router needs to pick a
/// shard before relaying the bytes untouched.
pub fn peek_route(bytes: &[u8]) -> Result<(BinOp, &str)> {
    let header = header_of(bytes)?;
    declared_len(header).map_err(|e| anyhow!("{e}"))?;
    let op = BinOp::from_byte(header[5]).expect("declared_len validated the op");
    let name_len = u16::from_le_bytes([header[6], header[7]]) as usize;
    if bytes.len() < HEADER_LEN + name_len {
        bail!("PLNB frame truncated inside the model name");
    }
    let model = std::str::from_utf8(&bytes[HEADER_LEN..HEADER_LEN + name_len])
        .map_err(|_| anyhow!("invalid utf-8 in PLNB model name"))?;
    Ok((op, model))
}

fn header_of(bytes: &[u8]) -> Result<&[u8; HEADER_LEN]> {
    if bytes.len() < HEADER_LEN {
        bail!("PLNB frame truncated: {} bytes (header is {HEADER_LEN})", bytes.len());
    }
    Ok(bytes[..HEADER_LEN].try_into().expect("length checked"))
}

// ---------------------------------------------------------------------------
// Framed connection I/O (shared by daemon, router, and client).
// ---------------------------------------------------------------------------

/// One complete protocol frame, either framing.
pub(crate) enum WirePayload {
    /// A newline-delimited JSON line (without its newline).
    Line(String),
    /// A complete binary frame, header included — relayed bytes-
    /// untouched by the router.
    Binary(Vec<u8>),
}

impl WirePayload {
    /// Write the frame in its wire form (lines get their newline back).
    pub(crate) fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        match self {
            WirePayload::Line(s) => write_line(w, s),
            WirePayload::Binary(b) => w.write_all(b),
        }
    }
}

/// Write one newline-terminated line as a SINGLE `write_all` — two
/// writes (body, then a lone `\n`) would let Nagle hold the newline
/// back until the body's ACK on a real network, stalling the peer's
/// frame completion by a delayed-ACK interval.
pub(crate) fn write_line(w: &mut impl Write, line: &str) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(line.len() + 1);
    buf.extend_from_slice(line.as_bytes());
    buf.push(b'\n');
    w.write_all(&buf)
}

/// Outcome of one bounded frame read.
pub(crate) enum WireRead {
    /// A complete frame.
    Payload(WirePayload),
    /// The stream ended mid-frame after this many bytes. NOT a complete
    /// frame — the peer died, and treating the bytes as an answer would
    /// hand a truncated response to a caller as if it were whole.
    Partial(usize),
    /// The frame exceeds (or declares more than) the byte cap; the
    /// payload carries how many bytes were read or declared.
    TooLong(usize),
    /// A malformed frame: invalid UTF-8 in a line (the frame boundary
    /// is still intact — non-fatal), or a broken binary header (no
    /// resync possible — fatal).
    Bad { msg: String, fatal: bool },
    /// Clean end of stream before any byte of a new frame.
    Eof,
}

/// Read one protocol frame with a byte cap — the codec underneath the
/// daemon, the router, and the protocol client. With `binary` set
/// (a negotiated v2 connection), a frame starting with the magic byte
/// `P` is read as a length-prefixed binary frame; everything else is a
/// newline-delimited line, exactly as v1.
pub(crate) fn read_wire(
    r: &mut impl BufRead,
    max: usize,
    binary: bool,
) -> std::io::Result<WireRead> {
    let first = {
        let chunk = r.fill_buf()?;
        if chunk.is_empty() {
            return Ok(WireRead::Eof);
        }
        chunk[0]
    };
    if binary && first == PLNB_MAGIC[0] {
        read_binary_frame(r, max)
    } else {
        read_line_frame(r, max)
    }
}

fn read_line_frame(r: &mut impl BufRead, max: usize) -> std::io::Result<WireRead> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = r.fill_buf()?;
        if chunk.is_empty() {
            return Ok(if buf.is_empty() {
                WireRead::Eof
            } else {
                WireRead::Partial(buf.len())
            });
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => {
                buf.extend_from_slice(&chunk[..i]);
                r.consume(i + 1);
                if buf.len() > max {
                    return Ok(WireRead::TooLong(buf.len()));
                }
                // A frame that is not UTF-8 is answered with a distinct
                // protocol error instead of being lossily converted and
                // parsed as if the peer had sent replacement chars.
                return Ok(match String::from_utf8(buf) {
                    Ok(s) => WireRead::Payload(WirePayload::Line(s)),
                    Err(e) => WireRead::Bad {
                        msg: format!(
                            "invalid utf-8 in frame ({} bytes)",
                            e.as_bytes().len()
                        ),
                        fatal: false,
                    },
                });
            }
            None => {
                let n = chunk.len();
                buf.extend_from_slice(chunk);
                r.consume(n);
                if buf.len() > max {
                    return Ok(WireRead::TooLong(buf.len()));
                }
            }
        }
    }
}

fn read_binary_frame(r: &mut impl BufRead, max: usize) -> std::io::Result<WireRead> {
    let mut header = [0u8; HEADER_LEN];
    if let Some(got) = fill_exact(r, &mut header)? {
        return Ok(WireRead::Partial(got));
    }
    let total = match declared_len(&header) {
        Ok(n) => n,
        // A broken header torpedoes the framing: there is no newline to
        // resync on, so the connection must close.
        Err(msg) => return Ok(WireRead::Bad { msg, fatal: true }),
    };
    if total > max as u128 {
        // Checked BEFORE any payload allocation: a hostile length never
        // becomes a giant Vec.
        return Ok(WireRead::TooLong(total.min(usize::MAX as u128) as usize));
    }
    let mut frame = vec![0u8; total as usize];
    frame[..HEADER_LEN].copy_from_slice(&header);
    if let Some(got) = fill_exact(r, &mut frame[HEADER_LEN..])? {
        return Ok(WireRead::Partial(HEADER_LEN + got));
    }
    Ok(WireRead::Payload(WirePayload::Binary(frame)))
}

/// Fill `buf` from `r`: `Ok(None)` when filled, `Ok(Some(n))` when the
/// stream ended after `n` bytes.
fn fill_exact(r: &mut impl BufRead, buf: &mut [u8]) -> std::io::Result<Option<usize>> {
    let mut filled = 0;
    while filled < buf.len() {
        let chunk = r.fill_buf()?;
        if chunk.is_empty() {
            return Ok(Some(filled));
        }
        let n = chunk.len().min(buf.len() - filled);
        buf[filled..filled + n].copy_from_slice(&chunk[..n]);
        r.consume(n);
        filled += n;
    }
    Ok(None)
}

// ---------------------------------------------------------------------------
// The shared per-connection serve loop.
// ---------------------------------------------------------------------------

/// Per-connection protocol state. Every connection starts at v1; a
/// `hello` op upgrades it (see [`handle_hello`]).
pub(crate) struct ConnState {
    pub proto: u8,
}

pub(crate) fn ok_obj(mut pairs: Vec<(&str, Json)>) -> Json {
    pairs.insert(0, ("ok", Json::Bool(true)));
    Json::obj(pairs)
}

pub(crate) fn err_json(msg: String) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg))])
}

/// Apply a `hello` negotiation request to the connection: the peer asks
/// for a protocol version and gets the minimum of that and
/// [`PROTO_MAX`]. Identical on the daemon and the router, and legal at
/// any point in a connection's life.
pub(crate) fn handle_hello(req: &Json, conn: &mut ConnState) -> Json {
    match req.get("proto") {
        Json::Null => ok_obj(vec![("proto", Json::num(conn.proto as f64))]),
        v => match v.as_u64() {
            Some(p) if p >= 1 => {
                conn.proto = p.min(PROTO_MAX) as u8;
                ok_obj(vec![("proto", Json::num(conn.proto as f64))])
            }
            _ => err_json(format!("hello needs an integer \"proto\" >= 1, got {v}")),
        },
    }
}

/// The shared per-connection serve loop (daemon and router): bounded
/// frame reads, one response frame per request frame, oversized-frame
/// protocol error + close, empty lines skipped. `dispatch` maps one
/// request frame to `(response frame, is_shutdown)` and may upgrade the
/// connection via the [`ConnState`] (a `hello` op); binary frames are
/// only recognized once `proto >= 2`. On shutdown the loop wakes the
/// accept loop at `wake_addr` so it observes the stop flag, then
/// closes. A `Partial` read means the peer died mid-frame — nothing to
/// answer.
pub(crate) fn serve_wire(
    stream: TcpStream,
    requests: &AtomicU64,
    wake_addr: SocketAddr,
    mut dispatch: impl FnMut(&WirePayload, &mut ConnState) -> (WirePayload, bool),
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut conn = ConnState { proto: 1 };
    loop {
        match read_wire(&mut reader, MAX_FRAME_BYTES, conn.proto >= 2) {
            Ok(WireRead::Payload(payload)) => {
                if matches!(&payload, WirePayload::Line(l) if l.trim().is_empty()) {
                    continue;
                }
                requests.fetch_add(1, Ordering::SeqCst);
                let (resp, is_shutdown) = dispatch(&payload, &mut conn);
                if resp.write_to(&mut writer).is_err() {
                    break;
                }
                if is_shutdown {
                    let _ = TcpStream::connect(wake_addr);
                    break;
                }
            }
            Ok(WireRead::TooLong(n)) => {
                requests.fetch_add(1, Ordering::SeqCst);
                let resp = WirePayload::Line(
                    err_json(format!(
                        "request frame exceeds {MAX_FRAME_BYTES} bytes ({n} read or \
                         declared); closing connection"
                    ))
                    .to_string(),
                );
                let _ = resp.write_to(&mut writer);
                break;
            }
            Ok(WireRead::Bad { msg, fatal }) => {
                requests.fetch_add(1, Ordering::SeqCst);
                let resp = WirePayload::Line(err_json(msg).to_string());
                if resp.write_to(&mut writer).is_err() || fatal {
                    break;
                }
            }
            Ok(WireRead::Partial(_)) | Ok(WireRead::Eof) | Err(_) => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn feed(src: &[u8], max: usize, binary: bool) -> Vec<WireRead> {
        let mut r = BufReader::new(Cursor::new(src.to_vec()));
        let mut out = Vec::new();
        loop {
            match read_wire(&mut r, max, binary).unwrap() {
                WireRead::Eof => break,
                f @ (WireRead::TooLong(_) | WireRead::Bad { fatal: true, .. }) => {
                    out.push(f);
                    break;
                }
                f => out.push(f),
            }
        }
        out
    }

    fn line_of(read: &WireRead) -> &str {
        match read {
            WireRead::Payload(WirePayload::Line(s)) => s,
            _ => panic!("expected a line frame"),
        }
    }

    #[test]
    fn line_frames_split_and_bound_exactly_as_v1() {
        let frames = feed(b"abc\ndef\ntail", 100, false);
        assert_eq!(frames.len(), 3);
        assert_eq!(line_of(&frames[0]), "abc");
        assert_eq!(line_of(&frames[1]), "def");
        assert!(matches!(frames[2], WireRead::Partial(4)), "unterminated tail is partial");
        // Exactly at the cap is fine; one byte over is TooLong.
        assert_eq!(line_of(&feed(b"abcde\n", 5, false)[0]), "abcde");
        assert!(matches!(feed(b"abcdef\n", 5, false)[0], WireRead::TooLong(_)));
        assert!(matches!(feed(b"abcdefgh", 5, false)[0], WireRead::TooLong(_)));
    }

    #[test]
    fn invalid_utf8_line_is_a_distinct_nonfatal_error() {
        let frames = feed(b"{\"op\": \xff\xfe}\nnext\n", 100, false);
        match &frames[0] {
            WireRead::Bad { msg, fatal } => {
                assert!(msg.contains("invalid utf-8 in frame"), "{msg}");
                assert!(!fatal, "a line boundary survives bad utf-8");
            }
            _ => panic!("expected Bad"),
        }
        // The connection resyncs on the newline: the next line parses.
        assert_eq!(line_of(&frames[1]), "next");
    }

    #[test]
    fn binary_roundtrip_preserves_every_field() {
        let meta = Json::obj(vec![("warm", Json::Bool(false)), ("top", Json::num(7.0))]);
        let data: Vec<Elem> = (0..12).map(|i| i as Elem * 0.5 - 2.0).collect();
        let bytes = encode(BinOp::Transform, "news-é", &meta, 3, 4, &data).unwrap();
        assert_eq!(bytes[..4], PLNB_MAGIC);
        let f = decode(&bytes).unwrap();
        assert_eq!(f.op, BinOp::Transform);
        assert_eq!(f.model, "news-é");
        assert_eq!(f.meta, meta);
        assert_eq!((f.rows, f.cols), (3, 4));
        assert_eq!(f.data, data);
        // The routing peek agrees without touching meta/data.
        let (op, model) = peek_route(&bytes).unwrap();
        assert_eq!((op, model), (BinOp::Transform, "news-é"));
        // Empty meta decodes as Null.
        let bytes = encode(BinOp::TransformResp, "", &Json::Null, 0, 0, &[]).unwrap();
        let f = decode(&bytes).unwrap();
        assert!(f.meta.is_null());
        assert_eq!(f.data.len(), 0);
    }

    #[test]
    fn encode_rejects_mismatched_and_oversized_frames() {
        let err = format!(
            "{:#}",
            encode(BinOp::Transform, "m", &Json::Null, 2, 3, &[0.0; 5]).unwrap_err()
        );
        assert!(err.contains("2x3"), "{err}");
        // A frame that would blow the cap is rejected at encode time,
        // before the output buffer is ever allocated.
        let n = MAX_FRAME_BYTES / 4 + 1;
        let data = vec![0.0 as Elem; n];
        let err = format!(
            "{:#}",
            encode(BinOp::Transform, "m", &Json::Null, n, 1, &data).unwrap_err()
        );
        assert!(err.contains("cap"), "{err}");
    }

    #[test]
    fn decode_rejects_corrupt_headers_and_lengths() {
        let good = encode(BinOp::Transform, "m", &Json::Null, 1, 2, &[1.0, 2.0]).unwrap();
        // Truncated.
        assert!(decode(&good[..HEADER_LEN - 1]).is_err());
        assert!(decode(&good[..good.len() - 1]).is_err());
        // Bad magic / version / op.
        let mut bad = good.clone();
        bad[0] = b'Q';
        assert!(decode(&bad).is_err());
        let mut bad = good.clone();
        bad[4] = 9;
        assert!(format!("{:#}", decode(&bad).unwrap_err()).contains("version"));
        let mut bad = good.clone();
        bad[5] = 0x7f;
        assert!(format!("{:#}", decode(&bad).unwrap_err()).contains("unknown PLNB op"));
        // Declared length disagreeing with the actual frame.
        let mut bad = good.clone();
        bad[12] = 2; // rows = 2 while only 1 row of data follows
        assert!(format!("{:#}", decode(&bad).unwrap_err()).contains("length mismatch"));
    }

    #[test]
    fn binary_reader_bounds_declared_length_before_allocating() {
        // rows = cols = u32::MAX declares a ~64 GiB payload; the reader
        // must answer TooLong from the 20 header bytes alone.
        let mut header = Vec::new();
        header.extend_from_slice(&PLNB_MAGIC);
        header.push(PLNB_VERSION);
        header.push(BinOp::Transform as u8);
        header.extend_from_slice(&0u16.to_le_bytes());
        header.extend_from_slice(&0u32.to_le_bytes());
        header.extend_from_slice(&u32::MAX.to_le_bytes());
        header.extend_from_slice(&u32::MAX.to_le_bytes());
        let frames = feed(&header, MAX_FRAME_BYTES, true);
        assert!(matches!(frames[0], WireRead::TooLong(_)));
    }

    #[test]
    fn binary_reader_flags_bad_magic_as_fatal() {
        let frames = feed(b"PXNBxxxxxxxxxxxxxxxxxxxx", 1000, true);
        match &frames[0] {
            WireRead::Bad { msg, fatal } => {
                assert!(msg.contains("magic"), "{msg}");
                assert!(*fatal, "no resync after a broken binary header");
            }
            _ => panic!("expected Bad"),
        }
        // Without negotiation the same bytes are read as a plain line.
        let frames = feed(b"PXNBxxxx\n", 1000, false);
        assert_eq!(line_of(&frames[0]), "PXNBxxxx");
    }

    #[test]
    fn binary_reader_reports_truncation_as_partial() {
        let good = encode(BinOp::Transform, "m", &Json::Null, 2, 2, &[1.0; 4]).unwrap();
        let frames = feed(&good[..10], 1000, true);
        assert!(matches!(frames[0], WireRead::Partial(10)), "mid-header close");
        let frames = feed(&good[..good.len() - 3], 1000, true);
        assert!(matches!(frames[0], WireRead::Partial(_)), "mid-payload close");
        // A complete frame followed by a line still splits correctly.
        let mut both = good.clone();
        both.extend_from_slice(b"{\"op\": \"ping\"}\n");
        let frames = feed(&both, 1000, true);
        assert!(matches!(&frames[0], WireRead::Payload(WirePayload::Binary(b)) if *b == good));
        assert_eq!(line_of(&frames[1]), "{\"op\": \"ping\"}");
    }

    #[test]
    fn update_op_roundtrips_and_is_not_load_balanced() {
        // 0x05 must decode, carry its batch, and stay OUT of is_request:
        // the router fans updates out to every replica itself instead of
        // picking one (a retried-on-another-replica update would leave
        // the fleet at mixed epochs).
        assert_eq!(BinOp::Update as u8, 0x05);
        assert_eq!(BinOp::from_byte(0x05), Some(BinOp::Update));
        assert!(!BinOp::Update.is_request());
        let meta = Json::obj(vec![("sweeps", Json::num(12.0))]);
        let bytes = encode(BinOp::Update, "news", &meta, 2, 4, &[0.5; 8]).unwrap();
        let f = decode(&bytes).unwrap();
        assert_eq!(f.op, BinOp::Update);
        assert_eq!(f.model, "news");
        assert_eq!(f.meta.get("sweeps").as_u64(), Some(12));
        assert_eq!((f.rows, f.cols), (2, 4));
        let (op, model) = peek_route(&bytes).unwrap();
        assert_eq!((op, model), (BinOp::Update, "news"));
    }

    #[test]
    fn training_ops_roundtrip_but_are_not_routable() {
        for (op, byte) in [
            (BinOp::ShardLoad, 0x03u8),
            (BinOp::Sweep, 0x04),
            (BinOp::SweepMu, 0x06),
            (BinOp::GridSweepA, 0x07),
            (BinOp::GridSweepB, 0x08),
            (BinOp::GramResp, 0x83),
        ] {
            assert_eq!(op as u8, byte);
            assert_eq!(BinOp::from_byte(byte), Some(op));
            // The serving router must refuse to forward training ops:
            // they mutate worker-resident state.
            assert!(!op.is_request(), "op 0x{byte:02x} must not be router-forwardable");
            let meta = Json::obj(vec![("epoch", Json::num(3.0))]);
            let bytes = encode(op, "job", &meta, 2, 3, &[1.0; 6]).unwrap();
            let f = decode(&bytes).unwrap();
            assert_eq!(f.op, op);
            assert_eq!(f.meta.get("epoch").as_u64(), Some(3));
            assert_eq!((f.rows, f.cols), (2, 3));
        }
    }

    #[test]
    fn hello_negotiates_up_to_proto_max_and_rejects_garbage() {
        let hello =
            |src: &str, conn: &mut ConnState| handle_hello(&Json::parse(src).unwrap(), conn);
        let mut conn = ConnState { proto: 1 };
        let resp = hello(r#"{"op": "hello", "proto": 2}"#, &mut conn);
        assert_eq!(resp.get("proto").as_u64(), Some(2));
        assert_eq!(conn.proto, 2);
        // Higher than we speak: negotiated down, never up.
        let mut conn = ConnState { proto: 1 };
        let resp = hello(r#"{"op": "hello", "proto": 9}"#, &mut conn);
        assert_eq!(resp.get("proto").as_u64(), Some(2));
        // Explicit v1 stays v1; absent proto just reports the current.
        let mut conn = ConnState { proto: 2 };
        let resp = hello(r#"{"op": "hello", "proto": 1}"#, &mut conn);
        assert_eq!(resp.get("proto").as_u64(), Some(1));
        assert_eq!(conn.proto, 1);
        let mut conn = ConnState { proto: 1 };
        let resp = hello(r#"{"op": "hello"}"#, &mut conn);
        assert_eq!(resp.get("proto").as_u64(), Some(1));
        // Garbage protos are loud errors, and the connection stays v1.
        for bad in [r#"{"proto": 0}"#, r#"{"proto": -2}"#, r#"{"proto": 1.5}"#, r#"{"proto": "x"}"#]
        {
            let mut conn = ConnState { proto: 1 };
            let resp = handle_hello(&Json::parse(bad).unwrap(), &mut conn);
            assert_eq!(resp.get("ok").as_bool(), Some(false), "{bad}");
            assert_eq!(conn.proto, 1, "{bad}");
        }
    }
}

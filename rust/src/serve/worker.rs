//! Worker-process lifecycle for the cross-process shard router.
//!
//! A *worker* is one `plnmf serve` daemon owning exactly one model: its
//! factors, cached Gram, warm cache, and thread pool live in that
//! process's heap and stay hot in that process's caches — the
//! serving-scale analogue of the paper's §5 residency argument, and the
//! same per-model isolation seam `ModelRegistry` draws in-process. This
//! module owns only *local* process supervision:
//!
//! * [`spawn_worker`] — start `plnmf serve` on a single-model manifest
//!   and an assigned port;
//! * [`wait_ready`] — bounded readiness probe (connect + `ping`);
//! * [`ManagedWorker`] — the child handle with crash detection
//!   ([`ManagedWorker::poll_exit`]) and graceful-then-forced shutdown;
//! * [`probe_free_port`] — OS-assigned port allocation for respawns
//!   (a restarted worker always moves to a fresh port: the old one may
//!   sit in `TIME_WAIT`, and the router's table is re-pointed anyway).
//!
//! Everything above this layer addresses workers by `host:port` only
//! (see [`crate::serve::router`]) — a shard served by a process on
//! another host plugs into the same routing table untouched.

use std::net::{SocketAddr, TcpListener};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context};

use crate::serve::registry::{SpecOverride, MANIFEST_FORMAT};
use crate::serve::server::Client;
use crate::util::json::Json;
use crate::Result;

/// How a local worker process is launched.
#[derive(Debug, Clone)]
pub struct WorkerOpts {
    /// The `plnmf` binary to exec (`std::env::current_exe()` for the
    /// `plnmf route` CLI; `env!("CARGO_BIN_EXE_plnmf")` in tests).
    pub binary: PathBuf,
    /// Interface workers bind (`plnmf serve` listens on 127.0.0.1; the
    /// router connects to this host).
    pub host: String,
    /// Directory for the generated single-model manifests the workers
    /// serve from (created on demand).
    pub work_dir: PathBuf,
    /// Extra `plnmf serve` arguments appended verbatim — serving knobs
    /// like `--threads`, `--sweeps`, `--batch`, `--serve_tol`,
    /// `--warm_cache` pass through here.
    pub extra_args: Vec<String>,
}

impl WorkerOpts {
    pub fn new(binary: PathBuf) -> WorkerOpts {
        WorkerOpts {
            binary,
            host: "127.0.0.1".to_string(),
            work_dir: std::env::temp_dir().join(format!("plnmf-route-{}", std::process::id())),
            extra_args: Vec::new(),
        }
    }
}

/// A supervised local worker process.
pub struct ManagedWorker {
    child: Child,
    addr: SocketAddr,
}

impl ManagedWorker {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn pid(&self) -> u32 {
        self.child.id()
    }

    /// Non-blocking crash detection: `Some(status)` once the process
    /// has exited (reaping it), `None` while it is still running.
    pub fn poll_exit(&mut self) -> Option<String> {
        match self.child.try_wait() {
            Ok(Some(status)) => Some(status.to_string()),
            Ok(None) => None,
            Err(e) => Some(format!("wait failed: {e}")),
        }
    }

    /// Hard-kill the process (fault-injection / chaos paths). After
    /// this, [`ManagedWorker::poll_exit`] reports the cached exit
    /// status, so supervisors observe the death exactly like a crash.
    pub fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }

    /// Graceful shutdown: send the protocol `shutdown`, give the
    /// process `deadline` to drain and exit, then SIGKILL as backstop.
    pub fn shutdown(mut self, deadline: Duration) {
        let graceful = Client::connect(self.addr).and_then(|c| {
            c.set_read_timeout(Some(Duration::from_secs(2)))?;
            let mut c = c;
            c.request(&Json::obj(vec![("op", Json::str("shutdown"))]))
                .map_err(anyhow::Error::from)
        });
        if graceful.is_err() {
            // Unreachable worker (already dead or hung): fall through
            // to the kill below.
            crate::debug!("worker {}: graceful shutdown failed", self.addr);
        }
        let end = Instant::now() + deadline;
        while Instant::now() < end {
            if self.child.try_wait().map(|s| s.is_some()).unwrap_or(true) {
                return;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Ask the OS for a currently-free port on `host` (bind-probe). The
/// port is released before returning, so a raced bind by another
/// process is possible — callers treat a worker that dies at startup
/// like any other crash (fresh port on the next restart attempt).
pub fn probe_free_port(host: &str) -> Result<u16> {
    let listener =
        TcpListener::bind((host, 0)).with_context(|| format!("probing a free port on {host}"))?;
    Ok(listener.local_addr().context("reading probed port")?.port())
}

/// Write the single-model manifest a worker serves from and return its
/// path. Regenerated on every (re)spawn so a changed model path is
/// picked up without touching the worker CLI. Each replica gets its own
/// manifest file (`{name}.r{replica}.manifest.json`): replicas of one
/// model restart independently, and two concurrent respawns must never
/// race on one file.
pub fn write_worker_manifest(
    work_dir: &Path,
    name: &str,
    replica: usize,
    model_path: &Path,
    spec: SpecOverride,
) -> Result<PathBuf> {
    std::fs::create_dir_all(work_dir)
        .with_context(|| format!("creating worker dir {work_dir:?}"))?;
    // The model path is resolved against the *fleet* manifest already;
    // make it absolute so the worker manifest's directory is irrelevant.
    let abs = if model_path.is_absolute() {
        model_path.to_path_buf()
    } else {
        std::env::current_dir().context("resolving model path")?.join(model_path)
    };
    let path = work_dir.join(format!("{name}.r{replica}.manifest.json"));
    // The fleet manifest's spec overrides ride along into the worker's
    // single-model manifest — a KL-override entry must spawn a worker
    // that actually projects under KL.
    let mut entry = vec![
        ("name", Json::str(name)),
        ("path", Json::str(abs.display().to_string().as_str())),
    ];
    if let Some(l) = spec.loss {
        entry.push(("loss", Json::str(l.name())));
    }
    if let Some(a) = spec.alpha {
        entry.push(("alpha", Json::num(a)));
    }
    if let Some(r) = spec.l1_ratio {
        entry.push(("l1_ratio", Json::num(r)));
    }
    let body = Json::obj(vec![
        ("format", Json::str(MANIFEST_FORMAT)),
        ("version", Json::num(1.0)),
        ("max_total_nnz", Json::num(0.0)),
        ("models", Json::Arr(vec![Json::obj(entry)])),
    ])
    .pretty();
    std::fs::write(&path, body).with_context(|| format!("writing worker manifest {path:?}"))?;
    Ok(path)
}

/// Spawn one worker on `port` serving `name` from `model_path` (under
/// the entry's serving-spec overrides, if any) as the shard's
/// `replica`-th copy (0-based; every replica serves the model under the
/// same name — the index only keys the manifest file and logs).
pub fn spawn_worker(
    opts: &WorkerOpts,
    name: &str,
    replica: usize,
    model_path: &Path,
    spec: SpecOverride,
    port: u16,
) -> Result<ManagedWorker> {
    let manifest = write_worker_manifest(&opts.work_dir, name, replica, model_path, spec)?;
    let child = Command::new(&opts.binary)
        .arg("serve")
        .arg("--models_manifest")
        .arg(&manifest)
        .arg("--serve_port")
        .arg(port.to_string())
        .args(&opts.extra_args)
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::inherit())
        .spawn()
        .with_context(|| format!("spawning worker '{name}' ({:?})", opts.binary))?;
    let addr: SocketAddr = format!("{}:{port}", opts.host)
        .parse()
        .map_err(|e| anyhow!("worker '{name}': bad address: {e}"))?;
    crate::info!("worker '{name}' replica {replica}: spawned pid {} on {addr}", child.id());
    Ok(ManagedWorker { child, addr })
}

/// Spawn one *training* worker on `port`: a `plnmf serve` daemon with
/// zero serving models (`--train_worker`) whose only job is to host
/// dataset shards and answer `shard-load` / `sweep` frames for the
/// distributed-training coordinator ([`crate::dist`]). No manifest is
/// written — training workers receive all state over the wire.
pub fn spawn_train_worker(binary: &Path, host: &str, port: u16) -> Result<ManagedWorker> {
    let child = Command::new(binary)
        .arg("serve")
        .arg("--train_worker")
        .arg("--serve_port")
        .arg(port.to_string())
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::inherit())
        .spawn()
        .with_context(|| format!("spawning train worker ({binary:?})"))?;
    let addr: SocketAddr = format!("{host}:{port}")
        .parse()
        .map_err(|e| anyhow!("train worker: bad address: {e}"))?;
    crate::info!("train worker: spawned pid {} on {addr}", child.id());
    Ok(ManagedWorker { child, addr })
}

/// Block until the worker answers `ping` on `addr` (bounded by
/// `deadline`). Fails fast if the process exits first — a worker that
/// cannot bind its port or load its model dies immediately, and waiting
/// out the full deadline would only slow the restart backoff loop.
pub fn wait_ready(worker: &mut ManagedWorker, deadline: Duration) -> Result<()> {
    let end = Instant::now() + deadline;
    let addr = worker.addr;
    loop {
        if let Some(status) = worker.poll_exit() {
            bail!("worker on {addr} exited during startup ({status})");
        }
        if let Ok(client) = Client::connect(addr) {
            let _ = client.set_read_timeout(Some(Duration::from_secs(2)));
            let mut client = client;
            if let Ok(resp) = client.request(&Json::obj(vec![("op", Json::str("ping"))])) {
                if resp.get("pong").as_bool() == Some(true) {
                    return Ok(());
                }
            }
        }
        if Instant::now() >= end {
            bail!("worker on {addr} not ready within {deadline:?}");
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_returns_bindable_port() {
        let p = probe_free_port("127.0.0.1").unwrap();
        assert!(p > 0);
        // Immediately bindable (the probe released it).
        TcpListener::bind(("127.0.0.1", p)).unwrap();
    }

    #[test]
    fn worker_manifest_is_single_model_and_absolute() {
        let dir = std::env::temp_dir().join(format!("plnmf-workerman-{}", std::process::id()));
        let none = SpecOverride::default();
        let path =
            write_worker_manifest(&dir, "news", 0, Path::new("/models/news.json"), none).unwrap();
        let m = crate::serve::Manifest::load(&path).unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.models.len(), 1);
        assert_eq!(m.models[0].name, "news");
        assert_eq!(m.models[0].path, Path::new("/models/news.json"));
        assert!(m.models[0].spec.is_none(), "no override keys for a default spec");
        // Replicas of one model write distinct manifest files (respawns
        // of different replicas must never race on one path), and each
        // still serves the model under its undecorated name.
        let path1 =
            write_worker_manifest(&dir, "news", 1, Path::new("/models/news.json"), none).unwrap();
        assert_ne!(path, path1);
        let m1 = crate::serve::Manifest::load(&path1).unwrap();
        assert_eq!(m1.models[0].name, "news");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn worker_manifest_carries_spec_overrides() {
        use crate::nmf::Loss;
        let dir = std::env::temp_dir().join(format!("plnmf-workerspec-{}", std::process::id()));
        let ovr = SpecOverride { loss: Some(Loss::Kl), alpha: Some(0.1), l1_ratio: Some(1.0) };
        let path =
            write_worker_manifest(&dir, "topics", 0, Path::new("/models/t.json"), ovr).unwrap();
        let m = crate::serve::Manifest::load(&path).unwrap();
        assert_eq!(m.models[0].spec, ovr, "overrides round-trip through the worker manifest");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn spawn_failure_surfaces_binary_context() {
        let opts = WorkerOpts::new(PathBuf::from("/definitely/not/a/binary"));
        let err = format!(
            "{:#}",
            spawn_worker(&opts, "m", 0, Path::new("/tmp/m.json"), SpecOverride::default(), 1)
                .unwrap_err()
        );
        assert!(err.contains("spawning worker 'm'"), "{err}");
        std::fs::remove_dir_all(&opts.work_dir).ok();
    }
}

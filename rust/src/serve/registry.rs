//! Sharded multi-model registry for the serving daemon.
//!
//! A [`ModelRegistry`] owns a set of named, independently-loaded
//! [`Projector`]s. Each model is a self-contained serving shard:
//!
//! * its **own thread pool** — the fork/join [`ThreadPool`] is
//!   deliberately non-reentrant, so per-model pools (each sized to a
//!   share of the machine) are what lets two models solve concurrently
//!   without oversubscribing cores;
//! * its **own request queue** — a per-model mutex serializes solves on
//!   that model (the pool saturates internally; queueing a second batch
//!   behind it is strictly better than interleaving), while requests for
//!   *different* models proceed in parallel;
//! * its **own warm cache and stats** — the [`WarmCache`] keys are
//!   fingerprints of query content, meaningless across models.
//!
//! Models come from an explicit [`ModelRegistry::load`] or from a
//! **manifest** — a small JSON file naming the fleet:
//!
//! ```json
//! {
//!   "format": "plnmf-manifest",
//!   "version": 3,
//!   "max_total_nnz": 50000000,
//!   "models": [
//!     {"name": "news", "path": "models/news.json", "replicas": 2},
//!     {"name": "faces", "path": "models/faces.json"}
//!   ]
//! }
//! ```
//!
//! `replicas` (default 1) is consumed by `plnmf route`, which runs that
//! many worker *processes* for the model; this in-process registry
//! ignores it (see [`ManifestModel::replicas`]).
//!
//! Relative model paths resolve against the manifest's directory.
//! [`ModelRegistry::reload_manifest`] re-reads the file and applies it
//! **only when `version` increased** (hot reload: bump the version after
//! editing); models whose file changed on disk are rebuilt, models
//! dropped from the list are unloaded, and in-flight requests on
//! surviving models are never interrupted (entries are `Arc`-shared with
//! their callers).
//!
//! Beyond reload-from-disk, models are updatable **in place**:
//! [`ModelRegistry::update`] folds a batch of new data rows into a
//! model's factors (warm-started NNLS for the mixtures, then HALS W
//! refinement over accumulated sufficient statistics — the
//! limited-internal-memory frame) and atomically publishes the result
//! as factor **epoch N+1** behind the same `Arc` seam the hot-reload
//! path uses: in-flight requests finish on epoch N, new dispatches see
//! N+1, nothing is dropped.
//!
//! Admission is **nnz-aware**: every model is weighed by the non-zero
//! count of its `W` factor, and a budget (`max_total_nnz`, 0 = unlimited)
//! rejects loads that would blow the resident-factor footprint — the
//! §5 data-movement story only holds while the factors actually stay
//! cache/memory resident.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, bail, Context};

use crate::linalg::Mat;
use crate::nmf::products;
use crate::nmf::spec::{EngineSpec, Loss, Solver};
use crate::nmf::Factors;
use crate::parallel::ThreadPool;
use crate::serve::model_io::{load_model, ModelMeta};
use crate::serve::projector::{
    FoldState, ProjectStats, Projector, ProjectorOpts, Queries, WarmCache,
};
use crate::util::json::Json;
use crate::{Elem, Result};

/// Format marker of a manifest file.
pub const MANIFEST_FORMAT: &str = "plnmf-manifest";

/// Upper bound on `replicas` per manifest entry — a typo like
/// `"replicas": 2000` must not fork-bomb the host with worker
/// processes.
pub const MAX_REPLICAS: usize = 64;

/// Field-wise serving-spec overrides a manifest entry may lay on top of
/// the model file's saved [`EngineSpec`] — e.g. serving a Frobenius-
/// trained model with an extra l1 penalty, or forcing the KL projection
/// path. Absent fields keep the file's values.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SpecOverride {
    pub loss: Option<Loss>,
    pub alpha: Option<f64>,
    pub l1_ratio: Option<f64>,
}

impl SpecOverride {
    pub fn is_none(&self) -> bool {
        *self == SpecOverride::default()
    }

    /// The effective serving spec: `base` (the model file's spec) with
    /// this override applied field-wise, re-validated as a whole.
    pub fn apply(&self, mut spec: EngineSpec) -> Result<EngineSpec> {
        if let Some(l) = self.loss {
            spec.loss = l;
            // KL is only reachable through the multiplicative solver.
            if l == Loss::Kl {
                spec.solver = Solver::Mu;
            }
        }
        if let Some(a) = self.alpha {
            spec.alpha = a;
        }
        if let Some(r) = self.l1_ratio {
            spec.l1_ratio = r;
        }
        spec.validate()?;
        Ok(spec)
    }
}

/// One `models[]` entry of a manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestModel {
    pub name: String,
    /// Absolute, or relative to the manifest file's directory.
    pub path: PathBuf,
    /// How many worker processes `plnmf route` runs for this model
    /// (default 1). The in-process registry ignores this — N copies of
    /// one model inside a single heap would share everything anyway;
    /// replication is a property of the *process* topology.
    pub replicas: usize,
    /// Optional serving-spec overrides (`loss`/`alpha`/`l1_ratio` keys
    /// on the entry), applied over the model file's saved spec.
    pub spec: SpecOverride,
}

/// Parsed manifest: the model fleet plus the admission budget.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub version: u64,
    /// Total admitted `W` non-zeros across models (0 = unlimited).
    pub max_total_nnz: usize,
    pub models: Vec<ManifestModel>,
}

impl Manifest {
    pub fn parse(src: &str, base_dir: &Path) -> Result<Manifest> {
        let j = Json::parse(src).map_err(|e| anyhow!("manifest: {e}"))?;
        // Distinguish the three failure shapes loudly: a *missing* key
        // (probably not a manifest at all), a non-string value (malformed
        // manifest), and a wrong marker (some other file format). The
        // old `unwrap_or("")` collapsed the first into a baffling
        // "format '', expected …".
        let format = match j.get("format") {
            Json::Null => bail!(
                "not a plnmf manifest: missing \"format\" key (expected \
                 \"format\": \"{MANIFEST_FORMAT}\")"
            ),
            v => v.as_str().ok_or_else(|| {
                anyhow!("manifest \"format\" must be a string, got {v}")
            })?,
        };
        if format != MANIFEST_FORMAT {
            bail!("not a plnmf manifest (format '{format}', expected '{MANIFEST_FORMAT}')");
        }
        let version = j
            .get("version")
            .as_u64()
            .ok_or_else(|| anyhow!("manifest needs an integer \"version\""))?;
        // Absent means unlimited; present-but-bogus (negative,
        // fractional, overflowing) is a loud error — a typoed budget
        // must never silently become "unlimited".
        let max_total_nnz =
            j.get_usize_or("max_total_nnz", 0).map_err(|e| anyhow!("manifest {e}"))?;
        let entries = j
            .get("models")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest needs a \"models\" array"))?;
        let mut models = Vec::with_capacity(entries.len());
        for (i, e) in entries.iter().enumerate() {
            let name = e
                .get("name")
                .as_str()
                .filter(|s| !s.is_empty())
                .ok_or_else(|| anyhow!("models[{i}] needs a non-empty \"name\""))?;
            let path = e
                .get("path")
                .as_str()
                .ok_or_else(|| anyhow!("models[{i}] ('{name}') needs a \"path\""))?;
            if models.iter().any(|m: &ManifestModel| m.name == name) {
                bail!("manifest lists model '{name}' twice");
            }
            let replicas = match e.get("replicas") {
                Json::Null => 1,
                v => match v.as_usize() {
                    Some(r) if (1..=MAX_REPLICAS).contains(&r) => r,
                    _ => bail!(
                        "models[{i}] ('{name}'): \"replicas\" must be an integer in \
                         1..={MAX_REPLICAS}, got {v}"
                    ),
                },
            };
            // Spec overrides: absent means "keep the model file's
            // value"; present-but-bogus errors loudly at parse time.
            let loss = match e.get("loss") {
                Json::Null => None,
                v => match v.as_str() {
                    Some(s) => Some(Loss::from_str(s).map_err(|err| {
                        anyhow!("models[{i}] ('{name}'): \"loss\": {err}")
                    })?),
                    None => bail!("models[{i}] ('{name}'): \"loss\" must be a string"),
                },
            };
            let alpha = match e.get("alpha") {
                Json::Null => None,
                v => match v.as_f64() {
                    Some(a) if a.is_finite() && a >= 0.0 => Some(a),
                    _ => bail!(
                        "models[{i}] ('{name}'): \"alpha\" must be a finite number >= 0, \
                         got {v}"
                    ),
                },
            };
            let l1_ratio = match e.get("l1_ratio") {
                Json::Null => None,
                v => match v.as_f64() {
                    Some(r) if (0.0..=1.0).contains(&r) => Some(r),
                    _ => bail!(
                        "models[{i}] ('{name}'): \"l1_ratio\" must be a number in [0, 1], \
                         got {v}"
                    ),
                },
            };
            let path = Path::new(path);
            let path =
                if path.is_absolute() { path.to_path_buf() } else { base_dir.join(path) };
            models.push(ManifestModel {
                name: name.to_string(),
                path,
                replicas,
                spec: SpecOverride { loss, alpha, l1_ratio },
            });
        }
        Ok(Manifest { version, max_total_nnz, models })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?}"))?;
        let base = path.parent().unwrap_or(Path::new("."));
        Self::parse(&src, base).with_context(|| format!("parsing manifest {path:?}"))
    }
}

/// Registry configuration.
#[derive(Debug, Clone, Copy)]
pub struct RegistryOpts {
    /// Total worker threads the daemon may use across models.
    pub threads: usize,
    /// Threads per model pool. 0 = `max(1, threads / 2)`, a safe default
    /// for the common one-or-two-model case; `plnmf serve` sets it
    /// explicitly to `threads / fleet_size` so any fleet solves
    /// concurrently without oversubscribing cores.
    pub per_model_threads: usize,
    /// Solver knobs shared by every model's projector.
    pub projector: ProjectorOpts,
    /// Warm cache capacity per model (entries; 0 disables warm starts).
    pub warm_cache: usize,
    /// Admission budget in `W` non-zeros (0 = unlimited). A manifest's
    /// `max_total_nnz` overrides this when set.
    pub max_total_nnz: usize,
    /// HALS W-refinement sweeps per online `update` batch (when the
    /// request doesn't say); see [`ModelRegistry::update`].
    pub update_sweeps: usize,
}

impl Default for RegistryOpts {
    fn default() -> Self {
        RegistryOpts {
            threads: 2,
            per_model_threads: 0,
            projector: ProjectorOpts::default(),
            warm_cache: 256,
            max_total_nnz: 0,
            update_sweeps: 20,
        }
    }
}

/// Sweep/doc counters for one serving bucket (see [`ModelStats`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct BucketStats {
    pub requests: u64,
    pub docs: u64,
    pub micro_batches: u64,
    pub sweeps: u64,
}

impl BucketStats {
    fn record(&mut self, docs: usize, ps: &ProjectStats) {
        self.requests += 1;
        self.docs += docs as u64;
        self.micro_batches += ps.micro_batches as u64;
        self.sweeps += ps.sweeps as u64;
    }

    /// Average sweeps-to-`tol` per micro-batch — the warm-start headline.
    pub fn avg_sweeps(&self) -> f64 {
        if self.micro_batches == 0 {
            0.0
        } else {
            self.sweeps as f64 / self.micro_batches as f64
        }
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("docs", Json::num(self.docs as f64)),
            ("micro_batches", Json::num(self.micro_batches as f64)),
            ("sweeps", Json::num(self.sweeps as f64)),
            ("avg_sweeps", Json::num(self.avg_sweeps())),
        ])
    }
}

/// Per-model serving statistics, bucketed by warm-cache outcome so the
/// `stats` op can show sweeps-to-`tol` with and without warm starts side
/// by side: `cold` = no row hit, `warm` = every row hit, `mixed` = some.
#[derive(Debug, Clone, Copy, Default)]
pub struct ModelStats {
    pub requests: u64,
    pub warm_hits: u64,
    pub warm_misses: u64,
    pub cold: BucketStats,
    pub warm: BucketStats,
    pub mixed: BucketStats,
}

impl ModelStats {
    fn record(&mut self, docs: usize, ps: &ProjectStats) {
        self.requests += 1;
        self.warm_hits += ps.warm_hits as u64;
        self.warm_misses += ps.warm_misses as u64;
        let bucket = if ps.warm_hits > 0 && ps.warm_misses == 0 {
            &mut self.warm
        } else if ps.warm_hits == 0 {
            &mut self.cold
        } else {
            &mut self.mixed
        };
        bucket.record(docs, ps);
    }
}

struct ModelState {
    warm: WarmCache,
    stats: ModelStats,
    /// Online-update sufficient statistics, materialized (V×K) on the
    /// first `update` from the K×K seed retained on the entry, then
    /// carried across epochs as each update publishes a successor.
    fold: Option<FoldState>,
}

/// A loaded, servable model: projector + pool + queue + warm cache.
pub struct ModelEntry {
    name: String,
    path: PathBuf,
    meta: ModelMeta,
    /// Non-zero entries of `W` — the admission weight.
    nnz: usize,
    /// Content fingerprint of the model file at load time (length +
    /// FNV-1a); `None` when the file could not be read back. Mtimes are
    /// not good enough for the reload rebuild test: a rewrite within
    /// mtime granularity — or a file whose metadata read fails — must
    /// still count as changed.
    loaded_fp: Option<u64>,
    /// Factor epoch: bumped each time an online update publishes a
    /// successor entry. Freshly loaded models start at the epoch saved
    /// in the model file (0 for a plain train).
    epoch: u64,
    /// Mixture Gram `H₀ᵀH₀` of the model file's own training mixtures —
    /// the K² -sized seed from which update statistics resume.
    seed_s: Mat,
    /// Training rows behind `seed_s`.
    seed_rows: usize,
    projector: Projector,
    /// Serializes solves on this model: the projector's pool is
    /// fork/join (non-reentrant), so concurrent requests queue here and
    /// run back to back at full pool width.
    state: Mutex<ModelState>,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The factor epoch these factors were published at.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn nnz(&self) -> usize {
        self.nnz
    }

    pub fn projector(&self) -> &Projector {
        &self.projector
    }

    pub fn stats(&self) -> ModelStats {
        self.state.lock().unwrap().stats
    }

    /// Project a batch through this model's queue. `use_warm` is the
    /// caller's wish; it only takes effect when the registry enabled a
    /// warm cache for this model.
    pub fn transform(
        &self,
        q: Queries<'_>,
        use_warm: bool,
    ) -> Result<(Mat, Vec<f64>, ProjectStats)> {
        let docs = q.rows();
        let mut res = vec![0.0f64; docs];
        let mut st = self.state.lock().unwrap();
        let state = &mut *st;
        let warm = if use_warm && state.warm.capacity() > 0 { Some(&mut state.warm) } else { None };
        let (h, ps) = self.projector.project_with(q, Some(&mut res), warm)?;
        state.stats.record(docs, &ps);
        Ok((h, res, ps))
    }

    /// Top-N recommendation through this model's queue.
    pub fn recommend(
        &self,
        q: Queries<'_>,
        top_n: usize,
        exclude_seen: bool,
        use_warm: bool,
    ) -> Result<(Vec<Vec<(u32, Elem)>>, ProjectStats)> {
        let docs = q.rows();
        let mut st = self.state.lock().unwrap();
        let state = &mut *st;
        let warm = if use_warm && state.warm.capacity() > 0 { Some(&mut state.warm) } else { None };
        let (h, ps) = self.projector.project_with(q, None, warm)?;
        let recs = self.projector.recommend_for(q, &h, top_n, exclude_seen)?;
        state.stats.record(docs, &ps);
        Ok((recs, ps))
    }

    pub fn stats_json(&self) -> Json {
        let st = self.state.lock().unwrap();
        let s = st.stats;
        Json::obj(vec![
            ("v", Json::num(self.projector.v() as f64)),
            ("k", Json::num(self.projector.k() as f64)),
            // The *effective* serving spec (file spec + manifest
            // overrides) — clients can see which objective they query.
            ("spec", self.projector.spec().to_json()),
            ("tile", Json::num(self.projector.tile() as f64)),
            ("threads", Json::num(self.projector.threads() as f64)),
            // Kernel backend of this model's pool; structural (identical
            // across replicas), so the router merge keeps the first.
            ("kernels", Json::str(self.projector.kernels_name())),
            ("nnz", Json::num(self.nnz as f64)),
            // Which factor version answers queries right now — clients
            // watch this to confirm an online update took effect.
            ("epoch", Json::num(self.epoch as f64)),
            ("warm_cache_entries", Json::num(st.warm.len() as f64)),
            ("requests", Json::num(s.requests as f64)),
            ("warm_hits", Json::num(s.warm_hits as f64)),
            ("warm_misses", Json::num(s.warm_misses as f64)),
            ("cold", s.cold.to_json()),
            ("warm", s.warm.to_json()),
            ("mixed", s.mixed.to_json()),
        ])
    }
}

/// The registry proper. Cheap reads (request dispatch) take the `models`
/// read lock only long enough to clone an `Arc`; loads build the new
/// projector outside any lock.
pub struct ModelRegistry {
    opts: RegistryOpts,
    models: RwLock<HashMap<String, Arc<ModelEntry>>>,
    manifest_path: Option<PathBuf>,
    /// (applied manifest version, effective admission budget).
    control: Mutex<(u64, usize)>,
}

impl ModelRegistry {
    /// An empty registry; models arrive via [`Self::load`].
    pub fn new(opts: RegistryOpts) -> ModelRegistry {
        ModelRegistry {
            control: Mutex::new((0, opts.max_total_nnz)),
            opts,
            models: RwLock::new(HashMap::new()),
            manifest_path: None,
        }
    }

    /// Load every model of a manifest; fails if any model fails.
    pub fn from_manifest(path: &Path, opts: RegistryOpts) -> Result<ModelRegistry> {
        let manifest = Manifest::load(path)?;
        Self::from_loaded(&manifest, path, opts)
    }

    /// [`Self::from_manifest`] for an already-parsed manifest — callers
    /// that pre-read it (e.g. to size thread pools from the fleet) avoid
    /// a second read racing a concurrent manifest edit. `path` is kept
    /// for hot reloads.
    pub fn from_loaded(
        manifest: &Manifest,
        path: &Path,
        opts: RegistryOpts,
    ) -> Result<ModelRegistry> {
        let mut reg = ModelRegistry::new(opts);
        reg.manifest_path = Some(path.to_path_buf());
        if manifest.max_total_nnz > 0 {
            reg.control.lock().unwrap().1 = manifest.max_total_nnz;
        }
        for m in &manifest.models {
            reg.load_with(&m.name, &m.path, m.spec)
                .with_context(|| format!("manifest model '{}'", m.name))?;
        }
        reg.control.lock().unwrap().0 = manifest.version;
        Ok(reg)
    }

    fn per_model_threads(&self) -> usize {
        if self.opts.per_model_threads > 0 {
            self.opts.per_model_threads
        } else {
            (self.opts.threads / 2).max(1)
        }
    }

    /// The applied manifest version (0 when no manifest is attached).
    pub fn manifest_version(&self) -> u64 {
        self.control.lock().unwrap().0
    }

    /// Effective admission budget (0 = unlimited).
    pub fn admission_budget(&self) -> usize {
        self.control.lock().unwrap().1
    }

    /// Total admitted `W` non-zeros across loaded models.
    pub fn total_nnz(&self) -> usize {
        self.models.read().unwrap().values().map(|e| e.nnz).sum()
    }

    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.models.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.read().unwrap().is_empty()
    }

    pub fn get(&self, name: &str) -> Result<Arc<ModelEntry>> {
        // Bind before ok_or_else: the closure re-locks via names(), and
        // std read locks are not guaranteed reentrant.
        let entry = self.models.read().unwrap().get(name).cloned();
        entry.ok_or_else(|| {
            anyhow!("no model '{name}' loaded (have: {})", self.names().join(", "))
        })
    }

    /// Load (or replace) a named model from a `plnmf-model` file.
    /// Admission: rejected if the model's `W` non-zeros would push the
    /// registry past its budget.
    pub fn load(&self, name: &str, path: &Path) -> Result<Arc<ModelEntry>> {
        self.load_with(name, path, SpecOverride::default())
    }

    /// [`Self::load`] with manifest-entry spec overrides applied over
    /// the model file's saved spec; the resulting spec picks the
    /// projection path (tiled HALS / regularized NNLS / KL).
    pub fn load_with(
        &self,
        name: &str,
        path: &Path,
        ovr: SpecOverride,
    ) -> Result<Arc<ModelEntry>> {
        if name.is_empty() {
            bail!("model name must be non-empty");
        }
        let (factors, meta) =
            load_model(path).with_context(|| format!("loading model '{name}'"))?;
        let spec = ovr
            .apply(meta.spec)
            .with_context(|| format!("serving spec for model '{name}'"))?;
        let Factors { w, h } = factors;
        let nnz = w.data().iter().filter(|&&x| x != 0.0).count();

        // Build the projector before taking any lock (the Gram build is
        // the expensive part); admission is then checked under the same
        // write lock that inserts, so two concurrent loads cannot both
        // read the old resident total and jointly blow the budget.
        let loaded_fp = file_fingerprint(path);
        let pool = Arc::new(ThreadPool::new(self.per_model_threads()));
        let projector = Projector::with_spec(w, pool, self.opts.projector, spec)
            .with_context(|| format!("building projector for '{name}'"))?;
        // The update seed: K×K now, the V×K panel only on first update.
        let seed_s = products::factor_gram(&projector.pool(), &h);
        let epoch = meta.epoch;
        let mut warm = WarmCache::new(self.opts.warm_cache);
        warm.set_salt(epoch);
        let entry = Arc::new(ModelEntry {
            name: name.to_string(),
            path: path.to_path_buf(),
            meta,
            nnz,
            loaded_fp,
            epoch,
            seed_s,
            seed_rows: h.rows(),
            projector,
            state: Mutex::new(ModelState {
                warm,
                stats: ModelStats::default(),
                fold: None,
            }),
        });
        {
            let mut models = self.models.write().unwrap();
            let budget = self.admission_budget();
            if budget > 0 {
                let resident: usize = models
                    .iter()
                    .filter(|(n, _)| n.as_str() != name)
                    .map(|(_, e)| e.nnz)
                    .sum();
                if resident + nnz > budget {
                    bail!(
                        "admission: loading '{name}' ({nnz} W non-zeros) would exceed the \
                         registry budget ({resident} resident of {budget}); unload a model \
                         or raise max_total_nnz"
                    );
                }
            }
            models.insert(name.to_string(), Arc::clone(&entry));
        }
        crate::info!(
            "registry: loaded '{name}' from {path:?} (V={}, K={}, nnz={nnz})",
            entry.projector.v(),
            entry.projector.k()
        );
        Ok(entry)
    }

    pub fn unload(&self, name: &str) -> Result<()> {
        match self.models.write().unwrap().remove(name) {
            Some(_) => {
                crate::info!("registry: unloaded '{name}'");
                Ok(())
            }
            None => bail!("no model '{name}' loaded"),
        }
    }

    /// Re-read the attached manifest and apply it if its `version`
    /// increased: load new names, rebuild entries whose path or file
    /// mtime changed, unload names no longer listed. Returns whether a
    /// reload happened. Without an attached manifest this is a no-op.
    ///
    /// A version is **attempted at most once**: it is recorded before
    /// the fleet changes, so a manifest with a broken entry does not
    /// re-run its (expensive, partially-destructive) apply on every
    /// poll. A failed apply can leave the fleet partial — de-listed
    /// models already unloaded, later models not yet loaded; the error
    /// is surfaced to the caller (daemon log / `load` op response), and
    /// the operator republishes a fixed manifest under a *new* version.
    pub fn reload_manifest(&self) -> Result<bool> {
        let path = match &self.manifest_path {
            Some(p) => p.clone(),
            None => return Ok(false),
        };
        let manifest = Manifest::load(&path)?;
        {
            let mut control = self.control.lock().unwrap();
            if manifest.version <= control.0 {
                return Ok(false);
            }
            control.0 = manifest.version;
            if manifest.max_total_nnz > 0 {
                control.1 = manifest.max_total_nnz;
            }
        }
        // Unload de-listed models FIRST: a budget-constrained swap (drop
        // model X, add similar-weight model Y) must free X's admission
        // weight before Y is weighed. In-flight requests on X finish
        // fine — entries are Arc-shared with their callers.
        let listed: Vec<&str> = manifest.models.iter().map(|m| m.name.as_str()).collect();
        let stale: Vec<String> = {
            let models = self.models.read().unwrap();
            models.keys().filter(|n| !listed.contains(&n.as_str())).cloned().collect()
        };
        for name in stale {
            // Tolerate a concurrent wire `unload` of the same name: the
            // goal is "not loaded", not "was loaded a moment ago".
            if self.models.write().unwrap().remove(&name).is_some() {
                crate::info!("registry: unloaded '{name}' (de-listed by manifest)");
            }
        }
        for m in &manifest.models {
            let needs_load = match self.models.read().unwrap().get(&m.name) {
                None => true,
                Some(e) => {
                    // Content fingerprint, not mtime: an in-place rewrite
                    // within mtime granularity must still rebuild, and an
                    // unreadable file counts as changed so the load path
                    // surfaces the real error loudly.
                    let fp = file_fingerprint(&m.path);
                    e.path != m.path
                        || fp.is_none()
                        || fp != e.loaded_fp
                        // Rebuild when the entry's spec override now
                        // resolves to a different serving spec.
                        || m.spec.apply(e.meta.spec).ok() != Some(e.projector.spec())
                }
            };
            if needs_load {
                self.load_with(&m.name, &m.path, m.spec)
                    .with_context(|| format!("manifest reload: model '{}'", m.name))?;
            }
        }
        crate::info!("registry: applied manifest version {}", manifest.version);
        Ok(true)
    }

    /// Fold a batch of new data rows into a served model's factors and
    /// **atomically publish the result as epoch N+1** — the in-memory
    /// half of hot reload. The solve runs on the model's own queue (so
    /// it serializes with in-flight transforms on the *current* entry,
    /// exactly like a big transform would), the successor projector is
    /// built on the same thread pool, and the swap is a single map
    /// insert: requests that already hold the epoch-N `Arc` finish on
    /// epoch N, every later dispatch sees N+1. Nothing is dropped.
    ///
    /// `sweeps` (W refinement passes over the accumulated statistics)
    /// defaults to [`RegistryOpts::update_sweeps`]. Updates are
    /// in-memory only: the model *file* still holds the trained factors,
    /// and a daemon restart starts over from it — durability comes from
    /// retraining and republishing through the manifest path.
    pub fn update(
        &self,
        name: &str,
        q: Queries<'_>,
        sweeps: Option<usize>,
    ) -> Result<UpdateOutcome> {
        let sweeps = sweeps.unwrap_or(self.opts.update_sweeps);
        let entry = self.get(name)?;
        let docs = q.rows();
        let mut st = entry.state.lock().unwrap();
        let state = &mut *st;
        let mut fold = match state.fold.take() {
            Some(f) => f,
            None => entry
                .projector
                .fold_resume(entry.seed_s.clone(), entry.seed_rows)
                .with_context(|| format!("seeding update statistics for '{name}'"))?,
        };
        let warm = if state.warm.capacity() > 0 { Some(&mut state.warm) } else { None };
        let (w_new, ps) = match entry.projector.fold_in(q, &mut fold, warm, sweeps) {
            Ok(x) => x,
            Err(e) => {
                // fold_in bails before touching the statistics — keep
                // them for the next attempt.
                state.fold = Some(fold);
                return Err(e).with_context(|| format!("updating model '{name}'"));
            }
        };
        state.stats.record(docs, &ps);
        let rows_seen = fold.rows();
        let epoch = entry.epoch + 1;
        let nnz = w_new.data().iter().filter(|&&x| x != 0.0).count();
        let projector = Projector::with_spec(
            w_new,
            entry.projector.pool(),
            self.opts.projector,
            entry.projector.spec(),
        )
        .with_context(|| format!("rebuilding projector for '{name}' at epoch {epoch}"))?;
        // Fresh warm cache salted with the new epoch: stale epoch-N
        // seeds are structurally unreachable (see WarmCache::set_salt).
        let mut warm = WarmCache::new(self.opts.warm_cache);
        warm.set_salt(epoch);
        let successor = Arc::new(ModelEntry {
            name: entry.name.clone(),
            path: entry.path.clone(),
            meta: {
                let mut m = entry.meta.clone();
                m.epoch = epoch;
                m
            },
            nnz,
            loaded_fp: entry.loaded_fp,
            epoch,
            seed_s: entry.seed_s.clone(),
            seed_rows: entry.seed_rows,
            projector,
            state: Mutex::new(ModelState { warm, stats: state.stats, fold: Some(fold) }),
        });
        let published = (|| -> Result<()> {
            let mut models = self.models.write().unwrap();
            match models.get(name) {
                Some(cur) if Arc::ptr_eq(cur, &entry) => {}
                _ => bail!(
                    "model '{name}' was replaced or unloaded mid-update; \
                     discarding the stale result"
                ),
            }
            let budget = self.admission_budget();
            if budget > 0 {
                let resident: usize = models
                    .iter()
                    .filter(|(n, _)| n.as_str() != name)
                    .map(|(_, e)| e.nnz)
                    .sum();
                if resident + nnz > budget {
                    bail!(
                        "admission: updated '{name}' ({nnz} W non-zeros) would exceed \
                         the registry budget ({resident} resident of {budget})"
                    );
                }
            }
            models.insert(name.to_string(), Arc::clone(&successor));
            Ok(())
        })();
        if let Err(e) = published {
            // The successor was never published (we are its only owner)
            // — reclaim the statistics so the next update resumes them.
            state.fold = successor.state.lock().unwrap().fold.take();
            return Err(e);
        }
        crate::info!(
            "registry: published '{name}' epoch {epoch} (+{docs} rows, {rows_seen} total, \
             nnz={nnz})"
        );
        Ok(UpdateOutcome { epoch, rows_seen, stats: ps })
    }

    /// Per-model stats as a JSON object keyed by model name.
    ///
    /// Snapshots the entry list first and drops the registry lock before
    /// touching any per-model state mutex — those are held for whole
    /// solves, and blocking on one while holding the read lock would
    /// stall every load/unload/reload behind a long transform.
    pub fn stats_json(&self) -> Json {
        let entries: Vec<(String, Arc<ModelEntry>)> = {
            let models = self.models.read().unwrap();
            models.iter().map(|(n, e)| (n.clone(), Arc::clone(e))).collect()
        };
        Json::Obj(entries.into_iter().map(|(n, e)| (n, e.stats_json())).collect())
    }
}

/// Outcome of an online [`ModelRegistry::update`].
#[derive(Debug, Clone, Copy)]
pub struct UpdateOutcome {
    /// The factor epoch the update published (predecessor + 1).
    pub epoch: u64,
    /// Total data rows the model's statistics now summarize (training
    /// seed + every folded batch).
    pub rows_seen: usize,
    /// Projection stats of the folded batch.
    pub stats: ProjectStats,
}

/// Content fingerprint of a file: FNV-1a over the bytes, mixed with the
/// length. `None` when the file cannot be read — callers treat that as
/// "changed", so the subsequent load surfaces the real error loudly
/// instead of silently serving stale factors.
pub fn file_fingerprint(path: &Path) -> Option<u64> {
    let bytes = std::fs::read(path).ok()?;
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in &bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    Some(h ^ (bytes.len() as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15))
}

/// Serialize a manifest (helper for tools/tests writing fleets).
/// Every model gets the default single replica; use
/// [`manifest_json_replicated`] to declare replica counts.
pub fn manifest_json(version: u64, max_total_nnz: usize, models: &[(&str, &str)]) -> Json {
    let with_replicas: Vec<(&str, &str, usize)> =
        models.iter().map(|&(name, path)| (name, path, 1)).collect();
    manifest_json_replicated(version, max_total_nnz, &with_replicas)
}

/// [`manifest_json`] with an explicit `(name, path, replicas)` triple
/// per model — the replicated-fleet shape `plnmf route` consumes.
pub fn manifest_json_replicated(
    version: u64,
    max_total_nnz: usize,
    models: &[(&str, &str, usize)],
) -> Json {
    Json::obj(vec![
        ("format", Json::str(MANIFEST_FORMAT)),
        ("version", Json::num(version as f64)),
        ("max_total_nnz", Json::num(max_total_nnz as f64)),
        (
            "models",
            Json::Arr(
                models
                    .iter()
                    .map(|(name, path, replicas)| {
                        Json::obj(vec![
                            ("name", Json::str(*name)),
                            ("path", Json::str(*path)),
                            ("replicas", Json::num(*replicas as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nmf::Factors;
    use crate::serve::model_io::save_model;

    fn tmpdir(name: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("plnmf-registry-{}-{name}", std::process::id()));
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    fn write_model(dir: &Path, file: &str, v: usize, k: usize, seed: u64) -> PathBuf {
        let f = Factors::random(v, 6, k, seed);
        let path = dir.join(file);
        save_model(&path, &f, &ModelMeta::default()).unwrap();
        path
    }

    fn small_opts() -> RegistryOpts {
        RegistryOpts { threads: 2, per_model_threads: 1, ..Default::default() }
    }

    #[test]
    fn load_get_unload_roundtrip() {
        let dir = tmpdir("lgu");
        let p = write_model(&dir, "a.json", 20, 4, 1);
        let reg = ModelRegistry::new(small_opts());
        assert!(reg.is_empty());
        reg.load("a", &p).unwrap();
        assert_eq!(reg.names(), vec!["a"]);
        let e = reg.get("a").unwrap();
        assert_eq!((e.projector().v(), e.projector().k()), (20, 4));
        assert!(e.nnz() > 0);
        assert!(reg.get("b").is_err());
        reg.unload("a").unwrap();
        assert!(reg.unload("a").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn transform_records_stats() {
        let dir = tmpdir("stats");
        let p = write_model(&dir, "a.json", 15, 3, 2);
        let reg = ModelRegistry::new(RegistryOpts {
            projector: ProjectorOpts { sweeps: 50, tol: 1e-6, ..Default::default() },
            ..small_opts()
        });
        let e = reg.load("a", &p).unwrap();
        let q = Mat::from_fn(4, 15, |i, j| ((i * 7 + j) % 5) as Elem);
        let (h, res, ps) = e.transform(Queries::Dense(&q), true).unwrap();
        assert_eq!((h.rows(), h.cols()), (4, 3));
        assert_eq!(res.len(), 4);
        assert_eq!(ps.warm_misses, 4);
        // Repeat: all rows hit, no more sweeps than the cold pass.
        let (_, _, ps2) = e.transform(Queries::Dense(&q), true).unwrap();
        assert_eq!(ps2.warm_hits, 4);
        assert!(ps2.sweeps <= ps.sweeps);
        let s = e.stats();
        assert_eq!(s.requests, 2);
        assert_eq!(s.cold.requests, 1);
        assert_eq!(s.warm.requests, 1);
        assert!(s.warm.avg_sweeps() <= s.cold.avg_sweeps());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn admission_budget_rejects_oversize_loads() {
        let dir = tmpdir("admission");
        let a = write_model(&dir, "a.json", 30, 4, 3);
        let b = write_model(&dir, "b.json", 30, 4, 4);
        let reg = ModelRegistry::new(RegistryOpts {
            max_total_nnz: 150, // one 30x4 dense-random W (~120 nnz) fits
            ..small_opts()
        });
        reg.load("a", &a).unwrap();
        let err = format!("{:#}", reg.load("b", &b).unwrap_err());
        assert!(err.contains("admission"), "{err}");
        // Replacing the resident model under the same name is fine.
        reg.load("a", &b).unwrap();
        assert_eq!(reg.len(), 1);
        // And after unloading there is room again.
        reg.unload("a").unwrap();
        reg.load("b", &b).unwrap();
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn manifest_parse_validates() {
        let base = Path::new("/models");
        let good = r#"{"format": "plnmf-manifest", "version": 2,
            "models": [{"name": "a", "path": "a.json"},
                       {"name": "b", "path": "/abs/b.json"}]}"#;
        let m = Manifest::parse(good, base).unwrap();
        assert_eq!(m.version, 2);
        assert_eq!(m.models[0].path, Path::new("/models/a.json"));
        assert_eq!(m.models[1].path, Path::new("/abs/b.json"));
        assert_eq!(m.models[0].replicas, 1, "replicas defaults to 1");
        for bad in [
            r#"{"format": "other", "version": 1, "models": []}"#,
            r#"{"format": "plnmf-manifest", "models": []}"#,
            r#"{"format": "plnmf-manifest", "version": 1}"#,
            r#"{"format": "plnmf-manifest", "version": 1,
                "models": [{"name": "a", "path": "x"}, {"name": "a", "path": "y"}]}"#,
            r#"{"format": "plnmf-manifest", "version": 1, "models": [{"path": "x"}]}"#,
            // Silent-coercion regression: bogus numbers error loudly.
            r#"{"format": "plnmf-manifest", "version": -1, "models": []}"#,
            r#"{"format": "plnmf-manifest", "version": 1.5, "models": []}"#,
            r#"{"format": "plnmf-manifest", "version": 1e300, "models": []}"#,
        ] {
            assert!(Manifest::parse(bad, base).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn manifest_format_errors_name_the_actual_problem() {
        let base = Path::new("/models");
        // Missing key: the error must say so, not claim "format ''".
        let err = format!(
            "{:#}",
            Manifest::parse(r#"{"version": 1, "models": []}"#, base).unwrap_err()
        );
        assert!(err.contains("missing \"format\" key"), "{err}");
        assert!(!err.contains("format ''"), "must not report an empty format: {err}");
        // Non-string value: a type error, not a marker mismatch.
        let err = format!(
            "{:#}",
            Manifest::parse(r#"{"format": 3, "version": 1, "models": []}"#, base)
                .unwrap_err()
        );
        assert!(err.contains("must be a string"), "{err}");
        // Wrong value: the classic mismatch message, unchanged.
        let err = format!(
            "{:#}",
            Manifest::parse(r#"{"format": "other", "version": 1, "models": []}"#, base)
                .unwrap_err()
        );
        assert!(err.contains("format 'other'"), "{err}");
        assert!(err.contains(MANIFEST_FORMAT), "{err}");
    }

    #[test]
    fn reload_detects_same_mtime_rewrite() {
        // Regression: a model file rewritten in place *with its mtime
        // restored* (or within mtime granularity) must still rebuild on
        // the next manifest version bump — the content fingerprint, not
        // the timestamp, is what decides.
        let dir = tmpdir("samemtime");
        let a = write_model(&dir, "a.json", 20, 3, 5);
        let man = dir.join("manifest.json");
        std::fs::write(&man, manifest_json(1, 0, &[("a", "a.json")]).pretty()).unwrap();
        let reg = ModelRegistry::from_manifest(&man, small_opts()).unwrap();
        let before = reg.get("a").unwrap();

        // Rewrite with different factors, then forge the original mtime.
        let orig_mtime = std::fs::metadata(&a).unwrap().modified().unwrap();
        write_model(&dir, "a.json", 20, 3, 99);
        let f = std::fs::OpenOptions::new().write(true).open(&a).unwrap();
        f.set_times(std::fs::FileTimes::new().set_modified(orig_mtime)).unwrap();
        drop(f);
        assert_eq!(
            std::fs::metadata(&a).unwrap().modified().unwrap(),
            orig_mtime,
            "test setup: mtime must be restored for the regression to bite"
        );

        std::fs::write(&man, manifest_json(2, 0, &[("a", "a.json")]).pretty()).unwrap();
        assert!(reg.reload_manifest().unwrap());
        let after = reg.get("a").unwrap();
        assert!(
            !Arc::ptr_eq(&before, &after),
            "same-mtime rewrite must rebuild the entry"
        );
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn update_publishes_new_epoch_without_touching_in_flight_entries() {
        let dir = tmpdir("update");
        let p = write_model(&dir, "a.json", 20, 4, 11);
        let reg = ModelRegistry::new(RegistryOpts {
            projector: ProjectorOpts { sweeps: 50, ..Default::default() },
            ..small_opts()
        });
        let before = reg.load("a", &p).unwrap();
        assert_eq!(before.epoch(), 0);
        let q = Mat::from_fn(5, 20, |i, j| ((i * 3 + j) % 4) as Elem);
        let h_before = before.transform(Queries::Dense(&q), false).unwrap().0;

        let out = reg.update("a", Queries::Dense(&q), None).unwrap();
        assert_eq!(out.epoch, 1);
        assert_eq!(out.rows_seen, 6 + 5, "training seed rows + folded batch");

        let after = reg.get("a").unwrap();
        assert!(!Arc::ptr_eq(&before, &after), "update must publish a successor");
        assert_eq!(after.epoch(), 1);
        assert_eq!(after.meta().epoch, 1);
        assert!(after.stats_json().to_string().contains("\"epoch\""));
        // The folded data moved the factors: same query, different answer.
        let h_after = after.transform(Queries::Dense(&q), false).unwrap().0;
        assert!(h_before.max_abs_diff(&h_after) > 0.0);
        // The epoch-N entry still answers — in-flight requests holding
        // its Arc are untouched by the swap.
        let h_old = before.transform(Queries::Dense(&q), false).unwrap().0;
        assert_eq!(h_old, h_before);
        // Chained updates keep advancing.
        let out2 = reg.update("a", Queries::Dense(&q), Some(5)).unwrap();
        assert_eq!(out2.epoch, 2);
        assert_eq!(out2.rows_seen, 6 + 5 + 5);
        // Unknown models refuse loudly (the spec gate is covered by the
        // projector's fold_in tests).
        assert!(reg.update("nope", Queries::Dense(&q), None).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn manifest_max_total_nnz_is_strict_when_present() {
        let base = Path::new("/models");
        let ok = r#"{"format": "plnmf-manifest", "version": 1, "max_total_nnz": 500,
            "models": [{"name": "a", "path": "a.json"}]}"#;
        assert_eq!(Manifest::parse(ok, base).unwrap().max_total_nnz, 500);
        // Absent = unlimited…
        let absent = r#"{"format": "plnmf-manifest", "version": 1,
            "models": [{"name": "a", "path": "a.json"}]}"#;
        assert_eq!(Manifest::parse(absent, base).unwrap().max_total_nnz, 0);
        // …but a present bogus budget must never silently become 0
        // (unlimited) — that would quietly disable admission control.
        for bad_nnz in ["-1", "2.7", "1e300", "\"big\""] {
            let bad = format!(
                r#"{{"format": "plnmf-manifest", "version": 1, "max_total_nnz": {bad_nnz},
                    "models": [{{"name": "a", "path": "a.json"}}]}}"#
            );
            let err = format!("{:#}", Manifest::parse(&bad, base).unwrap_err());
            assert!(err.contains("max_total_nnz"), "nnz={bad_nnz}: {err}");
        }
    }

    #[test]
    fn manifest_replicas_parse_and_validate() {
        let base = Path::new("/models");
        let src = r#"{"format": "plnmf-manifest", "version": 1,
            "models": [{"name": "a", "path": "a.json", "replicas": 3},
                       {"name": "b", "path": "b.json"}]}"#;
        let m = Manifest::parse(src, base).unwrap();
        assert_eq!(m.models[0].replicas, 3);
        assert_eq!(m.models[1].replicas, 1);
        // Round-trip through the replicated serializer.
        let json = manifest_json_replicated(1, 0, &[("a", "a.json", 3), ("b", "b.json", 1)]);
        let re = Manifest::parse(&json.to_string(), base).unwrap();
        assert_eq!(re.models[0].replicas, 3);
        assert_eq!(re.models[1].replicas, 1);
        // Degenerate counts are rejected loudly, not clamped.
        for bad_replicas in ["0", "65", "-1", "1.5", "\"two\""] {
            let bad = format!(
                r#"{{"format": "plnmf-manifest", "version": 1,
                    "models": [{{"name": "a", "path": "x", "replicas": {bad_replicas}}}]}}"#
            );
            let err = format!("{:#}", Manifest::parse(&bad, base).unwrap_err());
            assert!(err.contains("replicas"), "replicas={bad_replicas}: {err}");
        }
    }

    #[test]
    fn manifest_spec_overrides_parse_and_reject() {
        let base = Path::new("/models");
        let src = r#"{"format": "plnmf-manifest", "version": 1,
            "models": [{"name": "a", "path": "a.json",
                        "loss": "kl", "alpha": 0.2, "l1_ratio": 0.5},
                       {"name": "b", "path": "b.json"}]}"#;
        let m = Manifest::parse(src, base).unwrap();
        assert_eq!(
            m.models[0].spec,
            SpecOverride { loss: Some(Loss::Kl), alpha: Some(0.2), l1_ratio: Some(0.5) }
        );
        assert!(m.models[1].spec.is_none(), "absent keys leave the file's spec alone");
        for (key, bad) in [
            ("loss", "\"poisson\""),
            ("loss", "3"),
            ("alpha", "-1"),
            ("alpha", "\"big\""),
            ("l1_ratio", "2"),
            ("l1_ratio", "-0.5"),
        ] {
            let src = format!(
                r#"{{"format": "plnmf-manifest", "version": 1,
                    "models": [{{"name": "a", "path": "a.json", "{key}": {bad}}}]}}"#
            );
            let err = format!("{:#}", Manifest::parse(&src, base).unwrap_err());
            assert!(err.contains(key), "{key}={bad}: {err}");
        }
    }

    #[test]
    fn registry_serves_mixed_specs_from_one_manifest() {
        let dir = tmpdir("mixed");
        write_model(&dir, "fro.json", 20, 3, 7);
        write_model(&dir, "kl.json", 20, 3, 8);
        let man = dir.join("manifest.json");
        std::fs::write(
            &man,
            r#"{"format": "plnmf-manifest", "version": 1,
                "models": [{"name": "fro", "path": "fro.json"},
                           {"name": "kl", "path": "kl.json",
                            "loss": "kl", "alpha": 0.1, "l1_ratio": 1.0}]}"#,
        )
        .unwrap();
        let reg = ModelRegistry::from_manifest(&man, small_opts()).unwrap();
        let fro = reg.get("fro").unwrap();
        let kl = reg.get("kl").unwrap();
        assert_eq!(fro.projector().spec(), EngineSpec::default());
        assert_eq!(kl.projector().spec().loss, Loss::Kl);
        assert_eq!(kl.projector().spec().solver, Solver::Mu, "kl forces the mu solver");
        assert!((kl.projector().spec().alpha - 0.1).abs() < 1e-12);
        // Both objectives answer transforms side by side.
        let q = Mat::from_fn(3, 20, |i, j| ((i * 5 + j) % 4) as Elem);
        let (hf, _, _) = fro.transform(Queries::Dense(&q), false).unwrap();
        let (hk, _, _) = kl.transform(Queries::Dense(&q), false).unwrap();
        assert!(hf.data().iter().any(|&x| x > 0.0));
        assert!(hk.data().iter().any(|&x| x > 0.0));
        // Stats echo the *effective* spec per model.
        let stats = kl.stats_json().to_string();
        assert!(stats.contains("\"spec\""), "{stats}");
        assert!(stats.contains("\"kl\""), "{stats}");
        assert!(!fro.stats_json().to_string().contains("\"kl\""));
        // A version bump that only changes an override rebuilds the
        // entry (same file, same mtime).
        std::fs::write(
            &man,
            r#"{"format": "plnmf-manifest", "version": 2,
                "models": [{"name": "fro", "path": "fro.json", "alpha": 0.3},
                           {"name": "kl", "path": "kl.json",
                            "loss": "kl", "alpha": 0.1, "l1_ratio": 1.0}]}"#,
        )
        .unwrap();
        assert!(reg.reload_manifest().unwrap());
        assert!((reg.get("fro").unwrap().projector().spec().alpha - 0.3).abs() < 1e-12);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn manifest_reload_applies_only_on_version_bump() {
        let dir = tmpdir("reload");
        let a = write_model(&dir, "a.json", 20, 3, 5);
        let b = write_model(&dir, "b.json", 18, 3, 6);
        let man = dir.join("manifest.json");
        std::fs::write(&man, manifest_json(1, 0, &[("a", "a.json")]).pretty()).unwrap();

        let reg = ModelRegistry::from_manifest(&man, small_opts()).unwrap();
        assert_eq!(reg.manifest_version(), 1);
        assert_eq!(reg.names(), vec!["a"]);

        // Same version → no-op even though the file now lists b.
        std::fs::write(&man, manifest_json(1, 0, &[("b", "b.json")]).pretty()).unwrap();
        assert!(!reg.reload_manifest().unwrap());
        assert_eq!(reg.names(), vec!["a"]);

        // Version bump → b loads, a unloads.
        std::fs::write(&man, manifest_json(2, 0, &[("b", "b.json")]).pretty()).unwrap();
        assert!(reg.reload_manifest().unwrap());
        assert_eq!(reg.manifest_version(), 2);
        assert_eq!(reg.names(), vec!["b"]);
        assert_eq!(reg.get("b").unwrap().path(), b.as_path());
        drop(a);
        std::fs::remove_dir_all(dir).ok();
    }
}

//! Layer-3 coordinator: the leader process that owns dataset lifecycle,
//! the worker pool, engine selection (native vs PJRT-backed), the
//! convergence loop, and metrics.
//!
//! The PL-NMF paper's "system" is a shared-memory parallel runtime; the
//! pieces here correspond to it directly:
//!
//! * [`driver`] — builds a run from a [`RunConfig`](crate::config::RunConfig)
//!   (dataset → pool → engine) and executes the iterate/record loop.
//! * [`comparison`] — runs several engines from the *same* random init on
//!   the same dataset (the paper's Figs. 7–9 protocol).
//! * [`shard`] — nnz-balanced row partitioning for the skewed (Zipf)
//!   sparse datasets; used by the performance pass to pin static shards.
//! * [`metrics`] — trace/CSV output and timer tables.

pub mod driver;
pub mod comparison;
pub mod shard;
pub mod metrics;

pub use driver::{create_engine, Driver, RunReport};

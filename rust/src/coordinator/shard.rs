//! Load-balanced row sharding for skewed sparse matrices.
//!
//! Bag-of-words matrices are Zipf-skewed: head words carry thousands of
//! non-zeros, tail words a handful. An even *row-count* split can leave
//! one worker with several times the nnz of another; this module
//! partitions rows so each contiguous shard carries ≈ nnz/parts
//! non-zeros. The SpMM path uses dynamic chunking by default; the
//! coordinator's static-shard mode (used where the perf pass wants
//! reproducible placement, and by the Gram reduction) uses these plans.

use std::ops::Range;

use crate::sparse::Csr;

/// Contiguous row ranges whose nnz loads differ by at most one row's
/// worth.
pub fn balanced_row_shards(a: &Csr, parts: usize) -> Vec<Range<usize>> {
    assert!(parts > 0);
    let total = a.nnz();
    let rows = a.rows();
    let row_ptr = a.row_ptr();
    let mut shards = Vec::with_capacity(parts);
    let mut start = 0usize;
    for p in 0..parts {
        // Ideal cumulative boundary after shard p.
        let target = total * (p + 1) / parts;
        // Advance to the first row whose cumulative nnz reaches target.
        let mut end = start;
        while end < rows && row_ptr[end + 1] < target {
            end += 1;
        }
        if end < rows {
            end += 1; // include the boundary row
        }
        // Remaining shards must each get at least 0 rows; last shard
        // takes the tail.
        if p == parts - 1 {
            end = rows;
        }
        shards.push(start..end.min(rows));
        start = end.min(rows);
    }
    debug_assert_eq!(shards.last().unwrap().end, rows);
    shards
}

/// Max shard nnz / mean shard nnz — 1.0 is perfect balance.
pub fn imbalance(a: &Csr, shards: &[Range<usize>]) -> f64 {
    let row_ptr = a.row_ptr();
    let loads: Vec<usize> =
        shards.iter().map(|r| row_ptr[r.end] - row_ptr[r.start]).collect();
    let max = *loads.iter().max().unwrap_or(&0) as f64;
    let mean = loads.iter().sum::<usize>() as f64 / loads.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::text::generate_corpus;
    use crate::parallel::split_even;
    use crate::testing::PropConfig;

    #[test]
    fn covers_all_rows_disjointly() {
        let a = generate_corpus(500, 100, 3000, 1.1, 1);
        for parts in [1, 2, 4, 7, 16] {
            let shards = balanced_row_shards(&a, parts);
            assert_eq!(shards.len(), parts);
            let mut next = 0;
            for s in &shards {
                assert_eq!(s.start, next);
                next = s.end;
            }
            assert_eq!(next, 500);
        }
    }

    #[test]
    fn beats_even_split_on_zipf_data() {
        // Zipf corpora have hot head rows; nnz-balanced shards must be
        // at least as balanced as row-count shards.
        let a = generate_corpus(2000, 300, 20_000, 1.2, 3);
        let parts = 8;
        let balanced = balanced_row_shards(&a, parts);
        let even = split_even(a.rows(), parts);
        let ib = imbalance(&a, &balanced);
        let ie = imbalance(&a, &even);
        assert!(ib <= ie + 1e-9, "balanced {ib} vs even {ie}");
        assert!(ib < 1.5, "balanced imbalance too high: {ib}");
    }

    #[test]
    fn property_valid_partition() {
        PropConfig::trials(20).run("shards partition rows", |g| {
            let rows = g.usize_in(1, 300);
            let cols = g.usize_in(1, 50);
            let nnz = g.usize_in(rows.min(cols), (rows * cols).min(2000)).max(cols);
            let parts = g.usize_in(1, 12);
            let a = generate_corpus(
                rows.max(10),
                cols.max(5),
                nnz.max(cols.max(5)),
                1.1,
                g.trial,
            );
            let shards = balanced_row_shards(&a, parts);
            assert_eq!(shards.len(), parts);
            assert_eq!(shards.iter().map(|r| r.len()).sum::<usize>(), a.rows());
        });
    }
}

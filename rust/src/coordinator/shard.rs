//! Load-balanced row sharding for skewed sparse matrices.
//!
//! Bag-of-words matrices are Zipf-skewed: head words carry thousands of
//! non-zeros, tail words a handful. An even *row-count* split can leave
//! one worker with several times the nnz of another; this module
//! partitions rows so each contiguous shard carries ≈ nnz/parts
//! non-zeros. The SpMM path uses dynamic chunking by default; the
//! coordinator's static-shard mode (used where the perf pass wants
//! reproducible placement, and by the Gram reduction) uses these plans.

use std::ops::Range;

use crate::sparse::Csr;

/// Contiguous row ranges whose nnz loads differ by at most one row's
/// worth.
///
/// Guarantees, for every input (including the degenerate ones that the
/// distributed trainer hands this function):
///
/// * exactly `parts` ranges that cover `0..rows` disjointly, in order;
/// * an empty shard never precedes a non-empty one — empties appear
///   only at the tail, and only when `parts > rows` makes them
///   unavoidable;
/// * an all-zero matrix (no nnz signal) falls back to an even
///   row-count split rather than a one-row-per-shard-plus-giant-tail
///   plan.
pub fn balanced_row_shards(a: &Csr, parts: usize) -> Vec<Range<usize>> {
    assert!(parts > 0);
    let total = a.nnz();
    let rows = a.rows();
    if total == 0 {
        // No nnz signal to balance on: an even row split is the best
        // plan (and split_even already handles parts > rows by handing
        // out one-row shards followed by trailing empties).
        return crate::parallel::split_even(rows, parts);
    }
    let row_ptr = a.row_ptr();
    let mut shards = Vec::with_capacity(parts);
    let mut start = 0usize;
    for p in 0..parts {
        let remaining_shards = parts - p; // this one included
        if rows - start <= remaining_shards {
            // Fewer rows left than shards to emit: one row each until
            // rows run out, then (unavoidable) trailing empties.
            let end = (start + 1).min(rows);
            shards.push(start..end);
            start = end;
            continue;
        }
        if p == parts - 1 {
            shards.push(start..rows); // tail, even past the last nnz
            start = rows;
            continue;
        }
        // Ideal cumulative boundary after shard p: smallest end with
        // row_ptr[end] >= target, clamped so this shard takes at least
        // one row and leaves at least one row for each shard after it.
        let target = total * (p + 1) / parts;
        let cap = rows - (remaining_shards - 1);
        let mut end = start + 1;
        while end < cap && row_ptr[end] < target {
            end += 1;
        }
        shards.push(start..end);
        start = end;
    }
    debug_assert_eq!(shards.len(), parts);
    debug_assert_eq!(shards.last().unwrap().end, rows);
    shards
}

/// Max shard nnz / mean shard nnz — 1.0 is perfect balance.
pub fn imbalance(a: &Csr, shards: &[Range<usize>]) -> f64 {
    let row_ptr = a.row_ptr();
    let loads: Vec<usize> =
        shards.iter().map(|r| row_ptr[r.end] - row_ptr[r.start]).collect();
    let max = *loads.iter().max().unwrap_or(&0) as f64;
    let mean = loads.iter().sum::<usize>() as f64 / loads.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::text::generate_corpus;
    use crate::parallel::split_even;
    use crate::testing::PropConfig;

    #[test]
    fn covers_all_rows_disjointly() {
        let a = generate_corpus(500, 100, 3000, 1.1, 1);
        for parts in [1, 2, 4, 7, 16] {
            let shards = balanced_row_shards(&a, parts);
            assert_eq!(shards.len(), parts);
            let mut next = 0;
            for s in &shards {
                assert_eq!(s.start, next);
                next = s.end;
            }
            assert_eq!(next, 500);
        }
    }

    #[test]
    fn beats_even_split_on_zipf_data() {
        // Zipf corpora have hot head rows; nnz-balanced shards must be
        // at least as balanced as row-count shards.
        let a = generate_corpus(2000, 300, 20_000, 1.2, 3);
        let parts = 8;
        let balanced = balanced_row_shards(&a, parts);
        let even = split_even(a.rows(), parts);
        let ib = imbalance(&a, &balanced);
        let ie = imbalance(&a, &even);
        assert!(ib <= ie + 1e-9, "balanced {ib} vs even {ie}");
        assert!(ib < 1.5, "balanced imbalance too high: {ib}");
    }

    #[test]
    fn property_valid_partition() {
        PropConfig::trials(20).run("shards partition rows", |g| {
            let rows = g.usize_in(1, 300);
            let cols = g.usize_in(1, 50);
            let nnz = g.usize_in(rows.min(cols), (rows * cols).min(2000)).max(cols);
            let parts = g.usize_in(1, 12);
            let a = generate_corpus(
                rows.max(10),
                cols.max(5),
                nnz.max(cols.max(5)),
                1.1,
                g.trial,
            );
            let shards = balanced_row_shards(&a, parts);
            assert_eq!(shards.len(), parts);
            assert_eq!(shards.iter().map(|r| r.len()).sum::<usize>(), a.rows());
        });
    }

    /// Exact disjoint cover of `0..rows`, and empties only at the tail.
    fn assert_valid_plan(rows: usize, shards: &[std::ops::Range<usize>], parts: usize) {
        assert_eq!(shards.len(), parts);
        let mut next = 0;
        for s in shards {
            assert_eq!(s.start, next, "gap or overlap at {s:?}");
            assert!(s.start <= s.end, "inverted range {s:?}");
            next = s.end;
        }
        assert_eq!(next, rows, "plan does not cover 0..{rows}");
        // No empty shard may precede a non-empty one: once rows run
        // out they run out, and while rows remain every shard gets one.
        let first_empty = shards.iter().position(|s| s.is_empty());
        if let Some(i) = first_empty {
            assert!(
                shards[i..].iter().all(|s| s.is_empty()),
                "empty shard {i} precedes a non-empty one in {shards:?}"
            );
            assert!(
                parts > rows,
                "empty shard emitted for {rows} rows / {parts} parts (avoidable)"
            );
        }
    }

    #[test]
    fn property_degenerate_plans() {
        PropConfig::trials(40).run("degenerate shard plans stay valid", |g| {
            let rows = g.usize_in(1, 24);
            let parts = g.usize_in(1, 40); // frequently parts > rows
            let a = match g.usize_in(0, 2) {
                // All-zero matrix: no nnz at all.
                0 => Csr::from_triplets(rows, 8, Vec::new()),
                // All mass in one hot row.
                1 => {
                    let hot = g.usize_in(0, rows - 1);
                    Csr::from_triplets(rows, 8, (0..8).map(|c| (hot, c, 1.0)))
                }
                // Mass only in a head prefix; long all-zero tail.
                _ => {
                    let head = g.usize_in(1, rows);
                    Csr::from_triplets(rows, 8, (0..head).map(|r| (r, r % 8, 1.0)))
                }
            };
            assert_valid_plan(rows, &balanced_row_shards(&a, parts), parts);
        });
    }

    #[test]
    fn parts_beyond_rows_gives_singletons_then_empties() {
        let a = generate_corpus(3, 10, 12, 1.1, 5);
        let shards = balanced_row_shards(&a, 7);
        assert_valid_plan(3, &shards, 7);
        assert_eq!(&shards[..3], &[0..1, 1..2, 2..3]);
        assert!(shards[3..].iter().all(|s| s.is_empty()));
    }

    #[test]
    fn all_zero_matrix_splits_rows_evenly() {
        let a = Csr::from_triplets(10, 4, Vec::new());
        let shards = balanced_row_shards(&a, 4);
        assert_valid_plan(10, &shards, 4);
        // Even row split, not 1+1+1+7.
        let lens: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(lens.iter().max().unwrap() - lens.iter().min().unwrap(), 1);
        assert_eq!(lens, vec![3, 3, 2, 2]);
    }

    #[test]
    fn hot_tail_never_starves_later_shards() {
        // All nnz in the last row: earlier targets are tiny, but every
        // shard must still receive at least one row.
        let a = Csr::from_triplets(6, 5, (0..5).map(|c| (5, c, 1.0)));
        let shards = balanced_row_shards(&a, 3);
        assert_valid_plan(6, &shards, 3);
        assert!(shards.iter().all(|s| !s.is_empty()), "{shards:?}");
    }
}

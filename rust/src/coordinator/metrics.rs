//! Run metrics output: CSV traces (the figures' raw data) and rendered
//! summary tables.

use std::io::Write;
use std::path::Path;

use anyhow::Context;

use crate::Result;

use super::driver::RunReport;

/// Write a convergence trace as CSV (`iter,elapsed_secs,rel_error`).
pub fn write_trace_csv(path: &Path, report: &RunReport) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).ok();
        }
    }
    let f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "iter,elapsed_secs,rel_error")?;
    for r in &report.trace {
        writeln!(w, "{},{:.6},{:.8}", r.iter, r.elapsed_secs, r.rel_error)?;
    }
    Ok(())
}

/// Write several engines' traces into one long-format CSV
/// (`engine,dataset,k,iter,elapsed_secs,rel_error`) — the raw data for
/// Figs. 7 and 8.
pub fn write_comparison_csv(path: &Path, reports: &[RunReport]) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).ok();
        }
    }
    let f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "engine,dataset,k,iter,elapsed_secs,rel_error")?;
    for rep in reports {
        for r in &rep.trace {
            writeln!(
                w,
                "{},{},{},{},{:.6},{:.8}",
                rep.engine, rep.dataset, rep.k, r.iter, r.elapsed_secs, r.rel_error
            )?;
        }
    }
    Ok(())
}

/// A fixed-width summary table of reports (final error, time, per-iter).
pub fn summary_table(reports: &[RunReport]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<14} {:<14} {:>4} {:>9} {:>12} {:>12} {:>12}\n",
        "engine", "dataset", "k", "iters", "final err", "total s", "s/iter"
    ));
    for r in reports {
        out.push_str(&format!(
            "{:<14} {:<14} {:>4} {:>9} {:>12.6} {:>12.3} {:>12.4}\n",
            r.engine,
            r.dataset,
            r.k,
            r.iters_run(),
            r.final_rel_error,
            r.total_step_secs,
            r.secs_per_iter()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nmf::IterRecord;
    use crate::util::PhaseTimers;

    fn fake_report(engine: &'static str) -> RunReport {
        RunReport {
            engine,
            dataset: "tiny".into(),
            k: 4,
            tile: 2,
            threads: 2,
            trace: vec![
                IterRecord { iter: 0, elapsed_secs: 0.0, rel_error: 0.9 },
                IterRecord { iter: 1, elapsed_secs: 0.5, rel_error: 0.5 },
            ],
            final_rel_error: 0.5,
            total_step_secs: 0.5,
            timers: PhaseTimers::new(),
        }
    }

    #[test]
    fn comparison_csv_long_format() {
        let p = std::env::temp_dir().join(format!("plnmf-cmp-{}.csv", std::process::id()));
        write_comparison_csv(&p, &[fake_report("a"), fake_report("b")]).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert_eq!(body.lines().count(), 5);
        assert!(body.contains("a,tiny,4,1,0.500000,0.50000000"));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn summary_contains_all_engines() {
        let s = summary_table(&[fake_report("plnmf-cpu"), fake_report("mu-cpu")]);
        assert!(s.contains("plnmf-cpu"));
        assert!(s.contains("mu-cpu"));
    }
}

//! Run driver: config → dataset → pool → engine → convergence loop.

use std::sync::Arc;

use anyhow::{bail, Context};

use crate::config::{EngineKind, RunConfig};
use crate::data::{load_dataset, Dataset};
use crate::nmf::bpp::BppEngine;
use crate::nmf::fasthals::FastHalsEngine;
use crate::nmf::mu::MuEngine;
use crate::nmf::mukl::MuKlEngine;
use crate::nmf::plnmf::PlNmfEngine;
use crate::nmf::spec::{Init, Loss};
use crate::nmf::{IterRecord, NmfEngine};
use crate::parallel::{pool::default_threads, ThreadPool};
use crate::runtime::engine::{MuXlaEngine, PlNmfXlaEngine};
use crate::util::PhaseTimers;
use crate::Result;

/// Outcome of a full run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub engine: &'static str,
    pub dataset: String,
    pub k: usize,
    pub tile: usize,
    pub threads: usize,
    pub trace: Vec<IterRecord>,
    pub final_rel_error: f64,
    /// Total step (update) time, excluding error evaluations.
    pub total_step_secs: f64,
    pub timers: PhaseTimers,
}

impl RunReport {
    pub fn iters_run(&self) -> usize {
        self.trace.last().map(|r| r.iter).unwrap_or(0)
    }

    pub fn secs_per_iter(&self) -> f64 {
        let n = self.iters_run();
        if n == 0 {
            0.0
        } else {
            self.total_step_secs / n as f64
        }
    }

    /// First (time, iter) at which the trace reaches `target` error, if
    /// it does — the Fig. 9 "time to matched quality" measurement.
    pub fn time_to_error(&self, target: f64) -> Option<f64> {
        self.trace.iter().find(|r| r.rel_error <= target).map(|r| r.elapsed_secs)
    }
}

/// Instantiate an engine for `kind` on an already-loaded dataset.
///
/// The config's loss/alpha/l1_ratio/init surface is resolved into an
/// [`crate::nmf::EngineSpec`] for the engine actually built (`kind` may
/// differ from `cfg.engine` in comparison sweeps): `--engine mu --loss
/// kl` promotes to the KL MU engine, the XLA engines run fixed AOT
/// graphs and reject any non-default spec, and invalid combinations are
/// errors here rather than asserts inside an engine.
pub fn create_engine(
    kind: EngineKind,
    ds: Arc<Dataset>,
    pool: Arc<ThreadPool>,
    cfg: &RunConfig,
) -> Result<Box<dyn NmfEngine>> {
    let mut spec_cfg = cfg.clone();
    spec_cfg.engine = kind;
    let kind = spec_cfg.effective_engine();
    spec_cfg.engine = kind;
    let spec = spec_cfg.engine_spec()?;
    if kind == EngineKind::MuKl && spec.loss != Loss::Kl {
        bail!("engine 'mu-kl-cpu' optimizes the KL objective; drop --loss or use --loss kl");
    }
    if kind.is_xla()
        && !(spec.loss == Loss::Frobenius && spec.alpha == 0.0 && spec.init == Init::Random)
    {
        bail!(
            "engine '{}' runs a fixed AOT graph; loss/alpha/init overrides need a native engine",
            kind.name()
        );
    }
    Ok(match kind {
        EngineKind::PlNmf => Box::new(PlNmfEngine::with_spec(
            ds,
            pool,
            cfg.k,
            cfg.seed,
            cfg.tile,
            cfg.cache_bytes,
            spec,
        )),
        EngineKind::FastHals => Box::new(FastHalsEngine::with_spec(ds, pool, cfg.k, cfg.seed, spec)),
        EngineKind::Mu => Box::new(MuEngine::with_spec(ds, pool, cfg.k, cfg.seed, spec)),
        EngineKind::MuKl => Box::new(MuKlEngine::with_spec(ds, pool, cfg.k, cfg.seed, spec)),
        EngineKind::Bpp => Box::new(BppEngine::with_spec(ds, pool, cfg.k, cfg.seed, spec)),
        EngineKind::PlNmfXla => Box::new(
            PlNmfXlaEngine::new(ds, pool, cfg.k, cfg.seed, &cfg.artifacts_dir)
                .context("creating plnmf-accel engine")?,
        ),
        EngineKind::MuXla => Box::new(
            MuXlaEngine::new(ds, pool, cfg.k, cfg.seed, &cfg.artifacts_dir)
                .context("creating mu-accel engine")?,
        ),
    })
}

/// A configured, ready-to-run NMF job.
pub struct Driver {
    cfg: RunConfig,
    pub ds: Arc<Dataset>,
    pub pool: Arc<ThreadPool>,
    engine: Box<dyn NmfEngine>,
}

impl Driver {
    pub fn from_config(cfg: &RunConfig) -> Result<Driver> {
        cfg.validate()?;
        let ds = Arc::new(load_dataset(&cfg.dataset, cfg.seed)?);
        let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };
        let pool = Arc::new(ThreadPool::new(threads));
        Self::with_dataset(cfg, ds, pool)
    }

    /// Reuse an existing dataset/pool (the comparison runner and benches
    /// share one dataset across engines).
    pub fn with_dataset(cfg: &RunConfig, ds: Arc<Dataset>, pool: Arc<ThreadPool>) -> Result<Driver> {
        let engine = create_engine(cfg.engine, ds.clone(), pool.clone(), cfg)?;
        Ok(Driver { cfg: cfg.clone(), ds, pool, engine })
    }

    pub fn engine_mut(&mut self) -> &mut dyn NmfEngine {
        self.engine.as_mut()
    }

    /// Run to completion per the config; returns the report and writes
    /// the CSV trace if configured.
    pub fn run(&mut self) -> Result<RunReport> {
        crate::info!(
            "run: engine={} dataset={} k={} iters={} threads={}",
            self.engine.name(),
            self.cfg.dataset,
            self.cfg.k,
            self.cfg.max_iters,
            self.pool.n_threads()
        );
        let trace = self.engine.run(self.cfg.max_iters, self.cfg.record_every, self.cfg.tol)?;
        let total_step_secs = trace.last().map(|r| r.elapsed_secs).unwrap_or(0.0);
        let report = RunReport {
            engine: self.engine.name(),
            dataset: self.cfg.dataset.clone(),
            k: self.cfg.k,
            tile: self.cfg.tile,
            threads: self.pool.n_threads(),
            final_rel_error: trace.last().map(|r| r.rel_error).unwrap_or(f64::NAN),
            trace,
            total_step_secs,
            timers: self.engine.timers().clone(),
        };
        if let Some(path) = &self.cfg.trace_path {
            super::metrics::write_trace_csv(std::path::Path::new(path), &report)?;
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(engine: EngineKind) -> RunConfig {
        let mut c = RunConfig::default();
        c.dataset = "tiny".into();
        c.k = 4;
        c.max_iters = 10;
        c.threads = 2;
        c.engine = engine;
        c
    }

    #[test]
    fn driver_runs_all_native_engines() {
        for kind in [EngineKind::PlNmf, EngineKind::FastHals, EngineKind::Mu, EngineKind::Bpp] {
            let mut d = Driver::from_config(&cfg(kind)).unwrap();
            let report = d.run().unwrap();
            assert_eq!(report.engine, kind.name());
            assert!(report.final_rel_error.is_finite());
            assert!(report.final_rel_error < report.trace[0].rel_error);
            assert_eq!(report.iters_run(), 10);
            assert!(report.secs_per_iter() > 0.0);
        }
    }

    #[test]
    fn loss_kl_promotes_mu_and_rejects_hals() {
        use crate::nmf::spec::Loss;
        let mut c = cfg(EngineKind::Mu);
        c.loss = Some(Loss::Kl);
        let mut d = Driver::from_config(&c).unwrap();
        let report = d.run().unwrap();
        assert_eq!(report.engine, "mu-kl-cpu");
        // The same loss under a HALS engine is a loud config error.
        let mut c = cfg(EngineKind::PlNmf);
        c.loss = Some(Loss::Kl);
        assert!(Driver::from_config(&c).is_err());
    }

    #[test]
    fn regularized_spec_runs_through_driver() {
        let mut c = cfg(EngineKind::PlNmf);
        c.alpha = 0.2;
        c.l1_ratio = 0.5;
        c.init = crate::nmf::spec::Init::Nndsvda;
        let mut d = Driver::from_config(&c).unwrap();
        let report = d.run().unwrap();
        assert!(report.final_rel_error.is_finite());
        assert!(report.final_rel_error < report.trace[0].rel_error);
    }

    #[test]
    fn time_to_error_is_monotone_lookup() {
        let mut d = Driver::from_config(&cfg(EngineKind::PlNmf)).unwrap();
        let report = d.run().unwrap();
        let final_err = report.final_rel_error;
        assert!(report.time_to_error(final_err).is_some());
        assert!(report.time_to_error(0.0).is_none());
        assert_eq!(report.time_to_error(1.0), Some(0.0)); // iter-0 record
    }

    #[test]
    fn trace_csv_written() {
        let mut c = cfg(EngineKind::FastHals);
        let path = std::env::temp_dir().join(format!("plnmf-trace-{}.csv", std::process::id()));
        c.trace_path = Some(path.to_str().unwrap().to_string());
        Driver::from_config(&c).unwrap().run().unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("iter,elapsed_secs,rel_error"));
        assert!(body.lines().count() >= 11);
        std::fs::remove_file(path).ok();
    }
}

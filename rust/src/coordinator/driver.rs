//! Run driver: config → dataset → pool → engine → convergence loop.

use std::sync::Arc;

use anyhow::Context;

use crate::config::{EngineKind, RunConfig};
use crate::data::{load_dataset, Dataset};
use crate::nmf::bpp::BppEngine;
use crate::nmf::fasthals::FastHalsEngine;
use crate::nmf::mu::MuEngine;
use crate::nmf::mukl::MuKlEngine;
use crate::nmf::plnmf::PlNmfEngine;
use crate::nmf::{IterRecord, NmfEngine};
use crate::parallel::{pool::default_threads, ThreadPool};
use crate::runtime::engine::{MuXlaEngine, PlNmfXlaEngine};
use crate::util::PhaseTimers;
use crate::Result;

/// Outcome of a full run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub engine: &'static str,
    pub dataset: String,
    pub k: usize,
    pub tile: usize,
    pub threads: usize,
    pub trace: Vec<IterRecord>,
    pub final_rel_error: f64,
    /// Total step (update) time, excluding error evaluations.
    pub total_step_secs: f64,
    pub timers: PhaseTimers,
}

impl RunReport {
    pub fn iters_run(&self) -> usize {
        self.trace.last().map(|r| r.iter).unwrap_or(0)
    }

    pub fn secs_per_iter(&self) -> f64 {
        let n = self.iters_run();
        if n == 0 {
            0.0
        } else {
            self.total_step_secs / n as f64
        }
    }

    /// First (time, iter) at which the trace reaches `target` error, if
    /// it does — the Fig. 9 "time to matched quality" measurement.
    pub fn time_to_error(&self, target: f64) -> Option<f64> {
        self.trace.iter().find(|r| r.rel_error <= target).map(|r| r.elapsed_secs)
    }
}

/// Instantiate an engine for `kind` on an already-loaded dataset.
pub fn create_engine(
    kind: EngineKind,
    ds: Arc<Dataset>,
    pool: Arc<ThreadPool>,
    cfg: &RunConfig,
) -> Result<Box<dyn NmfEngine>> {
    Ok(match kind {
        EngineKind::PlNmf => Box::new(PlNmfEngine::new(
            ds,
            pool,
            cfg.k,
            cfg.seed,
            cfg.tile,
            cfg.cache_bytes,
        )),
        EngineKind::FastHals => Box::new(FastHalsEngine::new(ds, pool, cfg.k, cfg.seed)),
        EngineKind::Mu => Box::new(MuEngine::new(ds, pool, cfg.k, cfg.seed)),
        EngineKind::MuKl => Box::new(MuKlEngine::new(ds, pool, cfg.k, cfg.seed)),
        EngineKind::Bpp => Box::new(BppEngine::new(ds, pool, cfg.k, cfg.seed)),
        EngineKind::PlNmfXla => Box::new(
            PlNmfXlaEngine::new(ds, pool, cfg.k, cfg.seed, &cfg.artifacts_dir)
                .context("creating plnmf-accel engine")?,
        ),
        EngineKind::MuXla => Box::new(
            MuXlaEngine::new(ds, pool, cfg.k, cfg.seed, &cfg.artifacts_dir)
                .context("creating mu-accel engine")?,
        ),
    })
}

/// A configured, ready-to-run NMF job.
pub struct Driver {
    cfg: RunConfig,
    pub ds: Arc<Dataset>,
    pub pool: Arc<ThreadPool>,
    engine: Box<dyn NmfEngine>,
}

impl Driver {
    pub fn from_config(cfg: &RunConfig) -> Result<Driver> {
        cfg.validate()?;
        let ds = Arc::new(load_dataset(&cfg.dataset, cfg.seed)?);
        let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };
        let pool = Arc::new(ThreadPool::new(threads));
        Self::with_dataset(cfg, ds, pool)
    }

    /// Reuse an existing dataset/pool (the comparison runner and benches
    /// share one dataset across engines).
    pub fn with_dataset(cfg: &RunConfig, ds: Arc<Dataset>, pool: Arc<ThreadPool>) -> Result<Driver> {
        let engine = create_engine(cfg.engine, ds.clone(), pool.clone(), cfg)?;
        Ok(Driver { cfg: cfg.clone(), ds, pool, engine })
    }

    pub fn engine_mut(&mut self) -> &mut dyn NmfEngine {
        self.engine.as_mut()
    }

    /// Run to completion per the config; returns the report and writes
    /// the CSV trace if configured.
    pub fn run(&mut self) -> Result<RunReport> {
        crate::info!(
            "run: engine={} dataset={} k={} iters={} threads={}",
            self.engine.name(),
            self.cfg.dataset,
            self.cfg.k,
            self.cfg.max_iters,
            self.pool.n_threads()
        );
        let trace = self.engine.run(self.cfg.max_iters, self.cfg.record_every, self.cfg.tol)?;
        let total_step_secs = trace.last().map(|r| r.elapsed_secs).unwrap_or(0.0);
        let report = RunReport {
            engine: self.engine.name(),
            dataset: self.cfg.dataset.clone(),
            k: self.cfg.k,
            tile: self.cfg.tile,
            threads: self.pool.n_threads(),
            final_rel_error: trace.last().map(|r| r.rel_error).unwrap_or(f64::NAN),
            trace,
            total_step_secs,
            timers: self.engine.timers().clone(),
        };
        if let Some(path) = &self.cfg.trace_path {
            super::metrics::write_trace_csv(std::path::Path::new(path), &report)?;
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(engine: EngineKind) -> RunConfig {
        let mut c = RunConfig::default();
        c.dataset = "tiny".into();
        c.k = 4;
        c.max_iters = 10;
        c.threads = 2;
        c.engine = engine;
        c
    }

    #[test]
    fn driver_runs_all_native_engines() {
        for kind in [EngineKind::PlNmf, EngineKind::FastHals, EngineKind::Mu, EngineKind::Bpp] {
            let mut d = Driver::from_config(&cfg(kind)).unwrap();
            let report = d.run().unwrap();
            assert_eq!(report.engine, kind.name());
            assert!(report.final_rel_error.is_finite());
            assert!(report.final_rel_error < report.trace[0].rel_error);
            assert_eq!(report.iters_run(), 10);
            assert!(report.secs_per_iter() > 0.0);
        }
    }

    #[test]
    fn time_to_error_is_monotone_lookup() {
        let mut d = Driver::from_config(&cfg(EngineKind::PlNmf)).unwrap();
        let report = d.run().unwrap();
        let final_err = report.final_rel_error;
        assert!(report.time_to_error(final_err).is_some());
        assert!(report.time_to_error(0.0).is_none());
        assert_eq!(report.time_to_error(1.0), Some(0.0)); // iter-0 record
    }

    #[test]
    fn trace_csv_written() {
        let mut c = cfg(EngineKind::FastHals);
        let path = std::env::temp_dir().join(format!("plnmf-trace-{}.csv", std::process::id()));
        c.trace_path = Some(path.to_str().unwrap().to_string());
        Driver::from_config(&c).unwrap().run().unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("iter,elapsed_secs,rel_error"));
        assert!(body.lines().count() >= 11);
        std::fs::remove_file(path).ok();
    }
}

//! Multi-engine comparison runner — the Figs. 7/8/9 protocol: every
//! engine starts from the *same* seeded random factors on the *same*
//! dataset instance, and we record aligned (time, iteration, error)
//! traces.

use std::sync::Arc;

use crate::config::{EngineKind, RunConfig};
use crate::data::{load_dataset, Dataset};
use crate::parallel::{pool::default_threads, ThreadPool};
use crate::Result;

use super::driver::{Driver, RunReport};

/// Run `engines` sequentially on one dataset and collect reports.
/// Engines that fail to construct (e.g. missing artifacts for the XLA
/// path) are reported as `Err` entries rather than aborting the whole
/// comparison — Fig. 7 runs partial engine sets when artifacts are
/// absent.
pub struct Comparison {
    pub ds: Arc<Dataset>,
    pub pool: Arc<ThreadPool>,
    pub reports: Vec<RunReport>,
    pub skipped: Vec<(EngineKind, String)>,
}

pub fn run_comparison(base: &RunConfig, engines: &[EngineKind]) -> Result<Comparison> {
    let ds = Arc::new(load_dataset(&base.dataset, base.seed)?);
    let threads = if base.threads == 0 { default_threads() } else { base.threads };
    let pool = Arc::new(ThreadPool::new(threads));
    let mut reports = Vec::new();
    let mut skipped = Vec::new();
    for &kind in engines {
        let mut cfg = base.clone();
        cfg.engine = kind;
        match Driver::with_dataset(&cfg, ds.clone(), pool.clone()) {
            Ok(mut driver) => reports.push(driver.run()?),
            Err(e) => {
                crate::warn_!("skipping {}: {e:#}", kind.name());
                skipped.push((kind, format!("{e:#}")));
            }
        }
    }
    Ok(Comparison { ds, pool, reports, skipped })
}

/// The Fig. 9 measurement: speedup of `fast` over each `slow` at matched
/// relative error. For each error level in `targets`, returns
/// `(target, slow_name, t_slow / t_fast)` for every pair where both
/// traces reach the target.
pub fn speedups_at_matched_error(
    fast: &RunReport,
    slows: &[&RunReport],
    targets: &[f64],
) -> Vec<(f64, &'static str, f64)> {
    let mut out = Vec::new();
    for &target in targets {
        if let Some(tf) = fast.time_to_error(target) {
            for slow in slows {
                if let Some(ts) = slow.time_to_error(target) {
                    // Guard the iter-0 record (elapsed 0): both engines
                    // start at the same error, so a target above the
                    // initial error is vacuous.
                    if tf == 0.0 && ts == 0.0 {
                        continue;
                    }
                    out.push((target, slow.engine, ts / tf.max(1e-9)));
                }
            }
        }
    }
    out
}

/// Error targets shared by a set of traces: evenly spaced between the
/// error after one iteration and the best error every trace reaches (so
/// every (engine, target) pair is well-defined). The iteration-0 record
/// is skipped: with large K the random-init objective is far above 1
/// and every engine collapses it in a single iteration, so targets
/// anchored there would only measure first-step time (the paper's
/// Fig. 9 targets likewise sit in the converged regime, e.g. 0.12 on
/// PIE).
pub fn common_error_targets(reports: &[&RunReport], n: usize) -> Vec<f64> {
    let start = reports
        .iter()
        .map(|r| {
            r.trace
                .iter()
                .find(|t| t.iter >= 1)
                .or_else(|| r.trace.first())
                .map(|t| t.rel_error)
                .unwrap_or(1.0)
        })
        .fold(f64::INFINITY, f64::min);
    let floor = reports
        .iter()
        .map(|r| r.trace.iter().map(|t| t.rel_error).fold(f64::INFINITY, f64::min))
        .fold(0.0f64, f64::max);
    if !start.is_finite() || !floor.is_finite() || floor >= start {
        return vec![];
    }
    (1..=n)
        .map(|i| start - (start - floor) * (i as f64) / (n as f64 + 1.0))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> RunConfig {
        let mut c = RunConfig::default();
        c.dataset = "tiny".into();
        c.k = 4;
        c.max_iters = 12;
        c.threads = 2;
        c
    }

    #[test]
    fn comparison_shares_init_across_engines() {
        let cmp = run_comparison(&base(), &[EngineKind::PlNmf, EngineKind::FastHals]).unwrap();
        assert_eq!(cmp.reports.len(), 2);
        assert!(cmp.skipped.is_empty());
        // Same seed → identical starting error.
        let e0: Vec<f64> = cmp.reports.iter().map(|r| r.trace[0].rel_error).collect();
        assert!((e0[0] - e0[1]).abs() < 1e-12, "{e0:?}");
        // HALS-family trajectories coincide per iteration (Fig. 8).
        for (a, b) in cmp.reports[0].trace.iter().zip(&cmp.reports[1].trace) {
            assert!((a.rel_error - b.rel_error).abs() < 2e-3);
        }
    }

    #[test]
    fn missing_artifacts_skips_not_fails() {
        let mut cfg = base();
        cfg.artifacts_dir = "/nonexistent".into();
        let cmp = run_comparison(&cfg, &[EngineKind::FastHals, EngineKind::PlNmfXla]).unwrap();
        assert_eq!(cmp.reports.len(), 1);
        assert_eq!(cmp.skipped.len(), 1);
        assert_eq!(cmp.skipped[0].0, EngineKind::PlNmfXla);
    }

    #[test]
    fn speedups_and_targets() {
        let cmp = run_comparison(&base(), &[EngineKind::PlNmf, EngineKind::Mu]).unwrap();
        let fast = &cmp.reports[0];
        let slow = &cmp.reports[1];
        let targets = common_error_targets(&[fast, slow], 4);
        assert!(!targets.is_empty());
        assert!(targets.windows(2).all(|w| w[0] > w[1]));
        let sp = speedups_at_matched_error(fast, &[slow], &targets);
        assert!(!sp.is_empty());
        for (t, name, s) in &sp {
            assert!(*t > 0.0 && s.is_finite());
            assert_eq!(*name, "mu-cpu");
        }
    }
}

//! PJRT-backed NMF engines — the stand-ins for the paper's GPU
//! implementations (PL-NMF-gpu, bionmf-MU-gpu), executing the AOT-lowered
//! JAX/Pallas update graphs.
//!
//! Data flow per outer iteration:
//!
//! * **dense datasets** — `A` stays device-resident for the whole run;
//!   one fused `plnmf_step`/`mu_step` executable computes all products
//!   and both tiled updates on device; the small factors (V×K + D×K)
//!   round-trip so the next iteration can feed them back as parameters
//!   (PJRT tuple outputs cannot be re-passed whole) and so the error
//!   metric runs natively.
//! * **sparse datasets** — XLA has no sparse kernels, so the coordinator
//!   computes `R = AᵀW` / `P = A·H` with its CSR SpMM and ships only the
//!   dense tall-skinny panels; the `plnmf_update_h`/`plnmf_update_w`
//!   executables run the tiled updates. This is the same division of
//!   labor as the paper's GPU code (cusparseDcsrmm for products, custom
//!   kernels for the update) with the sparse half on the host.
//!
//! Timer keys: `spmm_r`/`spmm_p` (host SpMM, sparse only), `h2d`/`d2h`
//! (transfers), `xla_update_h`/`xla_update_w` or `xla_step` (device
//! compute).

use std::sync::Arc;

use anyhow::Context;

use crate::data::Dataset;
use crate::linalg::Mat;
use crate::nmf::{products, Factors, NmfEngine};
use crate::parallel::ThreadPool;
use crate::util::PhaseTimers;
use crate::Result;

use super::buffers::{literal_to_mat, untuple, upload};
use super::manifest::{ArtifactMeta, Manifest};
use super::xe;

/// A compiled artifact ready to execute.
pub struct XlaExec {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl XlaExec {
    /// Load + compile `fn_name` for `(dataset, k)` from the manifest.
    pub fn load(
        client: &xla::PjRtClient,
        manifest: &Manifest,
        fn_name: &str,
        dataset: &str,
        k: usize,
    ) -> Result<XlaExec> {
        let meta = manifest.find(fn_name, dataset, k)?.clone();
        let path = manifest.hlo_path(&meta);
        let proto = xe(xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        ))
        .with_context(|| format!("loading {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = xe(client.compile(&comp)).with_context(|| format!("compiling {}", meta.name))?;
        Ok(XlaExec { meta, exe })
    }

    /// Execute on device-resident buffers; returns the decomposed output
    /// literals (jax lowers with `return_tuple=True`).
    pub fn call_b(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            args.len() == self.meta.inputs.len(),
            "{} expects {} inputs, got {}",
            self.meta.name,
            self.meta.inputs.len(),
            args.len()
        );
        let out = xe(self.exe.execute_b(args))?;
        let lit = xe(out[0][0].to_literal_sync())?;
        untuple(lit)
    }
}

/// Which artifact family an engine runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Family {
    PlNmf,
    Mu,
}

impl Family {
    fn step_fn(self) -> &'static str {
        match self {
            Family::PlNmf => "plnmf_step",
            Family::Mu => "mu_step",
        }
    }

    fn update_h_fn(self) -> &'static str {
        match self {
            Family::PlNmf => "plnmf_update_h",
            Family::Mu => "mu_update_h",
        }
    }

    fn update_w_fn(self) -> &'static str {
        match self {
            Family::PlNmf => "plnmf_update_w",
            Family::Mu => "mu_update_w",
        }
    }

    fn name(self) -> &'static str {
        match self {
            Family::PlNmf => "plnmf-accel",
            Family::Mu => "mu-accel",
        }
    }
}

enum Mode {
    Dense {
        step: XlaExec,
        /// A uploaded once; the dominant buffer stays device-resident.
        a_buf: xla::PjRtBuffer,
    },
    Sparse {
        update_h: XlaExec,
        update_w: XlaExec,
        r: Mat,
        p: Mat,
    },
}

/// Generic PJRT engine over a family of artifacts.
pub struct XlaEngine {
    ds: Arc<Dataset>,
    pool: Arc<ThreadPool>,
    factors: Factors,
    timers: PhaseTimers,
    client: xla::PjRtClient,
    mode: Mode,
    family: Family,
    pub tile: usize,
}

impl XlaEngine {
    fn create(
        family: Family,
        ds: Arc<Dataset>,
        pool: Arc<ThreadPool>,
        k: usize,
        seed: u64,
        artifacts_dir: &str,
    ) -> Result<XlaEngine> {
        let manifest = Manifest::load(std::path::Path::new(artifacts_dir))?;
        let client = super::cpu_client()?;
        let dataset = ds.profile.name;
        let factors = Factors::random(ds.v(), ds.d(), k, seed);
        let (mode, tile) = if ds.a.is_sparse() {
            let update_h = XlaExec::load(&client, &manifest, family.update_h_fn(), dataset, k)?;
            let update_w = XlaExec::load(&client, &manifest, family.update_w_fn(), dataset, k)?;
            let tile = update_h.meta.tile;
            let r = Mat::zeros(ds.d(), k);
            let p = Mat::zeros(ds.v(), k);
            (Mode::Sparse { update_h, update_w, r, p }, tile)
        } else {
            let step = XlaExec::load(&client, &manifest, family.step_fn(), dataset, k)?;
            let a = match &ds.a {
                crate::data::DataMatrix::Dense(a) => a,
                _ => unreachable!(),
            };
            let tile = step.meta.tile;
            let a_buf = upload(&client, a)?;
            (Mode::Dense { step, a_buf }, tile)
        };
        Ok(XlaEngine { ds, pool, factors, timers: PhaseTimers::new(), client, mode, family, tile })
    }

    pub fn set_factors(&mut self, f: Factors) {
        self.factors = f;
    }
}

impl NmfEngine for XlaEngine {
    fn name(&self) -> &'static str {
        self.family.name()
    }

    fn step(&mut self) -> Result<()> {
        let (v, d, k) = (self.ds.v(), self.ds.d(), self.factors.k());
        match &mut self.mode {
            Mode::Dense { step, a_buf } => {
                let w_buf =
                    self.timers.time("h2d", || upload(&self.client, &self.factors.w))?;
                let h_buf = self.timers.time("h2d", || upload(&self.client, &self.factors.h))?;
                let outs =
                    self.timers.time("xla_step", || step.call_b(&[a_buf, &w_buf, &h_buf]))?;
                anyhow::ensure!(outs.len() == 2, "step returned {} outputs", outs.len());
                self.timers.time("d2h", || -> Result<()> {
                    self.factors.w = literal_to_mat(&outs[0], v, k)?;
                    self.factors.h = literal_to_mat(&outs[1], d, k)?;
                    Ok(())
                })?;
            }
            Mode::Sparse { update_h, update_w, r, p } => {
                // R = AᵀW on host (CSR SpMM), tiled H update on device.
                self.timers.time("spmm_r", || {
                    products::at_times(&self.pool, &self.ds, &self.factors.w, r)
                });
                let (w_buf, h_buf, r_buf) = self.timers.time("h2d", || -> Result<_> {
                    Ok((
                        upload(&self.client, &self.factors.w)?,
                        upload(&self.client, &self.factors.h)?,
                        upload(&self.client, r)?,
                    ))
                })?;
                let outs = self
                    .timers
                    .time("xla_update_h", || update_h.call_b(&[&w_buf, &h_buf, &r_buf]))?;
                self.timers.time("d2h", || -> Result<()> {
                    self.factors.h = literal_to_mat(&outs[0], d, k)?;
                    Ok(())
                })?;

                // P = A·H on host, tiled W update on device.
                self.timers.time("spmm_p", || {
                    products::a_times(&self.pool, &self.ds, &self.factors.h, p)
                });
                let (h_buf, p_buf) = self.timers.time("h2d", || -> Result<_> {
                    Ok((upload(&self.client, &self.factors.h)?, upload(&self.client, p)?))
                })?;
                let outs = self
                    .timers
                    .time("xla_update_w", || update_w.call_b(&[&w_buf, &h_buf, &p_buf]))?;
                self.timers.time("d2h", || -> Result<()> {
                    self.factors.w = literal_to_mat(&outs[0], v, k)?;
                    Ok(())
                })?;
            }
        }
        Ok(())
    }

    fn factors(&self) -> &Factors {
        &self.factors
    }

    fn timers(&self) -> &PhaseTimers {
        &self.timers
    }

    fn reset_timers(&mut self) {
        self.timers.reset();
    }

    fn dataset(&self) -> &Dataset {
        &self.ds
    }

    fn pool(&self) -> &ThreadPool {
        &self.pool
    }
}

/// PL-NMF through the XLA/Pallas path (`PL-NMF-accel`).
pub struct PlNmfXlaEngine;

impl PlNmfXlaEngine {
    pub fn new(
        ds: Arc<Dataset>,
        pool: Arc<ThreadPool>,
        k: usize,
        seed: u64,
        artifacts_dir: &str,
    ) -> Result<XlaEngine> {
        XlaEngine::create(Family::PlNmf, ds, pool, k, seed, artifacts_dir)
    }
}

/// MU through the XLA path (`mu-accel`, the bionmf-MU-gpu stand-in).
pub struct MuXlaEngine;

impl MuXlaEngine {
    pub fn new(
        ds: Arc<Dataset>,
        pool: Arc<ThreadPool>,
        k: usize,
        seed: u64,
        artifacts_dir: &str,
    ) -> Result<XlaEngine> {
        XlaEngine::create(Family::Mu, ds, pool, k, seed, artifacts_dir)
    }
}

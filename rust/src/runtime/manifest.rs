//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context};

use crate::util::json::Json;
use crate::Result;

/// Shape+dtype of one executable input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j.get("dtype").as_str().unwrap_or("f32").to_string();
        Ok(TensorSpec { shape, dtype })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    /// HLO text file, relative to the manifest's directory.
    pub file: String,
    /// Logical function: `plnmf_step`, `plnmf_update_h`, `mu_step`, ...
    pub fn_name: String,
    pub dataset: String,
    pub v: usize,
    pub d: usize,
    pub k: usize,
    pub tile: usize,
    pub sparse: bool,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactMeta {
    fn from_json(j: &Json) -> Result<ArtifactMeta> {
        let get_str = |k: &str| {
            j.get(k).as_str().map(|s| s.to_string()).ok_or_else(|| anyhow!("missing '{k}'"))
        };
        let get_usize =
            |k: &str| j.get(k).as_usize().ok_or_else(|| anyhow!("missing/invalid '{k}'"));
        let specs = |k: &str| -> Result<Vec<TensorSpec>> {
            j.get(k)
                .as_arr()
                .ok_or_else(|| anyhow!("missing '{k}'"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(ArtifactMeta {
            name: get_str("name")?,
            file: get_str("file")?,
            fn_name: get_str("fn")?,
            dataset: get_str("dataset")?,
            v: get_usize("v")?,
            d: get_usize("d")?,
            k: get_usize("k")?,
            tile: get_usize("tile")?,
            sparse: j.get("sparse").as_bool().unwrap_or(false),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
        })
    }
}

/// The parsed manifest plus its base directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    by_name: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&src).with_context(|| format!("parsing {path:?}"))?;
        // Strict: a negative/fractional version is a parse error, not a
        // silent 0 masquerading as "unsupported version 0".
        let version = j
            .get("version")
            .as_usize()
            .ok_or_else(|| anyhow!("manifest needs a non-negative integer \"version\""))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut by_name = BTreeMap::new();
        for a in j.get("artifacts").as_arr().ok_or_else(|| anyhow!("missing artifacts"))? {
            let meta = ArtifactMeta::from_json(a)?;
            by_name.insert(meta.name.clone(), meta);
        }
        Ok(Manifest { dir: dir.to_path_buf(), by_name })
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.by_name.get(name)
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.by_name.values()
    }

    /// Find the artifact for a logical function on a (dataset, k) config.
    pub fn find(&self, fn_name: &str, dataset: &str, k: usize) -> Result<&ArtifactMeta> {
        self.by_name
            .values()
            .find(|a| a.fn_name == fn_name && a.dataset == dataset && a.k == k)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for fn={fn_name} dataset={dataset} k={k}; \
                     available: [{}] — extend python/compile/aot.py's build set \
                     (e.g. `cd python && python -m compile.aot --out-dir ../artifacts \
                     --config {dataset}:{k}`)",
                    self.by_name
                        .values()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    /// Absolute path of an artifact's HLO text.
    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmpdir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("plnmf-manifest-{}-{name}", std::process::id()));
        p
    }

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "plnmf_step__tiny_k8_t3", "file": "plnmf_step__tiny_k8_t3.hlo.txt",
         "fn": "plnmf_step", "dataset": "tiny", "v": 60, "d": 40, "k": 8, "tile": 3,
         "sparse": false,
         "inputs": [{"shape": [60,40], "dtype": "f32"}, {"shape": [60,8], "dtype": "f32"},
                    {"shape": [40,8], "dtype": "f32"}],
         "outputs": [{"shape": [60,8], "dtype": "f32"}, {"shape": [40,8], "dtype": "f32"}]}
      ]
    }"#;

    #[test]
    fn parses_and_finds() {
        let dir = tmpdir("ok");
        write_manifest(&dir, SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.len(), 1);
        let a = m.find("plnmf_step", "tiny", 8).unwrap();
        assert_eq!(a.tile, 3);
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].shape, vec![60, 40]);
        assert_eq!(a.outputs[1].elements(), 320);
        assert!(m.hlo_path(a).ends_with("plnmf_step__tiny_k8_t3.hlo.txt"));
        assert!(m.find("plnmf_step", "tiny", 16).is_err());
        assert!(m.find("mu_step", "tiny", 8).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_bad_version() {
        let dir = tmpdir("badver");
        write_manifest(&dir, r#"{"version": 99, "artifacts": []}"#);
        assert!(Manifest::load(&dir).is_err());
        // Silent-coercion regression: a bogus version errors as such
        // instead of wrapping to 0 and reading as "unsupported 0".
        for bad in ["-1", "1.5", "1e300", "\"one\""] {
            write_manifest(&dir, &format!(r#"{{"version": {bad}, "artifacts": []}}"#));
            let err = format!("{:#}", Manifest::load(&dir).unwrap_err());
            assert!(err.contains("version"), "version={bad}: {err}");
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_file_reports_make_artifacts() {
        let dir = tmpdir("missing");
        std::fs::create_dir_all(&dir).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }
}

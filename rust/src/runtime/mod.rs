//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced by `make artifacts`) and executes them on the
//! PJRT CPU client via the `xla` crate. This is the bridge that makes the
//! JAX/Pallas layers (L2/L1) callable from the rust coordinator's request
//! path with zero python involvement.
//!
//! * [`manifest`] — artifact metadata (shapes, tile width, dataset).
//! * [`buffers`] — `Mat` ⇄ `Literal`/`PjRtBuffer` transfer helpers.
//! * [`engine`] — [`NmfEngine`](crate::nmf::NmfEngine) implementations
//!   backed by compiled executables: `PlNmfXlaEngine` / `MuXlaEngine`
//!   (the paper's GPU implementations, re-targeted — DESIGN.md §5).
//!
//! Note: `xla::PjRtClient` is `Rc`-backed (not `Send`), so each engine
//! owns its client and must stay on its creating thread — mirroring the
//! one-CUDA-context-per-process structure of the paper's GPU code.

pub mod manifest;
pub mod buffers;
pub mod engine;

pub use manifest::{ArtifactMeta, Manifest};

use crate::Result;

/// Map the xla crate's error into anyhow (it is not `Sync`, so `?` can't
/// cross directly).
pub(crate) fn xe<T>(r: std::result::Result<T, xla::Error>) -> Result<T> {
    r.map_err(|e| anyhow::anyhow!("xla: {e}"))
}

/// Create a PJRT CPU client.
pub fn cpu_client() -> Result<xla::PjRtClient> {
    xe(xla::PjRtClient::cpu())
}

//! `Mat` ⇄ PJRT transfer helpers.

use crate::linalg::Mat;
use crate::Result;

use super::xe;

/// Upload a matrix as a device buffer (row-major f32, same layout XLA
/// expects for a default-layout 2-D parameter).
pub fn upload(client: &xla::PjRtClient, m: &Mat) -> Result<xla::PjRtBuffer> {
    xe(client.buffer_from_host_buffer(m.data(), &[m.rows(), m.cols()], None))
}

/// Download a device buffer into a matrix of known shape.
pub fn download(buf: &xla::PjRtBuffer, rows: usize, cols: usize) -> Result<Mat> {
    let lit = xe(buf.to_literal_sync())?;
    let data = xe(lit.to_vec::<f32>())?;
    anyhow::ensure!(
        data.len() == rows * cols,
        "buffer has {} elements, expected {rows}x{cols}",
        data.len()
    );
    Ok(Mat::from_vec(rows, cols, data))
}

/// Decompose a (possibly tuple) execution result into per-output
/// literals. jax lowers with `return_tuple=True`, so even single outputs
/// arrive as 1-tuples.
pub fn untuple(result: xla::Literal) -> Result<Vec<xla::Literal>> {
    let shape = xe(result.shape())?;
    match shape {
        xla::Shape::Tuple(_) => xe(result.to_tuple()),
        _ => Ok(vec![result]),
    }
}

/// Literal → Mat.
pub fn literal_to_mat(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    let data = xe(lit.to_vec::<f32>())?;
    anyhow::ensure!(data.len() == rows * cols, "literal size mismatch");
    Ok(Mat::from_vec(rows, cols, data))
}

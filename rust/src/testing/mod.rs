//! Mini property-based testing support (proptest is unavailable offline).

pub mod prop;

pub use prop::{Gen, PropConfig};

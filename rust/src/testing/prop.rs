//! A tiny deterministic property-testing harness.
//!
//! No shrinking — when a case fails we print the seed and the generated
//! inputs' description so it can be replayed by constructing the same
//! [`Gen`]. Determinism guarantees CI reproducibility: each trial `i` of a
//! property runs on `Pcg32::new(seed, i)`.
//!
//! ```
//! use plnmf::testing::{Gen, PropConfig};
//! PropConfig::trials(64).run("add is commutative", |g| {
//!     let a = g.usize_in(0, 1000);
//!     let b = g.usize_in(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Pcg32;

/// Per-trial input generator.
pub struct Gen {
    rng: Pcg32,
    pub trial: u64,
    log: Vec<String>,
}

impl Gen {
    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = lo + self.rng.below((hi - lo + 1) as u32) as usize;
        self.log.push(format!("usize_in({lo},{hi}) -> {v}"));
        v
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let v = self.rng.range_f32(lo, hi);
        self.log.push(format!("f32_in({lo},{hi}) -> {v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.below(2) == 1;
        self.log.push(format!("bool -> {v}"));
        v
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        let i = self.rng.below(xs.len() as u32) as usize;
        self.log.push(format!("choose(#{i} of {})", xs.len()));
        &xs[i]
    }

    /// Vector of uniform floats.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let v: Vec<f32> = (0..len).map(|_| self.rng.range_f32(lo, hi)).collect();
        self.log.push(format!("vec_f32(len={len})"));
        v
    }

    /// A fresh RNG derived from this trial (for passing into library code
    /// that wants its own `Pcg32`).
    pub fn rng(&mut self) -> Pcg32 {
        self.rng.split(7777)
    }
}

/// Property runner configuration.
pub struct PropConfig {
    pub trials: u64,
    pub seed: u64,
}

impl PropConfig {
    pub fn trials(n: u64) -> PropConfig {
        // PLNMF_PROP_SEED overrides for replay; PLNMF_PROP_TRIALS scales
        // up for soak runs.
        let seed = std::env::var("PLNMF_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x9e37);
        let trials = std::env::var("PLNMF_PROP_TRIALS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(n);
        PropConfig { trials, seed }
    }

    /// Run `prop` for each trial; panics (with replay info) on failure.
    pub fn run(&self, name: &str, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
        for trial in 0..self.trials {
            let gen_rng = Pcg32::new(self.seed, trial);
            let mut g = Gen { rng: gen_rng, trial, log: Vec::new() };
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
            if let Err(payload) = result {
                eprintln!(
                    "property '{name}' failed at trial {trial} (seed {}):\n  inputs:\n    {}",
                    self.seed,
                    g.log.join("\n    ")
                );
                std::panic::resume_unwind(payload);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_simple_property() {
        PropConfig::trials(32).run("reverse twice is identity", |g| {
            let n = g.usize_in(0, 50);
            let v = g.vec_f32(n, -1.0, 1.0);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    fn reports_failures() {
        let res = std::panic::catch_unwind(|| {
            PropConfig { trials: 10, seed: 1 }.run("always fails at trial 3", |g| {
                assert!(g.trial != 3, "deliberate");
            });
        });
        assert!(res.is_err());
    }

    #[test]
    fn deterministic_inputs_per_trial() {
        let collect = || {
            let out = std::sync::Mutex::new(Vec::new());
            PropConfig { trials: 5, seed: 9 }.run("collect", |g| {
                out.lock().unwrap().push(g.usize_in(0, 1_000_000));
            });
            out.into_inner().unwrap()
        };
        assert_eq!(collect(), collect());
    }
}

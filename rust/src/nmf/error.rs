//! The evaluation metric: relative objective
//! `√(‖A − WH‖²_F / ‖A‖²_F)` (Kim & Park, used by the paper's §6.2.2).
//!
//! Never materializes the V×D product. Expanding the square:
//!
//! ```text
//! ‖A − WH‖² = ‖A‖² − 2·⟨P, W⟩ + ⟨Q, S⟩
//!   P = A·Hᵀ (V×K, the same product the W update needs)
//!   Q = HHᵀ,  S = WᵀW  (K×K Grams)
//!   ⟨X, Y⟩ = Σᵢⱼ XᵢⱼYᵢⱼ
//! ```
//!
//! Cost: one SpMM/GEMM + two Grams — O(nnz·K + (V+D)K²) instead of
//! O(V·D·K).

use crate::data::Dataset;
use crate::linalg::Mat;
use crate::parallel::{reduce, ThreadPool};

use super::products;

/// Compute the relative objective for factors `(w, h)` (h in D×K layout).
pub fn rel_error(pool: &ThreadPool, ds: &Dataset, w: &Mat, h: &Mat) -> f64 {
    let k = w.cols();
    assert_eq!(h.cols(), k);
    let mut p = Mat::zeros(ds.v(), k);
    products::a_times(pool, ds, h, &mut p);
    rel_error_with_p(pool, ds, w, h, &p)
}

/// Variant reusing an already-computed `P = A·H` (the engines have one).
pub fn rel_error_with_p(pool: &ThreadPool, ds: &Dataset, w: &Mat, h: &Mat, p: &Mat) -> f64 {
    let q = products::factor_gram(pool, h);
    rel_error_from_parts(pool, ds.fro2, p, w, &q)
}

/// Fully decomposed variant for callers that never hold the dataset or
/// the full `H` — the distributed coordinator, whose `P = Σ P_s` and
/// `Q = Σ Q_s` arrive as all-reduced partials from the workers. Bitwise
/// identical to [`rel_error_with_p`] given the same `p`/`q`, because the
/// remaining terms (`S = WᵀW`, the two Frobenius inners) depend only on
/// the arguments passed here.
pub fn rel_error_from_parts(pool: &ThreadPool, fro2: f64, p: &Mat, w: &Mat, q: &Mat) -> f64 {
    let s = products::factor_gram(pool, w);

    let pw = frobenius_inner(pool, p, w);
    let qs = frobenius_inner(pool, q, &s);

    let num = (fro2 - 2.0 * pw + qs).max(0.0);
    (num / fro2).sqrt()
}

/// `Σᵢⱼ XᵢⱼYᵢⱼ` with f64 accumulation, row-parallel.
pub fn frobenius_inner(pool: &ThreadPool, x: &Mat, y: &Mat) -> f64 {
    assert_eq!((x.rows(), x.cols()), (y.rows(), y.cols()));
    reduce(
        pool,
        x.rows(),
        |rows| {
            let mut s = 0.0f64;
            for i in rows {
                for (&a, &b) in x.row(i).iter().zip(y.row(i)) {
                    s += a as f64 * b as f64;
                }
            }
            s
        },
        |a, b| a + b,
    )
    .unwrap_or(0.0)
}

/// Naive reference: materializes WH (tests / tiny problems only).
pub fn rel_error_naive(ds: &Dataset, w: &Mat, h: &Mat) -> f64 {
    let a = match &ds.a {
        crate::data::DataMatrix::Sparse(m) => m.to_dense(),
        crate::data::DataMatrix::Dense(m) => m.clone(),
    };
    let mut num = 0.0f64;
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            let mut wh = 0.0f64;
            for t in 0..w.cols() {
                wh += w.at(i, t) as f64 * h.at(j, t) as f64;
            }
            let d = a.at(i, j) as f64 - wh;
            num += d * d;
        }
    }
    (num / ds.fro2).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::load_dataset;
    use crate::nmf::Factors;

    #[test]
    fn gram_trick_matches_naive() {
        let pool = ThreadPool::new(3);
        for name in ["tiny", "tiny-sparse"] {
            let ds = load_dataset(name, 3).unwrap();
            let f = Factors::random(ds.v(), ds.d(), 4, 11);
            let fast = rel_error(&pool, &ds, &f.w, &f.h);
            let slow = rel_error_naive(&ds, &f.w, &f.h);
            assert!(
                (fast - slow).abs() < 1e-4,
                "{name}: gram-trick {fast} vs naive {slow}"
            );
        }
    }

    #[test]
    fn zero_factors_give_error_one() {
        let pool = ThreadPool::new(2);
        let ds = load_dataset("tiny", 1).unwrap();
        let w = Mat::zeros(ds.v(), 3);
        let h = Mat::zeros(ds.d(), 3);
        let e = rel_error(&pool, &ds, &w, &h);
        assert!((e - 1.0).abs() < 1e-6);
    }

    #[test]
    fn perfect_factorization_gives_zero() {
        // Build A = W·Hᵀ exactly, then error must be ~0.
        let pool = ThreadPool::new(2);
        let f = Factors::random(20, 15, 3, 5);
        let mut a = Mat::zeros(20, 15);
        for i in 0..20 {
            for j in 0..15 {
                let mut s = 0.0;
                for t in 0..3 {
                    s += f.w.at(i, t) * f.h.at(j, t);
                }
                *a.at_mut(i, j) = s;
            }
        }
        let at = a.transposed();
        let fro2 = a.fro2();
        let ds = Dataset {
            profile: crate::config::dataset_profile("tiny").unwrap(),
            a: crate::data::DataMatrix::Dense(a),
            at: crate::data::DataMatrix::Dense(at),
            fro2,
        };
        let e = rel_error(&pool, &ds, &f.w, &f.h);
        assert!(e < 1e-3, "error {e}");
    }

    #[test]
    fn frobenius_inner_known() {
        let pool = ThreadPool::new(2);
        let x = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let y = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert!((frobenius_inner(&pool, &x, &y) - 70.0).abs() < 1e-9);
    }
}

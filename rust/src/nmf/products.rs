//! The matrix products every HALS-family step needs:
//! `P = A·H` (V×K), `R = Aᵀ·W` (D×K), `Q = HᵀH`, `S = WᵀW`
//! (with our transposed H storage, `P = A·H_stored` and `Q` is a plain
//! Gram — see `nmf` module docs).
//!
//! Sparse datasets route through the CSR SpMM (the paper's
//! `mkl_dcsrmm`), dense through the blocked GEMM (`cblas_dgemm`).

use crate::data::{DataMatrix, Dataset};
use crate::linalg::{gemm, gram, GemmOp, Mat};
use crate::parallel::ThreadPool;
use crate::sparse::spmm;

/// `out = A · x` where `x` is D×K and `out` V×K.
pub fn a_times(pool: &ThreadPool, ds: &Dataset, x: &Mat, out: &mut Mat) {
    assert_eq!(x.rows(), ds.d());
    assert_eq!((out.rows(), out.cols()), (ds.v(), x.cols()));
    match &ds.a {
        DataMatrix::Sparse(a) => spmm(pool, 1.0, a, x, GemmOp::Assign, &mut out.view_mut()),
        DataMatrix::Dense(a) => {
            gemm(pool, 1.0, a.view(), x.view(), GemmOp::Assign, &mut out.view_mut())
        }
    }
}

/// `out = Aᵀ · x` where `x` is V×K and `out` D×K (uses the resident
/// transpose).
pub fn at_times(pool: &ThreadPool, ds: &Dataset, x: &Mat, out: &mut Mat) {
    assert_eq!(x.rows(), ds.v());
    assert_eq!((out.rows(), out.cols()), (ds.d(), x.cols()));
    match &ds.at {
        DataMatrix::Sparse(at) => spmm(pool, 1.0, at, x, GemmOp::Assign, &mut out.view_mut()),
        DataMatrix::Dense(at) => {
            gemm(pool, 1.0, at.view(), x.view(), GemmOp::Assign, &mut out.view_mut())
        }
    }
}

/// Gram of a tall-skinny factor: `XᵀX` (K×K).
pub fn factor_gram(pool: &ThreadPool, x: &Mat) -> Mat {
    gram(pool, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::load_dataset;
    use crate::linalg::gemm::gemm_naive;
    use crate::util::rng::Pcg32;

    #[test]
    fn products_match_dense_reference() {
        let pool = ThreadPool::new(3);
        for name in ["tiny", "tiny-sparse"] {
            let ds = load_dataset(name, 9).unwrap();
            let mut rng = Pcg32::seeded(1);
            let h = Mat::random(ds.d(), 4, &mut rng, 0.0, 1.0);
            let w = Mat::random(ds.v(), 4, &mut rng, 0.0, 1.0);

            let a_dense = match &ds.a {
                DataMatrix::Sparse(a) => a.to_dense(),
                DataMatrix::Dense(a) => a.clone(),
            };

            let mut p = Mat::zeros(ds.v(), 4);
            a_times(&pool, &ds, &h, &mut p);
            let mut p_ref = Mat::zeros(ds.v(), 4);
            gemm_naive(1.0, a_dense.view(), h.view(), GemmOp::Assign, &mut p_ref.view_mut());
            assert!(p.max_abs_diff(&p_ref) < 1e-2, "{name}: P mismatch");

            let mut r = Mat::zeros(ds.d(), 4);
            at_times(&pool, &ds, &w, &mut r);
            let mut r_ref = Mat::zeros(ds.d(), 4);
            gemm_naive(
                1.0,
                a_dense.transposed().view(),
                w.view(),
                GemmOp::Assign,
                &mut r_ref.view_mut(),
            );
            assert!(r.max_abs_diff(&r_ref) < 1e-2, "{name}: R mismatch");
        }
    }
}

//! Factor initialization (Alg. 1 line 1).
//!
//! All engines in a comparison share the same seeded random init — the
//! paper: “For each dataset, the same randomly initialized non-negative
//! matrices were used for all CPU and GPU implementations.”
//!
//! Beyond the historical seeded-random init, [`Factors::init`] offers
//! **NNDSVD** and **NNDSVDa** (Boutsidis & Gallopoulos 2008; sklearn's
//! `init="nndsvd"/"nndsvda"`): a rank-k truncated SVD of `A` whose
//! positive/negative sections seed the factors, giving a deterministic,
//! data-aware starting point that typically converges in fewer
//! iterations. The SVD here is a from-scratch randomized subspace
//! iteration (seeded Gaussian sketch, two power iterations, small-Gram
//! Jacobi eigensolve) run **entirely serially in f64** — like
//! [`normalize_w_columns`], init-time math is deliberately off the
//! thread pool so the result is bit-identical across thread counts.

use crate::data::{DataMatrix, Dataset};
use crate::linalg::{vector, Mat};
use crate::nmf::spec::Init;
use crate::util::rng::Pcg32;

/// The factor pair. `h` is the transposed layout (D×K); see `nmf` module
/// docs.
#[derive(Clone, Debug)]
pub struct Factors {
    pub w: Mat,
    pub h: Mat,
}

impl Factors {
    /// Uniform `[0,1)` entries; `W` columns then L2-normalized, which
    /// FAST-HALS assumes at iteration entry (it maintains the unit-norm
    /// invariant by re-normalizing after every W update, making
    /// `S_kk = 1` so the H update's `+H_k` term is exact).
    pub fn random(v: usize, d: usize, k: usize, seed: u64) -> Factors {
        let mut rng = Pcg32::new(seed, 77);
        let mut w = Mat::random(v, k, &mut rng, 0.0, 1.0);
        let h = Mat::random(d, k, &mut rng, 0.0, 1.0);
        normalize_w_columns(&mut w);
        Factors { w, h }
    }

    /// Build from pre-existing matrices (model loading), validating the
    /// shared low rank.
    pub fn from_parts(w: Mat, h: Mat) -> crate::Result<Factors> {
        anyhow::ensure!(
            w.cols() == h.cols(),
            "factor rank mismatch: W is {}x{}, H is {}x{}",
            w.rows(),
            w.cols(),
            h.rows(),
            h.cols()
        );
        Ok(Factors { w, h })
    }

    /// Initialize per `init` against the dataset. `Init::Random` is
    /// byte-identical to [`Factors::random`]; the NNDSVD variants read
    /// `A` (deterministically, serially) to compute the seeding SVD.
    /// All variants leave W columns unit-L2-normalized — the invariant
    /// the HALS engines' `Plain` update kind relies on.
    pub fn init(ds: &Dataset, k: usize, seed: u64, init: Init) -> Factors {
        match init {
            Init::Random => Factors::random(ds.v(), ds.d(), k, seed),
            Init::Nndsvd => nndsvd(ds, k, seed, false),
            Init::Nndsvda => nndsvd(ds, k, seed, true),
        }
    }

    pub fn v(&self) -> usize {
        self.w.rows()
    }

    pub fn d(&self) -> usize {
        self.h.rows()
    }

    pub fn k(&self) -> usize {
        self.w.cols()
    }
}

/// L2-normalize every column of `w` (serial; init-time only).
pub fn normalize_w_columns(w: &mut Mat) {
    let k = w.cols();
    let mut norms = vec![0.0f64; k];
    for i in 0..w.rows() {
        let row = w.row(i);
        for (j, &x) in row.iter().enumerate() {
            norms[j] += x as f64 * x as f64;
        }
    }
    let inv: Vec<f32> = norms.iter().map(|&n| 1.0 / n.sqrt().max(1e-30) as f32).collect();
    for i in 0..w.rows() {
        let row = w.row_mut(i);
        for (j, x) in row.iter_mut().enumerate() {
            *x *= inv[j];
        }
    }
    let _ = vector::dot; // module link
}

// ---------------------------------------------------------------------------
// NNDSVD: nonnegative double SVD init (serial, deterministic).
// ---------------------------------------------------------------------------

/// Sketch oversampling of the randomized range finder. k+4 columns make
/// the leading k singular triplets accurate to working precision after
/// two power iterations on the low-effective-rank matrices NMF targets.
const NNDSVD_OVERSAMPLE: usize = 4;

/// `y = M·x` for either storage, serial f64 accumulation.
fn mat_vec_f64(m: &DataMatrix, x: &[f64]) -> Vec<f64> {
    match m {
        DataMatrix::Sparse(a) => {
            let mut y = vec![0.0f64; a.rows()];
            for (i, yi) in y.iter_mut().enumerate() {
                let (cols, vals) = a.row(i);
                let mut acc = 0.0f64;
                for (&c, &v) in cols.iter().zip(vals) {
                    acc += v as f64 * x[c as usize];
                }
                *yi = acc;
            }
            y
        }
        DataMatrix::Dense(a) => {
            let mut y = vec![0.0f64; a.rows()];
            for (i, yi) in y.iter_mut().enumerate() {
                let row = a.row(i);
                let mut acc = 0.0f64;
                for (j, &v) in row.iter().enumerate() {
                    acc += v as f64 * x[j];
                }
                *yi = acc;
            }
            y
        }
    }
}

/// Modified Gram–Schmidt over `cols` in place. Columns that collapse
/// below working precision are zeroed (rank deficiency is handled by
/// the caller's degenerate-component fill).
fn orthonormalize(cols: &mut [Vec<f64>]) {
    for j in 0..cols.len() {
        for i in 0..j {
            let proj: f64 = cols[i].iter().zip(&cols[j]).map(|(&a, &b)| a * b).sum();
            let (head, tail) = cols.split_at_mut(j);
            for (a, b) in tail[0].iter_mut().zip(&head[i]) {
                *a -= proj * b;
            }
        }
        let norm: f64 = cols[j].iter().map(|&x| x * x).sum::<f64>().sqrt();
        if norm > 1e-12 {
            let inv = 1.0 / norm;
            for x in cols[j].iter_mut() {
                *x *= inv;
            }
        } else {
            for x in cols[j].iter_mut() {
                *x = 0.0;
            }
        }
    }
}

/// Cyclic Jacobi eigendecomposition of a small symmetric matrix.
/// Returns (eigenvalues, eigenvectors-as-columns), unsorted.
fn jacobi_eigh(mut a: Vec<Vec<f64>>) -> (Vec<f64>, Vec<Vec<f64>>) {
    let n = a.len();
    let mut vecs = vec![vec![0.0f64; n]; n];
    for (i, row) in vecs.iter_mut().enumerate() {
        row[i] = 1.0;
    }
    let scale: f64 = a
        .iter()
        .map(|row| row.iter().map(|&x| x * x).sum::<f64>())
        .sum::<f64>()
        .sqrt()
        .max(1e-300);
    for _sweep in 0..100 {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += a[p][q] * a[p][q];
            }
        }
        if off.sqrt() <= 1e-14 * scale {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p][q];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let theta = (a[q][q] - a[p][p]) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                for i in 0..n {
                    let (aip, aiq) = (a[i][p], a[i][q]);
                    a[i][p] = c * aip - s * aiq;
                    a[i][q] = s * aip + c * aiq;
                }
                for i in 0..n {
                    let (api, aqi) = (a[p][i], a[q][i]);
                    a[p][i] = c * api - s * aqi;
                    a[q][i] = s * api + c * aqi;
                }
                for row in vecs.iter_mut() {
                    let (vip, viq) = (row[p], row[q]);
                    row[p] = c * vip - s * viq;
                    row[q] = s * vip + c * viq;
                }
            }
        }
    }
    let vals: Vec<f64> = (0..n).map(|i| a[i][i]).collect();
    (vals, vecs)
}

/// Leading-`r` singular triplets of `A` via seeded randomized subspace
/// iteration: sketch, two power passes (each re-orthonormalized), then
/// an exact eigensolve of the projected Gram. Returns
/// `(sigma, u-columns (len V), v-columns (len D))`, descending.
fn truncated_svd(ds: &Dataset, r: usize, seed: u64) -> (Vec<f64>, Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let (v, d) = (ds.v(), ds.d());
    let p = (r + NNDSVD_OVERSAMPLE).min(v.min(d));
    // Stream 78: distinct from the random-init stream (77), so an
    // NNDSVD run never correlates with a random run at the same seed.
    let mut rng = Pcg32::new(seed, 78);
    let mut omega: Vec<Vec<f64>> = Vec::with_capacity(p);
    for _ in 0..p {
        omega.push((0..d).map(|_| rng.next_gaussian()).collect());
    }
    let mut y: Vec<Vec<f64>> = omega.iter().map(|w| mat_vec_f64(&ds.a, w)).collect();
    orthonormalize(&mut y);
    for _ in 0..2 {
        let mut z: Vec<Vec<f64>> = y.iter().map(|q| mat_vec_f64(&ds.at, q)).collect();
        orthonormalize(&mut z);
        y = z.iter().map(|q| mat_vec_f64(&ds.a, q)).collect();
        orthonormalize(&mut y);
    }
    // C = QᵀA (p×D): row i is Aᵀ·qᵢ. G = C·Cᵀ is the projected Gram
    // whose eigenpairs give the singular triplets.
    let c: Vec<Vec<f64>> = y.iter().map(|q| mat_vec_f64(&ds.at, q)).collect();
    let mut g = vec![vec![0.0f64; p]; p];
    for i in 0..p {
        for j in i..p {
            let dot: f64 = c[i].iter().zip(&c[j]).map(|(&a, &b)| a * b).sum();
            g[i][j] = dot;
            g[j][i] = dot;
        }
    }
    let (vals, vecs) = jacobi_eigh(g);
    let mut order: Vec<usize> = (0..p).collect();
    order.sort_by(|&a, &b| vals[b].total_cmp(&vals[a]));

    let r = r.min(p);
    let mut sigma = Vec::with_capacity(r);
    let mut us = Vec::with_capacity(r);
    let mut vs = Vec::with_capacity(r);
    for &e in order.iter().take(r) {
        let s = vals[e].max(0.0).sqrt();
        // u = Q·g_e (length V), v = Cᵀ·g_e / σ (length D).
        let mut u = vec![0.0f64; v];
        for (i, q) in y.iter().enumerate() {
            let w = vecs[i][e];
            if w != 0.0 {
                for (ux, &qx) in u.iter_mut().zip(q) {
                    *ux += w * qx;
                }
            }
        }
        let mut vv = vec![0.0f64; d];
        if s > 1e-12 {
            let inv = 1.0 / s;
            for (i, ci) in c.iter().enumerate() {
                let w = vecs[i][e] * inv;
                if w != 0.0 {
                    for (vx, &cx) in vv.iter_mut().zip(ci) {
                        *vx += w * cx;
                    }
                }
            }
        }
        sigma.push(s);
        us.push(u);
        vs.push(vv);
    }
    (sigma, us, vs)
}

fn norm_f64(x: &[f64]) -> f64 {
    x.iter().map(|&a| a * a).sum::<f64>().sqrt()
}

/// Mean entry of `A` — the NNDSVDa fill value (and the degenerate-
/// component fallback).
fn data_mean(ds: &Dataset) -> f64 {
    let total: f64 = match &ds.a {
        DataMatrix::Sparse(a) => {
            let mut acc = 0.0f64;
            for i in 0..a.rows() {
                let (_, vals) = a.row(i);
                for &x in vals {
                    acc += x as f64;
                }
            }
            acc
        }
        DataMatrix::Dense(a) => a.data().iter().map(|&x| x as f64).sum(),
    };
    let cells = (ds.v() * ds.d()).max(1) as f64;
    total / cells
}

/// NNDSVD(a) proper: positive/negative section split of each singular
/// triplet, the larger section (by its rank-1 mass) seeding the
/// component. Deterministic, serial, non-negative by construction.
fn nndsvd(ds: &Dataset, k: usize, seed: u64, average_fill: bool) -> Factors {
    let (v, d) = (ds.v(), ds.d());
    assert!(k >= 1, "nndsvd needs k >= 1");
    let (sigma, us, vs) = truncated_svd(ds, k, seed);
    let avg = data_mean(ds).max(1e-6);
    let mut w = Mat::zeros(v, k);
    let mut h = Mat::zeros(d, k);

    let mut set_component = |t: usize, wcol: &[f64], hcol: &[f64], scale: f64| {
        let s = scale.sqrt();
        for (i, &x) in wcol.iter().enumerate() {
            *w.at_mut(i, t) = (s * x) as f32;
        }
        for (i, &x) in hcol.iter().enumerate() {
            *h.at_mut(i, t) = (s * x) as f32;
        }
    };

    for t in 0..k {
        if t >= sigma.len() || sigma[t] <= 1e-12 {
            // Rank-deficient tail (or k beyond min(V,D)): a uniform
            // positive component keeps every engine well-defined.
            let wfill = vec![1.0; v];
            let hfill = vec![avg; d];
            set_component(t, &wfill, &hfill, 1.0);
            continue;
        }
        let (u, vv, s) = (&us[t], &vs[t], sigma[t]);
        if t == 0 {
            // The leading pair is non-negative up to a global sign
            // (Perron–Frobenius for the non-negative A): orient it
            // positive and clamp rounding noise.
            let flip = if u.iter().sum::<f64>() < 0.0 { -1.0 } else { 1.0 };
            let up: Vec<f64> = u.iter().map(|&x| (flip * x).max(0.0)).collect();
            let vp: Vec<f64> = vv.iter().map(|&x| (flip * x).max(0.0)).collect();
            set_component(t, &up, &vp, s);
            continue;
        }
        let up: Vec<f64> = u.iter().map(|&x| x.max(0.0)).collect();
        let un: Vec<f64> = u.iter().map(|&x| (-x).max(0.0)).collect();
        let vp: Vec<f64> = vv.iter().map(|&x| x.max(0.0)).collect();
        let vn: Vec<f64> = vv.iter().map(|&x| (-x).max(0.0)).collect();
        let (nup, nun, nvp, nvn) = (norm_f64(&up), norm_f64(&un), norm_f64(&vp), norm_f64(&vn));
        let (mp, mn) = (nup * nvp, nun * nvn);
        let (usec, vsec, unorm, vnorm, m) =
            if mp >= mn { (&up, &vp, nup, nvp, mp) } else { (&un, &vn, nun, nvn, mn) };
        if m <= 1e-24 {
            let wfill = vec![1.0; v];
            let hfill = vec![avg; d];
            set_component(t, &wfill, &hfill, 1.0);
            continue;
        }
        let wcol: Vec<f64> = usec.iter().map(|&x| x / unorm).collect();
        let hcol: Vec<f64> = vsec.iter().map(|&x| x / vnorm).collect();
        set_component(t, &wcol, &hcol, s * m);
    }

    if average_fill {
        // NNDSVDa: zeros become the data mean — multiplicative (MU)
        // updates cannot revive exact zeros, and dense problems start
        // better without the hard sparsity of plain NNDSVD.
        let favg = avg as f32;
        for x in w.data_mut().iter_mut() {
            if *x < 1e-12 {
                *x = favg;
            }
        }
        for x in h.data_mut().iter_mut() {
            if *x < 1e-12 {
                *x = favg;
            }
        }
    }

    // Restore the unit-column-W invariant, moving the scale into H so
    // the product W·Hᵀ is preserved.
    let mut norms = vec![0.0f64; k];
    for i in 0..v {
        for (j, &x) in w.row(i).iter().enumerate() {
            norms[j] += x as f64 * x as f64;
        }
    }
    let scales: Vec<f64> = norms.iter().map(|&n| n.sqrt()).collect();
    for i in 0..v {
        for (j, x) in w.row_mut(i).iter_mut().enumerate() {
            if scales[j] > 1e-30 {
                *x = (*x as f64 / scales[j]) as f32;
            }
        }
    }
    for i in 0..d {
        for (j, x) in h.row_mut(i).iter_mut().enumerate() {
            if scales[j] > 1e-30 {
                *x = (*x as f64 * scales[j]) as f32;
            }
        }
    }
    Factors { w, h }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_nonnegativity() {
        let f = Factors::random(30, 20, 5, 1);
        assert_eq!(f.w.rows(), 30);
        assert_eq!(f.w.cols(), 5);
        assert_eq!(f.h.rows(), 20);
        assert_eq!(f.h.cols(), 5);
        assert!(f.w.data().iter().all(|&x| x >= 0.0));
        assert!(f.h.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn w_columns_unit_norm() {
        let f = Factors::random(100, 10, 7, 3);
        for j in 0..7 {
            let n: f64 = (0..100).map(|i| (f.w.at(i, j) as f64).powi(2)).sum();
            assert!((n - 1.0).abs() < 1e-5, "col {j} norm² {n}");
        }
    }

    #[test]
    fn from_parts_validates_rank() {
        let w = Mat::zeros(5, 3);
        let h = Mat::zeros(4, 3);
        assert!(Factors::from_parts(w, h).is_ok());
        assert!(Factors::from_parts(Mat::zeros(5, 3), Mat::zeros(4, 2)).is_err());
    }

    #[test]
    fn deterministic_in_seed() {
        let a = Factors::random(10, 10, 3, 5);
        let b = Factors::random(10, 10, 3, 5);
        assert_eq!(a.w, b.w);
        assert_eq!(a.h, b.h);
        let c = Factors::random(10, 10, 3, 6);
        assert_ne!(a.w, c.w);
    }

    #[test]
    fn init_random_matches_historical_random() {
        let ds = crate::data::load_dataset("tiny", 3).unwrap();
        let a = Factors::init(&ds, 4, 7, Init::Random);
        let b = Factors::random(ds.v(), ds.d(), 4, 7);
        assert_eq!(a.w, b.w);
        assert_eq!(a.h, b.h);
    }

    #[test]
    fn nndsvd_nonnegative_and_reproducible() {
        for name in ["tiny", "tiny-sparse"] {
            let ds = crate::data::load_dataset(name, 3).unwrap();
            for init in [Init::Nndsvd, Init::Nndsvda] {
                let a = Factors::init(&ds, 4, 7, init);
                assert!(
                    a.w.data().iter().all(|&x| x.is_finite() && x >= 0.0),
                    "{name} {init:?} W has a negative/non-finite entry"
                );
                assert!(
                    a.h.data().iter().all(|&x| x.is_finite() && x >= 0.0),
                    "{name} {init:?} H has a negative/non-finite entry"
                );
                // Serial f64 math ⇒ thread count cannot matter, but the
                // contract is bitwise reproducibility of repeated calls.
                let b = Factors::init(&ds, 4, 7, init);
                assert_eq!(a.w, b.w, "{name} {init:?} W not reproducible");
                assert_eq!(a.h, b.h, "{name} {init:?} H not reproducible");
            }
        }
    }

    #[test]
    fn nndsvd_w_columns_unit_norm() {
        let ds = crate::data::load_dataset("tiny", 3).unwrap();
        let f = Factors::init(&ds, 4, 7, Init::Nndsvda);
        for j in 0..4 {
            let n: f64 = (0..f.v()).map(|i| (f.w.at(i, j) as f64).powi(2)).sum();
            assert!((n - 1.0).abs() < 1e-4, "col {j} norm² {n}");
        }
    }

    #[test]
    fn nndsvd_starts_closer_than_random() {
        let pool = crate::parallel::ThreadPool::new(2);
        let ds = crate::data::load_dataset("tiny", 3).unwrap();
        let rand = Factors::init(&ds, 4, 7, Init::Random);
        let svd = Factors::init(&ds, 4, 7, Init::Nndsvd);
        let e_rand = crate::nmf::error::rel_error(&pool, &ds, &rand.w, &rand.h);
        let e_svd = crate::nmf::error::rel_error(&pool, &ds, &svd.w, &svd.h);
        assert!(
            e_svd < e_rand,
            "NNDSVD start ({e_svd}) should beat random start ({e_rand})"
        );
    }

    #[test]
    fn nndsvd_handles_k_beyond_rank() {
        // k > min(V, D): past-the-rank components fall back to the
        // uniform fill and everything stays finite + non-negative.
        let ds = crate::data::load_dataset("tiny", 3).unwrap();
        let k = ds.v().min(ds.d()) + 1;
        let f = Factors::init(&ds, k, 7, Init::Nndsvd);
        assert_eq!(f.k(), k);
        assert!(f.w.data().iter().all(|&x| x.is_finite() && x >= 0.0));
        assert!(f.h.data().iter().all(|&x| x.is_finite() && x >= 0.0));
    }
}

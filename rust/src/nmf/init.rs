//! Factor initialization (Alg. 1 line 1).
//!
//! All engines in a comparison share the same seeded random init — the
//! paper: “For each dataset, the same randomly initialized non-negative
//! matrices were used for all CPU and GPU implementations.”

use crate::linalg::{vector, Mat};
use crate::util::rng::Pcg32;

/// The factor pair. `h` is the transposed layout (D×K); see `nmf` module
/// docs.
#[derive(Clone, Debug)]
pub struct Factors {
    pub w: Mat,
    pub h: Mat,
}

impl Factors {
    /// Uniform `[0,1)` entries; `W` columns then L2-normalized, which
    /// FAST-HALS assumes at iteration entry (it maintains the unit-norm
    /// invariant by re-normalizing after every W update, making
    /// `S_kk = 1` so the H update's `+H_k` term is exact).
    pub fn random(v: usize, d: usize, k: usize, seed: u64) -> Factors {
        let mut rng = Pcg32::new(seed, 77);
        let mut w = Mat::random(v, k, &mut rng, 0.0, 1.0);
        let h = Mat::random(d, k, &mut rng, 0.0, 1.0);
        normalize_w_columns(&mut w);
        Factors { w, h }
    }

    /// Build from pre-existing matrices (model loading), validating the
    /// shared low rank.
    pub fn from_parts(w: Mat, h: Mat) -> crate::Result<Factors> {
        anyhow::ensure!(
            w.cols() == h.cols(),
            "factor rank mismatch: W is {}x{}, H is {}x{}",
            w.rows(),
            w.cols(),
            h.rows(),
            h.cols()
        );
        Ok(Factors { w, h })
    }

    pub fn v(&self) -> usize {
        self.w.rows()
    }

    pub fn d(&self) -> usize {
        self.h.rows()
    }

    pub fn k(&self) -> usize {
        self.w.cols()
    }
}

/// L2-normalize every column of `w` (serial; init-time only).
pub fn normalize_w_columns(w: &mut Mat) {
    let k = w.cols();
    let mut norms = vec![0.0f64; k];
    for i in 0..w.rows() {
        let row = w.row(i);
        for (j, &x) in row.iter().enumerate() {
            norms[j] += x as f64 * x as f64;
        }
    }
    let inv: Vec<f32> = norms.iter().map(|&n| 1.0 / n.sqrt().max(1e-30) as f32).collect();
    for i in 0..w.rows() {
        let row = w.row_mut(i);
        for (j, x) in row.iter_mut().enumerate() {
            *x *= inv[j];
        }
    }
    let _ = vector::dot; // module link
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_nonnegativity() {
        let f = Factors::random(30, 20, 5, 1);
        assert_eq!(f.w.rows(), 30);
        assert_eq!(f.w.cols(), 5);
        assert_eq!(f.h.rows(), 20);
        assert_eq!(f.h.cols(), 5);
        assert!(f.w.data().iter().all(|&x| x >= 0.0));
        assert!(f.h.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn w_columns_unit_norm() {
        let f = Factors::random(100, 10, 7, 3);
        for j in 0..7 {
            let n: f64 = (0..100).map(|i| (f.w.at(i, j) as f64).powi(2)).sum();
            assert!((n - 1.0).abs() < 1e-5, "col {j} norm² {n}");
        }
    }

    #[test]
    fn from_parts_validates_rank() {
        let w = Mat::zeros(5, 3);
        let h = Mat::zeros(4, 3);
        assert!(Factors::from_parts(w, h).is_ok());
        assert!(Factors::from_parts(Mat::zeros(5, 3), Mat::zeros(4, 2)).is_err());
    }

    #[test]
    fn deterministic_in_seed() {
        let a = Factors::random(10, 10, 3, 5);
        let b = Factors::random(10, 10, 3, 5);
        assert_eq!(a.w, b.w);
        assert_eq!(a.h, b.h);
        let c = Factors::random(10, 10, 3, 6);
        assert_ne!(a.w, c.w);
    }
}

//! [`EngineSpec`] — loss, solver, regularization, and initialization as
//! first-class, serializable model configuration.
//!
//! Every layer that used to hard-wire "Frobenius HALS, random init, no
//! regularization" now threads one plain-old-data value instead: engine
//! constructors take it, `model_io` persists it next to the factors, the
//! manifest can override it per model, the daemon echoes it in `stats`,
//! and the CLI/config surface exposes it as `--loss` / `--alpha` /
//! `--l1_ratio` / `--init` (the sklearn-parity surface: `solver`,
//! `beta_loss`, `alpha_H`, `l1_ratio`, `init`).
//!
//! Compatibility contract: [`EngineSpec::default`] IS today's behavior.
//! A default spec must leave every numeric path bit-for-bit identical to
//! the pre-spec code, every JSON writer byte-compatible (the spec object
//! is only written when non-default), and every reader accepting of
//! spec-less inputs. Present-but-bogus spec fields are loud errors —
//! the same strictness discipline as the rest of the wire/model surface
//! (absent ⇒ default, present ⇒ validated, unknown keys rejected).
//!
//! Regularization semantics: `alpha ≥ 0` and `l1_ratio ∈ [0, 1]` define
//! an elastic-net penalty on the **H factor** (document mixtures):
//!
//! ```text
//! min ½‖A − WH‖² (or KL(A‖WH)) + α·ρ·‖H‖₁ + ½·α·(1−ρ)·‖H‖²_F
//! ```
//!
//! W stays unit-column-normalized in the HALS engines (its Gram keeps
//! the unit diagonal the `Plain` update kind relies on), so this matches
//! sklearn's `alpha_W = 0, alpha_H = α` corner — the classic sparse-H
//! topic-modeling setup. `α = 0` disables both terms exactly.

use anyhow::{anyhow, bail};

use crate::util::json::Json;
use crate::{Elem, Result};

/// Reconstruction loss the factors minimize (and serving projects
/// under).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Loss {
    /// ½‖A − WH‖²_F — squared Euclidean (sklearn `beta_loss="frobenius"`).
    #[default]
    Frobenius,
    /// Generalized Kullback–Leibler divergence D(A‖WH) (sklearn
    /// `beta_loss="kullback-leibler"`).
    Kl,
}

impl Loss {
    pub fn from_str(s: &str) -> Result<Loss> {
        match s.to_ascii_lowercase().as_str() {
            "frobenius" | "fro" | "l2" => Ok(Loss::Frobenius),
            "kl" | "kullback-leibler" | "kullback_leibler" => Ok(Loss::Kl),
            other => bail!("unknown loss '{other}' (expected frobenius|kl)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Loss::Frobenius => "frobenius",
            Loss::Kl => "kl",
        }
    }
}

/// Update rule family of the training engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Solver {
    /// Hierarchical ALS coordinate descent (sklearn `solver="cd"`) —
    /// the FAST-HALS / tiled PL-NMF engines.
    #[default]
    Hals,
    /// Multiplicative updates (sklearn `solver="mu"`) — the only solver
    /// defined for the KL loss.
    Mu,
    /// ANLS with block principal pivoting (exact NNLS subproblems).
    Bpp,
}

impl Solver {
    pub fn from_str(s: &str) -> Result<Solver> {
        match s.to_ascii_lowercase().as_str() {
            "hals" | "cd" => Ok(Solver::Hals),
            "mu" => Ok(Solver::Mu),
            "bpp" | "anls" | "anls-bpp" => Ok(Solver::Bpp),
            other => bail!("unknown solver '{other}' (expected hals|mu|bpp)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Solver::Hals => "hals",
            Solver::Mu => "mu",
            Solver::Bpp => "bpp",
        }
    }
}

/// Factor initialization strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Init {
    /// Seeded uniform random with unit-normalized W columns — the
    /// historical [`crate::nmf::Factors::random`] path.
    #[default]
    Random,
    /// Nonnegative double SVD (Boutsidis & Gallopoulos): zeros stay
    /// zero — good for sparse factors.
    Nndsvd,
    /// NNDSVD with zeros filled by the data mean (sklearn `nndsvda`) —
    /// good for dense factors and mandatory-positive MU updates.
    Nndsvda,
}

impl Init {
    pub fn from_str(s: &str) -> Result<Init> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Ok(Init::Random),
            "nndsvd" => Ok(Init::Nndsvd),
            "nndsvda" => Ok(Init::Nndsvda),
            other => bail!("unknown init '{other}' (expected random|nndsvd|nndsvda)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Init::Random => "random",
            Init::Nndsvd => "nndsvd",
            Init::Nndsvda => "nndsvda",
        }
    }
}

/// The engine specification: one POD value describing what a model's
/// factors optimize and how they were initialized. `Default` is exactly
/// the pre-spec pipeline (Frobenius HALS, no regularization, random
/// init).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct EngineSpec {
    pub loss: Loss,
    pub solver: Solver,
    /// Regularization strength on H (0 = none).
    pub alpha: f64,
    /// L1 share of the penalty: 0 = pure L2 (ridge), 1 = pure L1
    /// (lasso/sparsity).
    pub l1_ratio: f64,
    pub init: Init,
}

impl EngineSpec {
    /// The L1 shrinkage coefficient `α·ρ` in element precision.
    pub fn l1(&self) -> Elem {
        (self.alpha * self.l1_ratio) as Elem
    }

    /// The L2 (ridge) coefficient `α·(1−ρ)` in element precision.
    pub fn l2(&self) -> Elem {
        (self.alpha * (1.0 - self.l1_ratio)) as Elem
    }

    /// The kernel-level shrink pair. `Shrink::NONE` (the bit-exact
    /// unregularized path) if and only if `alpha == 0`.
    pub fn shrink(&self) -> crate::nmf::halsops::Shrink {
        crate::nmf::halsops::Shrink { l1: self.l1(), l2: self.l2() }
    }

    pub fn is_default(&self) -> bool {
        *self == EngineSpec::default()
    }

    pub fn validate(&self) -> Result<()> {
        if !self.alpha.is_finite() || self.alpha < 0.0 {
            bail!("spec: alpha must be finite and >= 0, got {}", self.alpha);
        }
        if !self.l1_ratio.is_finite() || !(0.0..=1.0).contains(&self.l1_ratio) {
            bail!("spec: l1_ratio must be in [0, 1], got {}", self.l1_ratio);
        }
        if self.loss == Loss::Kl && self.solver != Solver::Mu {
            bail!(
                "spec: the kl loss is only defined for the mu solver (got solver '{}')",
                self.solver.name()
            );
        }
        Ok(())
    }

    /// Serialize as a JSON object (all five fields, explicit).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("loss", Json::str(self.loss.name())),
            ("solver", Json::str(self.solver.name())),
            ("alpha", Json::num(self.alpha)),
            ("l1_ratio", Json::num(self.l1_ratio)),
            ("init", Json::str(self.init.name())),
        ])
    }

    /// Parse a spec object. `Null` (absent) is the default spec; any
    /// present field is strictly validated; unknown fields are rejected
    /// — a typoed `"l1ratio"` must never silently mean "no
    /// regularization".
    pub fn from_json(j: &Json) -> Result<EngineSpec> {
        if j.is_null() {
            return Ok(EngineSpec::default());
        }
        let obj = j.as_obj().ok_or_else(|| anyhow!("spec must be a JSON object, got {j}"))?;
        for key in obj.keys() {
            if !matches!(key.as_str(), "loss" | "solver" | "alpha" | "l1_ratio" | "init") {
                bail!("spec has unknown field \"{key}\"");
            }
        }
        let mut spec = EngineSpec::default();
        if let Some(v) = obj.get("loss") {
            let s = v.as_str().ok_or_else(|| anyhow!("spec \"loss\" must be a string"))?;
            spec.loss = Loss::from_str(s)?;
        }
        if let Some(v) = obj.get("solver") {
            let s = v.as_str().ok_or_else(|| anyhow!("spec \"solver\" must be a string"))?;
            spec.solver = Solver::from_str(s)?;
        }
        if let Some(v) = obj.get("alpha") {
            spec.alpha =
                v.as_f64().ok_or_else(|| anyhow!("spec \"alpha\" must be a number"))?;
        }
        if let Some(v) = obj.get("l1_ratio") {
            spec.l1_ratio =
                v.as_f64().ok_or_else(|| anyhow!("spec \"l1_ratio\" must be a number"))?;
        }
        if let Some(v) = obj.get("init") {
            let s = v.as_str().ok_or_else(|| anyhow!("spec \"init\" must be a string"))?;
            spec.init = Init::from_str(s)?;
        }
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_the_pre_spec_pipeline() {
        let s = EngineSpec::default();
        assert_eq!(s.loss, Loss::Frobenius);
        assert_eq!(s.solver, Solver::Hals);
        assert_eq!(s.alpha, 0.0);
        assert_eq!(s.l1_ratio, 0.0);
        assert_eq!(s.init, Init::Random);
        assert!(s.is_default());
        assert_eq!(s.l1(), 0.0);
        assert_eq!(s.l2(), 0.0);
        s.validate().unwrap();
    }

    #[test]
    fn l1_l2_split_follows_l1_ratio() {
        let s = EngineSpec { alpha: 0.8, l1_ratio: 0.25, ..Default::default() };
        assert!((s.l1() - 0.2).abs() < 1e-7);
        assert!((s.l2() - 0.6).abs() < 1e-7);
        let lasso = EngineSpec { alpha: 0.5, l1_ratio: 1.0, ..Default::default() };
        assert_eq!(lasso.l2(), 0.0);
        let ridge = EngineSpec { alpha: 0.5, l1_ratio: 0.0, ..Default::default() };
        assert_eq!(ridge.l1(), 0.0);
    }

    #[test]
    fn json_roundtrip() {
        let s = EngineSpec {
            loss: Loss::Kl,
            solver: Solver::Mu,
            alpha: 0.1,
            l1_ratio: 0.5,
            init: Init::Nndsvda,
        };
        let re = EngineSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(re, s);
        // Absent spec is the default spec.
        assert_eq!(EngineSpec::from_json(&Json::Null).unwrap(), EngineSpec::default());
        // Partial objects fill the rest with defaults.
        let partial = Json::parse(r#"{"alpha": 0.3}"#).unwrap();
        let p = EngineSpec::from_json(&partial).unwrap();
        assert_eq!(p.alpha, 0.3);
        assert_eq!(p.loss, Loss::Frobenius);
    }

    #[test]
    fn from_json_rejects_bogus_fields() {
        for bad in [
            r#"{"l1ratio": 0.5}"#,                     // typo key
            r#"{"loss": "poisson"}"#,                  // unknown loss
            r#"{"solver": "sgd"}"#,                    // unknown solver
            r#"{"init": "zeros"}"#,                    // unknown init
            r#"{"alpha": "lots"}"#,                    // wrong type
            r#"{"alpha": -1.0}"#,                      // negative
            r#"{"l1_ratio": 1.5}"#,                    // out of range
            r#"{"loss": "kl", "solver": "hals"}"#,     // kl needs mu
            r#"{"loss": "kl", "solver": "bpp"}"#,      // kl needs mu
            r#"[1,2]"#,                                // not an object
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(EngineSpec::from_json(&j).is_err(), "should reject {bad}");
        }
        // kl + mu is the valid KL combination.
        let ok = Json::parse(r#"{"loss": "kl", "solver": "mu"}"#).unwrap();
        assert_eq!(EngineSpec::from_json(&ok).unwrap().loss, Loss::Kl);
    }

    #[test]
    fn enum_aliases_parse() {
        assert_eq!(Loss::from_str("KULLBACK-LEIBLER").unwrap(), Loss::Kl);
        assert_eq!(Loss::from_str("fro").unwrap(), Loss::Frobenius);
        assert_eq!(Solver::from_str("cd").unwrap(), Solver::Hals);
        assert_eq!(Solver::from_str("anls-bpp").unwrap(), Solver::Bpp);
        assert_eq!(Init::from_str("NNDSVDA").unwrap(), Init::Nndsvda);
        assert!(Loss::from_str("itakura-saito").is_err());
    }
}

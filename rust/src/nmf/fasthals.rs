//! Naive FAST-HALS engine (Alg. 1 verbatim) — the `planc-HALS-cpu`
//! baseline of Figs. 7–9 and the “Sequential FAST-HALS NMF” column of
//! Table 5.
//!
//! Timer keys: `spmm_r`, `gram_s`, `h_dmv` (H update);
//! `spmm_p`, `gram_q`, `w_dmv` (W update).

use std::sync::Arc;

use crate::data::Dataset;
use crate::linalg::Mat;
use crate::parallel::ThreadPool;
use crate::util::PhaseTimers;
use crate::Result;

use super::halsops::{update_naive, update_naive_reg, UpdateKind};
use super::products;
use super::spec::{EngineSpec, Loss};
use super::traits::{EngineCtx, NmfEngine};
use super::Factors;

pub struct FastHalsEngine {
    ctx: EngineCtx,
    r: Mat,
    p: Mat,
}

impl FastHalsEngine {
    pub fn new(ds: Arc<Dataset>, pool: Arc<ThreadPool>, k: usize, seed: u64) -> Self {
        FastHalsEngine::with_spec(ds, pool, k, seed, EngineSpec::default())
    }

    /// Construct with an [`EngineSpec`]: the init strategy seeds the
    /// factors and the elastic-net shrink applies to the H update. The
    /// KL loss has no HALS rule — reject it here rather than silently
    /// optimizing the wrong objective.
    pub fn with_spec(
        ds: Arc<Dataset>,
        pool: Arc<ThreadPool>,
        k: usize,
        seed: u64,
        spec: EngineSpec,
    ) -> Self {
        assert!(
            spec.loss != Loss::Kl,
            "the HALS solver is Frobenius-only; use the mu solver for kl"
        );
        let ctx = EngineCtx::with_spec(ds, pool, k, seed, spec);
        let (r, p) = ctx.buffers();
        FastHalsEngine { ctx, r, p }
    }

    /// Replace the factors (used by equivalence tests and the
    /// coordinator's shared-init comparisons).
    pub fn set_factors(&mut self, f: Factors) {
        self.ctx.factors = f;
    }
}

impl NmfEngine for FastHalsEngine {
    fn name(&self) -> &'static str {
        "fasthals-cpu"
    }

    fn step(&mut self) -> Result<()> {
        let EngineCtx { ds, pool, factors, timers, spec } = &mut self.ctx;
        let shrink = spec.shrink();

        // ---- update H (Alg. 1 lines 4–8) --------------------------------
        timers.time("spmm_r", || products::at_times(pool, ds, &factors.w, &mut self.r));
        let s = timers.time("gram_s", || products::factor_gram(pool, &factors.w));
        update_naive_reg(pool, &mut factors.h, &s, &self.r, UpdateKind::Plain, shrink, timers, "h_dmv");

        // ---- update W (Alg. 1 lines 10–16) ------------------------------
        timers.time("spmm_p", || products::a_times(pool, ds, &factors.h, &mut self.p));
        let q = timers.time("gram_q", || products::factor_gram(pool, &factors.h));
        update_naive(
            pool,
            &mut factors.w,
            &q,
            &self.p,
            UpdateKind::WithDiagAndNorm,
            timers,
            "w_dmv",
        );
        Ok(())
    }

    fn factors(&self) -> &Factors {
        &self.ctx.factors
    }

    fn timers(&self) -> &PhaseTimers {
        &self.ctx.timers
    }

    fn reset_timers(&mut self) {
        self.ctx.timers.reset();
    }

    fn dataset(&self) -> &Dataset {
        &self.ctx.ds
    }

    fn pool(&self) -> &ThreadPool {
        &self.ctx.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::load_dataset;

    #[test]
    fn error_decreases_monotonically_enough() {
        let ds = Arc::new(load_dataset("tiny", 3).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = FastHalsEngine::new(ds, pool, 4, 42);
        let trace = e.run(20, 1, 0.0).unwrap();
        let first = trace.first().unwrap().rel_error;
        let last = trace.last().unwrap().rel_error;
        assert!(last < first * 0.9, "error {first} -> {last}");
        // HALS is monotone non-increasing up to fp noise.
        for w in trace.windows(2) {
            assert!(w[1].rel_error <= w[0].rel_error + 1e-4);
        }
    }

    #[test]
    fn w_columns_stay_unit_norm() {
        let ds = Arc::new(load_dataset("tiny-sparse", 1).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = FastHalsEngine::new(ds, pool, 3, 7);
        for _ in 0..5 {
            e.step().unwrap();
        }
        let w = &e.factors().w;
        for j in 0..3 {
            let n: f64 = (0..w.rows()).map(|i| (w.at(i, j) as f64).powi(2)).sum();
            assert!((n - 1.0).abs() < 1e-4, "col {j} norm² {n}");
        }
    }

    #[test]
    fn default_spec_is_bit_identical_to_new() {
        let ds = Arc::new(load_dataset("tiny", 3).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut a = FastHalsEngine::new(ds.clone(), pool.clone(), 4, 42);
        let mut b = FastHalsEngine::with_spec(ds, pool, 4, 42, EngineSpec::default());
        for _ in 0..5 {
            a.step().unwrap();
            b.step().unwrap();
        }
        assert_eq!(a.factors().w, b.factors().w);
        assert_eq!(a.factors().h, b.factors().h);
    }

    #[test]
    fn l1_regularization_sparsifies_h() {
        let ds = Arc::new(load_dataset("tiny-sparse", 3).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let spec = EngineSpec { alpha: 0.5, l1_ratio: 1.0, ..Default::default() };
        let mut free = FastHalsEngine::new(ds.clone(), pool.clone(), 4, 42);
        let mut reg = FastHalsEngine::with_spec(ds, pool, 4, 42, spec);
        for _ in 0..10 {
            free.step().unwrap();
            reg.step().unwrap();
        }
        let floor = |m: &crate::linalg::Mat| {
            m.data().iter().filter(|&&v| v <= crate::EPS).count()
        };
        assert!(
            floor(&reg.factors().h) > floor(&free.factors().h),
            "regularized H floored {} entries vs {} unregularized",
            floor(&reg.factors().h),
            floor(&free.factors().h)
        );
        // W stays unit-norm: regularization targets H only.
        let w = &reg.factors().w;
        for j in 0..4 {
            let n: f64 = (0..w.rows()).map(|i| (w.at(i, j) as f64).powi(2)).sum();
            assert!((n - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn nndsvd_init_runs_and_converges() {
        let ds = Arc::new(load_dataset("tiny", 3).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let spec = EngineSpec { init: crate::nmf::Init::Nndsvda, ..Default::default() };
        let mut e = FastHalsEngine::with_spec(ds, pool, 4, 42, spec);
        let trace = e.run(10, 1, 0.0).unwrap();
        assert!(trace.last().unwrap().rel_error < trace[0].rel_error);
    }

    #[test]
    fn timers_populated() {
        let ds = Arc::new(load_dataset("tiny", 2).unwrap());
        let pool = Arc::new(ThreadPool::new(1));
        let mut e = FastHalsEngine::new(ds, pool, 3, 1);
        e.step().unwrap();
        for key in ["spmm_r", "gram_s", "h_dmv", "spmm_p", "gram_q", "w_dmv"] {
            assert_eq!(e.timers().count(key), 1, "{key}");
        }
        e.reset_timers();
        assert_eq!(e.timers().count("w_dmv"), 0);
    }
}

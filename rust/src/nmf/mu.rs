//! Multiplicative-update engine (Lee & Seung) — the `planc-MU-cpu`
//! baseline, and (through the XLA path) the bionmf-MU-gpu stand-in.
//!
//! ```text
//! H ← H ⊙ (AᵀW) ⊘ (H·WᵀW + δ)        (our storage: Ht ⊙ R ⊘ (Ht·S + δ))
//! W ← W ⊙ (AHᵀ) ⊘ (W·HHᵀ + δ)        (            W  ⊙ P ⊘ (W·Q + δ))
//! ```
//!
//! Timer keys: `spmm_r`, `gram_s`, `h_mu`, `spmm_p`, `gram_q`, `w_mu`.

use std::sync::Arc;

use crate::data::Dataset;
use crate::linalg::Mat;
use crate::parallel::ThreadPool;
use crate::util::PhaseTimers;
use crate::Result;

use super::halsops::{SharedRows, Shrink};
use super::products;
use super::spec::{EngineSpec, Loss};
use super::traits::{EngineCtx, NmfEngine};
use super::Factors;

/// Denominator guard (bionmf-style).
const DELTA: f32 = 1e-9;

pub struct MuEngine {
    ctx: EngineCtx,
    r: Mat,
    p: Mat,
}

impl MuEngine {
    pub fn new(ds: Arc<Dataset>, pool: Arc<ThreadPool>, k: usize, seed: u64) -> Self {
        MuEngine::with_spec(ds, pool, k, seed, EngineSpec::default())
    }

    /// Construct with an [`EngineSpec`]. This engine implements the
    /// Frobenius MU rules; the KL rules live in `MuKlEngine` (the driver
    /// picks between them from the spec's loss).
    pub fn with_spec(
        ds: Arc<Dataset>,
        pool: Arc<ThreadPool>,
        k: usize,
        seed: u64,
        spec: EngineSpec,
    ) -> Self {
        assert!(
            spec.loss != Loss::Kl,
            "MuEngine is the Frobenius MU engine; use MuKlEngine for kl"
        );
        let ctx = EngineCtx::with_spec(ds, pool, k, seed, spec);
        let (r, p) = ctx.buffers();
        MuEngine { ctx, r, p }
    }

    pub fn set_factors(&mut self, f: Factors) {
        self.ctx.factors = f;
    }
}

/// `x[i][t] *= num[i][t] / (Σ_j x[i][j]·g[j][t] + δ)` for all rows in
/// parallel (rows are independent in MU — the denominator uses the
/// *pre-update* row, so each row buffers its denominator first).
/// `pub(crate)` so the distributed sweep reuses the exact kernel.
pub(crate) fn mu_update(pool: &ThreadPool, x: &mut Mat, g: &Mat, num: &Mat) {
    mu_update_reg(pool, x, g, num, Shrink::NONE);
}

/// [`mu_update`] with the elastic-net terms folded into the denominator
/// (the sklearn MU regularization: `denom += l1 + l2·x`). `Shrink::NONE`
/// is the identical (bit-for-bit) unregularized path.
pub(crate) fn mu_update_reg(pool: &ThreadPool, x: &mut Mat, g: &Mat, num: &Mat, shrink: Shrink) {
    let k = x.cols();
    let reg = !shrink.is_none();
    let Shrink { l1, l2 } = shrink;
    let kern = pool.kernels();
    let xs = SharedRows::new(x);
    pool.parallel_for(num.rows(), None, |rows| {
        let mut denom = vec![0.0f32; k];
        for i in rows {
            let xrow = unsafe { xs.row_mut(i) };
            // denom = xrow · G (G symmetric ⇒ rows are columns).
            for t in 0..k {
                denom[t] = (kern.dot)(xrow, g.row(t)) + DELTA;
                if reg {
                    denom[t] += l1 + l2 * xrow[t];
                }
            }
            let nrow = num.row(i);
            for t in 0..k {
                xrow[t] *= nrow[t] / denom[t];
            }
        }
    });
}

impl NmfEngine for MuEngine {
    fn name(&self) -> &'static str {
        "mu-cpu"
    }

    fn step(&mut self) -> Result<()> {
        let EngineCtx { ds, pool, factors, timers, spec } = &mut self.ctx;
        let shrink = spec.shrink();

        timers.time("spmm_r", || products::at_times(pool, ds, &factors.w, &mut self.r));
        let s = timers.time("gram_s", || products::factor_gram(pool, &factors.w));
        timers.time("h_mu", || mu_update_reg(pool, &mut factors.h, &s, &self.r, shrink));

        timers.time("spmm_p", || products::a_times(pool, ds, &factors.h, &mut self.p));
        let q = timers.time("gram_q", || products::factor_gram(pool, &factors.h));
        timers.time("w_mu", || mu_update(pool, &mut factors.w, &q, &self.p));
        Ok(())
    }

    fn factors(&self) -> &Factors {
        &self.ctx.factors
    }

    fn timers(&self) -> &PhaseTimers {
        &self.ctx.timers
    }

    fn reset_timers(&mut self) {
        self.ctx.timers.reset();
    }

    fn dataset(&self) -> &Dataset {
        &self.ctx.ds
    }

    fn pool(&self) -> &ThreadPool {
        &self.ctx.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::load_dataset;

    #[test]
    fn error_decreases() {
        let ds = Arc::new(load_dataset("tiny", 3).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = MuEngine::new(ds, pool, 4, 42);
        let trace = e.run(30, 1, 0.0).unwrap();
        let (first, last) = (trace[0].rel_error, trace.last().unwrap().rel_error);
        assert!(last < first, "{first} -> {last}");
        // MU is monotone non-increasing in exact arithmetic.
        for w in trace.windows(2) {
            assert!(w[1].rel_error <= w[0].rel_error + 1e-4);
        }
    }

    #[test]
    fn preserves_nonnegativity_and_zero_locking() {
        let ds = Arc::new(load_dataset("tiny-sparse", 5).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = MuEngine::new(ds, pool, 3, 1);
        for _ in 0..5 {
            e.step().unwrap();
        }
        assert!(e.factors().w.data().iter().all(|&x| x >= 0.0));
        assert!(e.factors().h.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn regularization_shrinks_h_mass() {
        let ds = Arc::new(load_dataset("tiny", 3).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let spec = EngineSpec { alpha: 0.5, l1_ratio: 0.5, ..Default::default() };
        let mut free = MuEngine::new(ds.clone(), pool.clone(), 4, 42);
        let mut reg = MuEngine::with_spec(ds, pool, 4, 42, spec);
        for _ in 0..10 {
            free.step().unwrap();
            reg.step().unwrap();
        }
        let mass = |m: &Mat| m.data().iter().map(|&x| x as f64).sum::<f64>();
        assert!(
            mass(&reg.factors().h) < mass(&free.factors().h),
            "regularized H mass {} vs free {}",
            mass(&reg.factors().h),
            mass(&free.factors().h)
        );
    }

    #[test]
    fn default_spec_is_bit_identical_to_new() {
        let ds = Arc::new(load_dataset("tiny-sparse", 5).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut a = MuEngine::new(ds.clone(), pool.clone(), 3, 1);
        let mut b = MuEngine::with_spec(ds, pool, 3, 1, EngineSpec::default());
        for _ in 0..4 {
            a.step().unwrap();
            b.step().unwrap();
        }
        assert_eq!(a.factors().w, b.factors().w);
        assert_eq!(a.factors().h, b.factors().h);
    }

    #[test]
    fn converges_slower_than_hals_per_iteration() {
        // The Fig. 8 qualitative claim: after the same iteration budget,
        // MU's relative error is above FAST-HALS's.
        use crate::nmf::fasthals::FastHalsEngine;
        let ds = Arc::new(load_dataset("tiny", 9).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut mu = MuEngine::new(ds.clone(), pool.clone(), 4, 7);
        let mut hals = FastHalsEngine::new(ds, pool, 4, 7);
        let tm = mu.run(15, 15, 0.0).unwrap();
        let th = hals.run(15, 15, 0.0).unwrap();
        assert!(
            th.last().unwrap().rel_error <= tm.last().unwrap().rel_error + 1e-6,
            "hals {} vs mu {}",
            th.last().unwrap().rel_error,
            tm.last().unwrap().rel_error
        );
    }
}

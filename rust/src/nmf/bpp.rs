//! ANLS-BPP engine (Kim & Park) — the `planc-BPP-cpu` baseline.
//!
//! Alternating non-negative least squares: each half-step solves the
//! *exact* NNLS subproblem for one factor with the other fixed, via the
//! block-principal-pivoting solver in [`super::nnls`]:
//!
//! ```text
//! H ← argmin_{H≥0} ‖A − WH‖²    (rows of Ht: G = WᵀW = S, rhs = AᵀW = R)
//! W ← argmin_{W≥0} ‖A − WH‖²    (rows of W:  G = HHᵀ = Q, rhs = AHᵀ = P)
//! ```
//!
//! Per-iteration cost is much higher than HALS (repeated Cholesky solves)
//! but per-iteration error decrease is at least as large — the Fig. 7/8
//! trade-off the paper reports.
//!
//! Timer keys: `spmm_r`, `gram_s`, `h_bpp`, `spmm_p`, `gram_q`, `w_bpp`.

use std::sync::Arc;

use crate::data::Dataset;
use crate::linalg::Mat;
use crate::parallel::ThreadPool;
use crate::util::PhaseTimers;
use crate::Result;

use super::nnls::nnls_bpp_rows;
use super::products;
use super::traits::{EngineCtx, NmfEngine};
use super::Factors;

pub struct BppEngine {
    ctx: EngineCtx,
    r: Mat,
    p: Mat,
}

impl BppEngine {
    pub fn new(ds: Arc<Dataset>, pool: Arc<ThreadPool>, k: usize, seed: u64) -> Self {
        let ctx = EngineCtx::new(ds, pool, k, seed);
        let (r, p) = ctx.buffers();
        BppEngine { ctx, r, p }
    }

    pub fn set_factors(&mut self, f: Factors) {
        self.ctx.factors = f;
    }
}

impl NmfEngine for BppEngine {
    fn name(&self) -> &'static str {
        "bpp-cpu"
    }

    fn step(&mut self) -> Result<()> {
        let EngineCtx { ds, pool, factors, timers } = &mut self.ctx;

        timers.time("spmm_r", || products::at_times(pool, ds, &factors.w, &mut self.r));
        let s = timers.time("gram_s", || products::factor_gram(pool, &factors.w));
        timers.time("h_bpp", || nnls_bpp_rows(pool, &s, &self.r, &mut factors.h));

        timers.time("spmm_p", || products::a_times(pool, ds, &factors.h, &mut self.p));
        let q = timers.time("gram_q", || products::factor_gram(pool, &factors.h));
        timers.time("w_bpp", || nnls_bpp_rows(pool, &q, &self.p, &mut factors.w));
        Ok(())
    }

    fn factors(&self) -> &Factors {
        &self.ctx.factors
    }

    fn timers(&self) -> &PhaseTimers {
        &self.ctx.timers
    }

    fn reset_timers(&mut self) {
        self.ctx.timers.reset();
    }

    fn dataset(&self) -> &Dataset {
        &self.ctx.ds
    }

    fn pool(&self) -> &ThreadPool {
        &self.ctx.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::load_dataset;

    #[test]
    fn error_decreases_monotonically() {
        // ANLS solves each subproblem exactly ⇒ objective is monotone
        // non-increasing.
        let ds = Arc::new(load_dataset("tiny", 3).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = BppEngine::new(ds, pool, 4, 42);
        let trace = e.run(8, 1, 0.0).unwrap();
        for w in trace.windows(2) {
            assert!(
                w[1].rel_error <= w[0].rel_error + 1e-5,
                "{} -> {}",
                w[0].rel_error,
                w[1].rel_error
            );
        }
        assert!(trace.last().unwrap().rel_error < trace[0].rel_error * 0.9);
    }

    #[test]
    fn factors_nonnegative() {
        let ds = Arc::new(load_dataset("tiny-sparse", 2).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = BppEngine::new(ds, pool, 3, 9);
        for _ in 0..3 {
            e.step().unwrap();
        }
        assert!(e.factors().w.data().iter().all(|&x| x >= 0.0));
        assert!(e.factors().h.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn per_iteration_error_at_least_hals_quality() {
        // ANLS' exact subproblem solves should reach ≤ HALS error after
        // the same small iteration count (Fig. 8: BPP's per-iteration
        // quality is comparable; its weakness is per-iteration cost).
        use crate::nmf::fasthals::FastHalsEngine;
        let ds = Arc::new(load_dataset("tiny", 11).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut bpp = BppEngine::new(ds.clone(), pool.clone(), 4, 5);
        let mut hals = FastHalsEngine::new(ds, pool, 4, 5);
        let tb = bpp.run(10, 10, 0.0).unwrap();
        let th = hals.run(10, 10, 0.0).unwrap();
        let (eb, eh) = (tb.last().unwrap().rel_error, th.last().unwrap().rel_error);
        assert!(eb <= eh * 1.1 + 1e-3, "bpp {eb} vs hals {eh}");
    }
}

//! ANLS-BPP engine (Kim & Park) — the `planc-BPP-cpu` baseline.
//!
//! Alternating non-negative least squares: each half-step solves the
//! *exact* NNLS subproblem for one factor with the other fixed, via the
//! block-principal-pivoting solver in [`super::nnls`]:
//!
//! ```text
//! H ← argmin_{H≥0} ‖A − WH‖²    (rows of Ht: G = WᵀW = S, rhs = AᵀW = R)
//! W ← argmin_{W≥0} ‖A − WH‖²    (rows of W:  G = HHᵀ = Q, rhs = AHᵀ = P)
//! ```
//!
//! Per-iteration cost is much higher than HALS (repeated Cholesky solves)
//! but per-iteration error decrease is at least as large — the Fig. 7/8
//! trade-off the paper reports.
//!
//! Timer keys: `spmm_r`, `gram_s`, `h_bpp`, `spmm_p`, `gram_q`, `w_bpp`.

use std::sync::Arc;

use crate::data::Dataset;
use crate::linalg::Mat;
use crate::parallel::ThreadPool;
use crate::util::PhaseTimers;
use crate::Result;

use super::nnls::{nnls_bpp_rows, nnls_bpp_rows_reg};
use super::products;
use super::spec::{EngineSpec, Loss};
use super::traits::{EngineCtx, NmfEngine};
use super::Factors;

pub struct BppEngine {
    ctx: EngineCtx,
    r: Mat,
    p: Mat,
}

impl BppEngine {
    pub fn new(ds: Arc<Dataset>, pool: Arc<ThreadPool>, k: usize, seed: u64) -> Self {
        BppEngine::with_spec(ds, pool, k, seed, EngineSpec::default())
    }

    /// Construct with an [`EngineSpec`]: the H half-step solves the
    /// exact elastic-net NNLS subproblem. The KL loss has no least-
    /// squares subproblem and is rejected.
    pub fn with_spec(
        ds: Arc<Dataset>,
        pool: Arc<ThreadPool>,
        k: usize,
        seed: u64,
        spec: EngineSpec,
    ) -> Self {
        assert!(
            spec.loss != Loss::Kl,
            "the BPP solver is Frobenius-only; use the mu solver for kl"
        );
        let ctx = EngineCtx::with_spec(ds, pool, k, seed, spec);
        let (r, p) = ctx.buffers();
        BppEngine { ctx, r, p }
    }

    pub fn set_factors(&mut self, f: Factors) {
        self.ctx.factors = f;
    }
}

impl NmfEngine for BppEngine {
    fn name(&self) -> &'static str {
        "bpp-cpu"
    }

    fn step(&mut self) -> Result<()> {
        let EngineCtx { ds, pool, factors, timers, spec } = &mut self.ctx;
        let shrink = spec.shrink();

        timers.time("spmm_r", || products::at_times(pool, ds, &factors.w, &mut self.r));
        let s = timers.time("gram_s", || products::factor_gram(pool, &factors.w));
        timers.time("h_bpp", || nnls_bpp_rows_reg(pool, &s, &self.r, &mut factors.h, shrink));

        timers.time("spmm_p", || products::a_times(pool, ds, &factors.h, &mut self.p));
        let q = timers.time("gram_q", || products::factor_gram(pool, &factors.h));
        timers.time("w_bpp", || nnls_bpp_rows(pool, &q, &self.p, &mut factors.w));
        Ok(())
    }

    fn factors(&self) -> &Factors {
        &self.ctx.factors
    }

    fn timers(&self) -> &PhaseTimers {
        &self.ctx.timers
    }

    fn reset_timers(&mut self) {
        self.ctx.timers.reset();
    }

    fn dataset(&self) -> &Dataset {
        &self.ctx.ds
    }

    fn pool(&self) -> &ThreadPool {
        &self.ctx.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::load_dataset;

    #[test]
    fn error_decreases_monotonically() {
        // ANLS solves each subproblem exactly ⇒ objective is monotone
        // non-increasing.
        let ds = Arc::new(load_dataset("tiny", 3).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = BppEngine::new(ds, pool, 4, 42);
        let trace = e.run(8, 1, 0.0).unwrap();
        for w in trace.windows(2) {
            assert!(
                w[1].rel_error <= w[0].rel_error + 1e-5,
                "{} -> {}",
                w[0].rel_error,
                w[1].rel_error
            );
        }
        assert!(trace.last().unwrap().rel_error < trace[0].rel_error * 0.9);
    }

    #[test]
    fn factors_nonnegative() {
        let ds = Arc::new(load_dataset("tiny-sparse", 2).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = BppEngine::new(ds, pool, 3, 9);
        for _ in 0..3 {
            e.step().unwrap();
        }
        assert!(e.factors().w.data().iter().all(|&x| x >= 0.0));
        assert!(e.factors().h.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn default_spec_is_bit_identical_to_new() {
        let ds = Arc::new(load_dataset("tiny-sparse", 2).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut a = BppEngine::new(ds.clone(), pool.clone(), 3, 9);
        let mut b = BppEngine::with_spec(ds, pool, 3, 9, EngineSpec::default());
        for _ in 0..4 {
            a.step().unwrap();
            b.step().unwrap();
        }
        assert_eq!(a.factors().w, b.factors().w);
        assert_eq!(a.factors().h, b.factors().h);
    }

    #[test]
    fn l1_regularization_sparsifies_h() {
        // Pure L1 in the exact NNLS subproblem zeroes coordinates whose
        // dual never clears the shift — strictly more exact zeros than
        // the unregularized solve (BPP zeros are exact, not EPS floors).
        let ds = Arc::new(load_dataset("tiny", 3).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let spec = EngineSpec { alpha: 0.5, l1_ratio: 1.0, ..Default::default() };
        let mut free = BppEngine::new(ds.clone(), pool.clone(), 4, 42);
        let mut reg = BppEngine::with_spec(ds, pool, 4, 42, spec);
        for _ in 0..5 {
            free.step().unwrap();
            reg.step().unwrap();
        }
        let zeros = |m: &Mat| m.data().iter().filter(|&&x| x == 0.0).count();
        assert!(
            zeros(&reg.factors().h) > zeros(&free.factors().h),
            "regularized H zeros {} vs free {}",
            zeros(&reg.factors().h),
            zeros(&free.factors().h)
        );
    }

    #[test]
    fn per_iteration_error_at_least_hals_quality() {
        // ANLS' exact subproblem solves should reach ≤ HALS error after
        // the same small iteration count (Fig. 8: BPP's per-iteration
        // quality is comparable; its weakness is per-iteration cost).
        use crate::nmf::fasthals::FastHalsEngine;
        let ds = Arc::new(load_dataset("tiny", 11).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut bpp = BppEngine::new(ds.clone(), pool.clone(), 4, 5);
        let mut hals = FastHalsEngine::new(ds, pool, 4, 5);
        let tb = bpp.run(10, 10, 0.0).unwrap();
        let th = hals.run(10, 10, 0.0).unwrap();
        let (eb, eh) = (tb.last().unwrap().rel_error, th.last().unwrap().rel_error);
        assert!(eb <= eh * 1.1 + 1e-3, "bpp {eb} vs hals {eh}");
    }
}

//! NMF engines: the paper's contribution (PL-NMF, Alg. 2) plus every
//! baseline its evaluation compares against (FAST-HALS Alg. 1, MU,
//! ANLS-BPP), the relative-objective metric, and the data-movement cost
//! model of §5.
//!
//! ## Storage convention
//!
//! `A` is V×D. `W` is V×K row-major. `H` (K×D in the paper) is stored
//! **transposed** as a D×K row-major matrix, so that *both* factor
//! updates are column-panel operations on tall-skinny matrices and both
//! Gram matrices (`Q = HHᵀ`, `S = WᵀW`) are plain Grams of n×K matrices.
//! All public APIs in this crate that say "H" take/return the D×K layout.

pub mod spec;
pub mod traits;
pub mod init;
pub mod products;
pub mod halsops;
pub mod fasthals;
pub mod plnmf;
pub mod mu;
pub mod mukl;
pub mod nnls;
pub mod bpp;
pub mod error;
pub mod cost_model;

pub use error::rel_error;
pub use init::Factors;
pub use spec::{EngineSpec, Init, Loss, Solver};
pub use traits::{IterRecord, NmfEngine};

//! The data-movement cost model of §5 (Eqs. 3, 7–11) and the tile-size
//! selector derived from it.
//!
//! Units: *words* moved between main memory and a cache of `C` words. The
//! paper counts doubles, so `C = cache_bytes / 8` — with the paper's
//! 35 MB LLC, `C = 35·2²⁰/8 = 4,587,520`. The §5 worked example
//! (20 Newsgroups, V = 11,314 — the paper plugs the document count in
//! here — K = 160, T = 15) evaluates to 300,525,600 words for the
//! original scheme vs 44,897,687 for the tiled scheme, a 6.7× reduction;
//! unit tests below pin those exact numbers.

/// Cache size in words (doubles) from bytes.
pub fn cache_words(cache_bytes: usize) -> f64 {
    cache_bytes as f64 / 8.0
}

/// Data movement of the original Alg. 1 W-update loop (line 12):
/// `K(VK + K + 6V + 1)`.
pub fn naive_w_update_volume(v: usize, k: usize) -> f64 {
    let (v, k) = (v as f64, k as f64);
    k * (v * k + k + 6.0 * v + 1.0)
}

/// Data movement of the original Alg. 1 H-update loop (line 6):
/// `K(3D + DK + K)`.
pub fn naive_h_update_volume(d: usize, k: usize) -> f64 {
    let (d, k) = (d as f64, k as f64);
    k * (3.0 * d + d * k + k)
}

/// Total data movement of Alg. 1 per outer iteration (Eq. 3):
/// `K(K(V+D)(1 + 2/√C) + 4VD/√C + 6V + 3D + 2K + 1)`.
pub fn naive_total_volume(v: usize, d: usize, k: usize, c_words: f64) -> f64 {
    let (v, d, k) = (v as f64, d as f64, k as f64);
    let rc = 2.0 / c_words.sqrt();
    k * (k * (v + d) * (1.0 + rc) + 4.0 * v * d / c_words.sqrt() + 6.0 * v + 3.0 * d + 2.0 * k + 1.0)
}

/// Phases 1+3 volume for the tiled W update (Eq. 7):
/// `V·T²·(1/T + 2/√C)·(K² − KT)/(2T²)` summed over both directions gives
/// `V(1/T + 2/√C)(K² − KT)` when left and right contributions are
/// combined (the paper folds the factor 2 · (K²−KT)/2).
pub fn tiled_phase13_volume(v: usize, k: usize, t: usize, c_words: f64) -> f64 {
    let (v, k, t) = (v as f64, k as f64, t as f64);
    v * (1.0 / t + 2.0 / c_words.sqrt()) * (k * k - k * t)
}

/// Phase 2 volume (Eq. 8 dominant term): `K·V·T`.
pub fn tiled_phase2_volume(v: usize, k: usize, t: usize) -> f64 {
    v as f64 * k as f64 * t as f64
}

/// Total tiled W-update volume (Eq. 9):
/// `vol(T) = V(1/T + 2/√C)(K² − KT) + KVT`.
pub fn tiled_w_update_volume(v: usize, k: usize, t: usize, c_words: f64) -> f64 {
    tiled_phase13_volume(v, k, t, c_words) + tiled_phase2_volume(v, k, t)
}

/// The model's optimal (real-valued) tile width (Eq. 11):
/// `T* = √(K − 2/√C)`.
pub fn model_tile_real(k: usize, c_words: f64) -> f64 {
    (k as f64 - 2.0 / c_words.sqrt()).max(1.0).sqrt()
}

/// Integer tile selection: round the model optimum, clamp to `[1, K]`.
/// (The paper rounds pragmatically — it ran T = 10/15/15 for
/// K = 80/160/240 where the model gives 8.94/12.64/15.49; Fig. 6 shows
/// the basin around T* is flat, so nearest-integer is within noise.)
pub fn select_tile(k: usize, cache_bytes: usize) -> usize {
    let t = model_tile_real(k, cache_words(cache_bytes)).round() as usize;
    t.clamp(1, k.max(1))
}

/// Predicted volume ratio naive/tiled for the W update (the “6.7×
/// lower” §5 claim).
pub fn w_update_ratio(v: usize, k: usize, t: usize, c_words: f64) -> f64 {
    naive_w_update_volume(v, k) / tiled_w_update_volume(v, k, t, c_words)
}

/// A full model report row (used by `plnmf model` and the E6 bench).
#[derive(Debug, Clone)]
pub struct ModelReport {
    pub k: usize,
    pub t_real: f64,
    pub t_selected: usize,
    pub naive_volume: f64,
    pub tiled_volume: f64,
    pub ratio: f64,
}

pub fn model_report(v: usize, k: usize, cache_bytes: usize) -> ModelReport {
    let c = cache_words(cache_bytes);
    let t_real = model_tile_real(k, c);
    let t_selected = select_tile(k, cache_bytes);
    let naive = naive_w_update_volume(v, k);
    let tiled = tiled_w_update_volume(v, k, t_selected, c);
    ModelReport { k, t_real, t_selected, naive_volume: naive, tiled_volume: tiled, ratio: naive / tiled }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PAPER_CACHE: usize = 35 * 1024 * 1024; // 35 MB LLC
    const PAPER_V: usize = 11_314; // the value §5 plugs in for 20NG

    #[test]
    fn reproduces_paper_naive_volume() {
        // §5: “the data movement cost of original scheme is 300,525,600”.
        let vol = naive_w_update_volume(PAPER_V, 160);
        assert_eq!(vol as u64, 300_525_600);
    }

    #[test]
    fn reproduces_paper_tiled_volume() {
        // §5: “in our scheme based on Equation 9, the cost is only
        // 44,897,687” — evaluated at the experimentally-used T = 15.
        let c = cache_words(PAPER_CACHE);
        let vol = tiled_w_update_volume(PAPER_V, 160, 15, c);
        let target = 44_897_687.0;
        assert!(
            (vol - target).abs() / target < 1e-5,
            "tiled volume {vol} vs paper {target}"
        );
    }

    #[test]
    fn reproduces_paper_ratio() {
        // §5: “6.7× lower than the original scheme”.
        let c = cache_words(PAPER_CACHE);
        let ratio = w_update_ratio(PAPER_V, 160, 15, c);
        assert!((ratio - 6.7).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn reproduces_paper_model_tiles() {
        // §5: “the tile sizes computed by our model are 8.94, 12.64 and
        // 15.49 for K = 80, 160 and 240”.
        let c = cache_words(PAPER_CACHE);
        let cases = [(80, 8.94), (160, 12.64), (240, 15.49)];
        for (k, expect) in cases {
            let t = model_tile_real(k, c);
            assert!((t - expect).abs() < 0.01, "K={k}: model T {t} vs paper {expect}");
        }
    }

    #[test]
    fn volume_is_u_shaped_in_t() {
        // vol(1) and vol(K) both exceed vol(T*): the Fig. 6 shape.
        let c = cache_words(PAPER_CACHE);
        let (v, k) = (20_000, 160);
        let opt = select_tile(k, PAPER_CACHE);
        let vol_opt = tiled_w_update_volume(v, k, opt, c);
        assert!(tiled_w_update_volume(v, k, 1, c) > vol_opt);
        assert!(tiled_w_update_volume(v, k, k, c) > vol_opt);
    }

    #[test]
    fn selected_tile_is_argmin_over_integers() {
        let c = cache_words(PAPER_CACHE);
        for k in [16, 80, 160, 240] {
            let sel = select_tile(k, PAPER_CACHE);
            let vol_sel = tiled_w_update_volume(10_000, k, sel, c);
            let best = (1..=k)
                .map(|t| (t, tiled_w_update_volume(10_000, k, t, c)))
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .unwrap();
            // Selection must be within 2% of the integer argmin (rounding
            // the continuous optimum can be off by one).
            assert!(
                vol_sel <= best.1 * 1.02,
                "K={k}: selected T={sel} vol {vol_sel} vs argmin T={} vol {}",
                best.0,
                best.1
            );
        }
    }

    #[test]
    fn tile_clamped_to_valid_range() {
        assert_eq!(select_tile(1, PAPER_CACHE), 1);
        assert!(select_tile(4, PAPER_CACHE) <= 4);
        assert!(select_tile(240, PAPER_CACHE) >= 1);
    }

    #[test]
    fn eq3_total_dominated_by_dmv_loops() {
        // §3.2: the DMV loops are ~91% of data movement on 20NG. With
        // V=26214, D=11314 (Table 4) and K=160 the combined loop share of
        // Eq. 3 must dominate.
        let (v, d, k) = (26_214, 11_314, 160);
        let c = cache_words(PAPER_CACHE);
        let loops = naive_w_update_volume(v, k) + naive_h_update_volume(d, k);
        let total = naive_total_volume(v, d, k, c);
        let share = loops / total;
        assert!(share > 0.85, "DMV share {share}");
    }
}

//! The HALS factor-update kernels — naive (Alg. 1 lines 6–8 / 12–16) and
//! tiled three-phase (Alg. 2), shared by the FAST-HALS and PL-NMF
//! engines.
//!
//! Both kernels implement the same mathematical update of a tall-skinny
//! factor `X` (n×K) given the Gram `G` (K×K, symmetric) of the *other*
//! factor and the target product `B` (n×K):
//!
//! ```text
//! for t = 0..K:
//!     X[:,t] ← max(ε, diag·X[:,t] + B[:,t] − Σ_j X_mixed[:,j]·G[j,t])
//!     (optionally) X[:,t] ← X[:,t] / ‖X[:,t]‖₂
//! ```
//!
//! where `X_mixed[:,j]` is the *already-updated* value for `j < t` and
//! the old value for `j ≥ t` — the sequential feature dependency that
//! makes the loop a chain of matrix-vector products (DMV) in Alg. 1.
//!
//! * W update (Alg. 1 line 13): `diag = G[t,t]`, `normalize = true`.
//! * H update (Alg. 1 line 7):  `diag = 1`,      `normalize = false`.
//!
//! The tiled kernel reorders the additive contributions (associativity of
//! addition) into panel GEMMs (phases 1/3) + an in-tile sequential loop
//! (phase 2) with identical operation count — the paper's core
//! contribution. Equality with the naive kernel is exact up to fp
//! reassociation (asserted by the property tests below).
//!
//! Parallel structure of the normalized (W) updates mirrors the paper's
//! GPU Algs. 4/5: rows are sharded across workers; each column step
//! produces per-worker partial sums of squares; two barrier crossings
//! fold the norm and scale — the CPU analogue of warp-shuffle +
//! `atomicAdd` + `update_W_norm<<<...>>>`.

use crate::kernels::Kernels;
use crate::linalg::{gemm, GemmOp, Mat};
use crate::parallel::{split_even, Barrier, ThreadPool};
use crate::util::PhaseTimers;
use crate::{Elem, EPS};

use std::cell::UnsafeCell;

/// Which flavor of the column update to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateKind {
    /// `X[:,t]·G[t,t] + B[:,t] − Σ…` then L2-normalize — the W update.
    WithDiagAndNorm,
    /// `X[:,t] + B[:,t] − Σ…`, no normalization — the H update
    /// (FAST-HALS keeps `S_tt = 1` via W's unit columns).
    Plain,
    /// The exact coordinate solve against a raw (non-unit-diagonal)
    /// Gram: `(X[:,t]·G[t,t] + B[:,t] − Σ…) / G[t,t]`, no
    /// normalization. Row-parallel like `Plain` (no barriers). This is
    /// the serving kernel for regularized projection, where W is kept
    /// in raw scale so a uniform L1 shrink means the same thing for
    /// every component. Naive kernel only.
    WithDiag,
}

/// Elastic-net shrinkage applied to a factor update:
/// `x ← max(ε, (numerator − l1) / (denominator + l2))`. `Shrink::NONE`
/// takes the exact pre-regularization code path — bit-for-bit, not just
/// mathematically, identical (the shrink arithmetic is skipped, not
/// applied with zeros).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Shrink {
    pub l1: Elem,
    pub l2: Elem,
}

impl Shrink {
    pub const NONE: Shrink = Shrink { l1: 0.0, l2: 0.0 };

    #[inline]
    pub fn is_none(&self) -> bool {
        self.l1 == 0.0 && self.l2 == 0.0
    }
}

// ---------------------------------------------------------------------------
// Naive kernel (Alg. 1): K sequential matrix-vector products.
// ---------------------------------------------------------------------------

/// Per-column DMV update, parallel over rows. This is the
/// bandwidth-bound loop the paper's analysis targets: each column step
/// streams the whole `X` (n×K) once — `K(nK + …)` words moved total.
pub fn update_naive(
    pool: &ThreadPool,
    x: &mut Mat,
    g: &Mat,
    b: &Mat,
    kind: UpdateKind,
    timers: &mut PhaseTimers,
    label: &'static str,
) {
    update_naive_reg(pool, x, g, b, kind, Shrink::NONE, timers, label);
}

/// [`update_naive`] with elastic-net shrinkage on the solved factor.
/// `Shrink::NONE` is the identical (bit-for-bit) unregularized path.
#[allow(clippy::too_many_arguments)]
pub fn update_naive_reg(
    pool: &ThreadPool,
    x: &mut Mat,
    g: &Mat,
    b: &Mat,
    kind: UpdateKind,
    shrink: Shrink,
    timers: &mut PhaseTimers,
    label: &'static str,
) {
    let (n, k) = (x.rows(), x.cols());
    assert_eq!((g.rows(), g.cols()), (k, k));
    assert_eq!((b.rows(), b.cols()), (n, k));
    let plain_shrink = !shrink.is_none();
    let Shrink { l1, l2 } = shrink;
    let kern = pool.kernels();
    timers.time(label, || match kind {
        UpdateKind::Plain => {
            // Row-local: every row independent, one parallel sweep.
            // Unit diagonal (the FAST-HALS `S_tt = 1` invariant), so the
            // regularized denominator is the constant `1 + l2`.
            let inv_denom = 1.0 / (1.0 + l2);
            let xs = SharedRows::new(x);
            pool.parallel_for(n, None, |rows| {
                for i in rows {
                    let xrow = unsafe { xs.row_mut(i) };
                    let brow = b.row(i);
                    for t in 0..k {
                        // G symmetric: column t == row t (contiguous).
                        let s = (kern.dot)(xrow, g.row(t));
                        let v = if plain_shrink {
                            (xrow[t] + brow[t] - s - l1) * inv_denom
                        } else {
                            xrow[t] + brow[t] - s
                        };
                        xrow[t] = if v < EPS { EPS } else { v };
                    }
                }
            });
        }
        UpdateKind::WithDiag => {
            // Row-local exact coordinate solve against a raw Gram; dead
            // components (`G_tt + l2 == 0`) pin to EPS instead of
            // dividing by zero.
            let xs = SharedRows::new(x);
            pool.parallel_for(n, None, |rows| {
                for i in rows {
                    let xrow = unsafe { xs.row_mut(i) };
                    let brow = b.row(i);
                    for t in 0..k {
                        let s = (kern.dot)(xrow, g.row(t));
                        let num = xrow[t] * g.at(t, t) + brow[t] - s - l1;
                        let denom = g.at(t, t) + l2;
                        let v = if denom > 0.0 { num / denom } else { 0.0 };
                        xrow[t] = if v < EPS { EPS } else { v };
                    }
                }
            });
        }
        UpdateKind::WithDiagAndNorm => {
            columns_with_norm(pool, x, 0, k, |_i, xrow, brow, t| {
                let s = (kern.dot)(xrow, g.row(t));
                let num = xrow[t] * g.at(t, t) + brow[t] - s;
                let v = if plain_shrink {
                    let denom = g.at(t, t) + l2;
                    if denom > 0.0 {
                        (num - l1) / denom
                    } else {
                        0.0
                    }
                } else {
                    num
                };
                if v < EPS {
                    EPS
                } else {
                    v
                }
            }, b);
        }
    });
}

// ---------------------------------------------------------------------------
// Tiled kernel (Alg. 2): three phases per tile.
// ---------------------------------------------------------------------------

/// PL-NMF tiled update. `tile` is the panel width T (clamped to `[1,K]`).
///
/// Phase timings accumulate under `"phase1"` / `"phase2"` / `"phase3"`
/// (the Table 5 breakdown). `x_old` is caller-provided scratch (same
/// shape as `x`); on entry its contents are ignored, on exit it holds the
/// pre-update values of `x`.
pub fn update_tiled(
    pool: &ThreadPool,
    x: &mut Mat,
    x_old: &mut Mat,
    g: &Mat,
    b: &Mat,
    tile: usize,
    kind: UpdateKind,
    timers: &mut PhaseTimers,
    labels: [&'static str; 3],
) {
    update_tiled_reg(pool, x, x_old, g, b, tile, kind, Shrink::NONE, timers, labels);
}

/// [`update_tiled`] with elastic-net shrinkage on the solved factor.
/// `Shrink::NONE` is the identical (bit-for-bit) unregularized path.
/// `WithDiag` is a naive-kernel-only flavor (serving); the tiled
/// training kernel rejects it.
#[allow(clippy::too_many_arguments)]
pub fn update_tiled_reg(
    pool: &ThreadPool,
    x: &mut Mat,
    x_old: &mut Mat,
    g: &Mat,
    b: &Mat,
    tile: usize,
    kind: UpdateKind,
    shrink: Shrink,
    timers: &mut PhaseTimers,
    labels: [&'static str; 3],
) {
    assert!(
        kind != UpdateKind::WithDiag,
        "UpdateKind::WithDiag is a naive-kernel (serving) flavor; \
         the tiled training kernel supports Plain and WithDiagAndNorm"
    );
    let (n, k) = (x.rows(), x.cols());
    assert_eq!((g.rows(), g.cols()), (k, k));
    assert_eq!((b.rows(), b.cols()), (n, k));
    let t_w = tile.clamp(1, k);
    let [lbl_p1, lbl_p2, lbl_p3] = labels;

    x_old.copy_from(x);

    // ---- init (Alg. 2 lines 4–8): X_new = diag ⊙ X_old ------------------
    if kind == UpdateKind::WithDiagAndNorm {
        timers.time(lbl_p2, || {
            let xs = SharedRows::new(x);
            pool.parallel_for(n, None, |rows| {
                for i in rows {
                    let xrow = unsafe { xs.row_mut(i) };
                    let orow = x_old.row(i);
                    for t in 0..k {
                        xrow[t] = orow[t] * g.at(t, t);
                    }
                }
            });
        });
    }
    // Plain kind: the `+X[:,t]` term is X itself — already in place.

    // ---- phase 1 (Alg. 2 lines 11–13): old panels → columns left --------
    timers.time(lbl_p1, || {
        let mut t0 = t_w; // tile 0 has no left side
        while t0 < k {
            let t1 = (t0 + t_w).min(k);
            gemm(
                pool,
                -1.0,
                x_old.col_view(t0, t1),
                g.block_view(t0, t1, 0, t0),
                GemmOp::Add,
                &mut x.col_view_mut(0, t0),
            );
            t0 = t1;
        }
    });

    // ---- per tile: phase 2 then phase 3 ---------------------------------
    // Phase-2 scratch, reused across tiles: the transposed T×n slab (the
    // cache-resident working set the paper engineers for — 1.5 MiB at
    // V=26214, T=15) and the current-column buffer.
    let mut slab_old = vec![0.0 as Elem; t_w * n];
    let mut slab_xb = vec![0.0 as Elem; t_w * n];
    let mut t0 = 0;
    while t0 < k {
        let t1 = (t0 + t_w).min(k);

        timers.time(lbl_p2, || {
            phase2_sweep(pool, x, x_old, g, b, t0, t1, kind, shrink, &mut slab_old, &mut slab_xb);
        });

        // ---- phase 3 (Alg. 2 line 40): new panel → columns right --------
        timers.time(lbl_p3, || {
            if t1 < k {
                let (panel, mut right) = split_cols_same(x, t0, t1, k);
                gemm(pool, -1.0, panel, g.block_view(t0, t1, t1, k), GemmOp::Add, &mut right);
            }
        });

        t0 = t1;
    }
}

// ---------------------------------------------------------------------------
// Phase 2: vectorized column sweep over a transposed slab.
// ---------------------------------------------------------------------------

/// In-tile sequential column updates (Alg. 2 phase 2), restructured for
/// SIMD and cache-line economy. Two transposed `T x n` slabs hold the
/// tile's working set:
///
/// * `slab_old[j][v]` — the pre-update tile values (Alg. 2's W_old);
/// * `slab_xb[j][v]`  — initialized to `x[v][t0+j] + b[v][t0+j]` (the
///   running value with init/phase-1/phase-3 folds, plus the target
///   product), and overwritten in place with the *final* column values
///   as the sequential sweep passes each column.
///
/// Both slabs are filled in ONE row-major pass over `x`/`x_old`/`b`
/// (each matrix row's tile window shares a cache line), the coupled sum
/// for column `t` becomes `T` unit-stride FMA passes over `n`-vectors,
/// and the results flush back to `x` in one final row-major pass —
/// eliminating the per-column strided column walks that dominated the
/// first implementation (EXPERIMENTS.md §Perf, phase-2 iterations).
///
/// The mixed-state semantics of Alg. 2 lines 24-30 map to the source
/// choice: column `j < jt` reads `slab_xb` (already updated +
/// normalized), `j >= jt` reads `slab_old`.
///
/// For the H-flavor (`Plain`, no normalization) rows are independent, so
/// each worker additionally processes its shard in row blocks sized to
/// keep all slab windows L2-resident.
#[allow(clippy::too_many_arguments)]
fn phase2_sweep(
    pool: &ThreadPool,
    x: &mut Mat,
    x_old: &Mat,
    g: &Mat,
    b: &Mat,
    t0: usize,
    t1: usize,
    kind: UpdateKind,
    shrink: Shrink,
    slab_old: &mut [Elem],
    slab_xb: &mut [Elem],
) {
    let n = x.rows();
    let tw = t1 - t0;
    if n == 0 || tw == 0 {
        return;
    }
    let kern = pool.kernels();
    let nw = pool.n_threads();
    let shards = split_even(n, nw);
    let xs = SharedRows::new(x);
    let old_ptr = SharedSlice(slab_old.as_mut_ptr(), slab_old.len());
    let xb_ptr = SharedSlice(slab_xb.as_mut_ptr(), slab_xb.len());
    let barrier = Barrier::new(nw);
    let partials: Vec<PaddedCell> = (0..nw).map(|_| PaddedCell::new()).collect();
    let norm = PaddedCell::new();
    let normalize = kind == UpdateKind::WithDiagAndNorm;

    // Row-block width for the Plain kind: 3 slab windows of BV*tw f32
    // stay comfortably inside L2 (BV=2048, T=15 -> ~360 KiB).
    const BV: usize = 2048;

    pool.run(&|wid| {
        let rows = shards[wid].clone();
        if normalize {
            // -- W flavor: global per-column norms force a column-major
            //    outer loop across the full shard, with two barrier
            //    crossings per column (the Alg. 4/5 reduction).
            if !rows.is_empty() {
                load_tile_slabs(&xs, x_old, b, t0, tw, n, &old_ptr, &xb_ptr, rows.clone());
            }
            for t in t0..t1 {
                let jt = t - t0;
                let sumsq = if rows.is_empty() {
                    0.0
                } else {
                    column_step(
                        kern, g, t, t0, jt, tw, n, kind, shrink, &old_ptr, &xb_ptr,
                        rows.clone(),
                    )
                };
                unsafe { partials[wid].set(sumsq) };
                if barrier.wait() {
                    let total: f64 = partials.iter().map(|p| unsafe { p.get() }).sum();
                    let v = if total > 0.0 { 1.0 / total.sqrt() } else { 1.0 };
                    unsafe { norm.set(v) };
                }
                barrier.wait();
                if !rows.is_empty() {
                    let inv = (unsafe { norm.get() }) as Elem;
                    let dst = unsafe { xb_ptr.slice(jt * n + rows.start, rows.len()) };
                    for v in dst.iter_mut() {
                        *v *= inv;
                    }
                }
            }
            if !rows.is_empty() {
                flush_tile_slab(&xs, t0, tw, n, &xb_ptr, rows.clone());
            }
        } else {
            // -- H flavor: rows independent -> L2-resident row blocks.
            let mut v0 = rows.start;
            while v0 < rows.end {
                let v1 = (v0 + BV).min(rows.end);
                let blk = v0..v1;
                load_tile_slabs(&xs, x_old, b, t0, tw, n, &old_ptr, &xb_ptr, blk.clone());
                for t in t0..t1 {
                    let jt = t - t0;
                    column_step(
                        kern, g, t, t0, jt, tw, n, kind, shrink, &old_ptr, &xb_ptr,
                        blk.clone(),
                    );
                }
                flush_tile_slab(&xs, t0, tw, n, &xb_ptr, blk.clone());
                v0 = v1;
            }
        }
    });
}

/// One row-major pass filling both slabs for rows `[r0, r1)`.
#[allow(clippy::too_many_arguments)]
fn load_tile_slabs(
    xs: &SharedRows,
    x_old: &Mat,
    b: &Mat,
    t0: usize,
    tw: usize,
    n: usize,
    old_ptr: &SharedSlice,
    xb_ptr: &SharedSlice,
    rows: std::ops::Range<usize>,
) {
    for i in rows {
        // SAFETY: row i belongs to this worker's shard.
        let xrow = unsafe { xs.row_mut(i) };
        let orow = x_old.row(i);
        let brow = b.row(i);
        for j in 0..tw {
            unsafe {
                *old_ptr.slice(j * n + i, 1).get_unchecked_mut(0) = *orow.get_unchecked(t0 + j);
                *xb_ptr.slice(j * n + i, 1).get_unchecked_mut(0) =
                    *xrow.get_unchecked(t0 + j) + *brow.get_unchecked(t0 + j);
            }
        }
    }
}

/// The coupled update of one column over rows `[r0, r1)`:
/// `xb[jt] -= sum_j G[t0+j, t] * (j < jt ? xb[j] : old[j])`, then the
/// shrink (if any), clamp to EPS, return the window's sum of squares.
///
/// Every pass dispatches through the kernel table's exact-class
/// primitives (`d -= q·s` runs as `axpy(−q, ..)`, bit-identical since
/// IEEE negation is exact and `d + (−q)·s ≡ d − q·s`), so this sweep
/// produces the same bits on every backend.
#[allow(clippy::too_many_arguments)]
fn column_step(
    kern: &Kernels,
    g: &Mat,
    t: usize,
    t0: usize,
    jt: usize,
    tw: usize,
    n: usize,
    kind: UpdateKind,
    shrink: Shrink,
    old_ptr: &SharedSlice,
    xb_ptr: &SharedSlice,
    rows: std::ops::Range<usize>,
) -> f64 {
    let (r0, len) = (rows.start, rows.len());
    let gcol = g.row(t); // symmetric: row t == column t
    // SAFETY: windows are worker/block-disjoint.
    let dst = unsafe { xb_ptr.slice(jt * n + r0, len) };
    for j in 0..tw {
        let q = gcol[t0 + j];
        if q == 0.0 {
            continue;
        }
        if j < jt {
            let src = unsafe { xb_ptr.slice(j * n + r0, len) };
            (kern.axpy)(-q, src, dst);
        } else {
            let src = unsafe { old_ptr.slice(j * n + r0, len) };
            (kern.axpy)(-q, src, dst);
        }
    }
    if shrink.is_none() {
        (kern.clamp_sumsq)(dst, EPS)
    } else {
        // The slab's running value is the update's numerator (diag fold
        // happened at init for the WithDiagAndNorm flavor, and Plain's
        // diag is the unit `S_tt`).
        let diag = if kind == UpdateKind::Plain { 1.0 } else { g.at(t, t) };
        let denom = diag + shrink.l2;
        let inv = if denom > 0.0 { 1.0 / denom } else { 0.0 };
        (kern.shrink_clamp_sumsq)(dst, shrink.l1, inv, EPS)
    }
}

/// One row-major pass writing the finished tile back into `x`.
fn flush_tile_slab(
    xs: &SharedRows,
    t0: usize,
    tw: usize,
    n: usize,
    xb_ptr: &SharedSlice,
    rows: std::ops::Range<usize>,
) {
    for i in rows {
        let xrow = unsafe { xs.row_mut(i) };
        for j in 0..tw {
            unsafe {
                *xrow.get_unchecked_mut(t0 + j) = *xb_ptr.slice(j * n + i, 1).get_unchecked(0);
            }
        }
    }
}

/// Raw shared slice for worker-disjoint windows.
struct SharedSlice(*mut Elem, usize);

unsafe impl Sync for SharedSlice {}

impl SharedSlice {
    /// SAFETY: caller guarantees `[off, off+len)` windows are disjoint
    /// across concurrent users.
    #[allow(clippy::mut_from_ref)]
    unsafe fn slice(&self, off: usize, len: usize) -> &mut [Elem] {
        debug_assert!(off + len <= self.1);
        std::slice::from_raw_parts_mut(self.0.add(off), len)
    }
}

// ---------------------------------------------------------------------------
// Barrier-synchronized column driver (normalized updates).
// ---------------------------------------------------------------------------

/// For each column `t` in `[t0, t1)`: apply `compute(i, xrow, brow, t)`
/// to every row `i` (writing the returned value into `xrow[t]`), then
/// L2-normalize the column. Rows are statically sharded; norms fold
/// through per-worker slots with two barrier crossings per column.
///
/// `compute` receives the row's *current* mixed state (`xrow`), so reads
/// of `xrow[j]`, `j < t`, see already-updated-and-normalized values —
/// exactly Alg. 1/2's sequential semantics.
fn columns_with_norm<F>(pool: &ThreadPool, x: &mut Mat, t0: usize, t1: usize, compute: F, b: &Mat)
where
    F: Fn(usize, &mut [Elem], &[Elem], usize) -> Elem + Sync,
{
    let n = x.rows();
    if n == 0 || t0 >= t1 {
        return;
    }
    let nw = pool.n_threads();
    let shards = split_even(n, nw);
    let xs = SharedRows::new(x);
    let barrier = Barrier::new(nw);
    let partials: Vec<PaddedCell> = (0..nw).map(|_| PaddedCell::new()).collect();
    let norm = PaddedCell::new();

    pool.run(&|wid| {
        let rows = shards[wid].clone();
        for t in t0..t1 {
            // -- update my rows, accumulate ∑ x² in f64 -------------------
            let mut sumsq = 0.0f64;
            for i in rows.clone() {
                let xrow = unsafe { xs.row_mut(i) };
                let v = compute(i, xrow, b.row(i), t);
                xrow[t] = v;
                sumsq += v as f64 * v as f64;
            }
            unsafe { partials[wid].set(sumsq) };
            // -- fold (leader), publish inverse norm ----------------------
            if barrier.wait() {
                let total: f64 = partials.iter().map(|p| unsafe { p.get() }).sum();
                let inv = if total > 0.0 { 1.0 / total.sqrt() } else { 1.0 };
                unsafe { norm.set(inv) };
            }
            barrier.wait();
            let inv = unsafe { norm.get() } as Elem;
            // -- scale my rows (Alg. 2 line 36 / Alg. 5) ------------------
            for i in rows.clone() {
                let xrow = unsafe { xs.row_mut(i) };
                xrow[t] *= inv;
            }
            // No third barrier: column t+1 only reads each worker's own
            // rows, which that worker has already scaled.
        }
    });
}

// ---------------------------------------------------------------------------
// Raw shared access helpers.
// ---------------------------------------------------------------------------

/// Row-disjoint mutable access to a matrix from multiple workers.
pub(crate) struct SharedRows {
    ptr: *mut Elem,
    rows: usize,
    cols: usize,
}

unsafe impl Sync for SharedRows {}
unsafe impl Send for SharedRows {}

impl SharedRows {
    pub fn new(m: &mut Mat) -> SharedRows {
        SharedRows { ptr: m.data_mut().as_mut_ptr(), rows: m.rows(), cols: m.cols() }
    }

    /// SAFETY: caller guarantees row-disjoint access across workers.
    #[inline]
    pub unsafe fn row_mut(&self, i: usize) -> &mut [Elem] {
        debug_assert!(i < self.rows);
        std::slice::from_raw_parts_mut(self.ptr.add(i * self.cols), self.cols)
    }
}

/// Cache-line padded f64 cell for barrier-separated publish/consume.
#[repr(align(64))]
struct PaddedCell(UnsafeCell<f64>);

unsafe impl Sync for PaddedCell {}

impl PaddedCell {
    fn new() -> Self {
        PaddedCell(UnsafeCell::new(0.0))
    }

    /// SAFETY: writes and reads are separated by barrier crossings.
    #[inline]
    unsafe fn set(&self, v: f64) {
        *self.0.get() = v;
    }

    #[inline]
    unsafe fn get(&self) -> f64 {
        *self.0.get()
    }
}

/// Split the same matrix into an immutable panel view `[p0,p1)` and a
/// mutable view of columns `[p1,hi)` — phase 3's aliasing shape. Sound
/// because the two views address disjoint column ranges and all accesses
/// are bounds-limited by each view's geometry.
fn split_cols_same(
    x: &mut Mat,
    p0: usize,
    p1: usize,
    hi: usize,
) -> (crate::linalg::View<'_>, crate::linalg::ViewMut<'_>) {
    assert!(p0 <= p1 && p1 <= hi && hi <= x.cols());
    let rows = x.rows();
    let cols = x.cols();
    let data = x.data_mut();
    let len = data.len();
    let ptr = data.as_mut_ptr();
    // SAFETY: disjoint column windows of the same allocation; see above.
    let data_const: &[Elem] = unsafe { std::slice::from_raw_parts(ptr, len) };
    let data_mut: &mut [Elem] = unsafe { std::slice::from_raw_parts_mut(ptr, len) };
    (
        crate::linalg::View { data: data_const, rows, cols: p1 - p0, rs: cols, off: p0 },
        crate::linalg::ViewMut { data: data_mut, rows, cols: hi - p1, rs: cols, off: p1 },
    )
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::PropConfig;
    use crate::util::rng::Pcg32;

    /// Scalar reference implementation of the column update loop,
    /// transliterated from Alg. 1 (f64 throughout, serial), with the
    /// elastic-net shrink spelled out at full precision.
    fn update_reference_reg(x: &mut Mat, g: &Mat, b: &Mat, kind: UpdateKind, shrink: Shrink) {
        let (n, k) = (x.rows(), x.cols());
        let (l1, l2) = (shrink.l1 as f64, shrink.l2 as f64);
        for t in 0..k {
            let mut sumsq = 0.0f64;
            for i in 0..n {
                let mut s = 0.0f64;
                for j in 0..k {
                    s += x.at(i, j) as f64 * g.at(j, t) as f64;
                }
                let diag = match kind {
                    UpdateKind::WithDiagAndNorm | UpdateKind::WithDiag => g.at(t, t) as f64,
                    UpdateKind::Plain => 1.0,
                };
                let num = x.at(i, t) as f64 * diag + b.at(i, t) as f64 - s;
                let v = if shrink.is_none() && kind != UpdateKind::WithDiag {
                    num
                } else {
                    let denom = diag + l2;
                    if denom > 0.0 {
                        (num - l1) / denom
                    } else {
                        0.0
                    }
                };
                let v = if v < EPS as f64 { EPS as f64 } else { v };
                *x.at_mut(i, t) = v as Elem;
                sumsq += v * v;
            }
            if kind == UpdateKind::WithDiagAndNorm {
                let inv = if sumsq > 0.0 { 1.0 / sumsq.sqrt() } else { 1.0 };
                for i in 0..n {
                    *x.at_mut(i, t) = (x.at(i, t) as f64 * inv) as Elem;
                }
            }
        }
    }

    fn update_reference(x: &mut Mat, g: &Mat, b: &Mat, kind: UpdateKind) {
        update_reference_reg(x, g, b, kind, Shrink::NONE);
    }

    fn random_problem(n: usize, k: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Pcg32::seeded(seed);
        let x = Mat::random(n, k, &mut rng, 0.0, 1.0);
        // G: symmetric PSD-ish (Gram of a random factor).
        let f = Mat::random(n.max(k) + 3, k, &mut rng, 0.0, 1.0);
        let g = crate::linalg::gram::gram_naive(&f);
        let b = Mat::random(n, k, &mut rng, 0.0, 2.0);
        (x, g, b)
    }

    fn max_rel_diff(a: &Mat, b: &Mat) -> f64 {
        let mut worst = 0.0f64;
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                let (x, y) = (a.at(i, j) as f64, b.at(i, j) as f64);
                let d = (x - y).abs() / x.abs().max(y.abs()).max(1e-6);
                worst = worst.max(d);
            }
        }
        worst
    }

    #[test]
    fn naive_matches_reference_both_kinds() {
        let pool = ThreadPool::new(4);
        for kind in [UpdateKind::Plain, UpdateKind::WithDiagAndNorm] {
            let (mut x, g, b) = random_problem(57, 9, 1);
            let mut x_ref = x.clone();
            let mut timers = PhaseTimers::new();
            update_naive(&pool, &mut x, &g, &b, kind, &mut timers, "dmv");
            update_reference(&mut x_ref, &g, &b, kind);
            assert!(max_rel_diff(&x, &x_ref) < 5e-4, "{kind:?}");
            assert!(timers.secs("dmv") >= 0.0);
        }
    }

    #[test]
    fn tiled_matches_naive_all_tile_widths() {
        let pool = ThreadPool::new(4);
        for kind in [UpdateKind::Plain, UpdateKind::WithDiagAndNorm] {
            for tile in [1, 2, 3, 4, 5, 8, 9, 12] {
                let (x0, g, b) = random_problem(41, 9, 2);
                let mut x_naive = x0.clone();
                let mut x_tiled = x0.clone();
                let mut scratch = Mat::zeros(41, 9);
                let mut t1 = PhaseTimers::new();
                let mut t2 = PhaseTimers::new();
                update_naive(&pool, &mut x_naive, &g, &b, kind, &mut t1, "dmv");
                update_tiled(&pool, &mut x_tiled, &mut scratch, &g, &b, tile, kind, &mut t2, ["phase1", "phase2", "phase3"]);
                let d = max_rel_diff(&x_naive, &x_tiled);
                assert!(d < 5e-4, "{kind:?} tile={tile}: rel diff {d}");
            }
        }
    }

    #[test]
    fn tiled_records_phase_timers() {
        let pool = ThreadPool::new(2);
        let (mut x, g, b) = random_problem(30, 8, 3);
        let mut scratch = Mat::zeros(30, 8);
        let mut t = PhaseTimers::new();
        update_tiled(&pool, &mut x, &mut scratch, &g, &b, 4, UpdateKind::WithDiagAndNorm, &mut t, ["phase1", "phase2", "phase3"]);
        assert!(t.count("phase1") > 0);
        assert!(t.count("phase2") > 0);
        assert!(t.count("phase3") > 0);
    }

    #[test]
    fn nonnegativity_invariant() {
        PropConfig::trials(24).run("updates preserve X >= EPS", |gen| {
            let n = gen.usize_in(1, 60);
            let k = gen.usize_in(1, 12);
            let tile = gen.usize_in(1, k);
            let kind =
                *gen.choose(&[UpdateKind::Plain, UpdateKind::WithDiagAndNorm]);
            let seed = gen.usize_in(0, 10_000) as u64;
            let (mut x, g, b) = random_problem(n, k, seed);
            let mut scratch = Mat::zeros(n, k);
            let pool = ThreadPool::new(2);
            let mut t = PhaseTimers::new();
            update_tiled(&pool, &mut x, &mut scratch, &g, &b, tile, kind, &mut t, ["phase1", "phase2", "phase3"]);
            assert!(
                x.data().iter().all(|&v| v > 0.0),
                "found non-positive entry after update"
            );
        });
    }

    #[test]
    fn normalized_columns_are_unit_norm() {
        let pool = ThreadPool::new(3);
        let (mut x, g, b) = random_problem(80, 7, 5);
        let mut scratch = Mat::zeros(80, 7);
        let mut t = PhaseTimers::new();
        update_tiled(&pool, &mut x, &mut scratch, &g, &b, 3, UpdateKind::WithDiagAndNorm, &mut t, ["phase1", "phase2", "phase3"]);
        for j in 0..7 {
            let n: f64 = (0..80).map(|i| (x.at(i, j) as f64).powi(2)).sum();
            assert!((n - 1.0).abs() < 1e-4, "col {j}: ‖·‖² = {n}");
        }
    }

    #[test]
    fn thread_count_invariance() {
        // Same result for 1, 2, 8 threads (static sharding + f64 partial
        // folds in worker order makes the normalized path deterministic
        // only per thread-count; across thread counts we allow fp slack).
        let (x0, g, b) = random_problem(64, 8, 7);
        let mut outs = Vec::new();
        for threads in [1, 2, 8] {
            let pool = ThreadPool::new(threads);
            let mut x = x0.clone();
            let mut scratch = Mat::zeros(64, 8);
            let mut t = PhaseTimers::new();
            update_tiled(&pool, &mut x, &mut scratch, &g, &b, 4, UpdateKind::WithDiagAndNorm, &mut t, ["phase1", "phase2", "phase3"]);
            outs.push(x);
        }
        assert!(max_rel_diff(&outs[0], &outs[1]) < 1e-4);
        assert!(max_rel_diff(&outs[0], &outs[2]) < 1e-4);
    }

    #[test]
    fn property_tiled_equals_naive() {
        PropConfig::trials(20).run("tiled == naive (fp tolerance)", |gen| {
            let n = gen.usize_in(2, 70);
            let k = gen.usize_in(2, 14);
            let tile = gen.usize_in(1, k);
            let seed = gen.usize_in(0, 100_000) as u64;
            let kind = *gen.choose(&[UpdateKind::Plain, UpdateKind::WithDiagAndNorm]);
            let (x0, g, b) = random_problem(n, k, seed);
            let pool = ThreadPool::new(*gen.choose(&[1usize, 3, 4]));
            let mut xn = x0.clone();
            let mut xt = x0.clone();
            let mut scratch = Mat::zeros(n, k);
            let mut t = PhaseTimers::new();
            update_naive(&pool, &mut xn, &g, &b, kind, &mut t, "dmv");
            update_tiled(&pool, &mut xt, &mut scratch, &g, &b, tile, kind, &mut t, ["phase1", "phase2", "phase3"]);
            let d = max_rel_diff(&xn, &xt);
            assert!(d < 1e-3, "n={n} k={k} tile={tile} {kind:?}: diff {d}");
        });
    }

    #[test]
    fn zero_shrink_is_bit_identical() {
        // Passing an explicit zero Shrink must take the exact original
        // path — bitwise, not just within tolerance.
        let pool = ThreadPool::new(3);
        for kind in [UpdateKind::Plain, UpdateKind::WithDiagAndNorm] {
            let (x0, g, b) = random_problem(47, 8, 11);
            let mut t = PhaseTimers::new();
            let mut plainv = x0.clone();
            update_naive(&pool, &mut plainv, &g, &b, kind, &mut t, "dmv");
            let mut reg = x0.clone();
            update_naive_reg(&pool, &mut reg, &g, &b, kind, Shrink { l1: 0.0, l2: 0.0 }, &mut t, "dmv");
            assert_eq!(plainv, reg, "naive {kind:?}");

            let mut tiled = x0.clone();
            let mut s1 = Mat::zeros(47, 8);
            update_tiled(&pool, &mut tiled, &mut s1, &g, &b, 3, kind, &mut t, ["phase1", "phase2", "phase3"]);
            let mut tiled_reg = x0.clone();
            let mut s2 = Mat::zeros(47, 8);
            update_tiled_reg(&pool, &mut tiled_reg, &mut s2, &g, &b, 3, kind, Shrink::NONE, &mut t, ["phase1", "phase2", "phase3"]);
            assert_eq!(tiled, tiled_reg, "tiled {kind:?}");
        }
    }

    #[test]
    fn reg_naive_matches_reference_all_kinds() {
        let pool = ThreadPool::new(4);
        let shrink = Shrink { l1: 0.05, l2: 0.2 };
        for kind in [UpdateKind::Plain, UpdateKind::WithDiag, UpdateKind::WithDiagAndNorm] {
            let (mut x, g, b) = random_problem(53, 7, 13);
            let mut x_ref = x.clone();
            let mut t = PhaseTimers::new();
            update_naive_reg(&pool, &mut x, &g, &b, kind, shrink, &mut t, "dmv");
            update_reference_reg(&mut x_ref, &g, &b, kind, shrink);
            let d = max_rel_diff(&x, &x_ref);
            assert!(d < 5e-4, "{kind:?}: rel diff {d}");
        }
    }

    #[test]
    fn with_diag_matches_reference_without_shrink() {
        // The raw-Gram solve (no shrink) is the exact coordinate-descent
        // fixed point; reference-check it separately since the plain
        // kinds never exercise the division.
        let pool = ThreadPool::new(2);
        let (mut x, g, b) = random_problem(31, 6, 17);
        let mut x_ref = x.clone();
        let mut t = PhaseTimers::new();
        update_naive_reg(&pool, &mut x, &g, &b, UpdateKind::WithDiag, Shrink::NONE, &mut t, "dmv");
        update_reference_reg(&mut x_ref, &g, &b, UpdateKind::WithDiag, Shrink::NONE);
        let d = max_rel_diff(&x, &x_ref);
        assert!(d < 5e-4, "rel diff {d}");
    }

    #[test]
    fn property_reg_tiled_equals_reg_naive() {
        PropConfig::trials(16).run("reg tiled == reg naive (fp tolerance)", |gen| {
            let n = gen.usize_in(2, 60);
            let k = gen.usize_in(2, 12);
            let tile = gen.usize_in(1, k);
            let seed = gen.usize_in(0, 100_000) as u64;
            let kind = *gen.choose(&[UpdateKind::Plain, UpdateKind::WithDiagAndNorm]);
            let shrink = Shrink {
                l1: *gen.choose(&[0.0, 0.01, 0.1]),
                l2: *gen.choose(&[0.0, 0.05, 0.5]),
            };
            let (x0, g, b) = random_problem(n, k, seed);
            let pool = ThreadPool::new(*gen.choose(&[1usize, 3, 4]));
            let mut xn = x0.clone();
            let mut xt = x0.clone();
            let mut scratch = Mat::zeros(n, k);
            let mut t = PhaseTimers::new();
            update_naive_reg(&pool, &mut xn, &g, &b, kind, shrink, &mut t, "dmv");
            update_tiled_reg(&pool, &mut xt, &mut scratch, &g, &b, tile, kind, shrink, &mut t, ["phase1", "phase2", "phase3"]);
            let d = max_rel_diff(&xn, &xt);
            assert!(d < 1e-3, "n={n} k={k} tile={tile} {kind:?} {shrink:?}: diff {d}");
        });
    }

    #[test]
    fn simd_and_scalar_backends_agree_all_kinds_and_shrinks() {
        // Cross-backend parity over every UpdateKind × Shrink combination,
        // with pools pinned to each kernel table via `with_kernels` — no
        // env mutation (lib unit tests share one process, so flipping
        // `PLNMF_KERNELS` here could race unrelated tests). Row counts
        // are chosen to exercise full SIMD lanes and remainder tails.
        let simd = Kernels::detected();
        if simd.backend == crate::kernels::Backend::Scalar {
            return; // host has no AVX2+FMA — nothing to compare against
        }
        let scalar_pool = ThreadPool::with_kernels(3, Kernels::scalar());
        let simd_pool = ThreadPool::with_kernels(3, simd);
        let shrinks = [
            Shrink::NONE,
            Shrink { l1: 0.05, l2: 0.0 },
            Shrink { l1: 0.0, l2: 0.3 },
            Shrink { l1: 0.02, l2: 0.4 },
        ];
        for (n, k, tile) in [(37usize, 7usize, 3usize), (64, 9, 9), (5, 2, 1)] {
            for kind in [UpdateKind::Plain, UpdateKind::WithDiag, UpdateKind::WithDiagAndNorm] {
                for shrink in shrinks {
                    let (x0, g, b) = random_problem(n, k, 23 + n as u64);
                    let mut t = PhaseTimers::new();

                    // Naive kernel (its row dots are reassociated on AVX2
                    // — same ≤2e-3 slack as the tiled-vs-naive property).
                    let mut xs_ = x0.clone();
                    let mut xv = x0.clone();
                    update_naive_reg(&scalar_pool, &mut xs_, &g, &b, kind, shrink, &mut t, "dmv");
                    update_naive_reg(&simd_pool, &mut xv, &g, &b, kind, shrink, &mut t, "dmv");
                    let d = max_rel_diff(&xs_, &xv);
                    assert!(d < 2e-3, "naive {kind:?} {shrink:?} n={n}: backend diff {d}");

                    // Tiled kernel (WithDiag is naive/serving-only).
                    if kind != UpdateKind::WithDiag {
                        let mut xs_ = x0.clone();
                        let mut xv = x0.clone();
                        let mut s1 = Mat::zeros(n, k);
                        let mut s2 = Mat::zeros(n, k);
                        update_tiled_reg(&scalar_pool, &mut xs_, &mut s1, &g, &b, tile, kind, shrink, &mut t, ["phase1", "phase2", "phase3"]);
                        update_tiled_reg(&simd_pool, &mut xv, &mut s2, &g, &b, tile, kind, shrink, &mut t, ["phase1", "phase2", "phase3"]);
                        let d = max_rel_diff(&xs_, &xv);
                        assert!(d < 2e-3, "tiled {kind:?} {shrink:?} n={n} tile={tile}: backend diff {d}");
                    }
                }
            }
        }
    }

    #[test]
    fn l1_shrink_sparsifies() {
        // A strong L1 should pin (many) more entries to the EPS floor
        // than the unregularized update does.
        let pool = ThreadPool::new(2);
        let (x0, g, b) = random_problem(64, 8, 19);
        let mut t = PhaseTimers::new();
        let mut free = x0.clone();
        update_naive(&pool, &mut free, &g, &b, UpdateKind::Plain, &mut t, "dmv");
        let mut shrunk = x0.clone();
        update_naive_reg(
            &pool,
            &mut shrunk,
            &g,
            &b,
            UpdateKind::Plain,
            Shrink { l1: 1.0, l2: 0.0 },
            &mut t,
            "dmv",
        );
        let at_floor = |m: &Mat| m.data().iter().filter(|&&v| v <= EPS).count();
        assert!(
            at_floor(&shrunk) > at_floor(&free),
            "l1=1.0 floored {} entries vs {} unregularized",
            at_floor(&shrunk),
            at_floor(&free)
        );
    }
}

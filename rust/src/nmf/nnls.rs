//! Non-negative least squares via Block Principal Pivoting
//! (Kim & Park 2011) — the solver inside the ANLS-BPP baseline.
//!
//! Solves, for every row `b` of `B` (n×K) independently:
//!
//! ```text
//! min_{x ≥ 0} ‖F·x − a‖²   ⇔   G·x − b = y,  x ≥ 0, y ≥ 0, xᵀy = 0
//! ```
//!
//! with `G = FᵀF` (K×K, SPD up to ridge) and `b = Fᵀa` supplied by the
//! caller. Each row maintains a passive set `P` (x free, y = 0); each BPP
//! iteration solves the passive subsystem by Cholesky and exchanges
//! infeasible variables — full exchange while progress is made, Murty's
//! single-variable backup rule otherwise (guarantees termination).
//!
//! Rows are solved in parallel chunks. The first iteration's all-passive
//! solve is shared across every row (one factorization of the full `G`),
//! which is the common case for well-conditioned interior solutions.

use crate::linalg::Mat;
use crate::parallel::ThreadPool;
use crate::Elem;

use super::halsops::{SharedRows, Shrink};

/// Ridge added to G's diagonal for numerical safety.
const RIDGE: f64 = 1e-10;
/// Maximum BPP exchanges per row before declaring non-convergence (the
/// row then keeps its best-effort clamped solution).
const MAX_EXCHANGES: usize = 200;

/// Solve all rows of `X` (n×K): `min ‖·‖, x ≥ 0` with shared Gram `G` and
/// per-row rhs from `B`. `X` is overwritten with the solutions.
pub fn nnls_bpp_rows(pool: &ThreadPool, g: &Mat, b: &Mat, x: &mut Mat) {
    nnls_bpp_rows_reg(pool, g, b, x, Shrink::NONE);
}

/// [`nnls_bpp_rows`] with the elastic-net penalty: the exact KKT system
/// of `min_{x≥0} ½‖F·x − a‖² + l1·Σx + ½·l2·‖x‖²` is the plain NNLS
/// system with `G + l2·I` and `b − l1` — L2 joins the (shared) Gram
/// diagonal once, L1 shifts every rhs read. `Shrink::NONE` is the
/// identical unregularized path (adding 0.0 is exact in IEEE, and the
/// shared `g64` build skips the add entirely).
pub fn nnls_bpp_rows_reg(pool: &ThreadPool, g: &Mat, b: &Mat, x: &mut Mat, shrink: Shrink) {
    let k = g.rows();
    assert_eq!(g.cols(), k);
    assert_eq!(b.cols(), k);
    assert_eq!((x.rows(), x.cols()), (b.rows(), k));

    // f64 copy of G once (all solves read it), ridge-regularized.
    let mut g64: Vec<f64> = g.data().iter().map(|&v| v as f64).collect();
    if shrink.l2 != 0.0 {
        for j in 0..k {
            g64[j * k + j] += shrink.l2 as f64;
        }
    }
    let l1 = shrink.l1 as f64;

    let xs = SharedRows::new(x);
    pool.parallel_for(b.rows(), Some(8), |rows| {
        let mut solver = RowSolver::new(k);
        for i in rows {
            let xrow = unsafe { xs.row_mut(i) };
            solver.solve(&g64, b.row(i), l1, xrow);
        }
    });
}

/// Workspace for one row's BPP iterations (reused across rows in a
/// chunk — no allocation in the inner loop).
struct RowSolver {
    k: usize,
    passive: Vec<bool>,
    idx: Vec<usize>,     // passive indices, packed
    chol: Vec<f64>,      // packed lower-triangular factor (k*k scratch)
    rhs: Vec<f64>,
    x: Vec<f64>,
    y: Vec<f64>,
}

impl RowSolver {
    fn new(k: usize) -> RowSolver {
        RowSolver {
            k,
            passive: vec![true; k],
            idx: Vec::with_capacity(k),
            chol: vec![0.0; k * k],
            rhs: vec![0.0; k],
            x: vec![0.0; k],
            y: vec![0.0; k],
        }
    }

    /// BPP for a single row; writes the non-negative solution into
    /// `out`. `l1` shifts every read of `b` (elastic-net L1 term; 0.0
    /// for plain NNLS — subtracting 0.0 is bit-exact).
    fn solve(&mut self, g: &[f64], b: &[Elem], l1: f64, out: &mut [Elem]) {
        let k = self.k;
        // Start all-passive (unconstrained LS), the Kim–Park default.
        self.passive.iter_mut().for_each(|p| *p = true);

        let mut best_infeasible = usize::MAX;
        let mut backup_budget = 3usize;

        for _ in 0..MAX_EXCHANGES {
            // -- solve passive subsystem ----------------------------------
            self.idx.clear();
            self.idx.extend((0..k).filter(|&j| self.passive[j]));
            let p = self.idx.len();
            self.x.iter_mut().for_each(|v| *v = 0.0);
            if p > 0 {
                // Build G_PP and b_P.
                for (pi, &gi) in self.idx.iter().enumerate() {
                    for (pj, &gj) in self.idx.iter().enumerate() {
                        self.chol[pi * p + pj] = g[gi * k + gj];
                    }
                    self.chol[pi * p + pi] += RIDGE;
                    self.rhs[pi] = b[gi] as f64 - l1;
                }
                if !cholesky_solve_in_place(&mut self.chol, &mut self.rhs, p) {
                    // Singular passive block: clamp what we have and stop.
                    break;
                }
                for (pi, &gi) in self.idx.iter().enumerate() {
                    self.x[gi] = self.rhs[pi];
                }
            }
            // -- dual for active set: y_A = G_A,P x_P − b_A ----------------
            for j in 0..k {
                self.y[j] = if self.passive[j] {
                    0.0
                } else {
                    let mut s = -(b[j] as f64 - l1);
                    for &gi in &self.idx {
                        s += g[j * k + gi] * self.x[gi];
                    }
                    s
                };
            }
            // -- infeasibilities ------------------------------------------
            let mut v1: Option<usize> = None; // largest-index infeasible
            let mut count = 0usize;
            for j in 0..k {
                let infeasible =
                    (self.passive[j] && self.x[j] < 0.0) || (!self.passive[j] && self.y[j] < 0.0);
                if infeasible {
                    count += 1;
                    v1 = Some(j);
                }
            }
            if count == 0 {
                break; // KKT satisfied
            }
            // -- exchange rule --------------------------------------------
            if count < best_infeasible {
                best_infeasible = count;
                backup_budget = 3;
                // full exchange
                for j in 0..k {
                    if self.passive[j] && self.x[j] < 0.0 {
                        self.passive[j] = false;
                    } else if !self.passive[j] && self.y[j] < 0.0 {
                        self.passive[j] = true;
                    }
                }
            } else if backup_budget > 0 {
                backup_budget -= 1;
                for j in 0..k {
                    if self.passive[j] && self.x[j] < 0.0 {
                        self.passive[j] = false;
                    } else if !self.passive[j] && self.y[j] < 0.0 {
                        self.passive[j] = true;
                    }
                }
            } else {
                // Murty's backup: flip only the largest infeasible index.
                let j = v1.unwrap();
                self.passive[j] = !self.passive[j];
            }
        }

        for j in 0..k {
            out[j] = self.x[j].max(0.0) as Elem;
        }
    }
}

/// In-place Cholesky factorization + solve of a dense SPD `p×p` system
/// stored row-major in `a[..p*p]`, rhs in `b[..p]`. Returns false if the
/// matrix is not positive definite.
fn cholesky_solve_in_place(a: &mut [f64], b: &mut [f64], p: usize) -> bool {
    // Factor: a = L·Lᵀ (L in the lower triangle).
    for i in 0..p {
        for j in 0..=i {
            let mut s = a[i * p + j];
            for t in 0..j {
                s -= a[i * p + t] * a[j * p + t];
            }
            if i == j {
                if s <= 0.0 {
                    return false;
                }
                a[i * p + i] = s.sqrt();
            } else {
                a[i * p + j] = s / a[j * p + j];
            }
        }
    }
    // Forward substitution: L z = b.
    for i in 0..p {
        let mut s = b[i];
        for t in 0..i {
            s -= a[i * p + t] * b[t];
        }
        b[i] = s / a[i * p + i];
    }
    // Back substitution: Lᵀ x = z.
    for i in (0..p).rev() {
        let mut s = b[i];
        for t in (i + 1)..p {
            s -= a[t * p + i] * b[t];
        }
        b[i] = s / a[i * p + i];
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gram::gram_naive;
    use crate::testing::PropConfig;
    use crate::util::rng::Pcg32;

    /// Brute-force reference: try every active set (2^K subsets), pick
    /// the feasible KKT point (K ≤ 8 only).
    fn nnls_exhaustive(g: &Mat, b: &[Elem]) -> Vec<f64> {
        let k = g.rows();
        let mut best: Option<(f64, Vec<f64>)> = None;
        for mask in 0u32..(1 << k) {
            let idx: Vec<usize> = (0..k).filter(|&j| mask & (1 << j) != 0).collect();
            let p = idx.len();
            let mut a = vec![0.0f64; p * p];
            let mut rhs = vec![0.0f64; p];
            for (pi, &gi) in idx.iter().enumerate() {
                for (pj, &gj) in idx.iter().enumerate() {
                    a[pi * p + pj] = g.at(gi, gj) as f64;
                }
                a[pi * p + pi] += RIDGE;
                rhs[pi] = b[gi] as f64;
            }
            if p > 0 && !cholesky_solve_in_place(&mut a, &mut rhs, p) {
                continue;
            }
            let mut x = vec![0.0f64; k];
            for (pi, &gi) in idx.iter().enumerate() {
                x[gi] = rhs[pi];
            }
            if x.iter().any(|&v| v < -1e-9) {
                continue;
            }
            // objective ∝ ½xᵀGx − bᵀx
            let mut obj = 0.0;
            for i in 0..k {
                for j in 0..k {
                    obj += 0.5 * x[i] * g.at(i, j) as f64 * x[j];
                }
                obj -= b[i] as f64 * x[i];
            }
            if best.as_ref().map(|(o, _)| obj < *o - 1e-12).unwrap_or(true) {
                best = Some((obj, x));
            }
        }
        best.unwrap().1
    }

    fn random_spd(k: usize, seed: u64) -> Mat {
        let mut rng = Pcg32::seeded(seed);
        let f = Mat::random(k + 5, k, &mut rng, -1.0, 1.0);
        gram_naive(&f)
    }

    #[test]
    fn cholesky_solves_known_system() {
        // [[4,2],[2,3]] x = [10, 9] -> x = [1.5, 2]
        let mut a = vec![4.0, 2.0, 2.0, 3.0];
        let mut b = vec![10.0, 9.0];
        assert!(cholesky_solve_in_place(&mut a, &mut b, 2));
        assert!((b[0] - 1.5).abs() < 1e-12);
        assert!((b[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        let mut b = vec![1.0, 1.0];
        assert!(!cholesky_solve_in_place(&mut a, &mut b, 2));
    }

    #[test]
    fn matches_exhaustive_small() {
        PropConfig::trials(40).run("BPP == exhaustive KKT", |gen| {
            let k = gen.usize_in(1, 6);
            let seed = gen.usize_in(0, 1_000_000) as u64;
            let g = random_spd(k, seed);
            let mut rng = Pcg32::seeded(seed ^ 0xabc);
            let b: Vec<Elem> = (0..k).map(|_| rng.range_f32(-2.0, 2.0)).collect();

            let bmat = Mat::from_vec(1, k, b.clone());
            let mut x = Mat::zeros(1, k);
            let pool = ThreadPool::new(1);
            nnls_bpp_rows(&pool, &g, &bmat, &mut x);

            let x_ref = nnls_exhaustive(&g, &b);
            for j in 0..k {
                assert!(
                    (x.at(0, j) as f64 - x_ref[j]).abs() < 1e-4,
                    "k={k} j={j}: bpp {} vs ref {}",
                    x.at(0, j),
                    x_ref[j]
                );
            }
        });
    }

    #[test]
    fn unconstrained_interior_solution() {
        // If the LS solution is already non-negative, BPP returns it.
        let g = Mat::from_vec(2, 2, vec![2.0, 0.0, 0.0, 2.0]);
        let b = Mat::from_vec(1, 2, vec![4.0, 6.0]);
        let mut x = Mat::zeros(1, 2);
        let pool = ThreadPool::new(1);
        nnls_bpp_rows(&pool, &g, &b, &mut x);
        assert!((x.at(0, 0) - 2.0).abs() < 1e-5);
        assert!((x.at(0, 1) - 3.0).abs() < 1e-5);
    }

    #[test]
    fn negative_rhs_gives_zero() {
        let g = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let b = Mat::from_vec(1, 2, vec![-1.0, -5.0]);
        let mut x = Mat::zeros(1, 2);
        let pool = ThreadPool::new(1);
        nnls_bpp_rows(&pool, &g, &b, &mut x);
        assert_eq!(x.at(0, 0), 0.0);
        assert_eq!(x.at(0, 1), 0.0);
    }

    #[test]
    fn elastic_net_equals_shifted_plain_system() {
        // The reg path must solve exactly the plain system with
        // `G + l2·I` and `b − l1` — assert bitwise agreement against
        // explicitly shifted inputs.
        let k = 5;
        let g = random_spd(k, 21);
        let mut rng = Pcg32::seeded(22);
        let b = Mat::random(12, k, &mut rng, -1.0, 3.0);
        let shrink = Shrink { l1: 0.3, l2: 0.7 };
        let pool = ThreadPool::new(2);

        let mut x_reg = Mat::zeros(12, k);
        nnls_bpp_rows_reg(&pool, &g, &b, &mut x_reg, shrink);

        let mut g_shift = g.clone();
        for j in 0..k {
            *g_shift.at_mut(j, j) = (g.at(j, j) as f64 + shrink.l2 as f64) as Elem;
        }
        let mut b_shift = b.clone();
        for v in b_shift.data_mut().iter_mut() {
            *v = (*v as f64 - shrink.l1 as f64) as Elem;
        }
        let mut x_plain = Mat::zeros(12, k);
        nnls_bpp_rows(&pool, &g_shift, &b_shift, &mut x_plain);

        // Shifts are applied in f64 inside the reg path, so the f32
        // pre-shift can differ by rounding — allow fp slack only.
        let d = x_reg.max_abs_diff(&x_plain);
        assert!(d < 1e-5, "reg vs shifted-plain diff {d}");
        assert!(x_reg.data().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn l1_zeroes_weak_coordinates() {
        let g = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let b = Mat::from_vec(1, 2, vec![0.5, 3.0]);
        let pool = ThreadPool::new(1);
        let mut x = Mat::zeros(1, 2);
        nnls_bpp_rows_reg(&pool, &g, &b, &mut x, Shrink { l1: 1.0, l2: 0.0 });
        // b0 − l1 < 0 ⇒ coordinate 0 inactive; b1 − l1 = 2.
        assert_eq!(x.at(0, 0), 0.0);
        assert!((x.at(0, 1) - 2.0).abs() < 1e-5);
    }

    #[test]
    fn many_rows_parallel() {
        let k = 7;
        let g = random_spd(k, 3);
        let mut rng = Pcg32::seeded(4);
        let n = 100;
        let b = Mat::random(n, k, &mut rng, -1.0, 3.0);
        let mut x1 = Mat::zeros(n, k);
        let mut x4 = Mat::zeros(n, k);
        nnls_bpp_rows(&ThreadPool::new(1), &g, &b, &mut x1);
        nnls_bpp_rows(&ThreadPool::new(4), &g, &b, &mut x4);
        assert_eq!(x1, x4, "row-independent solves must not depend on threads");
        assert!(x1.data().iter().all(|&v| v >= 0.0));
    }
}

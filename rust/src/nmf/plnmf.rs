//! PL-NMF engine — the paper's contribution (Alg. 2): FAST-HALS with the
//! tiled three-phase locality-optimized factor updates.
//!
//! Timer keys: `spmm_r`, `gram_s`, `h_phase1/2/3` (H update);
//! `spmm_p`, `gram_q`, `w_phase1/2/3` (W update — the Table 5 rows).

use std::sync::Arc;

use crate::data::Dataset;
use crate::linalg::Mat;
use crate::parallel::ThreadPool;
use crate::util::PhaseTimers;
use crate::Result;

use super::cost_model;
use super::halsops::{update_tiled, update_tiled_reg, UpdateKind};
use super::products;
use super::spec::{EngineSpec, Loss};
use super::traits::{EngineCtx, NmfEngine};
use super::Factors;

pub struct PlNmfEngine {
    ctx: EngineCtx,
    r: Mat,
    p: Mat,
    /// Scratch for the pre-update factor copy (W_old / H_old of Alg. 2),
    /// sized for the larger factor and reused by both updates.
    scratch_w: Mat,
    scratch_h: Mat,
    tile: usize,
}

impl PlNmfEngine {
    /// `tile = 0` selects T from the §5 model (Eq. 11) given
    /// `cache_bytes`.
    pub fn new(
        ds: Arc<Dataset>,
        pool: Arc<ThreadPool>,
        k: usize,
        seed: u64,
        tile: usize,
        cache_bytes: usize,
    ) -> Self {
        PlNmfEngine::with_spec(ds, pool, k, seed, tile, cache_bytes, EngineSpec::default())
    }

    /// Construct with an [`EngineSpec`] (init + H-side elastic net; the
    /// KL loss has no HALS rule and is rejected).
    #[allow(clippy::too_many_arguments)]
    pub fn with_spec(
        ds: Arc<Dataset>,
        pool: Arc<ThreadPool>,
        k: usize,
        seed: u64,
        tile: usize,
        cache_bytes: usize,
        spec: EngineSpec,
    ) -> Self {
        assert!(
            spec.loss != Loss::Kl,
            "the HALS solver is Frobenius-only; use the mu solver for kl"
        );
        let tile = if tile == 0 { cost_model::select_tile(k, cache_bytes) } else { tile };
        let ctx = EngineCtx::with_spec(ds, pool, k, seed, spec);
        let (r, p) = ctx.buffers();
        let scratch_w = Mat::zeros(ctx.ds.v(), k);
        let scratch_h = Mat::zeros(ctx.ds.d(), k);
        PlNmfEngine { ctx, r, p, scratch_w, scratch_h, tile }
    }

    pub fn tile(&self) -> usize {
        self.tile
    }

    pub fn set_factors(&mut self, f: Factors) {
        self.ctx.factors = f;
    }
}

impl NmfEngine for PlNmfEngine {
    fn name(&self) -> &'static str {
        "plnmf-cpu"
    }

    fn step(&mut self) -> Result<()> {
        let EngineCtx { ds, pool, factors, timers, spec } = &mut self.ctx;
        let shrink = spec.shrink();

        // ---- update H: tiled, no normalization --------------------------
        timers.time("spmm_r", || products::at_times(pool, ds, &factors.w, &mut self.r));
        let s = timers.time("gram_s", || products::factor_gram(pool, &factors.w));
        update_tiled_reg(
            pool,
            &mut factors.h,
            &mut self.scratch_h,
            &s,
            &self.r,
            self.tile,
            UpdateKind::Plain,
            shrink,
            timers,
            ["h_phase1", "h_phase2", "h_phase3"],
        );

        // ---- update W: tiled + interleaved normalization (Alg. 2) -------
        timers.time("spmm_p", || products::a_times(pool, ds, &factors.h, &mut self.p));
        let q = timers.time("gram_q", || products::factor_gram(pool, &factors.h));
        update_tiled(
            pool,
            &mut factors.w,
            &mut self.scratch_w,
            &q,
            &self.p,
            self.tile,
            UpdateKind::WithDiagAndNorm,
            timers,
            ["w_phase1", "w_phase2", "w_phase3"],
        );
        Ok(())
    }

    fn factors(&self) -> &Factors {
        &self.ctx.factors
    }

    fn timers(&self) -> &PhaseTimers {
        &self.ctx.timers
    }

    fn reset_timers(&mut self) {
        self.ctx.timers.reset();
    }

    fn dataset(&self) -> &Dataset {
        &self.ctx.ds
    }

    fn pool(&self) -> &ThreadPool {
        &self.ctx.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::load_dataset;
    use crate::nmf::fasthals::FastHalsEngine;

    #[test]
    fn matches_fasthals_trajectory() {
        // The paper's associativity argument: tiled and naive FAST-HALS
        // follow the same convergence trajectory (Fig. 8 shows identical
        // curves for planc-HALS and PL-NMF). Same init → same errors up
        // to fp reassociation.
        for name in ["tiny", "tiny-sparse"] {
            let ds = Arc::new(load_dataset(name, 5).unwrap());
            let pool = Arc::new(ThreadPool::new(3));
            let mut hals = FastHalsEngine::new(ds.clone(), pool.clone(), 5, 99);
            let mut pl = PlNmfEngine::new(ds, pool, 5, 99, 2, 35 << 20);
            let th = hals.run(10, 1, 0.0).unwrap();
            let tp = pl.run(10, 1, 0.0).unwrap();
            for (a, b) in th.iter().zip(&tp) {
                assert!(
                    (a.rel_error - b.rel_error).abs() < 2e-3,
                    "{name} iter {}: hals {} vs plnmf {}",
                    a.iter,
                    a.rel_error,
                    b.rel_error
                );
            }
        }
    }

    #[test]
    fn regularized_matches_regularized_fasthals() {
        // The associativity argument holds with the shrink applied: the
        // tiled and naive regularized engines share a trajectory.
        let spec = EngineSpec { alpha: 0.2, l1_ratio: 0.5, ..Default::default() };
        let ds = Arc::new(load_dataset("tiny", 5).unwrap());
        let pool = Arc::new(ThreadPool::new(3));
        let mut hals = FastHalsEngine::with_spec(ds.clone(), pool.clone(), 5, 99, spec);
        let mut pl = PlNmfEngine::with_spec(ds, pool, 5, 99, 2, 35 << 20, spec);
        let th = hals.run(8, 1, 0.0).unwrap();
        let tp = pl.run(8, 1, 0.0).unwrap();
        for (a, b) in th.iter().zip(&tp) {
            assert!(
                (a.rel_error - b.rel_error).abs() < 2e-3,
                "iter {}: hals {} vs plnmf {}",
                a.iter,
                a.rel_error,
                b.rel_error
            );
        }
    }

    #[test]
    fn auto_tile_uses_model() {
        let ds = Arc::new(load_dataset("tiny", 1).unwrap());
        let pool = Arc::new(ThreadPool::new(1));
        let e = PlNmfEngine::new(ds, pool, 16, 1, 0, 35 << 20);
        assert_eq!(e.tile(), cost_model::select_tile(16, 35 << 20));
    }

    #[test]
    fn error_decreases() {
        let ds = Arc::new(load_dataset("tiny-sparse", 8).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = PlNmfEngine::new(ds, pool, 4, 3, 0, 35 << 20);
        let trace = e.run(15, 1, 0.0).unwrap();
        assert!(trace.last().unwrap().rel_error < trace[0].rel_error * 0.98);
    }

    #[test]
    fn phase_timers_present() {
        let ds = Arc::new(load_dataset("tiny", 4).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = PlNmfEngine::new(ds, pool, 6, 2, 2, 35 << 20);
        e.step().unwrap();
        for key in ["w_phase1", "w_phase2", "w_phase3", "h_phase1", "h_phase2", "h_phase3"] {
            assert!(e.timers().count(key) > 0, "{key}");
        }
    }

    #[test]
    fn tile_not_dividing_k_still_converges() {
        let ds = Arc::new(load_dataset("tiny", 6).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = PlNmfEngine::new(ds, pool, 7, 3, 3, 35 << 20); // 3 ∤ 7
        let trace = e.run(8, 1, 0.0).unwrap();
        assert!(trace.last().unwrap().rel_error <= trace[0].rel_error);
    }
}

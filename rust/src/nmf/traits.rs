//! The engine abstraction shared by native-rust and PJRT-backed NMF
//! implementations.

use std::sync::Arc;

use crate::data::Dataset;
use crate::linalg::Mat;
use crate::parallel::ThreadPool;
use crate::util::PhaseTimers;
use crate::Result;

use super::error;
use super::init::Factors;
use super::spec::EngineSpec;

/// One row of a convergence trace (Figs. 7/8 data points).
#[derive(Debug, Clone, Copy)]
pub struct IterRecord {
    pub iter: usize,
    /// Cumulative *update* time (seconds) — excludes the error
    /// evaluation itself, matching how the paper times convergence.
    pub elapsed_secs: f64,
    pub rel_error: f64,
}

/// An NMF solver that advances one outer iteration at a time.
///
/// Not `Send`: the PJRT-backed engines hold an `Rc`-based client and must
/// stay on their creating thread (native engines are thread-safe but the
/// driver runs every engine on the leader thread anyway).
pub trait NmfEngine {
    /// Engine display name (matches `EngineKind::name`).
    fn name(&self) -> &'static str;

    /// Perform one outer iteration (full H update + full W update).
    fn step(&mut self) -> Result<()>;

    /// Current factors (`h` in the D×K transposed layout).
    fn factors(&self) -> &Factors;

    /// Accumulated phase timers (keys documented per engine).
    fn timers(&self) -> &PhaseTimers;

    fn reset_timers(&mut self);

    fn dataset(&self) -> &Dataset;

    fn pool(&self) -> &ThreadPool;

    /// Relative objective of the current factors (not included in step
    /// timing).
    fn rel_error(&self) -> f64 {
        let f = self.factors();
        error::rel_error(self.pool(), self.dataset(), &f.w, &f.h)
    }

    /// Run `iters` iterations, recording the error every `record_every`
    /// (and always at iteration 0 and the last). `tol`, if positive,
    /// stops early when the error improves less than `tol` over a
    /// 5-record window.
    fn run(&mut self, iters: usize, record_every: usize, tol: f64) -> Result<Vec<IterRecord>> {
        let record_every = record_every.max(1);
        let mut trace = Vec::with_capacity(iters / record_every + 2);
        trace.push(IterRecord { iter: 0, elapsed_secs: 0.0, rel_error: self.rel_error() });
        let mut elapsed = 0.0f64;
        for it in 1..=iters {
            let t = std::time::Instant::now();
            self.step()?;
            elapsed += t.elapsed().as_secs_f64();
            if it % record_every == 0 || it == iters {
                trace.push(IterRecord { iter: it, elapsed_secs: elapsed, rel_error: self.rel_error() });
                if tol > 0.0 && trace.len() > 5 {
                    let prev = trace[trace.len() - 6].rel_error;
                    let cur = trace[trace.len() - 1].rel_error;
                    if prev - cur < tol {
                        break;
                    }
                }
            }
        }
        Ok(trace)
    }
}

/// Shared state owned by every native engine.
pub struct EngineCtx {
    pub ds: Arc<Dataset>,
    pub pool: Arc<ThreadPool>,
    pub factors: Factors,
    pub timers: PhaseTimers,
    /// Loss/regularization/init configuration. The default spec is the
    /// exact pre-spec pipeline; engines apply its shrink to the **H**
    /// update only (W keeps its unit-norm invariant).
    pub spec: EngineSpec,
}

impl EngineCtx {
    pub fn new(ds: Arc<Dataset>, pool: Arc<ThreadPool>, k: usize, seed: u64) -> EngineCtx {
        EngineCtx::with_spec(ds, pool, k, seed, EngineSpec::default())
    }

    pub fn with_spec(
        ds: Arc<Dataset>,
        pool: Arc<ThreadPool>,
        k: usize,
        seed: u64,
        spec: EngineSpec,
    ) -> EngineCtx {
        let factors = Factors::init(&ds, k, seed, spec.init);
        EngineCtx { ds, pool, factors, timers: PhaseTimers::new(), spec }
    }

    /// Pre-sized product buffers: R (D×K) and P (V×K).
    pub fn buffers(&self) -> (Mat, Mat) {
        let k = self.factors.k();
        (Mat::zeros(self.ds.d(), k), Mat::zeros(self.ds.v(), k))
    }
}

//! Multiplicative updates for the Kullback–Leibler objective —
//! the second objective family of §2.1 (Lee & Seung's original KL rules;
//! the GPU baselines of Lopes et al. evaluate both Euclidean and KL).
//! An *extension* relative to the paper's evaluation (which is
//! Frobenius-only), included because the NMF substrate is objective-
//! parametric and downstream topic-modeling users overwhelmingly run KL.
//!
//! ```text
//! W_vk ← W_vk · Σ_d (A_vd / (WH)_vd) H_kd / Σ_d H_kd
//! H_kd ← H_kd · Σ_v W_vk (A_vd / (WH)_vd) / Σ_v W_vk
//! ```
//!
//! `(WH)_vd` is only ever needed at the non-zeros of `A`, so the sparse
//! path costs O(nnz·K) per half-step — the same order as the Frobenius
//! MU. Convergence is tracked with the (normalized) KL divergence
//! `D(A‖WH) = Σ a·ln(a/(wh)) − a + wh`, reported through the common
//! `IterRecord.rel_error` channel as `D/D₀`-style absolute divergence.
//!
//! Timer keys: `h_mukl`, `w_mukl`.

use std::sync::Arc;

use crate::data::{DataMatrix, Dataset};
use crate::linalg::Mat;
use crate::parallel::{reduce, ThreadPool};
use crate::util::PhaseTimers;
use crate::Result;

use super::halsops::{SharedRows, Shrink};
use super::spec::{EngineSpec, Loss, Solver};
use super::traits::{EngineCtx, NmfEngine};
use super::Factors;

const DELTA: f32 = 1e-9;

pub struct MuKlEngine {
    ctx: EngineCtx,
    /// Numerator accumulator, reused for both half-steps (max(V,D) × K).
    num: Mat,
}

impl MuKlEngine {
    pub fn new(ds: Arc<Dataset>, pool: Arc<ThreadPool>, k: usize, seed: u64) -> Self {
        let spec = EngineSpec { loss: Loss::Kl, solver: Solver::Mu, ..Default::default() };
        MuKlEngine::with_spec(ds, pool, k, seed, spec)
    }

    /// Construct with an [`EngineSpec`] (must carry the KL loss; the
    /// Frobenius MU rules live in `MuEngine`). The elastic-net terms
    /// join the H half-step's denominator.
    pub fn with_spec(
        ds: Arc<Dataset>,
        pool: Arc<ThreadPool>,
        k: usize,
        seed: u64,
        spec: EngineSpec,
    ) -> Self {
        assert!(
            spec.loss == Loss::Kl,
            "MuKlEngine optimizes the KL objective; use MuEngine for frobenius"
        );
        let ctx = EngineCtx::with_spec(ds, pool, k, seed, spec);
        let n = ctx.ds.v().max(ctx.ds.d());
        let num = Mat::zeros(n, k);
        MuKlEngine { ctx, num }
    }

    pub fn set_factors(&mut self, f: Factors) {
        self.ctx.factors = f;
    }

    /// KL divergence `Σ a ln(a/(wh)) − a + wh` over the support of A
    /// plus the full `Σ (wh)` term (computed via factor column sums, no
    /// V×D materialization).
    pub fn kl_divergence(&self) -> f64 {
        let f = &self.ctx.factors;
        let (w, h) = (&f.w, &f.h);
        let k = f.k();
        let kern = self.ctx.pool.kernels();
        // Σ_vd (WH)_vd = Σ_k (Σ_v W_vk)(Σ_d H_dk)
        let mut wsum = vec![0.0f64; k];
        for i in 0..w.rows() {
            (kern.colsum_f64)(w.row(i), &mut wsum);
        }
        let mut hsum = vec![0.0f64; k];
        for i in 0..h.rows() {
            (kern.colsum_f64)(h.row(i), &mut hsum);
        }
        let total_wh: f64 = wsum.iter().zip(&hsum).map(|(a, b)| a * b).sum();

        let support_terms = |v: usize, d: usize, a: f32| -> f64 {
            let wh = dot_wh(w, h, v, d) as f64 + DELTA as f64;
            let a = a as f64;
            a * (a / wh).ln() - a
        };
        let pool = &self.ctx.pool;
        let sum_support = match &self.ctx.ds.a {
            DataMatrix::Sparse(csr) => reduce(
                pool,
                csr.rows(),
                |rows| {
                    let mut s = 0.0f64;
                    for v in rows {
                        let (cols, vals) = csr.row(v);
                        for (&d, &a) in cols.iter().zip(vals) {
                            s += support_terms(v, d as usize, a);
                        }
                    }
                    s
                },
                |a, b| a + b,
            )
            .unwrap_or(0.0),
            DataMatrix::Dense(m) => reduce(
                pool,
                m.rows(),
                |rows| {
                    let mut s = 0.0f64;
                    for v in rows {
                        for (d, &a) in m.row(v).iter().enumerate() {
                            if a > 0.0 {
                                s += support_terms(v, d, a);
                            }
                        }
                    }
                    s
                },
                |a, b| a + b,
            )
            .unwrap_or(0.0),
        };
        sum_support + total_wh
    }
}

#[inline]
fn dot_wh(w: &Mat, h: &Mat, v: usize, d: usize) -> f32 {
    let wr = w.row(v);
    let hr = h.row(d);
    let mut s = 0.0f32;
    for (a, b) in wr.iter().zip(hr) {
        s += a * b;
    }
    s
}

/// One KL half-step updating `x` (n×K) given the fixed factor `other`
/// (m×K): `x ← x ⊙ num ⊘ (colsum(other) + l1 + l2·x)` where
/// `num[i][k] = Σ_j ratio(i,j)·other[j][k]` over A's support (with A in
/// the orientation that makes `i` the rows). `Shrink::NONE` is the
/// identical (bit-for-bit) unregularized path.
///
/// Composed of [`kl_colsum`] → [`kl_numer`] → [`kl_apply`] so the
/// distributed sweep can run the pieces on different hosts (workers
/// compute per-shard colsums and numerator partials, the coordinator
/// reduces and applies) with the exact single-process arithmetic.
pub(crate) fn kl_half_step(
    pool: &ThreadPool,
    a: &DataMatrix,
    x: &mut Mat,
    other: &Mat,
    num: &mut Mat,
    shrink: Shrink,
) {
    let denom = kl_colsum(pool, other);
    kl_numer(pool, a, x, other, num);
    kl_apply(pool, x, num, &denom, shrink);
}

/// Column sums of the fixed factor (the KL denominator), f64-accumulated.
pub(crate) fn kl_colsum(pool: &ThreadPool, other: &Mat) -> Vec<f64> {
    let k = other.cols();
    let kern = pool.kernels();
    reduce(
        pool,
        other.rows(),
        |rows| {
            let mut s = vec![0.0f64; k];
            for i in rows {
                (kern.colsum_f64)(other.row(i), &mut s);
            }
            s
        },
        |mut a, b| {
            for (x, y) in a.iter_mut().zip(&b) {
                *x += *y;
            }
            a
        },
    )
    .unwrap_or_else(|| vec![0.0; k])
}

/// KL numerators over A's support; rows of `num` match rows of `x` and
/// are zeroed before accumulation (rows of `num` beyond `a`'s row count
/// are left untouched — callers reuse oversized buffers).
pub(crate) fn kl_numer(pool: &ThreadPool, a: &DataMatrix, x: &Mat, other: &Mat, num: &mut Mat) {
    let k = x.cols();
    let kern = pool.kernels();
    let xs = SharedRows::new(num);
    match a {
        DataMatrix::Sparse(csr) => {
            pool.parallel_for(csr.rows(), None, |rows| {
                for i in rows {
                    let nrow = unsafe { xs.row_mut(i) };
                    nrow[..k].fill(0.0);
                    let (cols, vals) = csr.row(i);
                    let xrow_i = x.row(i);
                    for (&j, &aval) in cols.iter().zip(vals) {
                        let j = j as usize;
                        let orow = other.row(j);
                        let wh = (kern.dot)(xrow_i, orow);
                        let r = aval / (wh + DELTA);
                        (kern.axpy)(r, orow, &mut nrow[..k]);
                    }
                }
            });
        }
        DataMatrix::Dense(m) => {
            pool.parallel_for(m.rows(), None, |rows| {
                for i in rows {
                    let nrow = unsafe { xs.row_mut(i) };
                    nrow[..k].fill(0.0);
                    let xrow_i = x.row(i);
                    for (j, &aval) in m.row(i).iter().enumerate() {
                        if aval == 0.0 {
                            continue;
                        }
                        let orow = other.row(j);
                        let wh = (kern.dot)(xrow_i, orow);
                        let r = aval / (wh + DELTA);
                        (kern.axpy)(r, orow, &mut nrow[..k]);
                    }
                }
            });
        }
    }
}

/// Apply step: `x ← x ⊙ num ⊘ (denom + δ (+ l1 + l2·x))` row-parallel.
pub(crate) fn kl_apply(pool: &ThreadPool, x: &mut Mat, num: &Mat, denom: &[f64], shrink: Shrink) {
    let k = x.cols();
    let n_rows = x.rows();
    let reg = !shrink.is_none();
    let Shrink { l1, l2 } = shrink;
    let xs = SharedRows::new(x);
    pool.parallel_for(n_rows, None, |rows| {
        for i in rows {
            let xrow = unsafe { xs.row_mut(i) };
            let nrow = num.row(i);
            for j in 0..k {
                let d = if reg {
                    denom[j] as f32 + DELTA + l1 + l2 * xrow[j]
                } else {
                    denom[j] as f32 + DELTA
                };
                xrow[j] *= nrow[j] / d;
            }
        }
    });
}

impl NmfEngine for MuKlEngine {
    fn name(&self) -> &'static str {
        "mu-kl-cpu"
    }

    fn step(&mut self) -> Result<()> {
        let EngineCtx { ds, pool, factors, timers, spec } = &mut self.ctx;
        let shrink = spec.shrink();
        // H half-step: A is consumed transposed (rows = documents).
        timers.time("h_mukl", || {
            kl_half_step(pool, &ds.at, &mut factors.h, &factors.w, &mut self.num, shrink)
        });
        // W half-step (never regularized — see the spec module docs).
        timers.time("w_mukl", || {
            kl_half_step(pool, &ds.a, &mut factors.w, &factors.h, &mut self.num, Shrink::NONE)
        });
        Ok(())
    }

    fn factors(&self) -> &Factors {
        &self.ctx.factors
    }

    fn timers(&self) -> &PhaseTimers {
        &self.ctx.timers
    }

    fn reset_timers(&mut self) {
        self.ctx.timers.reset();
    }

    fn dataset(&self) -> &Dataset {
        &self.ctx.ds
    }

    fn pool(&self) -> &ThreadPool {
        &self.ctx.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::load_dataset;

    #[test]
    fn kl_divergence_decreases() {
        let ds = Arc::new(load_dataset("tiny-sparse", 3).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = MuKlEngine::new(ds, pool, 4, 42);
        let d0 = e.kl_divergence();
        for _ in 0..15 {
            e.step().unwrap();
        }
        let d1 = e.kl_divergence();
        assert!(d1 < d0, "KL divergence {d0} -> {d1}");
    }

    #[test]
    fn factors_stay_nonnegative() {
        let ds = Arc::new(load_dataset("tiny", 5).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = MuKlEngine::new(ds, pool, 3, 7);
        for _ in 0..5 {
            e.step().unwrap();
        }
        assert!(e.factors().w.data().iter().all(|&x| x >= 0.0));
        assert!(e.factors().h.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn regularization_shrinks_h_mass() {
        let ds = Arc::new(load_dataset("tiny-sparse", 3).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let spec = EngineSpec {
            loss: Loss::Kl,
            solver: Solver::Mu,
            alpha: 0.5,
            l1_ratio: 0.5,
            ..Default::default()
        };
        let mut free = MuKlEngine::new(ds.clone(), pool.clone(), 4, 42);
        let mut reg = MuKlEngine::with_spec(ds, pool, 4, 42, spec);
        for _ in 0..10 {
            free.step().unwrap();
            reg.step().unwrap();
        }
        let mass = |m: &Mat| m.data().iter().map(|&x| x as f64).sum::<f64>();
        assert!(
            mass(&reg.factors().h) < mass(&free.factors().h),
            "regularized H mass {} vs free {}",
            mass(&reg.factors().h),
            mass(&free.factors().h)
        );
        // The KL objective still improves under the penalty.
        assert!(reg.kl_divergence().is_finite());
    }

    #[test]
    fn dense_and_sparse_paths_agree() {
        // Same matrix supplied dense and sparse must give identical steps.
        let sparse = load_dataset("tiny-sparse", 9).unwrap();
        let dense_a = match &sparse.a {
            DataMatrix::Sparse(csr) => csr.to_dense(),
            _ => unreachable!(),
        };
        let at = dense_a.transposed();
        let fro2 = dense_a.fro2();
        let dense = Dataset {
            profile: sparse.profile.clone(),
            a: DataMatrix::Dense(dense_a),
            at: DataMatrix::Dense(at),
            fro2,
        };
        let pool = Arc::new(ThreadPool::new(2));
        let mut es = MuKlEngine::new(Arc::new(sparse), pool.clone(), 4, 11);
        let mut ed = MuKlEngine::new(Arc::new(dense), pool, 4, 11);
        for _ in 0..3 {
            es.step().unwrap();
            ed.step().unwrap();
        }
        let dmax = es.factors().w.max_abs_diff(&ed.factors().w);
        assert!(dmax < 1e-4, "sparse/dense divergence {dmax}");
    }

    #[test]
    fn euclidean_error_also_improves_under_kl() {
        // KL optimizes a different objective, but on non-negative data
        // the Frobenius relative error should still drop from random.
        let ds = Arc::new(load_dataset("tiny", 13).unwrap());
        let pool = Arc::new(ThreadPool::new(2));
        let mut e = MuKlEngine::new(ds, pool, 4, 17);
        let e0 = e.rel_error();
        for _ in 0..20 {
            e.step().unwrap();
        }
        assert!(e.rel_error() < e0);
    }
}

//! Range-chunking helpers shared by the scheduling primitives.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Split `0..n` into `parts` contiguous ranges whose lengths differ by at
/// most one (the first `n % parts` ranges get the extra element). Empty
/// ranges are returned when `parts > n` so worker indices stay aligned.
pub fn split_even(n: usize, parts: usize) -> Vec<Range<usize>> {
    assert!(parts > 0);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// A dynamic chunk dispenser: workers repeatedly `take` the next chunk of
/// up to `grain` items until the range is exhausted. This is OpenMP
/// `schedule(dynamic, grain)`.
pub struct Chunks {
    next: AtomicUsize,
    n: usize,
    grain: usize,
}

impl Chunks {
    pub fn new(n: usize, grain: usize) -> Self {
        Chunks { next: AtomicUsize::new(0), n, grain: grain.max(1) }
    }

    #[inline]
    pub fn take(&self) -> Option<Range<usize>> {
        let start = self.next.fetch_add(self.grain, Ordering::Relaxed);
        if start >= self.n {
            return None;
        }
        Some(start..(start + self.grain).min(self.n))
    }

    /// Reset for reuse (only call when no worker is drawing from it).
    pub fn reset(&self) {
        self.next.store(0, Ordering::Relaxed);
    }
}

/// Pick a grain size that yields ~4 chunks per worker (dynamic-scheduling
/// sweet spot: enough slack to balance, not enough to thrash the counter).
pub fn auto_grain(n: usize, workers: usize) -> usize {
    (n / (workers * 4).max(1)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_even_covers_exactly() {
        for &(n, p) in &[(10, 3), (0, 4), (7, 7), (3, 8), (1000, 28)] {
            let parts = split_even(n, p);
            assert_eq!(parts.len(), p);
            let mut covered = 0;
            let mut expect_start = 0;
            for r in &parts {
                assert_eq!(r.start, expect_start);
                expect_start = r.end;
                covered += r.len();
            }
            assert_eq!(covered, n);
            let lens: Vec<usize> = parts.iter().map(|r| r.len()).collect();
            let min = lens.iter().min().unwrap();
            let max = lens.iter().max().unwrap();
            assert!(max - min <= 1);
        }
    }

    #[test]
    fn chunks_cover_without_overlap() {
        let c = Chunks::new(103, 10);
        let mut seen = vec![false; 103];
        while let Some(r) = c.take() {
            for i in r {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
        assert!(c.take().is_none());
        c.reset();
        assert_eq!(c.take(), Some(0..10));
    }

    #[test]
    fn auto_grain_reasonable() {
        assert_eq!(auto_grain(0, 8), 1);
        assert!(auto_grain(1000, 8) >= 1);
        assert!(auto_grain(1_000_000, 8) * 8 * 4 <= 1_000_000 + 8 * 4);
    }
}

//! Persistent fork/join thread pool.
//!
//! Design: `n` logical workers = the calling (leader) thread + `n-1`
//! spawned threads. [`ThreadPool::run`] publishes a borrowed closure to
//! all workers, participates as worker 0, and returns only after every
//! worker finished — which is what makes handing out a *non-`'static`*
//! closure sound (the stack frame that owns the closure and the data it
//! borrows strictly outlives every use).
//!
//! Dispatch latency is a single mutex/condvar round-trip (~1–5 µs), cheap
//! enough for the per-column granularity of the PL-NMF phase-2 loop; the
//! engines additionally batch whole tiles inside a single `run` using
//! [`super::Barrier`] for column-step synchronization.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::chunks::{auto_grain, split_even, Chunks};
use crate::kernels::Kernels;

/// Type-erased borrowed job. The raw pointer is only dereferenced between
/// publication and completion of a `run`, during which the referent is
/// guaranteed alive (see module docs).
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared-call safe) and the pool's join
// protocol guarantees it outlives all uses.
unsafe impl Send for JobPtr {}

struct JobSlot {
    epoch: u64,
    job: Option<JobPtr>,
    remaining: usize,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<JobSlot>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// A pool of persistent worker threads with fork/join semantics.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    n_threads: usize,
    in_run: AtomicBool,
    /// The SIMD microkernel table every engine built on this pool
    /// dispatches through — selected once at construction (env override
    /// + CPU detection, see [`Kernels::select`]).
    kernels: &'static Kernels,
}

impl ThreadPool {
    /// Create a pool with `n_threads` logical workers (including the
    /// caller). `n_threads == 1` degenerates to serial execution with no
    /// spawned threads — used for the sequential baselines.
    pub fn new(n_threads: usize) -> Self {
        Self::with_kernels(n_threads, Kernels::select())
    }

    /// [`Self::new`] with an explicit kernel table — parity tests and
    /// the kernels bench pin a backend without touching the env.
    pub fn with_kernels(n_threads: usize, kernels: &'static Kernels) -> Self {
        let n_threads = n_threads.max(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new(JobSlot { epoch: 0, job: None, remaining: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..n_threads)
            .map(|id| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("plnmf-worker-{id}"))
                    .spawn(move || worker_loop(id, shared))
                    .expect("failed to spawn worker thread")
            })
            .collect();
        ThreadPool { shared, handles, n_threads, in_run: AtomicBool::new(false), kernels }
    }

    /// Pool sized to the machine (or `PLNMF_THREADS` when set).
    pub fn with_default_threads() -> Self {
        Self::new(default_threads())
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// The microkernel dispatch table this pool's engines run on.
    #[inline]
    pub fn kernels(&self) -> &'static Kernels {
        self.kernels
    }

    /// Execute `f(worker_id)` on every worker (ids `0..n_threads`), the
    /// caller acting as worker 0. Returns when all workers are done.
    ///
    /// Not reentrant: calling `run` from inside a job panics.
    pub fn run(&self, f: &(dyn Fn(usize) + Sync)) {
        if self.n_threads == 1 {
            f(0);
            return;
        }
        assert!(
            !self.in_run.swap(true, Ordering::Acquire),
            "ThreadPool::run is not reentrant"
        );
        // SAFETY: we erase the borrow lifetime to 'static; the join
        // protocol below guarantees no worker touches the pointer after
        // `run` returns, so the pointee strictly outlives every use.
        let raw: *const (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute(f as *const (dyn Fn(usize) + Sync + '_))
        };
        let ptr = JobPtr(raw);
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.job = Some(ptr);
            slot.epoch += 1;
            slot.remaining = self.n_threads - 1;
            self.shared.work_cv.notify_all();
        }
        f(0);
        let mut slot = self.shared.slot.lock().unwrap();
        while slot.remaining > 0 {
            slot = self.shared.done_cv.wait(slot).unwrap();
        }
        slot.job = None;
        self.in_run.store(false, Ordering::Release);
    }

    /// Dynamically scheduled parallel loop over `0..n`.
    /// `f` receives disjoint sub-ranges; the grain defaults to ~4 chunks
    /// per worker (see [`auto_grain`]).
    pub fn parallel_for(&self, n: usize, grain: Option<usize>, f: impl Fn(Range<usize>) + Sync) {
        if n == 0 {
            return;
        }
        let grain = grain.unwrap_or_else(|| auto_grain(n, self.n_threads));
        if self.n_threads == 1 || n <= grain {
            f(0..n);
            return;
        }
        let chunks = Chunks::new(n, grain);
        self.run(&|_wid| {
            while let Some(r) = chunks.take() {
                f(r);
            }
        });
    }

    /// Statically scheduled parallel loop: worker `w` gets the `w`-th of
    /// `n_threads` contiguous even ranges (empty ranges skipped).
    pub fn parallel_for_static(&self, n: usize, f: impl Fn(usize, Range<usize>) + Sync) {
        if n == 0 {
            return;
        }
        if self.n_threads == 1 {
            f(0, 0..n);
            return;
        }
        let parts = split_even(n, self.n_threads);
        self.run(&|wid| {
            let r = parts[wid].clone();
            if !r.is_empty() {
                f(wid, r);
            }
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(id: usize, shared: Arc<Shared>) {
    let mut last_epoch = 0u64;
    loop {
        let job = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.shutdown {
                    return;
                }
                if slot.epoch != last_epoch {
                    last_epoch = slot.epoch;
                    break slot.job.expect("job published with epoch bump");
                }
                slot = shared.work_cv.wait(slot).unwrap();
            }
        };
        // SAFETY: valid for the duration of the run (leader joins before
        // dropping the closure).
        let f = unsafe { &*job.0 };
        f(id);
        let mut slot = shared.slot.lock().unwrap();
        slot.remaining -= 1;
        if slot.remaining == 0 {
            shared.done_cv.notify_one();
        }
    }
}

/// `PLNMF_THREADS` env override, else `available_parallelism`.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PLNMF_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn run_executes_on_all_workers() {
        for n in [1, 2, 4, 7] {
            let pool = ThreadPool::new(n);
            let mut hit = vec![false; n];
            let hits: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
            pool.run(&|wid| hits[wid].store(true, Ordering::Relaxed));
            for (i, h) in hits.iter().enumerate() {
                hit[i] = h.load(Ordering::Relaxed);
            }
            assert!(hit.iter().all(|&x| x), "n={n}: {hit:?}");
        }
    }

    #[test]
    fn parallel_for_sums_correctly() {
        let pool = ThreadPool::new(4);
        let total = AtomicUsize::new(0);
        pool.parallel_for(10_001, None, |r| {
            let s: usize = r.sum();
            total.fetch_add(s, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 10_001 * 10_000 / 2);
    }

    #[test]
    fn parallel_for_static_partitions() {
        let pool = ThreadPool::new(3);
        let marks: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for_static(100, |_wid, r| {
            for i in r {
                marks[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(marks.iter().all(|m| m.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn borrowed_mutation_through_disjoint_ranges() {
        // The canonical use: workers write disjoint slices of a borrowed
        // buffer through raw parts.
        let pool = ThreadPool::new(4);
        let mut data = vec![0usize; 1000];
        let ptr = data.as_mut_ptr() as usize;
        pool.parallel_for(1000, Some(100), |r| {
            let slice =
                unsafe { std::slice::from_raw_parts_mut((ptr as *mut usize).add(r.start), r.len()) };
            for (off, x) in slice.iter_mut().enumerate() {
                *x = r.start + off;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn many_small_runs_complete() {
        // Latency smoke test: thousands of fork/joins (the phase-2 shape).
        let pool = ThreadPool::new(4);
        let c = AtomicUsize::new(0);
        for _ in 0..2000 {
            pool.run(&|_| {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(c.load(Ordering::Relaxed), 2000 * 4);
    }

    #[test]
    fn empty_and_tiny_loops() {
        let pool = ThreadPool::new(4);
        pool.parallel_for(0, None, |_| panic!("must not be called"));
        let c = AtomicUsize::new(0);
        pool.parallel_for(1, None, |r| {
            c.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(c.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(8);
        drop(pool); // must not hang
    }
}

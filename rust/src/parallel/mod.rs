//! Shared-memory parallel runtime (the paper's OpenMP substrate, rebuilt).
//!
//! The paper parallelizes with `#pragma omp parallel for` + MKL threading.
//! Offline we have neither OpenMP nor rayon, so this module provides the
//! equivalent primitives used by every engine and by the coordinator:
//!
//! * [`ThreadPool`] — persistent workers with low-latency fork/join
//!   dispatch (`run`), so per-column phase-2 loops don't pay thread-spawn
//!   costs (the W update runs K ≤ 240 column steps per iteration).
//! * [`pool::ThreadPool::parallel_for`] — dynamically chunked parallel
//!   loop (OpenMP `schedule(dynamic)`).
//! * [`pool::ThreadPool::parallel_for_static`] — contiguous static split
//!   (OpenMP `schedule(static)`), used where locality of fixed shards
//!   matters (the coordinator pins row shards to workers).
//! * [`Barrier`] — reusable sense-reversing barrier for in-`run` phase
//!   synchronization (the CPU analogue of `__syncthreads` +
//!   `cudaDeviceSynchronize` in Algorithms 3–5).
//! * [`reduce`] — per-worker partials + leader combine (the CPU analogue
//!   of the paper's warp-shuffle / `atomicAdd` reduction hierarchy).

pub mod pool;
pub mod chunks;
pub mod barrier;
pub mod reduce;

pub use barrier::Barrier;
pub use chunks::{split_even, Chunks};
pub use pool::ThreadPool;
pub use reduce::{reduce, reduce_vec};

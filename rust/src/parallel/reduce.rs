//! Parallel map-reduce over index ranges.
//!
//! The CPU analogue of the paper's GPU reduction hierarchy (Alg. 4: warp
//! shuffle → shared memory → atomicAdd): each worker folds its chunk into
//! a private accumulator (register-resident), partials land in per-worker
//! slots (one cache line apart), and the leader combines the ≤ n_threads
//! partials. Deterministic for a fixed thread count when used with static
//! scheduling — which the engines rely on so convergence trajectories are
//! reproducible run-to-run.

use std::cell::UnsafeCell;
use std::ops::Range;

use super::pool::ThreadPool;

/// Cache-line-padded slot to avoid false sharing between partials.
#[repr(align(64))]
struct Slot<T>(UnsafeCell<Option<T>>);

// SAFETY: each slot is written by exactly one worker during `run`.
unsafe impl<T: Send> Sync for Slot<T> {}

/// Statically partitioned parallel reduce: `map` folds each contiguous
/// range to a partial, `combine` merges partials in worker order
/// (deterministic).
pub fn reduce<T, M, C>(pool: &ThreadPool, n: usize, map: M, combine: C) -> Option<T>
where
    T: Send,
    M: Fn(Range<usize>) -> T + Sync,
    C: Fn(T, T) -> T,
{
    if n == 0 {
        return None;
    }
    let parts = super::chunks::split_even(n, pool.n_threads());
    let slots: Vec<Slot<T>> = (0..pool.n_threads()).map(|_| Slot(UnsafeCell::new(None))).collect();
    pool.run(&|wid| {
        let r = parts[wid].clone();
        if !r.is_empty() {
            // SAFETY: slot `wid` is exclusively ours during this run.
            unsafe { *slots[wid].0.get() = Some(map(r)) };
        }
    });
    let mut acc: Option<T> = None;
    for s in slots {
        if let Some(part) = s.0.into_inner() {
            acc = Some(match acc {
                None => part,
                Some(a) => combine(a, part),
            });
        }
    }
    acc
}

/// Elementwise vector reduce: workers produce partial vectors of length
/// `len` over their range, then the leader sums them. Used for the
/// per-column sums and Gram-matrix partials.
pub fn reduce_vec<M>(pool: &ThreadPool, n: usize, len: usize, map: M) -> Vec<f64>
where
    M: Fn(Range<usize>, &mut [f64]) + Sync,
{
    reduce(
        pool,
        n,
        |r| {
            let mut part = vec![0.0f64; len];
            map(r, &mut part);
            part
        },
        |mut a, b| {
            for (x, y) in a.iter_mut().zip(&b) {
                *x += *y;
            }
            a
        },
    )
    .unwrap_or_else(|| vec![0.0; len])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_matches_serial() {
        let pool = ThreadPool::new(4);
        let data: Vec<f64> = (0..10_000).map(|i| (i as f64).sin()).collect();
        let serial: f64 = data.iter().sum();
        let par = reduce(&pool, data.len(), |r| r.map(|i| data[i]).sum::<f64>(), |a, b| a + b)
            .unwrap();
        assert!((serial - par).abs() < 1e-9 * serial.abs().max(1.0));
    }

    #[test]
    fn deterministic_across_repeats() {
        let pool = ThreadPool::new(7);
        let data: Vec<f64> = (0..5000).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let r1 = reduce(&pool, data.len(), |r| r.map(|i| data[i]).sum::<f64>(), |a, b| a + b);
        let r2 = reduce(&pool, data.len(), |r| r.map(|i| data[i]).sum::<f64>(), |a, b| a + b);
        assert_eq!(r1, r2, "static reduce must be bitwise deterministic");
    }

    #[test]
    fn empty_returns_none() {
        let pool = ThreadPool::new(2);
        assert!(reduce(&pool, 0, |_| 1.0, |a, b| a + b).is_none());
    }

    #[test]
    fn reduce_vec_sums_columns() {
        let pool = ThreadPool::new(3);
        // 100 rows x 4 cols of ones -> column sums all 100.
        let out = reduce_vec(&pool, 100, 4, |r, part| {
            for _i in r {
                for p in part.iter_mut() {
                    *p += 1.0;
                }
            }
        });
        assert_eq!(out, vec![100.0; 4]);
    }

    #[test]
    fn min_max_reduce() {
        let pool = ThreadPool::new(4);
        let data: Vec<f64> = (0..1000).map(|i| ((i * 7919) % 1000) as f64).collect();
        let mx = reduce(
            &pool,
            data.len(),
            |r| r.map(|i| data[i]).fold(f64::MIN, f64::max),
            f64::max,
        )
        .unwrap();
        assert_eq!(mx, 999.0);
    }
}

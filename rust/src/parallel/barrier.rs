//! Reusable sense-reversing barrier.
//!
//! Used *inside* a [`super::ThreadPool::run`] job to synchronize column
//! steps of the PL-NMF phase-2 loop without paying a full fork/join per
//! column: workers compute their V-shard of column `t`, hit the barrier,
//! worker 0 folds the partial sums-of-squares and publishes the norm, all
//! hit the barrier again, proceed to column `t+1`. This mirrors the
//! paper's GPU structure (Alg. 3 lines 14–18: kernel launch + device
//! synchronize per column) in shared memory.
//!
//! Spin-then-yield waiting: phase-2 column steps are ~10–100 µs, so a
//! short spin almost always succeeds; we yield after `SPIN_LIMIT` to stay
//! polite on oversubscribed CI machines.

use std::sync::atomic::{AtomicUsize, Ordering};

const SPIN_LIMIT: u32 = 4096;

/// A reusable barrier for exactly `n` participants.
pub struct Barrier {
    n: usize,
    count: AtomicUsize,
    sense: AtomicUsize,
}

impl Barrier {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        Barrier { n, count: AtomicUsize::new(0), sense: AtomicUsize::new(0) }
    }

    /// Block until all `n` participants arrive. Returns `true` on exactly
    /// one participant (the last to arrive), like `std::sync::Barrier`.
    pub fn wait(&self) -> bool {
        if self.n == 1 {
            return true;
        }
        let sense = self.sense.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) == self.n - 1 {
            // Last arrival: reset and flip sense to release the others.
            self.count.store(0, Ordering::Relaxed);
            self.sense.store(sense.wrapping_add(1), Ordering::Release);
            true
        } else {
            let mut spins = 0u32;
            while self.sense.load(Ordering::Acquire) == sense {
                spins += 1;
                if spins < SPIN_LIMIT {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::ThreadPool;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn single_participant_trivially_passes() {
        let b = Barrier::new(1);
        for _ in 0..10 {
            assert!(b.wait());
        }
    }

    #[test]
    fn phases_are_ordered() {
        // Every worker increments in phase 1; after the barrier, all must
        // observe the full phase-1 total.
        let n = 4;
        let pool = ThreadPool::new(n);
        let b = Barrier::new(n);
        let counter = AtomicUsize::new(0);
        let failures = AtomicUsize::new(0);
        pool.run(&|_wid| {
            for round in 1..=50usize {
                counter.fetch_add(1, Ordering::Relaxed);
                b.wait();
                if counter.load(Ordering::Relaxed) != round * n {
                    failures.fetch_add(1, Ordering::Relaxed);
                }
                b.wait();
            }
        });
        assert_eq!(failures.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn exactly_one_leader_per_round() {
        let n = 8;
        let pool = ThreadPool::new(n);
        let b = Barrier::new(n);
        let leaders = AtomicUsize::new(0);
        pool.run(&|_| {
            for _ in 0..100 {
                if b.wait() {
                    leaders.fetch_add(1, Ordering::Relaxed);
                }
            }
        });
        assert_eq!(leaders.load(Ordering::Relaxed), 100);
    }
}

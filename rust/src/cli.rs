//! Command-line parsing (clap is unavailable offline).
//!
//! Grammar:  `plnmf <subcommand> [--key value]... [--flag]... [positional]...`
//! Options may also be written `--key=value`. `--config path.json` loads a
//! [`crate::config::RunConfig`] file first; later `--key value` pairs
//! override individual fields.

use std::collections::BTreeMap;
use std::net::SocketAddr;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::RunConfig;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if stripped.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn opt_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.opt(key) {
            None => Ok(None),
            Some(v) => match v.parse() {
                Ok(n) => Ok(Some(n)),
                Err(_) => bail!("--{key} expects an integer, got '{v}'"),
            },
        }
    }

    /// Build a [`RunConfig`]: defaults ← `--config file` ← individual
    /// `--key value` overrides.
    pub fn to_run_config(&self) -> Result<RunConfig> {
        let mut cfg = match self.opt("config") {
            Some(path) => RunConfig::from_file(path)?,
            None => RunConfig::default(),
        };
        for (k, v) in &self.options {
            // Skip keys that aren't config fields (commands own those).
            if k == "config" || NON_CONFIG_KEYS.contains(&k.as_str()) {
                continue;
            }
            if !RunConfig::is_config_key(k) {
                bail!("unknown option --{k}");
            }
            // A real option with a bad value surfaces its own message
            // (e.g. "sweeps must be >= 1"), not "unknown option".
            cfg.set_str(k, v).with_context(|| format!("--{k}"))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Options consumed by subcommands rather than RunConfig.
const NON_CONFIG_KEYS: &[&str] = &[
    "out", "out-dir", "reps", "warmup", "ks", "tiles", "datasets", "engines", "scale",
    "target-error", "format", "top", "input", "attach",
];

/// The flag surface shared by every training-flavored subcommand.
///
/// `run`, `train-dist`, and the spec overrides of `transform` /
/// `recommend` all parse through this one helper, so
/// `--k/--engine/--loss/--alpha/--l1_ratio/--init/--sweeps` (and
/// `--grid`, which rides the same [`RunConfig`] surface) behave — and
/// fail, with identical messages — the same way under every
/// subcommand. Precedence is [`Args::to_run_config`]'s: defaults ←
/// `--config file` ← individual `--key value` overrides.
#[derive(Debug, Clone)]
pub struct TrainArgs {
    /// The validated run configuration (engine spec included).
    pub cfg: RunConfig,
    /// `--attach host:port,...`: pre-started `serve --train_worker`
    /// daemons for `train-dist` (empty = spawn workers).
    pub attach: Vec<SocketAddr>,
}

impl TrainArgs {
    pub fn from_args(args: &Args) -> Result<TrainArgs> {
        let cfg = args.to_run_config()?;
        let attach = match args.opt("attach") {
            Some(list) => parse_attach(list)?,
            None => Vec::new(),
        };
        Ok(TrainArgs { cfg, attach })
    }
}

/// Parse a `--attach host:port,host:port,...` list into socket
/// addresses; every entry must parse (a typoed address silently
/// dropping to a spawned local worker would mask a fleet misconfig).
pub fn parse_attach(list: &str) -> Result<Vec<SocketAddr>> {
    list.split(',')
        .map(|s| {
            let s = s.trim();
            s.parse::<SocketAddr>()
                .map_err(|e| anyhow!("bad --attach address '{s}': {e}"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("run --dataset 20news --k 160 --fast");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.opt("dataset"), Some("20news"));
        assert_eq!(a.opt("k"), Some("160"));
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --k=240 --engine=plnmf");
        assert_eq!(a.opt("k"), Some("240"));
        assert_eq!(a.opt("engine"), Some("plnmf"));
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse("model 80 160 240");
        assert_eq!(a.subcommand.as_deref(), Some("model"));
        assert_eq!(a.positional, vec!["80", "160", "240"]);
    }

    #[test]
    fn run_config_overrides() {
        let a = parse("run --k 240 --engine mu --seed 7");
        let cfg = a.to_run_config().unwrap();
        assert_eq!(cfg.k, 240);
        assert_eq!(cfg.engine, crate::config::EngineKind::Mu);
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn unknown_option_rejected() {
        let a = parse("run --bogus 3");
        let err = format!("{:#}", a.to_run_config().unwrap_err());
        assert!(err.contains("unknown option --bogus"), "{err}");
    }

    #[test]
    fn bad_value_for_real_option_shows_its_own_error() {
        // Regression: a validation failure on a known flag must surface
        // the validation message, not masquerade as an unknown option.
        let a = parse("run --sweeps 0");
        let err = format!("{:#}", a.to_run_config().unwrap_err());
        assert!(err.contains("sweeps must be >= 1"), "{err}");
        assert!(!err.contains("unknown option"), "{err}");
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("run --verbose");
        assert!(a.has_flag("verbose"));
    }

    fn write_tmp_config(name: &str, body: &str) -> std::path::PathBuf {
        let path =
            std::env::temp_dir().join(format!("plnmf-cli-{}-{name}.json", std::process::id()));
        std::fs::write(&path, body).unwrap();
        path
    }

    #[test]
    fn config_file_loads_fields() {
        let path = write_tmp_config(
            "load",
            r#"{"dataset": "tiny", "k": 8, "engine": "mu", "sweeps": 5}"#,
        );
        let a = parse(&format!("run --config {}", path.display()));
        let cfg = a.to_run_config().unwrap();
        assert_eq!(cfg.dataset, "tiny");
        assert_eq!(cfg.k, 8);
        assert_eq!(cfg.engine, crate::config::EngineKind::Mu);
        assert_eq!(cfg.sweeps, 5);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn cli_overrides_beat_config_file() {
        // Precedence: defaults ← --config file ← individual --key value.
        let path = write_tmp_config(
            "precedence",
            r#"{"dataset": "tiny", "k": 8, "seed": 3, "batch": 16}"#,
        );
        let a = parse(&format!("run --config {} --k 12 --batch=128", path.display()));
        let cfg = a.to_run_config().unwrap();
        assert_eq!(cfg.k, 12, "CLI --k overrides the file");
        assert_eq!(cfg.batch, 128, "CLI --batch=v overrides the file");
        assert_eq!(cfg.dataset, "tiny", "file beats the default");
        assert_eq!(cfg.seed, 3, "file beats the default");
        assert_eq!(cfg.max_iters, RunConfig::default().max_iters, "defaults fill the rest");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn transform_subcommand_args() {
        let a = parse("transform --model m.json --dataset tiny-sparse --sweeps 40 --out h.csv");
        assert_eq!(a.subcommand.as_deref(), Some("transform"));
        let cfg = a.to_run_config().unwrap();
        assert_eq!(cfg.model_path.as_deref(), Some("m.json"));
        assert_eq!(cfg.sweeps, 40);
        assert_eq!(cfg.dataset, "tiny-sparse");
        // `out` is a subcommand option, not a config field.
        assert_eq!(a.opt("out"), Some("h.csv"));
    }

    #[test]
    fn spec_flags_reach_the_config() {
        use crate::nmf::spec::{Init, Loss};
        let a = parse("run --engine mu --loss kl --alpha 0.1 --l1_ratio 0.5 --init nndsvda");
        let cfg = a.to_run_config().unwrap();
        assert_eq!(cfg.loss, Some(Loss::Kl));
        assert_eq!(cfg.init, Init::Nndsvda);
        assert!((cfg.alpha - 0.1).abs() < 1e-12);
        assert!((cfg.l1_ratio - 0.5).abs() < 1e-12);
        assert_eq!(cfg.effective_engine(), crate::config::EngineKind::MuKl);
        // An invalid combination fails at to_run_config (validate).
        let a = parse("run --engine plnmf --loss kl");
        assert!(a.to_run_config().is_err());
    }

    #[test]
    fn spec_flags_fail_identically_across_subcommands() {
        // The consolidation satellite's contract: one shared parser
        // means one error text, whichever subcommand the flag rode in
        // on.
        for bad in ["--sweeps 0", "--engine warp", "--grid 0x2", "--loss poisson"] {
            let mut msgs: Vec<String> = Vec::new();
            for sub in ["run", "train-dist", "transform"] {
                let a = parse(&format!("{sub} {bad}"));
                msgs.push(format!("{:#}", TrainArgs::from_args(&a).unwrap_err()));
            }
            assert_eq!(msgs[0], msgs[1], "{bad}: run vs train-dist");
            assert_eq!(msgs[0], msgs[2], "{bad}: run vs transform");
        }
    }

    #[test]
    fn train_args_carry_the_shared_surface_plus_attach_and_grid() {
        let a = parse(
            "train-dist --dataset tiny --k 4 --engine mu --alpha 0.1 --l1_ratio 0.5 \
             --grid 2x2 --attach 127.0.0.1:7001,127.0.0.1:7002",
        );
        let t = TrainArgs::from_args(&a).unwrap();
        assert_eq!(t.cfg.dataset, "tiny");
        assert_eq!(t.cfg.k, 4);
        assert_eq!(t.cfg.engine, crate::config::EngineKind::Mu);
        assert!((t.cfg.alpha - 0.1).abs() < 1e-12);
        assert_eq!(t.cfg.grid, Some((2, 2)));
        assert_eq!(t.attach.len(), 2);
        assert_eq!(t.attach[1].port(), 7002);
        // No --attach: spawn mode.
        let t = TrainArgs::from_args(&parse("run --k 4")).unwrap();
        assert!(t.attach.is_empty());
    }

    #[test]
    fn grid_precedence_follows_the_config_chain() {
        // --grid obeys the same defaults ← file ← CLI chain as every
        // other spec flag, because it IS one of them.
        let path = write_tmp_config("grid", r#"{"dataset": "tiny", "grid": "1x4"}"#);
        let a = parse(&format!("train-dist --config {}", path.display()));
        assert_eq!(a.to_run_config().unwrap().grid, Some((1, 4)), "file beats default");
        let a = parse(&format!("train-dist --config {} --grid 2x2", path.display()));
        assert_eq!(a.to_run_config().unwrap().grid, Some((2, 2)), "CLI beats file");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn attach_list_parses_or_rejects_loudly() {
        let addrs = parse_attach("127.0.0.1:7001, 127.0.0.1:7002").unwrap();
        assert_eq!(addrs.len(), 2);
        assert_eq!(addrs[0].port(), 7001);
        assert_eq!(addrs[1].port(), 7002);
        assert_eq!(parse_attach("127.0.0.1:9000").unwrap().len(), 1);
        for bad in ["localhost", "127.0.0.1", "127.0.0.1:7001,,", "host:port"] {
            let err = format!("{:#}", parse_attach(bad).unwrap_err());
            assert!(err.contains("--attach"), "{bad}: {err}");
        }
    }

    #[test]
    fn train_dist_attach_is_a_subcommand_option() {
        // `--attach` belongs to the train-dist subcommand, not RunConfig:
        // it must pass through to_run_config without an "unknown option"
        // error and stay readable via opt().
        let a = parse("train-dist --dataset tiny --k 4 --attach 127.0.0.1:7001,127.0.0.1:7002");
        let cfg = a.to_run_config().unwrap();
        assert_eq!(cfg.dataset, "tiny");
        assert_eq!(a.opt("attach"), Some("127.0.0.1:7001,127.0.0.1:7002"));
    }

    #[test]
    fn recommend_subcommand_args() {
        let a = parse("recommend --model m.json --input q.mtx --top 5 --exclude-seen --batch 32");
        assert_eq!(a.subcommand.as_deref(), Some("recommend"));
        let cfg = a.to_run_config().unwrap();
        assert_eq!(cfg.model_path.as_deref(), Some("m.json"));
        assert_eq!(cfg.batch, 32);
        assert_eq!(a.opt("input"), Some("q.mtx"));
        assert_eq!(a.opt_usize("top").unwrap(), Some(5));
        assert!(a.has_flag("exclude-seen"));
    }
}

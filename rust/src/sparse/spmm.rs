//! Sparse × dense products: `C op= alpha · A · B` with CSR `A`.
//!
//! This is the paper's `mkl_dcsrmm`/`cusparseDcsrmm` role: `P = A·Hᵀ`
//! (V×D · D×K) and, via the pre-transposed `Aᵀ`, `R = Aᵀ·W`. The kernel
//! is row-parallel (each output row owned by one task) with a contiguous
//! inner loop over the K dimension dispatched through the SIMD kernel
//! table's `axpy` (bit-identical across backends); work is
//! dynamically chunked because bag-of-words rows have wildly skewed nnz
//! (Zipf), making static splits unbalanced.

use crate::kernels::Kernels;
use crate::linalg::dense::{Mat, ViewMut};
use crate::linalg::GemmOp;
use crate::parallel::ThreadPool;
use crate::Elem;

use super::csr::Csr;

/// `c op= alpha * a · b` where `a` is CSR (m×k), `b` dense (k×n), `c` m×n.
pub fn spmm(pool: &ThreadPool, alpha: Elem, a: &Csr, b: &Mat, op: GemmOp, c: &mut ViewMut<'_>) {
    assert_eq!(a.cols(), b.rows(), "spmm inner dims");
    assert_eq!(c.rows, a.rows(), "spmm c rows");
    assert_eq!(c.cols, b.cols(), "spmm c cols");
    let craw = c.raw();
    let kern = pool.kernels();
    // Grain: aim for ~1k nnz per chunk, expressed in rows.
    let avg_row = (a.nnz() / a.rows().max(1)).max(1);
    let grain = (1024 / avg_row).clamp(1, 512);
    pool.parallel_for(a.rows(), Some(grain), |rows| {
        for i in rows {
            // SAFETY: row i is exclusive to this task.
            let crow = unsafe { craw.row_mut(i) };
            if op == GemmOp::Assign {
                crow.fill(0.0);
            }
            let (cols, vals) = a.row(i);
            for (&d, &v) in cols.iter().zip(vals) {
                (kern.axpy)(alpha * v, b.row(d as usize), crow);
            }
        }
    });
}

/// Serial variant for per-shard use inside the coordinator.
pub fn spmm_serial(alpha: Elem, a: &Csr, b: &Mat, op: GemmOp, c: &mut ViewMut<'_>) {
    assert_eq!(a.cols(), b.rows());
    assert_eq!((c.rows, c.cols), (a.rows(), b.cols()));
    let kern = Kernels::select();
    for i in 0..a.rows() {
        let crow = c.row_mut(i);
        if op == GemmOp::Assign {
            crow.fill(0.0);
        }
        let (cols, vals) = a.row(i);
        for (&d, &v) in cols.iter().zip(vals) {
            (kern.axpy)(alpha * v, b.row(d as usize), crow);
        }
    }
}

/// `c = alpha * a[rows, :] · b` — the row-window variant the serving
/// layer's micro-batcher uses: the output panel has `rows.len()` rows and
/// no copy of the CSR window is made. Always assigns (serving panels are
/// computed fresh per micro-batch).
pub fn spmm_range(
    pool: &ThreadPool,
    alpha: Elem,
    a: &Csr,
    rows: std::ops::Range<usize>,
    b: &Mat,
    c: &mut ViewMut<'_>,
) {
    assert!(rows.end <= a.rows(), "spmm_range window out of bounds");
    assert_eq!(a.cols(), b.rows(), "spmm_range inner dims");
    assert_eq!(c.rows, rows.len(), "spmm_range c rows");
    assert_eq!(c.cols, b.cols(), "spmm_range c cols");
    let craw = c.raw();
    let kern = pool.kernels();
    let r0 = rows.start;
    let n = rows.len();
    let avg_row = (a.nnz() / a.rows().max(1)).max(1);
    let grain = (1024 / avg_row).clamp(1, 512);
    pool.parallel_for(n, Some(grain), |rr| {
        for i in rr {
            // SAFETY: output row i is exclusive to this task.
            let crow = unsafe { craw.row_mut(i) };
            crow.fill(0.0);
            let (cols, vals) = a.row(r0 + i);
            for (&d, &v) in cols.iter().zip(vals) {
                (kern.axpy)(alpha * v, b.row(d as usize), crow);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::gemm_naive;
    use crate::util::rng::Pcg32;

    fn random_csr(rows: usize, cols: usize, nnz: usize, seed: u64) -> Csr {
        let mut rng = Pcg32::seeded(seed);
        let trips: Vec<(usize, usize, Elem)> = (0..nnz)
            .map(|_| {
                (rng.below(rows as u32) as usize, rng.below(cols as u32) as usize, rng.next_f32())
            })
            .collect();
        Csr::from_triplets(rows, cols, trips)
    }

    #[test]
    fn matches_dense_gemm() {
        let pool = ThreadPool::new(4);
        let mut rng = Pcg32::seeded(10);
        for &(m, k, n, nnz) in &[(20, 30, 8, 100), (100, 50, 16, 800), (5, 5, 1, 3)] {
            let a = random_csr(m, k, nnz, 11);
            let b = Mat::random(k, n, &mut rng, -1.0, 1.0);
            let mut c1 = Mat::random(m, n, &mut rng, -1.0, 1.0);
            let mut c2 = c1.clone();
            spmm(&pool, 2.0, &a, &b, GemmOp::Add, &mut c1.view_mut());
            gemm_naive(2.0, a.to_dense().view(), b.view(), GemmOp::Add, &mut c2.view_mut());
            assert!(c1.max_abs_diff(&c2) < 1e-3);
        }
    }

    #[test]
    fn assign_overwrites_stale_contents() {
        let pool = ThreadPool::new(2);
        let a = random_csr(10, 10, 30, 12);
        let mut rng = Pcg32::seeded(13);
        let b = Mat::random(10, 4, &mut rng, 0.0, 1.0);
        let mut c = Mat::from_fn(10, 4, |_, _| 999.0);
        spmm(&pool, 1.0, &a, &b, GemmOp::Assign, &mut c.view_mut());
        let mut expect = Mat::zeros(10, 4);
        gemm_naive(1.0, a.to_dense().view(), b.view(), GemmOp::Assign, &mut expect.view_mut());
        assert!(c.max_abs_diff(&expect) < 1e-4);
    }

    #[test]
    fn serial_equals_parallel() {
        let pool = ThreadPool::new(4);
        let a = random_csr(57, 43, 300, 14);
        let mut rng = Pcg32::seeded(15);
        let b = Mat::random(43, 7, &mut rng, -1.0, 1.0);
        let mut c1 = Mat::zeros(57, 7);
        let mut c2 = Mat::zeros(57, 7);
        spmm(&pool, 1.0, &a, &b, GemmOp::Assign, &mut c1.view_mut());
        spmm_serial(1.0, &a, &b, GemmOp::Assign, &mut c2.view_mut());
        assert_eq!(c1, c2);
    }

    #[test]
    fn range_variant_matches_full_product() {
        let pool = ThreadPool::new(3);
        let a = random_csr(60, 30, 400, 18);
        let mut rng = Pcg32::seeded(19);
        let b = Mat::random(30, 5, &mut rng, 0.0, 1.0);
        let mut full = Mat::zeros(60, 5);
        spmm(&pool, 1.0, &a, &b, GemmOp::Assign, &mut full.view_mut());
        for (r0, r1) in [(0usize, 60usize), (10, 25), (59, 60), (7, 7)] {
            let mut win = Mat::from_fn(r1 - r0, 5, |_, _| 777.0);
            spmm_range(&pool, 1.0, &a, r0..r1, &b, &mut win.view_mut());
            for i in 0..(r1 - r0) {
                assert_eq!(win.row(i), full.row(r0 + i), "window ({r0},{r1}) row {i}");
            }
        }
    }

    #[test]
    fn transpose_product_r_equals_atw() {
        // R = Aᵀ·W via spmm on the pre-transposed CSR.
        let pool = ThreadPool::new(3);
        let a = random_csr(40, 25, 200, 16);
        let at = a.transposed();
        let mut rng = Pcg32::seeded(17);
        let w = Mat::random(40, 6, &mut rng, 0.0, 1.0);
        let mut r = Mat::zeros(25, 6);
        spmm(&pool, 1.0, &at, &w, GemmOp::Assign, &mut r.view_mut());
        let mut expect = Mat::zeros(25, 6);
        gemm_naive(
            1.0,
            a.to_dense().transposed().view(),
            w.view(),
            GemmOp::Assign,
            &mut expect.view_mut(),
        );
        assert!(r.max_abs_diff(&expect) < 1e-3);
    }
}

//! Matrix Market I/O (`.mtx`) — coordinate format for sparse, array
//! format for dense.
//!
//! Lets users run the pipeline on *real* datasets (the paper's corpora are
//! distributed as sparse matrices convertible to MatrixMarket) instead of
//! the synthetic generators; the examples accept `--matrix file.mtx`.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::linalg::Mat;
use crate::Elem;

use super::csr::Csr;

/// Either kind of loaded matrix.
pub enum Loaded {
    Sparse(Csr),
    Dense(Mat),
}

/// Read a MatrixMarket file (`matrix coordinate real general` or
/// `matrix array real general`).
pub fn read_matrix_market(path: &Path) -> Result<Loaded> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut lines = std::io::BufReader::new(file).lines();

    let header = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty file"))?
        .context("reading header")?;
    let h: Vec<&str> = header.split_whitespace().collect();
    if h.len() < 4 || h[0] != "%%MatrixMarket" || h[1] != "matrix" {
        bail!("not a MatrixMarket matrix file: {header}");
    }
    let coordinate = match h[2] {
        "coordinate" => true,
        "array" => false,
        other => bail!("unsupported storage '{other}'"),
    };
    if !matches!(h[3], "real" | "integer") {
        bail!("unsupported field '{}'", h[3]);
    }
    let symmetric = h.get(4).map(|s| *s == "symmetric").unwrap_or(false);

    // Skip comments, read the size line.
    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line?;
        if line.starts_with('%') || line.trim().is_empty() {
            continue;
        }
        size_line = Some(line);
        break;
    }
    let size_line = size_line.ok_or_else(|| anyhow::anyhow!("missing size line"))?;
    let dims: Vec<usize> =
        size_line.split_whitespace().map(|t| t.parse().context("size line")).collect::<Result<_>>()?;

    if coordinate {
        let (&rows, &cols, &nnz) = match dims.as_slice() {
            [r, c, n] => (r, c, n),
            _ => bail!("coordinate size line must be 'rows cols nnz'"),
        };
        let mut trips = Vec::with_capacity(nnz);
        for line in lines {
            let line = line?;
            let t = line.trim();
            if t.is_empty() || t.starts_with('%') {
                continue;
            }
            let mut it = t.split_whitespace();
            let i: usize = it.next().context("row index")?.parse()?;
            let j: usize = it.next().context("col index")?.parse()?;
            let v: Elem = it.next().map(|s| s.parse()).transpose()?.unwrap_or(1.0);
            if i == 0 || j == 0 || i > rows || j > cols {
                bail!("index ({i},{j}) out of bounds {rows}x{cols} (1-based)");
            }
            trips.push((i - 1, j - 1, v));
            if symmetric && i != j {
                trips.push((j - 1, i - 1, v));
            }
        }
        Ok(Loaded::Sparse(Csr::from_triplets(rows, cols, trips)))
    } else {
        let (&rows, &cols) = match dims.as_slice() {
            [r, c] => (r, c),
            _ => bail!("array size line must be 'rows cols'"),
        };
        // Array format is column-major.
        let mut vals = Vec::with_capacity(rows * cols);
        for line in lines {
            let line = line?;
            let t = line.trim();
            if t.is_empty() || t.starts_with('%') {
                continue;
            }
            for tok in t.split_whitespace() {
                vals.push(tok.parse::<Elem>()?);
            }
        }
        if vals.len() != rows * cols {
            bail!("expected {} values, got {}", rows * cols, vals.len());
        }
        let mut m = Mat::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                *m.at_mut(i, j) = vals[j * rows + i];
            }
        }
        Ok(Loaded::Dense(m))
    }
}

/// Write a CSR matrix in coordinate format.
pub fn write_sparse(path: &Path, a: &Csr) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "{} {} {}", a.rows(), a.cols(), a.nnz())?;
    for i in 0..a.rows() {
        let (cols, vals) = a.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            writeln!(w, "{} {} {}", i + 1, c as usize + 1, v)?;
        }
    }
    Ok(())
}

/// Write a dense matrix in array format (column-major per the spec).
pub fn write_dense(path: &Path, m: &Mat) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix array real general")?;
    writeln!(w, "{} {}", m.rows(), m.cols())?;
    for j in 0..m.cols() {
        for i in 0..m.rows() {
            writeln!(w, "{}", m.at(i, j))?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("plnmf-mmio-{}-{name}", std::process::id()));
        p
    }

    #[test]
    fn sparse_roundtrip() {
        let a = Csr::from_triplets(3, 4, vec![(0, 1, 2.5), (2, 3, -1.0), (1, 0, 4.0)]);
        let p = tmp("sparse.mtx");
        write_sparse(&p, &a).unwrap();
        match read_matrix_market(&p).unwrap() {
            Loaded::Sparse(b) => assert_eq!(a, b),
            _ => panic!("expected sparse"),
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn dense_roundtrip() {
        let m = Mat::from_fn(3, 2, |i, j| (i * 2 + j) as Elem + 0.5);
        let p = tmp("dense.mtx");
        write_dense(&p, &m).unwrap();
        match read_matrix_market(&p).unwrap() {
            Loaded::Dense(b) => assert_eq!(m, b),
            _ => panic!("expected dense"),
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_bad_header() {
        let p = tmp("bad.mtx");
        std::fs::write(&p, "not a matrix\n1 1 1\n").unwrap();
        assert!(read_matrix_market(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn symmetric_expansion() {
        let p = tmp("sym.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n1 1 1.0\n2 1 3.0\n",
        )
        .unwrap();
        match read_matrix_market(&p).unwrap() {
            Loaded::Sparse(a) => {
                assert_eq!(a.nnz(), 3);
                let d = a.to_dense();
                assert_eq!(d.at(0, 1), 3.0);
                assert_eq!(d.at(1, 0), 3.0);
            }
            _ => panic!(),
        }
        std::fs::remove_file(p).ok();
    }
}

//! Sparse matrix substrate (the role MKL's `mkl_dcsrmm` plays in the
//! paper: the text corpora are 99.6–99.8 % sparse, so `P = A·Hᵀ` and
//! `R = Aᵀ·W` must run as CSR × dense products).

pub mod csr;
pub mod spmm;
pub mod mmio;

pub use csr::Csr;
pub use spmm::{spmm, spmm_range};

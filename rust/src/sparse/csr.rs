//! Compressed Sparse Row matrix.

use crate::linalg::Mat;
use crate::Elem;

/// CSR matrix with `rows+1` row pointers, column indices sorted within
/// each row, and no explicit zeros (construction de-duplicates by
/// summing).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    rows: usize,
    cols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    values: Vec<Elem>,
}

impl Csr {
    /// Build from COO triplets; duplicates are summed, entries with value
    /// 0 dropped, columns sorted within each row.
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: impl IntoIterator<Item = (usize, usize, Elem)>,
    ) -> Csr {
        let mut by_row: Vec<Vec<(u32, Elem)>> = vec![Vec::new(); rows];
        for (r, c, v) in triplets {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of {rows}x{cols}");
            by_row[r].push((c as u32, v));
        }
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0);
        for row in &mut by_row {
            row.sort_unstable_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < row.len() {
                let c = row[i].0;
                let mut v = 0.0;
                while i < row.len() && row[i].0 == c {
                    v += row[i].1;
                    i += 1;
                }
                if v != 0.0 {
                    col_idx.push(c);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        Csr { rows, cols, row_ptr, col_idx, values }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
        }
    }

    /// (column indices, values) of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[Elem]) {
        let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[s..e], &self.values[s..e])
    }

    pub fn row_ptr(&self) -> &[usize] {
        &self.row_ptr
    }

    /// Transpose to a new CSR (counting sort by column — O(nnz + cols)).
    /// Engines keep both `A` and `Aᵀ` resident, as planc does.
    pub fn transposed(&self) -> Csr {
        let mut counts = vec![0usize; self.cols + 1];
        for &c in &self.col_idx {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.cols {
            counts[i + 1] += counts[i];
        }
        let row_ptr = counts.clone();
        let mut col_idx = vec![0u32; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        let mut next = counts;
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                let dst = next[c as usize];
                col_idx[dst] = r as u32;
                values[dst] = v;
                next[c as usize] += 1;
            }
        }
        Csr { rows: self.cols, cols: self.rows, row_ptr, col_idx, values }
    }

    /// Copy the contiguous row window `[r0, r1)` into its own CSR (same
    /// column space) — used to carve query batches for serving benches.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Csr {
        assert!(r0 <= r1 && r1 <= self.rows, "slice_rows({r0},{r1}) of {} rows", self.rows);
        let (s, e) = (self.row_ptr[r0], self.row_ptr[r1]);
        Csr {
            rows: r1 - r0,
            cols: self.cols,
            row_ptr: self.row_ptr[r0..=r1].iter().map(|&p| p - s).collect(),
            col_idx: self.col_idx[s..e].to_vec(),
            values: self.values[s..e].to_vec(),
        }
    }

    /// Densify (tests and tiny problems only).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                *m.at_mut(r, c as usize) = v;
            }
        }
        m
    }

    pub fn from_dense(m: &Mat) -> Csr {
        let mut trips = Vec::new();
        for i in 0..m.rows() {
            for j in 0..m.cols() {
                let v = m.at(i, j);
                if v != 0.0 {
                    trips.push((i, j, v));
                }
            }
        }
        Csr::from_triplets(m.rows(), m.cols(), trips)
    }

    /// Squared Frobenius norm (f64 accumulation).
    pub fn fro2(&self) -> f64 {
        self.values.iter().map(|&v| v as f64 * v as f64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_triplets_sorts_and_dedups() {
        let a = Csr::from_triplets(2, 3, vec![(0, 2, 1.0), (0, 0, 2.0), (0, 2, 3.0), (1, 1, 0.0)]);
        assert_eq!(a.nnz(), 2);
        let (cols, vals) = a.row(0);
        assert_eq!(cols, &[0, 2]);
        assert_eq!(vals, &[2.0, 4.0]);
        let (cols1, _) = a.row(1);
        assert!(cols1.is_empty());
    }

    #[test]
    fn dense_roundtrip() {
        let m = Mat::from_vec(2, 3, vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0]);
        let a = Csr::from_dense(&m);
        assert_eq!(a.nnz(), 3);
        assert_eq!(a.to_dense(), m);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut trips = Vec::new();
        let mut rng = crate::util::rng::Pcg32::seeded(8);
        for _ in 0..200 {
            trips.push((rng.below(17) as usize, rng.below(31) as usize, rng.next_f32() + 0.1));
        }
        let a = Csr::from_triplets(17, 31, trips);
        let t = a.transposed();
        assert_eq!(t.rows(), 31);
        assert_eq!(t.cols(), 17);
        assert_eq!(t.nnz(), a.nnz());
        assert_eq!(t.to_dense(), a.to_dense().transposed());
        assert_eq!(t.transposed().to_dense(), a.to_dense());
    }

    #[test]
    fn transpose_has_sorted_columns() {
        let a = Csr::from_triplets(3, 3, vec![(2, 0, 1.0), (0, 0, 2.0), (1, 0, 3.0)]);
        let t = a.transposed();
        let (cols, _) = t.row(0);
        assert!(cols.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn slice_rows_windows_match_dense() {
        let mut trips = Vec::new();
        let mut rng = crate::util::rng::Pcg32::seeded(9);
        for _ in 0..150 {
            trips.push((rng.below(20) as usize, rng.below(9) as usize, rng.next_f32() + 0.1));
        }
        let a = Csr::from_triplets(20, 9, trips);
        let dense = a.to_dense();
        for (r0, r1) in [(0usize, 20usize), (3, 11), (19, 20), (5, 5)] {
            let s = a.slice_rows(r0, r1);
            assert_eq!(s.rows(), r1 - r0);
            assert_eq!(s.cols(), 9);
            let sd = s.to_dense();
            for i in 0..(r1 - r0) {
                assert_eq!(sd.row(i), dense.row(r0 + i));
            }
        }
    }

    #[test]
    fn fro2_matches_dense() {
        let m = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, -2.0]);
        let a = Csr::from_dense(&m);
        assert!((a.fro2() - m.fro2()).abs() < 1e-12);
    }
}

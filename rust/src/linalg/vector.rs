//! Small vector kernels used by the engines' inner loops.

use crate::Elem;

/// `y += a * x` over contiguous slices (auto-vectorized).
#[inline]
pub fn axpy(a: Elem, x: &[Elem], y: &mut [Elem]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Dot product with f32 accumulation (hot loop; callers that need
/// deterministic high precision use [`dot_f64`]).
#[inline]
pub fn dot(x: &[Elem], y: &[Elem]) -> Elem {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0;
    for (&a, &b) in x.iter().zip(y) {
        s += a * b;
    }
    s
}

/// Dot product accumulated in f64.
#[inline]
pub fn dot_f64(x: &[Elem], y: &[Elem]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0f64;
    for (&a, &b) in x.iter().zip(y) {
        s += a as f64 * b as f64;
    }
    s
}

/// Sum of squares in f64 (column norms, objective pieces).
#[inline]
pub fn nrm2_sq(x: &[Elem]) -> f64 {
    let mut s = 0.0f64;
    for &a in x {
        s += a as f64 * a as f64;
    }
    s
}

/// Scale in place.
#[inline]
pub fn scale(a: Elem, x: &mut [Elem]) {
    for xi in x {
        *xi *= a;
    }
}

/// Elementwise `max(eps, ·)` — the non-negativity projection of Alg. 1.
#[inline]
pub fn clamp_eps(eps: Elem, x: &mut [Elem]) {
    for xi in x {
        if *xi < eps {
            *xi = eps;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn dots_agree() {
        let x: Vec<Elem> = (0..100).map(|i| i as Elem * 0.01).collect();
        let y: Vec<Elem> = (0..100).map(|i| (100 - i) as Elem * 0.02).collect();
        let a = dot(&x, &y) as f64;
        let b = dot_f64(&x, &y);
        assert!((a - b).abs() < 1e-3);
    }

    #[test]
    fn nrm2_sq_known() {
        assert!((nrm2_sq(&[3.0, 4.0]) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn clamp_floors_values() {
        let mut x = [-1.0, 0.0, 0.5, 2.0];
        clamp_eps(1e-16, &mut x);
        assert!(x.iter().all(|&v| v >= 1e-16));
        assert_eq!(x[3], 2.0);
    }

    #[test]
    fn scale_in_place() {
        let mut x = [1.0, -2.0];
        scale(-0.5, &mut x);
        assert_eq!(x, [-0.5, 1.0]);
    }
}

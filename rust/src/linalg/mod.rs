//! Dense linear algebra substrate (the role MKL's `cblas_dgemm` plays in
//! the paper's implementation).
//!
//! Everything is `f32` row-major. The engines work on three shapes:
//! tall-skinny factors (`V×K`, `D×K`), small square Grams (`K×K`), and the
//! data matrix (`V×D`, dense datasets only). The GEMMs that matter are
//! panel×small (phases 1/3) and tall×tall-skinny (P = A·H), both served by
//! the blocked, thread-parallel [`gemm`] on strided views.

pub mod dense;
pub mod gemm;
pub mod gram;
pub mod vector;

pub use dense::{Mat, View, ViewMut};
pub use gemm::{gemm, gemm_serial, GemmOp};
pub use gram::gram;

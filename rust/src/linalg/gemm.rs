//! Blocked, thread-parallel GEMM on strided views.
//!
//! `C op= alpha · A · B` where `op` is assign or accumulate. This is the
//! workhorse behind phases 1/3 of the PL-NMF update (panel × small-square)
//! and behind `P = A·H` / `R = Aᵀ·W` on dense datasets. The paper uses
//! MKL's `cblas_dgemm` here; our kernel is a classic i-k-j register/cache
//! blocking:
//!
//! * rows of `C` are distributed across the thread pool (row-disjoint
//!   writes, no synchronization on the output);
//! * the k-dimension is blocked (`KB`) so the active panel of `B` stays in
//!   L1/L2 while a block of `A` rows streams through;
//! * the innermost loop runs over contiguous `j` (row-major `B` and `C`),
//!   dispatched through the [`Kernels`] table (`axpy2` for the k-pair
//!   unroll, `axpy` for the odd-k tail) — AVX2+FMA when the CPU has it,
//!   the scalar loop otherwise.

use super::dense::{View, ViewMut};
use crate::kernels::Kernels;
use crate::parallel::ThreadPool;
use crate::Elem;

/// What to do with the existing contents of C.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmOp {
    /// `C = alpha·A·B`
    Assign,
    /// `C += alpha·A·B`  (use a negative `alpha` for subtraction — the
    /// `-=` panel updates of Alg. 2 lines 12/40).
    Add,
}

/// Cache block sizes. `KB` × `JB` f32 of B = 64 KiB — sized to stay L2
/// resident while A streams; `IB` limits the C working set per task.
const IB: usize = 64;
const KB: usize = 128;

/// Thread-parallel GEMM over views: `c op= alpha * a · b`.
///
/// Shapes: `a: m×k`, `b: k×n`, `c: m×n`. Parallelism is over row blocks of
/// `c`; safe because row ranges are disjoint.
pub fn gemm(pool: &ThreadPool, alpha: Elem, a: View<'_>, b: View<'_>, op: GemmOp, c: &mut ViewMut<'_>) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    assert_eq!(b.rows, k, "gemm: inner dims {}x{} · {}x{}", m, k, b.rows, n);
    assert_eq!(c.rows, m, "gemm: c rows");
    assert_eq!(c.cols, n, "gemm: c cols");
    if m == 0 || n == 0 {
        return;
    }
    let craw = c.raw();
    let kern = pool.kernels();
    // Choose a grain: whole row-blocks of IB rows.
    let blocks = m.div_ceil(IB);
    pool.parallel_for(blocks, Some(1), |block_range| {
        for blk in block_range {
            let i0 = blk * IB;
            let i1 = (i0 + IB).min(m);
            // SAFETY: block rows [i0, i1) are exclusive to this task.
            unsafe { gemm_rows(kern, alpha, a, b, op, &craw, i0, i1) };
        }
    });
}

/// Serial GEMM (used by small K×K products and inside already-parallel
/// regions, e.g. per-worker shards in the coordinator).
pub fn gemm_serial(alpha: Elem, a: View<'_>, b: View<'_>, op: GemmOp, c: &mut ViewMut<'_>) {
    let (m, n) = (a.rows, b.cols);
    assert_eq!(b.rows, a.cols);
    assert_eq!((c.rows, c.cols), (m, n));
    if m == 0 || n == 0 {
        return;
    }
    let craw = c.raw();
    unsafe { gemm_rows(Kernels::select(), alpha, a, b, op, &craw, 0, m) };
}

/// Compute rows `[i0, i1)` of `c`. Caller guarantees exclusive access to
/// those rows.
unsafe fn gemm_rows(
    kern: &Kernels,
    alpha: Elem,
    a: View<'_>,
    b: View<'_>,
    op: GemmOp,
    c: &super::dense::RawViewMut,
    i0: usize,
    i1: usize,
) {
    let k = a.cols;
    if op == GemmOp::Assign {
        for i in i0..i1 {
            c.row_mut(i).fill(0.0);
        }
    }
    let mut kb = 0;
    while kb < k {
        let kend = (kb + KB).min(k);
        for i in i0..i1 {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            // Unroll pairs of k for fewer passes over the C row.
            let mut kk = kb;
            while kk + 1 < kend {
                let x0 = arow[kk];
                let x1 = arow[kk + 1];
                // Zero-skip on the A elements themselves, NOT the
                // alpha-scaled products: `alpha * x` can be ±0.0 for a
                // nonzero `x` (alpha = ±0.0, or a denormal-range
                // underflow), and skipping on the product silently
                // changed which contributions were applied depending on
                // alpha's scaling.
                if x0 != 0.0 || x1 != 0.0 {
                    (kern.axpy2)(alpha * x0, b.row(kk), alpha * x1, b.row(kk + 1), crow);
                }
                kk += 2;
            }
            if kk < kend {
                let x0 = arow[kk];
                if x0 != 0.0 {
                    (kern.axpy)(alpha * x0, b.row(kk), crow);
                }
            }
        }
        kb = kend;
    }
}

/// Reference triple loop for testing.
pub fn gemm_naive(alpha: Elem, a: View<'_>, b: View<'_>, op: GemmOp, c: &mut ViewMut<'_>) {
    assert_eq!(b.rows, a.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut s = 0.0f64;
            for kk in 0..a.cols {
                s += a.at(i, kk) as f64 * b.at(kk, j) as f64;
            }
            let v = alpha as f64 * s;
            let dst = c.at_mut(i, j);
            *dst = match op {
                GemmOp::Assign => v as Elem,
                GemmOp::Add => *dst + v as Elem,
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::rng::Pcg32;

    fn random_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Pcg32::seeded(seed);
        Mat::random(r, c, &mut rng, -1.0, 1.0)
    }

    fn check_close(a: &Mat, b: &Mat, tol: f64) {
        let d = a.max_abs_diff(b);
        assert!(d < tol, "max diff {d} > {tol}");
    }

    #[test]
    fn matches_naive_assign_and_add() {
        let pool = ThreadPool::new(4);
        for &(m, k, n) in &[(1, 1, 1), (5, 7, 3), (64, 64, 64), (130, 257, 33), (97, 1, 5)] {
            let a = random_mat(m, k, 1);
            let b = random_mat(k, n, 2);
            let mut c1 = random_mat(m, n, 3);
            let mut c2 = c1.clone();
            gemm(&pool, 0.5, a.view(), b.view(), GemmOp::Add, &mut c1.view_mut());
            gemm_naive(0.5, a.view(), b.view(), GemmOp::Add, &mut c2.view_mut());
            check_close(&c1, &c2, 1e-3);

            let mut c3 = random_mat(m, n, 4);
            let mut c4 = c3.clone();
            gemm(&pool, -1.0, a.view(), b.view(), GemmOp::Assign, &mut c3.view_mut());
            gemm_naive(-1.0, a.view(), b.view(), GemmOp::Assign, &mut c4.view_mut());
            check_close(&c3, &c4, 1e-3);
        }
    }

    #[test]
    fn strided_views_panel_update() {
        // The Alg. 2 phase-1 shape: W_new[:, 0..c) -= W_old[:, t0..t1) · Q[t0..t1, 0..c)
        let pool = ThreadPool::new(3);
        let (v, k, t0, t1) = (50, 16, 8, 12);
        let w_old = random_mat(v, k, 5);
        let q = random_mat(k, k, 6);
        let mut w_new = random_mat(v, k, 7);
        let mut w_ref = w_new.clone();

        gemm(
            &pool,
            -1.0,
            w_old.col_view(t0, t1),
            q.block_view(t0, t1, 0, t0),
            GemmOp::Add,
            &mut w_new.col_view_mut(0, t0),
        );
        // Reference: explicit loops.
        for i in 0..v {
            for j in 0..t0 {
                let mut s = 0.0f64;
                for t in t0..t1 {
                    s += w_old.at(i, t) as f64 * q.at(t, j) as f64;
                }
                *w_ref.at_mut(i, j) -= s as Elem;
            }
        }
        check_close(&w_new, &w_ref, 1e-4);
        // Columns outside [0, t0) untouched:
        for i in 0..v {
            for j in t0..k {
                assert_eq!(w_new.at(i, j), w_ref.at(i, j));
            }
        }
    }

    #[test]
    fn serial_equals_parallel() {
        let a = random_mat(77, 31, 8);
        let b = random_mat(31, 19, 9);
        let mut c1 = Mat::zeros(77, 19);
        let mut c2 = Mat::zeros(77, 19);
        let pool = ThreadPool::new(4);
        gemm(&pool, 1.0, a.view(), b.view(), GemmOp::Assign, &mut c1.view_mut());
        gemm_serial(1.0, a.view(), b.view(), GemmOp::Assign, &mut c2.view_mut());
        // Identical blocking => bitwise equal.
        assert_eq!(c1, c2);
    }

    #[test]
    fn negative_zero_alpha_matches_naive() {
        // Regression for the zero-skip branch: it must test the A
        // elements, not `alpha * a` — with alpha = ±0.0 every scaled
        // coefficient is a signed zero, and the old product-based skip
        // dropped the (sign-carrying) zero contributions entirely
        // instead of applying them like the reference does.
        let pool = ThreadPool::new(3);
        for alpha in [-0.0f32, 0.0f32] {
            for op in [GemmOp::Assign, GemmOp::Add] {
                let a = random_mat(33, 17, 21);
                let b = random_mat(17, 9, 22);
                let mut c1 = random_mat(33, 9, 23);
                let mut c2 = c1.clone();
                gemm(&pool, alpha, a.view(), b.view(), op, &mut c1.view_mut());
                gemm_naive(alpha, a.view(), b.view(), op, &mut c2.view_mut());
                // Zero-alpha contributions are all ±0, so values must
                // agree exactly (0.0 == -0.0 under IEEE comparison).
                for i in 0..33 {
                    for j in 0..9 {
                        assert_eq!(c1.at(i, j), c2.at(i, j), "alpha={alpha} {op:?} ({i},{j})");
                    }
                }
            }
        }
        // Bit-level check: with A = −1, B = 1, C = −0.0, the ±0
        // contribution `(−0.0 · −1) · 1 = +0.0` must be APPLIED, turning
        // C's −0.0 into +0.0 exactly as the reference does — the old
        // product-based skip dropped it and left −0.0 behind. Exercised
        // at k = 1 (axpy tail) and k = 2 (axpy2 pair).
        for k in [1usize, 2] {
            let a = Mat::from_fn(4, k, |_, _| -1.0);
            let b = Mat::from_fn(k, 3, |_, _| 1.0);
            let mut c1 = Mat::from_fn(4, 3, |_, _| -0.0);
            let mut c2 = c1.clone();
            gemm(&pool, -0.0, a.view(), b.view(), GemmOp::Add, &mut c1.view_mut());
            gemm_naive(-0.0, a.view(), b.view(), GemmOp::Add, &mut c2.view_mut());
            for i in 0..4 {
                for j in 0..3 {
                    assert_eq!(
                        c1.at(i, j).to_bits(),
                        c2.at(i, j).to_bits(),
                        "k={k} ({i},{j}): signed-zero contribution dropped"
                    );
                }
            }
        }
    }

    #[test]
    fn zero_rows_of_a_skip_without_changing_results() {
        // The skip itself (x == 0.0 rows of A) must be value-neutral.
        let pool = ThreadPool::new(2);
        let mut a = random_mat(20, 12, 31);
        for kk in [0usize, 3, 4, 11] {
            for i in 0..20 {
                *a.at_mut(i, kk) = 0.0;
            }
        }
        let b = random_mat(12, 7, 32);
        let mut c1 = random_mat(20, 7, 33);
        let mut c2 = c1.clone();
        gemm(&pool, 1.0, a.view(), b.view(), GemmOp::Add, &mut c1.view_mut());
        gemm_naive(1.0, a.view(), b.view(), GemmOp::Add, &mut c2.view_mut());
        check_close(&c1, &c2, 1e-3);
    }

    #[test]
    fn empty_dims_are_noops() {
        let pool = ThreadPool::new(2);
        let a = random_mat(4, 0, 1);
        let b = Mat::zeros(0, 3);
        let mut c = random_mat(4, 3, 2);
        let before = c.clone();
        gemm(&pool, 1.0, a.view(), b.view(), GemmOp::Add, &mut c.view_mut());
        assert_eq!(c, before); // k=0 => no contribution

        let a2 = Mat::zeros(0, 5);
        let b2 = random_mat(5, 3, 3);
        let mut c2 = Mat::zeros(0, 3);
        gemm(&pool, 1.0, a2.view(), b2.view(), GemmOp::Assign, &mut c2.view_mut());
    }
}

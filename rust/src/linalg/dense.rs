//! Row-major dense matrix and borrowed views.

use crate::util::rng::Pcg32;
use crate::Elem;

/// Owned row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<Elem>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> Elem) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<Elem>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Uniform random entries in `[lo, hi)` — NMF factor initialization
    /// (Alg. 1 line 1 “random non-negative numbers”).
    pub fn random(rows: usize, cols: usize, rng: &mut Pcg32, lo: Elem, hi: Elem) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for x in &mut m.data {
            *x = rng.range_f32(lo, hi);
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> Elem {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut Elem {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[Elem] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [Elem] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn data(&self) -> &[Elem] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [Elem] {
        &mut self.data
    }

    pub fn fill(&mut self, x: Elem) {
        self.data.fill(x);
    }

    pub fn copy_from(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data.copy_from_slice(&other.data);
    }

    /// Full-matrix immutable view.
    pub fn view(&self) -> View<'_> {
        View { data: &self.data, rows: self.rows, cols: self.cols, rs: self.cols, off: 0 }
    }

    /// View of a contiguous column range `[c0, c1)`.
    pub fn col_view(&self, c0: usize, c1: usize) -> View<'_> {
        assert!(c0 <= c1 && c1 <= self.cols);
        View { data: &self.data, rows: self.rows, cols: c1 - c0, rs: self.cols, off: c0 }
    }

    /// View of a row range × column range.
    pub fn block_view(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> View<'_> {
        assert!(r0 <= r1 && r1 <= self.rows && c0 <= c1 && c1 <= self.cols);
        View {
            data: &self.data,
            rows: r1 - r0,
            cols: c1 - c0,
            rs: self.cols,
            off: r0 * self.cols + c0,
        }
    }

    pub fn view_mut(&mut self) -> ViewMut<'_> {
        let (rows, cols) = (self.rows, self.cols);
        ViewMut { data: &mut self.data, rows, cols, rs: cols, off: 0 }
    }

    pub fn col_view_mut(&mut self, c0: usize, c1: usize) -> ViewMut<'_> {
        assert!(c0 <= c1 && c1 <= self.cols);
        let (rows, cols) = (self.rows, self.cols);
        ViewMut { data: &mut self.data, rows, cols: c1 - c0, rs: cols, off: c0 }
    }

    pub fn block_view_mut(&mut self, r0: usize, r1: usize, c0: usize, c1: usize) -> ViewMut<'_> {
        assert!(r0 <= r1 && r1 <= self.rows && c0 <= c1 && c1 <= self.cols);
        let cols = self.cols;
        ViewMut {
            data: &mut self.data,
            rows: r1 - r0,
            cols: c1 - c0,
            rs: cols,
            off: r0 * cols + c0,
        }
    }

    /// Out-of-place transpose (used once at load time: `At = Aᵀ` so both
    /// `A·H` and `Aᵀ·W` run as row-parallel NN products; planc keeps the
    /// same pair).
    pub fn transposed(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Blocked to keep both source rows and destination rows in cache.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Squared Frobenius norm with f64 accumulation.
    pub fn fro2(&self) -> f64 {
        self.data.iter().map(|&x| x as f64 * x as f64).sum()
    }

    /// Max |a - b| over all entries.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a as f64 - b as f64).abs())
            .fold(0.0, f64::max)
    }
}

/// Borrowed strided view (row stride `rs`, linear offset `off`).
#[derive(Clone, Copy, Debug)]
pub struct View<'a> {
    pub data: &'a [Elem],
    pub rows: usize,
    pub cols: usize,
    pub rs: usize,
    pub off: usize,
}

impl<'a> View<'a> {
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> Elem {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[self.off + i * self.rs + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &'a [Elem] {
        let start = self.off + i * self.rs;
        &self.data[start..start + self.cols]
    }
}

/// Mutable strided view.
#[derive(Debug)]
pub struct ViewMut<'a> {
    pub data: &'a mut [Elem],
    pub rows: usize,
    pub cols: usize,
    pub rs: usize,
    pub off: usize,
}

impl<'a> ViewMut<'a> {
    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut Elem {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[self.off + i * self.rs + j]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [Elem] {
        let start = self.off + i * self.rs;
        &mut self.data[start..start + self.cols]
    }

    /// Raw base pointer + geometry, for disjoint-row parallel writes.
    pub(crate) fn raw(&mut self) -> RawViewMut {
        RawViewMut {
            ptr: self.data.as_mut_ptr(),
            len: self.data.len(),
            rows: self.rows,
            cols: self.cols,
            rs: self.rs,
            off: self.off,
        }
    }
}

/// Unsafe escape hatch: workers write disjoint row ranges of the same
/// view concurrently (GEMM row-parallelism).
#[derive(Clone, Copy)]
pub(crate) struct RawViewMut {
    ptr: *mut Elem,
    len: usize,
    pub rows: usize,
    pub cols: usize,
    rs: usize,
    off: usize,
}

unsafe impl Send for RawViewMut {}
unsafe impl Sync for RawViewMut {}

impl RawViewMut {
    /// Mutable row slice. Caller must guarantee row-disjoint access.
    #[inline]
    pub unsafe fn row_mut(&self, i: usize) -> &mut [Elem] {
        debug_assert!(i < self.rows);
        let start = self.off + i * self.rs;
        debug_assert!(start + self.cols <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_and_rows() {
        let m = Mat::from_fn(3, 4, |i, j| (10 * i + j) as Elem);
        assert_eq!(m.at(2, 3), 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
    }

    #[test]
    fn views_are_windows() {
        let m = Mat::from_fn(4, 6, |i, j| (i * 6 + j) as Elem);
        let v = m.col_view(2, 5);
        assert_eq!(v.rows, 4);
        assert_eq!(v.cols, 3);
        assert_eq!(v.at(1, 0), m.at(1, 2));
        assert_eq!(v.row(2), &[14.0, 15.0, 16.0]);
        let b = m.block_view(1, 3, 2, 4);
        assert_eq!(b.at(0, 0), m.at(1, 2));
        assert_eq!(b.at(1, 1), m.at(2, 3));
    }

    #[test]
    fn view_mut_writes_through() {
        let mut m = Mat::zeros(3, 3);
        {
            let mut v = m.col_view_mut(1, 3);
            *v.at_mut(0, 0) = 5.0;
            v.row_mut(2).copy_from_slice(&[7.0, 8.0]);
        }
        assert_eq!(m.at(0, 1), 5.0);
        assert_eq!(m.at(2, 1), 7.0);
        assert_eq!(m.at(2, 2), 8.0);
        assert_eq!(m.at(0, 0), 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg32::seeded(1);
        let m = Mat::random(37, 53, &mut rng, 0.0, 1.0);
        let t = m.transposed();
        assert_eq!(t.rows(), 53);
        assert_eq!(t.cols(), 37);
        for i in 0..m.rows() {
            for j in 0..m.cols() {
                assert_eq!(m.at(i, j), t.at(j, i));
            }
        }
        assert_eq!(t.transposed(), m);
    }

    #[test]
    fn fro2_matches_manual() {
        let m = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert!((m.fro2() - 30.0).abs() < 1e-12);
    }

    #[test]
    fn random_within_bounds() {
        let mut rng = Pcg32::seeded(2);
        let m = Mat::random(10, 10, &mut rng, 0.5, 1.5);
        assert!(m.data().iter().all(|&x| (0.5..1.5).contains(&x)));
    }
}

//! Gram matrices: `G = Xᵀ·X` for a tall-skinny `X` (n×k).
//!
//! Alg. 1 computes `S = WᵀW` and `Q = HHᵀ` every iteration; with our
//! storage convention (H held transposed, D×K) both are Grams of n×k
//! matrices with k ≤ 240. Parallelized as per-worker partial Grams over
//! row shards + deterministic combine — the same partial/combine shape the
//! coordinator uses across shards, and the CPU analogue of the paper's
//! reduction tree.

use super::dense::Mat;
use crate::kernels::Kernels;
use crate::parallel::{reduce, ThreadPool};
use crate::Elem;

/// Rows per f32 accumulation block. Entries are O(1) (factors live in
/// [ε, ~255]), so a 128-row f32 partial stays well inside f32's exact
/// range; block partials are folded in f64. This keeps the hot loop in
/// 8-wide f32 FMA instead of f64 (measured 2.6→7+ GFLOP/s on the
/// 20news K=240 Gram — see EXPERIMENTS.md §Perf).
const F32_BLOCK: usize = 128;

/// `G = Xᵀ·X` (k×k, symmetric). f32 FMA inner loop, f64 block folds.
pub fn gram(pool: &ThreadPool, x: &Mat) -> Mat {
    let k = x.cols();
    let kern = pool.kernels();
    let partial = reduce(
        pool,
        x.rows(),
        |r| {
            let mut acc = vec![0.0f64; k * k];
            let mut block = vec![0.0f32; k * k];
            let mut in_block = 0usize;
            let mut i = r.start;
            while i < r.end {
                if i + 1 < r.end {
                    // Row pair: one accumulator pass serves two rows
                    // (halves the dominant dst load/store traffic).
                    gram_accumulate_rows2_f32(kern, &mut block, x.row(i), x.row(i + 1), k);
                    i += 2;
                    in_block += 2;
                } else {
                    gram_accumulate_row_f32(kern, &mut block, x.row(i), k);
                    i += 1;
                    in_block += 1;
                }
                if in_block >= F32_BLOCK {
                    fold_block(&mut acc, &mut block);
                    in_block = 0;
                }
            }
            if in_block > 0 {
                fold_block(&mut acc, &mut block);
            }
            acc
        },
        |mut a, b| {
            for (x, y) in a.iter_mut().zip(&b) {
                *x += *y;
            }
            a
        },
    )
    .unwrap_or_else(|| vec![0.0f64; k * k]);

    let mut g = Mat::zeros(k, k);
    for i in 0..k {
        for j in i..k {
            let v = partial[i * k + j] as Elem;
            *g.at_mut(i, j) = v;
            *g.at_mut(j, i) = v;
        }
    }
    g
}

/// Accumulate the upper triangle of `row ⊗ row` into `acc` (k×k, f32).
#[inline]
fn gram_accumulate_row_f32(kern: &Kernels, acc: &mut [f32], row: &[Elem], k: usize) {
    for i in 0..k {
        let xi = row[i];
        if xi == 0.0 {
            continue;
        }
        (kern.axpy)(xi, &row[i..k], &mut acc[i * k + i..i * k + k]);
    }
}

/// Two-row variant: `acc += r0 ⊗ r0 + r1 ⊗ r1` in one pass over the
/// upper triangle.
#[inline]
fn gram_accumulate_rows2_f32(kern: &Kernels, acc: &mut [f32], r0: &[Elem], r1: &[Elem], k: usize) {
    for i in 0..k {
        let a0 = r0[i];
        let a1 = r1[i];
        if a0 == 0.0 && a1 == 0.0 {
            continue;
        }
        (kern.axpy2)(a0, &r0[i..k], a1, &r1[i..k], &mut acc[i * k + i..i * k + k]);
    }
}

/// Fold a f32 block partial into the f64 accumulator and clear it.
#[inline]
fn fold_block(acc: &mut [f64], block: &mut [f32]) {
    for (a, b) in acc.iter_mut().zip(block.iter_mut()) {
        *a += *b as f64;
        *b = 0.0;
    }
}

/// Serial reference for testing.
pub fn gram_naive(x: &Mat) -> Mat {
    let k = x.cols();
    let mut g = Mat::zeros(k, k);
    for i in 0..k {
        for j in 0..k {
            let mut s = 0.0f64;
            for r in 0..x.rows() {
                s += x.at(r, i) as f64 * x.at(r, j) as f64;
            }
            *g.at_mut(i, j) = s as Elem;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn matches_naive() {
        let pool = ThreadPool::new(4);
        let mut rng = Pcg32::seeded(3);
        for &(n, k) in &[(1, 1), (10, 3), (257, 16), (1000, 33)] {
            let x = Mat::random(n, k, &mut rng, -1.0, 1.0);
            let g = gram(&pool, &x);
            let gn = gram_naive(&x);
            assert!(g.max_abs_diff(&gn) < 1e-3, "n={n} k={k}");
        }
    }

    #[test]
    fn symmetric_and_psd_diagonal() {
        let pool = ThreadPool::new(3);
        let mut rng = Pcg32::seeded(4);
        let x = Mat::random(100, 8, &mut rng, -2.0, 2.0);
        let g = gram(&pool, &x);
        for i in 0..8 {
            assert!(g.at(i, i) >= 0.0);
            for j in 0..8 {
                assert_eq!(g.at(i, j), g.at(j, i));
            }
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let pool = ThreadPool::new(7);
        let mut rng = Pcg32::seeded(5);
        let x = Mat::random(503, 24, &mut rng, 0.0, 1.0);
        let g1 = gram(&pool, &x);
        let g2 = gram(&pool, &x);
        assert_eq!(g1, g2);
    }

    #[test]
    fn zero_rows() {
        let pool = ThreadPool::new(2);
        let x = Mat::zeros(0, 5);
        let g = gram(&pool, &x);
        assert_eq!(g.rows(), 5);
        assert!(g.data().iter().all(|&v| v == 0.0));
    }
}

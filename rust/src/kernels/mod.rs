//! Unified SIMD microkernel layer with runtime dispatch.
//!
//! Every bandwidth-bound inner loop of the engines — the GEMM k-pair
//! unroll, the spmm row accumulate, the Gram row folds, the HALS
//! column-step saxpy + `max(ε)` shrink, the MU denominators, and the KL
//! column sums — bottoms out in one of the primitives below. Each has a
//! portable scalar implementation (verbatim the loops this module
//! replaced, so the scalar backend is bit-for-bit identical to the
//! pre-refactor code) and an x86_64 AVX2+FMA implementation behind
//! `#[target_feature]`, selected at [`Kernels::select`] time via
//! `is_x86_feature_detected!` into a table of plain fn pointers that
//! [`crate::parallel::ThreadPool`] carries to every engine.
//!
//! ## Exactness contract
//!
//! Two classes of primitives, asserted by the parity tests below:
//!
//! * **Exact** — `axpy`, `clamp_sumsq`, `shrink_clamp_sumsq`,
//!   `colsum_f64`: the vector body performs the *same* elementwise
//!   operations in the same per-element order as the scalar loop
//!   (separate multiply + add, never a fused FMA; sequential f64 sum
//!   folds), so the AVX2 backend is bit-identical to scalar. This keeps
//!   `spmm` and the tiled phase-2 column sweep backend-independent.
//! * **Reassociated** — `dot`, `axpy2`, `sqnorm_f64`: FMA contraction
//!   and SIMD-lane reduction reorder the accumulation, so results match
//!   scalar only within relative fp tolerance (≤ 2e-3 at engine scale,
//!   the same slack the tiled-vs-naive property tests allow).
//!
//! ## Override
//!
//! `PLNMF_KERNELS=scalar` forces the scalar backend (the golden-trace
//! suite pins this so committed traces stay machine-independent);
//! `PLNMF_KERNELS=avx2` requests AVX2+FMA and falls back to scalar when
//! the CPU lacks it. Unset: auto-detect. The variable is consulted on
//! every `select()` call, so benches can measure both backends in one
//! process by re-constructing pools under different values.

use crate::Elem;

/// Which implementation family a [`Kernels`] table dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Portable scalar loops (bit-identical to the pre-SIMD code).
    Scalar,
    /// AVX2 + FMA `#[target_feature]` kernels (x86_64 only).
    Avx2Fma,
}

impl Backend {
    /// Stable name, reported by the serving `stats` op.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2Fma => "avx2+fma",
        }
    }
}

/// Dispatch table of the microkernel primitives. Plain fn pointers: one
/// indirect call per slice-level operation, nothing per element.
#[derive(Clone, Copy)]
pub struct Kernels {
    pub backend: Backend,
    /// `y[j] += a · x[j]` (exact across backends).
    pub axpy: fn(Elem, &[Elem], &mut [Elem]),
    /// `y[j] += a0 · x0[j] + a1 · x1[j]` — the GEMM k-pair unroll
    /// (reassociated: the AVX2 body uses FMA).
    pub axpy2: fn(Elem, &[Elem], Elem, &[Elem], &mut [Elem]),
    /// f32-accumulated dot product (reassociated on AVX2).
    pub dot: fn(&[Elem], &[Elem]) -> Elem,
    /// `s[j] += x[j] as f64` — the KL denominator column sum (exact).
    pub colsum_f64: fn(&[Elem], &mut [f64]),
    /// `x[j] = max(eps, x[j])`, returns `Σ x[j]²` in f64 with the
    /// scalar's sequential fold order (exact across backends).
    pub clamp_sumsq: fn(&mut [Elem], Elem) -> f64,
    /// `x[j] = max(eps, (x[j] − l1) · inv)`, returns `Σ x[j]²` in f64 —
    /// the elastic-net shrink + non-negativity projection (exact).
    pub shrink_clamp_sumsq: fn(&mut [Elem], Elem, Elem, Elem) -> f64,
    /// `Σ x[j]²` in f64 (reassociated on AVX2).
    pub sqnorm_f64: fn(&[Elem]) -> f64,
}

impl Kernels {
    /// Backend name (`"scalar"` / `"avx2+fma"`), for stats surfaces.
    pub fn name(&self) -> &'static str {
        self.backend.name()
    }

    /// The scalar table (always available).
    pub fn scalar() -> &'static Kernels {
        &SCALAR
    }

    /// The fastest table this CPU supports, ignoring the env override.
    pub fn detected() -> &'static Kernels {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
            {
                return &AVX2;
            }
        }
        &SCALAR
    }

    /// Runtime selection: the `PLNMF_KERNELS` env override, else CPU
    /// feature detection. Consulted per call (detection is cached by
    /// std), so a process can flip backends between pool constructions.
    pub fn select() -> &'static Kernels {
        match std::env::var("PLNMF_KERNELS").as_deref() {
            Ok("scalar") => &SCALAR,
            Ok("avx2") | Ok("avx2+fma") => Self::detected(),
            _ => Self::detected(),
        }
    }
}

/// The portable backend — each body is the verbatim loop it replaced.
pub static SCALAR: Kernels = Kernels {
    backend: Backend::Scalar,
    axpy: scalar::axpy,
    axpy2: scalar::axpy2,
    dot: scalar::dot,
    colsum_f64: scalar::colsum_f64,
    clamp_sumsq: scalar::clamp_sumsq,
    shrink_clamp_sumsq: scalar::shrink_clamp_sumsq,
    sqnorm_f64: scalar::sqnorm_f64,
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    backend: Backend::Avx2Fma,
    axpy: avx2::axpy,
    axpy2: avx2::axpy2,
    dot: avx2::dot,
    colsum_f64: avx2::colsum_f64,
    clamp_sumsq: avx2::clamp_sumsq,
    shrink_clamp_sumsq: avx2::shrink_clamp_sumsq,
    sqnorm_f64: avx2::sqnorm_f64,
};

mod scalar {
    use super::Elem;

    pub fn axpy(a: Elem, x: &[Elem], y: &mut [Elem]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }

    pub fn axpy2(a0: Elem, x0: &[Elem], a1: Elem, x1: &[Elem], y: &mut [Elem]) {
        debug_assert_eq!(x0.len(), y.len());
        debug_assert_eq!(x1.len(), y.len());
        for ((yi, &u), &v) in y.iter_mut().zip(x0).zip(x1) {
            *yi += a0 * u + a1 * v;
        }
    }

    pub fn dot(x: &[Elem], y: &[Elem]) -> Elem {
        debug_assert_eq!(x.len(), y.len());
        let mut s = 0.0;
        for (&a, &b) in x.iter().zip(y) {
            s += a * b;
        }
        s
    }

    pub fn colsum_f64(x: &[Elem], s: &mut [f64]) {
        debug_assert_eq!(x.len(), s.len());
        for (si, &xi) in s.iter_mut().zip(x) {
            *si += xi as f64;
        }
    }

    pub fn clamp_sumsq(x: &mut [Elem], eps: Elem) -> f64 {
        let mut sumsq = 0.0f64;
        for d in x.iter_mut() {
            if *d < eps {
                *d = eps;
            }
            sumsq += *d as f64 * *d as f64;
        }
        sumsq
    }

    pub fn shrink_clamp_sumsq(x: &mut [Elem], l1: Elem, inv: Elem, eps: Elem) -> f64 {
        let mut sumsq = 0.0f64;
        for d in x.iter_mut() {
            let v = (*d - l1) * inv;
            *d = if v < eps { eps } else { v };
            sumsq += *d as f64 * *d as f64;
        }
        sumsq
    }

    pub fn sqnorm_f64(x: &[Elem]) -> f64 {
        let mut s = 0.0f64;
        for &a in x {
            s += a as f64 * a as f64;
        }
        s
    }
}

/// AVX2+FMA backend. Every public fn here is a safe wrapper whose inner
/// `#[target_feature]` body is only reachable through the [`AVX2`] table
/// — which [`Kernels::detected`] installs strictly after
/// `is_x86_feature_detected!("avx2") && ...("fma")` — so the required
/// CPU features are guaranteed present at call time.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::Elem;
    use std::arch::x86_64::*;

    const LANES: usize = 8;

    pub fn axpy(a: Elem, x: &[Elem], y: &mut [Elem]) {
        debug_assert_eq!(x.len(), y.len());
        // SAFETY: table installed only after AVX2+FMA detection.
        unsafe { axpy_body(a, x, y) }
    }

    /// Exact: separate mul + add matches the scalar `y += a·x` per
    /// element; the remainder tail runs the identical scalar op.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy_body(a: Elem, x: &[Elem], y: &mut [Elem]) {
        let n = y.len();
        let av = _mm256_set1_ps(a);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0;
        while i + LANES <= n {
            let xv = _mm256_loadu_ps(xp.add(i));
            let yv = _mm256_loadu_ps(yp.add(i));
            _mm256_storeu_ps(yp.add(i), _mm256_add_ps(yv, _mm256_mul_ps(av, xv)));
            i += LANES;
        }
        while i < n {
            *yp.add(i) += a * *xp.add(i);
            i += 1;
        }
    }

    pub fn axpy2(a0: Elem, x0: &[Elem], a1: Elem, x1: &[Elem], y: &mut [Elem]) {
        debug_assert_eq!(x0.len(), y.len());
        debug_assert_eq!(x1.len(), y.len());
        // SAFETY: table installed only after AVX2+FMA detection.
        unsafe { axpy2_body(a0, x0, a1, x1, y) }
    }

    /// Reassociated: two chained FMAs per element (the contraction LLVM
    /// never applied to the scalar source).
    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy2_body(a0: Elem, x0: &[Elem], a1: Elem, x1: &[Elem], y: &mut [Elem]) {
        let n = y.len();
        let a0v = _mm256_set1_ps(a0);
        let a1v = _mm256_set1_ps(a1);
        let p0 = x0.as_ptr();
        let p1 = x1.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0;
        while i + LANES <= n {
            let yv = _mm256_loadu_ps(yp.add(i));
            let t = _mm256_fmadd_ps(a1v, _mm256_loadu_ps(p1.add(i)), yv);
            let r = _mm256_fmadd_ps(a0v, _mm256_loadu_ps(p0.add(i)), t);
            _mm256_storeu_ps(yp.add(i), r);
            i += LANES;
        }
        while i < n {
            *yp.add(i) += a0 * *p0.add(i) + a1 * *p1.add(i);
            i += 1;
        }
    }

    pub fn dot(x: &[Elem], y: &[Elem]) -> Elem {
        debug_assert_eq!(x.len(), y.len());
        // SAFETY: table installed only after AVX2+FMA detection.
        unsafe { dot_body(x, y) }
    }

    /// Reassociated: two independent FMA accumulators + lane reduction.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_body(x: &[Elem], y: &[Elem]) -> Elem {
        let n = x.len();
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 2 * LANES <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(xp.add(i + LANES)),
                _mm256_loadu_ps(yp.add(i + LANES)),
                acc1,
            );
            i += 2 * LANES;
        }
        if i + LANES <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)), acc0);
            i += LANES;
        }
        let acc = _mm256_add_ps(acc0, acc1);
        // Horizontal sum: 8 → 4 → 2 → 1.
        let hi = _mm256_extractf128_ps(acc, 1);
        let lo = _mm256_castps256_ps128(acc);
        let q = _mm_add_ps(lo, hi);
        let d = _mm_add_ps(q, _mm_movehl_ps(q, q));
        let s1 = _mm_add_ss(d, _mm_shuffle_ps(d, d, 0b01));
        let mut s = _mm_cvtss_f32(s1);
        while i < n {
            s += *xp.add(i) * *yp.add(i);
            i += 1;
        }
        s
    }

    pub fn colsum_f64(x: &[Elem], s: &mut [f64]) {
        debug_assert_eq!(x.len(), s.len());
        // SAFETY: table installed only after AVX2+FMA detection.
        unsafe { colsum_f64_body(x, s) }
    }

    /// Exact: each `s[j] += x[j] as f64` is the same widen + add as the
    /// scalar loop — per-slot accumulators never reassociate.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn colsum_f64_body(x: &[Elem], s: &mut [f64]) {
        let n = x.len();
        let xp = x.as_ptr();
        let sp = s.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let xd = _mm256_cvtps_pd(_mm_loadu_ps(xp.add(i)));
            let sv = _mm256_loadu_pd(sp.add(i));
            _mm256_storeu_pd(sp.add(i), _mm256_add_pd(sv, xd));
            i += 4;
        }
        while i < n {
            *sp.add(i) += *xp.add(i) as f64;
            i += 1;
        }
    }

    pub fn clamp_sumsq(x: &mut [Elem], eps: Elem) -> f64 {
        // SAFETY: table installed only after AVX2+FMA detection.
        unsafe { clamp_sumsq_body(x, eps) }
    }

    /// Exact: the clamp vectorizes (`max(eps, d)` matches the scalar
    /// `if d < eps` branch for every input, NaN included — max returns
    /// the second operand on NaN); the f64 sum-of-squares then folds
    /// sequentially over the stored values, preserving the scalar's
    /// accumulation order bit-for-bit.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn clamp_sumsq_body(x: &mut [Elem], eps: Elem) -> f64 {
        let n = x.len();
        let ev = _mm256_set1_ps(eps);
        let xp = x.as_mut_ptr();
        let mut i = 0;
        while i + LANES <= n {
            let dv = _mm256_loadu_ps(xp.add(i));
            _mm256_storeu_ps(xp.add(i), _mm256_max_ps(ev, dv));
            i += LANES;
        }
        while i < n {
            if *xp.add(i) < eps {
                *xp.add(i) = eps;
            }
            i += 1;
        }
        let mut sumsq = 0.0f64;
        for &d in x.iter() {
            sumsq += d as f64 * d as f64;
        }
        sumsq
    }

    pub fn shrink_clamp_sumsq(x: &mut [Elem], l1: Elem, inv: Elem, eps: Elem) -> f64 {
        // SAFETY: table installed only after AVX2+FMA detection.
        unsafe { shrink_clamp_sumsq_body(x, l1, inv, eps) }
    }

    /// Exact: `(d − l1) · inv` as separate sub + mul (no FMA) matches
    /// the scalar expression per element; clamp and sum fold as in
    /// [`clamp_sumsq_body`].
    #[target_feature(enable = "avx2,fma")]
    unsafe fn shrink_clamp_sumsq_body(x: &mut [Elem], l1: Elem, inv: Elem, eps: Elem) -> f64 {
        let n = x.len();
        let l1v = _mm256_set1_ps(l1);
        let iv = _mm256_set1_ps(inv);
        let ev = _mm256_set1_ps(eps);
        let xp = x.as_mut_ptr();
        let mut i = 0;
        while i + LANES <= n {
            let dv = _mm256_loadu_ps(xp.add(i));
            let v = _mm256_mul_ps(_mm256_sub_ps(dv, l1v), iv);
            _mm256_storeu_ps(xp.add(i), _mm256_max_ps(ev, v));
            i += LANES;
        }
        while i < n {
            let v = (*xp.add(i) - l1) * inv;
            *xp.add(i) = if v < eps { eps } else { v };
            i += 1;
        }
        let mut sumsq = 0.0f64;
        for &d in x.iter() {
            sumsq += d as f64 * d as f64;
        }
        sumsq
    }

    pub fn sqnorm_f64(x: &[Elem]) -> f64 {
        // SAFETY: table installed only after AVX2+FMA detection.
        unsafe { sqnorm_f64_body(x) }
    }

    /// Reassociated: 4-lane f64 FMA accumulation + reduction.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn sqnorm_f64_body(x: &[Elem]) -> f64 {
        let n = x.len();
        let xp = x.as_ptr();
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i + 4 <= n {
            let xd = _mm256_cvtps_pd(_mm_loadu_ps(xp.add(i)));
            acc = _mm256_fmadd_pd(xd, xd, acc);
            i += 4;
        }
        let hi = _mm256_extractf128_pd(acc, 1);
        let lo = _mm256_castpd256_pd128(acc);
        let q = _mm_add_pd(lo, hi);
        let mut s = _mm_cvtsd_f64(_mm_add_sd(q, _mm_unpackhi_pd(q, q)));
        while i < n {
            let v = *xp.add(i) as f64;
            s += v * v;
            i += 1;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::EPS;

    /// Lengths chosen to hit the empty case, sub-lane sizes, exact lane
    /// multiples, and every remainder-tail residue.
    const LENS: &[usize] = &[0, 1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 100, 257];

    fn vecs(n: usize, seed: u64) -> (Vec<Elem>, Vec<Elem>) {
        let mut rng = Pcg32::seeded(seed);
        let a = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let b = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        (a, b)
    }

    fn simd() -> Option<&'static Kernels> {
        let k = Kernels::detected();
        (k.backend == Backend::Avx2Fma).then_some(k)
    }

    #[test]
    fn backend_names_are_stable() {
        assert_eq!(Backend::Scalar.name(), "scalar");
        assert_eq!(Backend::Avx2Fma.name(), "avx2+fma");
        assert!(["scalar", "avx2+fma"].contains(&Kernels::select().name()));
    }

    #[test]
    fn scalar_table_matches_legacy_vector_ops() {
        // The scalar backend must be the exact pre-refactor arithmetic.
        let (x, y0) = vecs(33, 1);
        let mut y1 = y0.clone();
        let mut y2 = y0.clone();
        (SCALAR.axpy)(0.37, &x, &mut y1);
        crate::linalg::vector::axpy(0.37, &x, &mut y2);
        assert_eq!(y1, y2);
        assert_eq!((SCALAR.dot)(&x, &y0), crate::linalg::vector::dot(&x, &y0));
        assert_eq!((SCALAR.sqnorm_f64)(&x), crate::linalg::vector::nrm2_sq(&x));
    }

    #[test]
    fn axpy_simd_is_bit_identical() {
        let Some(k) = simd() else { return };
        for &n in LENS {
            for (i, &a) in [0.0, -0.0, 1.0, -2.5, 0.125].iter().enumerate() {
                let (x, y0) = vecs(n, 100 + i as u64);
                let mut ys = y0.clone();
                let mut yv = y0.clone();
                (SCALAR.axpy)(a, &x, &mut ys);
                (k.axpy)(a, &x, &mut yv);
                assert_eq!(ys, yv, "axpy n={n} a={a}");
            }
        }
    }

    #[test]
    fn colsum_simd_is_bit_identical() {
        let Some(k) = simd() else { return };
        for &n in LENS {
            let (x, _) = vecs(n, 7);
            let mut ss: Vec<f64> = (0..n).map(|i| i as f64 * 0.1).collect();
            let mut sv = ss.clone();
            (SCALAR.colsum_f64)(&x, &mut ss);
            (k.colsum_f64)(&x, &mut sv);
            assert_eq!(ss, sv, "colsum n={n}");
        }
    }

    #[test]
    fn clamp_and_shrink_simd_are_bit_identical() {
        let Some(k) = simd() else { return };
        for &n in LENS {
            let (x, _) = vecs(n, 9);
            let mut xs = x.clone();
            let mut xv = x.clone();
            let ss = (SCALAR.clamp_sumsq)(&mut xs, EPS);
            let sv = (k.clamp_sumsq)(&mut xv, EPS);
            assert_eq!(xs, xv, "clamp values n={n}");
            assert_eq!(ss.to_bits(), sv.to_bits(), "clamp sumsq n={n}");

            let mut xs = x.clone();
            let mut xv = x.clone();
            let ss = (SCALAR.shrink_clamp_sumsq)(&mut xs, 0.05, 0.8, EPS);
            let sv = (k.shrink_clamp_sumsq)(&mut xv, 0.05, 0.8, EPS);
            assert_eq!(xs, xv, "shrink values n={n}");
            assert_eq!(ss.to_bits(), sv.to_bits(), "shrink sumsq n={n}");
        }
    }

    #[test]
    fn clamp_simd_preserves_scalar_nan_semantics() {
        let Some(k) = simd() else { return };
        let mut xs = vec![f32::NAN, -1.0, 0.5, f32::NAN, 2.0, -0.0, 0.0, 1e-20, 3.0];
        let mut xv = xs.clone();
        (SCALAR.clamp_sumsq)(&mut xs, EPS);
        (k.clamp_sumsq)(&mut xv, EPS);
        for (a, b) in xs.iter().zip(&xv) {
            assert_eq!(a.to_bits(), b.to_bits(), "NaN/zero handling diverged");
        }
    }

    #[test]
    fn dot_axpy2_sqnorm_within_reassociation_tolerance() {
        let Some(k) = simd() else { return };
        for &n in LENS {
            let (x, y) = vecs(n, 11);
            let ds = (SCALAR.dot)(&x, &y) as f64;
            let dv = (k.dot)(&x, &y) as f64;
            assert!(
                (ds - dv).abs() <= 2e-3 * ds.abs().max(1.0),
                "dot n={n}: {ds} vs {dv}"
            );

            let ns = (SCALAR.sqnorm_f64)(&x);
            let nv = (k.sqnorm_f64)(&x);
            assert!(
                (ns - nv).abs() <= 2e-3 * ns.max(1.0),
                "sqnorm n={n}: {ns} vs {nv}"
            );

            let (x1, y0) = vecs(n, 13);
            let mut ys = y0.clone();
            let mut yv = y0.clone();
            (SCALAR.axpy2)(0.7, &x, -1.3, &x1, &mut ys);
            (k.axpy2)(0.7, &x, -1.3, &x1, &mut yv);
            for (j, (a, b)) in ys.iter().zip(&yv).enumerate() {
                let d = (*a as f64 - *b as f64).abs();
                assert!(
                    d <= 2e-3 * (a.abs() as f64).max(1.0),
                    "axpy2 n={n} j={j}: {a} vs {b}"
                );
            }
        }
    }

    // NOTE: the `PLNMF_KERNELS=scalar` override itself is asserted in
    // `tests/golden_traces.rs` (its own process — lib unit tests run
    // concurrently in one process, so mutating the env here could flip
    // the backend under an unrelated test mid-comparison).
}

//! Experiment configuration: JSON config files + CLI overrides.
//!
//! Every run of the `plnmf` binary, every example, and every bench is
//! driven by a [`RunConfig`], so experiments are fully described by a
//! `configs/*.json` file (reproducibility) while remaining overridable
//! from the command line (exploration).

pub mod schema;
pub mod profiles;

pub use profiles::{dataset_profile, list_profiles, DatasetKind, DatasetProfile};
pub use schema::{EngineKind, RunConfig};

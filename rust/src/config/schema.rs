//! The run configuration schema.

use crate::nmf::spec::{EngineSpec, Init, Loss, Solver};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Which NMF engine to run.
///
/// The `*Xla` variants execute the AOT-compiled JAX/Pallas update graphs
/// through the PJRT runtime (`rust/src/runtime`) — the stand-in for the
/// paper's GPU implementations (see DESIGN.md §5). The native variants
/// are the CPU implementations compared in Figs. 7–9.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// PL-NMF: the paper's tiled three-phase FAST-HALS (Alg. 2).
    PlNmf,
    /// Naive FAST-HALS (Alg. 1) — the `planc-HALS-cpu` baseline.
    FastHals,
    /// Multiplicative updates — the `planc-MU-cpu` baseline.
    Mu,
    /// ANLS with block principal pivoting — the `planc-BPP-cpu` baseline.
    Bpp,
    /// MU under the Kullback–Leibler objective (extension; §2.1's other
    /// objective family).
    MuKl,
    /// PL-NMF lowered via JAX/Pallas → HLO → PJRT (`PL-NMF-accel`,
    /// standing in for PL-NMF-gpu).
    PlNmfXla,
    /// MU through the same PJRT path (standing in for bionmf-MU-gpu).
    MuXla,
}

impl EngineKind {
    pub fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "plnmf" | "pl-nmf" | "plnmf-cpu" => EngineKind::PlNmf,
            "fasthals" | "fast-hals" | "hals" | "planc-hals" | "fasthals-cpu" => {
                EngineKind::FastHals
            }
            "mu" | "planc-mu" | "mu-cpu" => EngineKind::Mu,
            "mu-kl" | "mukl" | "mu-kl-cpu" => EngineKind::MuKl,
            "bpp" | "anls-bpp" | "planc-bpp" | "bpp-cpu" => EngineKind::Bpp,
            "plnmf-xla" | "plnmf-accel" | "plnmf-gpu" => EngineKind::PlNmfXla,
            "mu-xla" | "mu-accel" | "bionmf-mu" | "mu-gpu" => EngineKind::MuXla,
            other => bail!("unknown engine '{other}'"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            EngineKind::PlNmf => "plnmf-cpu",
            EngineKind::FastHals => "fasthals-cpu",
            EngineKind::Mu => "mu-cpu",
            EngineKind::MuKl => "mu-kl-cpu",
            EngineKind::Bpp => "bpp-cpu",
            EngineKind::PlNmfXla => "plnmf-accel",
            EngineKind::MuXla => "mu-accel",
        }
    }

    /// All engines, in the order Figs. 7–9 list them (plus extensions).
    pub fn all() -> [EngineKind; 7] {
        [
            EngineKind::PlNmf,
            EngineKind::FastHals,
            EngineKind::Mu,
            EngineKind::Bpp,
            EngineKind::MuKl,
            EngineKind::PlNmfXla,
            EngineKind::MuXla,
        ]
    }

    pub fn is_xla(self) -> bool {
        matches!(self, EngineKind::PlNmfXla | EngineKind::MuXla)
    }
}

/// Every key (and alias) accepted by [`RunConfig::set`].
const KNOWN_KEYS: &[&str] = &[
    "dataset", "k", "tile", "t", "engine", "max_iters", "iters", "tol", "threads", "seed",
    "cache_bytes", "record_every", "artifacts_dir", "trace_path", "model_path", "model",
    "sweeps", "batch", "serve_tol", "serve_port", "models_manifest", "manifest", "warm_cache",
    "update_sweeps",
    "route_port", "worker_port_base", "restart_backoff_ms", "max_backoff_ms", "route_retries",
    "max_inflight", "train_workers", "sync_every", "grid", "loss", "alpha", "l1_ratio", "init",
];

/// Parse a `PRxPC` worker-grid spec (`2x2`, `1x4`; a bare `N` means the
/// 1D `1xN` plan).
fn parse_grid(s: &str) -> Result<(usize, usize)> {
    let bad = || anyhow!("bad grid '{s}': expected PRxPC like '2x2' (or a bare N for 1xN)");
    let (pr, pc) = match s.split_once(['x', 'X']) {
        Some((a, b)) => {
            (a.trim().parse::<usize>().map_err(|_| bad())?,
             b.trim().parse::<usize>().map_err(|_| bad())?)
        }
        None => (1, s.trim().parse::<usize>().map_err(|_| bad())?),
    };
    if pr == 0 || pc == 0 {
        bail!("grid axes must be >= 1, got {pr}x{pc}");
    }
    Ok((pr, pc))
}

/// Full description of one NMF run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Dataset profile name (see `config::profiles`).
    pub dataset: String,
    /// Low rank K.
    pub k: usize,
    /// Tile width T; 0 selects via the data-movement model (Eq. 11).
    pub tile: usize,
    pub engine: EngineKind,
    pub max_iters: usize,
    /// Stop when relative error improves by less than `tol` over a
    /// 5-iteration window (0 disables early stopping — paper-style fixed
    /// iteration counts).
    pub tol: f64,
    /// Worker threads; 0 = machine default.
    pub threads: usize,
    pub seed: u64,
    /// Cache size C in bytes for the tile-size model (default 35 MB, the
    /// paper's Xeon E5-2680 v4 LLC).
    pub cache_bytes: usize,
    /// Evaluate the relative objective every `record_every` iterations.
    pub record_every: usize,
    /// Directory with AOT artifacts (XLA engines only).
    pub artifacts_dir: String,
    /// Optional path to write the per-iteration trace as CSV.
    pub trace_path: Option<String>,
    /// Model file: `run` saves trained factors here; `transform` /
    /// `recommend` load from it (CLI alias: `--model`).
    pub model_path: Option<String>,
    /// Serving: HALS sweeps per projection micro-batch.
    pub sweeps: usize,
    /// Serving: queries per micro-batch.
    pub batch: usize,
    /// Serving: early-stop a micro-batch when a sweep's max entry
    /// change falls below this (0 = always run all sweeps). Distinct
    /// from `tol`, whose units are training rel-error improvement.
    pub serve_tol: f64,
    /// Daemon: TCP port for `plnmf serve` (0 = OS-assigned ephemeral).
    pub serve_port: usize,
    /// Daemon: path to a `plnmf-manifest` JSON naming the model fleet.
    pub models_manifest: Option<String>,
    /// Daemon: warm-start cache capacity per model, in cached query
    /// solutions (0 disables warm starts).
    pub warm_cache: usize,
    /// Daemon: default W-column HALS sweeps per online `update` batch
    /// (a request-level `"sweeps"` overrides it per call).
    pub update_sweeps: usize,
    /// Router: front TCP port for `plnmf route` (0 = OS-assigned).
    pub route_port: usize,
    /// Router: first worker port; the fleet takes `base`, `base+1`, …
    /// (0 = OS-assigned ports throughout; restarted workers always get
    /// a fresh OS-assigned port either way).
    pub worker_port_base: usize,
    /// Router: initial delay before restarting a crashed worker, in
    /// milliseconds (doubles while restarts keep failing, bounded by
    /// `max_backoff_ms`).
    pub restart_backoff_ms: usize,
    /// Router: ceiling on the doubling restart backoff, in milliseconds.
    /// A crash-looping worker settles at this retry cadence instead of
    /// backing off unboundedly (minutes between attempts would turn a
    /// transient crash into a long outage for train-dist epochs).
    pub max_backoff_ms: usize,
    /// Router: how many times an idempotent data op (`transform` /
    /// `recommend`) may be re-sent to a *different* replica after a
    /// failed forward, per request (0 = fail fast like non-idempotent
    /// ops).
    pub route_retries: usize,
    /// Router: per-replica in-flight request ceiling. When every live
    /// replica of a model is at the ceiling the router answers with the
    /// `busy` backpressure error (plus a `retry_after_ms` hint) instead
    /// of queuing unboundedly (0 = unlimited).
    pub max_inflight: usize,
    /// Distributed training: worker-process count for `plnmf
    /// train-dist` (clamped to the dataset's D — a shard must own at
    /// least one row).
    pub train_workers: usize,
    /// Distributed training: epochs between factor checkpoints (the
    /// coordinator pulls every worker's H panel and snapshots W). A
    /// worker death rolls the run back to the last checkpointed epoch,
    /// so smaller values cost bandwidth but lose less work per crash.
    pub sync_every: usize,
    /// Distributed training: the worker grid as `(pr, pc)` — pr W-row
    /// panels × pc H-row panels, `pr·pc` workers (CLI: `--grid 2x2`).
    /// `None` runs the 1D row-sharded plan over `train_workers`
    /// daemons; `(1, n)` is that plan bit-for-bit.
    pub grid: Option<(usize, usize)>,
    /// Reconstruction loss. `None` infers from the engine (mu-kl ⇒ KL,
    /// everything else ⇒ Frobenius); `Some(Kl)` with `engine = mu`
    /// promotes to the KL engine (see [`Self::effective_engine`]).
    pub loss: Option<Loss>,
    /// Elastic-net strength on H (0 = unregularized, the historical
    /// path, bit-for-bit).
    pub alpha: f64,
    /// L1 share of the penalty: 0 = ridge, 1 = lasso.
    pub l1_ratio: f64,
    /// Factor initialization (`random` | `nndsvd` | `nndsvda`).
    pub init: Init,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "20news-small".into(),
            k: 32,
            tile: 0,
            engine: EngineKind::PlNmf,
            max_iters: 100,
            tol: 0.0,
            threads: 0,
            seed: 42,
            cache_bytes: 35 * 1024 * 1024,
            record_every: 1,
            artifacts_dir: "artifacts".into(),
            trace_path: None,
            model_path: None,
            sweeps: 30,
            batch: 64,
            serve_tol: 0.0,
            serve_port: 7878,
            models_manifest: None,
            warm_cache: 256,
            update_sweeps: 20,
            route_port: 7900,
            worker_port_base: 0,
            restart_backoff_ms: 500,
            max_backoff_ms: 30_000,
            route_retries: 1,
            max_inflight: 32,
            train_workers: 2,
            sync_every: 4,
            grid: None,
            loss: None,
            alpha: 0.0,
            l1_ratio: 0.0,
            init: Init::Random,
        }
    }
}

impl RunConfig {
    /// Parse from a JSON object; unknown keys are rejected (typo safety).
    pub fn from_json(j: &Json) -> Result<Self> {
        let obj = j.as_obj().ok_or_else(|| anyhow!("config must be a JSON object"))?;
        let mut cfg = RunConfig::default();
        for (k, v) in obj {
            cfg.set(k, v).with_context(|| format!("config key '{k}'"))?;
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<Self> {
        let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let j = Json::parse(&src).with_context(|| format!("parsing {path}"))?;
        Self::from_json(&j)
    }

    /// Whether `key` names a [`RunConfig`] field (including aliases).
    /// Kept in sync with [`Self::set`]'s match arms (asserted by the
    /// `known_keys_match_set` test) so the CLI can distinguish "no such
    /// option" from "bad value for a real option".
    pub fn is_config_key(key: &str) -> bool {
        KNOWN_KEYS.contains(&key)
    }

    /// Apply one `key = value` override (shared by JSON and CLI paths).
    pub fn set(&mut self, key: &str, v: &Json) -> Result<()> {
        let need_usize =
            || v.as_usize().ok_or_else(|| anyhow!("expected non-negative integer, got {v}"));
        let need_str = || v.as_str().ok_or_else(|| anyhow!("expected string, got {v}"));
        match key {
            "dataset" => self.dataset = need_str()?.to_string(),
            "k" => self.k = need_usize()?,
            "tile" | "t" => self.tile = need_usize()?,
            "engine" => self.engine = EngineKind::from_str(need_str()?)?,
            "max_iters" | "iters" => self.max_iters = need_usize()?,
            "tol" => self.tol = v.as_f64().ok_or_else(|| anyhow!("expected number"))?,
            "threads" => self.threads = need_usize()?,
            "seed" => self.seed = v.as_u64().ok_or_else(|| anyhow!("expected integer"))?,
            "cache_bytes" => self.cache_bytes = need_usize()?,
            "record_every" => self.record_every = need_usize()?.max(1),
            "artifacts_dir" => self.artifacts_dir = need_str()?.to_string(),
            "trace_path" => {
                self.trace_path =
                    if v.is_null() { None } else { Some(need_str()?.to_string()) }
            }
            "model_path" | "model" => {
                self.model_path =
                    if v.is_null() { None } else { Some(need_str()?.to_string()) }
            }
            // No silent `.max(1)` clamps: a zero here is a config bug
            // the user should hear about, not a value to paper over.
            "sweeps" => match need_usize()? {
                0 => bail!("sweeps must be >= 1"),
                n => self.sweeps = n,
            },
            "batch" => match need_usize()? {
                0 => bail!("batch must be >= 1"),
                n => self.batch = n,
            },
            "serve_tol" => {
                self.serve_tol = v.as_f64().ok_or_else(|| anyhow!("expected number"))?
            }
            "serve_port" => match need_usize()? {
                p if p > u16::MAX as usize => {
                    bail!("serve_port must fit a TCP port (0..=65535), got {p}")
                }
                p => self.serve_port = p,
            },
            "models_manifest" | "manifest" => {
                self.models_manifest =
                    if v.is_null() { None } else { Some(need_str()?.to_string()) }
            }
            "warm_cache" => self.warm_cache = need_usize()?,
            // Zero sweeps would make `update` a silent no-op publish.
            "update_sweeps" => match need_usize()? {
                0 => bail!("update_sweeps must be >= 1"),
                n => self.update_sweeps = n,
            },
            "route_port" => match need_usize()? {
                p if p > u16::MAX as usize => {
                    bail!("route_port must fit a TCP port (0..=65535), got {p}")
                }
                p => self.route_port = p,
            },
            "worker_port_base" => match need_usize()? {
                p if p > u16::MAX as usize => {
                    bail!("worker_port_base must fit a TCP port (0..=65535), got {p}")
                }
                p => self.worker_port_base = p,
            },
            // Bounded-backoff restarts need a non-zero floor: a zero
            // here would turn a crash-looping worker into a hot loop.
            "restart_backoff_ms" => match need_usize()? {
                0 => bail!("restart_backoff_ms must be >= 1"),
                n => self.restart_backoff_ms = n,
            },
            // The cap shares the floor: a zero ceiling would clamp every
            // backoff to zero and hot-loop restarts.
            "max_backoff_ms" => match need_usize()? {
                0 => bail!("max_backoff_ms must be >= 1"),
                n => self.max_backoff_ms = n,
            },
            // 0 is meaningful for both: no retries / no ceiling.
            "route_retries" => self.route_retries = need_usize()?,
            "max_inflight" => self.max_inflight = need_usize()?,
            "train_workers" => match need_usize()? {
                0 => bail!("train_workers must be >= 1"),
                n => self.train_workers = n,
            },
            "sync_every" => match need_usize()? {
                0 => bail!("sync_every must be >= 1"),
                n => self.sync_every = n,
            },
            "grid" => {
                self.grid = if v.is_null() {
                    None
                } else if let Some(n) = v.as_usize() {
                    // `--grid 4`: the CLI type-infers a number; treat it
                    // as the 1D 1xN plan like the string form does.
                    Some(parse_grid(&n.to_string())?)
                } else {
                    Some(parse_grid(v.as_str().ok_or_else(|| {
                        anyhow!("expected a PRxPC grid like '2x2', got {v}")
                    })?)?)
                }
            }
            "loss" => {
                self.loss = if v.is_null() { None } else { Some(Loss::from_str(need_str()?)?) }
            }
            "alpha" => self.alpha = v.as_f64().ok_or_else(|| anyhow!("expected number"))?,
            "l1_ratio" => {
                self.l1_ratio = v.as_f64().ok_or_else(|| anyhow!("expected number"))?
            }
            "init" => self.init = Init::from_str(need_str()?)?,
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Set from a CLI-style string value, inferring the JSON type.
    pub fn set_str(&mut self, key: &str, value: &str) -> Result<()> {
        let j = if let Ok(n) = value.parse::<f64>() {
            Json::Num(n)
        } else if value == "true" || value == "false" {
            Json::Bool(value == "true")
        } else {
            Json::Str(value.to_string())
        };
        self.set(key, &j)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("dataset", Json::str(self.dataset.clone())),
            ("k", Json::num(self.k as f64)),
            ("tile", Json::num(self.tile as f64)),
            ("engine", Json::str(self.engine.name())),
            ("max_iters", Json::num(self.max_iters as f64)),
            ("tol", Json::num(self.tol)),
            ("threads", Json::num(self.threads as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("cache_bytes", Json::num(self.cache_bytes as f64)),
            ("record_every", Json::num(self.record_every as f64)),
            ("artifacts_dir", Json::str(self.artifacts_dir.clone())),
            ("sweeps", Json::num(self.sweeps as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("serve_tol", Json::num(self.serve_tol)),
            ("serve_port", Json::num(self.serve_port as f64)),
            ("warm_cache", Json::num(self.warm_cache as f64)),
            ("update_sweeps", Json::num(self.update_sweeps as f64)),
            ("route_port", Json::num(self.route_port as f64)),
            ("worker_port_base", Json::num(self.worker_port_base as f64)),
            ("restart_backoff_ms", Json::num(self.restart_backoff_ms as f64)),
            ("max_backoff_ms", Json::num(self.max_backoff_ms as f64)),
            ("route_retries", Json::num(self.route_retries as f64)),
            ("max_inflight", Json::num(self.max_inflight as f64)),
            ("train_workers", Json::num(self.train_workers as f64)),
            ("sync_every", Json::num(self.sync_every as f64)),
            ("alpha", Json::num(self.alpha)),
            ("l1_ratio", Json::num(self.l1_ratio)),
            ("init", Json::str(self.init.name())),
        ];
        if let Some((pr, pc)) = self.grid {
            pairs.push(("grid", Json::str(format!("{pr}x{pc}"))));
        }
        if let Some(l) = self.loss {
            pairs.push(("loss", Json::str(l.name())));
        }
        if let Some(m) = &self.model_path {
            pairs.push(("model_path", Json::str(m.clone())));
        }
        if let Some(m) = &self.models_manifest {
            pairs.push(("models_manifest", Json::str(m.clone())));
        }
        Json::obj(pairs)
    }

    /// The [`EngineSpec`] this config describes: the solver follows the
    /// engine, the loss is explicit or inferred (mu-kl ⇒ KL, everything
    /// else ⇒ Frobenius), and regularization/init carry over verbatim.
    /// Invalid combinations (e.g. `--loss kl` with a HALS engine) are
    /// loud errors here rather than asserts deep inside an engine.
    pub fn engine_spec(&self) -> Result<EngineSpec> {
        let solver = match self.engine {
            EngineKind::PlNmf | EngineKind::FastHals | EngineKind::PlNmfXla => Solver::Hals,
            EngineKind::Mu | EngineKind::MuKl | EngineKind::MuXla => Solver::Mu,
            EngineKind::Bpp => Solver::Bpp,
        };
        let loss = match self.loss {
            Some(l) => l,
            None if self.engine == EngineKind::MuKl => Loss::Kl,
            None => Loss::Frobenius,
        };
        let spec = EngineSpec {
            loss,
            solver,
            alpha: self.alpha,
            l1_ratio: self.l1_ratio,
            init: self.init,
        };
        spec.validate().with_context(|| {
            format!("engine '{}' with loss/alpha/l1_ratio/init", self.engine.name())
        })?;
        Ok(spec)
    }

    /// The engine that actually runs: `--engine mu --loss kl` promotes
    /// to the KL MU engine (one solver family, two losses — the sklearn
    /// `solver="mu", beta_loss=...` surface). All other combinations run
    /// the named engine as-is.
    pub fn effective_engine(&self) -> EngineKind {
        if self.engine == EngineKind::Mu && self.loss == Some(Loss::Kl) {
            EngineKind::MuKl
        } else {
            self.engine
        }
    }

    /// Sanity-check ranges that would otherwise fail deep inside engines.
    pub fn validate(&self) -> Result<()> {
        if self.k == 0 {
            bail!("k must be >= 1");
        }
        self.engine_spec()?;
        if self.tile > self.k {
            bail!("tile ({}) must be <= k ({})", self.tile, self.k);
        }
        if self.max_iters == 0 {
            bail!("max_iters must be >= 1");
        }
        if self.sweeps == 0 {
            bail!("sweeps must be >= 1");
        }
        if self.batch == 0 {
            bail!("batch must be >= 1");
        }
        if self.update_sweeps == 0 {
            bail!("update_sweeps must be >= 1");
        }
        if self.serve_port > u16::MAX as usize {
            bail!("serve_port must fit a TCP port (0..=65535)");
        }
        if self.route_port > u16::MAX as usize {
            bail!("route_port must fit a TCP port (0..=65535)");
        }
        if self.worker_port_base > u16::MAX as usize {
            bail!("worker_port_base must fit a TCP port (0..=65535)");
        }
        if self.restart_backoff_ms == 0 {
            bail!("restart_backoff_ms must be >= 1");
        }
        if self.max_backoff_ms == 0 {
            bail!("max_backoff_ms must be >= 1");
        }
        if self.train_workers == 0 {
            bail!("train_workers must be >= 1");
        }
        if self.sync_every == 0 {
            bail!("sync_every must be >= 1");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = RunConfig::default();
        cfg.k = 80;
        cfg.engine = EngineKind::Mu;
        cfg.dataset = "pie".into();
        let j = cfg.to_json();
        let re = RunConfig::from_json(&j).unwrap();
        assert_eq!(re.k, 80);
        assert_eq!(re.engine, EngineKind::Mu);
        assert_eq!(re.dataset, "pie");
    }

    #[test]
    fn unknown_key_rejected() {
        let j = Json::parse(r#"{"knob": 3}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn engine_aliases() {
        assert_eq!(EngineKind::from_str("planc-hals").unwrap(), EngineKind::FastHals);
        assert_eq!(EngineKind::from_str("PL-NMF").unwrap(), EngineKind::PlNmf);
        assert_eq!(EngineKind::from_str("bionmf-mu").unwrap(), EngineKind::MuXla);
        assert!(EngineKind::from_str("nope").is_err());
    }

    #[test]
    fn validate_catches_bad_tile() {
        let mut cfg = RunConfig::default();
        cfg.k = 8;
        cfg.tile = 9;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn integer_keys_reject_silent_coercions() {
        // Regression for the wire-coercion sweep: values that used to
        // wrap or truncate through a bare `as usize` must all be loud
        // errors, across every integer-typed config key.
        let mut cfg = RunConfig::default();
        for (key, bad) in [
            ("k", "-1"),
            ("k", "2.7"),
            ("k", "1e300"),
            ("threads", "-4"),
            ("seed", "-1"),
            ("seed", "1e300"),
            ("max_inflight", "18446744073709551616"), // 2^64
            ("route_retries", "0.5"),
        ] {
            let err = format!("{:#}", cfg.set_str(key, bad).unwrap_err());
            assert!(err.contains("expected"), "{key}={bad}: {err}");
        }
        assert_eq!(cfg.k, RunConfig::default().k, "failed sets must not alter the config");
        // Large-but-valid integers still parse exactly.
        cfg.set_str("seed", "1e18").unwrap();
        assert_eq!(cfg.seed, 1_000_000_000_000_000_000);
    }

    #[test]
    fn set_str_infers_types() {
        let mut cfg = RunConfig::default();
        cfg.set_str("k", "160").unwrap();
        cfg.set_str("dataset", "tdt2").unwrap();
        cfg.set_str("tol", "1e-4").unwrap();
        assert_eq!(cfg.k, 160);
        assert_eq!(cfg.dataset, "tdt2");
        assert!((cfg.tol - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn serving_keys_roundtrip() {
        let mut cfg = RunConfig::default();
        cfg.set_str("sweeps", "12").unwrap();
        cfg.set_str("batch", "256").unwrap();
        cfg.set_str("model", "models/a.json").unwrap();
        cfg.set_str("serve_tol", "1e-6").unwrap();
        assert_eq!(cfg.sweeps, 12);
        assert_eq!(cfg.batch, 256);
        assert_eq!(cfg.model_path.as_deref(), Some("models/a.json"));
        assert!((cfg.serve_tol - 1e-6).abs() < 1e-15);
        let re = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(re.sweeps, 12);
        assert_eq!(re.batch, 256);
        assert_eq!(re.model_path.as_deref(), Some("models/a.json"));
        // Degenerate serving knobs are rejected loudly, not clamped.
        assert!(cfg.set_str("sweeps", "0").is_err());
        assert!(cfg.set_str("batch", "0").is_err());
        assert_eq!(cfg.sweeps, 12, "failed set must not alter the config");
    }

    #[test]
    fn spec_keys_roundtrip_and_validate() {
        let cfg = RunConfig::default();
        // Defaults are the pre-spec pipeline.
        assert_eq!(cfg.loss, None);
        assert_eq!(cfg.engine_spec().unwrap(), EngineSpec::default());
        assert_eq!(cfg.effective_engine(), EngineKind::PlNmf);

        let mut cfg = cfg;
        cfg.set_str("loss", "kl").unwrap();
        cfg.set_str("engine", "mu").unwrap();
        cfg.set_str("alpha", "0.3").unwrap();
        cfg.set_str("l1_ratio", "0.5").unwrap();
        cfg.set_str("init", "nndsvda").unwrap();
        let spec = cfg.engine_spec().unwrap();
        assert_eq!(spec.loss, Loss::Kl);
        assert_eq!(spec.solver, Solver::Mu);
        assert_eq!(spec.init, Init::Nndsvda);
        assert!((spec.alpha - 0.3).abs() < 1e-12);
        // mu + kl promotes to the KL engine.
        assert_eq!(cfg.effective_engine(), EngineKind::MuKl);
        let re = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(re.loss, Some(Loss::Kl));
        assert_eq!(re.init, Init::Nndsvda);
        assert!((re.alpha - 0.3).abs() < 1e-12);
        assert!((re.l1_ratio - 0.5).abs() < 1e-12);
    }

    #[test]
    fn spec_inference_and_rejection() {
        // mu-kl with no explicit loss infers KL.
        let mut cfg = RunConfig::default();
        cfg.set_str("engine", "mu-kl").unwrap();
        assert_eq!(cfg.engine_spec().unwrap().loss, Loss::Kl);
        assert_eq!(cfg.effective_engine(), EngineKind::MuKl);
        // KL under a HALS engine is a loud config error, caught by
        // validate() before any engine is built.
        let mut cfg = RunConfig::default();
        cfg.set_str("loss", "kl").unwrap();
        assert!(cfg.engine_spec().is_err());
        assert!(cfg.validate().is_err());
        // Bad values are rejected at set / validate time.
        assert!(cfg.set_str("loss", "poisson").is_err());
        assert!(cfg.set_str("init", "zeros").is_err());
        cfg.set_str("loss", "frobenius").unwrap();
        cfg.set_str("alpha", "-1").unwrap();
        assert!(cfg.validate().is_err());
        cfg.set_str("alpha", "0.1").unwrap();
        cfg.set_str("l1_ratio", "1.5").unwrap();
        assert!(cfg.validate().is_err());
        cfg.set_str("l1_ratio", "1").unwrap();
        cfg.validate().unwrap();
    }

    #[test]
    fn known_keys_match_set() {
        // Every KNOWN_KEYS entry must reach a real `set` arm (its error,
        // if any, is about the value — never "unknown config key"), and
        // keys outside the list must be rejected as unknown.
        let mut cfg = RunConfig::default();
        for key in KNOWN_KEYS {
            assert!(RunConfig::is_config_key(key));
            if let Err(e) = cfg.set(key, &Json::Null) {
                let msg = format!("{e:#}");
                assert!(
                    !msg.contains("unknown config key"),
                    "'{key}' is listed in KNOWN_KEYS but set() does not know it"
                );
            }
        }
        assert!(!RunConfig::is_config_key("bogus"));
        let err = format!("{:#}", cfg.set("bogus", &Json::Null).unwrap_err());
        assert!(err.contains("unknown config key"), "{err}");
    }

    #[test]
    fn daemon_keys_roundtrip_and_validate() {
        let mut cfg = RunConfig::default();
        cfg.set_str("serve_port", "9090").unwrap();
        cfg.set_str("models_manifest", "models/manifest.json").unwrap();
        cfg.set_str("warm_cache", "512").unwrap();
        cfg.set_str("update_sweeps", "40").unwrap();
        assert_eq!(cfg.serve_port, 9090);
        assert_eq!(cfg.models_manifest.as_deref(), Some("models/manifest.json"));
        assert_eq!(cfg.warm_cache, 512);
        assert_eq!(cfg.update_sweeps, 40);
        let re = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(re.serve_port, 9090);
        assert_eq!(re.models_manifest.as_deref(), Some("models/manifest.json"));
        assert_eq!(re.warm_cache, 512);
        assert_eq!(re.update_sweeps, 40);
        // Zero update sweeps would be a silent no-op publish: rejected.
        assert!(cfg.set_str("update_sweeps", "0").is_err());
        assert_eq!(cfg.update_sweeps, 40, "failed set must not alter the config");
        // `manifest` is an accepted alias; ports must fit u16.
        cfg.set_str("manifest", "other.json").unwrap();
        assert_eq!(cfg.models_manifest.as_deref(), Some("other.json"));
        assert!(cfg.set_str("serve_port", "70000").is_err());
        // warm_cache 0 (disabled) is a valid setting.
        cfg.set_str("warm_cache", "0").unwrap();
        assert_eq!(cfg.warm_cache, 0);
    }

    #[test]
    fn router_keys_roundtrip_and_validate() {
        let mut cfg = RunConfig::default();
        cfg.set_str("route_port", "7901").unwrap();
        cfg.set_str("worker_port_base", "7910").unwrap();
        cfg.set_str("restart_backoff_ms", "250").unwrap();
        assert_eq!(cfg.route_port, 7901);
        assert_eq!(cfg.worker_port_base, 7910);
        assert_eq!(cfg.restart_backoff_ms, 250);
        let re = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(re.route_port, 7901);
        assert_eq!(re.worker_port_base, 7910);
        assert_eq!(re.restart_backoff_ms, 250);
        // Ports must fit u16; the restart backoff must be non-zero
        // (bounded backoff needs a floor), and 0 for either port field
        // means OS-assigned, which is valid.
        assert!(cfg.set_str("route_port", "70000").is_err());
        assert!(cfg.set_str("worker_port_base", "70000").is_err());
        assert!(cfg.set_str("restart_backoff_ms", "0").is_err());
        assert_eq!(cfg.restart_backoff_ms, 250, "failed set must not alter the config");
        cfg.set_str("route_port", "0").unwrap();
        cfg.set_str("worker_port_base", "0").unwrap();
        cfg.validate().unwrap();
    }

    #[test]
    fn training_and_backoff_keys_roundtrip_and_validate() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.train_workers, 2);
        assert_eq!(cfg.sync_every, 4);
        assert_eq!(cfg.max_backoff_ms, 30_000, "restart backoff capped at ~30s by default");
        let mut cfg = cfg;
        cfg.set_str("train_workers", "4").unwrap();
        cfg.set_str("sync_every", "2").unwrap();
        cfg.set_str("max_backoff_ms", "5000").unwrap();
        let re = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(re.train_workers, 4);
        assert_eq!(re.sync_every, 2);
        assert_eq!(re.max_backoff_ms, 5000);
        // All three have a >= 1 floor: zero workers is meaningless, a
        // zero sync interval would checkpoint nowhere, and a zero
        // backoff cap would clamp every restart delay to a hot loop.
        assert!(cfg.set_str("train_workers", "0").is_err());
        assert!(cfg.set_str("sync_every", "0").is_err());
        assert!(cfg.set_str("max_backoff_ms", "0").is_err());
        assert_eq!(cfg.train_workers, 4, "failed set must not alter the config");
        cfg.validate().unwrap();
    }

    #[test]
    fn grid_key_parses_roundtrips_and_rejects() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.grid, None, "no grid by default — the 1D plan");
        cfg.set_str("grid", "2x2").unwrap();
        assert_eq!(cfg.grid, Some((2, 2)));
        cfg.set_str("grid", "1X4").unwrap();
        assert_eq!(cfg.grid, Some((1, 4)));
        // A bare N is the 1D 1xN plan (the CLI type-infers it numeric).
        cfg.set_str("grid", "4").unwrap();
        assert_eq!(cfg.grid, Some((1, 4)));
        let re = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(re.grid, Some((1, 4)));
        // Null clears it (and keeps known_keys_match_set honest).
        cfg.set("grid", &Json::Null).unwrap();
        assert_eq!(cfg.grid, None);
        let re = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(re.grid, None, "unset grid stays off the JSON");
        for bad in ["0x2", "2x0", "2x", "x2", "axb", "2x2x2", "-1x2"] {
            let err = format!("{:#}", cfg.set_str("grid", bad).unwrap_err());
            assert!(err.contains("grid"), "{bad}: {err}");
        }
        assert_eq!(cfg.grid, None, "failed sets must not alter the config");
        cfg.validate().unwrap();
    }

    #[test]
    fn replication_keys_roundtrip_and_validate() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.route_retries, 1, "one retry on a different replica by default");
        assert_eq!(cfg.max_inflight, 32, "bounded in-flight by default, not unbounded queues");
        let mut cfg = cfg;
        cfg.set_str("route_retries", "3").unwrap();
        cfg.set_str("max_inflight", "8").unwrap();
        assert_eq!(cfg.route_retries, 3);
        assert_eq!(cfg.max_inflight, 8);
        let re = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(re.route_retries, 3);
        assert_eq!(re.max_inflight, 8);
        // 0 is meaningful for both (fail fast / unlimited), negative is not.
        cfg.set_str("route_retries", "0").unwrap();
        cfg.set_str("max_inflight", "0").unwrap();
        cfg.validate().unwrap();
        assert!(cfg.set_str("route_retries", "-1").is_err());
        assert!(cfg.set_str("max_inflight", "1.5").is_err());
    }
}

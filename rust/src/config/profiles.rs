//! Dataset profiles.
//!
//! The paper evaluates on five datasets (Table 4). The original files live
//! behind university URLs we cannot fetch offline, so each profile drives
//! a *synthetic generator* (`crate::data`) matched to the published
//! statistics: exact V, D and NNZ for the sparse text corpora, exact dense
//! dimensions for the image sets. The `-small` profiles are scaled-down
//! versions for tests/CI; `tiny` is for unit tests.
//!
//! `plnmf datasets` prints the realized statistics next to Table 4's
//! numbers (experiment E8).

use anyhow::{bail, Result};

/// Sparse (CSR bag-of-words) vs dense generator family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// Zipf-distributed synthetic bag-of-words (20news / tdt2 / reuters).
    SparseText,
    /// Smooth low-rank-plus-noise dense matrix (att / pie face images).
    DenseImage,
}

/// Generator parameters for one dataset.
#[derive(Debug, Clone)]
pub struct DatasetProfile {
    pub name: &'static str,
    pub kind: DatasetKind,
    /// Rows of A (vocabulary size for text; pixels or images per Table 4).
    pub v: usize,
    /// Columns of A (documents for text).
    pub d: usize,
    /// Target number of non-zeros (sparse kinds only; dense uses v*d).
    pub nnz: usize,
    /// Zipf exponent for the word marginal (text kinds).
    pub zipf_s: f64,
    /// Planted rank for the dense image generator (error curves then have
    /// meaningful decay, like real face datasets).
    pub planted_rank: usize,
    /// Table 4 row this profile reproduces, if any (paper V, D, NNZ).
    pub paper_stats: Option<(usize, usize, usize)>,
}

impl DatasetProfile {
    pub fn density(&self) -> f64 {
        self.nnz as f64 / (self.v as f64 * self.d as f64)
    }

    pub fn is_sparse(&self) -> bool {
        self.kind == DatasetKind::SparseText
    }
}

/// Look up a dataset profile by name.
pub fn dataset_profile(name: &str) -> Result<DatasetProfile> {
    let p = match name {
        // ---- paper-scale profiles (Table 4) --------------------------------
        "20news" => DatasetProfile {
            name: "20news",
            kind: DatasetKind::SparseText,
            v: 26_214,
            d: 11_314,
            nnz: 1_018_191,
            zipf_s: 1.07,
            planted_rank: 0,
            paper_stats: Some((26_214, 11_314, 1_018_191)),
        },
        "tdt2" => DatasetProfile {
            name: "tdt2",
            kind: DatasetKind::SparseText,
            v: 36_771,
            d: 10_212,
            nnz: 1_323_869,
            zipf_s: 1.07,
            planted_rank: 0,
            paper_stats: Some((36_771, 10_212, 1_323_869)),
        },
        "reuters" => DatasetProfile {
            name: "reuters",
            kind: DatasetKind::SparseText,
            v: 18_933,
            d: 8_293,
            nnz: 389_455,
            zipf_s: 1.12,
            planted_rank: 0,
            paper_stats: Some((18_933, 8_293, 389_455)),
        },
        "att" => DatasetProfile {
            name: "att",
            kind: DatasetKind::DenseImage,
            v: 400,
            d: 10_304, // 92 x 112 pixels
            nnz: 400 * 10_304,
            zipf_s: 0.0,
            planted_rank: 40,
            paper_stats: Some((400, 10_304, 4_121_478)),
        },
        "pie" => DatasetProfile {
            name: "pie",
            kind: DatasetKind::DenseImage,
            v: 11_554,
            d: 4_096, // 64 x 64 pixels
            nnz: 11_554 * 4_096,
            zipf_s: 0.0,
            planted_rank: 60,
            paper_stats: Some((11_554, 4_096, 47_321_408)),
        },
        // ---- scaled-down profiles for tests / CI ---------------------------
        "20news-small" => DatasetProfile {
            name: "20news-small",
            kind: DatasetKind::SparseText,
            v: 3_277,
            d: 1_414,
            nnz: 15_900,
            zipf_s: 1.07,
            planted_rank: 0,
            paper_stats: None,
        },
        "tdt2-small" => DatasetProfile {
            name: "tdt2-small",
            kind: DatasetKind::SparseText,
            v: 4_596,
            d: 1_276,
            nnz: 20_600,
            zipf_s: 1.07,
            planted_rank: 0,
            paper_stats: None,
        },
        "reuters-small" => DatasetProfile {
            name: "reuters-small",
            kind: DatasetKind::SparseText,
            v: 2_366,
            d: 1_036,
            nnz: 6_100,
            zipf_s: 1.12,
            planted_rank: 0,
            paper_stats: None,
        },
        "att-small" => DatasetProfile {
            name: "att-small",
            kind: DatasetKind::DenseImage,
            v: 100,
            d: 1_288,
            nnz: 100 * 1_288,
            zipf_s: 0.0,
            planted_rank: 12,
            paper_stats: None,
        },
        "pie-small" => DatasetProfile {
            name: "pie-small",
            kind: DatasetKind::DenseImage,
            v: 1_444,
            d: 512,
            nnz: 1_444 * 512,
            zipf_s: 0.0,
            planted_rank: 16,
            paper_stats: None,
        },
        // ---- unit-test profile ---------------------------------------------
        "tiny" => DatasetProfile {
            name: "tiny",
            kind: DatasetKind::DenseImage,
            v: 60,
            d: 40,
            nnz: 60 * 40,
            zipf_s: 0.0,
            planted_rank: 6,
            paper_stats: None,
        },
        "tiny-sparse" => DatasetProfile {
            name: "tiny-sparse",
            kind: DatasetKind::SparseText,
            v: 80,
            d: 50,
            nnz: 400,
            zipf_s: 1.1,
            planted_rank: 0,
            paper_stats: None,
        },
        other => bail!(
            "unknown dataset '{other}' (known: {})",
            list_profiles().join(", ")
        ),
    };
    Ok(p)
}

/// Names of all registered profiles.
pub fn list_profiles() -> Vec<&'static str> {
    vec![
        "20news", "tdt2", "reuters", "att", "pie", "20news-small", "tdt2-small",
        "reuters-small", "att-small", "pie-small", "tiny", "tiny-sparse",
    ]
}

/// The five paper datasets, in the order the figures list them.
pub fn paper_datasets() -> [&'static str; 5] {
    ["20news", "tdt2", "reuters", "att", "pie"]
}

/// The scaled-down counterparts, same order.
pub fn small_datasets() -> [&'static str; 5] {
    ["20news-small", "tdt2-small", "reuters-small", "att-small", "pie-small"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_profiles_resolve() {
        for name in list_profiles() {
            let p = dataset_profile(name).unwrap();
            assert_eq!(p.name, name);
            assert!(p.v > 0 && p.d > 0 && p.nnz > 0);
            assert!(p.nnz <= p.v * p.d);
        }
    }

    #[test]
    fn paper_stats_match_table4() {
        // Table 4 exact values.
        let cases = [
            ("20news", 26_214, 11_314, 1_018_191),
            ("tdt2", 36_771, 10_212, 1_323_869),
            ("reuters", 18_933, 8_293, 389_455),
            ("att", 400, 10_304, 4_121_478),
            ("pie", 11_554, 4_096, 47_321_408),
        ];
        for (name, v, d, nnz) in cases {
            let p = dataset_profile(name).unwrap();
            assert_eq!(p.paper_stats, Some((v, d, nnz)));
            assert_eq!(p.v, v);
            assert_eq!(p.d, d);
        }
    }

    #[test]
    fn sparse_text_density_matches_paper_sparsity() {
        // 20news sparsity 99.6567% occupied-complement => density ~0.34%.
        let p = dataset_profile("20news").unwrap();
        let sparsity = 100.0 * (1.0 - p.density());
        assert!((sparsity - 99.6567).abs() < 0.01, "sparsity {sparsity}");
    }

    #[test]
    fn unknown_rejected() {
        assert!(dataset_profile("nope").is_err());
    }
}

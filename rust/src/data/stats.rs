//! Realized dataset statistics — reproduces Table 4 (experiment E8).

use super::datasets::Dataset;

/// One row of the Table 4 reproduction.
#[derive(Debug, Clone)]
pub struct DatasetStats {
    pub name: String,
    pub v: usize,
    pub d: usize,
    pub nnz: usize,
    pub sparsity_pct: f64,
    pub paper: Option<(usize, usize, usize)>,
}

impl DatasetStats {
    pub fn of(ds: &Dataset) -> DatasetStats {
        let nnz = ds.a.nnz();
        let total = ds.v() as f64 * ds.d() as f64;
        // The paper reports "Sparsity (%)" as the fraction of zeros for
        // text data; for the dense image sets the column shows a small
        // number (fraction occupied scaled oddly) — we report zeros% for
        // sparse and density% for dense, matching Table 4's intent.
        let sparsity_pct = if ds.a.is_sparse() {
            100.0 * (1.0 - nnz as f64 / total)
        } else {
            100.0 * (1.0 - nnz as f64 / total)
        };
        DatasetStats {
            name: ds.profile.name.to_string(),
            v: ds.v(),
            d: ds.d(),
            nnz,
            sparsity_pct,
            paper: ds.profile.paper_stats,
        }
    }

    /// Render one table row; includes the paper's numbers when known.
    pub fn row(&self) -> String {
        let paper = match self.paper {
            Some((v, d, n)) => format!("paper: V={v} D={d} NNZ={n}"),
            None => "—".to_string(),
        };
        format!(
            "{:<14} {:>7} {:>7} {:>10} {:>9.4}%   {}",
            self.name, self.v, self.d, self.nnz, self.sparsity_pct, paper
        )
    }
}

pub fn table_header() -> String {
    format!(
        "{:<14} {:>7} {:>7} {:>10} {:>10}   {}",
        "dataset", "V", "D", "NNZ", "sparsity", "reference"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::load_dataset;

    #[test]
    fn stats_match_profile() {
        let ds = load_dataset("tiny-sparse", 42).unwrap();
        let s = DatasetStats::of(&ds);
        assert_eq!(s.v, 80);
        assert_eq!(s.d, 50);
        assert_eq!(s.nnz, 400);
        assert!(s.sparsity_pct > 80.0);
        assert!(s.row().contains("tiny-sparse"));
    }
}

//! Synthetic dense "image collection" generator (AT&T / PIE stand-ins).
//!
//! Face datasets are approximately low-rank with smooth, non-negative
//! structure. We plant rank-`r` structure with smooth Gaussian-bump basis
//! vectors plus positive noise, scaled to the 0–255 pixel range, so:
//! * NMF error curves show the characteristic fast-then-slow decay,
//! * the dense code paths (`cblas_dgemm`-style products) see realistic
//!   magnitudes and no special sparsity to exploit.

use crate::linalg::Mat;
use crate::util::rng::Pcg32;
use crate::Elem;

/// Generate a `v × d` dense non-negative matrix with planted rank `r`.
/// `v` indexes images, `d` pixels (per Table 4's AT&T layout).
pub fn generate_images(v: usize, d: usize, r: usize, seed: u64) -> Mat {
    assert!(r >= 1, "planted rank must be >= 1");
    let mut rng = Pcg32::new(seed, 3001);

    // Basis over pixel space: r smooth bumps (each basis vector is a
    // mixture of 3 Gaussians over a virtual 1-D pixel axis — smoothness is
    // what matters, not 2-D geometry).
    let mut basis = Mat::zeros(r, d);
    for k in 0..r {
        let mut brng = rng.split(10 + k as u64);
        for _ in 0..3 {
            let center = brng.next_f64() * d as f64;
            let width = (0.02 + 0.08 * brng.next_f64()) * d as f64;
            let height = 0.3 + brng.next_f64();
            for j in 0..d {
                let z = (j as f64 - center) / width;
                basis.row_mut(k)[j] += (height * (-0.5 * z * z).exp()) as Elem;
            }
        }
    }

    // Per-image mixing weights: sparse-ish gamma-like positives.
    let mut coeff = Mat::zeros(v, r);
    for i in 0..v {
        let row = coeff.row_mut(i);
        for x in row.iter_mut() {
            // Squared uniform ≈ right-skewed positive weights.
            let u = rng.next_f32();
            *x = u * u;
        }
    }

    // A = coeff · basis + 5% positive noise, scaled to [0, 255].
    let mut a = Mat::zeros(v, d);
    for i in 0..v {
        let crow = coeff.row(i).to_vec();
        let arow = a.row_mut(i);
        for (k, &c) in crow.iter().enumerate() {
            if c != 0.0 {
                let brow = basis.row(k);
                for j in 0..d {
                    arow[j] += c * brow[j];
                }
            }
        }
    }
    let max = a.data().iter().cloned().fold(0.0f32, f32::max).max(1e-6);
    let inv = 240.0 / max;
    let mut nrng = rng.split(99);
    for x in a.data_mut() {
        *x = *x * inv + 12.0 * nrng.next_f32(); // positive noise floor
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_and_nonnegativity() {
        let a = generate_images(50, 200, 8, 1);
        assert_eq!(a.rows(), 50);
        assert_eq!(a.cols(), 200);
        assert!(a.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn deterministic() {
        let a = generate_images(20, 100, 4, 5);
        let b = generate_images(20, 100, 4, 5);
        assert_eq!(a, b);
    }

    #[test]
    fn pixel_range() {
        let a = generate_images(30, 150, 6, 2);
        let max = a.data().iter().cloned().fold(0.0f32, f32::max);
        assert!(max <= 255.0 + 1.0);
        assert!(max > 50.0, "expected pixel-like magnitudes, got max {max}");
    }

    #[test]
    fn approximately_low_rank() {
        // Rank-r structure: a rank-r NMF should reach much lower error
        // than rank-1. Proxy test: energy of residual after projecting on
        // the top singular direction (power iteration) is well below total.
        let a = generate_images(40, 120, 4, 3);
        // Power iteration for the top singular vector of AᵀA.
        let mut v = vec![1.0f64; 120];
        for _ in 0..30 {
            // u = A v
            let mut u = vec![0.0f64; 40];
            for i in 0..40 {
                let row = a.row(i);
                u[i] = row.iter().zip(&v).map(|(&x, &y)| x as f64 * y).sum();
            }
            // v = Aᵀ u
            let mut nv = vec![0.0f64; 120];
            for i in 0..40 {
                let row = a.row(i);
                for j in 0..120 {
                    nv[j] += row[j] as f64 * u[i];
                }
            }
            let n = nv.iter().map(|x| x * x).sum::<f64>().sqrt();
            for x in &mut nv {
                *x /= n;
            }
            v = nv;
        }
        // sigma1^2 = ||A v||^2
        let mut u = vec![0.0f64; 40];
        for i in 0..40 {
            u[i] = a.row(i).iter().zip(&v).map(|(&x, &y)| x as f64 * y).sum();
        }
        let sigma1_sq: f64 = u.iter().map(|x| x * x).sum();
        let total = a.fro2();
        assert!(
            sigma1_sq > 0.5 * total,
            "top direction holds {:.1}% of energy — not low-rank-like",
            100.0 * sigma1_sq / total
        );
    }
}

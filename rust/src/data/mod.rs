//! Dataset handling: synthetic generators matched to the paper's Table 4
//! statistics, plus loading real matrices from MatrixMarket files.
//!
//! The paper's corpora (20 Newsgroups, TDT2, Reuters) and face datasets
//! (AT&T, PIE) sit behind URLs unreachable offline, so each profile drives
//! a generator that reproduces the characteristics the algorithms are
//! sensitive to: dimensions, nnz/sparsity, the Zipf rank-frequency decay
//! of bag-of-words data, and (for the dense sets) approximate low-rank
//! structure so error curves decay meaningfully. See DESIGN.md §5.

pub mod datasets;
pub mod text;
pub mod image;
pub mod stats;

pub use datasets::{load_dataset, load_matrix_market, DataMatrix, Dataset};

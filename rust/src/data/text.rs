//! Synthetic bag-of-words corpus with Zipf-distributed word marginals.
//!
//! Real document-term matrices have (i) a power-law word frequency
//! distribution, (ii) log-normal document lengths, and (iii) term
//! frequencies ≥ 1 with a heavy tail. All three shape NMF behaviour: the
//! Zipf head gives dense rows (load imbalance for SpMM — why our SpMM is
//! dynamically scheduled) and the tf values give non-trivial convergence
//! curves. The generator hits the profile's NNZ *exactly* by assigning
//! per-document distinct-term budgets with largest-remainder rounding.

use crate::sparse::Csr;
use crate::util::rng::Pcg32;
use crate::Elem;

/// Generate a `v × d` document-term matrix (rows = vocabulary) with
/// exactly `nnz` stored entries, Zipf exponent `s`.
pub fn generate_corpus(v: usize, d: usize, nnz: usize, s: f64, seed: u64) -> Csr {
    assert!(nnz >= d, "need at least one term per document");
    assert!(nnz <= v * d, "nnz exceeds capacity");
    let mut rng = Pcg32::new(seed, 1001);

    // --- per-document distinct-term budgets, summing exactly to nnz -----
    let lens = doc_lengths(d, nnz, v, &mut rng);

    // --- Zipf inverse-CDF table over the vocabulary ----------------------
    let cdf = zipf_cdf(v, s);

    // --- sample each document's terms ------------------------------------
    // Per-document RNG streams keep generation deterministic regardless of
    // any future parallelization of this loop.
    let mut triplets: Vec<(usize, usize, Elem)> = Vec::with_capacity(nnz);
    for (doc, &len) in lens.iter().enumerate() {
        let mut drng = Pcg32::new(seed ^ 0x9e3779b97f4a7c15, 2_000_000 + doc as u64);
        // Collect `len` distinct words; duplicates bump term frequency.
        let mut counts: std::collections::BTreeMap<usize, u32> = std::collections::BTreeMap::new();
        let mut guard = 0usize;
        while counts.len() < len {
            let w = zipf_sample(&cdf, &mut drng);
            *counts.entry(w).or_insert(0) += 1;
            guard += 1;
            if guard > 50 * len + 1000 {
                // Zipf head saturated (tiny vocabularies): fall back to
                // uniform tail sampling for the remainder.
                let mut w = drng.below(v as u32) as usize;
                while counts.contains_key(&w) {
                    w = (w + 1) % v;
                }
                counts.insert(w, 1);
            }
        }
        // tf-like weighting: log-scaled counts, as in standard tf encodings.
        for (w, c) in counts {
            let tf = 1.0 + (c as f32).ln();
            triplets.push((w, doc, tf));
        }
    }
    debug_assert_eq!(triplets.len(), nnz);
    Csr::from_triplets(v, d, triplets)
}

/// Log-normal per-document distinct-term budgets, clamped to `[1, v]`,
/// rescaled to sum exactly to `nnz` (largest remainder method).
fn doc_lengths(d: usize, nnz: usize, v: usize, rng: &mut Pcg32) -> Vec<usize> {
    let raw: Vec<f64> = (0..d).map(|_| rng.next_lognormal(0.0, 0.6)).collect();
    let sum: f64 = raw.iter().sum();
    let scale = nnz as f64 / sum;
    // Floor + remainders.
    let mut lens: Vec<usize> = Vec::with_capacity(d);
    let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(d);
    let mut total = 0usize;
    for (i, &x) in raw.iter().enumerate() {
        let t = (x * scale).max(1.0).min(v as f64);
        let fl = t.floor() as usize;
        lens.push(fl);
        total += fl;
        fracs.push((t - fl as f64, i));
    }
    // Distribute the remainder to the largest fractional parts.
    if total < nnz {
        let mut need = nnz - total;
        fracs.sort_by(|a, b| b.0.total_cmp(&a.0));
        let mut cursor = 0;
        while need > 0 {
            let (_, i) = fracs[cursor % fracs.len()];
            if lens[i] < v {
                lens[i] += 1;
                need -= 1;
            }
            cursor += 1;
            assert!(cursor < 100 * fracs.len() + 100, "cannot place nnz within v*d bounds");
        }
    } else if total > nnz {
        let mut excess = total - nnz;
        let mut cursor = 0;
        while excess > 0 {
            let i = cursor % d;
            if lens[i] > 1 {
                lens[i] -= 1;
                excess -= 1;
            }
            cursor += 1;
        }
    }
    debug_assert_eq!(lens.iter().sum::<usize>(), nnz);
    lens
}

/// Cumulative Zipf(s) weights over ranks `1..=v`, normalized to 1.
fn zipf_cdf(v: usize, s: f64) -> Vec<f64> {
    let mut cdf = Vec::with_capacity(v);
    let mut acc = 0.0;
    for r in 1..=v {
        acc += 1.0 / (r as f64).powf(s);
        cdf.push(acc);
    }
    let z = acc;
    for x in &mut cdf {
        *x /= z;
    }
    cdf
}

/// Inverse-CDF sample (binary search).
#[inline]
fn zipf_sample(cdf: &[f64], rng: &mut Pcg32) -> usize {
    let u = rng.next_f64();
    match cdf.binary_search_by(|p| p.total_cmp(&u)) {
        Ok(i) => i,
        Err(i) => i.min(cdf.len() - 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_nnz_and_dims() {
        let a = generate_corpus(500, 80, 2000, 1.07, 7);
        assert_eq!(a.rows(), 500);
        assert_eq!(a.cols(), 80);
        assert_eq!(a.nnz(), 2000);
    }

    #[test]
    fn deterministic() {
        let a = generate_corpus(200, 40, 800, 1.1, 3);
        let b = generate_corpus(200, 40, 800, 1.1, 3);
        assert_eq!(a, b);
        let c = generate_corpus(200, 40, 800, 1.1, 4);
        assert_ne!(a, c);
    }

    #[test]
    fn all_values_positive() {
        let a = generate_corpus(300, 50, 1500, 1.07, 11);
        let d = a.to_dense();
        assert!(d.data().iter().all(|&x| x >= 0.0));
        assert!(d.data().iter().any(|&x| x > 0.0));
    }

    #[test]
    fn zipf_head_is_heavier() {
        // Row (word) frequencies should be strongly rank-skewed: the top
        // 1% of words should hold far more than 1% of the nnz.
        let v = 1000;
        let a = generate_corpus(v, 200, 10_000, 1.07, 5);
        let mut row_nnz: Vec<usize> =
            (0..v).map(|i| a.row(i).0.len()).collect();
        row_nnz.sort_unstable_by(|x, y| y.cmp(x));
        let head: usize = row_nnz[..v / 100].iter().sum();
        assert!(
            head as f64 > 0.05 * 10_000.0,
            "top-1% words hold {head} nnz — not Zipf-like"
        );
    }

    #[test]
    fn every_document_nonempty() {
        let a = generate_corpus(100, 60, 300, 1.1, 9);
        let at = a.transposed();
        for dcol in 0..60 {
            assert!(!at.row(dcol).0.is_empty(), "document {dcol} empty");
        }
    }

    #[test]
    fn tiny_vocab_fallback_terminates() {
        // v small enough that the Zipf head saturates: fallback must fill.
        let a = generate_corpus(10, 5, 40, 1.5, 1);
        assert_eq!(a.nnz(), 40);
    }
}

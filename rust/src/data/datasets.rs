//! Dataset loading: profile → generated (or file-loaded) matrix pair.

use std::path::Path;

use anyhow::Result;

use crate::config::{dataset_profile, DatasetKind, DatasetProfile};
use crate::linalg::Mat;
use crate::sparse::mmio::{read_matrix_market, Loaded};
use crate::sparse::Csr;

use super::{image, text};

/// The input matrix in whichever storage the dataset calls for.
#[derive(Clone, Debug)]
pub enum DataMatrix {
    Sparse(Csr),
    Dense(Mat),
}

impl DataMatrix {
    pub fn rows(&self) -> usize {
        match self {
            DataMatrix::Sparse(a) => a.rows(),
            DataMatrix::Dense(a) => a.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            DataMatrix::Sparse(a) => a.cols(),
            DataMatrix::Dense(a) => a.cols(),
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            DataMatrix::Sparse(a) => a.nnz(),
            DataMatrix::Dense(a) => a.data().iter().filter(|&&x| x != 0.0).count(),
        }
    }

    pub fn fro2(&self) -> f64 {
        match self {
            DataMatrix::Sparse(a) => a.fro2(),
            DataMatrix::Dense(a) => a.fro2(),
        }
    }

    pub fn transposed(&self) -> DataMatrix {
        match self {
            DataMatrix::Sparse(a) => DataMatrix::Sparse(a.transposed()),
            DataMatrix::Dense(a) => DataMatrix::Dense(a.transposed()),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, DataMatrix::Sparse(_))
    }
}

/// A loaded dataset: the matrix, its transpose (both products `A·H` and
/// `Aᵀ·W` run row-parallel — planc keeps the same pair), and `‖A‖²_F`
/// (denominator of the Kim-et-al relative objective).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub profile: DatasetProfile,
    pub a: DataMatrix,
    pub at: DataMatrix,
    pub fro2: f64,
}

impl Dataset {
    pub fn v(&self) -> usize {
        self.a.rows()
    }

    pub fn d(&self) -> usize {
        self.a.cols()
    }
}

/// Generate (or regenerate — deterministic in `seed`) the dataset for a
/// named profile.
pub fn load_dataset(name: &str, seed: u64) -> Result<Dataset> {
    let profile = dataset_profile(name)?;
    let a = match profile.kind {
        DatasetKind::SparseText => DataMatrix::Sparse(text::generate_corpus(
            profile.v,
            profile.d,
            profile.nnz,
            profile.zipf_s,
            seed,
        )),
        DatasetKind::DenseImage => DataMatrix::Dense(image::generate_images(
            profile.v,
            profile.d,
            profile.planted_rank,
            seed,
        )),
    };
    let at = a.transposed();
    let fro2 = a.fro2();
    Ok(Dataset { profile, a, at, fro2 })
}

/// Load a dataset from a MatrixMarket file (real-data path for the
/// examples; profile fields are synthesized from the file).
pub fn load_matrix_market(path: &Path) -> Result<Dataset> {
    let a = match read_matrix_market(path)? {
        Loaded::Sparse(m) => DataMatrix::Sparse(m),
        Loaded::Dense(m) => DataMatrix::Dense(m),
    };
    let at = a.transposed();
    let fro2 = a.fro2();
    let profile = DatasetProfile {
        name: "file",
        kind: if a.is_sparse() { DatasetKind::SparseText } else { DatasetKind::DenseImage },
        v: a.rows(),
        d: a.cols(),
        nnz: a.nnz(),
        zipf_s: 0.0,
        planted_rank: 0,
        paper_stats: None,
    };
    Ok(Dataset { profile, a, at, fro2 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_profile_loads_with_exact_stats() {
        let ds = load_dataset("tiny-sparse", 42).unwrap();
        assert_eq!(ds.v(), 80);
        assert_eq!(ds.d(), 50);
        assert_eq!(ds.a.nnz(), 400);
        assert!(ds.a.is_sparse());
        assert_eq!(ds.at.rows(), 50);
        assert!((ds.fro2 - ds.at.fro2()).abs() < 1e-9);
    }

    #[test]
    fn dense_profile_loads() {
        let ds = load_dataset("tiny", 42).unwrap();
        assert_eq!(ds.v(), 60);
        assert_eq!(ds.d(), 40);
        assert!(!ds.a.is_sparse());
        assert!(ds.fro2 > 0.0);
    }

    #[test]
    fn seeds_change_content_not_stats() {
        let a = load_dataset("tiny-sparse", 1).unwrap();
        let b = load_dataset("tiny-sparse", 2).unwrap();
        assert_eq!(a.a.nnz(), b.a.nnz());
        assert_ne!(a.fro2, b.fro2);
    }

    #[test]
    fn transpose_is_consistent() {
        let ds = load_dataset("tiny-sparse", 7).unwrap();
        match (&ds.a, &ds.at) {
            (DataMatrix::Sparse(a), DataMatrix::Sparse(at)) => {
                assert_eq!(at.to_dense(), a.to_dense().transposed());
            }
            _ => panic!(),
        }
    }
}

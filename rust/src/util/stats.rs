//! Summary statistics for the benchmark harness (criterion is unavailable
//! offline, so the harness computes its own robust estimators).

/// Robust summary of a sample of measurements (seconds or any unit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    /// Median absolute deviation scaled to be consistent with σ for
    /// normal data (×1.4826).
    pub mad: f64,
    pub p05: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        // total_cmp, not partial_cmp().unwrap(): one NaN measurement (a
        // failed timer read, a 0/0 rate) must not panic the whole bench
        // harness mid-run. NaNs sort to the end and show up loudly in
        // `max`/`mean` instead.
        sorted.sort_by(f64::total_cmp);
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let median = percentile_sorted(&sorted, 50.0);
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(f64::total_cmp);
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
            mad: percentile_sorted(&devs, 50.0) * 1.4826,
            p05: percentile_sorted(&sorted, 5.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for cross-dataset speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.mad, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_speedups() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn median_is_robust_to_outlier() {
        let s = Summary::of(&[1.0, 1.0, 1.0, 1.0, 100.0]);
        assert_eq!(s.median, 1.0);
        assert!(s.mean > 10.0);
    }

    #[test]
    fn nan_sample_does_not_panic() {
        // Regression: partial_cmp().unwrap() used to abort the whole
        // bench harness on a single NaN measurement. total_cmp sorts
        // NaNs after every real number, so the robust estimators stay
        // meaningful and the contamination is visible in max/mean.
        let s = Summary::of(&[1.0, 2.0, f64::NAN, 3.0, 4.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan(), "NaN sorts last and surfaces as max");
        assert_eq!(s.median, 3.0);
        assert!(s.mean.is_nan());
        // All-NaN input is degenerate but must still not panic.
        let s = Summary::of(&[f64::NAN, f64::NAN]);
        assert!(s.median.is_nan());
    }
}

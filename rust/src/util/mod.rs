//! Small self-contained utilities (offline build: no external crates).

pub mod rng;
pub mod json;
pub mod timer;
pub mod stats;
pub mod logging;

pub use rng::Pcg32;
pub use timer::{PhaseTimers, Timer};

//! Leveled stderr logging controlled by `PLNMF_LOG` (error|warn|info|debug|trace).
//!
//! A deliberate micro-substrate: the `log` facade exists in the vendor set
//! but a backend does not, and the coordinator wants timestamps relative to
//! process start for readable phase traces.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Initialize from the `PLNMF_LOG` environment variable. Idempotent.
pub fn init_from_env() {
    EPOCH.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("PLNMF_LOG") {
        if let Some(l) = Level::from_str(&v) {
            set_level(l);
        }
    }
}

pub fn set_level(l: Level) {
    EPOCH.get_or_init(Instant::now);
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let t = EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64();
        eprintln!("[{:>9.3}s {:5}] {}", t, l.name(), args);
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("info"), Some(Level::Info));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}

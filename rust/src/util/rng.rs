//! Deterministic pseudo-random number generation.
//!
//! The `rand` crate is unavailable offline, so we carry a small PCG32
//! (O'Neill 2014, `pcg32_xsh_rr`) — statistically solid, 8 bytes of state,
//! and trivially reproducible across platforms. All experiment seeds in
//! this repo flow through this type so every figure is regenerable
//! bit-for-bit.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor (stream 54, a fixed default).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    /// Derive an independent child generator (used to give each worker /
    /// dataset shard its own stream without correlation).
    pub fn split(&mut self, stream: u64) -> Self {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Self::new(seed, stream.wrapping_mul(2654435761).wrapping_add(1))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 24 bits of mantissa entropy.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with 53 bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire's multiply-shift rejection).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let l = m as u32;
            if l >= bound || l >= (u32::MAX - bound + 1) % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller (one value per call, cheap enough).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-12 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Log-normal with parameters of the underlying normal.
    pub fn next_lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.next_gaussian()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // For small k relative to n use a hash-free rejection via sort;
        // for simplicity and determinism do a full index shuffle when the
        // ratio is large, otherwise Floyd's algorithm.
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx.sort_unstable();
            idx
        } else {
            let mut chosen = std::collections::BTreeSet::new();
            for j in (n - k)..n {
                let t = self.below(j as u32 + 1) as usize;
                if !chosen.insert(t) {
                    chosen.insert(j);
                }
            }
            chosen.into_iter().collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_constructions() {
        let mut a = Pcg32::new(42, 54);
        let mut b = Pcg32::new(42, 54);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_bounds_and_roughly_uniform() {
        let mut rng = Pcg32::seeded(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c} out of range");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.next_gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg32::seeded(3);
        let mut xs: Vec<usize> = (0..257).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..257).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut rng = Pcg32::seeded(5);
        for &(n, k) in &[(100, 5), (100, 60), (10, 10), (1, 1), (1000, 1)] {
            let idx = rng.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            assert!(idx.windows(2).all(|w| w[0] < w[1]));
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn split_generators_are_independent() {
        let mut root = Pcg32::seeded(1);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }
}

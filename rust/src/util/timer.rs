//! Wall-clock timing helpers used by the engines (Table 5 breakdown) and
//! the bench harness.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Named accumulating phase timers — this is how the Table 5 breakdown
/// (SpMM / DMM / DMV vs Phase 1 / Phase 2&3) is collected without
/// perturbing the hot loop: `accumulate` is two `Instant::now()` calls
/// around a whole phase, not per-element instrumentation.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimers {
    acc: BTreeMap<&'static str, Duration>,
    counts: BTreeMap<&'static str, u64>,
}

impl PhaseTimers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and accumulate under `name`.
    #[inline]
    pub fn time<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(name, t.elapsed());
        out
    }

    pub fn add(&mut self, name: &'static str, d: Duration) {
        *self.acc.entry(name).or_default() += d;
        *self.counts.entry(name).or_default() += 1;
    }

    pub fn merge(&mut self, other: &PhaseTimers) {
        for (k, v) in &other.acc {
            *self.acc.entry(k).or_default() += *v;
        }
        for (k, c) in &other.counts {
            *self.counts.entry(k).or_default() += *c;
        }
    }

    pub fn secs(&self, name: &str) -> f64 {
        self.acc.get(name).map(|d| d.as_secs_f64()).unwrap_or(0.0)
    }

    pub fn count(&self, name: &str) -> u64 {
        self.counts.get(name).copied().unwrap_or(0)
    }

    pub fn names(&self) -> impl Iterator<Item = &'static str> + '_ {
        self.acc.keys().copied()
    }

    pub fn reset(&mut self) {
        self.acc.clear();
        self.counts.clear();
    }

    pub fn total_secs(&self) -> f64 {
        self.acc.values().map(|d| d.as_secs_f64()).sum()
    }

    /// Render a two-column breakdown table (seconds).
    pub fn table(&self) -> String {
        let mut out = String::new();
        let width = self.acc.keys().map(|k| k.len()).max().unwrap_or(8).max(8);
        for (k, v) in &self.acc {
            out.push_str(&format!(
                "{:width$}  {:>10.4} s  (x{})\n",
                k,
                v.as_secs_f64(),
                self.counts[k],
                width = width
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_counts() {
        let mut t = PhaseTimers::new();
        let v = t.time("phase1", || 42);
        assert_eq!(v, 42);
        t.time("phase1", || ());
        t.time("phase2", || ());
        assert_eq!(t.count("phase1"), 2);
        assert_eq!(t.count("phase2"), 1);
        assert!(t.secs("phase1") >= 0.0);
        assert_eq!(t.count("missing"), 0);
        assert_eq!(t.secs("missing"), 0.0);
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimers::new();
        let mut b = PhaseTimers::new();
        a.add("x", Duration::from_millis(10));
        b.add("x", Duration::from_millis(5));
        b.add("y", Duration::from_millis(1));
        a.merge(&b);
        assert!((a.secs("x") - 0.015).abs() < 1e-9);
        assert_eq!(a.count("x"), 2);
        assert_eq!(a.count("y"), 1);
    }

    #[test]
    fn table_renders_all_rows() {
        let mut t = PhaseTimers::new();
        t.add("spmm", Duration::from_millis(2));
        t.add("dmm", Duration::from_millis(1));
        let table = t.table();
        assert!(table.contains("spmm"));
        assert!(table.contains("dmm"));
    }
}

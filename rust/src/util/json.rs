//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Used for experiment configs (`configs/*.json`), the AOT artifact
//! manifest (`artifacts/manifest.json`) written by `python/compile/aot.py`,
//! and result records written by the bench harness. Supports the full JSON
//! grammar except `\u` surrogate pairs beyond the BMP (not needed here —
//! the manifest and configs are ASCII).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in sorted order (BTreeMap) so that
/// serialization is deterministic — results files diff cleanly.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let (v, consumed) = Self::parse_prefix(src)?;
        let mut p = Parser { src: src.as_bytes(), pos: consumed };
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Streaming parse: the **first** JSON value in `src`, plus the
    /// number of bytes consumed. Trailing content is left to the caller
    /// — this is what lets the serving daemon parse one value out of a
    /// protocol line without first splitting or copying it.
    pub fn parse_prefix(src: &str) -> Result<(Json, usize), JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        Ok((v, p.pos))
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer coercion with **no silent wrap, truncation, or
    /// saturation**: `None` for negatives, fractions, non-finite
    /// values, and anything at or above 2^N (note `usize::MAX as f64`
    /// rounds UP to 2^64, so the comparison must be strict — `x <=
    /// MAX` would accept exactly 2^64 and saturate it to `MAX`). Every
    /// f64 that passes converts exactly.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x < usize::MAX as f64 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    /// See [`Self::as_usize`] — same strictness, u64 range.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x < u64::MAX as f64 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` lookup returning Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// `obj[key]` as a strict optional count — the shared
    /// "strict-when-present" shape of the silent-coercion sweep: a
    /// missing key (or non-object) yields `default`, while a present
    /// value that is not a clean non-negative integer (negative,
    /// fractional, non-finite, overflowing — see [`Self::as_usize`]) is
    /// an error naming the key, never silently the default.
    pub fn get_usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            Json::Null => Ok(default),
            v => v
                .as_usize()
                .ok_or_else(|| format!("\"{key}\" must be a non-negative integer, got {v}")),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- builders -----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(xs: Vec<Json>) -> Json {
        Json::Arr(xs)
    }

    /// Pretty-print with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}' in object"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']' in array"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        if self.pos + 4 > self.src.len() {
                            return Err(self.err("truncated \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.src[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode a UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid UTF-8")),
                    };
                    if start + width > self.src.len() {
                        return Err(self.err("truncated UTF-8"));
                    }
                    let chunk = std::str::from_utf8(&self.src[start..start + width])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    s.push_str(chunk);
                    self.pos = start + width;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, false, null], "c": {"d": "x\ny"}, "e": -2.5e3}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").as_usize(), Some(1));
        assert_eq!(v.get("e").as_f64(), Some(-2500.0));
        assert_eq!(v.get("c").get("d").as_str(), Some("x\ny"));
        assert_eq!(v.get("b").as_arr().unwrap().len(), 3);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::obj(vec![
            ("name", Json::str("fig6")),
            ("ks", Json::arr(vec![Json::num(80.0), Json::num(160.0)])),
            ("nested", Json::obj(vec![("x", Json::Bool(true))])),
        ]);
        let re = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1.2.3", "\"abc", "{\"a\":1} x"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — ≤\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ≤"));
    }

    #[test]
    fn parse_prefix_streams_one_value() {
        let src = r#"  {"op": "ping"} {"op": "next"}"#;
        let (v, consumed) = Json::parse_prefix(src).unwrap();
        assert_eq!(v.get("op").as_str(), Some("ping"));
        assert_eq!(&src[consumed..], r#" {"op": "next"}"#);
        // The second value parses from the remainder.
        let (v2, _) = Json::parse_prefix(&src[consumed..]).unwrap();
        assert_eq!(v2.get("op").as_str(), Some("next"));
        // Scalars and arrays stream too.
        let (n, c) = Json::parse_prefix("42, tail").unwrap();
        assert_eq!(n.as_f64(), Some(42.0));
        assert_eq!(c, 2);
        assert!(Json::parse_prefix("   ").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.5).to_string(), "3.5");
    }

    #[test]
    fn integer_coercions_reject_negative_fractional_and_overflowing() {
        // Regression for the silent-coercion class: -1, 2.7, 1e300, and
        // 2^64 must all be None — never wrapped, truncated, or
        // saturated into a "valid" count.
        for bad in ["-1", "2.7", "1e300", "18446744073709551616", "-0.5"] {
            let v = Json::parse(bad).unwrap();
            assert_eq!(v.as_usize(), None, "as_usize({bad})");
            assert_eq!(v.as_u64(), None, "as_u64({bad})");
        }
        assert_eq!(Json::Num(f64::NAN).as_u64(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_usize(), None);
        // Non-numbers never coerce.
        assert_eq!(Json::parse("\"5\"").unwrap().as_usize(), None);
        assert_eq!(Json::parse("true").unwrap().as_u64(), None);
        // In-range integers convert exactly, including large ones.
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
        assert_eq!(Json::parse("-0.0").unwrap().as_usize(), Some(0));
        assert_eq!(Json::parse("1e18").unwrap().as_u64(), Some(1_000_000_000_000_000_000));
        assert_eq!(
            Json::parse("9007199254740992").unwrap().as_u64(), // 2^53
            Some(9_007_199_254_740_992)
        );
    }

    #[test]
    fn get_usize_or_is_strict_when_present() {
        let v = Json::parse(r#"{"top": 5, "bad": -1}"#).unwrap();
        assert_eq!(v.get_usize_or("top", 10), Ok(5));
        assert_eq!(v.get_usize_or("absent", 10), Ok(10), "missing key takes the default");
        let err = v.get_usize_or("bad", 10).unwrap_err();
        assert!(err.contains("bad"), "{err}");
        // Non-objects behave like all-missing (the `get` contract).
        assert_eq!(Json::parse("[1]").unwrap().get_usize_or("top", 3), Ok(3));
    }
}

//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation (§5–§6), plus the CLI that fronts the whole system.
//!
//! | experiment | paper artifact | module | bench target |
//! |------------|----------------|--------|--------------|
//! | E1 | Fig. 6 tile-size sweep        | [`fig6`]   | `cargo bench --bench fig6_tile_size` |
//! | E2 | Fig. 7 error vs time          | [`fig7`]   | `cargo bench --bench fig7_time_to_error` |
//! | E3 | Fig. 8 error vs iterations    | [`fig8`]   | `cargo bench --bench fig8_convergence` |
//! | E4 | Fig. 9 speedup @ matched err  | [`fig9`]   | `cargo bench --bench fig9_speedup` |
//! | E5 | Table 5 W-update breakdown    | [`table5`] | `cargo bench --bench table5_breakdown` |
//! | E6 | §5 cost-model numbers         | [`model_report`] | unit tests + `plnmf model` |
//! | E7 | §6.3.2 per-iter speedup       | [`fig7`] (`--per-iter`) | same bench |
//! | E8 | Table 4 dataset statistics    | `plnmf datasets` | — |
//!
//! Every run defaults to the scaled-down `-small` profiles so `cargo
//! bench` completes in minutes; pass `--scale paper` (or env
//! `PLNMF_SCALE=paper`) for the full Table 4 sizes.

pub mod harness;
pub mod report;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod table5;

use anyhow::bail;

use crate::cli::Args;
use crate::config::{profiles, EngineKind, RunConfig};
use crate::coordinator::{metrics, Driver};
use crate::data::stats::{table_header, DatasetStats};
use crate::nmf::cost_model;
use crate::Result;

/// Benchmark scale: which dataset profiles a bench touches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// `-small` profiles, reduced K — CI-friendly (default).
    Small,
    /// Full Table 4 datasets at the paper's K values.
    Paper,
}

impl Scale {
    pub fn from_args(args: &Args) -> Scale {
        let v = args
            .opt("scale")
            .map(str::to_string)
            .or_else(|| std::env::var("PLNMF_SCALE").ok())
            .unwrap_or_default();
        if v.eq_ignore_ascii_case("paper") {
            Scale::Paper
        } else {
            Scale::Small
        }
    }

    pub fn datasets(self) -> [&'static str; 5] {
        match self {
            Scale::Small => profiles::small_datasets(),
            Scale::Paper => profiles::paper_datasets(),
        }
    }

    /// The K sweep (Figs. 6/7 use 80/160/240 at paper scale).
    pub fn ks(self) -> Vec<usize> {
        match self {
            Scale::Small => vec![16, 32],
            Scale::Paper => vec![80, 160, 240],
        }
    }

    /// The single operating point of Figs. 8/9 (K = 240, T = 15).
    pub fn k_single(self) -> usize {
        match self {
            Scale::Small => 32,
            Scale::Paper => 240,
        }
    }

    pub fn iters(self) -> usize {
        match self {
            Scale::Small => 30,
            Scale::Paper => 100,
        }
    }
}

/// CLI dispatch (shared by the `plnmf` binary and the examples).
pub fn cli_main(args: Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("compare") => cmd_compare(&args),
        Some("datasets") => cmd_datasets(&args),
        Some("model") => cmd_model(&args),
        Some("bench") => cmd_bench(&args),
        Some("help") | None => {
            print!("{}", HELP);
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}' (see `plnmf help`)"),
    }
}

const HELP: &str = "\
plnmf — Parallel Locality-Optimized NMF (paper reproduction)

USAGE: plnmf <command> [--key value ...]

COMMANDS:
  run        run one engine: --dataset --k --engine --iters --tile --threads
             --seed --trace_path out.csv [--config file.json]
  compare    run several engines from one init: --engines a,b,c (default all
             native), same options as run; writes results/compare_*.csv
  datasets   print Table-4 statistics of every dataset profile (E8)
  model      print the §5 data-movement model report (E6): --k or positional
             K values, --dataset for V, --cache_bytes
  bench      regenerate paper artifacts: bench <fig6|fig7|fig8|fig9|table5|all>
             [--scale small|paper] [--out-dir results]
  help       this text

Engines: plnmf | fasthals | mu | bpp | mu-kl | plnmf-xla | mu-xla
Dataset profiles: 20news tdt2 reuters att pie (+-small variants, tiny)
";

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = args.to_run_config()?;
    let mut driver = Driver::from_config(&cfg)?;
    let report = driver.run()?;
    print!("{}", metrics::summary_table(std::slice::from_ref(&report)));
    println!("\nphase breakdown:\n{}", report.timers.table());
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let cfg = args.to_run_config()?;
    let engines: Vec<EngineKind> = match args.opt("engines") {
        Some(list) => list
            .split(',')
            .map(|s| EngineKind::from_str(s.trim()))
            .collect::<Result<Vec<_>>>()?,
        None => vec![EngineKind::PlNmf, EngineKind::FastHals, EngineKind::Mu, EngineKind::Bpp],
    };
    let cmp = crate::coordinator::comparison::run_comparison(&cfg, &engines)?;
    print!("{}", metrics::summary_table(&cmp.reports));
    for (kind, why) in &cmp.skipped {
        println!("skipped {}: {}", kind.name(), why);
    }
    let out = report::results_dir(args).join(format!("compare_{}_k{}.csv", cfg.dataset, cfg.k));
    metrics::write_comparison_csv(&out, &cmp.reports)?;
    println!("\ntrace CSV: {}", out.display());
    Ok(())
}

fn cmd_datasets(args: &Args) -> Result<()> {
    let scale = Scale::from_args(args);
    println!("{}", table_header());
    for name in scale.datasets() {
        let ds = crate::data::load_dataset(name, 42)?;
        println!("{}", DatasetStats::of(&ds).row());
    }
    Ok(())
}

fn cmd_model(args: &Args) -> Result<()> {
    let cfg = args.to_run_config()?;
    let ks: Vec<usize> = if args.positional.is_empty() {
        vec![80, 160, 240]
    } else {
        args.positional.iter().map(|s| s.parse().unwrap_or(0)).filter(|&k| k > 0).collect()
    };
    // §5 uses V = 11,314 for the 20NG worked example.
    let v = crate::config::dataset_profile(&cfg.dataset).map(|p| p.d).unwrap_or(11_314);
    println!("data-movement model (V={v}, C={} bytes):", cfg.cache_bytes);
    println!(
        "{:>5} {:>8} {:>6} {:>16} {:>16} {:>7}",
        "K", "T*", "T", "naive words", "tiled words", "ratio"
    );
    for k in ks {
        let r = cost_model::model_report(v, k, cfg.cache_bytes);
        println!(
            "{:>5} {:>8.2} {:>6} {:>16.0} {:>16.0} {:>6.1}x",
            r.k, r.t_real, r.t_selected, r.naive_volume, r.tiled_volume, r.ratio
        );
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let scale = Scale::from_args(args);
    let out = report::results_dir(args);
    // Optional subset overrides: --datasets a,b --ks 80,160 --iters N
    let sel = Selection {
        datasets: args.opt("datasets").map(|v| v.split(',').map(str::to_string).collect()),
        ks: args
            .opt("ks")
            .map(|v| v.split(',').filter_map(|x| x.parse().ok()).collect()),
        iters: args.opt_usize("iters")?,
        engines: match args.opt("engines") {
            Some(list) => Some(
                list.split(',')
                    .map(|s| EngineKind::from_str(s.trim()))
                    .collect::<Result<Vec<_>>>()?,
            ),
            None => None,
        },
    };
    match which {
        "fig6" => fig6::run_sel(scale, &out, &sel)?,
        "fig7" => fig7::run_sel(scale, &out, &sel)?,
        "fig8" => fig8::run_sel(scale, &out, &sel)?,
        "fig9" => fig9::run_sel(scale, &out, &sel)?,
        "table5" => table5::run(scale, &out)?,
        "all" => {
            fig6::run_sel(scale, &out, &sel)?;
            fig7::run_sel(scale, &out, &sel)?;
            fig8::run_sel(scale, &out, &sel)?;
            fig9::run_sel(scale, &out, &sel)?;
            table5::run(scale, &out)?;
        }
        other => bail!("unknown bench '{other}'"),
    }
    Ok(())
}

/// Optional subset overrides for the bench commands.
#[derive(Debug, Clone, Default)]
pub struct Selection {
    pub datasets: Option<Vec<String>>,
    pub ks: Option<Vec<usize>>,
    pub iters: Option<usize>,
    pub engines: Option<Vec<EngineKind>>,
}

impl Selection {
    pub fn datasets<'a>(&'a self, scale: Scale) -> Vec<&'a str> {
        match &self.datasets {
            Some(v) => v.iter().map(|s| s.as_str()).collect(),
            None => scale.datasets().to_vec(),
        }
    }

    pub fn ks(&self, scale: Scale) -> Vec<usize> {
        self.ks.clone().unwrap_or_else(|| scale.ks())
    }

    pub fn engines(&self, default: Vec<EngineKind>) -> Vec<EngineKind> {
        self.engines.clone().unwrap_or(default)
    }
}

/// E6 as a library call (used by the end-to-end example).
pub fn model_report(v: usize, k: usize, cache_bytes: usize) -> cost_model::ModelReport {
    cost_model::model_report(v, k, cache_bytes)
}

/// Build a base RunConfig for a bench at a given scale.
pub fn bench_config(dataset: &str, k: usize, scale: Scale) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.dataset = dataset.to_string();
    cfg.k = k;
    cfg.max_iters = scale.iters();
    cfg.seed = 42;
    cfg
}

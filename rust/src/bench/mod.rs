//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation (§5–§6), plus the CLI that fronts the whole system.
//!
//! | experiment | paper artifact | module | bench target |
//! |------------|----------------|--------|--------------|
//! | E1 | Fig. 6 tile-size sweep        | [`fig6`]   | `cargo bench --bench fig6_tile_size` |
//! | E2 | Fig. 7 error vs time          | [`fig7`]   | `cargo bench --bench fig7_time_to_error` |
//! | E3 | Fig. 8 error vs iterations    | [`fig8`]   | `cargo bench --bench fig8_convergence` |
//! | E4 | Fig. 9 speedup @ matched err  | [`fig9`]   | `cargo bench --bench fig9_speedup` |
//! | E5 | Table 5 W-update breakdown    | [`table5`] | `cargo bench --bench table5_breakdown` |
//! | E6 | §5 cost-model numbers         | [`model_report`] | unit tests + `plnmf model` |
//! | E7 | §6.3.2 per-iter speedup       | [`fig7`] (`--per-iter`) | same bench |
//! | E8 | Table 4 dataset statistics    | `plnmf datasets` | — |
//! | S1 | serving docs/sec @ batch size | [`serving`] | `cargo bench --bench serving_throughput` |
//! | S2 | train-dist worker scaling     | [`train_dist`] | `cargo bench --bench train_dist_scaling` |
//! | —  | SIMD kernel dispatch speedup  | [`kernels`] | `cargo bench --bench kernels_speedup` |
//!
//! Every run defaults to the scaled-down `-small` profiles so `cargo
//! bench` completes in minutes; pass `--scale paper` (or env
//! `PLNMF_SCALE=paper`) for the full Table 4 sizes.

pub mod harness;
pub mod report;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod table5;
pub mod serving;
pub mod train_dist;
pub mod kernels;

use std::path::Path;
use std::sync::Arc;

use anyhow::bail;

use crate::cli::{Args, TrainArgs};
use crate::config::{profiles, EngineKind, RunConfig};
use crate::coordinator::{metrics, Driver};
use crate::data::stats::{table_header, DatasetStats};
use crate::data::{load_dataset, load_matrix_market, DataMatrix, Dataset};
use crate::nmf::cost_model;
use crate::parallel::{pool::default_threads, ThreadPool};
use crate::serve::{load_model, save_model, ModelMeta, Projector, ProjectorOpts, Queries};
use crate::util::Timer;
use crate::Result;

/// Benchmark scale: which dataset profiles a bench touches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// `-small` profiles, reduced K — CI-friendly (default).
    Small,
    /// Full Table 4 datasets at the paper's K values.
    Paper,
}

impl Scale {
    pub fn from_args(args: &Args) -> Scale {
        let v = args
            .opt("scale")
            .map(str::to_string)
            .or_else(|| std::env::var("PLNMF_SCALE").ok())
            .unwrap_or_default();
        if v.eq_ignore_ascii_case("paper") {
            Scale::Paper
        } else {
            Scale::Small
        }
    }

    pub fn datasets(self) -> [&'static str; 5] {
        match self {
            Scale::Small => profiles::small_datasets(),
            Scale::Paper => profiles::paper_datasets(),
        }
    }

    /// The K sweep (Figs. 6/7 use 80/160/240 at paper scale).
    pub fn ks(self) -> Vec<usize> {
        match self {
            Scale::Small => vec![16, 32],
            Scale::Paper => vec![80, 160, 240],
        }
    }

    /// The single operating point of Figs. 8/9 (K = 240, T = 15).
    pub fn k_single(self) -> usize {
        match self {
            Scale::Small => 32,
            Scale::Paper => 240,
        }
    }

    pub fn iters(self) -> usize {
        match self {
            Scale::Small => 30,
            Scale::Paper => 100,
        }
    }
}

/// CLI dispatch (shared by the `plnmf` binary and the examples).
pub fn cli_main(args: Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("compare") => cmd_compare(&args),
        Some("transform") => cmd_transform(&args),
        Some("recommend") => cmd_recommend(&args),
        Some("serve") => cmd_serve(&args),
        Some("route") => cmd_route(&args),
        Some("train-dist") => cmd_train_dist(&args),
        Some("datasets") => cmd_datasets(&args),
        Some("model") => cmd_model(&args),
        Some("bench") => cmd_bench(&args),
        Some("help") | None => {
            print!("{}", HELP);
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}' (see `plnmf help`)"),
    }
}

const HELP: &str = "\
plnmf — Parallel Locality-Optimized NMF (paper reproduction)

USAGE: plnmf <command> [--key value ...]

COMMANDS:
  run        run one engine: --dataset --k --engine --iters --tile --threads
             --seed --trace_path out.csv [--config file.json]
             [--model m.json — save the trained factors for serving]
             [--loss frobenius|kl --alpha A --l1_ratio R
             --init random|nndsvd|nndsvda — the engine spec; `--engine mu
             --loss kl` runs the KL MU engine, alpha>0 adds an elastic-net
             penalty on H, and the spec is saved with the model]
  compare    run several engines from one init: --engines a,b,c (default all
             native), same options as run; writes results/compare_*.csv
  transform  project query columns onto a saved model's topics:
             --model m.json [--input file.mtx | --dataset name]
             [--sweeps N --batch B --out h.csv]
             [--loss --alpha --l1_ratio — override the model's saved
             serving spec field-wise]
  recommend  top-N items from reconstructions of a saved model:
             same inputs as transform, plus --top N [--exclude-seen]
  serve      long-lived daemon: newline-delimited JSON over TCP, models
             stay resident (cached Grams, warm-start cache, per-model
             pools); the `update` op folds new data rows into a model's
             factors and hot-swaps them in as epoch N+1 without dropping
             a request: --models_manifest fleet.json | --model m.json
             [--serve_port P --warm_cache N --serve_tol T --threads N
             --update_sweeps S]
             [--train_worker — host no models, just train-dist shards]
  route      cross-process shard router: `plnmf serve` worker processes
             per manifest model (\"replicas\": N each, default 1), same
             protocol on the front port; least-loaded replica routing,
             idempotent-op retry budget, busy backpressure, crash
             detection + bounded-backoff restarts + manifest hot-reload;
             `update` fans out to every replica of its model:
             --models_manifest fleet.json
             [--route_port P --worker_port_base B --restart_backoff_ms N
             --route_retries R --max_inflight C
             --threads T + the serve knobs, passed through to workers]
  train-dist distributed NMF over `serve --train_worker` daemons: the
             dataset is block-partitioned on a pr×pc grid (nnz-balanced
             both axes), workers keep their A block + H panel resident,
             the coordinator exchanges factor panels and all-reduces k×k
             Grams per epoch over the PLNB v2 binary wire:
             --dataset --k --iters --train_workers N --sync_every E
             [--grid PRxPC — 2D worker grid; 1xN (default) is the
             row-sharded plan, pr>1 panel-shards W too and shrinks
             coordinator traffic to panel-sized]
             [--engine fasthals|mu --loss frobenius|kl — the engine
             spec, same flags as run; KL needs a 1xN grid]
             [--threads --seed --trace_path out.csv + the run knobs]
             [--attach host:port,... — use already-running
             `serve --train_worker` daemons instead of spawning]
  datasets   print Table-4 statistics of every dataset profile (E8)
  model      print the §5 data-movement model report (E6): --k or positional
             K values, --dataset for V, --cache_bytes
  bench      regenerate paper artifacts: bench
             <fig6|fig7|fig8|fig9|table5|serving|train-dist|kernels|all>
             [--scale small|paper] [--out-dir results]
  help       this text

Engines: plnmf | fasthals | mu | bpp | mu-kl | plnmf-xla | mu-xla
Dataset profiles: 20news tdt2 reuters att pie (+-small variants, tiny)
";

fn cmd_run(args: &Args) -> Result<()> {
    let TrainArgs { cfg, .. } = TrainArgs::from_args(args)?;
    let mut driver = Driver::from_config(&cfg)?;
    let report = driver.run()?;
    print!("{}", metrics::summary_table(std::slice::from_ref(&report)));
    println!("\nphase breakdown:\n{}", report.timers.table());
    if let Some(model_path) = &cfg.model_path {
        let meta = ModelMeta {
            engine: report.engine.to_string(),
            dataset: cfg.dataset.clone(),
            seed: cfg.seed,
            iters: report.iters_run(),
            rel_error: report.final_rel_error,
            spec: cfg.engine_spec()?,
        };
        save_model(Path::new(model_path), driver.engine_mut().factors(), &meta)?;
        println!("\nmodel saved: {model_path}");
    }
    Ok(())
}

/// Resolve the query batch for `transform` / `recommend`: an explicit
/// MatrixMarket file (`--input`), an explicit `--dataset`, or the
/// model's own training dataset profile.
fn load_queries(args: &Args, cfg: &RunConfig, meta: &ModelMeta, model_v: usize) -> Result<Dataset> {
    let ds = if let Some(input) = args.opt("input") {
        load_matrix_market(Path::new(input))?
    } else if args.opt("dataset").is_some() || meta.dataset.is_empty() {
        load_dataset(&cfg.dataset, cfg.seed)?
    } else {
        // Defaulting to the model's training dataset: use the training
        // seed too — the synthetic generators are seed-dependent, and
        // mixing the trained profile with a different seed would
        // silently project a *different* random corpus.
        load_dataset(&meta.dataset, meta.seed)?
    };
    if ds.v() != model_v {
        bail!(
            "query matrix has V={} rows but the model was trained with V={model_v}",
            ds.v()
        );
    }
    Ok(ds)
}

fn queries_of(ds: &Dataset) -> Queries<'_> {
    // Queries are the *columns* of A, i.e. the rows of the resident Aᵀ.
    match &ds.at {
        DataMatrix::Sparse(c) => Queries::Sparse(c),
        DataMatrix::Dense(m) => Queries::Dense(m),
    }
}

fn serve_projector(args: &Args, cfg: &RunConfig) -> Result<(Projector, ModelMeta, Arc<ThreadPool>)> {
    let model_path = cfg.model_path.clone().ok_or_else(|| {
        anyhow::anyhow!("--model <file> is required (save one with `plnmf run --model m.json`)")
    })?;
    let (factors, meta) = load_model(Path::new(&model_path))?;
    let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };
    let pool = Arc::new(ThreadPool::new(threads));
    let opts = ProjectorOpts {
        sweeps: cfg.sweeps,
        micro_batch: cfg.batch,
        tile: cfg.tile,
        cache_bytes: cfg.cache_bytes,
        tol: cfg.serve_tol,
    };
    // The model file's spec drives projection; explicit CLI flags
    // override it field-wise (e.g. project a KL model without its
    // training-time sparsity penalty via `--alpha 0`).
    let mut spec = meta.spec;
    if let Some(l) = cfg.loss {
        spec.loss = l;
        if l == crate::nmf::Loss::Kl {
            spec.solver = crate::nmf::Solver::Mu;
        }
    }
    if args.opt("alpha").is_some() {
        spec.alpha = cfg.alpha;
    }
    if args.opt("l1_ratio").is_some() {
        spec.l1_ratio = cfg.l1_ratio;
    }
    spec.validate()?;
    Ok((Projector::with_spec(factors.w, pool.clone(), opts, spec)?, meta, pool))
}

/// Default sweep tolerance `plnmf serve` applies when warm caching is on
/// but no `serve_tol` was configured: warm starts only pay off through
/// the convergence early-stop, so a daemon with a warm cache and
/// `tol = 0` would cache solutions it never benefits from.
const SERVE_DEFAULT_WARM_TOL: f64 = 1e-5;

fn cmd_serve(args: &Args) -> Result<()> {
    use crate::serve::{ModelRegistry, RegistryOpts, Server};

    let cfg = args.to_run_config()?;
    let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };
    let serve_tol = if cfg.warm_cache > 0 && cfg.serve_tol == 0.0 {
        println!(
            "serve: warm_cache={} with serve_tol=0 — defaulting serve_tol to {SERVE_DEFAULT_WARM_TOL} \
             (warm starts cut sweeps only via the convergence early-stop)",
            cfg.warm_cache
        );
        SERVE_DEFAULT_WARM_TOL
    } else {
        cfg.serve_tol
    };
    // Read the manifest once: it sizes the per-model pools AND seeds the
    // registry (re-reading for each would race a concurrent edit).
    let manifest = match &cfg.models_manifest {
        Some(path) => Some(crate::serve::Manifest::load(Path::new(path))?),
        None => None,
    };
    // Per-model pool width: the machine divided across the fleet, so all
    // models can solve concurrently without oversubscribing cores (a
    // single `--model` daemon gets the full width).
    let fleet_size = manifest.as_ref().map(|m| m.models.len()).unwrap_or(1);
    let ropts = RegistryOpts {
        threads,
        per_model_threads: (threads / fleet_size.max(1)).max(1),
        projector: ProjectorOpts {
            sweeps: cfg.sweeps,
            micro_batch: cfg.batch,
            tile: cfg.tile,
            cache_bytes: cfg.cache_bytes,
            tol: serve_tol,
        },
        warm_cache: cfg.warm_cache,
        update_sweeps: cfg.update_sweeps,
        max_total_nnz: 0,
    };
    let registry = if let (Some(manifest), Some(path)) = (&manifest, &cfg.models_manifest) {
        ModelRegistry::from_loaded(manifest, Path::new(path), ropts)?
    } else if let Some(model) = &cfg.model_path {
        let registry = ModelRegistry::new(ropts);
        registry.load("default", Path::new(model))?;
        registry
    } else if args.has_flag("train_worker") {
        // A training worker hosts no serving models: it exists to hold a
        // dataset shard + H panel for a `plnmf train-dist` coordinator
        // (every daemon dispatches the binary training ops either way —
        // this flag just waives the model requirement).
        ModelRegistry::new(ropts)
    } else {
        bail!(
            "serve needs --models_manifest fleet.json (multi-model) or --model m.json \
             (single model, registered as 'default')"
        );
    };
    let names = registry.names();
    let server = Server::bind(Arc::new(registry), "127.0.0.1", cfg.serve_port as u16)?;
    println!(
        "plnmf serve: listening on {} — {} model(s): {} (warm_cache={}, serve_tol={}, {} threads)",
        server.local_addr(),
        names.len(),
        names.join(", "),
        cfg.warm_cache,
        serve_tol,
        threads
    );
    server.run()
}

fn cmd_route(args: &Args) -> Result<()> {
    use crate::serve::{Router, RouterOpts, WorkerOpts};

    let cfg = args.to_run_config()?;
    let manifest_path = cfg.models_manifest.clone().ok_or_else(|| {
        anyhow::anyhow!(
            "route needs --models_manifest fleet.json (one worker process is spawned per model)"
        )
    })?;
    // Read the manifest once: it sizes the per-worker thread shares AND
    // seeds the router (re-reading for each would race a concurrent
    // edit). Split the machine across the fleet like `serve` does
    // across its per-model pools — here each worker process (every
    // replica is its own process) gets its own share.
    let manifest = crate::serve::Manifest::load(Path::new(&manifest_path))?;
    let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };
    let fleet_workers: usize = manifest.models.iter().map(|m| m.replicas).sum();
    let per_worker_threads = (threads / fleet_workers.max(1)).max(1);
    let binary = std::env::current_exe()
        .map_err(|e| anyhow::anyhow!("resolving the plnmf binary for workers: {e}"))?;
    let mut worker_opts = WorkerOpts::new(binary);
    // Serving knobs pass through to the workers verbatim; `serve`
    // applies its own warm-tol defaulting on arrival.
    worker_opts.extra_args = vec![
        "--threads".into(),
        per_worker_threads.to_string(),
        "--sweeps".into(),
        cfg.sweeps.to_string(),
        "--batch".into(),
        cfg.batch.to_string(),
        "--serve_tol".into(),
        cfg.serve_tol.to_string(),
        "--warm_cache".into(),
        cfg.warm_cache.to_string(),
        "--update_sweeps".into(),
        cfg.update_sweeps.to_string(),
    ];
    let opts = RouterOpts {
        route_port: cfg.route_port as u16,
        worker_port_base: cfg.worker_port_base as u16,
        restart_backoff: std::time::Duration::from_millis(cfg.restart_backoff_ms as u64),
        max_backoff: std::time::Duration::from_millis(cfg.max_backoff_ms as u64),
        route_retries: cfg.route_retries,
        max_inflight: cfg.max_inflight,
        ..Default::default()
    };
    let router = Router::from_loaded(&manifest, Path::new(&manifest_path), worker_opts, opts)?;
    let names = router.names();
    println!(
        "plnmf route: listening on {} — {} model(s) over {} worker process(es): {} \
         ({per_worker_threads} threads each, restart backoff {}ms capped at {}ms, \
         retry budget {}, in-flight ceiling {})",
        router.local_addr(),
        names.len(),
        router.worker_count(),
        names.join(", "),
        cfg.restart_backoff_ms,
        cfg.max_backoff_ms,
        cfg.route_retries,
        cfg.max_inflight
    );
    router.run()
}

fn cmd_train_dist(args: &Args) -> Result<()> {
    let TrainArgs { cfg, attach } = TrainArgs::from_args(args)?;
    let binary = std::env::current_exe()
        .map_err(|e| anyhow::anyhow!("resolving the plnmf binary for train workers: {e}"))?;
    let opts = crate::dist::DistOpts {
        binary: Some(binary),
        workers: cfg.train_workers,
        sync_every: cfg.sync_every,
        attach,
        grid: cfg.grid,
        ..Default::default()
    };
    let (report, stats) = crate::dist::train_dist_with_stats(&cfg, &opts)?;
    print!("{}", metrics::summary_table(std::slice::from_ref(&report)));
    println!("\nphase breakdown:\n{}", report.timers.table());
    println!(
        "\ntopology: {}x{} grid, {} worker(s), {} epochs, {} coordinator bytes/epoch",
        stats.grid.0,
        stats.grid.1,
        stats.workers,
        stats.epochs,
        stats.bytes_per_epoch()
    );
    if let Some(path) = &cfg.trace_path {
        println!("\ntrace CSV: {path}");
    }
    Ok(())
}

fn cmd_transform(args: &Args) -> Result<()> {
    let TrainArgs { cfg, .. } = TrainArgs::from_args(args)?;
    let (projector, meta, _pool) = serve_projector(args, &cfg)?;
    let ds = load_queries(args, &cfg, &meta, projector.v())?;
    let q = queries_of(&ds);
    let (m, k) = (q.rows(), projector.k());

    let t = Timer::start();
    let (h, res) = projector.project_with_residuals(q)?;
    let secs = t.elapsed_secs();
    let mean_res = res.iter().sum::<f64>() / res.len().max(1) as f64;
    let max_res = res.iter().cloned().fold(0.0, f64::max);
    println!(
        "transform: {m} docs onto {} (k={k}, tile={}, sweeps={}, batch={})",
        meta.engine,
        projector.tile(),
        cfg.sweeps,
        cfg.batch
    );
    println!(
        "  {:.4} s  [{:.1} docs/s]   rel residual mean {:.4}, max {:.4}",
        secs,
        m as f64 / secs.max(1e-12),
        mean_res,
        max_res
    );

    if let Some(out) = args.opt("out") {
        let header = std::iter::once("doc".to_string())
            .chain((0..k).map(|t| format!("h{t}")))
            .collect::<Vec<_>>()
            .join(",");
        let rows: Vec<String> = (0..m)
            .map(|i| {
                let mut row = i.to_string();
                for &x in h.row(i) {
                    row.push_str(&format!(",{x}"));
                }
                row
            })
            .collect();
        report::write_csv(Path::new(out), &header, &rows)?;
        println!("  wrote {out}");
    }
    Ok(())
}

fn cmd_recommend(args: &Args) -> Result<()> {
    let TrainArgs { cfg, .. } = TrainArgs::from_args(args)?;
    let (projector, meta, _pool) = serve_projector(args, &cfg)?;
    let ds = load_queries(args, &cfg, &meta, projector.v())?;
    let q = queries_of(&ds);
    let top = args.opt_usize("top")?.unwrap_or(10);
    let exclude_seen = args.has_flag("exclude-seen");

    let t = Timer::start();
    let recs = projector.recommend(q, top, exclude_seen)?;
    let secs = t.elapsed_secs();
    println!(
        "recommend: top-{top} for {} queries in {:.4} s  [{:.1} queries/s]{}",
        recs.len(),
        secs,
        recs.len() as f64 / secs.max(1e-12),
        if exclude_seen { "  (seen items excluded)" } else { "" }
    );
    for (i, rec) in recs.iter().take(5).enumerate() {
        let line: Vec<String> =
            rec.iter().map(|(item, score)| format!("{item}:{score:.4}")).collect();
        println!("  query {i}: {}", line.join("  "));
    }
    if recs.len() > 5 {
        println!("  … ({} more)", recs.len() - 5);
    }

    if let Some(out) = args.opt("out") {
        let rows: Vec<String> = recs
            .iter()
            .enumerate()
            .flat_map(|(i, rec)| {
                rec.iter()
                    .enumerate()
                    .map(move |(rank, (item, score))| format!("{i},{rank},{item},{score}"))
            })
            .collect();
        report::write_csv(Path::new(out), "query,rank,item,score", &rows)?;
        println!("  wrote {out}");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let cfg = args.to_run_config()?;
    let engines: Vec<EngineKind> = match args.opt("engines") {
        Some(list) => list
            .split(',')
            .map(|s| EngineKind::from_str(s.trim()))
            .collect::<Result<Vec<_>>>()?,
        None => vec![EngineKind::PlNmf, EngineKind::FastHals, EngineKind::Mu, EngineKind::Bpp],
    };
    let cmp = crate::coordinator::comparison::run_comparison(&cfg, &engines)?;
    print!("{}", metrics::summary_table(&cmp.reports));
    for (kind, why) in &cmp.skipped {
        println!("skipped {}: {}", kind.name(), why);
    }
    let out = report::results_dir(args).join(format!("compare_{}_k{}.csv", cfg.dataset, cfg.k));
    metrics::write_comparison_csv(&out, &cmp.reports)?;
    println!("\ntrace CSV: {}", out.display());
    Ok(())
}

fn cmd_datasets(args: &Args) -> Result<()> {
    let scale = Scale::from_args(args);
    println!("{}", table_header());
    for name in scale.datasets() {
        let ds = crate::data::load_dataset(name, 42)?;
        println!("{}", DatasetStats::of(&ds).row());
    }
    Ok(())
}

fn cmd_model(args: &Args) -> Result<()> {
    let cfg = args.to_run_config()?;
    let ks: Vec<usize> = if args.positional.is_empty() {
        vec![80, 160, 240]
    } else {
        args.positional.iter().map(|s| s.parse().unwrap_or(0)).filter(|&k| k > 0).collect()
    };
    // §5 uses V = 11,314 for the 20NG worked example.
    let v = crate::config::dataset_profile(&cfg.dataset).map(|p| p.d).unwrap_or(11_314);
    println!("data-movement model (V={v}, C={} bytes):", cfg.cache_bytes);
    println!(
        "{:>5} {:>8} {:>6} {:>16} {:>16} {:>7}",
        "K", "T*", "T", "naive words", "tiled words", "ratio"
    );
    for k in ks {
        let r = cost_model::model_report(v, k, cfg.cache_bytes);
        println!(
            "{:>5} {:>8.2} {:>6} {:>16.0} {:>16.0} {:>6.1}x",
            r.k, r.t_real, r.t_selected, r.naive_volume, r.tiled_volume, r.ratio
        );
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let scale = Scale::from_args(args);
    let out = report::results_dir(args);
    // Optional subset overrides: --datasets a,b --ks 80,160 --iters N
    let sel = Selection {
        datasets: args.opt("datasets").map(|v| v.split(',').map(str::to_string).collect()),
        ks: args
            .opt("ks")
            .map(|v| v.split(',').filter_map(|x| x.parse().ok()).collect()),
        iters: args.opt_usize("iters")?,
        engines: match args.opt("engines") {
            Some(list) => Some(
                list.split(',')
                    .map(|s| EngineKind::from_str(s.trim()))
                    .collect::<Result<Vec<_>>>()?,
            ),
            None => None,
        },
    };
    match which {
        "fig6" => fig6::run_sel(scale, &out, &sel)?,
        "fig7" => fig7::run_sel(scale, &out, &sel)?,
        "fig8" => fig8::run_sel(scale, &out, &sel)?,
        "fig9" => fig9::run_sel(scale, &out, &sel)?,
        "table5" => table5::run(scale, &out)?,
        "serving" => serving::run(scale, &out)?,
        "train-dist" => train_dist::run(scale, &out)?,
        "kernels" => kernels::run(scale, &out)?,
        "all" => {
            fig6::run_sel(scale, &out, &sel)?;
            fig7::run_sel(scale, &out, &sel)?;
            fig8::run_sel(scale, &out, &sel)?;
            fig9::run_sel(scale, &out, &sel)?;
            table5::run(scale, &out)?;
            serving::run(scale, &out)?;
            train_dist::run(scale, &out)?;
            kernels::run(scale, &out)?;
        }
        other => bail!("unknown bench '{other}'"),
    }
    Ok(())
}

/// Optional subset overrides for the bench commands.
#[derive(Debug, Clone, Default)]
pub struct Selection {
    pub datasets: Option<Vec<String>>,
    pub ks: Option<Vec<usize>>,
    pub iters: Option<usize>,
    pub engines: Option<Vec<EngineKind>>,
}

impl Selection {
    pub fn datasets<'a>(&'a self, scale: Scale) -> Vec<&'a str> {
        match &self.datasets {
            Some(v) => v.iter().map(|s| s.as_str()).collect(),
            None => scale.datasets().to_vec(),
        }
    }

    pub fn ks(&self, scale: Scale) -> Vec<usize> {
        self.ks.clone().unwrap_or_else(|| scale.ks())
    }

    pub fn engines(&self, default: Vec<EngineKind>) -> Vec<EngineKind> {
        self.engines.clone().unwrap_or(default)
    }
}

/// E6 as a library call (used by the end-to-end example).
pub fn model_report(v: usize, k: usize, cache_bytes: usize) -> cost_model::ModelReport {
    cost_model::model_report(v, k, cache_bytes)
}

/// Build a base RunConfig for a bench at a given scale.
pub fn bench_config(dataset: &str, k: usize, scale: Scale) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.dataset = dataset.to_string();
    cfg.k = k;
    cfg.max_iters = scale.iters();
    cfg.seed = 42;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_args_reach_dist_opts() {
        // The CLI wiring end of the consolidation satellite: the shared
        // TrainArgs parse must land `--attach` and `--grid` in DistOpts
        // exactly as parsed.
        let args = crate::cli::Args::parse(
            ["train-dist", "--grid", "2x2", "--attach", "127.0.0.1:7001,127.0.0.1:7002"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let TrainArgs { cfg, attach } = TrainArgs::from_args(&args).unwrap();
        let opts = crate::dist::DistOpts { attach, grid: cfg.grid, ..Default::default() };
        assert_eq!(opts.attach.len(), 2);
        assert_eq!(opts.attach[1], "127.0.0.1:7002".parse().unwrap());
        assert_eq!(opts.grid, Some((2, 2)));
    }
}

//! E4 / Fig. 9: speedup of the accelerated PL-NMF (XLA/Pallas via PJRT —
//! the PL-NMF-gpu stand-in) over every CPU implementation at matched
//! relative error. The paper's claim: all points > 1 (the accelerated
//! implementation dominates), with enormous ratios vs MU-family CPU
//! engines (287× on PIE in the paper).

use std::path::Path;

use crate::config::EngineKind;
use crate::coordinator::comparison::{
    common_error_targets, run_comparison, speedups_at_matched_error,
};
use crate::Result;

use super::{bench_config, report::write_csv, Scale};

#[derive(Debug, Clone)]
pub struct Fig9Row {
    pub dataset: String,
    pub target_error: f64,
    pub baseline: &'static str,
    pub speedup: f64,
}

pub fn run_datasets(datasets: &[&str], k: usize, scale: Scale) -> Result<Vec<Fig9Row>> {
    run_datasets_iters(datasets, k, scale, None)
}

pub fn run_datasets_iters(
    datasets: &[&str],
    k: usize,
    scale: Scale,
    iters: Option<usize>,
) -> Result<Vec<Fig9Row>> {
    run_datasets_engines(datasets, k, scale, iters, &default_engines())
}

pub fn default_engines() -> Vec<EngineKind> {
    vec![
        EngineKind::PlNmfXla,
        EngineKind::PlNmf,
        EngineKind::FastHals,
        EngineKind::Mu,
        EngineKind::Bpp,
        EngineKind::MuXla,
    ]
}

pub fn run_datasets_engines(
    datasets: &[&str],
    k: usize,
    scale: Scale,
    iters: Option<usize>,
    engines: &[EngineKind],
) -> Result<Vec<Fig9Row>> {
    let mut rows = Vec::new();
    for &name in datasets {
        let mut cfg = bench_config(name, k, scale);
        if let Some(it) = iters {
            cfg.max_iters = it;
        }
        let cmp = run_comparison(&cfg, engines)?;
        let Some(fast) = cmp.reports.iter().find(|r| r.engine == "plnmf-accel") else {
            crate::warn_!(
                "fig9: no plnmf-accel report for {name} (artifacts missing?) — skipping"
            );
            continue;
        };
        let slows: Vec<_> =
            cmp.reports.iter().filter(|r| r.engine != "plnmf-accel").collect();
        let refs: Vec<&crate::coordinator::RunReport> =
            std::iter::once(fast).chain(slows.iter().copied()).collect();
        let targets = common_error_targets(&refs, 5);
        for (t, engine, s) in speedups_at_matched_error(fast, &slows, &targets) {
            rows.push(Fig9Row {
                dataset: name.to_string(),
                target_error: t,
                baseline: engine,
                speedup: s,
            });
        }
    }
    Ok(rows)
}

pub fn run(scale: Scale, out_dir: &Path) -> Result<()> {
    run_sel(scale, out_dir, &super::Selection::default())
}

pub fn run_sel(scale: Scale, out_dir: &Path, sel: &super::Selection) -> Result<()> {
    let k = sel.ks.as_ref().and_then(|v| v.first().copied()).unwrap_or(scale.k_single());
    let rows = run_datasets_engines(
        &sel.datasets(scale),
        k,
        scale,
        sel.iters,
        &sel.engines(default_engines()),
    )?;
    println!("Fig. 9 — speedup of plnmf-accel at matched relative error (K={k})\n");
    println!(
        "{:<16} {:>12} {:<16} {:>9}",
        "dataset", "target err", "baseline", "speedup"
    );
    let mut csv = Vec::new();
    for r in &rows {
        println!(
            "{:<16} {:>12.6} {:<16} {:>8.2}x",
            r.dataset, r.target_error, r.baseline, r.speedup
        );
        csv.push(format!(
            "{},{:.8},{},{:.4}",
            r.dataset, r.target_error, r.baseline, r.speedup
        ));
    }
    write_csv(
        &out_dir.join("fig9_speedup.csv"),
        "dataset,target_error,baseline,speedup",
        &csv,
    )?;
    if rows.is_empty() {
        println!("(no rows — build XLA artifacts first: make artifacts)");
    } else {
        let above = rows.iter().filter(|r| r.speedup > 1.0).count();
        println!(
            "\n{} of {} points > 1.0 (paper: all points above one)",
            above,
            rows.len()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_with_or_without_artifacts() {
        // With the `test` artifact set present this produces rows for
        // tiny; without it, it must return empty rather than fail.
        let rows = run_datasets(&["tiny"], 8, Scale::Small).unwrap_or_default();
        for r in &rows {
            assert!(r.speedup.is_finite());
            assert!(r.target_error > 0.0);
        }
    }
}

//! E5 / Table 5: breakdown of elapsed time for updating W on the
//! 20 Newsgroups dataset — SpMM / DMM / DMV for sequential FAST-HALS vs
//! SpMM / DMM / Phase 1 / Phases 2&3 for PL-NMF. The paper's numbers
//! (2.039 s DMV vs 0.005 + 0.026 s phases): the phases replace the DMV
//! loop at a fraction of its cost while SpMM and DMM are identical
//! between the two columns.

use std::path::Path;
use std::sync::Arc;

use crate::data::load_dataset;
use crate::nmf::fasthals::FastHalsEngine;
use crate::nmf::plnmf::PlNmfEngine;
use crate::nmf::NmfEngine;
use crate::parallel::{pool::default_threads, ThreadPool};
use crate::Result;

use super::{report::write_csv, Scale};

#[derive(Debug, Clone)]
pub struct Table5 {
    pub dataset: String,
    pub k: usize,
    pub iters: usize,
    /// FAST-HALS column: (SpMM, DMM, DMV) seconds per iteration.
    pub hals: (f64, f64, f64),
    /// PL-NMF column: (SpMM, DMM, Phase 1, Phases 2&3) secs per iter.
    pub plnmf: (f64, f64, f64, f64),
}

impl Table5 {
    pub fn dmv_over_phases(&self) -> f64 {
        let phases = self.plnmf.2 + self.plnmf.3;
        if phases > 0.0 {
            self.hals.2 / phases
        } else {
            f64::INFINITY
        }
    }
}

/// Measure the W-update breakdown over `iters` iterations (averaged).
pub fn measure(dataset: &str, k: usize, tile: usize, iters: usize) -> Result<Table5> {
    let ds = Arc::new(load_dataset(dataset, 42)?);
    let pool = Arc::new(ThreadPool::new(default_threads()));

    let mut hals = FastHalsEngine::new(ds.clone(), pool.clone(), k, 42);
    hals.step()?; // warmup / buffer touch
    hals.reset_timers();
    for _ in 0..iters {
        hals.step()?;
    }
    let ht = hals.timers();
    let n = iters as f64;
    let hals_row = (ht.secs("spmm_p") / n, ht.secs("gram_q") / n, ht.secs("w_dmv") / n);

    let mut pl = PlNmfEngine::new(ds, pool, k, 42, tile, 35 << 20);
    pl.step()?;
    pl.reset_timers();
    for _ in 0..iters {
        pl.step()?;
    }
    let pt = pl.timers();
    let pl_row = (
        pt.secs("spmm_p") / n,
        pt.secs("gram_q") / n,
        pt.secs("w_phase1") / n,
        (pt.secs("w_phase2") + pt.secs("w_phase3")) / n,
    );

    Ok(Table5 { dataset: dataset.to_string(), k, iters, hals: hals_row, plnmf: pl_row })
}

pub fn render(t: &Table5) -> String {
    format!(
        "Table 5 — W-update breakdown on {} (K={}, avg over {} iters)\n\
         {:<28} {:>12} | {:<14} {:>12}\n\
         {:<28} {:>12.4} | {:<14} {:>12.4}\n\
         {:<28} {:>12.4} | {:<14} {:>12.4}\n\
         {:<28} {:>12.4} | {:<14} {:>12.4}\n\
         {:<28} {:>12} | {:<14} {:>12.4}\n\
         DMV / (phase1 + phases2&3) = {:.2}x (paper: 2.039 / 0.031 ≈ 66x on 28-core MKL)\n",
        t.dataset,
        t.k,
        t.iters,
        "Sequential FAST-HALS", "s/iter", "PL-NMF", "s/iter",
        "SpMM (A·H)", t.hals.0, "SpMM (A·H)", t.plnmf.0,
        "DMM (HᵀH)", t.hals.1, "DMM (HᵀH)", t.plnmf.1,
        "DMV (k-loop)", t.hals.2, "Phase 1", t.plnmf.2,
        "", "", "Phases 2&3", t.plnmf.3,
        t.dmv_over_phases(),
    )
}

pub fn run(scale: Scale, out_dir: &Path) -> Result<()> {
    let (dataset, k, tile, iters) = match scale {
        // Table 5 is 20NG at K=160 in the paper.
        Scale::Paper => ("20news", 160, 13, 10),
        Scale::Small => ("20news-small", 32, 6, 10),
    };
    let t = measure(dataset, k, tile, iters)?;
    print!("{}", render(&t));
    write_csv(
        &out_dir.join("table5_breakdown.csv"),
        "dataset,k,impl,spmm,dmm,dmv_or_phase1,phases23",
        &[
            format!("{},{},fasthals,{:.6},{:.6},{:.6},", t.dataset, t.k, t.hals.0, t.hals.1, t.hals.2),
            format!(
                "{},{},plnmf,{:.6},{:.6},{:.6},{:.6}",
                t.dataset, t.k, t.plnmf.0, t.plnmf.1, t.plnmf.2, t.plnmf.3
            ),
        ],
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_measures_all_cells() {
        let t = measure("tiny-sparse", 8, 3, 3).unwrap();
        assert!(t.hals.2 > 0.0, "DMV time must be positive");
        assert!(t.plnmf.3 > 0.0, "phase 2&3 time must be positive");
        assert!(t.dmv_over_phases().is_finite());
        let s = render(&t);
        assert!(s.contains("Phase 1"));
        assert!(s.contains("DMV"));
    }
}

//! Measurement harness (criterion is unavailable offline): warmup +
//! repeated timed runs + robust summary statistics.

use std::time::Instant;

use crate::util::stats::Summary;

/// Harness configuration. Honors `PLNMF_BENCH_REPS` / `PLNMF_BENCH_WARMUP`
/// for CI tuning.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub warmup: usize,
    pub reps: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        let get = |k: &str, d: usize| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        BenchOpts { warmup: get("PLNMF_BENCH_WARMUP", 2), reps: get("PLNMF_BENCH_REPS", 5) }
    }
}

/// Time `f` (seconds per call) with warmup; returns the sample summary.
pub fn measure(opts: BenchOpts, mut f: impl FnMut()) -> Summary {
    for _ in 0..opts.warmup {
        f();
    }
    let mut samples = Vec::with_capacity(opts.reps.max(1));
    for _ in 0..opts.reps.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// Render a bench row: `name  median ± mad  (min … max, n)`.
pub fn row(name: &str, s: &Summary) -> String {
    format!(
        "{:<44} {:>10.4}s ±{:>8.4}  ({:.4} … {:.4}, n={})",
        name, s.median, s.mad, s.min, s.max, s.n
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_roughly() {
        let s = measure(BenchOpts { warmup: 0, reps: 3 }, || {
            std::thread::sleep(std::time::Duration::from_millis(5));
        });
        assert!(s.median >= 0.004, "median {}", s.median);
        assert!(s.median < 0.2);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn row_formats() {
        let s = Summary::of(&[0.1, 0.2, 0.3]);
        let r = row("x", &s);
        assert!(r.contains("n=3"));
    }
}

//! E3 / Fig. 8: relative error as a function of *iterations* (K = 240,
//! T = 15 at paper scale). The claims this reproduces:
//!
//! * planc-HALS and PL-NMF follow the same per-iteration trajectory (the
//!   tiled reorder only reassociates additions);
//! * MU converges more slowly per iteration;
//! * BPP matches HALS quality per iteration (at higher per-iter cost).

use std::path::Path;

use crate::config::EngineKind;
use crate::coordinator::comparison::run_comparison;
use crate::coordinator::metrics::write_comparison_csv;
use crate::coordinator::RunReport;
use crate::Result;

use super::{bench_config, Scale};

pub fn run_datasets(datasets: &[&str], k: usize, scale: Scale) -> Result<Vec<RunReport>> {
    run_datasets_iters(datasets, k, scale, None)
}

pub fn run_datasets_iters(
    datasets: &[&str],
    k: usize,
    scale: Scale,
    iters: Option<usize>,
) -> Result<Vec<RunReport>> {
    run_datasets_engines(datasets, k, scale, iters, &default_engines())
}

pub fn default_engines() -> Vec<EngineKind> {
    vec![EngineKind::PlNmf, EngineKind::FastHals, EngineKind::Mu, EngineKind::Bpp]
}

pub fn run_datasets_engines(
    datasets: &[&str],
    k: usize,
    scale: Scale,
    iters: Option<usize>,
    engines: &[EngineKind],
) -> Result<Vec<RunReport>> {
    let mut reports = Vec::new();
    for &name in datasets {
        let mut cfg = bench_config(name, k, scale);
        if let Some(it) = iters {
            cfg.max_iters = it;
        }
        let cmp = run_comparison(&cfg, engines)?;
        reports.extend(cmp.reports);
    }
    Ok(reports)
}

/// Max |err_plnmf − err_hals| across aligned iterations (the Fig. 8
/// "identical trajectories" check).
pub fn hals_family_divergence(reports: &[RunReport]) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    let datasets: std::collections::BTreeSet<_> =
        reports.iter().map(|r| r.dataset.clone()).collect();
    for ds in datasets {
        let find = |engine: &str| {
            reports.iter().find(|r| r.dataset == ds && r.engine == engine)
        };
        if let (Some(p), Some(h)) = (find("plnmf-cpu"), find("fasthals-cpu")) {
            let d = p
                .trace
                .iter()
                .zip(&h.trace)
                .map(|(a, b)| (a.rel_error - b.rel_error).abs())
                .fold(0.0f64, f64::max);
            out.push((ds, d));
        }
    }
    out
}

pub fn run(scale: Scale, out_dir: &Path) -> Result<()> {
    run_sel(scale, out_dir, &super::Selection::default())
}

pub fn run_sel(scale: Scale, out_dir: &Path, sel: &super::Selection) -> Result<()> {
    let k = sel.ks.as_ref().and_then(|v| v.first().copied()).unwrap_or(scale.k_single());
    let reports = run_datasets_engines(
        &sel.datasets(scale),
        k,
        scale,
        sel.iters,
        &sel.engines(default_engines()),
    )?;
    println!("Fig. 8 — relative error vs iterations (K={k})\n");
    // Render a compact per-iteration table per dataset.
    let datasets: std::collections::BTreeSet<_> =
        reports.iter().map(|r| r.dataset.clone()).collect();
    for ds in &datasets {
        println!("{ds}:");
        let group: Vec<&RunReport> = reports.iter().filter(|r| &r.dataset == ds).collect();
        print!("{:>6}", "iter");
        for g in &group {
            print!(" {:>14}", g.engine);
        }
        println!();
        let n = group.iter().map(|g| g.trace.len()).min().unwrap_or(0);
        let show = [0, n / 4, n / 2, 3 * n / 4, n.saturating_sub(1)];
        for &i in show.iter().filter(|&&i| i < n) {
            print!("{:>6}", group[0].trace[i].iter);
            for g in &group {
                print!(" {:>14.6}", g.trace[i].rel_error);
            }
            println!();
        }
    }
    for (ds, d) in hals_family_divergence(&reports) {
        println!("HALS-family max per-iteration divergence on {ds}: {d:.2e}");
    }
    write_comparison_csv(&out_dir.join("fig8_convergence.csv"), &reports)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trajectories_align_on_tiny() {
        let reports = run_datasets(&["tiny"], 6, Scale::Small).unwrap();
        let div = hals_family_divergence(&reports);
        assert_eq!(div.len(), 1);
        assert!(div[0].1 < 5e-3, "divergence {}", div[0].1);
        // MU is never better than HALS at the shared final iteration.
        let hals = reports.iter().find(|r| r.engine == "fasthals-cpu").unwrap();
        let mu = reports.iter().find(|r| r.engine == "mu-cpu").unwrap();
        assert!(
            hals.final_rel_error <= mu.final_rel_error + 1e-6,
            "hals {} mu {}",
            hals.final_rel_error,
            mu.final_rel_error
        );
    }
}

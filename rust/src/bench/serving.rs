//! Serving-layer throughput: docs/sec of batched factor projection at
//! micro-batch sizes 1 / 32 / 512.
//!
//! The measurement behind the serving layer's design claim: batching
//! amortizes kernel dispatch and turns per-query dot products into panel
//! GEMMs against the cached Gram, so per-doc cost falls as the
//! micro-batch grows (until the working set leaves cache). Run via
//! `cargo bench --bench serving_throughput` or `plnmf bench serving`.

use std::path::Path;
use std::sync::Arc;

use crate::bench::harness::{measure, row, BenchOpts};
use crate::bench::Scale;
use crate::data::{load_dataset, DataMatrix};
use crate::linalg::Mat;
use crate::nmf::Factors;
use crate::parallel::{pool::default_threads, ThreadPool};
use crate::serve::{Projector, ProjectorOpts, Queries};
use crate::Result;

use super::report::write_csv;

/// Micro-batch sizes the CSV and the acceptance criterion reference.
pub const BATCH_SIZES: [usize; 3] = [1, 32, 512];

pub fn run(scale: Scale, out: &Path) -> Result<()> {
    run_with(scale, out, BenchOpts::default())
}

/// [`run`] with explicit measurement options (tests pass fast settings
/// directly instead of tunneling them through env vars).
pub fn run_with(scale: Scale, out: &Path, bench_opts: BenchOpts) -> Result<()> {
    let dataset = match scale {
        Scale::Small => "20news-small",
        Scale::Paper => "20news",
    };
    let k = scale.k_single();
    let ds = load_dataset(dataset, 42)?;
    let threads = default_threads();
    let pool = Arc::new(ThreadPool::new(threads));

    // Throughput does not depend on factor quality, so skip training and
    // serve a seeded random model of the right shape.
    let factors = Factors::random(ds.v(), ds.d(), k, 42);

    // Query set: the first ≤512 documents (columns of A, rows of Aᵀ),
    // so every batch size projects the same work list.
    let n_docs = ds.d().min(512);
    enum Owned {
        Dense(Mat),
        Sparse(crate::sparse::Csr),
    }
    let owned = match &ds.at {
        DataMatrix::Sparse(c) => Owned::Sparse(c.slice_rows(0, n_docs)),
        DataMatrix::Dense(m) => {
            Owned::Dense(Mat::from_fn(n_docs, m.cols(), |i, j| m.at(i, j)))
        }
    };
    let queries = match &owned {
        Owned::Dense(m) => Queries::Dense(m),
        Owned::Sparse(c) => Queries::Sparse(c),
    };

    println!(
        "serving throughput on {dataset} (V={}, K={k}, {n_docs} docs, {threads} threads):\n",
        ds.v()
    );
    let mut rows = Vec::new();
    for &mb in &BATCH_SIZES {
        let opts = ProjectorOpts { sweeps: 8, micro_batch: mb, ..Default::default() };
        let projector = Projector::new(factors.w.clone(), pool.clone(), opts);
        let s = measure(bench_opts, || {
            projector.project(queries).expect("projection failed");
        });
        let docs_per_sec = n_docs as f64 / s.median;
        println!(
            "{}  [{:.1} docs/s]",
            row(&format!("project micro-batch={mb:>3}"), &s),
            docs_per_sec
        );
        rows.push(format!(
            "{dataset},{k},{mb},{n_docs},{:.6},{:.1}",
            s.median, docs_per_sec
        ));
    }
    let csv = out.join("serving_throughput.csv");
    write_csv(&csv, "dataset,k,batch,docs,secs_median,docs_per_sec", &rows)?;
    println!("\nCSV: {}", csv.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_throughput_csv() {
        // Tiny smoke run of the full bench path: no training happens —
        // only projection runs, with single-rep measurement.
        let dir = std::env::temp_dir().join(format!("plnmf-servebench-{}", std::process::id()));
        run_with(Scale::Small, &dir, BenchOpts { warmup: 0, reps: 1 }).unwrap();
        let body = std::fs::read_to_string(dir.join("serving_throughput.csv")).unwrap();
        assert!(body.starts_with("dataset,k,batch,docs"));
        assert_eq!(body.lines().count(), 1 + BATCH_SIZES.len());
        std::fs::remove_dir_all(dir).ok();
    }
}

//! Serving-layer throughput: docs/sec of batched factor projection at
//! micro-batch sizes 1 / 32 / 512, plus the daemon and routed round
//! trips.
//!
//! Three measurements back the serving layer's design claims:
//!
//! 1. **Batching** (in-process): batching amortizes kernel dispatch and
//!    turns per-query dot products into panel GEMMs against the cached
//!    Gram, so per-doc cost falls as the micro-batch grows (until the
//!    working set leaves cache).
//! 2. **Residency + warm starts** (daemon): a `plnmf serve` round trip
//!    pays TCP + JSON once but *keeps the model resident* — no per-call
//!    model load or Gram build — and a repeated batch hits the warm
//!    cache, cutting sweeps-to-tol. The bench reports cold vs warm
//!    round-trip docs/sec and the per-micro-batch sweep counts.
//! 3. **Routing overhead**: the same round trip through a `plnmf route`
//!    front (one extra TCP hop + request inspection + byte relay) next
//!    to the direct-daemon rows — what cross-process sharding costs per
//!    request.
//! 4. **Replication scaling** (`routed_replicated_r{N}` rows): one
//!    model behind 1 / 2 / 4 replicas, hammered by 2 concurrent clients
//!    per replica. Each replica is its own worker (own registry + pool)
//!    and the router's least-loaded pick spreads the load, so wall-
//!    clock throughput should grow with N until the machine runs out of
//!    cores — the replica fan-out's headline number.
//! 5. **Binary framing** (`binary_*` vs `dense_json_*` rows): the same
//!    large dense batch (256×128) shipped as PLNB v2 raw-f32 frames and
//!    as v1 JSON text, direct to a daemon (cold + warm) and through a
//!    router. JSON encode/decode dominates round-trip time at this
//!    batch size — the binary rows are the wire-level data-movement
//!    saving, measured.
//! 6. **Mixed-loss serving** (`kl_cold`/`kl_warm` rows): the same
//!    daemon round trip against the same model file, with the loss
//!    flipped to KL by a manifest-style spec override — what the
//!    multiplicative KL projection costs per request next to the
//!    tiled-HALS rows, and how much its warm cache claws back.
//! 7. **Hot swap under load** (`swap_under_load`/`swap_update` rows):
//!    sustained transform traffic against one daemon while `update`
//!    batches publish new factor epochs in the background. The
//!    transform row shows serving never pauses for a swap (the
//!    registry's epoch publish is a single map insert); the update row
//!    is the fold-in + republish cost per batch.
//!
//! Run via `cargo bench --bench serving_throughput` or `plnmf bench
//! serving`.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::bench::harness::{measure, row, BenchOpts};
use crate::bench::Scale;
use crate::data::{load_dataset, DataMatrix};
use crate::linalg::Mat;
use crate::nmf::{Factors, Loss};
use crate::parallel::{pool::default_threads, ThreadPool};
use crate::serve::{
    queries_to_json, save_model, Client, ModelMeta, ModelRegistry, OwnedQueries, Projector,
    ProjectorOpts, RegistryOpts, Router, RouterOpts, Server, SpecOverride,
};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::util::Timer;
use crate::Result;

use super::report::write_csv;

/// Micro-batch sizes the CSV and the acceptance criterion reference.
pub const BATCH_SIZES: [usize; 3] = [1, 32, 512];

/// Docs per daemon round trip (kept modest: the payload is JSON text).
const DAEMON_DOCS: usize = 128;

/// Replica counts of the `routed_replicated` scaling rows.
pub const REPLICA_COUNTS: [usize; 3] = [1, 2, 4];

/// Docs per request in the replicated rows (smaller than
/// [`DAEMON_DOCS`]: many concurrent requests in flight at once).
const REPL_DOCS: usize = 32;

/// Transform requests each concurrent client sends per replica count.
const REPL_REQS_PER_CLIENT: usize = 4;

/// Dense-batch shape of the binary-vs-JSON framing rows — at 256×128
/// the JSON text is ~4× the raw f32 payload and its encode/decode
/// dominates the round trip (the acceptance floor for the PLNB rows).
pub const BINARY_DOCS: usize = 256;
pub const BINARY_V: usize = 128;

/// Factor epochs the swap-under-load pass publishes via `update`.
const SWAP_EPOCHS: usize = 3;

/// New user rows folded in per `update` batch.
const SWAP_UPDATE_ROWS: usize = 16;

pub fn run(scale: Scale, out: &Path) -> Result<()> {
    run_with(scale, out, BenchOpts::default())
}

/// First `n` rows of an owned batch (the daemon round trip uses a
/// smaller slice of the same work list).
fn head(q: &OwnedQueries, n: usize) -> OwnedQueries {
    match q {
        OwnedQueries::Dense(m) => {
            let n = n.min(m.rows());
            OwnedQueries::Dense(Mat::from_fn(n, m.cols(), |i, j| m.at(i, j)))
        }
        OwnedQueries::Sparse(c) => OwnedQueries::Sparse(c.slice_rows(0, n.min(c.rows()))),
    }
}

/// [`run`] with explicit measurement options (tests pass fast settings
/// directly instead of tunneling them through env vars).
pub fn run_with(scale: Scale, out: &Path, bench_opts: BenchOpts) -> Result<()> {
    let dataset = match scale {
        Scale::Small => "20news-small",
        Scale::Paper => "20news",
    };
    let k = scale.k_single();
    let ds = load_dataset(dataset, 42)?;
    let threads = default_threads();
    let pool = Arc::new(ThreadPool::new(threads));

    // Throughput does not depend on factor quality, so skip training and
    // serve a seeded random model of the right shape.
    let factors = Factors::random(ds.v(), ds.d(), k, 42);

    // Query set: the first ≤512 documents (columns of A, rows of Aᵀ),
    // so every batch size projects the same work list.
    let n_docs = ds.d().min(512);
    let owned = match &ds.at {
        DataMatrix::Sparse(c) => OwnedQueries::Sparse(c.slice_rows(0, n_docs)),
        DataMatrix::Dense(m) => {
            OwnedQueries::Dense(Mat::from_fn(n_docs, m.cols(), |i, j| m.at(i, j)))
        }
    };
    let queries = owned.as_queries();

    println!(
        "serving throughput on {dataset} (V={}, K={k}, {n_docs} docs, {threads} threads):\n",
        ds.v()
    );
    let mut rows = Vec::new();
    for &mb in &BATCH_SIZES {
        let opts = ProjectorOpts { sweeps: 8, micro_batch: mb, ..Default::default() };
        let projector = Projector::new(factors.w.clone(), pool.clone(), opts)?;
        let s = measure(bench_opts, || {
            projector.project(queries).expect("projection failed");
        });
        let docs_per_sec = n_docs as f64 / s.median;
        println!(
            "{}  [{:.1} docs/s]",
            row(&format!("project micro-batch={mb:>3}"), &s),
            docs_per_sec
        );
        rows.push(format!(
            "{dataset},{k},{mb},{n_docs},{:.6},{:.1}",
            s.median, docs_per_sec
        ));
    }
    let csv = out.join("serving_throughput.csv");
    write_csv(&csv, "dataset,k,batch,docs,secs_median,docs_per_sec", &rows)?;
    println!("\nCSV: {}", csv.display());

    let mut daemon_rows = daemon_roundtrip(dataset, k, &factors, &owned, threads)?;
    daemon_rows.extend(router_roundtrip(dataset, k, &factors, &owned, threads)?);
    daemon_rows.extend(replicated_roundtrip(dataset, k, &factors, &owned, threads)?);
    daemon_rows.extend(binary_roundtrip(dataset, k, threads)?);
    daemon_rows.extend(kl_roundtrip(dataset, k, &factors, &owned, threads)?);
    daemon_rows.extend(swap_under_load(dataset, k, &factors, &owned, threads)?);
    let csv = out.join("serving_daemon.csv");
    write_csv(
        &csv,
        "dataset,k,docs,mode,secs,docs_per_sec,sweeps,micro_batches,warm_hits",
        &daemon_rows,
    )?;
    println!("CSV: {}", csv.display());
    Ok(())
}

/// The pinned daemon fleet options both round-trip benches use (one
/// model, whole pool, warm cache on — so the direct and routed rows
/// differ only by the extra hop).
fn bench_registry_opts(threads: usize) -> RegistryOpts {
    RegistryOpts {
        threads,
        per_model_threads: threads,
        projector: ProjectorOpts { sweeps: 30, micro_batch: 32, tol: 1e-5, ..Default::default() },
        warm_cache: 2 * DAEMON_DOCS,
        max_total_nnz: 0,
        update_sweeps: 20,
    }
}

/// One cold + one warm transform round trip through `client`; returns
/// the CSV rows (`mode_prefix` distinguishes direct from routed).
fn roundtrip_rows(
    client: &mut Client,
    req: &Json,
    dataset: &str,
    k: usize,
    docs: usize,
    mode_prefix: &str,
    label: &str,
) -> Result<Vec<String>> {
    let mut rows = Vec::new();
    for mode in ["cold", "warm"] {
        let t = Timer::start();
        let resp = client.request_ok(req)?;
        let secs = t.elapsed_secs();
        let sweeps = resp.get("warm").get("sweeps").as_usize().unwrap_or(0);
        let batches = resp.get("warm").get("micro_batches").as_usize().unwrap_or(0);
        let hits = resp.get("warm").get("hits").as_usize().unwrap_or(0);
        let docs_per_sec = docs as f64 / secs.max(1e-12);
        println!(
            "{label} transform ({mode})   {secs:>10.4} s  [{docs_per_sec:.1} docs/s]  \
             sweeps {sweeps} over {batches} micro-batches, {hits} warm hits"
        );
        rows.push(format!(
            "{dataset},{k},{docs},{mode_prefix}{mode},{secs:.6},{docs_per_sec:.1},\
             {sweeps},{batches},{hits}"
        ));
    }
    Ok(rows)
}

/// S1b: daemon round-trip docs/sec, cold vs warm-cache-hit, against the
/// in-process numbers above.
fn daemon_roundtrip(
    dataset: &str,
    k: usize,
    factors: &Factors,
    owned: &OwnedQueries,
    threads: usize,
) -> Result<Vec<String>> {
    let dir = std::env::temp_dir().join(format!("plnmf-daemonbench-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let model_path = dir.join("bench-model.json");
    save_model(&model_path, factors, &ModelMeta::default())?;

    let registry = ModelRegistry::new(bench_registry_opts(threads));
    registry.load("bench", &model_path)?;
    let server = Server::bind(Arc::new(registry), "127.0.0.1", 0)?;
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run());

    let sub = head(owned, DAEMON_DOCS);
    let docs = sub.as_queries().rows();
    let req = Json::obj(vec![
        ("op", Json::str("transform")),
        ("model", Json::str("bench")),
        ("queries", queries_to_json(sub.as_queries())),
    ]);
    let mut client = Client::connect(addr)?;

    println!("\ndaemon round trip ({docs} docs over TCP/JSON, model resident):\n");
    let rows = roundtrip_rows(&mut client, &req, dataset, k, docs, "", "daemon")?;
    let stats = client.request_ok(&Json::obj(vec![("op", Json::str("stats"))]))?;
    let model = stats.get("models").get("bench");
    println!(
        "stats: cold avg sweeps/micro-batch {:.1} vs warm {:.1}",
        model.get("cold").get("avg_sweeps").as_f64().unwrap_or(0.0),
        model.get("warm").get("avg_sweeps").as_f64().unwrap_or(0.0),
    );
    client.request_ok(&Json::obj(vec![("op", Json::str("shutdown"))]))?;
    handle.join().map_err(|_| anyhow::anyhow!("server thread panicked"))??;
    std::fs::remove_dir_all(dir).ok();
    Ok(rows)
}

/// S1c: the same round trip through a `plnmf route` front — the routed
/// rows' delta against the direct rows is the per-request cost of
/// cross-process sharding (extra TCP hop + request inspection + relay).
/// The worker here is an in-process `Server` addressed by `host:port`
/// (the router does not care where a worker lives), so the bench stays
/// self-contained in the library.
fn router_roundtrip(
    dataset: &str,
    k: usize,
    factors: &Factors,
    owned: &OwnedQueries,
    threads: usize,
) -> Result<Vec<String>> {
    let dir = std::env::temp_dir().join(format!("plnmf-routebench-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let model_path = dir.join("bench-model.json");
    save_model(&model_path, factors, &ModelMeta::default())?;

    // Fresh registry so the routed cold row is genuinely cold.
    let registry = ModelRegistry::new(bench_registry_opts(threads));
    registry.load("bench", &model_path)?;
    let worker = Server::bind(Arc::new(registry), "127.0.0.1", 0)?;
    let worker_addr = worker.local_addr();
    let worker_handle = std::thread::spawn(move || worker.run());

    let router =
        Router::with_external_workers(&[("bench", worker_addr)], RouterOpts::default())?;
    let addr = router.local_addr();
    let router_handle = std::thread::spawn(move || router.run());

    let sub = head(owned, DAEMON_DOCS);
    let docs = sub.as_queries().rows();
    let req = Json::obj(vec![
        ("op", Json::str("transform")),
        ("model", Json::str("bench")),
        ("queries", queries_to_json(sub.as_queries())),
    ]);
    let mut client = Client::connect(addr)?;

    println!("\nrouted round trip (same payload through the `plnmf route` front):\n");
    let rows = roundtrip_rows(&mut client, &req, dataset, k, docs, "routed_", "routed")?;
    // Router shutdown drains, then stops its fleet — including the
    // external worker, whose server thread then joins cleanly.
    client.request_ok(&Json::obj(vec![("op", Json::str("shutdown"))]))?;
    router_handle.join().map_err(|_| anyhow::anyhow!("router thread panicked"))??;
    worker_handle.join().map_err(|_| anyhow::anyhow!("worker thread panicked"))??;
    std::fs::remove_dir_all(dir).ok();
    Ok(rows)
}

/// S1d: replication scaling — the same model behind 1 / 2 / 4 replicas
/// (each an in-process `Server` with its own registry and pool, the
/// per-process shape `plnmf route` spawns), driven by 2 concurrent
/// clients per replica. Warm caching is OFF so every request costs the
/// same solve and the rows measure routing + parallelism, not cache
/// luck.
fn replicated_roundtrip(
    dataset: &str,
    k: usize,
    factors: &Factors,
    owned: &OwnedQueries,
    threads: usize,
) -> Result<Vec<String>> {
    let dir = std::env::temp_dir().join(format!("plnmf-replbench-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let model_path = dir.join("bench-model.json");
    save_model(&model_path, factors, &ModelMeta::default())?;

    let sub = head(owned, REPL_DOCS);
    let docs_per_req = sub.as_queries().rows();
    let req = Json::obj(vec![
        ("op", Json::str("transform")),
        ("model", Json::str("bench")),
        ("queries", queries_to_json(sub.as_queries())),
    ]);

    println!(
        "\nreplicated routed throughput ({docs_per_req}-doc transforms, 2 clients per \
         replica, {REPL_REQS_PER_CLIENT} requests each, warm cache off):\n"
    );
    let mut rows = Vec::new();
    for &n in &REPLICA_COUNTS {
        // N identical workers: the machine's threads split across them,
        // like `plnmf route` splits threads across worker processes.
        let per_replica_threads = (threads / n).max(1);
        let mut addrs = Vec::with_capacity(n);
        let mut worker_handles = Vec::with_capacity(n);
        for _ in 0..n {
            let registry = ModelRegistry::new(RegistryOpts {
                threads: per_replica_threads,
                per_model_threads: per_replica_threads,
                projector: ProjectorOpts { sweeps: 8, micro_batch: 32, ..Default::default() },
                warm_cache: 0,
                max_total_nnz: 0,
                update_sweeps: 20,
            });
            registry.load("bench", &model_path)?;
            let server = Server::bind(Arc::new(registry), "127.0.0.1", 0)?;
            addrs.push(server.local_addr());
            worker_handles.push(std::thread::spawn(move || server.run()));
        }
        let externals: Vec<(&str, std::net::SocketAddr)> =
            addrs.iter().map(|&a| ("bench", a)).collect();
        let router = Router::with_external_workers(&externals, RouterOpts::default())?;
        let addr = router.local_addr();
        let router_handle = std::thread::spawn(move || router.run());

        let clients = 2 * n;
        let t = Timer::start();
        let per_client: Vec<(usize, usize, usize)> = std::thread::scope(|s| {
            let joins: Vec<_> = (0..clients)
                .map(|_| {
                    let req = &req;
                    s.spawn(move || -> Result<(usize, usize, usize)> {
                        let mut client = Client::connect(addr)?;
                        let (mut sweeps, mut batches, mut hits) = (0, 0, 0);
                        for _ in 0..REPL_REQS_PER_CLIENT {
                            let resp = client.request_ok(req)?;
                            sweeps += resp.get("warm").get("sweeps").as_usize().unwrap_or(0);
                            batches +=
                                resp.get("warm").get("micro_batches").as_usize().unwrap_or(0);
                            hits += resp.get("warm").get("hits").as_usize().unwrap_or(0);
                        }
                        Ok((sweeps, batches, hits))
                    })
                })
                .collect();
            joins
                .into_iter()
                .map(|j| j.join().expect("bench client thread panicked"))
                .collect::<Result<Vec<_>>>()
        })?;
        let secs = t.elapsed_secs();
        let total_docs = clients * REPL_REQS_PER_CLIENT * docs_per_req;
        let docs_per_sec = total_docs as f64 / secs.max(1e-12);
        let sweeps: usize = per_client.iter().map(|r| r.0).sum();
        let batches: usize = per_client.iter().map(|r| r.1).sum();
        let hits: usize = per_client.iter().map(|r| r.2).sum();
        println!(
            "routed replicated (r={n})     {secs:>10.4} s  [{docs_per_sec:.1} docs/s]  \
             {total_docs} docs over {clients} clients"
        );
        rows.push(format!(
            "{dataset},{k},{total_docs},routed_replicated_r{n},{secs:.6},{docs_per_sec:.1},\
             {sweeps},{batches},{hits}"
        ));

        // One shutdown drains the router, which then stops every
        // replica — all worker server threads join cleanly.
        let mut shut = Client::connect(addr)?;
        shut.request_ok(&Json::obj(vec![("op", Json::str("shutdown"))]))?;
        router_handle.join().map_err(|_| anyhow::anyhow!("router thread panicked"))??;
        for h in worker_handles {
            h.join().map_err(|_| anyhow::anyhow!("worker thread panicked"))??;
        }
    }
    std::fs::remove_dir_all(dir).ok();
    Ok(rows)
}

/// One timed dense transform via [`Client::transform_dense`] (the
/// framing follows the client's negotiated protocol) → one CSV row.
fn dense_row(
    client: &mut Client,
    q: &Mat,
    dataset: &str,
    k: usize,
    prefix: &str,
    mode: &str,
) -> Result<String> {
    let docs = q.rows();
    let t = Timer::start();
    let (h, _res, meta) = client.transform_dense("bench", q, true)?;
    let secs = t.elapsed_secs();
    anyhow::ensure!(h.rows() == docs, "short transform response: {} rows", h.rows());
    let warm = meta.get("warm");
    let sweeps = warm.get("sweeps").as_usize().unwrap_or(0);
    let batches = warm.get("micro_batches").as_usize().unwrap_or(0);
    let hits = warm.get("hits").as_usize().unwrap_or(0);
    let docs_per_sec = docs as f64 / secs.max(1e-12);
    println!(
        "{prefix}{mode} transform   {secs:>10.4} s  [{docs_per_sec:.1} docs/s]  \
         sweeps {sweeps} over {batches} micro-batches, {hits} warm hits"
    );
    Ok(format!(
        "{dataset},{k},{docs},{prefix}{mode},{secs:.6},{docs_per_sec:.1},{sweeps},{batches},{hits}"
    ))
}

/// S1e: PLNB v2 binary framing vs its JSON twin on the same large
/// dense batch — direct to a daemon (cold + warm rows) and through a
/// router front (one trip each). Every pass gets a fresh daemon so its
/// cold row is genuinely cold; the only variable between twin rows is
/// the wire framing, so the delta is pure encode/transfer/decode cost.
fn binary_roundtrip(dataset: &str, k: usize, threads: usize) -> Result<Vec<String>> {
    let dir = std::env::temp_dir().join(format!("plnmf-binbench-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let model_path = dir.join("bench-model.json");
    let factors = Factors::random(BINARY_V, 16, k, 4242);
    save_model(&model_path, &factors, &ModelMeta::default())?;
    let mut rng = Pcg32::seeded(7);
    let q = Mat::random(BINARY_DOCS, BINARY_V, &mut rng, 0.0, 1.0);

    let opts = RegistryOpts {
        threads,
        per_model_threads: threads,
        projector: ProjectorOpts { sweeps: 30, micro_batch: 32, tol: 1e-5, ..Default::default() },
        warm_cache: 2 * BINARY_DOCS,
        max_total_nnz: 0,
        update_sweeps: 20,
    };
    type DaemonHandle = std::thread::JoinHandle<Result<()>>;
    let start_daemon = |opts: RegistryOpts| -> Result<(std::net::SocketAddr, DaemonHandle)> {
        let registry = ModelRegistry::new(opts);
        registry.load("bench", &model_path)?;
        let server = Server::bind(Arc::new(registry), "127.0.0.1", 0)?;
        let addr = server.local_addr();
        Ok((addr, std::thread::spawn(move || server.run())))
    };

    println!(
        "\nbinary (PLNB v2) vs JSON framing ({BINARY_DOCS}x{BINARY_V} dense batch, \
         model resident):\n"
    );
    let mut rows = Vec::new();
    for (prefix, negotiate) in [("dense_json_", false), ("binary_", true)] {
        let (addr, handle) = start_daemon(opts)?;
        let mut client = Client::connect(addr)?;
        if negotiate {
            anyhow::ensure!(client.negotiate()? == 2, "daemon did not negotiate PLNB v2");
        }
        for mode in ["cold", "warm"] {
            rows.push(dense_row(&mut client, &q, dataset, k, prefix, mode)?);
        }
        client.request_ok(&Json::obj(vec![("op", Json::str("shutdown"))]))?;
        handle.join().map_err(|_| anyhow::anyhow!("server thread panicked"))??;
    }
    for (prefix, negotiate) in [("dense_json_", false), ("binary_", true)] {
        let (worker_addr, worker_handle) = start_daemon(opts)?;
        let router =
            Router::with_external_workers(&[("bench", worker_addr)], RouterOpts::default())?;
        let addr = router.local_addr();
        let router_handle = std::thread::spawn(move || router.run());
        let mut client = Client::connect(addr)?;
        if negotiate {
            anyhow::ensure!(client.negotiate()? == 2, "router did not negotiate PLNB v2");
        }
        rows.push(dense_row(&mut client, &q, dataset, k, prefix, "routed")?);
        client.request_ok(&Json::obj(vec![("op", Json::str("shutdown"))]))?;
        router_handle.join().map_err(|_| anyhow::anyhow!("router thread panicked"))??;
        worker_handle.join().map_err(|_| anyhow::anyhow!("worker thread panicked"))??;
    }
    std::fs::remove_dir_all(dir).ok();
    Ok(rows)
}

/// S1f: mixed-loss serving — the daemon round trip of S1b repeated with
/// the model's loss flipped to KL (plus an L1 penalty) via the same
/// spec-override surface a fleet manifest uses. The `kl_cold`/`kl_warm`
/// delta against the plain `cold`/`warm` rows is the per-request price
/// of the multiplicative KL projection vs tiled HALS, and the warm row
/// shows the KL warm cache paying off on a repeated batch.
fn kl_roundtrip(
    dataset: &str,
    k: usize,
    factors: &Factors,
    owned: &OwnedQueries,
    threads: usize,
) -> Result<Vec<String>> {
    let dir = std::env::temp_dir().join(format!("plnmf-klbench-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let model_path = dir.join("bench-model.json");
    save_model(&model_path, factors, &ModelMeta::default())?;

    let registry = ModelRegistry::new(bench_registry_opts(threads));
    registry.load_with(
        "bench",
        &model_path,
        SpecOverride { loss: Some(Loss::Kl), alpha: Some(0.1), l1_ratio: Some(1.0) },
    )?;
    let server = Server::bind(Arc::new(registry), "127.0.0.1", 0)?;
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run());

    let sub = head(owned, DAEMON_DOCS);
    let docs = sub.as_queries().rows();
    let req = Json::obj(vec![
        ("op", Json::str("transform")),
        ("model", Json::str("bench")),
        ("queries", queries_to_json(sub.as_queries())),
    ]);
    let mut client = Client::connect(addr)?;

    println!("\nKL round trip (same payload, loss flipped by spec override):\n");
    let rows = roundtrip_rows(&mut client, &req, dataset, k, docs, "kl_", "kl")?;
    client.request_ok(&Json::obj(vec![("op", Json::str("shutdown"))]))?;
    handle.join().map_err(|_| anyhow::anyhow!("server thread panicked"))??;
    std::fs::remove_dir_all(dir).ok();
    Ok(rows)
}

/// S1g: hot swap under load — one client hammers `transform` while the
/// main thread publishes [`SWAP_EPOCHS`] factor epochs via `update`.
/// Every transform must succeed (a failed request fails the bench):
/// the registry's epoch publish is a lock-free-to-readers map insert,
/// so swaps never pause serving. The `swap_under_load` row is the
/// transform throughput *measured across the swaps*; the `swap_update`
/// row is the fold-in + republish cost per batch.
fn swap_under_load(
    dataset: &str,
    k: usize,
    factors: &Factors,
    owned: &OwnedQueries,
    threads: usize,
) -> Result<Vec<String>> {
    let dir = std::env::temp_dir().join(format!("plnmf-swapbench-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let model_path = dir.join("bench-model.json");
    save_model(&model_path, factors, &ModelMeta::default())?;

    let registry = ModelRegistry::new(bench_registry_opts(threads));
    registry.load("bench", &model_path)?;
    let server = Server::bind(Arc::new(registry), "127.0.0.1", 0)?;
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run());

    let sub = head(owned, REPL_DOCS);
    let docs_per_req = sub.as_queries().rows();
    let req = Json::obj(vec![
        ("op", Json::str("transform")),
        ("model", Json::str("bench")),
        ("queries", queries_to_json(sub.as_queries())),
    ]);
    let mut rng = Pcg32::seeded(99);
    let batch = Mat::random(SWAP_UPDATE_ROWS, factors.w.rows(), &mut rng, 0.0, 1.0);

    println!(
        "\nhot swap under load ({SWAP_EPOCHS} `update` epochs of {SWAP_UPDATE_ROWS} rows \
         vs sustained {docs_per_req}-doc transforms):\n"
    );
    let stop = AtomicBool::new(false);
    let t = Timer::start();
    let (traffic, upd) = std::thread::scope(|s| {
        let req = &req;
        let stop = &stop;
        let jt = s.spawn(move || -> Result<(usize, usize, usize, usize)> {
            let mut client = Client::connect(addr)?;
            let (mut reqs, mut sweeps, mut batches, mut hits) = (0usize, 0usize, 0usize, 0usize);
            loop {
                let resp = client.request_ok(req)?;
                let warm = resp.get("warm");
                sweeps += warm.get("sweeps").as_usize().unwrap_or(0);
                batches += warm.get("micro_batches").as_usize().unwrap_or(0);
                hits += warm.get("hits").as_usize().unwrap_or(0);
                reqs += 1;
                if stop.load(Ordering::SeqCst) {
                    return Ok((reqs, sweeps, batches, hits));
                }
            }
        });
        let upd = (|| -> Result<(f64, usize, usize, usize, usize)> {
            let mut client = Client::connect(addr)?;
            let tu = Timer::start();
            let (mut epoch, mut sweeps, mut batches, mut hits) = (0usize, 0usize, 0usize, 0usize);
            for _ in 0..SWAP_EPOCHS {
                let resp = client.update_dense("bench", &batch, None)?;
                epoch = resp.get("epoch").as_usize().unwrap_or(0);
                let warm = resp.get("warm");
                sweeps += warm.get("sweeps").as_usize().unwrap_or(0);
                batches += warm.get("micro_batches").as_usize().unwrap_or(0);
                hits += warm.get("hits").as_usize().unwrap_or(0);
            }
            Ok((tu.elapsed_secs(), epoch, sweeps, batches, hits))
        })();
        // Raise the stop flag even when an update failed, so the scope
        // never hangs waiting on the traffic loop.
        stop.store(true, Ordering::SeqCst);
        (jt.join().expect("traffic thread panicked"), upd)
    });
    let secs = t.elapsed_secs();
    let (reqs, sweeps, batches, hits) = traffic?;
    let (upd_secs, epoch, upd_sweeps, upd_batches, upd_hits) = upd?;
    anyhow::ensure!(
        epoch >= SWAP_EPOCHS,
        "expected >= {SWAP_EPOCHS} published epochs, daemon reports {epoch}"
    );

    let total_docs = reqs * docs_per_req;
    let docs_per_sec = total_docs as f64 / secs.max(1e-12);
    let upd_rows = SWAP_EPOCHS * SWAP_UPDATE_ROWS;
    let rows_per_sec = upd_rows as f64 / upd_secs.max(1e-12);
    println!(
        "swap under load     {secs:>10.4} s  [{docs_per_sec:.1} docs/s]  \
         {reqs} transforms, 0 failed, across {SWAP_EPOCHS} epoch swaps (now at epoch {epoch})"
    );
    println!(
        "swap update         {upd_secs:>10.4} s  [{rows_per_sec:.1} rows/s]  \
         {upd_rows} rows folded over {SWAP_EPOCHS} batches"
    );
    let rows = vec![
        format!(
            "{dataset},{k},{total_docs},swap_under_load,{secs:.6},{docs_per_sec:.1},\
             {sweeps},{batches},{hits}"
        ),
        format!(
            "{dataset},{k},{upd_rows},swap_update,{upd_secs:.6},{rows_per_sec:.1},\
             {upd_sweeps},{upd_batches},{upd_hits}"
        ),
    ];

    let mut shut = Client::connect(addr)?;
    shut.request_ok(&Json::obj(vec![("op", Json::str("shutdown"))]))?;
    handle.join().map_err(|_| anyhow::anyhow!("server thread panicked"))??;
    std::fs::remove_dir_all(dir).ok();
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_throughput_and_daemon_csvs() {
        // Tiny smoke run of the full bench path: no training happens —
        // only projection runs, with single-rep measurement, plus one
        // cold + one warm daemon round trip.
        let dir = std::env::temp_dir().join(format!("plnmf-servebench-{}", std::process::id()));
        run_with(Scale::Small, &dir, BenchOpts { warmup: 0, reps: 1 }).unwrap();
        let body = std::fs::read_to_string(dir.join("serving_throughput.csv")).unwrap();
        assert!(body.starts_with("dataset,k,batch,docs"));
        assert_eq!(body.lines().count(), 1 + BATCH_SIZES.len());

        let daemon = std::fs::read_to_string(dir.join("serving_daemon.csv")).unwrap();
        assert!(daemon.starts_with("dataset,k,docs,mode"));
        let lines: Vec<&str> = daemon.lines().collect();
        assert_eq!(
            lines.len(),
            15 + REPLICA_COUNTS.len(),
            "header + direct cold/warm + routed cold/warm + replicated r1/r2/r4 + \
             dense-json/binary cold/warm/routed twins + kl cold/warm + \
             swap_under_load/swap_update: {daemon}"
        );
        assert!(lines[1].contains(",cold,"));
        assert!(lines[2].contains(",warm,"));
        assert!(lines[3].contains(",routed_cold,"));
        assert!(lines[4].contains(",routed_warm,"));
        for (i, n) in REPLICA_COUNTS.iter().enumerate() {
            let line = lines[5 + i];
            assert!(
                line.contains(&format!(",routed_replicated_r{n},")),
                "replica scaling row r={n} missing: {daemon}"
            );
            let docs_per_sec: f64 = line.split(',').nth(5).unwrap().parse().unwrap();
            assert!(docs_per_sec > 0.0, "throughput must be measured: {line}");
        }
        // Binary rows and their JSON twins, all on the large dense
        // batch the acceptance criterion names.
        for (i, mode) in [
            "dense_json_cold",
            "dense_json_warm",
            "binary_cold",
            "binary_warm",
            "dense_json_routed",
            "binary_routed",
        ]
        .iter()
        .enumerate()
        {
            let line = lines[5 + REPLICA_COUNTS.len() + i];
            assert!(line.contains(&format!(",{mode},")), "row {mode} missing: {daemon}");
            let docs: usize = line.split(',').nth(2).unwrap().parse().unwrap();
            assert_eq!(docs, BINARY_DOCS, "{mode} must use the {BINARY_DOCS}-doc batch");
        }
        // The warm pass must not sweep more than the cold pass — on the
        // direct, routed, and binary paths alike.
        let sweeps = |line: &str| -> usize {
            line.split(',').nth(6).unwrap().parse().unwrap()
        };
        assert!(sweeps(lines[2]) <= sweeps(lines[1]), "{daemon}");
        assert!(sweeps(lines[4]) <= sweeps(lines[3]), "{daemon}");
        let bin_base = 5 + REPLICA_COUNTS.len();
        assert!(sweeps(lines[bin_base + 3]) <= sweeps(lines[bin_base + 2]), "{daemon}");
        // Mixed-loss rows: the KL round trip on the same query batch,
        // cold then warm, with the warm cache doing no worse.
        let kl_base = bin_base + 6;
        assert!(lines[kl_base].contains(",kl_cold,"), "kl_cold row missing: {daemon}");
        assert!(lines[kl_base + 1].contains(",kl_warm,"), "kl_warm row missing: {daemon}");
        assert!(sweeps(lines[kl_base + 1]) <= sweeps(lines[kl_base]), "{daemon}");
        // Hot-swap rows: transform throughput measured across epoch
        // swaps (with zero failures, or the bench would have bailed),
        // and the fold-in cost of the SWAP_EPOCHS update batches.
        let swap_base = kl_base + 2;
        assert!(
            lines[swap_base].contains(",swap_under_load,"),
            "swap_under_load row missing: {daemon}"
        );
        assert!(
            lines[swap_base + 1].contains(",swap_update,"),
            "swap_update row missing: {daemon}"
        );
        let swap_docs: usize = lines[swap_base].split(',').nth(2).unwrap().parse().unwrap();
        assert!(swap_docs > 0, "swaps must not starve the transform traffic: {daemon}");
        let folded: usize = lines[swap_base + 1].split(',').nth(2).unwrap().parse().unwrap();
        assert_eq!(folded, SWAP_EPOCHS * SWAP_UPDATE_ROWS, "{daemon}");
        std::fs::remove_dir_all(dir).ok();
    }
}
